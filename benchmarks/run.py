"""Benchmark harness: one module per paper table/figure.

``PYTHONPATH=src python -m benchmarks.run [--only name]``

Output contract: each benchmark prints ``name,us_per_call,derived`` as its
final line (details as '#' comments above it). Exit code is non-zero if any
benchmark fails its paper-claim check.
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback

BENCHMARKS = [
    "table2_pairs",     # Tbl. 2  pair-type statistics
    "fig3_prune",       # Fig. 3  clip vs prune-victim vs prune-random
    "fig5_abfloat",     # Fig. 5  abfloat config sweep (E2M1 wins)
    "table6_accuracy",  # Tbl. 6/7/8 SQNR vs baselines
    "table9_llm",       # Tbl. 9  model-level PTQ perplexity
    "speedup",          # Fig. 9/10 roofline-translated speedup
    "kernels_bench",    # kernel correctness + decode-path timing
    "ablation_threshold",  # §3.4 scale/threshold selection ablation
]


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="run a single benchmark by name")
    args = ap.parse_args()
    names = [args.only] if args.only else BENCHMARKS

    print("name,us_per_call,derived")
    failures = []
    for name in names:
        t0 = time.time()
        try:
            mod = __import__(f"benchmarks.{name}", fromlist=["main"])
            rc = mod.main()
            if rc:
                failures.append(name)
        except Exception:
            traceback.print_exc()
            print(f"{name},-1,EXCEPTION")
            failures.append(name)
        print(f"# [{name}] wall={time.time()-t0:.1f}s", file=sys.stderr)

    if failures:
        print(f"# FAILED: {failures}", file=sys.stderr)
        return 1
    print("# all benchmarks passed their paper-claim checks",
          file=sys.stderr)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
