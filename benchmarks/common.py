"""Shared benchmark infrastructure.

Two tensor sources back every accuracy benchmark:
  1. `trained_lm()` — a small dense transformer trained in-repo on the
     synthetic bigram corpus until held-out perplexity is far below the
     unigram entropy. Its weights/activations are the "real model" tensors.
  2. `transformer_like()` — synthetic heavy-tailed tensors calibrated to the
     paper's Fig. 2 measurements (Max-σ up to ~325, >3σ fraction ≲0.5%),
     because a 4M-param LM trained for minutes does not develop OPT-scale
     outliers; the paper's phenomenon is injected with measured statistics.

Everything is cached under EXPERIMENTS/bench_cache so reruns are cheap.
"""
from __future__ import annotations

import json
import os
import time
from functools import partial
from typing import Callable, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

CACHE = os.path.join(os.path.dirname(__file__), "..", "EXPERIMENTS",
                     "bench_cache")

# Small LM used across benchmarks (dense GQA transformer, ~4M params).
LM_STEPS = int(os.environ.get("BENCH_LM_STEPS", "400"))
LM_SEQ = 128
LM_BATCH = 16


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    """The run.py output contract: ``name,us_per_call,derived`` CSV."""
    print(f"{name},{us_per_call:.1f},{derived}")


def timer(fn: Callable, *args, warmup: int = 1, iters: int = 5) -> float:
    """Median wall time per call in microseconds (after warmup/jit)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts)) * 1e6


# --------------------------------------------------------------------------
# Synthetic transformer-like tensors (Fig. 2 statistics)
# --------------------------------------------------------------------------
def transformer_like(key, shape, max_sigma: float = 60.0,
                     outlier_frac: float = 0.003) -> jax.Array:
    """Gaussian bulk + sparse symmetric outliers up to ``max_sigma``·σ.

    Matches the paper's Fig. 2 transformer profile: >3σ fraction well under
    0.5%, maxima one to two orders above σ. Outlier magnitudes are
    log-uniform in [4σ, max_sigma·σ] so every abfloat exponent bucket is
    exercised (the Fig. 5 sweep needs the full dynamic range).
    """
    kb, km, ks, kg = jax.random.split(key, 4)
    x = jax.random.normal(kb, shape)
    mask = jax.random.uniform(km, shape) < outlier_frac
    logmag = jax.random.uniform(ks, shape, minval=jnp.log(4.0),
                                maxval=jnp.log(max_sigma))
    sign = jnp.sign(jax.random.normal(kg, shape))
    out = sign * jnp.exp(logmag)
    return jnp.where(mask, out, x).astype(jnp.float32)


# --------------------------------------------------------------------------
# The trained small LM (shared fixture, cached)
# --------------------------------------------------------------------------
def _lm_cfg():
    from repro.configs.base import ArchConfig
    return ArchConfig(
        name="bench-lm", family="dense", n_layers=4, d_model=128,
        n_heads=4, n_kv_heads=2, d_ff=384, vocab=512, head_dim=32,
        block_pattern=("attn",), source="in-repo benchmark fixture")


def _corpus():
    from repro.data.synthetic import CorpusCfg
    return CorpusCfg(vocab=512, seed=1234)


def trained_lm(steps: int = LM_STEPS):
    """Returns (model_fp, params_fp32, loader). Cached after first train."""
    from repro.core.policy import QuantPolicy
    from repro.data.loader import LoaderCfg, SyntheticLoader
    from repro.models.model import build_model
    from repro.optim.adamw import AdamW
    from repro.train.train_step import init_state, make_train_step

    cfg = _lm_cfg()
    loader = SyntheticLoader(LoaderCfg(global_batch=LM_BATCH, seq_len=LM_SEQ,
                                       corpus=_corpus()))
    model = build_model(cfg, QuantPolicy(compute_dtype="float32"),
                        remat=False)

    os.makedirs(CACHE, exist_ok=True)
    path = os.path.join(CACHE, f"bench_lm_{steps}.npz")
    if os.path.exists(path):
        raw = np.load(path)
        params = model.init(jax.random.PRNGKey(0), dtype=jnp.float32)
        flat, treedef = jax.tree_util.tree_flatten(params)
        flat = [jnp.asarray(raw[f"a{i}"]) for i in range(len(flat))]
        return model, jax.tree_util.tree_unflatten(treedef, flat), loader

    opt = AdamW(lr=3e-3, weight_decay=0.0, moment_dtype=jnp.float32)
    state = init_state(model, opt, jax.random.PRNGKey(0))
    step_fn = jax.jit(make_train_step(model, opt))
    t0 = time.time()
    for s in range(steps):
        state, metrics = step_fn(state, loader.batch_at(s))
        if s % 100 == 0:
            print(f"# bench-lm step {s}: loss={float(metrics['loss']):.3f}")
    print(f"# bench-lm trained {steps} steps in {time.time()-t0:.0f}s, "
          f"final loss={float(metrics['loss']):.3f}")
    flat, _ = jax.tree_util.tree_flatten(state.params)
    np.savez(path, **{f"a{i}": np.asarray(v) for i, v in enumerate(flat)})
    return model, state.params, loader


def eval_ppl(model, params, loader, n_batches: int = 4) -> float:
    """Held-out perplexity of a (possibly quantized) parameter set."""
    from repro.train.train_step import lm_loss

    @jax.jit
    def ce(params, batch):
        _, parts = lm_loss(model, params, batch)
        return parts["ce"]

    tot = 0.0
    for s in range(n_batches):
        batch = loader.batch_at(s, eval_split=True)
        tot += float(ce(params, batch))
    return float(np.exp(tot / n_batches))


def weight_tensors(params, min_size: int = 4096) -> Dict[str, np.ndarray]:
    """Flatten the trained LM's linear weights (the PTQ targets)."""
    from repro.core.qlinear import is_linear_weight
    flat = jax.tree_util.tree_flatten_with_path(params)[0]
    out = {}
    for kp, w in flat:
        path = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in kp)
        if hasattr(w, "ndim") and w.ndim >= 2 and w.size >= min_size \
                and is_linear_weight(path, w):
            out[path] = np.asarray(w, np.float32)
    return out


def footprint(params) -> int:
    """Total parameter bytes, counting packed QuantizedTensor storage."""
    from repro.core.ovp import QuantizedTensor
    tot = 0
    for leaf in jax.tree_util.tree_leaves(
            params, is_leaf=lambda x: isinstance(x, QuantizedTensor)):
        if isinstance(leaf, QuantizedTensor):
            tot += leaf.nbytes()
        else:
            tot += leaf.size * leaf.dtype.itemsize
    return tot


def save_json(name: str, obj) -> str:
    os.makedirs(CACHE, exist_ok=True)
    path = os.path.join(CACHE, name + ".json")
    with open(path, "w") as f:
        json.dump(obj, f, indent=1, default=float)
    return path
