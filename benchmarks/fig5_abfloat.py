"""Paper Fig. 5: which 4-bit abfloat config (E0M3/E1M2/E2M1/E3M0) quantizes
the largest outliers with least error? The paper picks E2M1.

We draw the top outliers (the values OVP stores as abfloat) from
transformer-like tensors across the paper's Max-σ range and measure mean
relative rounding error per config, using the nearest-representable oracle.
E3M0 has range but no mantissa; E0M3 has precision but clips the range;
E2M1 balances both — the paper's conclusion, reproduced numerically.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.datatypes import (NORMAL_MAX, AbfloatSpec, abfloat_nearest,
                                  default_bias)

from . import common

CONFIGS = [("E0M3", 0, 3), ("E1M2", 1, 2), ("E2M1", 2, 1), ("E3M0", 3, 0)]


def main() -> int:
    t0 = time.perf_counter()
    key = jax.random.PRNGKey(11)
    errs = {name: [] for name, _, _ in CONFIGS}

    # Max-σ sweep per Fig. 2: transformer tensors peak anywhere from ~20σ
    # to ~325σ. The scale maps 3σ -> int4 max (the quantizer's init), so a
    # value at mσ lands at m/3*7 in scaled units.
    for max_sigma in (20.0, 60.0, 150.0, 325.0):
        x = common.transformer_like(key, (512, 2048), max_sigma=max_sigma,
                                    outlier_frac=0.004)
        sd = jnp.std(x)
        scale = 3.0 * sd / NORMAL_MAX["int4"]
        u = x / scale
        mags = jnp.abs(u.reshape(-1))
        k = 2048  # the largest outliers, as in Fig. 5
        top = jax.lax.top_k(mags, k)[0]
        for name, eb, mb in CONFIGS:
            spec = AbfloatSpec(ebits=eb, mb=mb,
                               bias=default_bias("int4", mb))
            got = abfloat_nearest(top, spec)
            rel = jnp.mean(jnp.abs(got - top) / top)
            errs[name].append(float(rel))

    print("# Fig. 5 analogue: mean relative error of the largest outliers")
    print("# config, err@20σ, err@60σ, err@150σ, err@325σ, mean")
    means = {}
    for name, _, _ in CONFIGS:
        e = errs[name]
        means[name] = float(np.mean(e))
        print(f"#   {name}: " + "  ".join(f"{v:7.4f}" for v in e)
              + f"   mean={means[name]:.4f}")

    best = min(means, key=means.get)
    ok = best == "E2M1"
    us = (time.perf_counter() - t0) * 1e6
    common.emit("fig5_abfloat", us,
                f"best={best} e2m1_err={means['E2M1']:.4f} "
                f"paper_choice_confirmed={ok}")
    common.save_json("fig5_abfloat", {"errs": errs, "best": best})
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
