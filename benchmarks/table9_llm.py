"""Paper Table 9 (model-level analogue): held-out perplexity of the
in-repo trained LM under each PTQ method — the end-to-end accuracy claim.

Paper's finding (perplexity, lower better):
  8-bit OliVe ≈ FP32;  4-bit OliVe close to FP32;
  int4 / 4-bit ANT collapse (orders of magnitude worse);
  GOBO (weights-only, fp16 compute) matches FP32 but gives no compute win.

Here, a 4M-param LM trained on the synthetic corpus does not develop
OPT-6.7B-scale outliers, so int4's collapse is milder — the *ordering* is
the reproduced claim, with deltas recorded. We additionally evaluate the
*outlier-equivalent* variant (fig3_prune.outlier_equivalent): a
function-identical transform of the same trained model whose weights carry
genuine functional outlier channels — on it, outlier-blind 4-bit methods
degrade sharply while OliVe holds, exactly the paper's >6B observation.
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import baselines
from repro.core.policy import QuantPolicy
from repro.core.qlinear import is_linear_weight, quantize_params
from repro.models.model import build_model

from . import common


def _map_weights(params, fn):
    flat = jax.tree_util.tree_flatten_with_path(params)[0]
    treedef = jax.tree_util.tree_structure(params)
    out = []
    for kp, w in flat:
        path = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in kp)
        if hasattr(w, "ndim") and w.ndim >= 2 and w.size >= 4096 \
                and is_linear_weight(path, w):
            out.append(fn(jnp.asarray(w, jnp.float32)))
        else:
            out.append(w)
    return jax.tree_util.tree_unflatten(treedef, out)


def _eval(cfg, policy, params, loader) -> float:
    model = build_model(cfg, policy, remat=False)
    return common.eval_ppl(model, params, loader)


def run_suite(cfg, params, loader, tag: str):
    fp = QuantPolicy(compute_dtype="float32")
    rows = {}
    rows["fp32"] = _eval(cfg, fp, params, loader)

    def ptq(policy):
        return quantize_params(params, policy)

    # OliVe (the paper): W4A4, W8A8, and weights-only W4
    p44 = QuantPolicy(method="olive", wbits=4, abits=4,
                      compute_dtype="float32")
    rows["olive_w4a4"] = _eval(cfg, p44, ptq(p44), loader)
    p88 = QuantPolicy(method="olive", wbits=8, abits=8,
                      w_normal_dtype="int8", a_normal_dtype="int8",
                      compute_dtype="float32")
    rows["olive_w8a8"] = _eval(cfg, p88, ptq(p88), loader)
    pw4 = QuantPolicy(method="olive", wbits=4, abits=0,
                      compute_dtype="float32")
    rows["olive_w4"] = _eval(cfg, pw4, ptq(pw4), loader)

    # baselines
    pi8 = QuantPolicy(method="int", wbits=8, abits=8,
                      compute_dtype="float32")
    rows["int8_w8a8"] = _eval(cfg, pi8, ptq(pi8), loader)
    pi4 = QuantPolicy(method="int", wbits=4, abits=4,
                      compute_dtype="float32")
    rows["int4_w4a4"] = _eval(cfg, pi4, ptq(pi4), loader)
    pa4 = QuantPolicy(method="ant", wbits=4, abits=4,
                      compute_dtype="float32")
    rows["ant_w4a4"] = _eval(cfg, pa4, ptq(pa4), loader)
    # GOBO: weights-only, fp compute (its GPU deployment mode)
    gparams = _map_weights(params,
                           lambda w: baselines.gobo_fake_quant(w, 4)[0])
    rows["gobo_w4"] = _eval(cfg, fp, gparams, loader)

    print(f"# Table 9 analogue [{tag}]: held-out perplexity")
    for k, v in rows.items():
        print(f"#   {k:12s} ppl={v:9.3f}  (+{100*(v/rows['fp32']-1):7.2f}%)")
    return rows


def main() -> int:
    t0 = time.perf_counter()
    model, params, loader = common.trained_lm()
    cfg = model.cfg

    rows = run_suite(cfg, params, loader, "trained-lm")
    # the >6B outlier regime: the function-identical outlier-equivalent
    # transform of the SAME model (functional outlier channels)
    from .fig3_prune import outlier_equivalent
    oparams = outlier_equivalent(params)
    orows = run_suite(cfg, oparams, loader, "trained-lm+outliers")

    def rel(r, k):
        return r[k] / r["fp32"] - 1.0

    # claims: olive8 ≈ fp32; olive4 within a few percent; olive4 beats the
    # 4-bit baselines; and under injected outliers the baseline gap widens
    ok = (rel(rows, "olive_w8a8") < 0.01
          and rel(rows, "olive_w4a4") < 0.10
          and rows["olive_w4a4"] <= rows["int4_w4a4"]
          and rows["olive_w4a4"] <= rows["ant_w4a4"]
          and rel(orows, "olive_w4a4") < 0.25
          and orows["int4_w4a4"] / orows["olive_w4a4"] > 1.5)

    us = (time.perf_counter() - t0) * 1e6
    common.emit(
        "table9_llm", us,
        f"olive4=+{100*rel(rows,'olive_w4a4'):.2f}% "
        f"int4=+{100*rel(rows,'int4_w4a4'):.2f}% "
        f"outlier_regime_int4/olive4="
        f"{orows['int4_w4a4']/orows['olive_w4a4']:.1f}x claims_ok={ok}")
    common.save_json("table9_llm", {"plain": rows, "outlier": orows,
                                    "ok": bool(ok)})
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
