"""Kernel microbenchmarks: the fused OVP-decode matmul vs oracles.

On this CPU container the Pallas kernels run in interpret mode (Python
emulation — correctness, not speed), so the numbers that matter are:
  1. allclose of pallas-interpret vs the pure-jnp oracle (correctness),
  2. wall time of the XLA decode-and-matmul path vs an fp32 matmul at the
     same logical shape (the decode prologue's overhead on CPU), and
  3. the HBM-traffic ratio (packed bytes vs bf16/fp32 bytes) — the term
     that governs TPU performance (see speedup.py / §Perf).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.ovp import ovp_dequantize, ovp_quantize
from repro.kernels import ops, ref

from . import common


def main() -> int:
    t0 = time.perf_counter()
    key = jax.random.PRNGKey(0)
    m, k, n = 256, 512, 256
    ka, kw = jax.random.split(key)
    a = common.transformer_like(ka, (m, k), max_sigma=40.0)
    w = common.transformer_like(kw, (k, n), max_sigma=40.0)

    wq = ovp_quantize(w, jnp.std(w) * 3 / 7, "int4", pair_axis=0)
    aq = ovp_quantize(a, jnp.std(a) * 3 / 7, "int4", pair_axis=1)

    # 1) correctness: pallas interpret vs oracle
    got16 = ops.matmul_w4a16(a, wq.data, jnp.asarray(wq.scale),
                             interpret=True)
    want16 = ref.ovp_matmul_w4a16_ref(a, wq.data) * wq.scale
    err16 = float(jnp.max(jnp.abs(got16 - want16))
                  / (jnp.max(jnp.abs(want16)) + 1e-9))
    got4 = ops.matmul_w4a4(aq.data, jnp.asarray(aq.scale), wq.data,
                           jnp.asarray(wq.scale), interpret=True)
    want4 = (ref.ovp_matmul_w4a4_ref(aq.data, wq.data)
             * aq.scale * wq.scale)
    err4 = float(jnp.max(jnp.abs(got4 - want4))
                 / (jnp.max(jnp.abs(want4)) + 1e-9))
    ok = err16 < 1e-5 and err4 < 1e-5

    # 2) XLA decode-path timing vs plain matmul (CPU; the TPU story is the
    #    bandwidth ratio, but the decode must not be catastrophic even here)
    @jax.jit
    def xla_path(a, wq):
        return a @ ovp_dequantize(wq, dtype=jnp.float32)

    @jax.jit
    def plain(a, w):
        return a @ w

    us_q = common.timer(xla_path, a, wq)
    us_p = common.timer(plain, a, w)

    # 3) traffic ratio
    bytes_packed = wq.nbytes()
    bytes_bf16 = w.size * 2
    bytes_f32 = w.size * 4

    print("# kernel correctness: max rel err "
          f"w4a16={err16:.2e} w4a4={err4:.2e}")
    print(f"# xla decode-matmul {us_q:.0f}us vs plain fp32 {us_p:.0f}us "
          f"({m}x{k}x{n})")
    print(f"# weight bytes: packed={bytes_packed} bf16={bytes_bf16} "
          f"fp32={bytes_f32} (ratios {bytes_bf16/bytes_packed:.2f}x / "
          f"{bytes_f32/bytes_packed:.2f}x)")

    us = (time.perf_counter() - t0) * 1e6
    common.emit("kernels_bench", us,
                f"err16={err16:.1e} err4={err4:.1e} "
                f"xla_decode_us={us_q:.0f} plain_us={us_p:.0f} "
                f"traffic_vs_bf16={bytes_bf16/bytes_packed:.2f}x ok={ok}")
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
