"""Kernel microbenchmarks: the fused OVP matmul vs oracles, the fused
single-dispatch path vs the unfused encode -> matmul -> scale pipeline,
and the grouped per-expert (MoE) path vs the XLA broadcast fallback.

On this CPU container the Pallas kernels run in interpret mode (Python
emulation — correctness, not speed), so the numbers that matter are:
  1. allclose of pallas-interpret vs the pure-jnp oracle (correctness),
  2. wall time of the XLA decode-and-matmul path vs an fp32 matmul at the
     same logical shape (the decode prologue's overhead on CPU),
  3. the HBM-traffic ratio (packed bytes vs bf16/fp32 bytes) — the term
     that governs TPU performance (see speedup.py / §Perf), and
  4. the dispatch-count delta of the fused backend: one pallas_call vs
     the unfused XLA-encode -> kernel-decode -> XLA-scale round trip
     (which also writes + rereads the packed activation tensor in HBM),
  5. the grouped MoE path: stacked (E, K, N) expert weights must serve on
     the grouped kernel with ZERO fallbacks to the XLA broadcast — any
     decline is reported with its machine-readable reason from
     `backends.dispatch_stats()` and fails the benchmark,
  6. the static calibrated prologue vs the dynamic 3σ one (2-D and
     grouped): same kernel count, bit-identical-scale numerics, and the
     wall-time delta of dropping the per-step std + the per-row scale
     operand — measured, not asserted (see docs/calibration.md),
  7. decode attention over the OVP-packed KV cache at serving shapes:
     the fused per-tile kernel vs the seed's full-cache-dequant-then-XLA
     path (wall + equivalence + the ~4x HBM read ratio), and a tiny
     quantized-cache ServingEngine run that must show ZERO
     decode-attention fallbacks — any fallback exits nonzero (see
     docs/kv_cache.md),
  8. the paged KV-cache subsystem: HBM held per request (block-table
     pages vs the fixed slab row), max concurrent requests at a fixed
     HBM budget (the paging headline — must be >= 2x with real contexts
     at a quarter of max_len), the fused cache-write prefill serve wall
     vs the slab's prefill-then-splice, and a quantized PAGED engine run
     that must serve decode AND prefill attention fused with zero
     fallbacks — any paged-path fallback exits nonzero,
  9. async streaming serve latency: the paged+chunked quantized engine
     driven through the asyncio front end (serve/frontend.py) with a
     MetricsLedger — per-request TTFT/TPOT distributions land in
     ``EXPERIMENTS/bench_cache/serve_trace.jsonl`` (the JSONL trace
     speedup.py reads back); the run must be token-for-token identical
     to the drained loop and show zero quantized-path fallbacks,
 10. the sharded backends (backends/sharded.py): with >= 2 devices
     (CI forces 8 via XLA_FLAGS) a (data, model) mesh is installed and
     the column-parallel TP matmul, expert-parallel grouped stack, and
     Hkv-sharded packed-cache decode must each reproduce the
     single-device kernel BIT-IDENTICALLY with zero ``shard_*``
     declines — any sharded-path fallback fails the benchmark; the
     JSON records the per-device packed-weight and KV-pool bytes
     shrinking by the model-axis factor (see docs/sharding.md).

``BENCH_SMOKE=1`` (or ``--smoke``) shrinks every shape so CI can run the
whole file in interpret mode in seconds; results land in
``EXPERIMENTS/bench_cache/kernels_bench.json`` either way (the CI smoke
job uploads that file as an artifact).
"""
from __future__ import annotations

import dataclasses
import os
import sys
import time

import jax
import jax.numpy as jnp

from repro import backends
from repro.core.ovp import ovp_dequantize, ovp_quantize
from repro.core.policy import QuantPolicy
from repro.core.qlinear import quantize_weight
from repro.core.quantizer import sigma_init_scale
from repro.kernels import ops, ref
from repro.kernels import ovp_matmul as raw_kernels

from . import common

count_pallas_calls = backends.count_pallas_calls


def _smoke_requested() -> bool:
    return os.environ.get("BENCH_SMOKE", "") not in ("", "0") \
        or "--smoke" in sys.argv[1:]


def main() -> int:
    t0 = time.perf_counter()
    smoke = _smoke_requested()
    m, k, n = (64, 128, 64) if smoke else (256, 512, 256)
    n_experts, cap = (2, 16) if smoke else (8, 64)
    key = jax.random.PRNGKey(0)
    ka, kw = jax.random.split(key)
    a = common.transformer_like(ka, (m, k), max_sigma=40.0)
    w = common.transformer_like(kw, (k, n), max_sigma=40.0)

    wq = ovp_quantize(w, jnp.std(w) * 3 / 7, "int4", pair_axis=0)
    aq = ovp_quantize(a, jnp.std(a) * 3 / 7, "int4", pair_axis=1)

    # 1) correctness: pallas interpret vs oracle
    got16 = ops.matmul_w4a16(a, wq.data, jnp.asarray(wq.scale),
                             interpret=True)
    want16 = ref.ovp_matmul_w4a16_ref(a, wq.data) * wq.scale
    err16 = float(jnp.max(jnp.abs(got16 - want16))
                  / (jnp.max(jnp.abs(want16)) + 1e-9))
    got4 = ops.matmul_w4a4(aq.data, jnp.asarray(aq.scale), wq.data,
                           jnp.asarray(wq.scale), interpret=True)
    want4 = (ref.ovp_matmul_w4a4_ref(aq.data, wq.data)
             * aq.scale * wq.scale)
    err4 = float(jnp.max(jnp.abs(got4 - want4))
                 / (jnp.max(jnp.abs(want4)) + 1e-9))
    ok = err16 < 1e-5 and err4 < 1e-5

    # 2) XLA decode-path timing vs plain matmul (CPU; the TPU story is the
    #    bandwidth ratio, but the decode must not be catastrophic even here)
    @jax.jit
    def xla_path(a, wq):
        return a @ ovp_dequantize(wq, dtype=jnp.float32)

    @jax.jit
    def plain(a, w):
        return a @ w

    us_q = common.timer(xla_path, a, wq)
    us_p = common.timer(plain, a, w)

    # 3) traffic ratio
    bytes_packed = wq.nbytes()
    bytes_bf16 = w.size * 2
    bytes_f32 = w.size * 4

    # 4) fused single-dispatch path vs the unfused pipeline it replaced:
    #    XLA-side encode kernel -> packed tensor -> decode matmul kernel ->
    #    XLA scale multiply (3 dispatches + an HBM round trip of the packed
    #    activations) vs ONE pallas_call with the in-kernel prologue.
    a_scale = sigma_init_scale(a, "int4")

    def fused(a, a_scale):
        return ops.fused_ovp_matmul(a, wq, a_dtype="int4",
                                    act_scale=a_scale, interpret=True)

    def unfused(a, a_scale):
        packed = ops.ovp_encode(a, a_scale, interpret=True)
        scaled_units = raw_kernels.ovp_matmul_w4a4(packed, wq.data,
                                                   interpret=True)
        return scaled_units * a_scale * jnp.asarray(wq.scale)

    n_fused = count_pallas_calls(fused, a, a_scale)
    n_unfused = count_pallas_calls(unfused, a, a_scale)
    out_fused = fused(a, a_scale)
    out_unfused = unfused(a, a_scale)
    err_fuse = float(jnp.max(jnp.abs(out_fused - out_unfused))
                     / (jnp.max(jnp.abs(out_unfused)) + 1e-9))
    us_fused = common.timer(jax.jit(fused), a, a_scale)
    us_unfused = common.timer(jax.jit(unfused), a, a_scale)
    pallas = backends.get_backend("pallas")
    xla_b = backends.get_backend("xla")
    ok = ok and err_fuse < 1e-5 and n_fused == pallas.dispatches_per_matmul \
        and n_fused < n_unfused

    # 5) grouped per-expert (MoE) path: stacked weights on the expert grid
    #    dim vs the XLA broadcast fallback they used to take. The dispatch
    #    ledger must show the stack SERVED on the kernel backend — any
    #    "->fallback:<reason>[stacked]" entry fails the benchmark.
    ke, kxg = jax.random.split(jax.random.PRNGKey(1))
    xg = common.transformer_like(kxg, (n_experts, cap, k), max_sigma=20.0)
    ws = common.transformer_like(ke, (n_experts, k, n), max_sigma=20.0)
    moe_pol = QuantPolicy(method="olive", wbits=4, abits=0,
                          w_granularity="tensor", compute_dtype="float32",
                          backend="pallas_interpret")
    wq_moe = quantize_weight(ws, moe_pol)

    backends.reset_dispatch_stats()

    def moe_grouped(xg):
        return backends.dispatch(xg, wq_moe, moe_pol)

    n_moe = count_pallas_calls(moe_grouped, xg)
    stats = backends.dispatch_stats()
    moe_fallbacks = sum(v for tag, v in stats.items()
                        if "->fallback:" in tag and "[stacked]" in tag)
    out_moe = moe_grouped(xg)
    want_moe = backends.dispatch(
        xg, wq_moe, dataclasses.replace(moe_pol, backend="xla"))
    err_moe = float(jnp.max(jnp.abs(out_moe - want_moe))
                    / (jnp.max(jnp.abs(want_moe)) + 1e-9))
    us_moe = common.timer(jax.jit(moe_grouped), xg)
    us_moe_xla = common.timer(jax.jit(
        lambda xg: backends.dispatch(
            xg, wq_moe, dataclasses.replace(moe_pol, backend="xla"))), xg)
    # declined layouts carry machine-readable reasons, not prose: a rank-4
    # weight stack is the one layout the grouped kernel still declines
    decline_r4 = pallas.decline_reason(
        xg[None], dataclasses.replace(wq_moe, data=wq_moe.data[None]),
        moe_pol)
    decline_lhs = pallas.decline_reason(xg[0, 0], wq_moe, moe_pol)
    ok = ok and err_moe < 1e-5 and moe_fallbacks == 0 \
        and n_moe == pallas.dispatches_per_matmul

    # 6) static calibrated prologue vs dynamic 3σ: the dynamic pipeline
    #    recomputes a full-tensor std and streams a per-row scale plane
    #    every step; the static path passes the calibrated scale as one
    #    (1, 1) scalar operand. At the same scale value the outputs must
    #    agree to fp32 rounding (per-row divide vs scalar reciprocal
    #    multiply), and both stay a single pallas_call.
    s_cal = float(a_scale)

    def dyn_prologue(a):
        return ops.fused_ovp_matmul(a, wq, a_dtype="int4",
                                    act_scale=sigma_init_scale(a, "int4"),
                                    interpret=True)

    def static_prologue(a):
        return ops.fused_ovp_matmul(a, wq, a_dtype="int4",
                                    static_act_scale=s_cal, interpret=True)

    err_static = float(jnp.max(jnp.abs(static_prologue(a) - fused(a,
                                                                  a_scale)))
                       / (jnp.max(jnp.abs(out_fused)) + 1e-9))
    n_static = count_pallas_calls(static_prologue, a)
    us_dynp = common.timer(jax.jit(dyn_prologue), a)
    us_statp = common.timer(jax.jit(static_prologue), a)

    def grouped_static(xg):
        return ops.grouped_ovp_matmul(xg, wq_moe, a_dtype="int4",
                                      static_act_scale=s_cal,
                                      interpret=True)

    def grouped_dyn(xg):
        return ops.grouped_ovp_matmul(
            xg, wq_moe, a_dtype="int4",
            act_scale=jnp.full(xg.shape[:-1], s_cal), interpret=True)

    err_gstatic = float(jnp.max(jnp.abs(grouped_static(xg)
                                        - grouped_dyn(xg)))
                        / (jnp.max(jnp.abs(grouped_dyn(xg))) + 1e-9))
    us_gdyn = common.timer(jax.jit(grouped_dyn), xg)
    us_gstat = common.timer(jax.jit(grouped_static), xg)
    ok = ok and err_static < 1e-5 and err_gstatic < 1e-5 and n_static == 1

    # 7) decode attention over the OVP-packed KV cache: the fused kernel
    #    (per-tile unpack in VMEM, in-kernel masking) vs the seed path
    #    (dequantize the ENTIRE cache, then XLA einsum) at serving shapes,
    #    plus a tiny ServingEngine run that must show ZERO decode-attention
    #    fallbacks — any quantized-cache decode falling back to the dense
    #    path fails the benchmark (exit nonzero).
    from repro.kernels import decode_attn as DA
    from repro.models import layers as Lyr

    db, ds, dhkv, dg, dd = (2, 64, 2, 2, 32) if smoke else (8, 1024, 8, 4,
                                                            128)
    kd_rng = jax.random.split(jax.random.PRNGKey(2), 3)
    kv_cache = Lyr.make_kv_cache(db, ds, dhkv, dd, kv_bits=4)
    kc = common.transformer_like(kd_rng[0], (db, ds, dhkv, dd),
                                 max_sigma=20.0)
    vc = common.transformer_like(kd_rng[1], (db, ds, dhkv, dd),
                                 max_sigma=20.0)
    kv_cache = Lyr.cache_write(kv_cache, kc, vc,
                               jnp.zeros((db,), jnp.int32))
    qd = common.transformer_like(kd_rng[2], (db, 1, dhkv * dg, dd),
                                 max_sigma=10.0)
    # mixed active lengths in one batch — one compiled kernel serves all
    posd = jnp.asarray([(ds - 1) if i % 2 else ds // 2 + i
                        for i in range(db)], jnp.int32)

    fused_dec = jax.jit(lambda q, p: DA.fused_decode_attention(
        q, kv_cache, p, interpret=True, block_s=1024))
    dequant_dec = jax.jit(lambda q, p: DA.xla_decode_attention(
        q, kv_cache, p))
    # tight oracle: dense path on an f32 dequant (the legacy path rounds
    # the dequantized cache to bf16, the kernel keeps f32)
    kf, vf = DA.read_cache_dense(kv_cache, dtype=jnp.float32)
    want_dec = DA.xla_decode_attention(qd, {"k": kf, "v": vf}, posd)
    out_dec = fused_dec(qd, posd)
    err_dec = float(jnp.max(jnp.abs(out_dec - want_dec))
                    / (jnp.max(jnp.abs(want_dec)) + 1e-9))
    n_dec = count_pallas_calls(lambda q, p: DA.fused_decode_attention(
        q, kv_cache, p, interpret=True), qd, posd)
    us_dec_fused = common.timer(fused_dec, qd, posd)
    us_dec_dequant = common.timer(dequant_dec, qd, posd)
    # HBM read per step (the TPU-governing term): packed nibbles + scales
    # vs the dense bf16 cache the dequant path rematerializes (it also
    # WRITES that tensor first — counted once here as a read-side ratio)
    bytes_dec_packed = (kv_cache["k_data"].size + kv_cache["v_data"].size
                        + 4 * (kv_cache["k_scl"].size
                               + kv_cache["v_scl"].size))
    bytes_dec_dense = 2 * kc.size * 2                    # k+v in bf16
    # engine smoke: continuous batching over a quantized cache must serve
    # every decode-attention site on the fused kernel
    from repro.configs.base import ArchConfig
    from repro.serve.engine import EngineCfg, ServingEngine
    eng_cfg = ArchConfig(name="bench-kv4", family="dense", n_layers=2,
                         d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
                         vocab=256, head_dim=16, block_pattern=("attn",))
    eng_pol = QuantPolicy(method="olive", wbits=4, abits=0, kv_bits=4,
                          compute_dtype="float32",
                          backend="pallas_interpret")
    from repro.models.model import build_model
    eng_model = build_model(eng_cfg, eng_pol, remat=False)
    eng = ServingEngine(eng_model, eng_model.init(jax.random.PRNGKey(3)),
                        EngineCfg(batch_slots=2, max_len=64))
    import numpy as _np
    _rng = _np.random.default_rng(0)
    backends.reset_dispatch_stats()
    for nreq in (5, 9, 3):
        eng.submit(_rng.integers(0, 256, size=nreq).astype(_np.int32),
                   max_new_tokens=4)
    eng.run_until_drained()
    eng_stats = {k: v for k, v in backends.dispatch_stats().items()
                 if "[decode_attn]" in k}
    dec_fallbacks = sum(v for tag, v in eng_stats.items()
                        if "->fallback:" in tag)
    dec_served = eng_stats.get("pallas_interpret[decode_attn]", 0)
    ok = ok and err_dec < 1e-5 and n_dec == 1 \
        and dec_fallbacks == 0 and dec_served > 0

    # 8) paged OVP KV cache (serve/paging.py): the block-table pool vs the
    #    fixed (batch_slots, max_len) slab — HBM held per request, max
    #    concurrent requests at a FIXED HBM budget (the paging headline:
    #    must be >= 2x when real contexts run at a quarter of max_len),
    #    fused cache-write prefill vs the prefill-then-splice slab path,
    #    and a quantized paged engine run that must serve BOTH attention
    #    paths fused — any paged-path fallback exits nonzero.
    from repro.serve.paging import (PagePoolCfg, kv_bytes_per_token_per_site,
                                    max_concurrent_requests, pages_for,
                                    pool_pages_for_budget)
    pg_ps = 16
    pg_max_len, pg_real = (128, 32) if smoke else (2048, 512)
    pg_slots = 4 if smoke else 8
    bpt = kv_bytes_per_token_per_site(eng_cfg.n_kv_heads, eng_cfg.head_dim,
                                      4) * eng_cfg.n_layers
    slab_bytes_req = pg_max_len * bpt            # slab reserves max_len
    paged_bytes_req = pages_for(pg_real, pg_ps) * pg_ps * bpt
    hbm_budget = pg_slots * slab_bytes_req       # what the slab layout holds
    pool_pages = pool_pages_for_budget(hbm_budget, pg_ps, bpt)
    paged_concurrent = max_concurrent_requests(pool_pages, pg_ps,
                                               tokens_per_request=pg_real)
    concurrency_gain = paged_concurrent / pg_slots

    pg_prompts = [(5, 4), (40, 3), (24, 2), (9, 4)]

    def run_serve(page_pool=None, prefill_chunk=0):
        e = ServingEngine(eng_model,
                          eng_model.init(jax.random.PRNGKey(3)),
                          EngineCfg(batch_slots=2, max_len=64,
                                    backend="pallas_interpret",
                                    page_pool=page_pool,
                                    prefill_chunk=prefill_chunk))
        r = _np.random.default_rng(1)
        for nreq, mn in pg_prompts:
            e.submit(r.integers(0, 256, size=nreq).astype(_np.int32),
                     max_new_tokens=mn)
        t = time.perf_counter()
        done = e.run_until_drained()
        return e, (time.perf_counter() - t) * 1e6, \
            {q.uid: q.out_tokens for q in done}

    _, us_slab_serve, outs_slab = run_serve()        # prefill + splice
    backends.reset_dispatch_stats()
    eng_pg, us_paged_serve, outs_paged = run_serve(
        page_pool=PagePoolCfg(page_size=pg_ps))      # fused cache-write
    pg_stats = {k: v for k, v in backends.dispatch_stats().items()
                if "[decode_attn]" in k or "[prefill_attn]" in k}
    pg_fallbacks = sum(v for tag, v in pg_stats.items()
                       if "->fallback:" in tag)
    pg_prefill_served = pg_stats.get("pallas_interpret[prefill_attn]", 0)
    pg_pool_stats = eng_pg.stats()["page_pool"]
    ok = ok and pg_fallbacks == 0 and pg_prefill_served > 0 \
        and outs_paged == outs_slab and concurrency_gain >= 2.0 \
        and pg_pool_stats["used_pages"] == 0

    # 9) async streaming serve latency (serve/frontend.py + metrics.py):
    #    the same quantized paged+chunked engine driven through the
    #    asyncio front end with a MetricsLedger; the JSONL trace written
    #    to EXPERIMENTS/bench_cache/serve_trace.jsonl is the artifact
    #    speedup.py's serve section reads back. Gates: token-for-token
    #    identical output to the drained loop at the identical config,
    #    zero quantized-path fallbacks in the trace, and a TTFT recorded
    #    for every request.
    import asyncio
    from repro.serve.frontend import AsyncFrontend
    from repro.serve.metrics import MetricsLedger, load_trace

    sl_chunk = pg_ps     # chunked prefill on, one chunk per step
    _, _, outs_drained = run_serve(page_pool=PagePoolCfg(page_size=pg_ps),
                                   prefill_chunk=sl_chunk)

    async def run_async_serve():
        e = ServingEngine(eng_model, eng_model.init(jax.random.PRNGKey(3)),
                          EngineCfg(batch_slots=2, max_len=64,
                                    backend="pallas_interpret",
                                    page_pool=PagePoolCfg(page_size=pg_ps),
                                    prefill_chunk=sl_chunk))
        ledger = MetricsLedger()
        r = _np.random.default_rng(1)
        async with AsyncFrontend(e, metrics=ledger) as fe:
            streams = [fe.submit(r.integers(0, 256, size=nreq)
                                 .astype(_np.int32), max_new_tokens=mn)
                       for nreq, mn in pg_prompts]
            await fe.drain()
        return ledger, {s.uid: s.tokens for s in streams}

    sl_ledger, outs_async = asyncio.run(run_async_serve())
    sl_trace_path = os.path.join(common.CACHE, "serve_trace.jsonl")
    os.makedirs(common.CACHE, exist_ok=True)
    sl_ledger.write_jsonl(sl_trace_path)
    sl = load_trace(sl_trace_path)["summary"]
    sl_tokens_match = outs_async == outs_drained
    sl_ttft, sl_tpot = sl["ttft_s"], sl["tpot_s"]
    ok = ok and sl_tokens_match and sl["fallbacks"] == 0 \
        and sl_ttft["n"] == len(pg_prompts) \
        and sl["requests"] == len(pg_prompts)

    # 10) sharded backends (backends/sharded.py): the same fused kernels
    #     under shard_map on a (data, model) mesh. Needs >= 2 devices —
    #     CI forces 8 logical host CPUs via
    #     XLA_FLAGS=--xla_force_host_platform_device_count=8; on a plain
    #     single-device run the section records enabled=False and gates
    #     nothing. Gates when enabled: column-parallel TP, the
    #     expert-parallel grouped stack, and Hkv-sharded packed-cache
    #     decode each BIT-IDENTICAL to the single-device kernel, and
    #     zero "shard_*" declines anywhere on the sharded path. The
    #     headline numbers are the per-device bytes: the N-split packed
    #     weight and the Hkv-split KV pool both shrink by the
    #     model-axis factor (block tables replicate, bytes-negligible).
    sh_devices = jax.device_count()
    sh_enabled = sh_devices >= 2
    sh_tp = 2
    sh_col_bit = sh_ep_bit = sh_kv_bit = False
    sh_fallbacks = 0
    sh_stats = {}
    sh_pol = QuantPolicy(method="olive", wbits=4, abits=0,
                         compute_dtype="float32",
                         backend="pallas_sharded_interpret")
    wq_sh = quantize_weight(w, sh_pol)           # (K, N), per-channel scale
    sh_weight_total = wq_sh.nbytes()
    sh_pool_total = int(pool_pages * pg_ps * bpt)
    if sh_enabled:
        from repro.runtime.elastic import MeshPlan
        backends.configure_mesh(MeshPlan(shape=(sh_devices // sh_tp, sh_tp),
                                         axis_names=("data", "model"),
                                         dropped_devices=0))
        backends.reset_dispatch_stats()
        try:
            out_sh_col = backends.dispatch(a, wq_sh, sh_pol,
                                           site="blocks/0/attn/wq")
            out_1d_col = backends.dispatch(
                a, wq_sh, sh_pol.with_backend("pallas_interpret"),
                site="blocks/0/attn/wq")
            sh_col_bit = bool(jnp.array_equal(out_sh_col, out_1d_col))
            out_sh_moe = backends.dispatch(
                xg, wq_moe,
                dataclasses.replace(moe_pol,
                                    backend="pallas_sharded_interpret"))
            sh_ep_bit = bool(jnp.array_equal(out_sh_moe, out_moe))
            sh_kv_pol = dataclasses.replace(sh_pol, kv_bits=4)
            out_sh_dec = backends.decode_attention(qd, kv_cache, posd,
                                                   policy=sh_kv_pol)
            out_1d_dec = backends.decode_attention(
                qd, kv_cache, posd,
                policy=sh_kv_pol.with_backend("pallas_interpret"))
            sh_kv_bit = bool(jnp.array_equal(out_sh_dec, out_1d_dec))
            sh_stats = dict(backends.dispatch_stats())
            sh_fallbacks = sum(v for tag, v in sh_stats.items()
                               if "->fallback:shard" in tag)
        finally:
            backends.configure_mesh(None)
        ok = ok and sh_col_bit and sh_ep_bit and sh_kv_bit \
            and sh_fallbacks == 0

    print("# kernel correctness: max rel err "
          f"w4a16={err16:.2e} w4a4={err4:.2e}")
    print(f"# xla decode-matmul {us_q:.0f}us vs plain fp32 {us_p:.0f}us "
          f"({m}x{k}x{n})")
    print(f"# weight bytes: packed={bytes_packed} bf16={bytes_bf16} "
          f"fp32={bytes_f32} (ratios {bytes_bf16/bytes_packed:.2f}x / "
          f"{bytes_f32/bytes_packed:.2f}x)")
    print(f"# fused vs unfused W4A4 dispatch: {n_fused} pallas_call vs "
          f"{n_unfused} + XLA scale mul ({xla_b.dispatches_per_matmul} "
          f"dispatches end-to-end unfused); rel err {err_fuse:.1e}; "
          f"interpret-mode wall {us_fused:.0f}us vs {us_unfused:.0f}us; "
          f"packed-act HBM round trip eliminated: {a.size // 2} B/matmul")
    print(f"# grouped MoE ({n_experts}x{cap}x{k}x{n}): {n_moe} pallas_call "
          f"for the whole expert stack, {moe_fallbacks} stacked fallbacks; "
          f"rel err vs XLA broadcast {err_moe:.1e}; interpret wall "
          f"{us_moe:.0f}us vs xla {us_moe_xla:.0f}us")
    print(f"# dispatch ledger: {stats} (declines carry reason codes — e.g. "
          f"rank-4 stack -> {decline_r4!r}, rank-1 lhs -> {decline_lhs!r})")
    print(f"# static vs dynamic act prologue: rel err {err_static:.1e} "
          f"(grouped {err_gstatic:.1e}); {n_static} pallas_call; "
          f"interpret wall {us_statp:.0f}us vs {us_dynp:.0f}us "
          f"(grouped {us_gstat:.0f}us vs {us_gdyn:.0f}us) — static drops "
          f"the per-step std and shrinks the (B, M, 1) scale plane to "
          f"one (1, 1) word")
    print(f"# decode attn (B={db} S={ds} Hkv={dhkv} G={dg} D={dd}, packed "
          f"KV): fused {us_dec_fused:.0f}us vs dequant-then-XLA "
          f"{us_dec_dequant:.0f}us; rel err {err_dec:.1e}; {n_dec} "
          f"pallas_call/site; HBM read {bytes_dec_packed} B vs dense "
          f"{bytes_dec_dense} B ({bytes_dec_dense/bytes_dec_packed:.2f}x) "
          f"+ no full-cache dequant materialization; engine smoke: "
          f"{dec_served} fused site(s), {dec_fallbacks} fallbacks "
          f"{eng_stats}")
    print(f"# paged KV (page={pg_ps}, max_len={pg_max_len}, real context "
          f"{pg_real}): HBM/request slab={slab_bytes_req} B vs "
          f"paged={paged_bytes_req} B "
          f"({slab_bytes_req/paged_bytes_req:.2f}x); at the slab's "
          f"{hbm_budget} B budget ({pool_pages} pages) the pool serves "
          f"{paged_concurrent} concurrent requests vs {pg_slots} slab "
          f"slots ({concurrency_gain:.1f}x); fused cache-write prefill "
          f"serve wall {us_paged_serve:.0f}us vs prefill+splice "
          f"{us_slab_serve:.0f}us; paged engine: {pg_prefill_served} "
          f"fused prefill(s), {pg_fallbacks} fallbacks, tokens == slab: "
          f"{outs_paged == outs_slab} {pg_stats}")
    print(f"# async serve (paged+chunked, {len(pg_prompts)} requests): "
          f"TTFT p50={sl_ttft.get('p50', 0)*1e3:.1f}ms "
          f"p95={sl_ttft.get('p95', 0)*1e3:.1f}ms, "
          f"TPOT p50={sl_tpot.get('p50', 0)*1e3:.1f}ms (n={sl_tpot['n']}), "
          f"{sl['steps']} steps, interleave="
          f"{sl['prefill_interleave_ratio']}, "
          f"fallbacks={sl['fallbacks']}, tokens == drained loop: "
          f"{sl_tokens_match}; trace -> {sl_trace_path}")
    if sh_enabled:
        print(f"# sharded ({sh_devices} devices, mesh "
              f"{sh_devices // sh_tp}x{sh_tp}): col TP bit-identical="
              f"{sh_col_bit} EP bit-identical={sh_ep_bit} "
              f"Hkv decode bit-identical={sh_kv_bit}, "
              f"shard fallbacks={sh_fallbacks}; per-device bytes: "
              f"weight {sh_weight_total}->{sh_weight_total // sh_tp}, "
              f"kv pool {sh_pool_total}->{sh_pool_total // sh_tp} "
              f"({sh_tp}x shrink) {sh_stats}")
    else:
        print(f"# sharded: skipped ({sh_devices} device; set "
              f"XLA_FLAGS=--xla_force_host_platform_device_count=8)")

    us = (time.perf_counter() - t0) * 1e6
    common.save_json("kernels_bench", {
        "smoke": smoke,
        "shapes": {"m": m, "k": k, "n": n, "experts": n_experts,
                   "cap": cap},
        "err_w4a16": err16, "err_w4a4": err4, "err_fused": err_fuse,
        "fused_calls": n_fused, "unfused_calls": n_unfused,
        "traffic_vs_bf16": bytes_bf16 / bytes_packed,
        "moe": {"pallas_calls": n_moe, "stacked_fallbacks": moe_fallbacks,
                "err_vs_xla": err_moe, "dispatch_stats": stats,
                "decline_rank4": decline_r4, "decline_lhs": decline_lhs,
                "wall_us": us_moe, "wall_us_xla": us_moe_xla},
        "static_prologue": {
            "scale": s_cal, "err_vs_dynamic": err_static,
            "err_vs_dynamic_grouped": err_gstatic,
            "pallas_calls": n_static,
            "wall_us_static": us_statp, "wall_us_dynamic": us_dynp,
            "wall_us_static_grouped": us_gstat,
            "wall_us_dynamic_grouped": us_gdyn,
        },
        "decode_attn": {
            "shapes": {"b": db, "s": ds, "hkv": dhkv, "g": dg, "d": dd},
            "err_vs_f32_dense": err_dec,
            "pallas_calls": n_dec,
            "wall_us_fused": us_dec_fused,
            "wall_us_dequant_xla": us_dec_dequant,
            "fused_beats_dequant": bool(us_dec_fused < us_dec_dequant),
            "hbm_read_bytes_packed": int(bytes_dec_packed),
            "hbm_read_bytes_dense_bf16": int(bytes_dec_dense),
            "hbm_read_ratio": bytes_dec_dense / bytes_dec_packed,
            "engine_decode_served_fused": int(dec_served),
            "engine_decode_fallbacks": int(dec_fallbacks),
            "engine_dispatch_stats": eng_stats,
        },
        "paged_kv": {
            "page_size": pg_ps,
            "max_len": pg_max_len,
            "real_context": pg_real,
            "bytes_per_token_per_layer_stack": int(bpt),
            "hbm_bytes_per_request_slab": int(slab_bytes_req),
            "hbm_bytes_per_request_paged": int(paged_bytes_req),
            "hbm_ratio": slab_bytes_req / paged_bytes_req,
            "hbm_budget_bytes": int(hbm_budget),
            "pool_pages_at_budget": int(pool_pages),
            "max_concurrent_slab": pg_slots,
            "max_concurrent_paged": int(paged_concurrent),
            "concurrency_gain": concurrency_gain,
            "serve_wall_us_slab_splice": us_slab_serve,
            "serve_wall_us_paged_fused": us_paged_serve,
            "tokens_match_slab": bool(outs_paged == outs_slab),
            "prefill_served_fused": int(pg_prefill_served),
            "paged_fallbacks": int(pg_fallbacks),
            "dispatch_stats": pg_stats,
            "pool_stats": pg_pool_stats,
        },
        "serve_latency": {
            "requests": len(pg_prompts),
            "prefill_chunk": sl_chunk,
            "steps": sl["steps"],
            "wall_s": sl["wall_s"],
            "ttft_s": sl_ttft,
            "tpot_s": sl_tpot,
            "latency_s": sl["latency_s"],
            "queue_depth": sl["queue_depth"],
            "batch_occupancy": sl["batch_occupancy"],
            "prefill_interleave_ratio": sl["prefill_interleave_ratio"],
            "fallbacks": sl["fallbacks"],
            "tokens_match_drained": bool(sl_tokens_match),
            "trace": "serve_trace.jsonl",
        },
        "sharded": {
            "enabled": bool(sh_enabled),
            "devices": int(sh_devices),
            "mesh": {"data": int(sh_devices // sh_tp) if sh_enabled else 1,
                     "model": int(sh_tp) if sh_enabled else 1},
            "tp_bit_identical": bool(sh_col_bit),
            "ep_bit_identical": bool(sh_ep_bit),
            "kv_bit_identical": bool(sh_kv_bit),
            "fallbacks": int(sh_fallbacks),
            "dispatch_stats": sh_stats,
            "weight_bytes_total": int(sh_weight_total),
            "weight_bytes_per_device": int(sh_weight_total // sh_tp)
            if sh_enabled else int(sh_weight_total),
            "kv_pool_bytes_total": int(sh_pool_total),
            "kv_pool_bytes_per_device": int(sh_pool_total // sh_tp)
            if sh_enabled else int(sh_pool_total),
            "shrink_factor": int(sh_tp) if sh_enabled else 1,
        },
        "ok": bool(ok),
    })
    common.emit("kernels_bench", us,
                f"err16={err16:.1e} err4={err4:.1e} "
                f"xla_decode_us={us_q:.0f} plain_us={us_p:.0f} "
                f"traffic_vs_bf16={bytes_bf16/bytes_packed:.2f}x "
                f"fused_calls={n_fused} unfused_calls={n_unfused} "
                f"moe_calls={n_moe} moe_fallbacks={moe_fallbacks} "
                f"fused_us={us_fused:.0f} unfused_us={us_unfused:.0f} "
                f"static_us={us_statp:.0f} dyn_us={us_dynp:.0f} "
                f"dec_fused_us={us_dec_fused:.0f} "
                f"dec_dequant_us={us_dec_dequant:.0f} "
                f"dec_fallbacks={dec_fallbacks} "
                f"paged_concurrency_gain={concurrency_gain:.1f}x "
                f"paged_fallbacks={pg_fallbacks} "
                f"serve_ttft_p50_ms={sl_ttft.get('p50', 0)*1e3:.1f} "
                f"serve_tpot_p50_ms={sl_tpot.get('p50', 0)*1e3:.1f} "
                f"serve_fallbacks={sl['fallbacks']} "
                f"ok={ok}")
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
