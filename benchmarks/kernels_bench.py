"""Kernel microbenchmarks: the fused OVP matmul vs oracles, the fused
single-dispatch path vs the unfused encode -> matmul -> scale pipeline,
and the grouped per-expert (MoE) path vs the XLA broadcast fallback.

On this CPU container the Pallas kernels run in interpret mode (Python
emulation — correctness, not speed), so the numbers that matter are:
  1. allclose of pallas-interpret vs the pure-jnp oracle (correctness),
  2. wall time of the XLA decode-and-matmul path vs an fp32 matmul at the
     same logical shape (the decode prologue's overhead on CPU),
  3. the HBM-traffic ratio (packed bytes vs bf16/fp32 bytes) — the term
     that governs TPU performance (see speedup.py / §Perf), and
  4. the dispatch-count delta of the fused backend: one pallas_call vs
     the unfused XLA-encode -> kernel-decode -> XLA-scale round trip
     (which also writes + rereads the packed activation tensor in HBM),
  5. the grouped MoE path: stacked (E, K, N) expert weights must serve on
     the grouped kernel with ZERO fallbacks to the XLA broadcast — any
     decline is reported with its machine-readable reason from
     `backends.dispatch_stats()` and fails the benchmark,
  6. the static calibrated prologue vs the dynamic 3σ one (2-D and
     grouped): same kernel count, bit-identical-scale numerics, and the
     wall-time delta of dropping the per-step std + the per-row scale
     operand — measured, not asserted (see docs/calibration.md).

``BENCH_SMOKE=1`` (or ``--smoke``) shrinks every shape so CI can run the
whole file in interpret mode in seconds; results land in
``EXPERIMENTS/bench_cache/kernels_bench.json`` either way (the CI smoke
job uploads that file as an artifact).
"""
from __future__ import annotations

import dataclasses
import os
import sys
import time

import jax
import jax.numpy as jnp

from repro import backends
from repro.core.ovp import ovp_dequantize, ovp_quantize
from repro.core.policy import QuantPolicy
from repro.core.qlinear import quantize_weight
from repro.core.quantizer import sigma_init_scale
from repro.kernels import ops, ref
from repro.kernels import ovp_matmul as raw_kernels

from . import common

count_pallas_calls = backends.count_pallas_calls


def _smoke_requested() -> bool:
    return os.environ.get("BENCH_SMOKE", "") not in ("", "0") \
        or "--smoke" in sys.argv[1:]


def main() -> int:
    t0 = time.perf_counter()
    smoke = _smoke_requested()
    m, k, n = (64, 128, 64) if smoke else (256, 512, 256)
    n_experts, cap = (2, 16) if smoke else (8, 64)
    key = jax.random.PRNGKey(0)
    ka, kw = jax.random.split(key)
    a = common.transformer_like(ka, (m, k), max_sigma=40.0)
    w = common.transformer_like(kw, (k, n), max_sigma=40.0)

    wq = ovp_quantize(w, jnp.std(w) * 3 / 7, "int4", pair_axis=0)
    aq = ovp_quantize(a, jnp.std(a) * 3 / 7, "int4", pair_axis=1)

    # 1) correctness: pallas interpret vs oracle
    got16 = ops.matmul_w4a16(a, wq.data, jnp.asarray(wq.scale),
                             interpret=True)
    want16 = ref.ovp_matmul_w4a16_ref(a, wq.data) * wq.scale
    err16 = float(jnp.max(jnp.abs(got16 - want16))
                  / (jnp.max(jnp.abs(want16)) + 1e-9))
    got4 = ops.matmul_w4a4(aq.data, jnp.asarray(aq.scale), wq.data,
                           jnp.asarray(wq.scale), interpret=True)
    want4 = (ref.ovp_matmul_w4a4_ref(aq.data, wq.data)
             * aq.scale * wq.scale)
    err4 = float(jnp.max(jnp.abs(got4 - want4))
                 / (jnp.max(jnp.abs(want4)) + 1e-9))
    ok = err16 < 1e-5 and err4 < 1e-5

    # 2) XLA decode-path timing vs plain matmul (CPU; the TPU story is the
    #    bandwidth ratio, but the decode must not be catastrophic even here)
    @jax.jit
    def xla_path(a, wq):
        return a @ ovp_dequantize(wq, dtype=jnp.float32)

    @jax.jit
    def plain(a, w):
        return a @ w

    us_q = common.timer(xla_path, a, wq)
    us_p = common.timer(plain, a, w)

    # 3) traffic ratio
    bytes_packed = wq.nbytes()
    bytes_bf16 = w.size * 2
    bytes_f32 = w.size * 4

    # 4) fused single-dispatch path vs the unfused pipeline it replaced:
    #    XLA-side encode kernel -> packed tensor -> decode matmul kernel ->
    #    XLA scale multiply (3 dispatches + an HBM round trip of the packed
    #    activations) vs ONE pallas_call with the in-kernel prologue.
    a_scale = sigma_init_scale(a, "int4")

    def fused(a, a_scale):
        return ops.fused_ovp_matmul(a, wq, a_dtype="int4",
                                    act_scale=a_scale, interpret=True)

    def unfused(a, a_scale):
        packed = ops.ovp_encode(a, a_scale, interpret=True)
        scaled_units = raw_kernels.ovp_matmul_w4a4(packed, wq.data,
                                                   interpret=True)
        return scaled_units * a_scale * jnp.asarray(wq.scale)

    n_fused = count_pallas_calls(fused, a, a_scale)
    n_unfused = count_pallas_calls(unfused, a, a_scale)
    out_fused = fused(a, a_scale)
    out_unfused = unfused(a, a_scale)
    err_fuse = float(jnp.max(jnp.abs(out_fused - out_unfused))
                     / (jnp.max(jnp.abs(out_unfused)) + 1e-9))
    us_fused = common.timer(jax.jit(fused), a, a_scale)
    us_unfused = common.timer(jax.jit(unfused), a, a_scale)
    pallas = backends.get_backend("pallas")
    xla_b = backends.get_backend("xla")
    ok = ok and err_fuse < 1e-5 and n_fused == pallas.dispatches_per_matmul \
        and n_fused < n_unfused

    # 5) grouped per-expert (MoE) path: stacked weights on the expert grid
    #    dim vs the XLA broadcast fallback they used to take. The dispatch
    #    ledger must show the stack SERVED on the kernel backend — any
    #    "->fallback:<reason>[stacked]" entry fails the benchmark.
    ke, kxg = jax.random.split(jax.random.PRNGKey(1))
    xg = common.transformer_like(kxg, (n_experts, cap, k), max_sigma=20.0)
    ws = common.transformer_like(ke, (n_experts, k, n), max_sigma=20.0)
    moe_pol = QuantPolicy(method="olive", wbits=4, abits=0,
                          w_granularity="tensor", compute_dtype="float32",
                          backend="pallas_interpret")
    wq_moe = quantize_weight(ws, moe_pol)

    backends.reset_dispatch_stats()

    def moe_grouped(xg):
        return backends.dispatch(xg, wq_moe, moe_pol)

    n_moe = count_pallas_calls(moe_grouped, xg)
    stats = backends.dispatch_stats()
    moe_fallbacks = sum(v for tag, v in stats.items()
                        if "->fallback:" in tag and "[stacked]" in tag)
    out_moe = moe_grouped(xg)
    want_moe = backends.dispatch(
        xg, wq_moe, dataclasses.replace(moe_pol, backend="xla"))
    err_moe = float(jnp.max(jnp.abs(out_moe - want_moe))
                    / (jnp.max(jnp.abs(want_moe)) + 1e-9))
    us_moe = common.timer(jax.jit(moe_grouped), xg)
    us_moe_xla = common.timer(jax.jit(
        lambda xg: backends.dispatch(
            xg, wq_moe, dataclasses.replace(moe_pol, backend="xla"))), xg)
    # declined layouts carry machine-readable reasons, not prose: a rank-4
    # weight stack is the one layout the grouped kernel still declines
    decline_r4 = pallas.decline_reason(
        xg[None], dataclasses.replace(wq_moe, data=wq_moe.data[None]),
        moe_pol)
    decline_lhs = pallas.decline_reason(xg[0, 0], wq_moe, moe_pol)
    ok = ok and err_moe < 1e-5 and moe_fallbacks == 0 \
        and n_moe == pallas.dispatches_per_matmul

    # 6) static calibrated prologue vs dynamic 3σ: the dynamic pipeline
    #    recomputes a full-tensor std and streams a per-row scale plane
    #    every step; the static path passes the calibrated scale as one
    #    (1, 1) scalar operand. At the same scale value the outputs must
    #    agree to fp32 rounding (per-row divide vs scalar reciprocal
    #    multiply), and both stay a single pallas_call.
    s_cal = float(a_scale)

    def dyn_prologue(a):
        return ops.fused_ovp_matmul(a, wq, a_dtype="int4",
                                    act_scale=sigma_init_scale(a, "int4"),
                                    interpret=True)

    def static_prologue(a):
        return ops.fused_ovp_matmul(a, wq, a_dtype="int4",
                                    static_act_scale=s_cal, interpret=True)

    err_static = float(jnp.max(jnp.abs(static_prologue(a) - fused(a,
                                                                  a_scale)))
                       / (jnp.max(jnp.abs(out_fused)) + 1e-9))
    n_static = count_pallas_calls(static_prologue, a)
    us_dynp = common.timer(jax.jit(dyn_prologue), a)
    us_statp = common.timer(jax.jit(static_prologue), a)

    def grouped_static(xg):
        return ops.grouped_ovp_matmul(xg, wq_moe, a_dtype="int4",
                                      static_act_scale=s_cal,
                                      interpret=True)

    def grouped_dyn(xg):
        return ops.grouped_ovp_matmul(
            xg, wq_moe, a_dtype="int4",
            act_scale=jnp.full(xg.shape[:-1], s_cal), interpret=True)

    err_gstatic = float(jnp.max(jnp.abs(grouped_static(xg)
                                        - grouped_dyn(xg)))
                        / (jnp.max(jnp.abs(grouped_dyn(xg))) + 1e-9))
    us_gdyn = common.timer(jax.jit(grouped_dyn), xg)
    us_gstat = common.timer(jax.jit(grouped_static), xg)
    ok = ok and err_static < 1e-5 and err_gstatic < 1e-5 and n_static == 1

    print("# kernel correctness: max rel err "
          f"w4a16={err16:.2e} w4a4={err4:.2e}")
    print(f"# xla decode-matmul {us_q:.0f}us vs plain fp32 {us_p:.0f}us "
          f"({m}x{k}x{n})")
    print(f"# weight bytes: packed={bytes_packed} bf16={bytes_bf16} "
          f"fp32={bytes_f32} (ratios {bytes_bf16/bytes_packed:.2f}x / "
          f"{bytes_f32/bytes_packed:.2f}x)")
    print(f"# fused vs unfused W4A4 dispatch: {n_fused} pallas_call vs "
          f"{n_unfused} + XLA scale mul ({xla_b.dispatches_per_matmul} "
          f"dispatches end-to-end unfused); rel err {err_fuse:.1e}; "
          f"interpret-mode wall {us_fused:.0f}us vs {us_unfused:.0f}us; "
          f"packed-act HBM round trip eliminated: {a.size // 2} B/matmul")
    print(f"# grouped MoE ({n_experts}x{cap}x{k}x{n}): {n_moe} pallas_call "
          f"for the whole expert stack, {moe_fallbacks} stacked fallbacks; "
          f"rel err vs XLA broadcast {err_moe:.1e}; interpret wall "
          f"{us_moe:.0f}us vs xla {us_moe_xla:.0f}us")
    print(f"# dispatch ledger: {stats} (declines carry reason codes — e.g. "
          f"rank-4 stack -> {decline_r4!r}, rank-1 lhs -> {decline_lhs!r})")
    print(f"# static vs dynamic act prologue: rel err {err_static:.1e} "
          f"(grouped {err_gstatic:.1e}); {n_static} pallas_call; "
          f"interpret wall {us_statp:.0f}us vs {us_dynp:.0f}us "
          f"(grouped {us_gstat:.0f}us vs {us_gdyn:.0f}us) — static drops "
          f"the per-step std and shrinks the (B, M, 1) scale plane to "
          f"one (1, 1) word")

    us = (time.perf_counter() - t0) * 1e6
    common.save_json("kernels_bench", {
        "smoke": smoke,
        "shapes": {"m": m, "k": k, "n": n, "experts": n_experts,
                   "cap": cap},
        "err_w4a16": err16, "err_w4a4": err4, "err_fused": err_fuse,
        "fused_calls": n_fused, "unfused_calls": n_unfused,
        "traffic_vs_bf16": bytes_bf16 / bytes_packed,
        "moe": {"pallas_calls": n_moe, "stacked_fallbacks": moe_fallbacks,
                "err_vs_xla": err_moe, "dispatch_stats": stats,
                "decline_rank4": decline_r4, "decline_lhs": decline_lhs,
                "wall_us": us_moe, "wall_us_xla": us_moe_xla},
        "static_prologue": {
            "scale": s_cal, "err_vs_dynamic": err_static,
            "err_vs_dynamic_grouped": err_gstatic,
            "pallas_calls": n_static,
            "wall_us_static": us_statp, "wall_us_dynamic": us_dynp,
            "wall_us_static_grouped": us_gstat,
            "wall_us_dynamic_grouped": us_gdyn,
        },
        "ok": bool(ok),
    })
    common.emit("kernels_bench", us,
                f"err16={err16:.1e} err4={err4:.1e} "
                f"xla_decode_us={us_q:.0f} plain_us={us_p:.0f} "
                f"traffic_vs_bf16={bytes_bf16/bytes_packed:.2f}x "
                f"fused_calls={n_fused} unfused_calls={n_unfused} "
                f"moe_calls={n_moe} moe_fallbacks={moe_fallbacks} "
                f"fused_us={us_fused:.0f} unfused_us={us_unfused:.0f} "
                f"static_us={us_statp:.0f} dyn_us={us_dynp:.0f} "
                f"ok={ok}")
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
