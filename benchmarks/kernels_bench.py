"""Kernel microbenchmarks: the fused OVP matmul vs oracles, and the fused
single-dispatch path vs the unfused encode -> matmul -> scale pipeline.

On this CPU container the Pallas kernels run in interpret mode (Python
emulation — correctness, not speed), so the numbers that matter are:
  1. allclose of pallas-interpret vs the pure-jnp oracle (correctness),
  2. wall time of the XLA decode-and-matmul path vs an fp32 matmul at the
     same logical shape (the decode prologue's overhead on CPU),
  3. the HBM-traffic ratio (packed bytes vs bf16/fp32 bytes) — the term
     that governs TPU performance (see speedup.py / §Perf), and
  4. the dispatch-count delta of the fused backend: one pallas_call vs
     the unfused XLA-encode -> kernel-decode -> XLA-scale round trip
     (which also writes + rereads the packed activation tensor in HBM).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import backends
from repro.core.ovp import ovp_dequantize, ovp_quantize
from repro.core.quantizer import sigma_init_scale
from repro.kernels import ops, ref
from repro.kernels import ovp_matmul as raw_kernels

from . import common

count_pallas_calls = backends.count_pallas_calls


def main() -> int:
    t0 = time.perf_counter()
    key = jax.random.PRNGKey(0)
    m, k, n = 256, 512, 256
    ka, kw = jax.random.split(key)
    a = common.transformer_like(ka, (m, k), max_sigma=40.0)
    w = common.transformer_like(kw, (k, n), max_sigma=40.0)

    wq = ovp_quantize(w, jnp.std(w) * 3 / 7, "int4", pair_axis=0)
    aq = ovp_quantize(a, jnp.std(a) * 3 / 7, "int4", pair_axis=1)

    # 1) correctness: pallas interpret vs oracle
    got16 = ops.matmul_w4a16(a, wq.data, jnp.asarray(wq.scale),
                             interpret=True)
    want16 = ref.ovp_matmul_w4a16_ref(a, wq.data) * wq.scale
    err16 = float(jnp.max(jnp.abs(got16 - want16))
                  / (jnp.max(jnp.abs(want16)) + 1e-9))
    got4 = ops.matmul_w4a4(aq.data, jnp.asarray(aq.scale), wq.data,
                           jnp.asarray(wq.scale), interpret=True)
    want4 = (ref.ovp_matmul_w4a4_ref(aq.data, wq.data)
             * aq.scale * wq.scale)
    err4 = float(jnp.max(jnp.abs(got4 - want4))
                 / (jnp.max(jnp.abs(want4)) + 1e-9))
    ok = err16 < 1e-5 and err4 < 1e-5

    # 2) XLA decode-path timing vs plain matmul (CPU; the TPU story is the
    #    bandwidth ratio, but the decode must not be catastrophic even here)
    @jax.jit
    def xla_path(a, wq):
        return a @ ovp_dequantize(wq, dtype=jnp.float32)

    @jax.jit
    def plain(a, w):
        return a @ w

    us_q = common.timer(xla_path, a, wq)
    us_p = common.timer(plain, a, w)

    # 3) traffic ratio
    bytes_packed = wq.nbytes()
    bytes_bf16 = w.size * 2
    bytes_f32 = w.size * 4

    # 4) fused single-dispatch path vs the unfused pipeline it replaced:
    #    XLA-side encode kernel -> packed tensor -> decode matmul kernel ->
    #    XLA scale multiply (3 dispatches + an HBM round trip of the packed
    #    activations) vs ONE pallas_call with the in-kernel prologue.
    a_scale = sigma_init_scale(a, "int4")

    def fused(a, a_scale):
        return ops.fused_ovp_matmul(a, wq, a_dtype="int4",
                                    act_scale=a_scale, interpret=True)

    def unfused(a, a_scale):
        packed = ops.ovp_encode(a, a_scale, interpret=True)
        scaled_units = raw_kernels.ovp_matmul_w4a4(packed, wq.data,
                                                   interpret=True)
        return scaled_units * a_scale * jnp.asarray(wq.scale)

    n_fused = count_pallas_calls(fused, a, a_scale)
    n_unfused = count_pallas_calls(unfused, a, a_scale)
    out_fused = fused(a, a_scale)
    out_unfused = unfused(a, a_scale)
    err_fuse = float(jnp.max(jnp.abs(out_fused - out_unfused))
                     / (jnp.max(jnp.abs(out_unfused)) + 1e-9))
    us_fused = common.timer(jax.jit(fused), a, a_scale)
    us_unfused = common.timer(jax.jit(unfused), a, a_scale)
    pallas = backends.get_backend("pallas")
    xla_b = backends.get_backend("xla")
    ok = ok and err_fuse < 1e-5 and n_fused == pallas.dispatches_per_matmul \
        and n_fused < n_unfused

    print("# kernel correctness: max rel err "
          f"w4a16={err16:.2e} w4a4={err4:.2e}")
    print(f"# xla decode-matmul {us_q:.0f}us vs plain fp32 {us_p:.0f}us "
          f"({m}x{k}x{n})")
    print(f"# weight bytes: packed={bytes_packed} bf16={bytes_bf16} "
          f"fp32={bytes_f32} (ratios {bytes_bf16/bytes_packed:.2f}x / "
          f"{bytes_f32/bytes_packed:.2f}x)")
    print(f"# fused vs unfused W4A4 dispatch: {n_fused} pallas_call vs "
          f"{n_unfused} + XLA scale mul ({xla_b.dispatches_per_matmul} "
          f"dispatches end-to-end unfused); rel err {err_fuse:.1e}; "
          f"interpret-mode wall {us_fused:.0f}us vs {us_unfused:.0f}us; "
          f"packed-act HBM round trip eliminated: {a.size // 2} B/matmul")

    us = (time.perf_counter() - t0) * 1e6
    common.emit("kernels_bench", us,
                f"err16={err16:.1e} err4={err4:.1e} "
                f"xla_decode_us={us_q:.0f} plain_us={us_p:.0f} "
                f"traffic_vs_bf16={bytes_bf16/bytes_packed:.2f}x "
                f"fused_calls={n_fused} unfused_calls={n_unfused} "
                f"fused_us={us_fused:.0f} unfused_us={us_unfused:.0f} "
                f"ok={ok}")
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
