"""Paper Tables 6/7/8 (tensor-level analogue): round-trip quantization
quality of OliVe vs every studied baseline on identical tensors — plus
the mixed-precision *policy program* rows the flat policy API could not
express: per-layer W4/W8 programs traded against model bytes.

Metric: SQNR (dB, higher better) + byte footprint; for the program rows,
held-out perplexity + parameter bytes. Tensors: the trained LM's linear
weights and transformer-like synthetic tensors across the Fig. 2
outlier-intensity range. The model-level (perplexity) analogue of
Tables 6/9 lives in table9_llm.py.

Expected ordering on outlier-heavy tensors (the paper's claim):
  OliVe-4bit  >  ANT-4bit ≈ int4-MSE  (outlier-blind 4-bit)
  OliVe-4bit  ≈  GOBO-4bit            (GOBO keeps outliers exact but pays
                                       2x footprint + unaligned access)
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import baselines
from repro.core.calibration import auto_mixed, record_weights, \
    site_sensitivity
from repro.core.policy import PolicyProgram, QuantPolicy
from repro.core.qlinear import quantize_params
from repro.core.quantizer import QuantSpec, dequantize, quantize
from repro.models.model import build_model

from . import common


def sqnr_db(x, xh) -> float:
    x = np.asarray(x, np.float64)
    xh = np.asarray(xh, np.float64)
    mse = np.mean((xh - x) ** 2)
    return float(10 * np.log10(np.mean(x ** 2) / max(mse, 1e-30)))


def olive4(x):
    qt = quantize(jnp.asarray(x), QuantSpec(normal_dtype="int4",
                                            granularity="tensor"))
    return dequantize(qt), qt.nbytes()


def olive8(x):
    qt = quantize(jnp.asarray(x), QuantSpec(normal_dtype="int8",
                                            granularity="tensor"))
    return dequantize(qt), qt.nbytes()


def _gobo(x):
    xh, st = baselines.gobo_fake_quant(x, 4)
    return xh, st["bytes"]


METHODS = {
    "olive_4bit": olive4,
    "olive_8bit": olive8,
    "int4_mse": lambda x: (baselines.uniform_int_fake_quant(x, 4),
                           x.size // 2),
    "int8_mse": lambda x: (baselines.uniform_int_fake_quant(x, 8), x.size),
    "ant_4bit": lambda x: (baselines.ant_fake_quant(x), x.size // 2),
    "adafloat_4bit": lambda x: (baselines.adaptivfloat_fake_quant(x, 4),
                                x.size // 2),
    "gobo_4bit": _gobo,
    "clip3s_int4": lambda x: (
        baselines.uniform_int_fake_quant(baselines.clip_outliers(x, 3.0), 4),
        x.size // 2),
}


def main() -> int:
    t0 = time.perf_counter()
    model, params, loader = common.trained_lm()
    tensors = {}
    ws = common.weight_tensors(params)
    # three representative LM weights + three synthetic intensities
    for name in list(ws)[:3]:
        tensors[f"lm:{name.split('/')[-1]}_{len(tensors)}"] = \
            jnp.asarray(ws[name])
    for tag, ms in [("syn20", 20.0), ("syn60", 60.0), ("syn325", 325.0)]:
        tensors[tag] = common.transformer_like(
            jax.random.PRNGKey(5), (512, 1024), max_sigma=ms,
            outlier_frac=0.003)

    results = {m: {} for m in METHODS}
    print("# Table 6/7/8 analogue: SQNR dB (higher better) per tensor")
    header = "# method          " + "  ".join(f"{t:>10s}" for t in tensors)
    print(header)
    for m, fn in METHODS.items():
        for tname, x in tensors.items():
            xh, nbytes = fn(x)
            results[m][tname] = {"sqnr": sqnr_db(x, xh),
                                 "bytes": float(nbytes)}
        line = f"#   {m:14s} " + "  ".join(
            f"{results[m][t]['sqnr']:10.2f}" for t in tensors)
        print(line)

    # ---- mixed-precision policy programs: ppl vs model bytes -----------
    cfg = model.cfg
    w4 = QuantPolicy(method="olive", wbits=4, abits=0,
                     compute_dtype="float32")
    w8 = QuantPolicy(method="olive", wbits=8, abits=0,
                     w_normal_dtype="int8", compute_dtype="float32")
    mixed = PolicyProgram.from_policy(w4, name="mixed_w48").with_rules([
        ("layers/0/*", w8),
        (f"layers/{cfg.n_layers - 1}/*", w8),
    ])
    sens = site_sensitivity(record_weights(params, min_size=1024),
                            "int4", n_grid=8)
    autop = auto_mixed(sens, budget_bits=5.0, low=w4, high=w8)
    programs = {
        "prog_uniform_w4": PolicyProgram.from_policy(w4),
        "prog_mixed_w48": mixed,
        "prog_auto_w48": autop,
        "prog_uniform_w8": PolicyProgram.from_policy(w8),
    }
    prog_rows = {}
    fp_bytes = common.footprint(params)
    print(f"# mixed-precision programs (fp32 "
          f"ppl={common.eval_ppl(model, params, loader):.3f}, "
          f"{fp_bytes/1e6:.2f} MB)")
    for tag, prog in programs.items():
        pm = build_model(cfg, prog, remat=False)
        qp = quantize_params(pm.adapt_params(params), prog, min_size=1024)
        ppl = common.eval_ppl(pm, qp, loader)
        nbytes = common.footprint(qp)
        prog_rows[tag] = {"ppl": ppl, "bytes": nbytes}
        print(f"#   {tag:18s} ppl={ppl:8.3f}  bytes={nbytes/1e6:6.2f} MB")

    syn = [t for t in tensors if t.startswith("syn")]
    mean_syn = {m: np.mean([results[m][t]["sqnr"] for t in syn])
                for m in METHODS}
    ok = (mean_syn["olive_4bit"] > mean_syn["ant_4bit"] + 3.0
          and mean_syn["olive_4bit"] > mean_syn["int4_mse"] + 3.0
          and mean_syn["olive_4bit"] > mean_syn["clip3s_int4"] + 3.0)
    # byte story: GOBO pays the coordinate-list overhead; OliVe stays dense
    b_olive = np.mean([results["olive_4bit"][t]["bytes"] for t in syn])
    b_gobo = np.mean([results["gobo_4bit"][t]["bytes"] for t in syn])
    print(f"#   bytes on synthetic: olive={b_olive:.0f} gobo={b_gobo:.0f} "
          f"(gobo/olive={b_gobo/b_olive:.2f}x)")

    # the program rows must show the expressible trade-off: mixed sits
    # between uniform W4 and uniform W8 in bytes
    ok_prog = (prog_rows["prog_uniform_w4"]["bytes"]
               < prog_rows["prog_mixed_w48"]["bytes"]
               < prog_rows["prog_uniform_w8"]["bytes"])
    ok = ok and ok_prog

    us = (time.perf_counter() - t0) * 1e6
    common.emit("table6_accuracy", us,
                f"olive4={mean_syn['olive_4bit']:.1f}dB "
                f"ant4={mean_syn['ant_4bit']:.1f}dB "
                f"int4={mean_syn['int4_mse']:.1f}dB "
                f"mixed_w48_ppl={prog_rows['prog_mixed_w48']['ppl']:.2f} "
                f"olive_beats_4bit_baselines={ok}")
    common.save_json("table6_accuracy", {
        "results": results, "programs": prog_rows, "ok": bool(ok)})
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
