"""§3.4 ablation: does the MSE-searched scale (3σ-seeded) actually matter?

The paper's framework picks the outlier threshold T (equivalently the
scale: T = nmax * scale) by MSE search seeded at 3σ, arguing that a bad T
either (small T) turns too many values into outlier-outlier pairs — whose
smaller member is pruned — or (large T) wastes the normal dtype's
resolution. We sweep fixed kσ thresholds against the searched one on
transformer-statistics tensors and the trained LM's weights.

Expected: searched >= every fixed kσ in SQNR, and the fixed-k curve is
unimodal around 3-4σ (the paper's initialisation insight).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.datatypes import NORMAL_MAX
from repro.core.ovp import ovp_fake_quant
from repro.core.quantizer import ovp_search_scale

from . import common


def sqnr_db(x, xh) -> float:
    x = np.asarray(x, np.float64)
    xh = np.asarray(xh, np.float64)
    mse = np.mean((xh - x) ** 2)
    return float(10 * np.log10(np.mean(x ** 2) / max(mse, 1e-30)))


KS = [1.0, 2.0, 3.0, 4.0, 6.0, 10.0]


def main() -> int:
    t0 = time.perf_counter()
    model, params, _ = common.trained_lm()
    ws = common.weight_tensors(params)
    tensors = {f"lm:{k.split('/')[-1]}{i}": jnp.asarray(v)
               for i, (k, v) in enumerate(list(ws.items())[:2])}
    for tag, ms in [("syn60", 60.0), ("syn325", 325.0)]:
        tensors[tag] = common.transformer_like(
            jax.random.PRNGKey(9), (512, 1024), max_sigma=ms,
            outlier_frac=0.003)

    nmax = float(NORMAL_MAX["int4"])
    rows = {}
    print("# §3.4 ablation: SQNR dB by threshold choice (int4 OVP)")
    print("# tensor, " + ", ".join(f"{k:.0f}σ" for k in KS)
          + ", searched")
    ok = True
    for tname, x in tensors.items():
        sd = float(jnp.std(x))
        fixed = []
        for k in KS:
            s = max(k * sd / nmax, 1e-8)
            fixed.append(sqnr_db(x, ovp_fake_quant(x, s, "int4")))
        s_best = ovp_search_scale(x.reshape(-1)[: (x.size // 2) * 2],
                                  "int4")
        searched = sqnr_db(x, ovp_fake_quant(x, s_best, "int4"))
        rows[tname] = {"fixed": dict(zip(KS, fixed)),
                       "searched": searched}
        print(f"#   {tname:10s} "
              + " ".join(f"{v:7.2f}" for v in fixed)
              + f"  | {searched:7.2f}")
        # searched never loses (tolerance for per-tensor-vs-flat layout)
        ok &= searched >= max(fixed) - 0.3
        # unimodal-ish: the extremes are worse than the 3σ neighbourhood
        ok &= fixed[0] < max(fixed[1:4]) and fixed[-1] < max(fixed[1:4])

    us = (time.perf_counter() - t0) * 1e6
    best_k = {t: max(r["fixed"], key=r["fixed"].get)
              for t, r in rows.items()}
    common.emit("ablation_threshold", us,
                f"best_fixed_k={sorted(set(best_k.values()))} "
                f"searched_never_loses={ok}")
    common.save_json("ablation_threshold", {"rows": rows, "ok": bool(ok)})
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
