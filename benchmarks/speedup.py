"""Paper Figs. 9/10 (TPU roofline translation): serving speedup of OliVe
vs GOBO / int8 / ANT from the bandwidth mechanism.

The paper's GPU/ASIC speedups are cycle-simulator results; their first-order
cause is HBM traffic (weights dominate decode at the paper's batch sizes:
2 for GPT-like, 16 for BERT-like, ctx ≤ 1k). On TPU the same mechanism is
the *memory roofline term*: per-decode-step HBM bytes per method, speedup =
t_mem ratios. Methods' traffic models (per Table 1 / §5.3):

  gobo_fp16   — weight-only quantization, decompressed at the DRAM level,
                on-chip traffic and compute are fp16
  int8        — W8A8: 1 B/weight, int8 activations
  ant4_mixed  — ANT PTQ needs int8 on ~80% of layers to hold accuracy
                (§5.3): 0.8·1B + 0.2·0.5B per weight
  olive4      — W4A4: 0.5 B/weight (packed OVP, zero metadata)
  olive4_kv   — beyond-paper: + OVP 4-bit KV cache

Two regimes are reported:
  paper_serving  (batch=2, ctx=1024)   — weight-dominated, validates the
                                         Fig. 9/10 speedup ordering
  decode_32k     (batch=128, ctx=32k)  — KV-dominated: weight-only wins
                                         vanish, which is exactly why the
                                         OVP KV cache extension exists
                                         (recorded in EXPERIMENTS.md §Perf)
"""
from __future__ import annotations

import glob
import json
import os
import time

import numpy as np

from repro import backends
from repro.configs import ARCHS
from repro.roofline import hw

from . import common

MODELS = ["qwen1.5-0.5b", "yi-6b", "qwen2-7b", "minitron-8b",
          "qwen3-moe-30b-a3b"]

REGIMES = {
    "paper_serving": (2, 1024),
    "decode_32k": (128, 32768),
}

METHODS = {
    # (weight B/el, kv B/el, act B/el)
    "gobo_fp16": (2.0, 2.0, 2.0),
    "int8": (1.0, 1.0, 1.0),
    "ant4_mixed": (0.8 * 1.0 + 0.2 * 0.5, 1.0, 1.0),
    "olive4": (0.5, 2.0, 0.5),      # paper: W+A quantized, KV bf16
    "olive4_kv": (0.5, 0.5, 0.5),   # beyond-paper OVP KV cache
}


def act_elements(cfg, batch) -> float:
    """Per-decode-step activation elements (≈8 linear operands/layer)."""
    return batch * cfg.n_layers * 8 * cfg.d_model


def step_bytes(cfg, batch, ctx, w_bpe, kv_bpe, a_bpe) -> float:
    n = cfg.active_param_count()
    kv = batch * ctx * 2 * cfg.n_layers * cfg.n_kv_heads * cfg.head_dim
    return n * w_bpe + kv * kv_bpe + act_elements(cfg, batch) * a_bpe


def act_encode_roundtrip_bytes(cfg, batch, a_bpe) -> float:
    """Extra HBM bytes/step when activation OVP encode is NOT fused into
    the matmul kernel: the packed tensor is written by the encode dispatch
    and reread by the matmul. Whether a backend eliminates this round trip
    comes from its `fuses_act_encode` flag (see main below)."""
    return 2 * act_elements(cfg, batch) * a_bpe


def expert_weight_elements(cfg) -> float:
    """Active (top-k) expert weight elements per decode step."""
    if not cfg.n_experts:
        return 0.0
    return cfg.moe_block_count() * cfg.top_k * 3 * cfg.d_model * cfg.d_ff


def grouped_moe_supported() -> bool:
    """Probe the kernel backend with a representative stacked-expert
    operand layout; the decline reason (None = served) is the
    machine-readable contract, not prose."""
    import jax.numpy as jnp
    from repro.core.ovp import QuantizedTensor
    pallas = backends.get_backend("pallas")
    w = QuantizedTensor(data=jnp.zeros((4, 8, 16), jnp.uint8),
                        scale=jnp.ones((4, 1, 16), jnp.float32),
                        normal_dtype="int4", pair_axis=-2, orig_dim=16)
    x = jnp.zeros((4, 2, 16), jnp.float32)
    from repro.core.policy import OLIVE_W4
    return pallas.decline_reason(x, w, OLIVE_W4) is None


PAGE_SIZE = 16


def paged_kv_supported() -> bool:
    """Probe the kernel backend with representative PAGED cache layouts
    (block-table decode and staged chunked prefill); served/declined is
    the machine-readable decline-reason contract, not a hardcoded flag."""
    import jax.numpy as jnp
    pallas = backends.get_backend("pallas")
    ps, hkv, d = PAGE_SIZE, 2, 16
    paged = {
        "k_data": jnp.zeros((4, ps, hkv, d // 2), jnp.uint8),
        "v_data": jnp.zeros((4, ps, hkv, d // 2), jnp.uint8),
        "k_scl": jnp.zeros((4, ps, hkv), jnp.float32),
        "v_scl": jnp.zeros((4, ps, hkv), jnp.float32),
        "block_table": jnp.zeros((1, 2), jnp.int32),
    }
    q1 = jnp.zeros((1, 1, 4, d), jnp.float32)
    if pallas.decode_attn_decline_reason(q1, paged) is not None:
        return False
    staged = dict(paged,
                  stage_k=jnp.zeros((1, 2 * ps, hkv, d), jnp.float32),
                  stage_v=jnp.zeros((1, 2 * ps, hkv, d), jnp.float32))
    qp = jnp.zeros((1, ps, 4, d), jnp.float32)
    return bool(pallas.fuses_prefill_attention) \
        and pallas.prefill_attn_decline_reason(qp, staged) is None


def measured_bf16_bytes(arch: str):
    p = os.path.join("EXPERIMENTS", "dryrun",
                     f"{arch}__decode_32k__single__none.json")
    if os.path.exists(p):
        with open(p) as f:
            rec = json.load(f)
        if rec.get("status") == "ok":
            r = rec["roofline"]
            return r["bytes_per_chip"] * r["n_chips"]
    return None


def main() -> int:
    t0 = time.perf_counter()
    rows = {}
    print("# Fig. 9/10 TPU translation: decode-step memory-roofline time")
    for regime, (batch, ctx) in REGIMES.items():
        print(f"# --- regime {regime}: batch={batch}, ctx={ctx} ---")
        print("# model, method, HBM GB/step, speedup vs gobo, vs int8")
        rows[regime] = {}
        for name in MODELS:
            cfg = ARCHS[name]
            per = {m: step_bytes(cfg, batch, ctx, *spec)
                   for m, spec in METHODS.items()}
            t = {m: b / hw.HBM_BW for m, b in per.items()}
            rows[regime][name] = {"bytes": per, "t_mem_s": t}
            for m in METHODS:
                print(f"#   {name:18s} {m:10s} {per[m]/1e9:9.3f} "
                      f"{t['gobo_fp16']/t[m]:6.2f}x {t['int8']/t[m]:6.2f}x")

    def mean_ratio(regime, a, b):
        return float(np.mean([rows[regime][n]["t_mem_s"][a]
                              / rows[regime][n]["t_mem_s"][b]
                              for n in MODELS]))

    sp_gobo = mean_ratio("paper_serving", "gobo_fp16", "olive4")
    sp_int8 = mean_ratio("paper_serving", "int8", "olive4")
    sp_ant = mean_ratio("paper_serving", "ant4_mixed", "olive4")
    kv_32k = mean_ratio("decode_32k", "olive4", "olive4_kv")
    w_only_32k = mean_ratio("decode_32k", "gobo_fp16", "olive4")

    print(f"# paper regime means: olive4 vs gobo {sp_gobo:.2f}x (paper "
          f"4.5x), vs int8 {sp_int8:.2f}x (2.7x), vs ant {sp_ant:.2f}x "
          f"(2.4x) — bandwidth-only model reproduces the ordering")
    print(f"# decode_32k: weight-only OliVe gives just {w_only_32k:.2f}x "
          f"(KV-dominated); OVP KV cache adds {kv_32k:.2f}x on top "
          f"(beyond-paper, see EXPERIMENTS.md §Perf)")

    # fused-prologue term, read from the backend registry: the pallas
    # backend encodes activations inside the matmul kernel, the xla
    # backend round-trips a packed activation tensor through HBM
    exec_backend = backends.get_backend("pallas")
    unfused_backend = backends.get_backend(exec_backend.fallback)
    if exec_backend.fuses_act_encode and not unfused_backend.fuses_act_encode:
        for regime, (batch, ctx) in REGIMES.items():
            extra = {nme: act_encode_roundtrip_bytes(ARCHS[nme], batch,
                                                     METHODS["olive4"][2])
                     for nme in MODELS}
            frac = float(np.mean(
                [extra[nme] / rows[regime][nme]["bytes"]["olive4"]
                 for nme in MODELS]))
            print(f"# fused act-encode prologue ({exec_backend.name}: "
                  f"{exec_backend.dispatches_per_matmul} dispatch vs "
                  f"{unfused_backend.name}: "
                  f"{unfused_backend.dispatches_per_matmul}) saves "
                  f"{np.mean(list(extra.values()))/1e6:.2f} MB/step "
                  f"({100*frac:.2f}% of olive4 traffic) in {regime}")
    # grouped per-expert kernel credit: before the grouped path, stacked
    # expert weights fell back to the XLA broadcast, whose separate
    # dequant dispatch writes + rereads the dequantized (top-k) expert
    # stack in the compute dtype — 2 x 2 B/el on top of the packed read.
    # With the grouped kernel serving stacked weights (probed via the
    # machine-readable decline-reason contract), that round trip is gone.
    moe_served = grouped_moe_supported()
    moe_credit = {}
    for name in MODELS:
        cfg = ARCHS[name]
        ew = expert_weight_elements(cfg)
        if not ew:
            continue
        roundtrip = 2 * ew * 2.0  # bf16 dequant write + reread per step
        base = rows["paper_serving"][name]["bytes"]["olive4"]
        moe_credit[name] = {"expert_elements": ew,
                            "fallback_roundtrip_bytes": roundtrip,
                            "frac_of_olive4": roundtrip / base,
                            "served_by_grouped_kernel": moe_served}
        verdict = "eliminated by the grouped kernel" if moe_served \
            else "STILL PAID (stacked weights fall back)"
        print(f"# grouped MoE path [{name}]: top-k expert weights "
              f"{ew/1e9:.2f} Gel/step; XLA-fallback dequant round trip "
              f"{roundtrip/1e9:.2f} GB/step "
              f"({100*roundtrip/base:.1f}% of olive4 traffic) — {verdict}")

    # paged KV-cache credit (decode_32k): whether the block-table layout
    # is SERVED fused comes from the registry probe above — a kernel that
    # declines it would force `gather_paged_cache`, a per-step write +
    # reread of the whole packed pool (slab materialization), on top of
    # the packed read the roofline rows already count. Capacity comes
    # from the paging helpers at the slab's own HBM budget, with real
    # contexts averaging a quarter of the 32k window.
    from repro.serve.paging import (kv_bytes_per_token_per_site,
                                    max_concurrent_requests, pages_for,
                                    pool_pages_for_budget)
    paged_served = paged_kv_supported()
    batch_32k, ctx_32k = REGIMES["decode_32k"]
    paged_rows = {}
    for name in MODELS:
        cfg = ARCHS[name]
        bpt = kv_bytes_per_token_per_site(cfg.n_kv_heads, cfg.head_dim,
                                          4) * cfg.n_layers
        pool_bytes = batch_32k * ctx_32k * bpt
        gather_roundtrip = 2 * pool_bytes
        base = rows["decode_32k"][name]["bytes"]["olive4_kv"]
        pool_pages = pool_pages_for_budget(pool_bytes, PAGE_SIZE, bpt)
        conc = max_concurrent_requests(pool_pages, PAGE_SIZE,
                                       tokens_per_request=ctx_32k // 4)
        # resident KV bytes per active request: the slab reserves the
        # full window per slot, the pool holds whole pages of the real
        # context (quarter-window requests here)
        resident_slab = ctx_32k * bpt
        resident_paged = pages_for(ctx_32k // 4, PAGE_SIZE) \
            * PAGE_SIZE * bpt
        paged_rows[name] = {
            "kv_bytes_per_token": bpt,
            "pool_bytes": pool_bytes,
            "resident_bytes_per_request_slab": resident_slab,
            "resident_bytes_per_request_paged_quarter_ctx": resident_paged,
            "gather_roundtrip_bytes": gather_roundtrip,
            "frac_of_olive4_kv": gather_roundtrip / base,
            "pool_pages_at_slab_budget": pool_pages,
            "max_concurrent_slab": batch_32k,
            "max_concurrent_paged_quarter_ctx": conc,
            "served_by_paged_kernel": paged_served,
        }
        verdict = "served fused (no slab materialization)" if paged_served \
            else "STILL PAID (paged layout declines to the gather path)"
        print(f"# paged KV [{name}]: resident/request "
              f"slab={resident_slab/1e6:.0f} MB vs "
              f"paged={resident_paged/1e6:.0f} MB at quarter context "
              f"({resident_slab/resident_paged:.1f}x); decline-path "
              f"gather round trip {gather_roundtrip/1e9:.1f} GB/step "
              f"({100*gather_roundtrip/base:.0f}% of olive4_kv traffic) "
              f"— {verdict}; at the slab budget the pool serves {conc} "
              f"quarter-context requests vs {batch_32k} slab rows "
              f"({conc/batch_32k:.1f}x)")

    for name in MODELS:
        meas = measured_bf16_bytes(name)
        if meas:
            print(f"# [cross-check] {name} dry-run bf16 decode_32k HBO "
                  f"bytes global={meas/1e9:.0f} GB")

    # measured serve-latency cross-check: the roofline rows above are a
    # traffic model; the JSONL trace kernels_bench's async-serve section
    # writes (serve/metrics.py vocabulary, docs/serving.md) is a measured
    # engine run. Reported when present; a trace showing quantized-path
    # fallbacks fails the benchmark (the model assumes fused serving).
    serve_meas = None
    trace_path = os.path.join(common.CACHE, "serve_trace.jsonl")
    if os.path.exists(trace_path):
        from repro.serve.metrics import load_trace
        s = load_trace(trace_path)["summary"]
        if s is not None:
            serve_meas = {
                "ttft_s": s["ttft_s"], "tpot_s": s["tpot_s"],
                "latency_s": s["latency_s"], "steps": s["steps"],
                "requests": s["requests"],
                "prefill_interleave_ratio": s["prefill_interleave_ratio"],
                "fallbacks": s["fallbacks"],
            }
            ttft, tpot = s["ttft_s"], s["tpot_s"]
            print(f"# [measured] async serve trace ({s['requests']} "
                  f"requests, {s['steps']} steps): TTFT "
                  f"p50={ttft.get('p50', 0)*1e3:.1f}ms "
                  f"p95={ttft.get('p95', 0)*1e3:.1f}ms, TPOT "
                  f"p50={tpot.get('p50', 0)*1e3:.1f}ms, interleave="
                  f"{s['prefill_interleave_ratio']}, "
                  f"fallbacks={s['fallbacks']}")

    # ordering claim: olive > ant > int8 > gobo in the paper's regime,
    # with the gobo gap being the big one (4x-class); plus the grouped
    # kernel must serve stacked expert weights (no silent MoE fallback)
    ok = (sp_gobo > 3.0 and sp_int8 > 1.7 and sp_ant > 1.6
          and kv_32k > 2.5 and moe_served and paged_served
          and (serve_meas is None or serve_meas["fallbacks"] == 0))
    us = (time.perf_counter() - t0) * 1e6
    common.emit("speedup", us,
                f"olive_vs_gobo={sp_gobo:.2f}x vs_int8={sp_int8:.2f}x "
                f"vs_ant={sp_ant:.2f}x kv_bonus_32k={kv_32k:.2f}x "
                f"moe_grouped={moe_served} paged_kv={paged_served} "
                f"ok={ok}")
    common.save_json("speedup", {"rows": rows, "moe_grouped": moe_credit,
                                 "paged_kv": paged_rows,
                                 "serve_measured": serve_meas,
                                 "ok": bool(ok)})
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
