"""Paper Table 2: pair-type statistics (normal-normal / outlier-normal /
outlier-outlier) on model tensors.

Claim under test: outlier-outlier pairs are vanishingly rare (<0.06% in the
paper's models) so pruning one victim per outlier loses almost nothing.
We measure on (a) the in-repo trained LM's weights, (b) transformer-like
synthetic tensors at several outlier intensities, (c) pure Gaussians as the
analytic control (P[oo] = p² for independent values, p = P[>3σ] ≈ 0.27%).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.ovp import pair_statistics

from . import common


def main() -> int:
    t0 = time.perf_counter()
    rows = []

    # (a) trained LM weights
    model, params, _ = common.trained_lm()
    ws = common.weight_tensors(params)
    stats = []
    for name, w in ws.items():
        flat = jnp.asarray(w.reshape(-1))
        if flat.size % 2:
            flat = flat[:-1]
        stats.append(pair_statistics(flat))
    nn = float(np.mean([s["normal_normal"] for s in stats]))
    on = float(np.mean([s["outlier_normal"] for s in stats]))
    oo = float(np.mean([s["outlier_outlier"] for s in stats]))
    rows.append(("bench-lm weights", nn, on, oo))

    # (b) transformer-like synthetic (Fig. 2-calibrated), 3 intensities
    for tag, frac, ms in [("synthetic lo", 0.001, 30.0),
                          ("synthetic mid", 0.003, 60.0),
                          ("synthetic hi", 0.006, 150.0)]:
        x = common.transformer_like(jax.random.PRNGKey(7), (1024, 2048),
                                    max_sigma=ms, outlier_frac=frac)
        s = pair_statistics(x.reshape(-1))
        rows.append((tag, s["normal_normal"], s["outlier_normal"],
                     s["outlier_outlier"]))

    # (c) Gaussian control
    g = jax.random.normal(jax.random.PRNGKey(3), (1024, 2048))
    s = pair_statistics(g.reshape(-1))
    rows.append(("gaussian control", s["normal_normal"],
                 s["outlier_normal"], s["outlier_outlier"]))

    print("# Table 2 analogue: pair-type percentages")
    print("# source, normal-normal %, outlier-normal %, outlier-outlier %")
    worst_oo = 0.0
    for tag, nn, on, oo in rows:
        print(f"#   {tag:18s}  {100*nn:7.3f}  {100*on:6.3f}  {100*oo:7.4f}")
        worst_oo = max(worst_oo, oo)

    ok = worst_oo < 0.001  # <0.1% OO pairs, vs paper's <0.06%
    us = (time.perf_counter() - t0) * 1e6
    common.emit("table2_pairs", us,
                f"worst_oo_pct={100*worst_oo:.4f} claim_oo_lt_0.1pct={ok}")
    common.save_json("table2_pairs", {"rows": rows, "ok": bool(ok)})
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
