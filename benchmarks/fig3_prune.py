"""Paper Fig. 3: clipping outliers is disastrous; pruning victims is nearly
free.

Two tiers, because the model-level catastrophe in the paper (BERT on GLUE
dropping tens of points) requires a large pretrained model whose function
concentrates in sparse huge-magnitude values — something a 4M-param LM
trained minutes on a synthetic corpus cannot exhibit no matter how it is
surgically transformed (we verified: it shrugs off any 3σ weight surgery).

Tier 1 — tensor level (STRICT, the mechanism itself): on transformer-
statistics tensors (Fig. 2-calibrated), compare the *signal energy*
destroyed by clip-at-3σ vs prune-victims vs prune-random-normals. Outliers
carry most of the tail energy, so clipping destroys orders of magnitude
more signal than sacrificing victims.

Tier 2 — model level: an *outlier-equivalent* trained LM via the
RMSNorm->Linear rescale invariance (gamma[k]/=c, W[k,:]*=c leaves the
function bit-identical but plants genuine c-sigma functional outlier
channels, the per-channel disparity real LLMs develop). The testable claim
at this scale is the paper's ENABLING observation: pruning victims costs
no more than pruning the same number of random normal values (both ≈
free). The clip-catastrophe itself cannot be reproduced surgically — the
invariance makes outlier channels functionally equal to normals, so clip
and victim damage are comparable by construction; in real >6B models the
outliers are emergently MORE important per value. That model-level
catastrophe is carried by tier 1 (signal energy) and by table9_llm.py
(olive-4bit vs clip-based int4 on the same outlier-equivalent model).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import baselines
from repro.core.qlinear import is_linear_weight

from . import common


def _map_weights(params, fn):
    flat = jax.tree_util.tree_flatten_with_path(params)[0]
    treedef = jax.tree_util.tree_structure(params)
    out = []
    for kp, w in flat:
        path = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in kp)
        if hasattr(w, "ndim") and w.ndim >= 2 and w.size >= 4096 \
                and is_linear_weight(path, w):
            out.append(fn(jnp.asarray(w, jnp.float32), path))
        else:
            out.append(w)
    return jax.tree_util.tree_unflatten(treedef, out)


def outlier_equivalent(params, n_channels: int = 2, gain: float = 16.0,
                       seed: int = 5):
    """Functionally identical params with genuine outlier weight channels.

    RMSNorm scale invariance applied to every block norm AND the final
    norm -> lm_head pair (the critical path): gamma[k] /= gain,
    consuming-weight rows W[k, :] *= gain. Channels are chosen at even
    indices so no outlier-outlier pairs are fabricated.

    Density note: defaults plant ~1.5% outlier entries at ~16σ — the
    realistic LLM regime (paper Fig. 2 / Table 2). Denser transforms
    (e.g. 16 channels x 64 gain = 12.5% outliers) exceed OVP's design
    envelope: one 4-bit scale cannot serve a bulk plus 12% huge values,
    and OliVe-4bit degrades like int4 (measured; OliVe-8bit/E4M3 still
    holds). OVP is a *sparse*-outlier mechanism — exactly Table 2's
    statistics — and the benchmark documents that boundary.
    """
    params = jax.tree_util.tree_map(lambda x: x, params)
    key = jax.random.PRNGKey(seed)

    def channel_mask(d, k):
        idx = jax.random.choice(k, d // 2, (n_channels,),
                                replace=False) * 2
        m = jnp.zeros((d,)).at[idx].set(1.0)
        return 1.0 + (gain - 1.0) * m

    blocks = dict(params["blocks"]["0"])
    d = blocks["ln1"]["gamma_scale"].shape[-1]
    k1, k2, k3 = jax.random.split(key, 3)

    c1 = channel_mask(d, k1)
    ln1 = {"gamma_scale": blocks["ln1"]["gamma_scale"] / c1}
    attn = dict(blocks["attn"])
    for w in ("wq", "wk", "wv"):
        attn[w] = blocks["attn"][w] * c1[None, :, None]
    c2 = channel_mask(d, k2)
    ln2 = {"gamma_scale": blocks["ln2"]["gamma_scale"] / c2}
    mlp = dict(blocks["mlp"])
    for w in ("wg", "wu"):
        mlp[w] = blocks["mlp"][w] * c2[None, :, None]
    blocks.update(ln1=ln1, attn=attn, ln2=ln2, mlp=mlp)
    params["blocks"] = {"0": blocks}

    c3 = channel_mask(d, k3)
    params["final_norm"] = {
        "gamma_scale": params["final_norm"]["gamma_scale"] / c3}
    params["lm_head"] = {"w_out": params["lm_head"]["w_out"] * c3[:, None]}
    return params


def energy_loss(x, xh) -> float:
    x = np.asarray(x, np.float64)
    xh = np.asarray(xh, np.float64)
    return float(np.sum((xh - x) ** 2) / np.sum(x ** 2))


def tier1_tensor_level():
    rows = {}
    for tag, ms in [("syn60", 60.0), ("syn150", 150.0), ("syn325", 325.0)]:
        x = common.transformer_like(jax.random.PRNGKey(13), (512, 2048),
                                    max_sigma=ms, outlier_frac=0.003)
        frac = float(jnp.mean(jnp.abs(x - jnp.mean(x))
                              > 3 * jnp.std(x)))
        rows[tag] = {
            "clip": energy_loss(x, baselines.clip_outliers(x, 3.0)),
            "victim": energy_loss(x, baselines.prune_victims(x, 3.0)),
            "random": energy_loss(
                x, baselines.prune_random(x, frac, jax.random.PRNGKey(1))),
        }
    return rows


def main() -> int:
    t0 = time.perf_counter()

    # ---- tier 1: signal energy destroyed per strategy -------------------
    t1 = tier1_tensor_level()
    print("# Fig. 3 tier 1 (tensor): fraction of signal energy destroyed")
    print("# tensor, clip@3σ, prune-victim, prune-random")
    for tag, r in t1.items():
        print(f"#   {tag:8s}  {r['clip']:.4f}  {r['victim']:.6f}  "
              f"{r['random']:.6f}")
    ratios = [r["clip"] / max(r["victim"], 1e-9) for r in t1.values()]
    t1_ok = all(rr > 50 for rr in ratios)

    # ---- tier 2: model-level directional ordering -----------------------
    model, raw_params, loader = common.trained_lm()
    params = outlier_equivalent(raw_params)
    ppl_raw = common.eval_ppl(model, raw_params, loader)
    ppl_eq = common.eval_ppl(model, params, loader)
    assert abs(ppl_eq / ppl_raw - 1) < 1e-3, (ppl_raw, ppl_eq)

    def victim_matched_random(w, path):
        """Prune exactly as many random values as prune_victims zeroes."""
        v = baselines.prune_victims(w, 3.0, pair_axis=-2)
        n_vic = float(jnp.mean((v == 0) & (w != 0)))
        return baselines.prune_random(
            w, n_vic, jax.random.PRNGKey(hash(path) % (1 << 31)))

    variants = {
        "source": params,
        "clip_outlier": _map_weights(
            params, lambda w, p: baselines.clip_outliers(w, 3.0)),
        "prune_victim": _map_weights(
            params, lambda w, p: baselines.prune_victims(w, 3.0,
                                                         pair_axis=-2)),
        "prune_random": _map_weights(params, victim_matched_random),
    }
    ppl = {k: common.eval_ppl(model, v, loader)
           for k, v in variants.items()}
    print("# Fig. 3 tier 2 (model): held-out ppl after weight surgery on")
    print(f"#   the outlier-equivalent LM (invariance check "
          f"{ppl_raw:.3f} -> {ppl_eq:.3f})")
    for k, v in ppl.items():
        print(f"#   {k:14s}  ppl={v:8.3f}  "
              f"(+{100*(v/ppl['source']-1):.2f}%)")
    print("#   claim under test: victim-prune ≈ count-matched random-prune"
          " ≈ free (the OVP-enabling observation). The clip catastrophe "
          "is carried by tier 1 + table9 (see module docstring).")

    d_clip = ppl["clip_outlier"] / ppl["source"] - 1
    d_vic = ppl["prune_victim"] / ppl["source"] - 1
    d_rnd = ppl["prune_random"] / ppl["source"] - 1
    t2_ok = (d_vic < 0.02) and (abs(d_vic - d_rnd) < 0.01)

    ok = t1_ok and t2_ok
    us = (time.perf_counter() - t0) * 1e6
    common.emit("fig3_prune", us,
                f"t1_clip/victim_energy={min(ratios):.0f}x "
                f"t2: clip=+{100*d_clip:.2f}% victim=+{100*d_vic:.2f}% "
                f"random=+{100*d_rnd:.2f}% ok={ok}")
    common.save_json("fig3_prune", {"tier1": t1, "ppl": ppl, "ok": ok})
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
