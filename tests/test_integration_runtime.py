"""Integration tests: fault tolerance, elastic restore, trainer resume,
loader determinism, serving engine quantized-vs-fp agreement.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import ckpt
from repro.configs.base import ArchConfig
from repro.core.policy import QuantPolicy
from repro.core.qlinear import quantize_params
from repro.data.loader import LoaderCfg, SyntheticLoader
from repro.data.synthetic import CorpusCfg
from repro.models.model import build_model
from repro.optim.adamw import AdamW
from repro.runtime.elastic import plan_mesh, resize_plan
from repro.runtime.fault import (PreemptionHandler, StepTimer,
                                 StragglerMonitor)
from repro.train.trainer import Trainer, TrainerCfg

TINY = ArchConfig(name="it-tiny", family="dense", n_layers=2, d_model=64,
                  n_heads=4, n_kv_heads=2, d_ff=128, vocab=256,
                  head_dim=16, block_pattern=("attn",))


def _loader(batch=4, seq=32, vocab=256):
    return SyntheticLoader(LoaderCfg(global_batch=batch, seq_len=seq,
                                     corpus=CorpusCfg(vocab=vocab)))


# --------------------------------------------------------------------------
# checkpoint: atomic publish, latest_step, restore exactness
# --------------------------------------------------------------------------
class TestCheckpoint:
    def test_save_restore_bit_exact(self, tmp_path):
        tree = {"a": jnp.arange(12.0).reshape(3, 4),
                "n": {"b": jnp.ones((2,), jnp.bfloat16)}}
        ckpt.save(str(tmp_path), 7, tree, blocking=True)
        assert ckpt.latest_step(str(tmp_path)) == 7
        out = ckpt.restore(str(tmp_path), 7, tree)
        np.testing.assert_array_equal(np.asarray(out["a"]),
                                      np.asarray(tree["a"]))
        assert out["n"]["b"].dtype == jnp.bfloat16

    def test_incomplete_checkpoint_ignored(self, tmp_path):
        tree = {"a": jnp.zeros((2,))}
        ckpt.save(str(tmp_path), 3, tree, blocking=True)
        # simulate a crash mid-write: dir without manifest
        broken = tmp_path / "step_00000009"
        broken.mkdir()
        (broken / "arrays.npz").write_bytes(b"junk")
        assert ckpt.latest_step(str(tmp_path)) == 3

    def test_async_save_joins(self, tmp_path):
        tree = {"a": jnp.ones((128, 128))}
        th = ckpt.save(str(tmp_path), 1, tree, blocking=False)
        th.join()
        assert ckpt.latest_step(str(tmp_path)) == 1

    def test_gc_keeps_last_k(self, tmp_path):
        tree = {"a": jnp.zeros((2,))}
        for s in range(6):
            ckpt.save(str(tmp_path), s, tree, blocking=True, keep=3)
        steps = sorted(int(d.split("_")[1])
                       for d in os.listdir(tmp_path)
                       if d.startswith("step_"))
        assert steps == [3, 4, 5]


# --------------------------------------------------------------------------
# trainer: resume produces the identical trajectory
# --------------------------------------------------------------------------
class TestTrainerResume:
    def test_resume_matches_uninterrupted(self, tmp_path):
        model = build_model(TINY, QuantPolicy(compute_dtype="float32"),
                            remat=False)
        loader = _loader()

        def make(steps, ckpt_dir, every):
            opt = AdamW(lr=1e-3)
            t = Trainer(model, opt, loader,
                        TrainerCfg(total_steps=steps, ckpt_dir=ckpt_dir,
                                   ckpt_every=every, ckpt_async=False,
                                   log_every=1000))
            return t.init_or_restore()

        # uninterrupted 6 steps
        t_full = make(6, "", 0)
        h_full = t_full.run()

        # interrupted at 3 (checkpoint), then resumed to 6
        d = str(tmp_path / "ck")
        t_a = make(3, d, 3)
        t_a.run()
        t_b = make(6, d, 3)
        assert t_b.step == 3
        h_b = t_b.run()
        np.testing.assert_allclose(h_full["loss"][3:], h_b["loss"],
                                   rtol=2e-4)

    def test_preemption_saves_state(self, tmp_path):
        model = build_model(TINY, QuantPolicy(compute_dtype="float32"),
                            remat=False)
        t = Trainer(model, AdamW(lr=1e-3), _loader(),
                    TrainerCfg(total_steps=50,
                               ckpt_dir=str(tmp_path / "p"),
                               ckpt_every=0, ckpt_async=False,
                               log_every=1000))
        t.init_or_restore()
        t.preempt.trigger()          # simulated SIGTERM
        t.run()
        assert t.step < 50           # stopped early
        assert ckpt.latest_step(str(tmp_path / "p")) == t.step


# --------------------------------------------------------------------------
# elastic: restore onto a different device count
# --------------------------------------------------------------------------
class TestElastic:
    def test_plan_mesh_shapes(self):
        p = plan_mesh(512, prefer_model=16)
        assert p.n_devices == 512
        r = resize_plan(p, 256)
        assert r["new_plan"].n_devices == 256
        assert r["needs_reshard"]

    def test_restore_after_mesh_change(self, tmp_path):
        # params saved flat restore cleanly regardless of mesh: on CPU we
        # emulate by restoring into a template with identical structure
        model = build_model(TINY, QuantPolicy(compute_dtype="float32"),
                            remat=False)
        params = model.init(jax.random.PRNGKey(0))
        ckpt.save(str(tmp_path), 1, {"params": params}, blocking=True)
        out = ckpt.restore(str(tmp_path), 1, {"params": params})["params"]
        a = jax.tree_util.tree_leaves(params)
        b = jax.tree_util.tree_leaves(out)
        for x, y in zip(a, b):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# --------------------------------------------------------------------------
# fault primitives
# --------------------------------------------------------------------------
class TestFault:
    def test_straggler_detection(self):
        mon = StragglerMonitor(n_hosts=4, threshold=2.0)
        for _ in range(8):
            for h in range(4):
                mon.record(h, 0.1 if h != 2 else 0.5)
        assert mon.stragglers() == [2]
        assert not mon.healthy()

    def test_step_timer_records(self):
        mon = StragglerMonitor(n_hosts=1)
        with StepTimer(mon, host=0) as t:
            pass
        assert t.last >= 0.0

    def test_preemption_handler_restore(self):
        h = PreemptionHandler(signals=())
        assert not h.should_stop
        h.trigger()
        assert h.should_stop
        h.restore()


# --------------------------------------------------------------------------
# loader determinism (restart safety)
# --------------------------------------------------------------------------
class TestLoader:
    def test_same_step_same_batch(self):
        l1, l2 = _loader(), _loader()
        b1 = l1.global_batch_at(17)
        b2 = l2.global_batch_at(17)
        np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                      np.asarray(b2["tokens"]))

    def test_rank_shards_disjoint(self):
        lr = SyntheticLoader(LoaderCfg(global_batch=8, seq_len=16,
                                       n_ranks=2))
        a = lr.batch_at(0, rank=0)["tokens"]
        b = lr.batch_at(0, rank=1)["tokens"]
        assert not np.array_equal(np.asarray(a), np.asarray(b))

    def test_eval_split_disjoint_from_train(self):
        lo = _loader()
        tr = lo.global_batch_at(0)["tokens"]
        ev = lo.global_batch_at(0, eval_split=True)["tokens"]
        assert not np.array_equal(np.asarray(tr), np.asarray(ev))


# --------------------------------------------------------------------------
# serving: engine agreement between fp and OliVe-quantized weights
# --------------------------------------------------------------------------
class TestServingQuant:
    def test_engine_outputs_agree(self):
        from repro.serve.engine import EngineCfg, ServingEngine
        model_fp = build_model(TINY, QuantPolicy(compute_dtype="float32"),
                               remat=False)
        params = model_fp.init(jax.random.PRNGKey(1))
        pol = QuantPolicy(method="olive", wbits=8, abits=0,
                          w_normal_dtype="int8", compute_dtype="float32")
        qparams = quantize_params(params, pol)
        model_q = build_model(TINY, pol, remat=False)

        rng = np.random.default_rng(0)
        prompts = [rng.integers(0, 256, size=6).astype(np.int32)
                   for _ in range(3)]

        def run(model, p):
            eng = ServingEngine(model, p, EngineCfg(batch_slots=2,
                                                    max_len=32))
            for pr in prompts:
                eng.submit(pr, max_new_tokens=4)
            return {r.uid: r.out_tokens for r in eng.run_until_drained()}

        a = run(model_fp, params)
        b = run(model_q, qparams)
        # 8-bit OliVe is near-lossless -> greedy tokens should agree
        agree = [np.mean([x == y for x, y in zip(a[k], b[k])])
                 for k in a]
        assert np.mean(agree) > 0.7
