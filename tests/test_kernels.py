"""Pallas kernels vs pure-jnp oracles (interpret=True on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.ovp import ovp_quantize
from repro.core.quantizer import sigma_init_scale
from repro.kernels import ops, ref
from repro.kernels.ovp_matmul import ovp_matmul_w4a16, ovp_matmul_w4a4
from repro.kernels.ovp_encode import ovp_encode_pallas

from test_ovp import heavy_tailed

SHAPES = [  # (M, K, N) — aligned, unaligned, tall, wide
    (128, 256, 128),
    (64, 128, 256),
    (256, 512, 64),
    (8, 256, 128),
    (130, 260, 136),   # forces padding in every dim
    (1, 512, 128),     # decode-style single row
]


def make_packed(key, k, n, normal_dtype="int4"):
    w = heavy_tailed(key, (k, n), outlier_frac=0.01, outlier_scale=12.0)
    s = sigma_init_scale(w, normal_dtype)
    qt = ovp_quantize(w, s, normal_dtype, pair_axis=0)
    return qt


class TestMatmulW4A16:
    @pytest.mark.parametrize("m,k,n", SHAPES)
    @pytest.mark.parametrize("adtype", [jnp.float32, jnp.bfloat16])
    def test_matches_ref(self, m, k, n, adtype):
        ka, kw = jax.random.split(jax.random.PRNGKey(m + k + n))
        a = jax.random.normal(ka, (m, k), dtype=jnp.float32).astype(adtype)
        qt = make_packed(kw, k, n)
        got = ops.matmul_w4a16(a, qt.data, jnp.asarray(qt.scale).reshape(-1),
                               "int4", interpret=True)
        want = ref.ovp_matmul_w4a16_ref(a, qt.data) * jnp.asarray(
            qt.scale).reshape(1, -1)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-2 if adtype == jnp.bfloat16
                                   else 1e-5,
                                   atol=1e-2 if adtype == jnp.bfloat16
                                   else 1e-4)

    @pytest.mark.parametrize("nd", ["int4", "flint4"])
    def test_normal_dtypes(self, nd):
        m, k, n = 64, 256, 128
        ka, kw = jax.random.split(jax.random.PRNGKey(0))
        a = jax.random.normal(ka, (m, k))
        qt = make_packed(kw, k, n, nd)
        got = ovp_matmul_w4a16(a, qt.data, nd, interpret=True)
        want = ref.ovp_matmul_w4a16_ref(a, qt.data, nd)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-4)

    @pytest.mark.parametrize("bk", [128, 256, 512])
    def test_block_size_sweep(self, bk):
        m, k, n = 128, 512, 128
        ka, kw = jax.random.split(jax.random.PRNGKey(1))
        a = jax.random.normal(ka, (m, k))
        qt = make_packed(kw, k, n)
        got = ovp_matmul_w4a16(a, qt.data, bk=bk, interpret=True)
        want = ref.ovp_matmul_w4a16_ref(a, qt.data)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-4)


class TestMatmulW4A4:
    @pytest.mark.parametrize("m,k,n", SHAPES)
    def test_matches_ref(self, m, k, n):
        ka, kw = jax.random.split(jax.random.PRNGKey(m * 7 + n))
        x = heavy_tailed(ka, (m, k), outlier_frac=0.01, outlier_scale=10.0)
        sa = sigma_init_scale(x, "int4")
        aq = ovp_quantize(x, sa, "int4", pair_axis=-1)
        wq = make_packed(kw, k, n)
        got = ops.matmul_w4a4(aq.data, jnp.asarray(aq.scale),
                              wq.data, jnp.asarray(wq.scale).reshape(-1),
                              interpret=True)
        want = (ref.ovp_matmul_w4a4_ref(aq.data, wq.data)
                * jnp.asarray(aq.scale)
                * jnp.asarray(wq.scale).reshape(1, -1))
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-4)

    def test_dispatch_from_quantized_tensors(self):
        m, k, n = 32, 128, 64
        ka, kw = jax.random.split(jax.random.PRNGKey(3))
        x = heavy_tailed(ka, (2, m, k)) * 0.3       # batched activations
        sa = sigma_init_scale(x, "int4")
        aq = ovp_quantize(x, sa, "int4", pair_axis=-1)
        wq = make_packed(kw, k, n)
        got = ops.ovp_matmul(aq, wq, interpret=True)
        assert got.shape == (2, m, n)
        from repro.core.ovp import ovp_dequantize
        want = jnp.matmul(ovp_dequantize(aq), ovp_dequantize(wq))
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-3)

    def test_end_to_end_error_small_vs_fp(self):
        """Full W4A4 pipeline ≈ fp matmul within quantization error."""
        m, k, n = 64, 512, 64
        ka, kw = jax.random.split(jax.random.PRNGKey(4))
        x = jax.random.normal(ka, (m, k)) * 0.5
        w = heavy_tailed(kw, (k, n), outlier_frac=0.005,
                         outlier_scale=8.0) * 0.05
        sa = sigma_init_scale(x, "int4")
        aq = ovp_quantize(x, sa, "int4", pair_axis=-1)
        wq = ovp_quantize(w, sigma_init_scale(w, "int4"), "int4",
                          pair_axis=0)
        got = ops.ovp_matmul(aq, wq, interpret=True)
        want = x @ w
        rel = float(jnp.linalg.norm(got - want) / jnp.linalg.norm(want))
        # 3σ-init scales without the MSE search (wiring test, not accuracy;
        # accuracy with searched scales is covered in test_quantizer)
        assert rel < 0.3


class TestEncodeKernel:
    @pytest.mark.parametrize("m,k", [(64, 128), (256, 512), (33, 130),
                                     (1, 4096), (128, 64)])
    def test_matches_ref(self, m, k):
        key = jax.random.PRNGKey(m + k)
        x = heavy_tailed(key, (m, k), outlier_frac=0.02, outlier_scale=9.0)
        s = sigma_init_scale(x, "int4")
        got = ops.ovp_encode(x, s, interpret=True)
        want = ref.ovp_encode_ref(x / s)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_encode_then_kernel_matmul(self):
        """Online activation quant + fused matmul (the serving path)."""
        m, k, n = 64, 256, 64
        ka, kw = jax.random.split(jax.random.PRNGKey(5))
        x = jax.random.normal(ka, (m, k))
        s = sigma_init_scale(x, "int4")
        packed = ops.ovp_encode(x, s, interpret=True)
        wq = make_packed(kw, k, n)
        got = ops.matmul_w4a4(packed, s, wq.data,
                              jnp.asarray(wq.scale).reshape(-1),
                              interpret=True)
        want = x @ (ref.decode_packed(wq.data, "int4", 0)
                    * jnp.asarray(wq.scale).reshape(1, -1))
        rel = float(jnp.linalg.norm(got - want) / jnp.linalg.norm(want))
        assert rel < 0.25  # activation quantization error only
