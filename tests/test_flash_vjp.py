"""FlashAttention-2 custom VJP (§Perf iteration F) vs naive attention:
forward AND gradients must match for causal/non-causal, GQA groups,
ragged lengths, q_offset (decode prefill continuation), and chunk sizes.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.layers import blockwise_attention


def naive_attention(q, k, v, causal=True, q_offset=0):
    b, t, h, d = q.shape
    s = k.shape[1]
    hkv = k.shape[2]
    g = h // hkv
    qr = q.reshape(b, t, hkv, g, d).astype(jnp.float32)
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", qr,
                        k.astype(jnp.float32)) / math.sqrt(d)
    if causal:
        qpos = q_offset + jnp.arange(t)
        mask = qpos[:, None] >= jnp.arange(s)[None, :]
        scores = jnp.where(mask[None, None, None], scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return out.reshape(b, t, h, d).astype(q.dtype)


def _qkv(key, b=2, t=24, s=24, h=4, hkv=2, d=8):
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, t, h, d))
    k = jax.random.normal(ks[1], (b, s, hkv, d))
    v = jax.random.normal(ks[2], (b, s, hkv, d))
    return q, k, v


CASES = [
    dict(causal=True, q_offset=0, t=24, s=24, qc=8, kc=8),
    dict(causal=False, q_offset=0, t=24, s=40, qc=8, kc=16),
    dict(causal=True, q_offset=16, t=8, s=24, qc=4, kc=8),   # continuation
    dict(causal=True, q_offset=0, t=17, s=17, qc=8, kc=8),   # ragged
    dict(causal=True, q_offset=0, t=24, s=24, qc=512, kc=512),  # one chunk
]


@pytest.mark.parametrize("case", CASES)
def test_forward_matches_naive(case):
    q, k, v = _qkv(jax.random.PRNGKey(0), t=case["t"], s=case["s"])
    got = blockwise_attention(q, k, v, causal=case["causal"],
                              q_offset=case["q_offset"],
                              q_chunk=case["qc"], kv_chunk=case["kc"])
    want = naive_attention(q, k, v, case["causal"], case["q_offset"])
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("case", CASES)
def test_gradients_match_naive(case):
    q, k, v = _qkv(jax.random.PRNGKey(1), t=case["t"], s=case["s"])
    tangent = jax.random.normal(jax.random.PRNGKey(2),
                                (2, case["t"], 4, 8))

    def loss_flash(q, k, v):
        out = blockwise_attention(q, k, v, causal=case["causal"],
                                  q_offset=case["q_offset"],
                                  q_chunk=case["qc"], kv_chunk=case["kc"])
        return jnp.sum(out * tangent)

    def loss_naive(q, k, v):
        return jnp.sum(naive_attention(q, k, v, case["causal"],
                                       case["q_offset"]) * tangent)

    gf = jax.grad(loss_flash, argnums=(0, 1, 2))(q, k, v)
    gn = jax.grad(loss_naive, argnums=(0, 1, 2))(q, k, v)
    for a, b_, name in zip(gf, gn, "qkv"):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                                   rtol=2e-4, atol=2e-5,
                                   err_msg=f"d{name} mismatch")


def test_grad_finite_with_fully_masked_rows():
    """q_offset puts early rows before any key: lse=+inf rows must produce
    zero (not NaN) gradients."""
    q, k, v = _qkv(jax.random.PRNGKey(3), t=8, s=4)
    # causal with q_offset=-4: first 4 q rows see no keys  (clip at 0 via
    # construction: use keys starting 'later' by passing offset negative)
    def loss(q, k, v):
        out = blockwise_attention(q, k, v, causal=True, q_offset=-4 + 0,
                                  q_chunk=4, kv_chunk=4)
        return jnp.sum(out ** 2)

    g = jax.grad(loss, argnums=(0, 1, 2))(q, k, v)
    for arr in g:
        assert np.all(np.isfinite(np.asarray(arr)))