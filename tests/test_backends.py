"""Backend registry + cross-backend equivalence suite.

Every registered execution backend must produce the same quantized matmul
(up to fp32 reassociation) as the pure-jnp reference backend, for every
weight/activation precision the policies can express, per-tensor and
per-channel weight scales, and 2-D / 3-D lhs. The fused Pallas path (one
pallas_call: in-kernel activation quantization + scale epilogue) is
verified against the XLA encode->decode path it replaced.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import backends
from repro.core.ovp import QuantizedTensor, ovp_dequantize, ovp_quantize
from repro.core.policy import QuantPolicy
from repro.core.qlinear import qmatmul, quantize_weight
from repro.core.quantizer import sigma_init_scale

from test_ovp import heavy_tailed

# every backend that must agree with "reference" (the fp32 oracle);
# "pallas" (compiled) is the same kernel as "pallas_interpret" and needs a
# TPU, so CPU CI exercises the interpret twin
EQUIV_BACKENDS = ["xla", "pallas_interpret"]

POLICIES = {
    "w4a16": dict(wbits=4, abits=0),
    "w4a4": dict(wbits=4, abits=4),
    "w4a4_flint4": dict(wbits=4, abits=4, w_normal_dtype="flint4",
                        a_normal_dtype="flint4"),
    "w8a8_int8_ovp": dict(wbits=8, abits=8, w_normal_dtype="int8",
                          a_normal_dtype="int8"),
}


def make_policy(kind: str, granularity: str, backend: str) -> QuantPolicy:
    return QuantPolicy(method="olive", compute_dtype="float32",
                       w_granularity=granularity, backend=backend,
                       **POLICIES[kind])


def rel_err(got, want):
    got, want = np.asarray(got, np.float64), np.asarray(want, np.float64)
    return float(np.max(np.abs(got - want)) / (np.max(np.abs(want)) + 1e-9))


@pytest.fixture(scope="module")
def operands():
    key = jax.random.PRNGKey(7)
    ka, kx, kw = jax.random.split(key, 3)
    k, n = 128, 96
    x2 = heavy_tailed(kx, (48, k), outlier_frac=0.01, outlier_scale=9.0)
    x3 = heavy_tailed(ka, (3, 16, k), outlier_frac=0.01, outlier_scale=9.0)
    w = heavy_tailed(kw, (k, n), outlier_frac=0.01, outlier_scale=9.0)
    return x2, x3, w


class TestRegistry:
    def test_expected_backends_registered(self):
        for name in ("xla", "pallas", "pallas_interpret", "reference"):
            assert name in backends.available()

    def test_unknown_backend_raises_with_options(self):
        with pytest.raises(KeyError, match="registered"):
            backends.get_backend("tpu_v9")

    def test_register_and_dispatch_custom_backend(self, operands):
        x2, _, w = operands

        class Doubling(backends.XlaBackend):
            name = "xla_doubled"

            def matmul(self, x, wq, policy, act_scale=None,
                       precision=None, site=""):
                return 2.0 * super().matmul(x, wq, policy,
                                            act_scale, precision, site)

        backends.register(Doubling())
        try:
            pol = make_policy("w4a16", "tensor", "xla_doubled")
            wq = quantize_weight(w, pol)
            got = backends.dispatch(x2, wq, pol)
            want = backends.dispatch(
                x2, wq, dataclasses.replace(pol, backend="xla"))
            np.testing.assert_allclose(np.asarray(got), 2 * np.asarray(want),
                                       rtol=1e-6)
        finally:
            backends._REGISTRY.pop("xla_doubled")


class TestBackendEquivalence:
    @pytest.mark.parametrize("backend", EQUIV_BACKENDS)
    @pytest.mark.parametrize("granularity", ["tensor", "channel"])
    @pytest.mark.parametrize("kind", sorted(POLICIES))
    def test_matches_reference_2d(self, backend, granularity, kind,
                                  operands):
        x2, _, w = operands
        pol = make_policy(kind, granularity, backend)
        wq = quantize_weight(w, pol)
        assert isinstance(wq, QuantizedTensor)
        got = qmatmul(x2, wq, pol)
        want = qmatmul(x2, wq,
                       dataclasses.replace(pol, backend="reference"))
        assert got.shape == want.shape
        assert rel_err(got, want) < 1e-5, (backend, granularity, kind)

    @pytest.mark.parametrize("backend", EQUIV_BACKENDS)
    @pytest.mark.parametrize("kind", sorted(POLICIES))
    def test_matches_reference_3d(self, backend, kind, operands):
        """3-D lhs (serving decode-step layout) takes the same fused path
        with no reshape glue and agrees with the oracle."""
        _, x3, w = operands
        pol = make_policy(kind, "channel", backend)
        wq = quantize_weight(w, pol)
        got = qmatmul(x3, wq, pol)
        want = qmatmul(x3, wq,
                       dataclasses.replace(pol, backend="reference"))
        assert got.shape == x3.shape[:-1] + (w.shape[1],)
        assert rel_err(got, want) < 1e-5, (backend, kind)

    @pytest.mark.parametrize("backend", EQUIV_BACKENDS)
    def test_static_per_row_act_scale(self, backend, operands):
        """Per-row static activation scales flow into the fused prologue /
        epilogue identically across backends."""
        x2, _, w = operands
        pol = dataclasses.replace(
            make_policy("w4a4", "channel", backend),
            act_scale_mode="static")
        wq = quantize_weight(w, pol)
        row_scale = jnp.linspace(0.05, 0.4, x2.shape[0])[:, None]
        got = qmatmul(x2, wq, pol, act_scale=row_scale)
        want = qmatmul(x2, wq,
                       dataclasses.replace(pol, backend="reference"),
                       act_scale=row_scale)
        assert rel_err(got, want) < 1e-5

    def test_decode_single_row_3d(self):
        """(B, 1, K) decode-step GEMM on the fused kernel batch dim."""
        key = jax.random.PRNGKey(3)
        x = jax.random.normal(key, (4, 1, 64))
        w = jax.random.normal(jax.random.split(key)[0], (64, 32))
        pol = make_policy("w4a4", "channel", "pallas_interpret")
        wq = quantize_weight(w, pol)
        got = qmatmul(x, wq, pol)
        want = qmatmul(x, wq,
                       dataclasses.replace(pol, backend="reference"))
        assert got.shape == (4, 1, 32)
        assert rel_err(got, want) < 1e-5


class TestMixedPrecision:
    def test_int8_act_with_4bit_weight_on_pallas(self, operands):
        """Regression: abits=8 with a packed 4-bit weight used to reach
        matmul_w4a4 as an unpacked int8 QuantizedTensor and trip the K/2
        shape assert; it now runs fused and matches the XLA path."""
        x2, x3, w = operands
        pol = QuantPolicy(method="olive", wbits=4, abits=8,
                          compute_dtype="float32",
                          backend="pallas_interpret")
        wq = quantize_weight(w, pol)
        assert wq.is_packed  # 4-bit weight, 8-bit activations
        for x in (x2, x3):
            got = qmatmul(x, wq, pol)
            want = qmatmul(x, wq,
                           dataclasses.replace(pol, backend="xla"))
            assert rel_err(got, want) < 1e-5

    def test_prepacked_int8_activation_tensor(self, operands):
        """ops.ovp_matmul no longer raises NotImplementedError on int8
        OVP operands (one code per byte)."""
        from repro.kernels import ops
        x2, _, w = operands
        wq = ovp_quantize(w, sigma_init_scale(w, "int8"), "int8",
                          pair_axis=0)
        aq = ovp_quantize(x2, sigma_init_scale(x2, "int8"), "int8",
                          pair_axis=-1)
        got = ops.ovp_matmul(aq, wq, interpret=True)
        want = ovp_dequantize(aq) @ ovp_dequantize(wq)
        assert rel_err(got, want) < 1e-5


class TestStackedWeights:
    def test_per_expert_stacked_serves_grouped(self, operands):
        """Stacked (per-expert) weights dispatch cleanly on every backend:
        the grouped Pallas kernel serves them (no XLA fallback — the
        dispatch ledger must show the stack was served, not declined) and
        agrees with the XLA broadcast path. The full grouped matrix lives
        in tests/test_grouped_kernel.py."""
        key = jax.random.PRNGKey(11)
        e, c, k, f = 4, 8, 64, 48
        xg = jax.random.normal(key, (e, c, k))
        ws = jax.random.normal(jax.random.split(key)[0], (e, k, f))
        pol = make_policy("w4a16", "channel", "pallas_interpret")
        wq = quantize_weight(ws, pol)
        assert wq.data.ndim == 3
        backends.reset_dispatch_stats()
        got = backends.dispatch(xg, wq, pol)
        stats = backends.dispatch_stats()
        assert stats.get("pallas_interpret[stacked]") == 1
        assert not any("->fallback:" in tag for tag in stats)
        want = backends.dispatch(
            xg, wq, dataclasses.replace(pol, backend="xla"))
        assert got.shape == (e, c, f)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)


class TestFusedSingleDispatch:
    def test_w4a4_is_one_pallas_call(self, operands):
        """Acceptance: fused W4A4 with in-kernel activation quantization
        and in-epilogue scales is a single pallas_call."""
        from repro.backends import count_pallas_calls
        from repro.kernels import ops
        x2, _, w = operands
        pol = make_policy("w4a4", "channel", "pallas_interpret")
        wq = quantize_weight(w, pol)
        scale = sigma_init_scale(x2, "int4")

        def fused(x):
            return ops.fused_ovp_matmul(x, wq, a_dtype="int4",
                                        act_scale=scale, interpret=True)

        assert count_pallas_calls(fused, x2) == 1
        # and the one call matches the XLA encode->decode round trip
        aq = ovp_quantize(x2, scale, "int4", pair_axis=-1)
        want = ovp_dequantize(aq) @ ovp_dequantize(wq)
        assert rel_err(fused(x2), want) < 1e-5
