"""Shared pytest fixtures.

The tier-1 suite runs every module in one process; on JAX-CPU each
module's jitted programs stay resident in XLA's executable cache for the
life of the process. With the full suite that accumulation segfaults the
CPU compiler late in the run (deep inside `backend_compile`, while
compiling an unrelated fresh trace) even though every module passes in
isolation. Dropping the caches at module boundaries bounds the resident
executable set; modules re-jit their own programs anyway, so the only
cost is a handful of recompiles.
"""
from __future__ import annotations

import jax
import pytest


@pytest.fixture(autouse=True, scope="module")
def _bound_xla_executable_cache():
    yield
    jax.clear_caches()
