"""Shared pytest fixtures.

Multi-device setup: the sharded-backend parity suite needs a real
multi-device `jax.devices()` view, so the XLA host platform is forced to
8 logical CPU devices BEFORE jax initializes (the flag is read once at
backend init — setting it after `import jax` has already created the
backend is a no-op). An externally provided
`xla_force_host_platform_device_count` (e.g. CI's env) wins.

The tier-1 suite runs every module in one process; on JAX-CPU each
module's jitted programs stay resident in XLA's executable cache for the
life of the process. With the full suite that accumulation segfaults the
CPU compiler late in the run (deep inside `backend_compile`, while
compiling an unrelated fresh trace) even though every module passes in
isolation. Dropping the caches at module boundaries bounds the resident
executable set; modules re-jit their own programs anyway, so the only
cost is a handful of recompiles.
"""
from __future__ import annotations

import os

_flags = os.environ.get("XLA_FLAGS", "")
if "xla_force_host_platform_device_count" not in _flags:
    os.environ["XLA_FLAGS"] = \
        (_flags + " --xla_force_host_platform_device_count=8").strip()

import jax  # noqa: E402  (must come after the XLA_FLAGS export above)
import pytest  # noqa: E402


@pytest.fixture(autouse=True, scope="module")
def _bound_xla_executable_cache():
    yield
    jax.clear_caches()


@pytest.fixture(scope="session")
def forced_devices():
    """The forced 8-logical-device view for multi-device tests.

    Skips (rather than fails) when the host could not be forced — e.g. a
    TPU runtime where the host-platform flag does not apply — so the
    sharded parity suite degrades gracefully off-CI.
    """
    devs = jax.devices()
    if len(devs) < 8:
        pytest.skip(f"needs 8 forced host devices, have {len(devs)}")
    return devs
