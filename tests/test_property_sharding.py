"""Property tests for sharding/rules.py and runtime/elastic.py.

Hypothesis sweeps the input lattice the example tests can't: arbitrary
leaf shapes × mesh sizes for `param_spec` (every axis a spec assigns
must DIVIDE that dim — the alternative is replication, never a crash or
a ragged shard), and arbitrary device counts for `plan_mesh` /
`resize_plan` (every device is either in the mesh or reported dropped,
the global batch always divides the data axis so per-device token
counts stay integral, and a same-count resize is an exact no-op).

Hypothesis ships in tests/requirements-optional.txt (CI installs it);
locally absent -> the module skips.
"""
from __future__ import annotations

import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from jax.sharding import PartitionSpec as P  # noqa: E402

from repro.configs import get_config  # noqa: E402
from repro.runtime.elastic import plan_mesh, resize_plan  # noqa: E402
from repro.sharding.rules import (COL_PARALLEL, ROW_PARALLEL,  # noqa: E402
                                  param_spec)


class K:
    def __init__(self, key):
        self.key = key


CFG = get_config("yi-6b")

LEAF_NAMES = sorted(COL_PARALLEL | ROW_PARALLEL) + [
    "table", "w_out", "gamma_scale", "b_out", "scale", "kernel"]

leaf = st.sampled_from(LEAF_NAMES)
dims = st.integers(min_value=1, max_value=12).map(lambda n: 2 * n)
shapes = st.lists(dims, min_size=1, max_size=4).map(tuple)
axis = st.sampled_from([1, 2, 3, 4, 8])


def _axes_product(entry, sizes):
    if entry is None:
        return 1
    names = entry if isinstance(entry, tuple) else (entry,)
    n = 1
    for a in names:
        n *= sizes[a]
    return n


@settings(max_examples=200, deadline=None)
@given(name=leaf, shape=shapes, data=axis, model=axis,
       dp_only=st.booleans(), under_experts=st.booleans())
def test_param_spec_divides_or_replicates(name, shape, data, model,
                                          dp_only, under_experts):
    """Whatever the leaf/mesh combination, param_spec never crashes and
    every mesh axis it assigns divides its dim exactly."""
    path = (K("blocks"), K("0"), K("attn"), K(name))
    if under_experts:
        path = (K("blocks"), K("0"), K("moe"), K("experts"), K(name))
    sizes = {"data": data, "model": model}
    spec = param_spec(path, shape, CFG, sizes, dp_only=dp_only)
    assert isinstance(spec, P)
    assert len(spec) == len(shape)
    for dim, entry in zip(shape, spec):
        assert dim % _axes_product(entry, sizes) == 0, (
            f"{name}: spec {spec} does not divide shape {shape} "
            f"under {sizes}")


@settings(max_examples=200, deadline=None)
@given(n=st.integers(min_value=1, max_value=511),
       prefer=st.sampled_from([1, 2, 4, 8, 16]),
       gb=st.sampled_from([8, 64, 256, 384, 512]))
def test_plan_mesh_invariants(n, prefer, gb):
    plan = plan_mesh(n, prefer_model=prefer, global_batch=gb)
    data, model = plan.shape[-2], plan.shape[-1]
    # single-pod fleet (n < 512): every device is in the mesh or dropped
    assert plan.n_devices + plan.dropped_devices == n
    assert plan.dropped_devices >= 0
    # the model axis is a power of two capped by the preference
    assert model & (model - 1) == 0
    assert model <= prefer
    # per-device token counts stay integral
    assert gb % data == 0


@settings(max_examples=200, deadline=None)
@given(n=st.integers(min_value=1, max_value=511),
       m=st.integers(min_value=1, max_value=511),
       gb=st.sampled_from([64, 256, 512]))
def test_resize_plan_token_round_trip(n, m, gb):
    """Grow/shrink n -> m: the new plan obeys the same token-count
    invariants and dp_ratio reports exactly the data-parallel rescale
    (what the batch splitter uses to re-apportion tokens)."""
    old = plan_mesh(n, global_batch=gb)
    r = resize_plan(old, m, global_batch=gb)
    new = r["new_plan"]
    assert new.n_devices + new.dropped_devices == m
    assert gb % new.shape[-2] == 0
    assert r["tp_changed"] == (new.shape[-1] != old.shape[-1])
    assert r["needs_reshard"] == (new.shape != old.shape)
    expect = (new.n_devices / new.shape[-1]) / \
        max(old.n_devices / old.shape[-1], 1)
    assert r["dp_ratio"] == pytest.approx(expect)


@settings(max_examples=100, deadline=None)
@given(n=st.integers(min_value=1, max_value=511),
       gb=st.sampled_from([64, 256]))
def test_resize_plan_same_count_is_noop(n, gb):
    """Resizing to the device count the old plan actually uses must be
    an exact round trip: same shape, no reshard, dp_ratio 1."""
    old = plan_mesh(n, global_batch=gb)
    r = resize_plan(old, old.n_devices, global_batch=gb)
    assert r["new_plan"].shape == old.shape
    assert not r["needs_reshard"]
    assert not r["tp_changed"]
    assert r["dp_ratio"] == pytest.approx(1.0)