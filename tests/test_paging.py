"""PagePool allocator suite: reserve-before-admit accounting, all-or-
nothing grants, double-free detection, compaction, and the pool-sizing
helpers the paged benchmarks build on (serve/paging.py)."""
from __future__ import annotations

import numpy as np
import pytest

from repro.serve.paging import (PagePool, PagePoolCfg,
                                kv_bytes_per_token_per_site,
                                max_concurrent_requests, pages_for,
                                pool_pages_for_budget)


def test_cfg_validates_page_size():
    assert PagePoolCfg().page_size == 16
    with pytest.raises(ValueError, match="even int"):
        PagePoolCfg(page_size=7)
    with pytest.raises(ValueError, match="even int"):
        PagePoolCfg(page_size=0)
    with pytest.raises(ValueError, match="n_pages"):
        PagePoolCfg(n_pages=-1)


def test_pages_for():
    assert pages_for(1, 16) == 1
    assert pages_for(16, 16) == 1
    assert pages_for(17, 16) == 2
    assert pages_for(0, 16) == 1   # even an empty request holds one page


def test_alloc_free_roundtrip():
    pool = PagePool(8, 16)
    a = pool.alloc(3, owner=1)
    b = pool.alloc(2, owner=2)
    assert sorted(a + b) == list(range(5))  # low ids first
    assert pool.used_pages == 5 and pool.free_pages == 3
    assert pool.pages_of(1) == a and pool.owners() == [1, 2]
    assert pool.free(1) == 3
    assert pool.used_pages == 2
    # freed pages are reusable
    c = pool.alloc(3, owner=3)
    assert len(c) == 3 and not set(c) & set(b)
    st = pool.stats()
    assert st["allocs"] == 8 and st["frees"] == 3
    assert st["peak_used"] == 5 and st["owners"] == 2


def test_alloc_is_all_or_nothing():
    pool = PagePool(4, 16)
    assert pool.alloc(3, owner=1) is not None
    assert not pool.can_alloc(2)
    assert pool.alloc(2, owner=2) is None       # no partial grant
    assert pool.used_pages == 3                 # nothing leaked
    assert pool.stats()["alloc_failures"] == 1
    assert pool.can_alloc(1) and pool.alloc(1, owner=2) is not None


def test_partial_free_and_double_free_raise():
    pool = PagePool(8, 16)
    got = pool.alloc(4, owner=7)
    assert pool.free(7, got[2:]) == 2           # trim the logical tail
    assert pool.pages_of(7) == got[:2]
    with pytest.raises(KeyError, match="double free"):
        pool.free(7, [got[3]])
    with pytest.raises(KeyError, match="holds no pages"):
        pool.free(99, [0])
    assert pool.free(99) == 0                   # free-all of a non-owner: noop


def test_occupancy():
    pool = PagePool(10, 16)
    assert pool.occupancy() == 0.0
    pool.alloc(5, owner=1)
    assert pool.occupancy() == 0.5


def test_compact_renumbers_onto_low_end():
    pool = PagePool(10, 16)
    a = pool.alloc(3, owner=1)
    b = pool.alloc(3, owner=2)
    pool.free(1)                                # holes at the low end
    src, remap = pool.compact()
    # live pages renumbered to [0, used); ownership order preserved
    assert pool.pages_of(2) == [remap[p] for p in b]
    assert sorted(pool.pages_of(2)) == [0, 1, 2]
    assert pool.free_pages == 7
    # src gathers pool data: new page i holds old page src[i]'s rows
    old = np.arange(10)
    new = old[np.asarray(src)]
    for p_old, p_new in remap.items():
        assert new[p_new] == p_old
    assert sorted(src.tolist()) == list(range(10))  # a permutation
    # pool still allocates correctly after compaction
    c = pool.alloc(7, owner=3)
    assert sorted(pool.pages_of(2) + c) == list(range(10))
    del a


def test_sizing_helpers():
    # packed OVP: D/2 nibble bytes + 4 scale bytes, x2 for K and V, per head
    assert kv_bytes_per_token_per_site(2, 16, 4) == 2 * (8 + 4) * 2
    assert kv_bytes_per_token_per_site(2, 16, 0) == 2 * 16 * 4 * 2
    bpt = kv_bytes_per_token_per_site(2, 16, 4)
    n = pool_pages_for_budget(100 * 16 * bpt, 16, bpt)
    assert n == 100
    assert max_concurrent_requests(n, 16, tokens_per_request=160) == 10
    # paging headline: same HBM, shorter real contexts -> more requests
    assert max_concurrent_requests(n, 16, tokens_per_request=32) == 50
