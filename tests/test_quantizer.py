import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (QuantPolicy, QuantSpec, baselines, dequantize,
                        ovp_search_scale, quantization_error, quantize,
                        quantize_params, quantize_weight, sigma_init_scale)
from repro.core.ovp import QuantizedTensor
from repro.core.qlinear import linear, qmatmul

from test_ovp import heavy_tailed


class TestScaleSearch:
    def test_sigma_init(self):
        x = jax.random.normal(jax.random.PRNGKey(0), (4096,))
        s = sigma_init_scale(x, "int4")
        np.testing.assert_allclose(float(s), 3.0 * float(jnp.std(x)) / 7,
                                   rtol=1e-5)

    def test_mse_search_beats_3sigma_init(self):
        x = heavy_tailed(jax.random.PRNGKey(1), (16384,))
        from repro.core.ovp import ovp_fake_quant
        s0 = sigma_init_scale(x, "int4")
        s = ovp_search_scale(x, "int4")
        mse0 = float(jnp.mean((ovp_fake_quant(x, s0, "int4") - x) ** 2))
        mse = float(jnp.mean((ovp_fake_quant(x, s, "int4") - x) ** 2))
        assert mse <= mse0 * (1 + 1e-6)

    def test_scale_positive(self):
        x = jnp.zeros((128,))
        s = ovp_search_scale(x, "int4")
        assert float(s) > 0


class TestOliveVsBaselines:
    """Tbl. 6/9 direction: OliVe-4bit must beat int4 & ANT on outlier data."""

    @pytest.mark.parametrize("outlier_scale", [15.0, 40.0])
    def test_olive4_beats_int4_on_heavy_tails(self, outlier_scale):
        x = heavy_tailed(jax.random.PRNGKey(2), (32768,),
                         outlier_frac=0.005, outlier_scale=outlier_scale)
        err_olive = quantization_error(x, QuantSpec("int4"))["mse"]
        int4 = baselines.uniform_int_fake_quant(x, 4)
        err_int4 = float(jnp.mean((int4 - x) ** 2))
        assert err_olive < err_int4

    def test_olive4_beats_ant4_on_heavy_tails(self):
        x = heavy_tailed(jax.random.PRNGKey(3), (32768,),
                         outlier_frac=0.005, outlier_scale=30.0)
        err_olive = quantization_error(x, QuantSpec("int4"))["mse"]
        ant = baselines.ant_fake_quant(x)
        err_ant = float(jnp.mean((ant - x) ** 2))
        assert err_olive < err_ant

    def test_olive8_near_lossless(self):
        x = heavy_tailed(jax.random.PRNGKey(4), (32768,),
                         outlier_frac=0.002, outlier_scale=30.0)
        err = quantization_error(x, QuantSpec("int8"))
        # victim pruning floors MSE at ~outlier_frac·σ² (the paper's <0.1%
        # accuracy-cost argument); 28 dB SQNR ≈ that floor at 0.2% victims
        assert err["sqnr_db"] > 28.0

    def test_gobo_bytes_exceed_olive(self):
        x = heavy_tailed(jax.random.PRNGKey(5), (256, 256))
        _, stats = baselines.gobo_fake_quant(x, bits=4)
        q = quantize(x, QuantSpec("int4"))
        assert q.nbytes() < stats["bytes"]  # coordinate-list overhead

    def test_adaptivfloat_roundtrip_sane(self):
        x = jax.random.normal(jax.random.PRNGKey(6), (4096,))
        xh = baselines.adaptivfloat_fake_quant(x, bits=4, ebits=2)
        assert float(jnp.mean((xh - x) ** 2)) < float(jnp.mean(x ** 2))

    def test_clip_outliers_hurts_more_than_prune_victims(self):
        # Fig. 3 ordering, in MSE terms on outlier-heavy data
        x = heavy_tailed(jax.random.PRNGKey(7), (65536,),
                         outlier_frac=0.01, outlier_scale=25.0)
        clip = baselines.clip_outliers(x, 3.0)
        prune = baselines.prune_victims(x, 3.0)
        mse_clip = float(jnp.mean((clip - x) ** 2))
        mse_prune = float(jnp.mean((prune - x) ** 2))
        assert mse_prune < mse_clip


class TestPerChannel:
    def test_per_channel_beats_per_tensor_on_varied_channels(self):
        key = jax.random.PRNGKey(8)
        scales = jnp.geomspace(0.1, 10.0, 16)
        x = jax.random.normal(key, (64, 16)) * scales[None, :]
        e_t = quantization_error(x, QuantSpec("int4", "tensor"))["mse"]
        e_c = quantization_error(
            x, QuantSpec("int4", "channel", channel_axis=-1,
                         pair_axis=0))["mse"]
        assert e_c < e_t

    def test_channel_scale_shape(self):
        x = jax.random.normal(jax.random.PRNGKey(9), (32, 8))
        q = quantize(x, QuantSpec("int4", "channel", channel_axis=-1,
                                  pair_axis=0))
        assert q.scale.shape == (1, 8)
        assert q.data.shape == (16, 8)
        assert dequantize(q).shape == (32, 8)


class TestQLinear:
    def test_fp_path(self):
        x = jax.random.normal(jax.random.PRNGKey(10), (4, 8))
        w = jax.random.normal(jax.random.PRNGKey(11), (8, 6))
        y = qmatmul(x, w, QuantPolicy())
        np.testing.assert_allclose(
            np.asarray(y), np.asarray(x @ w), rtol=2e-2, atol=2e-2)

    def test_quantized_weight_path_close(self):
        x = jax.random.normal(jax.random.PRNGKey(12), (16, 64))
        w = heavy_tailed(jax.random.PRNGKey(13), (64, 32)) * 0.05
        pol = QuantPolicy(method="olive", wbits=4, abits=0,
                          compute_dtype="float32")
        wq = quantize_weight(w, pol)
        assert isinstance(wq, QuantizedTensor)
        y = qmatmul(x, wq, pol)
        ref = x @ w
        rel = float(jnp.linalg.norm(y - ref) / jnp.linalg.norm(ref))
        assert rel < 0.15

    def test_w4a4_path_runs(self):
        x = jax.random.normal(jax.random.PRNGKey(14), (8, 64))
        w = jax.random.normal(jax.random.PRNGKey(15), (64, 32)) * 0.05
        pol = QuantPolicy(method="olive", wbits=4, abits=4,
                          compute_dtype="float32")
        wq = quantize_weight(w, pol)
        y = qmatmul(x, wq, pol)
        ref = x @ w
        rel = float(jnp.linalg.norm(y - ref) / jnp.linalg.norm(ref))
        assert rel < 0.3

    def test_qat_ste_has_gradients(self):
        pol = QuantPolicy(method="olive", wbits=4, abits=4, qat=True,
                          compute_dtype="float32")
        w = jax.random.normal(jax.random.PRNGKey(16), (16, 8)) * 0.1

        def loss(w, x):
            return jnp.sum(qmatmul(x, w, pol) ** 2)

        x = jax.random.normal(jax.random.PRNGKey(17), (4, 16))
        g = jax.grad(loss)(w, x)
        assert float(jnp.max(jnp.abs(g))) > 0
        assert not bool(jnp.any(jnp.isnan(g)))

    def test_bias(self):
        x = jnp.ones((2, 4))
        w = jnp.eye(4)
        b = jnp.arange(4.0)
        y = linear(x, w, b, QuantPolicy(compute_dtype="float32"))
        np.testing.assert_allclose(np.asarray(y[0]), [1, 2, 3, 4])


class TestQuantizeParams:
    def test_tree_quantization_selects_linears(self):
        params = {
            "embed": {"table": jax.random.normal(jax.random.PRNGKey(0),
                                                 (128, 64))},
            "layer": {
                "attn": {"wq": jax.random.normal(jax.random.PRNGKey(1),
                                                 (64, 64))},
                "mlp": {"wi": jax.random.normal(jax.random.PRNGKey(2),
                                                (64, 128)),
                        "bias": jnp.zeros((128,))},
                "norm": {"w_scale_vec": jnp.ones((64,))},
            },
        }
        pol = QuantPolicy(method="olive", wbits=4)
        q = quantize_params(params, pol)
        assert isinstance(q["layer"]["attn"]["wq"], QuantizedTensor)
        assert isinstance(q["layer"]["mlp"]["wi"], QuantizedTensor)
        assert not isinstance(q["embed"]["table"], QuantizedTensor)
        assert not isinstance(q["layer"]["mlp"]["bias"], QuantizedTensor)
        assert not isinstance(q["layer"]["norm"]["w_scale_vec"],
                              QuantizedTensor)
