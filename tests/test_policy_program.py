"""Policy-program resolution: legacy-flag compatibility against the seed
heuristics, rule precedence, mixed W4/W8 trees through `quantize_params` +
`backends.dispatch`, and the mixed-precision end-to-end serving path."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import backends
from repro.configs.base import ArchConfig
from repro.core.calibration import auto_mixed, record_weights, \
    site_sensitivity
from repro.core.ovp import QuantizedTensor
from repro.core.policy import (PolicyProgram, QuantPolicy, Rule,
                               get_program, parse_rules)
from repro.core.qlinear import quantize_params, tree_paths
from repro.models.model import build_model, unroll_params

TINY = ArchConfig(name="pp-tiny", family="dense", n_layers=4, d_model=64,
                  n_heads=4, n_kv_heads=2, d_ff=128, vocab=256,
                  head_dim=16, block_pattern=("attn",))

W4 = QuantPolicy(method="olive", wbits=4, abits=0, compute_dtype="float32")
W8 = QuantPolicy(method="olive", wbits=8, abits=0, w_normal_dtype="int8",
                 compute_dtype="float32")


def seed_eligible(path: str, policy: QuantPolicy) -> bool:
    """The seed repo's string heuristic, verbatim — the compatibility
    oracle `PolicyProgram.from_policy` must reproduce."""
    p = path.lower()
    if "embed" in p or "lm_head" in p:
        return policy.quantize_embed
    if "router" in p or "gate_router" in p:
        return policy.quantize_router
    if any(k in p for k in ("attn", "attention", "wq", "wk", "wv", "wo")):
        return policy.quantize_attn
    if any(k in p for k in ("mlp", "ffn", "expert", "wi", "wu", "wg", "wd")):
        return policy.quantize_ffn
    return policy.quantize_ffn  # default bucket


def quantized_paths(tree):
    return {path for path, leaf in tree_paths(tree)
            if isinstance(leaf, QuantizedTensor)}


@pytest.fixture(scope="module")
def tiny_params():
    model = build_model(TINY, QuantPolicy(compute_dtype="float32"),
                        remat=False)
    return model.init(jax.random.PRNGKey(0))


MOE_TINY = ArchConfig(name="pp-moe", family="moe", n_layers=2, d_model=64,
                      n_heads=4, n_kv_heads=2, d_ff=128, vocab=256,
                      head_dim=16, n_experts=4, top_k=2,
                      block_pattern=("moe",))


@pytest.mark.parametrize("flags", [
    dict(),
    dict(quantize_attn=False),
    dict(quantize_ffn=False),
    dict(quantize_embed=True),
    dict(quantize_attn=False, quantize_ffn=False, quantize_embed=True),
    dict(quantize_router=True),
])
@pytest.mark.parametrize("arch", [TINY, MOE_TINY])
def test_flag_compat_matches_seed_heuristics(flags, arch):
    """Flags compiled to rules make the same quantize_params decisions as
    the seed string heuristics, on a real param tree."""
    model = build_model(arch, QuantPolicy(compute_dtype="float32"),
                        remat=False)
    params = model.init(jax.random.PRNGKey(0))
    policy = dataclasses.replace(W4, **flags)
    min_size = 1024

    from repro.core.qlinear import is_linear_weight
    expect = {path for path, w in tree_paths(params)
              if hasattr(w, "ndim") and w.ndim >= 2
              and w.size >= min_size and w.shape[-2] % 2 == 0
              and seed_eligible(path, policy)
              and is_linear_weight(path, w)}

    got_flags = quantized_paths(quantize_params(params, policy,
                                                min_size=min_size))
    got_prog = quantized_paths(quantize_params(
        params, PolicyProgram.from_policy(policy), min_size=min_size))
    assert got_flags == expect
    assert got_prog == expect


def test_rule_precedence_first_match_wins():
    prog = PolicyProgram(rules=[
        Rule("layers/0/*", W8),
        Rule("layers/*", W4),
        Rule("layers/0/*", W4.off()),   # shadowed by the first rule
    ], default=W4.off())
    assert prog.resolve("layers/0/attn/wq").wbits == 8
    assert prog.resolve("layers/2/attn/wq").wbits == 4
    assert not prog.resolve("embed/table").enabled
    # matching is case-insensitive, * crosses separators
    assert prog.resolve("LAYERS/0/mlp/wg").wbits == 8


def test_with_rules_prepends_and_takes_precedence():
    base = PolicyProgram.from_policy(W4)
    prog = base.with_rules([("*attn/wq*", W8)])
    assert prog.resolve("layers/1/attn/wq").wbits == 8
    assert prog.resolve("layers/1/attn/wk").wbits == 4


def test_layer_uniform_layers_rule_forces_unroll(tiny_params):
    """A rule in the `layers/` grammar must unroll the model even when it
    resolves identically for every layer (a scan keeps `blocks/<j>`
    addresses, where the rule would silently never match)."""
    prog = PolicyProgram.from_policy(W4).with_rules(
        [("layers/*/attn/wq", W8)])
    assert not prog.varies_across_layers(TINY.n_layers)
    assert prog.addresses_layers(TINY.n_layers)
    model = build_model(TINY, prog, remat=False)
    assert model.unrolled
    qp = quantize_params(model.adapt_params(tiny_params), prog,
                         min_size=1024)
    dtypes = {path: leaf.normal_dtype for path, leaf in tree_paths(qp)
              if isinstance(leaf, QuantizedTensor)}
    assert dtypes["layers/2/attn/wq"] == "int8"   # the rule applied
    assert dtypes["layers/2/attn/wk"] == "int4"
    # probe-blind per-layer rules (sites outside _LAYER_PROBES) unroll too
    prog2 = PolicyProgram.from_policy(W4).with_rules(
        [("layers/2/mlstm/w_down", W8)])
    assert prog2.addresses_layers(4)


def test_parse_rules_and_presets():
    rules = parse_rules("layers/0/*=olive_w8a8, *mlp*=fp")
    assert rules[0].pattern == "layers/0/*"
    assert rules[0].policy.wbits == 8
    assert not rules[1].policy.enabled
    with pytest.raises(ValueError):
        parse_rules("no-equals-sign")
    prog = get_program("olive_mixed_w48", n_layers=6)
    assert prog.resolve("layers/0/attn/wq").wbits == 8
    assert prog.resolve("layers/5/attn/wq").wbits == 8
    assert prog.resolve("layers/3/attn/wq").wbits == 4
    assert not prog.resolve("embed/table").enabled


def test_mixed_tree_roundtrip_quantize_and_dispatch(tiny_params):
    """A layer-varying program quantizes one tree to mixed W4/W8 leaves,
    and each leaf dispatches on its site's backend."""
    prog = PolicyProgram.from_policy(W4).with_rules([
        ("layers/0/*", W8), ("layers/3/*", W8)])
    assert prog.varies_across_layers(TINY.n_layers)
    params = unroll_params(TINY, tiny_params)
    qp = quantize_params(params, prog, min_size=1024)

    dtypes = {path: leaf.normal_dtype for path, leaf in tree_paths(qp)
              if isinstance(leaf, QuantizedTensor)}
    assert dtypes["layers/0/attn/wq"] == "int8"
    assert dtypes["layers/3/mlp/wd"] == "int8"
    assert dtypes["layers/1/attn/wq"] == "int4"
    assert dtypes["layers/2/mlp/wg"] == "int4"

    # dispatch each leaf under its own resolved policy, against reference
    x = jax.random.normal(jax.random.PRNGKey(2), (3, 64))
    for path in ("layers/0/attn/wq", "layers/1/attn/wq"):
        w = dict(tree_paths(qp))[path]
        pol = prog.resolve(path)
        y = backends.dispatch(x, w, pol)
        y_ref = backends.dispatch(
            x, w, dataclasses.replace(pol, backend="reference"))
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                                   rtol=1e-4, atol=1e-4)


def test_mixed_program_end_to_end_serving(tiny_params):
    """Acceptance: a ≤10-line mixed program (first/last W8, middle W4,
    per-layer kv_bits) runs quantize_params -> ServingEngine on the
    pallas_interpret backend."""
    from repro.serve.engine import EngineCfg, ServingEngine
    w8kv = dataclasses.replace(W8, kv_bits=4)
    prog = PolicyProgram.from_policy(W4).with_rules([
        ("layers/0/*", w8kv),
        (f"layers/{TINY.n_layers - 1}/*", w8kv),
    ])

    model = build_model(TINY, prog, remat=False)
    assert model.unrolled
    qp = quantize_params(model.adapt_params(tiny_params), prog,
                         min_size=1024)
    caches = model.init_caches(2, 32, dtype=jnp.float32)
    # per-layer kv_bits: first/last layers OVP-packed, middle fp
    assert "k_data" in caches["layers"][0]["kv"]
    assert "k" in caches["layers"][1]["kv"]
    assert "k_data" in caches["layers"][3]["kv"]

    eng = ServingEngine(model, qp,
                        EngineCfg(batch_slots=2, max_len=48,
                                  backend="pallas_interpret"))
    assert eng.model.policy.backends() == frozenset(("pallas_interpret",))
    rng = np.random.default_rng(0)
    for n in (5, 9):
        eng.submit(rng.integers(0, TINY.vocab, size=n).astype(np.int32),
                   max_new_tokens=4)
    done = eng.run_until_drained()
    assert len(done) == 2
    assert all(len(r.out_tokens) == 4 for r in done)


def test_legacy_quantpolicy_call_sites_unchanged(tiny_params):
    """Old flat-policy call sites keep working bit-for-bit: resolve() on
    a QuantPolicy reproduces the flag decisions."""
    pol = QuantPolicy(method="olive", wbits=4, abits=0,
                      compute_dtype="float32", quantize_ffn=False)
    assert pol.resolve("blocks/0/attn/wq") == pol
    assert not pol.resolve("blocks/0/mlp/wg").enabled
    assert not pol.resolve("embed/table").enabled
    # disabled sites keep execution config (dtype/backend)
    off = pol.resolve("blocks/0/mlp/wg")
    assert off.compute_dtype == pol.compute_dtype
    assert off.backend == pol.backend


def test_auto_mixed_respects_budget(tiny_params):
    tape = record_weights(tiny_params, min_size=1024)
    sens = site_sensitivity(tape, "int4", n_grid=8)
    assert sens  # found sites
    prog = auto_mixed(sens, budget_bits=5.0, low=W4, high=W8)
    high_sites = [r.pattern for r in prog.rules
                  if r.policy.wbits == 8]
    # only sites the low program quantizes are promotable: the head
    # (fp under default flags) must never be force-quantized even if
    # it ranks most sensitive
    base = PolicyProgram.from_policy(W4)
    eligible = {k: v for k, v in sens.items() if base.resolve(k).enabled}
    assert "lm_head/w_out" in sens and "lm_head/w_out" not in eligible
    assert "lm_head/w_out" not in high_sites
    # 5-bit budget over {4,8} bits -> at most 25% of eligible sites at W8
    assert 0 < len(high_sites) <= max(1, len(eligible) // 4)
    # the W8 sites are the lowest-SQNR eligible ones
    ranked = sorted(eligible, key=lambda k: eligible[k])
    assert set(high_sites) == set(ranked[:len(high_sites)])
    # budget at the floor -> no high-precision sites
    lo = auto_mixed(sens, budget_bits=4.0, low=W4, high=W8)
    assert all(r.policy.wbits != 8 for r in lo.rules)
