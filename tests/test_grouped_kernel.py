"""Grouped (per-expert) kernel equivalence + dispatch suite.

The batched-weight OVP Pallas kernel must serve stacked `(E, K, N)` expert
weights — one pallas_call with an expert grid dim — and agree with the XLA
broadcast path it replaced, across every normal dtype, per-expert mixed
W4/W8 policy programs, activation modes, and decode-step lhs layouts.
Declined layouts must carry machine-readable reasons and fall back cleanly.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import backends
from repro.core.ovp import (MixedExpertQuant, QuantizedTensor,
                            ovp_dequantize)
from repro.core.policy import (OLIVE_W4A4, OLIVE_W8A8, PolicyProgram,
                               QuantPolicy, Rule)
from repro.core.qlinear import qmatmul, quantize_params, quantize_weight

from test_ovp import heavy_tailed

E, CAP, K, F = 4, 8, 64, 48

W_KINDS = {
    "int4": dict(wbits=4, w_normal_dtype="int4"),
    "flint4": dict(wbits=4, w_normal_dtype="flint4"),
    "int8": dict(wbits=8, w_normal_dtype="int8"),
}


def make_policy(kind: str, granularity: str = "channel",
                backend: str = "pallas_interpret", **kw) -> QuantPolicy:
    return QuantPolicy(method="olive", compute_dtype="float32",
                       w_granularity=granularity, backend=backend,
                       **{**W_KINDS[kind], **kw})


def rel_err(got, want):
    got, want = np.asarray(got, np.float64), np.asarray(want, np.float64)
    return float(np.max(np.abs(got - want)) / (np.max(np.abs(want)) + 1e-9))


@pytest.fixture(scope="module")
def operands():
    key = jax.random.PRNGKey(21)
    kx, kb, kw = jax.random.split(key, 3)
    xg3 = heavy_tailed(kx, (E, CAP, K), outlier_frac=0.01, outlier_scale=9.0)
    xg4 = heavy_tailed(kb, (2, E, CAP, K), outlier_frac=0.01,
                       outlier_scale=9.0)
    ws = heavy_tailed(kw, (E, K, F), outlier_frac=0.01, outlier_scale=9.0)
    return xg3, xg4, ws


class TestGroupedEquivalence:
    @pytest.mark.parametrize("granularity", ["tensor", "channel"])
    @pytest.mark.parametrize("kind", sorted(W_KINDS))
    def test_matches_xla_broadcast(self, kind, granularity, operands):
        """Stacked-weight dispatch on the grouped kernel matches the XLA
        broadcast path for every normal dtype and scale granularity."""
        xg3, _, ws = operands
        pol = make_policy(kind, granularity)
        wq = quantize_weight(ws, pol)
        assert wq.data.ndim == 3
        got = backends.dispatch(xg3, wq, pol)
        want = backends.dispatch(
            xg3, wq, dataclasses.replace(pol, backend="xla"))
        assert got.shape == (E, CAP, F)
        assert rel_err(got, want) < 1e-5, (kind, granularity)

    @pytest.mark.parametrize("kind", sorted(W_KINDS))
    def test_batched_4d_lhs(self, kind, operands):
        """(B, E, C, K) MoE dispatch tensors fold into the batch grid dim."""
        _, xg4, ws = operands
        pol = make_policy(kind)
        wq = quantize_weight(ws, pol)
        got = backends.dispatch(xg4, wq, pol)
        want = backends.dispatch(
            xg4, wq, dataclasses.replace(pol, backend="xla"))
        assert got.shape == (2, E, CAP, F)
        assert rel_err(got, want) < 1e-5, kind

    def test_decode_step_3d_lhs(self, operands):
        """(E, 1, K) decode-step slots (capacity 1) hit the grouped kernel
        without reshape glue."""
        _, _, ws = operands
        x = jax.random.normal(jax.random.PRNGKey(5), (E, 1, K))
        pol = make_policy("int4")
        wq = quantize_weight(ws, pol)
        got = backends.dispatch(x, wq, pol)
        want = backends.dispatch(
            x, wq, dataclasses.replace(pol, backend="xla"))
        assert got.shape == (E, 1, F)
        assert rel_err(got, want) < 1e-5

    @pytest.mark.parametrize("kind,abits,a_dtype", [
        ("int4", 4, "int4"),        # W4A4: fused act-OVP prologue
        ("int4", 8, "int4"),        # W4A8 mixed: int8 OVP activations
        ("int8", 8, "int8"),        # W8A8: one-code-per-byte both sides
    ])
    def test_activation_modes(self, kind, abits, a_dtype, operands):
        """The grouped kernel supports the same activation modes as the
        2-D kernel: in-kernel act quantization at the shared scale rule
        agrees with the XLA encode->decode path."""
        xg3, _, ws = operands
        pol = make_policy(kind, abits=abits, a_normal_dtype=a_dtype)
        wq = quantize_weight(ws, pol)
        got = backends.dispatch(xg3, wq, pol)
        want = backends.dispatch(
            xg3, wq, dataclasses.replace(pol, backend="xla"))
        assert rel_err(got, want) < 1e-5, (kind, abits)


class TestGroupedDispatch:
    def test_single_pallas_call_and_no_fallback(self, operands):
        """Acceptance: one pallas_call serves the whole expert stack, and
        the dispatch ledger shows zero stacked fallbacks."""
        xg3, _, ws = operands
        pol = make_policy("int4")
        wq = quantize_weight(ws, pol)
        backends.reset_dispatch_stats()
        n = backends.count_pallas_calls(
            lambda x: backends.dispatch(x, wq, pol), xg3)
        assert n == 1
        stats = backends.dispatch_stats()
        assert stats.get("pallas_interpret[stacked]") == 1
        assert not any("->fallback:" in tag for tag in stats)

    def test_decline_reasons_are_machine_readable(self, operands):
        """Layouts the kernel cannot run decline with stable reason codes
        (consumed by kernels_bench), and dispatch still falls back."""
        xg3, _, ws = operands
        pol = make_policy("int4")
        wq = quantize_weight(ws, pol)
        pallas = backends.get_backend("pallas_interpret")
        assert pallas.decline_reason(xg3, wq, pol) is None
        # rank-4 weight stack
        wq4 = dataclasses.replace(wq, data=wq.data[None])
        assert pallas.decline_reason(xg3[None], wq4, pol) \
            == "stacked_rank_gt_3"
        # lhs without the expert dim
        assert pallas.decline_reason(xg3[0, 0], wq, pol) \
            == "grouped_lhs_rank_lt_3"
        # lhs whose expert dim disagrees with the stack
        assert pallas.decline_reason(xg3[:2], wq, pol) \
            == "grouped_lhs_expert_mismatch"
        # ...and a declined layout XLA can still broadcast (the rank-4
        # stack) degrades to the fallback, recording why
        sc4 = jnp.asarray(wq.scale)[None]
        wq4 = dataclasses.replace(wq, data=wq.data[None], scale=sc4)
        backends.reset_dispatch_stats()
        got = backends.dispatch(xg3[None], wq4, pol)
        want = jnp.einsum("leck,lekf->lecf", xg3[None],
                          ovp_dequantize(wq4))
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)
        stats = backends.dispatch_stats()
        key = "pallas_interpret->fallback:stacked_rank_gt_3[stacked]"
        assert stats.get(key) == 1

    def test_moe_layer_runs_grouped(self):
        """End-to-end: a quantized MoE layer's three expert einsums each
        dispatch one grouped pallas_call and match the XLA backend."""
        from repro.models.layers import moe_layer, moe_params

        class Cfg:
            n_experts, top_k, norm_topk, capacity_factor = E, 2, False, 1.5

        key = jax.random.PRNGKey(3)
        p = moe_params(key, 64, 128, E)
        x = jax.random.normal(jax.random.split(key)[0], (2, 16, 64))
        pol = make_policy("int4")
        qp = quantize_params(p, pol)
        assert isinstance(qp["experts"]["wg"], QuantizedTensor)
        assert qp["experts"]["wg"].data.ndim == 3
        backends.reset_dispatch_stats()
        n = backends.count_pallas_calls(
            lambda xx: moe_layer(qp, xx, Cfg, pol)[0], x)
        assert n == 3  # wg, wu, wd — all grouped, zero fallbacks
        assert not any("->fallback:" in tag
                       for tag in backends.dispatch_stats())
        got, _ = moe_layer(qp, x, Cfg, pol)
        want, _ = moe_layer(qp, x, Cfg, pol.with_backend("xla"))
        assert rel_err(got, want) < 1e-5


class TestMixedExpertPrograms:
    def _mixed_program(self, w8_expert: int, fp_expert: int = -1):
        base = dataclasses.replace(OLIVE_W4A4, abits=0,
                                   compute_dtype="float32",
                                   backend="pallas_interpret")
        w8 = dataclasses.replace(OLIVE_W8A8, abits=0,
                                 compute_dtype="float32",
                                 backend="pallas_interpret")
        rules = [Rule(f"experts/*/{w8_expert}", w8)]
        if fp_expert >= 0:
            rules.append(Rule(f"experts/*/{fp_expert}",
                              QuantPolicy(method="none",
                                          compute_dtype="float32")))
        return PolicyProgram(rules=tuple(rules), default=base)

    def test_mixed_w4_w8_groups(self, operands):
        """A program addressing individual experts quantizes the stack
        group-wise: W8 experts and W4 experts in separate homogeneous
        stacked QuantizedTensors, partitioned exactly."""
        _, _, ws = operands
        prog = self._mixed_program(w8_expert=1)
        qp = quantize_params({"experts": {"wg": ws}}, prog)
        wmix = qp["experts"]["wg"]
        assert isinstance(wmix, MixedExpertQuant)
        assert wmix.n_experts == E
        by_dtype = {g.normal_dtype: ids
                    for g, ids in zip(wmix.groups, wmix.expert_ids)}
        assert by_dtype["int8"] == (1,)
        assert by_dtype["int4"] == (0, 2, 3)

    def test_mixed_dispatch_matches_manual_reference(self, operands):
        """Group-wise dispatch stitches outputs back into expert order and
        matches a per-expert dequantized einsum."""
        xg3, _, ws = operands
        prog = self._mixed_program(w8_expert=1, fp_expert=2)
        qp = quantize_params({"experts": {"wg": ws}}, prog)
        wmix = qp["experts"]["wg"]
        pol = dataclasses.replace(OLIVE_W4A4, abits=0,
                                  compute_dtype="float32",
                                  backend="pallas_interpret")
        got = qmatmul(xg3, wmix, pol)
        full = np.zeros((E, K, F), np.float32)
        for g, ids in zip(wmix.groups, wmix.expert_ids):
            d = ovp_dequantize(g) if isinstance(g, QuantizedTensor) else g
            full[np.asarray(ids)] = np.asarray(d)
        want = jnp.einsum("eck,ekf->ecf", xg3, jnp.asarray(full))
        assert rel_err(got, want) < 1e-5
        # the xla backend agrees through the same group-wise path
        want_xla = qmatmul(xg3, wmix,
                           dataclasses.replace(pol, backend="xla"))
        assert rel_err(got, want_xla) < 1e-5

    def test_mixed_dispatch_with_per_slot_act_scale(self, operands):
        """Per-slot static activation scales (…, E, C, 1) gather down to
        each group's expert subset instead of crashing mid-trace."""
        xg3, _, ws = operands
        prog = self._mixed_program(w8_expert=1)
        qp = quantize_params({"experts": {"wg": ws}}, prog)
        wmix = qp["experts"]["wg"]
        pol = dataclasses.replace(OLIVE_W4A4, abits=4,
                                  act_scale_mode="static",
                                  compute_dtype="float32",
                                  backend="pallas_interpret")
        scale = jnp.full((E, CAP, 1), 0.1, jnp.float32)
        got = backends.dispatch(xg3, wmix, pol, act_scale=scale)
        want = backends.dispatch(
            xg3, wmix, dataclasses.replace(pol, backend="xla"),
            act_scale=scale)
        assert got.shape == (E, CAP, F)
        assert rel_err(got, want) < 1e-5

    def test_uniform_program_keeps_single_stack(self, operands):
        """A program that does NOT distinguish experts keeps the stacked
        weight one homogeneous QuantizedTensor (bit-compat with the seed)."""
        _, _, ws = operands
        pol = make_policy("int4")
        qp = quantize_params({"experts": {"wg": ws}}, pol)
        assert isinstance(qp["experts"]["wg"], QuantizedTensor)

    def test_mixed_in_moe_layer(self):
        """moe_layer end-to-end with a per-expert mixed program."""
        from repro.models.layers import moe_layer, moe_params

        class Cfg:
            n_experts, top_k, norm_topk, capacity_factor = E, 2, False, 1.5

        key = jax.random.PRNGKey(9)
        p = moe_params(key, 64, 128, E)
        x = jax.random.normal(jax.random.split(key)[0], (2, 16, 64))
        prog = self._mixed_program(w8_expert=0)
        qp = quantize_params(p, prog)
        assert isinstance(qp["experts"]["wg"], MixedExpertQuant)
        got, _ = moe_layer(qp, x, Cfg, prog)
        want, _ = moe_layer(qp, x, Cfg, prog.with_backend("xla"))
        assert rel_err(got, want) < 1e-5


class TestStackedScaleLayouts:
    def test_tensor_granularity_stacked_scales(self, operands):
        """Regression: stacked weights at tensor granularity used to get
        (E,) scales that could not broadcast against (E, K, N) — dequant
        (and therefore the XLA fallback itself) crashed."""
        _, _, ws = operands
        pol = make_policy("int4", granularity="tensor")
        wq = quantize_weight(ws, pol)
        assert jnp.asarray(wq.scale).shape == (E, 1, 1)
        deq = ovp_dequantize(wq)
        assert deq.shape == (E, K, F)
