"""Per-kernel shape/dtype sweeps vs the pure-jnp oracle (interpret mode).

Covers non-block-multiple shapes (padding path), both packed dtypes, both
kernels, and a block-size sweep for the encoder kernel.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.ovp import ovp_quantize
from repro.kernels import ops, ref

SHAPES = [
    (8, 16, 8),        # tiny
    (32, 64, 16),
    (128, 256, 128),   # one full block
    (96, 130, 40),     # K not a block multiple (but even)
    (200, 512, 300),   # M/N not block multiples
    (16, 1024, 8),     # deep K
]
DTYPES = ["int4", "flint4"]


def _mk(key, m, k, n):
    ka, kw = jax.random.split(key)
    a = jax.random.normal(ka, (m, k)) * 2.0
    w = jax.random.normal(kw, (k, n)) * 2.0
    # sprinkle outliers so abfloat paths are exercised
    a = a.at[0, :: max(k // 7, 1)].set(37.0)
    w = w.at[:: max(k // 5, 1), 0].set(-29.0)
    return a, w


@pytest.mark.parametrize("m,k,n", SHAPES)
@pytest.mark.parametrize("dt", DTYPES)
def test_w4a16_sweep(m, k, n, dt):
    a, w = _mk(jax.random.PRNGKey(m * 7 + n), m, k, n)
    wq = ovp_quantize(w, 0.9, dt, pair_axis=0)
    got = ops.matmul_w4a16(a, wq.data, jnp.asarray(wq.scale),
                           normal_dtype=dt, interpret=True)
    want = ref.ovp_matmul_w4a16_ref(a, wq.data, dt) * wq.scale
    # kernel splits K into even/odd half-reductions + tiles: float
    # reassociation differs from the single-dot oracle
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=5e-4, atol=5e-4)


@pytest.mark.parametrize("m,k,n", SHAPES)
@pytest.mark.parametrize("dt", DTYPES)
def test_w4a4_sweep(m, k, n, dt):
    a, w = _mk(jax.random.PRNGKey(m + n * 3), m, k, n)
    aq = ovp_quantize(a, 1.1, dt, pair_axis=1)
    wq = ovp_quantize(w, 0.9, dt, pair_axis=0)
    got = ops.matmul_w4a4(aq.data, jnp.asarray(aq.scale), wq.data,
                          jnp.asarray(wq.scale), normal_dtype=dt,
                          interpret=True)
    want = (ref.ovp_matmul_w4a4_ref(aq.data, wq.data, dt)
            * aq.scale * wq.scale)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize("bm,bk", [(64, 128), (128, 256), (256, 512)])
def test_encode_kernel_block_sweep(bm, bk):
    x = jax.random.normal(jax.random.PRNGKey(0), (256, 512)) * 3.0
    x = x.at[5, 7].set(99.0)
    got = ops.ovp_encode(x, 1.0, "int4", interpret=True, bm=bm, bk=bk)
    want = ref.ovp_encode_ref(x, "int4")
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("dt", DTYPES)
def test_batched_dispatch_matches_2d(dt):
    """ops.ovp_matmul flattens leading dims; result must match per-slice."""
    key = jax.random.PRNGKey(3)
    a = jax.random.normal(key, (2, 3, 32))
    w = jax.random.normal(jax.random.PRNGKey(4), (32, 24))
    wq = ovp_quantize(w, 0.8, dt, pair_axis=0)
    got = ops.ovp_matmul(a, wq, interpret=True)
    assert got.shape == (2, 3, 24)
    for i in range(2):
        for j in range(3):
            want = ops.matmul_w4a16(a[i, j][None], wq.data,
                                    jnp.asarray(wq.scale),
                                    normal_dtype=dt, interpret=True)[0]
            np.testing.assert_allclose(np.asarray(got[i, j]),
                                       np.asarray(want), rtol=1e-5,
                                       atol=1e-4)


def test_dot_general_precision_fp32_accumulate():
    """Accumulation happens in fp32 even for bf16-ish magnitudes."""
    k = 2048
    a = jnp.ones((8, k)) * 0.1
    w = jnp.ones((k, 8)) * 0.07
    wq = ovp_quantize(w, 0.01, "int4", pair_axis=0)
    got = ops.matmul_w4a16(a, wq.data, jnp.asarray(wq.scale),
                           interpret=True)
    want = ref.ovp_matmul_w4a16_ref(a, wq.data) * wq.scale
    # bf16 accumulation would be off by ~1e-2 here; fp32 reassociation
    # stays under 1e-5
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5)
