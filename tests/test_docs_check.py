"""Docs hygiene gate (CI's `docs-check` job).

Greps the maintained markdown set — the root README, `docs/`, and
in-tree `README.md`s under `src/` — and fails on:

- intra-repo markdown links whose target file does not exist;
- `#anchor` fragments that match no heading in the target file
  (GitHub's slug rules: lowercase, punctuation stripped, spaces to
  hyphens — so renaming a heading breaks the build, not the reader);
- backtick code spans that look like repo file paths (optionally with a
  `::symbol` suffix) but point at nothing — paths resolve against the
  doc's own directory, the repo root, `src/`, and `src/repro/`;
- `--flag` tokens that no argparse definition in `src/repro/launch/`,
  `src/repro/analysis/`, or `benchmarks/` declares (docs describing
  nonexistent CLI flags).

Pure stdlib + grep-style regexes: no markdown parser dependency.
"""
from __future__ import annotations

import re
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parent.parent

DOC_FILES = sorted(
    [REPO / "README.md"]
    + list((REPO / "docs").glob("*.md"))
    + list((REPO / "src").rglob("README.md"))
)

# resolution roots for backtick path references, in order
PATH_ROOTS = [REPO, REPO / "src", REPO / "src" / "repro"]

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
HEADING_RE = re.compile(r"^#{1,6}\s+(.*)$", re.MULTILINE)
CODE_PATH_RE = re.compile(
    r"`([A-Za-z0-9_][A-Za-z0-9_./]*\.(?:py|md|json|jsonl))"
    r"(?:::([A-Za-z_][A-Za-z0-9_.]*))?`")
FLAG_RE = re.compile(r"(?<![\w-])(--[a-z][a-z0-9_-]+)")
ARGPARSE_FLAG_RE = re.compile(r"add_argument\(\s*[\"'](--[a-z0-9-]+)[\"']")
# underscore-style --xla_* tokens are XLA runtime flags (passed via the
# XLA_FLAGS env var, e.g. the forced host-device count in
# docs/sharding.md), not repo argparse flags — out of scope for this gate
EXTERNAL_FLAG_PREFIXES = ("--xla_",)


def github_slug(heading: str) -> str:
    """GitHub's heading -> anchor slug: strip markup, lowercase, drop
    punctuation (keeping word chars, spaces, hyphens), spaces->hyphens."""
    h = heading.strip().lower()
    h = h.replace("`", "")                       # inline code markup
    h = re.sub(r"[^\w\- ]", "", h)
    return h.replace(" ", "-")


def anchors_of(md_path: Path) -> set:
    text = md_path.read_text()
    return {github_slug(m.group(1)) for m in HEADING_RE.finditer(text)}


def _strip_code_fences(text: str) -> str:
    """Links/paths inside fenced code blocks are examples, not promises
    (e.g. `/tmp/...` output paths); check prose only — EXCEPT flags,
    which are checked fences-in (see test_cli_flags_exist)."""
    return re.sub(r"```.*?```", "", text, flags=re.DOTALL)


def test_doc_set_is_nonempty():
    assert len(DOC_FILES) >= 6, DOC_FILES


@pytest.mark.parametrize("md", DOC_FILES, ids=lambda p: str(p.relative_to(REPO)))
def test_intra_repo_links_resolve(md):
    bad = []
    for target in LINK_RE.findall(_strip_code_fences(md.read_text())):
        if target.startswith(("http://", "https://", "mailto:")):
            continue
        path_part, _, anchor = target.partition("#")
        if path_part:
            dest = (md.parent / path_part).resolve()
            if not dest.exists():
                bad.append(f"{target}: file {path_part} not found")
                continue
        else:
            dest = md
        if anchor:
            if dest.suffix != ".md":
                continue
            if anchor not in anchors_of(dest):
                bad.append(f"{target}: no heading slugs to '{anchor}' "
                           f"in {dest.name}")
    assert not bad, "\n".join(bad)


@pytest.mark.parametrize("md", DOC_FILES, ids=lambda p: str(p.relative_to(REPO)))
def test_backtick_paths_exist(md):
    bad = []
    for m in CODE_PATH_RE.finditer(_strip_code_fences(md.read_text())):
        ref, symbol = m.group(1), m.group(2)
        roots = [md.parent] + PATH_ROOTS
        hits = [r / ref for r in roots if (r / ref).exists()]
        if not hits:
            bad.append(f"`{ref}`: not found relative to {md.parent.name}/,"
                       f" repo root, src/, or src/repro/")
            continue
        if symbol and symbol not in hits[0].read_text():
            bad.append(f"`{ref}::{symbol}`: symbol not in {hits[0].name}")
    assert not bad, "\n".join(bad)


def _declared_cli_flags() -> set:
    flags = set()
    for src_dir in [REPO / "src" / "repro" / "launch",
                    REPO / "src" / "repro" / "analysis",
                    REPO / "benchmarks"]:
        for py in src_dir.glob("*.py"):
            flags.update(ARGPARSE_FLAG_RE.findall(py.read_text()))
    return flags


@pytest.mark.parametrize("md", DOC_FILES, ids=lambda p: str(p.relative_to(REPO)))
def test_cli_flags_exist(md):
    """Every --flag a doc mentions must be declared by some argparse in
    launch/, analysis/, or benchmarks/ — docs referencing removed or
    misspelled flags fail here (checked inside code fences too: that's
    where the copy-paste commands live)."""
    declared = _declared_cli_flags()
    bad = [f for f in FLAG_RE.findall(md.read_text())
           if f not in declared
           and not f.startswith(EXTERNAL_FLAG_PREFIXES)]
    assert not bad, (f"{sorted(set(bad))} not declared by any argparse in "
                     f"src/repro/launch/, src/repro/analysis/, or "
                     f"benchmarks/")


def test_launch_serve_flags_documented():
    """The reverse direction for the serving CLI: every serve.py flag
    appears somewhere in the maintained docs (the handbook's CLI section
    or the README quickstart)."""
    serve_src = (REPO / "src" / "repro" / "launch" / "serve.py").read_text()
    corpus = "\n".join(p.read_text() for p in DOC_FILES)
    missing = [f for f in ARGPARSE_FLAG_RE.findall(serve_src)
               if f not in corpus]
    assert not missing, missing
