"""Fused cache-write prefill suite (kernels/prefill_attn.py).

One pallas_call must both causally attend the chunk over the raw stage
and OVP-quantize every stage tile onto its block-table pages: parity
against the dense twin (page nibbles bit-identical, scales to 1 ULP,
attention to reassociation tolerance), chunked == one-shot prefill,
untouched pages preserved through the input/output alias, and the
decline vocabulary through the registry."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np
import pytest

from repro import backends
from repro.kernels.prefill_attn import (fused_prefill_attention,
                                        prefill_decline_reason,
                                        xla_prefill_attention)

KB = "pallas_interpret"


def _mk_paged(rng, packed, n_pages, ps, hkv, d, stage_len, bt_row):
    """Paged cache dict with random pre-existing pool content, a one-row
    block table, and a random raw stage (prompt K/V already staged)."""
    if packed:
        cache = {
            "k_data": jnp.asarray(rng.integers(
                0, 255, (n_pages, ps, hkv, d // 2), dtype=np.uint8)),
            "v_data": jnp.asarray(rng.integers(
                0, 255, (n_pages, ps, hkv, d // 2), dtype=np.uint8)),
            "k_scl": jnp.asarray(rng.normal(
                size=(n_pages, ps, hkv)).astype(np.float32)),
            "v_scl": jnp.asarray(rng.normal(
                size=(n_pages, ps, hkv)).astype(np.float32)),
        }
    else:
        cache = {
            "k": jnp.asarray(rng.normal(
                size=(n_pages, ps, hkv, d)).astype(np.float32)),
            "v": jnp.asarray(rng.normal(
                size=(n_pages, ps, hkv, d)).astype(np.float32)),
        }
    cache["block_table"] = jnp.asarray(np.asarray(bt_row, np.int32)[None])
    cache["stage_k"] = jnp.asarray(rng.normal(
        size=(1, stage_len, hkv, d)).astype(np.float32))
    cache["stage_v"] = jnp.asarray(rng.normal(
        size=(1, stage_len, hkv, d)).astype(np.float32))
    return cache


def _pool_keys(packed):
    return ("k_data", "v_data", "k_scl", "v_scl") if packed else ("k", "v")


def _assert_pools_match(a, b, packed):
    for key in _pool_keys(packed):
        x, y = np.asarray(a[key]), np.asarray(b[key])
        if x.dtype == np.uint8:
            np.testing.assert_array_equal(x, y)   # nibbles bit-identical
        else:
            # f32 scales: jnp.std reassociates differently between the
            # interpreted kernel and eager XLA — 1-ULP agreement
            np.testing.assert_allclose(x, y, atol=1e-6, rtol=1e-6)


@pytest.mark.parametrize("packed", [True, False])
@pytest.mark.parametrize("hkv,g", [(2, 2), (1, 4), (4, 1)])
def test_fused_matches_dense_twin(packed, hkv, g):
    rng = np.random.default_rng(0)
    ps, n_pages, d, s, c = 8, 12, 16, 24, 8
    bt_row = [5, 2, 9]                  # permuted physical pages
    cache = _mk_paged(rng, packed, n_pages, ps, hkv, d, s, bt_row)
    q = jnp.asarray(rng.normal(size=(1, c, hkv * g, d)).astype(np.float32))
    positions = jnp.asarray(np.arange(s - c, s, dtype=np.int32)[None])
    assert prefill_decline_reason(q, cache) is None

    out_f, cache_f = fused_prefill_attention(q, cache, positions,
                                             interpret=True)
    out_x, cache_x = xla_prefill_attention(q, cache, positions)
    np.testing.assert_allclose(np.asarray(out_f), np.asarray(out_x),
                               atol=2e-5, rtol=2e-5)
    _assert_pools_match(cache_f, cache_x, packed)
    # pages no stage tile maps to keep their bytes (aliased pool output)
    visited = set(bt_row)
    for key in _pool_keys(packed):
        orig, new = np.asarray(cache[key]), np.asarray(cache_f[key])
        for p in range(n_pages):
            if p not in visited:
                np.testing.assert_array_equal(new[p], orig[p])


def test_chunked_equals_one_shot():
    """Prefilling in chunks == whole-prompt prefill: same attention (the
    kernel attends the RAW stage, so chunk boundaries add no quantization
    noise) and byte-identical pages (history tiles rewrite idempotently
    every chunk)."""
    rng = np.random.default_rng(1)
    ps, n_pages, d, hkv, g, s = 8, 10, 16, 2, 2, 16
    bt_row = [7, 3]
    full = _mk_paged(rng, True, n_pages, ps, hkv, d, s, bt_row)
    q_all = jnp.asarray(rng.normal(size=(1, s, hkv * g, d))
                        .astype(np.float32))
    pos_all = jnp.asarray(np.arange(s, dtype=np.int32)[None])
    o1, c1 = fused_prefill_attention(q_all, full, pos_all, interpret=True)

    # two chunks of 8: stage grows, history pages rewritten each chunk
    chunked = dict(full,
                   stage_k=jnp.zeros_like(full["stage_k"]),
                   stage_v=jnp.zeros_like(full["stage_v"]))
    outs = []
    for ci in range(2):
        lo, hi = ci * 8, (ci + 1) * 8
        chunked = dict(
            chunked,
            stage_k=chunked["stage_k"].at[:, lo:hi].set(
                full["stage_k"][:, lo:hi]),
            stage_v=chunked["stage_v"].at[:, lo:hi].set(
                full["stage_v"][:, lo:hi]))
        o, chunked = fused_prefill_attention(
            q_all[:, lo:hi], chunked,
            jnp.asarray(np.arange(lo, hi, dtype=np.int32)[None]),
            interpret=True)
        outs.append(np.asarray(o))
    np.testing.assert_allclose(np.asarray(o1),
                               np.concatenate(outs, axis=1),
                               atol=2e-5, rtol=2e-5)
    for key in _pool_keys(True):   # same kernel both paths -> exact
        np.testing.assert_array_equal(np.asarray(c1[key]),
                                      np.asarray(chunked[key]))


def test_single_pallas_call():
    rng = np.random.default_rng(2)
    cache = _mk_paged(rng, True, 6, 8, 2, 16, 16, [1, 4])
    q = jnp.asarray(rng.normal(size=(1, 8, 4, 16)).astype(np.float32))
    positions = jnp.asarray(np.arange(8, 16, dtype=np.int32)[None])
    n = backends.count_pallas_calls(
        lambda q, p: fused_prefill_attention(q, cache, p,
                                             interpret=True)[0],
        q, positions)
    assert n == 1


def test_decline_reasons():
    rng = np.random.default_rng(3)
    cache = _mk_paged(rng, True, 6, 8, 2, 16, 16, [1, 4])
    q = jnp.zeros((1, 8, 4, 16))
    assert prefill_decline_reason(q, cache) is None
    assert prefill_decline_reason(jnp.zeros((2, 8, 4, 16)), cache) \
        == "prefill_batch_gt_1"
    slab = {"k": jnp.zeros((1, 16, 2, 16)), "v": jnp.zeros((1, 16, 2, 16))}
    assert prefill_decline_reason(q, slab) == "prefill_not_paged"
    no_stage = {k: v for k, v in cache.items()
                if not k.startswith("stage")}
    assert prefill_decline_reason(q, no_stage) == "prefill_no_stage"
    short_table = dict(cache, block_table=cache["block_table"][:, :1])
    assert prefill_decline_reason(q, short_table) \
        == "prefill_stage_misaligned"
    no_pool = {k: v for k, v in cache.items()
               if k in ("block_table", "stage_k", "stage_v")}
    assert prefill_decline_reason(q, no_pool) == "paged_no_pool"
    # registry: kernel backends expose the vocabulary, dense backends
    # serve any paged stage layout
    kb = backends.get_backend(KB)
    assert kb.fuses_prefill_attention
    assert kb.prefill_attn_decline_reason(q, slab) == "prefill_not_paged"
    assert backends.get_backend("xla").prefill_attn_decline_reason(
        q, cache) is None


def test_registry_dispatch_and_fallback_stats():
    from repro.core.policy import QuantPolicy
    rng = np.random.default_rng(4)
    cache = _mk_paged(rng, True, 6, 8, 2, 16, 16, [1, 4])
    q = jnp.asarray(rng.normal(size=(1, 8, 4, 16)).astype(np.float32))
    positions = jnp.asarray(np.arange(8, 16, dtype=np.int32)[None])
    pol = QuantPolicy(compute_dtype="float32", backend=KB)
    backends.reset_dispatch_stats()
    out, new_cache = backends.prefill_attention(q, cache, positions,
                                                policy=pol)
    assert backends.dispatch_stats() == {f"{KB}[prefill_attn]": 1}
    out_x, cache_x = xla_prefill_attention(q, cache, positions)
    np.testing.assert_allclose(np.asarray(out), np.asarray(out_x),
                               atol=2e-5, rtol=2e-5)
    _assert_pools_match(new_cache, cache_x, True)

    # declined layout (batch > 1) falls back to the dense twin with the
    # machine-readable reason recorded
    q2 = jnp.asarray(rng.normal(size=(2, 8, 4, 16)).astype(np.float32))
    cache2 = {k: (jnp.concatenate([v, v]) if k.startswith(("stage",
                                                          "block"))
                  else v) for k, v in cache.items()}
    backends.reset_dispatch_stats()
    backends.prefill_attention(
        q2, cache2, jnp.broadcast_to(positions, (2, 8)), policy=pol)
    assert backends.dispatch_stats() == {
        f"{KB}->fallback:prefill_batch_gt_1[prefill_attn]": 1}
