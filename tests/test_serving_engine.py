"""ServingEngine regressions: bucketed prefill reuses one trace per
bucket (and matches exact-length prefill token-for-token), and
`_splice_slot` fails loudly on shape mismatches instead of silently
dropping the prefilled row."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ArchConfig
from repro.core.policy import QuantPolicy
from repro.models.model import build_model
from repro.serve.engine import EngineCfg, ServingEngine, _splice_slot
from repro.serve.paging import PagePoolCfg

TINY = ArchConfig(name="se-tiny", family="dense", n_layers=2, d_model=64,
                  n_heads=4, n_kv_heads=2, d_ff=128, vocab=256,
                  head_dim=16, block_pattern=("attn",))


@pytest.fixture(scope="module")
def tiny_model_params():
    model = build_model(TINY, QuantPolicy(compute_dtype="float32"),
                        remat=False)
    return model, model.init(jax.random.PRNGKey(1))


def _run(model, params, prompts, max_new=4, exact=False):
    eng = ServingEngine(model, params, EngineCfg(batch_slots=2, max_len=64))
    if exact:
        eng._bucket_ok = False  # legacy exact-length prefill path
    for p in prompts:
        eng.submit(p, max_new_tokens=max_new)
    done = eng.run_until_drained()
    return eng, {r.uid: r.out_tokens for r in done}


def test_bucket_prefill_reuses_one_trace(tiny_model_params):
    """Two prompt lengths in one bucket -> one prefill trace, and the
    padded-bucket prefill produces the same tokens as exact-length."""
    model, params = tiny_model_params
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, TINY.vocab, size=n).astype(np.int32)
               for n in (5, 9, 13)]      # all in the 16-bucket

    eng, outs = _run(model, params, prompts)
    assert eng.prefill_traces == 1
    assert sorted(eng._prefill_cache) == [16]

    eng_exact, outs_exact = _run(model, params, prompts, exact=True)
    assert eng_exact.prefill_traces == 3  # the cost the bucket fix removes
    assert outs == outs_exact


def test_bucket_prefill_across_buckets(tiny_model_params):
    model, params = tiny_model_params
    rng = np.random.default_rng(1)
    prompts = [rng.integers(0, TINY.vocab, size=n).astype(np.int32)
               for n in (4, 20, 25)]     # buckets 16 and 32
    eng, outs = _run(model, params, prompts)
    assert eng.prefill_traces == 2
    assert sorted(eng._prefill_cache) == [16, 32]
    assert all(len(v) == 4 for v in outs.values())


def test_recurrent_arch_keeps_exact_prefill():
    """Recurrent states absorb trailing pads, so bucketing must stay off
    for non-attention block patterns."""
    cfg = ArchConfig(name="se-rg", family="hybrid", n_layers=2, d_model=64,
                     n_heads=4, n_kv_heads=4, d_ff=128, vocab=256,
                     head_dim=16, block_pattern=("rglru",))
    model = build_model(cfg, QuantPolicy(compute_dtype="float32"),
                        remat=False)
    params = model.init(jax.random.PRNGKey(0))
    eng = ServingEngine(model, params,
                        EngineCfg(batch_slots=1, max_len=64))
    assert not eng._bucket_ok


def test_max_new_tokens_one_returns_exactly_one_token(tiny_model_params):
    """Regression: the prefill token already satisfies max_new_tokens=1;
    the request must complete without ever entering decode (the seed
    appended the prefill token, decoded anyway, and returned 2)."""
    model, params = tiny_model_params
    eng = ServingEngine(model, params, EngineCfg(batch_slots=2, max_len=64))
    rng = np.random.default_rng(3)
    for n in (5, 9, 7):
        eng.submit(rng.integers(0, TINY.vocab, size=n).astype(np.int32),
                   max_new_tokens=1)
    done = eng.run_until_drained()
    assert sorted(len(r.out_tokens) for r in done) == [1, 1, 1]
    assert all(r.done and r.t_done >= r.t_first for r in done)
    # all three completed at admission: batch_slots=2 must not cap it
    assert not eng.queue and not eng._active()


def test_max_new_tokens_budget_exact(tiny_model_params):
    """max_new_tokens=n yields exactly n tokens (prefill token included)."""
    model, params = tiny_model_params
    for n in (2, 3):
        eng = ServingEngine(model, params,
                            EngineCfg(batch_slots=1, max_len=64))
        eng.submit(np.arange(4, dtype=np.int32), max_new_tokens=n)
        done = eng.run_until_drained()
        assert [len(r.out_tokens) for r in done] == [n]


def test_eos_on_prefill_token_terminates_at_admit(tiny_model_params):
    """An EOS produced by prefill must complete the request in _admit."""
    model, params = tiny_model_params
    prompt = np.arange(4, dtype=np.int32)
    probe = ServingEngine(model, params,
                          EngineCfg(batch_slots=1, max_len=64))
    probe.submit(prompt, max_new_tokens=8)
    probe.step()
    first = probe.completed[0].out_tokens[0] if probe.completed else \
        probe.slots[0].out_tokens[0]
    eng = ServingEngine(model, params,
                        EngineCfg(batch_slots=1, max_len=64, eos_id=first))
    eng.submit(prompt, max_new_tokens=8)
    done = eng.run_until_drained()
    assert [r.out_tokens for r in done] == [[first]]


# ------------------------------------------------------------ paged mode
def _run_paged(model, params, prompts, max_news, *, backend=None,
               page_pool=None, prefill_chunk=0, max_len=128, eos_id=-1):
    eng = ServingEngine(model, params, EngineCfg(
        batch_slots=2, max_len=max_len, backend=backend, eos_id=eos_id,
        page_pool=page_pool, prefill_chunk=prefill_chunk))
    for p, mn in zip(prompts, max_news):
        eng.submit(p, max_new_tokens=mn)
    done = eng.run_until_drained()
    return eng, {r.uid: r.out_tokens for r in done}


def _mixed_prompts(rng):
    # short prompts + one 4x-bucket-length (64 = 4x16), max_new spread
    prompts = [rng.integers(0, TINY.vocab, size=n).astype(np.int32)
               for n in (5, 9, 64, 13, 40)]
    return prompts, [4, 7, 5, 1, 3]


def test_paged_engine_matches_slab_tokens(tiny_model_params):
    """Headline acceptance: a paged engine serves a mixed batch token-
    for-token identically to the slab engine, and every page returns to
    the pool when the batch drains."""
    model, params = tiny_model_params
    prompts, max_news = _mixed_prompts(np.random.default_rng(0))
    _, outs_slab = _run_paged(model, params, prompts, max_news)
    eng, outs = _run_paged(model, params, prompts, max_news,
                           page_pool=PagePoolCfg(page_size=16))
    assert outs == outs_slab
    st = eng.stats()["page_pool"]
    assert st["used_pages"] == 0 and st["frees"] == st["allocs"] > 0
    assert all(r.finish_reason == "max_new_tokens" for r in eng.completed)


def test_paged_engine_quantized_zero_fallbacks():
    """Quantized paged path: prefill and decode both serve fused (no
    dense fallback anywhere), tokens identical to the quantized slab."""
    from repro import backends
    KB = "pallas_interpret"
    pol = QuantPolicy(method="olive", kv_bits=4, compute_dtype="float32",
                      backend=KB)
    model = build_model(TINY, pol, remat=False)
    params = model.init(jax.random.PRNGKey(1))
    rng = np.random.default_rng(2)
    prompts = [rng.integers(0, TINY.vocab, size=n).astype(np.int32)
               for n in (5, 9, 40)]
    max_news = [4, 3, 5]
    _, outs_slab = _run_paged(model, params, prompts, max_news, backend=KB)
    backends.reset_dispatch_stats()
    eng, outs = _run_paged(model, params, prompts, max_news, backend=KB,
                           page_pool=PagePoolCfg(page_size=16))
    assert outs == outs_slab
    stats = backends.dispatch_stats()
    attn = {k: v for k, v in stats.items()
            if "[decode_attn]" in k or "[prefill_attn]" in k}
    assert attn.get(f"{KB}[prefill_attn]", 0) >= 1
    assert attn.get(f"{KB}[decode_attn]", 0) >= 1
    assert not any("->fallback" in k for k in attn), attn


def test_chunked_prefill_matches_and_never_stalls_decode(
        tiny_model_params):
    """prefill_chunk splits long prompts across steps: tokens stay
    identical, at most one chunk runs per step, and an already-active
    request keeps decoding every step of a neighbour's chunked prefill."""
    model, params = tiny_model_params
    prompts, max_news = _mixed_prompts(np.random.default_rng(0))
    _, outs_slab = _run_paged(model, params, prompts, max_news)
    eng, outs = _run_paged(model, params, prompts, max_news,
                           page_pool=PagePoolCfg(page_size=16),
                           prefill_chunk=16)
    assert outs == outs_slab
    assert eng.prefill_chunks_run > len(prompts)  # 64-token prompt split

    # step-by-step: decode progress during a 4-chunk prefill
    eng2 = ServingEngine(model, params, EngineCfg(
        batch_slots=2, max_len=128,
        page_pool=PagePoolCfg(page_size=16), prefill_chunk=16))
    rng = np.random.default_rng(3)
    uid = eng2.submit(rng.integers(0, TINY.vocab, size=5)
                      .astype(np.int32), max_new_tokens=16)
    eng2.submit(rng.integers(0, TINY.vocab, size=64).astype(np.int32),
                max_new_tokens=4)
    decoded = []
    while eng2._prefilling or eng2.queue:
        before = eng2.prefill_chunks_run
        short = next((r for r in eng2.slots if r is not None
                      and r.uid == uid), None)
        n_before = len(short.out_tokens) if short else 0
        eng2.step()
        assert eng2.prefill_chunks_run - before <= 1  # stall bound
        if short is not None and not short.done:
            decoded.append(len(short.out_tokens) - n_before)
    assert decoded and all(d == 1 for d in decoded)  # never stalled
    eng2.run_until_drained()


@pytest.mark.parametrize("paged", [False, True])
def test_finish_reason_length_cap(tiny_model_params, paged):
    """A request whose budget exceeds the cache rows must surface the
    truncation as finish_reason="length_cap", in both cache layouts."""
    model, params = tiny_model_params
    pool = PagePoolCfg(page_size=16) if paged else None
    eng = ServingEngine(model, params, EngineCfg(
        batch_slots=1, max_len=32, page_pool=pool))
    eng.submit(np.arange(20, dtype=np.int32), max_new_tokens=64)
    done = eng.run_until_drained()
    assert done[0].finish_reason == "length_cap"
    assert len(done[0].out_tokens) < 64
    # the cap is max_len rows: prompt (20) + generated fit inside 32
    assert 20 + len(done[0].out_tokens) <= 32
    if paged:
        assert eng.stats()["page_pool"]["used_pages"] == 0


def test_prefill_cache_lru_eviction(tiny_model_params):
    """_prefill_cache holds at most prefill_cache_cap jitted entries and
    reports evictions: cap=1 with alternating buckets evicts twice.
    (Tokens are unaffected — jax keeps its own trace cache keyed on the
    underlying function, the LRU bounds the wrapper dict, whose keys are
    unbounded raw lengths on the exact-length path.)"""
    model, params = tiny_model_params
    eng = ServingEngine(model, params, EngineCfg(
        batch_slots=1, max_len=64, prefill_cache_cap=1))
    rng = np.random.default_rng(4)
    prompts = [rng.integers(0, TINY.vocab, size=n).astype(np.int32)
               for n in (5, 20, 9)]         # buckets 16, 32, 16
    for p in prompts:
        eng.submit(p, max_new_tokens=2)
    done = eng.run_until_drained()
    st = eng.stats()
    assert st["prefill_cache_size"] == 1    # capped
    assert st["prefill_cache_evictions"] == 2

    # same workload at the default cap: both buckets stay resident, no
    # evictions, identical tokens
    eng2 = ServingEngine(model, params,
                         EngineCfg(batch_slots=1, max_len=64))
    for p in prompts:
        eng2.submit(p, max_new_tokens=2)
    done2 = eng2.run_until_drained()
    st2 = eng2.stats()
    assert st2["prefill_cache_size"] == 2
    assert st2["prefill_cache_evictions"] == 0
    assert [r.out_tokens for r in done] == [r.out_tokens for r in done2]


def test_paged_pool_exhaustion_queues_head_of_line(tiny_model_params):
    """Admission reserves the full decode horizon, so a pool too small
    for two long requests serializes them (alloc failure -> head-of-line
    wait) instead of OOMing mid-decode — and still drains completely."""
    model, params = tiny_model_params
    rng = np.random.default_rng(5)
    prompts = [rng.integers(0, TINY.vocab, size=40).astype(np.int32)
               for _ in range(3)]
    # each request: stage bucket 64 -> 4 tiles of 16, horizon 43 -> 3
    # gen pages; need = 4; a 4-page pool serves exactly one at a time
    eng, outs = _run_paged(model, params, prompts, [3, 3, 3],
                           page_pool=PagePoolCfg(page_size=16, n_pages=4))
    assert sorted(len(v) for v in outs.values()) == [3, 3, 3]
    st = eng.stats()["page_pool"]
    assert st["alloc_failures"] >= 1 and st["peak_used"] <= 4
    assert st["used_pages"] == 0
    # same workload, unconstrained pool: tokens unchanged
    _, outs_big = _run_paged(model, params, prompts, [3, 3, 3],
                             page_pool=PagePoolCfg(page_size=16))
    assert outs == outs_big


def test_defrag_mid_serve_preserves_tokens(tiny_model_params):
    """Compacting the pool mid-serve (pages move, tables rebuilt) must
    not change a single token of any in-flight request."""
    model, params = tiny_model_params
    prompts, max_news = _mixed_prompts(np.random.default_rng(0))
    _, outs_ref = _run_paged(model, params, prompts, max_news,
                             page_pool=PagePoolCfg(page_size=16))

    eng = ServingEngine(model, params, EngineCfg(
        batch_slots=2, max_len=128, page_pool=PagePoolCfg(page_size=16)))
    for p, mn in zip(prompts, max_news):
        eng.submit(p, max_new_tokens=mn)
    steps = 0
    while eng.queue or eng._active() or eng._prefilling:
        eng.step()
        steps += 1
        if steps % 2 == 0:                  # churn the layout mid-flight
            remap = eng.defrag()
            assert remap is not None
    assert {r.uid: r.out_tokens for r in eng.completed} == outs_ref
    assert eng.stats()["page_pool"]["used_pages"] == 0


def test_splice_slot_raises_on_shape_mismatch():
    full = {"kv": {"k": jnp.zeros((4, 32, 2, 16))}}
    ok_row = {"kv": {"k": jnp.ones((1, 32, 2, 16))}}
    out = _splice_slot(full, ok_row, 2)
    assert float(out["kv"]["k"][2].sum()) == 32 * 2 * 16
    assert float(out["kv"]["k"][0].sum()) == 0.0

    bad_row = {"kv": {"k": jnp.ones((1, 16, 2, 16))}}  # seq-len mismatch
    with pytest.raises(ValueError, match="no axis"):
        _splice_slot(full, bad_row, 2)
