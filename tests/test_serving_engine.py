"""ServingEngine regressions: bucketed prefill reuses one trace per
bucket (and matches exact-length prefill token-for-token), and
`_splice_slot` fails loudly on shape mismatches instead of silently
dropping the prefilled row."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ArchConfig
from repro.core.policy import QuantPolicy
from repro.models.model import build_model
from repro.serve.engine import EngineCfg, ServingEngine, _splice_slot

TINY = ArchConfig(name="se-tiny", family="dense", n_layers=2, d_model=64,
                  n_heads=4, n_kv_heads=2, d_ff=128, vocab=256,
                  head_dim=16, block_pattern=("attn",))


@pytest.fixture(scope="module")
def tiny_model_params():
    model = build_model(TINY, QuantPolicy(compute_dtype="float32"),
                        remat=False)
    return model, model.init(jax.random.PRNGKey(1))


def _run(model, params, prompts, max_new=4, exact=False):
    eng = ServingEngine(model, params, EngineCfg(batch_slots=2, max_len=64))
    if exact:
        eng._bucket_ok = False  # legacy exact-length prefill path
    for p in prompts:
        eng.submit(p, max_new_tokens=max_new)
    done = eng.run_until_drained()
    return eng, {r.uid: r.out_tokens for r in done}


def test_bucket_prefill_reuses_one_trace(tiny_model_params):
    """Two prompt lengths in one bucket -> one prefill trace, and the
    padded-bucket prefill produces the same tokens as exact-length."""
    model, params = tiny_model_params
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, TINY.vocab, size=n).astype(np.int32)
               for n in (5, 9, 13)]      # all in the 16-bucket

    eng, outs = _run(model, params, prompts)
    assert eng.prefill_traces == 1
    assert sorted(eng._prefill_cache) == [16]

    eng_exact, outs_exact = _run(model, params, prompts, exact=True)
    assert eng_exact.prefill_traces == 3  # the cost the bucket fix removes
    assert outs == outs_exact


def test_bucket_prefill_across_buckets(tiny_model_params):
    model, params = tiny_model_params
    rng = np.random.default_rng(1)
    prompts = [rng.integers(0, TINY.vocab, size=n).astype(np.int32)
               for n in (4, 20, 25)]     # buckets 16 and 32
    eng, outs = _run(model, params, prompts)
    assert eng.prefill_traces == 2
    assert sorted(eng._prefill_cache) == [16, 32]
    assert all(len(v) == 4 for v in outs.values())


def test_recurrent_arch_keeps_exact_prefill():
    """Recurrent states absorb trailing pads, so bucketing must stay off
    for non-attention block patterns."""
    cfg = ArchConfig(name="se-rg", family="hybrid", n_layers=2, d_model=64,
                     n_heads=4, n_kv_heads=4, d_ff=128, vocab=256,
                     head_dim=16, block_pattern=("rglru",))
    model = build_model(cfg, QuantPolicy(compute_dtype="float32"),
                        remat=False)
    params = model.init(jax.random.PRNGKey(0))
    eng = ServingEngine(model, params,
                        EngineCfg(batch_slots=1, max_len=64))
    assert not eng._bucket_ok


def test_max_new_tokens_one_returns_exactly_one_token(tiny_model_params):
    """Regression: the prefill token already satisfies max_new_tokens=1;
    the request must complete without ever entering decode (the seed
    appended the prefill token, decoded anyway, and returned 2)."""
    model, params = tiny_model_params
    eng = ServingEngine(model, params, EngineCfg(batch_slots=2, max_len=64))
    rng = np.random.default_rng(3)
    for n in (5, 9, 7):
        eng.submit(rng.integers(0, TINY.vocab, size=n).astype(np.int32),
                   max_new_tokens=1)
    done = eng.run_until_drained()
    assert sorted(len(r.out_tokens) for r in done) == [1, 1, 1]
    assert all(r.done and r.t_done >= r.t_first for r in done)
    # all three completed at admission: batch_slots=2 must not cap it
    assert not eng.queue and not eng._active()


def test_max_new_tokens_budget_exact(tiny_model_params):
    """max_new_tokens=n yields exactly n tokens (prefill token included)."""
    model, params = tiny_model_params
    for n in (2, 3):
        eng = ServingEngine(model, params,
                            EngineCfg(batch_slots=1, max_len=64))
        eng.submit(np.arange(4, dtype=np.int32), max_new_tokens=n)
        done = eng.run_until_drained()
        assert [len(r.out_tokens) for r in done] == [n]


def test_eos_on_prefill_token_terminates_at_admit(tiny_model_params):
    """An EOS produced by prefill must complete the request in _admit."""
    model, params = tiny_model_params
    prompt = np.arange(4, dtype=np.int32)
    probe = ServingEngine(model, params,
                          EngineCfg(batch_slots=1, max_len=64))
    probe.submit(prompt, max_new_tokens=8)
    probe.step()
    first = probe.completed[0].out_tokens[0] if probe.completed else \
        probe.slots[0].out_tokens[0]
    eng = ServingEngine(model, params,
                        EngineCfg(batch_slots=1, max_len=64, eos_id=first))
    eng.submit(prompt, max_new_tokens=8)
    done = eng.run_until_drained()
    assert [r.out_tokens for r in done] == [[first]]


def test_splice_slot_raises_on_shape_mismatch():
    full = {"kv": {"k": jnp.zeros((4, 32, 2, 16))}}
    ok_row = {"kv": {"k": jnp.ones((1, 32, 2, 16))}}
    out = _splice_slot(full, ok_row, 2)
    assert float(out["kv"]["k"][2].sum()) == 32 * 2 * 16
    assert float(out["kv"]["k"][0].sum()) == 0.0

    bad_row = {"kv": {"k": jnp.ones((1, 16, 2, 16))}}  # seq-len mismatch
    with pytest.raises(ValueError, match="no axis"):
        _splice_slot(full, bad_row, 2)
