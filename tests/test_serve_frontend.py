"""Async streaming front end + serve metrics regressions.

Pins the serving contracts docs/serving.md documents:

- streaming: each request's tokens arrive through its `TokenStream`
  in sampling order, incrementally DURING the run (not in one burst at
  drain), and a stream finishes strictly after its last token;
- golden parity: an async streaming run is token-for-token identical
  to `run_until_drained` at the same config — fp slab AND the
  quantized paged+chunked path;
- TTFT is monotone in queue position when admission is serialized
  (batch_slots=1);
- COUNTER SEMANTICS: every scalar in `ServingEngine.stats()` /
  `PagePool.stats()` counters is a lifetime counter (never reset by a
  drain); per-step numbers come from `StepEvents` / the ledger;
- the metrics snapshot and its JSONL trace round-trip losslessly.
"""
from __future__ import annotations

import asyncio

import jax
import numpy as np
import pytest

from repro import backends
from repro.configs.base import ArchConfig
from repro.core.policy import QuantPolicy
from repro.models.model import build_model
from repro.serve import (AsyncFrontend, EngineCfg, MetricsLedger,
                         ServingEngine, load_trace)
from repro.serve.paging import PagePoolCfg

TINY = ArchConfig(name="fe-tiny", family="dense", n_layers=2, d_model=64,
                  n_heads=4, n_kv_heads=2, d_ff=128, vocab=256,
                  head_dim=16, block_pattern=("attn",))


@pytest.fixture(scope="module")
def tiny_model_params():
    model = build_model(TINY, QuantPolicy(compute_dtype="float32"),
                        remat=False)
    return model, model.init(jax.random.PRNGKey(1))


def _prompts(sizes, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, TINY.vocab, size=n).astype(np.int32)
            for n in sizes]


def _engine(model, params, page_pool=None, prefill_chunk=0,
            batch_slots=2, max_len=128, backend=None, mesh=None):
    return ServingEngine(model, params, EngineCfg(
        batch_slots=batch_slots, max_len=max_len, backend=backend,
        page_pool=page_pool, prefill_chunk=prefill_chunk, mesh=mesh))


def _drained(eng, prompts, max_news, metrics=None):
    for p, mn in zip(prompts, max_news):
        eng.submit(p, max_new_tokens=mn)
    done = eng.run_until_drained(metrics=metrics)
    return {r.uid: r.out_tokens for r in done}


def _async(eng, prompts, max_news, metrics=None, consume=True):
    """Run the async front end over `prompts`; returns (token dict,
    per-stream consumption records [(token, steps_run_at_consume)])."""

    async def go():
        records = {}

        async def consumer(stream):
            recs = []
            async for tok in stream:
                recs.append((tok, eng.steps_run, stream.done))
            records[stream.uid] = recs

        async with AsyncFrontend(eng, metrics=metrics) as fe:
            streams = [fe.submit(p, max_new_tokens=mn)
                       for p, mn in zip(prompts, max_news)]
            if consume:
                await asyncio.gather(*(consumer(s) for s in streams))
            else:
                await fe.drain()
        outs = {s.uid: list(s.tokens) for s in streams}
        reasons = {s.uid: s.finish_reason for s in streams}
        return outs, records, reasons

    return asyncio.run(go())


# ------------------------------------------------------------- streaming
def test_stream_order_and_incremental_arrival(tiny_model_params):
    """Mixed admission (queueing, chunked prefill, interleaved decode):
    every stream yields its tokens in sampling order, incrementally
    across the run — tokens of early requests are consumed strictly
    before the engine finishes, not in a burst at drain."""
    model, params = tiny_model_params
    prompts = _prompts((5, 9, 64, 13, 40), seed=3)
    max_news = [4, 7, 5, 1, 3]
    eng = _engine(model, params, page_pool=PagePoolCfg(page_size=16),
                  prefill_chunk=16)
    outs, records, reasons = _async(eng, prompts, max_news)

    total_steps = eng.steps_run
    by_uid = {r.uid: r for r in eng.completed}
    assert sorted(outs) == sorted(by_uid)
    for uid, toks in outs.items():
        req = by_uid[uid]
        # sampling order, token-for-token
        assert toks == req.out_tokens
        assert [t for t, _, _ in records[uid]] == req.out_tokens
        assert reasons[uid] == req.finish_reason
        # a stream finishes strictly after its last token: every token
        # was consumed while the stream was not yet marked done
        assert all(not done for _, _, done in records[uid])
        assert len(toks) <= max_news[uid - 1]
    # incremental delivery: consumption spans multiple engine steps and
    # the earliest tokens land while the engine still has work
    seen_steps = sorted({s for recs in records.values()
                         for _, s, _ in recs})
    assert len(seen_steps) >= 3, seen_steps
    assert seen_steps[0] < total_steps


def test_submit_while_running(tiny_model_params):
    """Continuous intake: a request submitted mid-run (from a stream
    consumer) is admitted and completes in the same front-end session."""
    model, params = tiny_model_params
    eng = _engine(model, params)

    async def go():
        async with AsyncFrontend(eng) as fe:
            first = fe.submit(_prompts((6,))[0], max_new_tokens=3)
            late = []
            async for _ in first:
                if not late:
                    late.append(fe.submit(_prompts((9,), seed=5)[0],
                                          max_new_tokens=2))
            toks = [t async for t in late[0]]
            return first.tokens, toks, late[0].finish_reason

    first_toks, late_toks, late_reason = asyncio.run(go())
    assert len(first_toks) == 3 and len(late_toks) == 2
    assert late_reason == "max_new_tokens"
    assert len(eng.completed) == 2


# ---------------------------------------------------------- golden parity
def test_async_matches_drained_fp_slab(tiny_model_params):
    model, params = tiny_model_params
    prompts = _prompts((5, 9, 13, 20), seed=1)
    max_news = [4, 4, 4, 4]
    outs_drained = _drained(_engine(model, params), prompts, max_news)
    outs_async, _, _ = _async(_engine(model, params), prompts, max_news,
                              consume=False)
    assert outs_async == outs_drained


def test_async_matches_drained_quantized_paged():
    """The acceptance path: OVP-quantized KV on the paged+chunked engine
    through the fused interpret kernels — async == drained
    token-for-token, with zero quantized-path fallbacks recorded by the
    ledger."""
    KB = "pallas_interpret"
    pol = QuantPolicy(method="olive", kv_bits=4, compute_dtype="float32",
                      backend=KB)
    model = build_model(TINY, pol, remat=False)
    params = model.init(jax.random.PRNGKey(1))
    prompts = _prompts((5, 9, 40), seed=2)
    max_news = [4, 3, 5]

    def eng():
        return _engine(model, params, page_pool=PagePoolCfg(page_size=16),
                       prefill_chunk=16, max_len=64, backend=KB)

    outs_drained = _drained(eng(), prompts, max_news)
    ledger = MetricsLedger()
    outs_async, _, _ = _async(eng(), prompts, max_news, metrics=ledger)
    assert outs_async == outs_drained
    snap = ledger.snapshot()
    assert snap["fallbacks"] == 0, snap["dispatch"]
    assert snap["requests"] == len(prompts)


def test_async_sharded_matches_single_device_golden(forced_devices):
    """The SAME golden config as test_async_matches_drained_quantized_
    paged, served on `pallas_sharded_interpret` over a (4, 2) mesh: the
    async sharded run must be token-for-token identical to the
    single-device drained run, with zero fallbacks (every matmul and
    both attention paths took the sharded kernels) and the per-device
    pool gauge showing both model-axis shards."""
    KB = "pallas_interpret"
    SB = "pallas_sharded_interpret"
    from repro.backends import configure_mesh
    from repro.runtime.elastic import MeshPlan
    pol = QuantPolicy(method="olive", kv_bits=4, compute_dtype="float32",
                      backend=KB)
    model = build_model(TINY, pol, remat=False)
    params = model.init(jax.random.PRNGKey(1))
    prompts = _prompts((5, 9, 40), seed=2)
    max_news = [4, 3, 5]

    golden = _drained(
        _engine(model, params, page_pool=PagePoolCfg(page_size=16),
                prefill_chunk=16, max_len=64, backend=KB),
        prompts, max_news)
    try:
        ledger = MetricsLedger()
        backends.reset_dispatch_stats()
        eng = _engine(model, params, page_pool=PagePoolCfg(page_size=16),
                      prefill_chunk=16, max_len=64, backend=SB,
                      mesh=MeshPlan(shape=(4, 2),
                                    axis_names=("data", "model"),
                                    dropped_devices=0))
        outs, _, _ = _async(eng, prompts, max_news, metrics=ledger)
        assert outs == golden
        snap = ledger.snapshot()
        assert snap["fallbacks"] == 0, snap["dispatch"]
        assert any(k.startswith(SB) for k in snap["dispatch"]), \
            snap["dispatch"]
        assert snap["pool_device_occupancy"]["n_devices"] == 2
        assert all(len(r["pool_device_occupancy"]) == 2
                   for r in ledger.step_records)
    finally:
        configure_mesh(None)


# ------------------------------------------------------------------ TTFT
def test_ttft_monotone_in_queue_position(tiny_model_params):
    """batch_slots=1 serializes admission, so TTFT must be monotone in
    queue position (uids are sequential == submission order)."""
    model, params = tiny_model_params
    prompts = _prompts((5, 6, 7, 8), seed=4)
    max_news = [2, 2, 2, 2]
    ledger = MetricsLedger()
    eng = _engine(model, params, batch_slots=1)
    _async(eng, prompts, max_news, metrics=ledger, consume=False)
    recs = sorted(ledger.request_records, key=lambda r: r["uid"])
    assert len(recs) == len(prompts)
    ttfts = [r["ttft_s"] for r in recs]
    assert all(a <= b for a, b in zip(ttfts, ttfts[1:])), ttfts
    assert all(t > 0 for t in ttfts)


# ------------------------------------------------------ counter semantics
def test_stats_counters_are_lifetime(tiny_model_params):
    """`stats()` scalars are LIFETIME counters: a drain does not reset
    them, and a second run on the same engine strictly grows them.
    Per-step numbers live in StepEvents / the ledger instead."""
    model, params = tiny_model_params
    eng = _engine(model, params, page_pool=PagePoolCfg(page_size=16))
    _drained(eng, _prompts((5, 9), seed=6), [3, 3])
    st1 = eng.stats()
    pool1 = st1["page_pool"]
    assert st1["steps_run"] > 0 and st1["prefill_chunks_run"] > 0
    assert pool1["allocs"] > 0 and pool1["frees"] == pool1["allocs"]
    assert pool1["used_pages"] == 0          # gauge: drained pool is empty

    _drained(eng, _prompts((6, 7), seed=7), [2, 2])
    st2 = eng.stats()
    pool2 = st2["page_pool"]
    assert st2["steps_run"] > st1["steps_run"]
    assert st2["prefill_chunks_run"] > st1["prefill_chunks_run"]
    assert len(eng.completed) == 4           # completion log accumulates
    assert pool2["allocs"] > pool1["allocs"]
    assert pool2["peak_used"] >= pool1["peak_used"]


def test_pool_gauges_sampled_per_step(tiny_model_params):
    """The ledger samples the pool gauges every step: occupancy rises
    while requests hold pages and the last record returns to 0."""
    model, params = tiny_model_params
    ledger = MetricsLedger()
    eng = _engine(model, params, page_pool=PagePoolCfg(page_size=16))
    _drained(eng, _prompts((20, 30), seed=8), [3, 3], metrics=ledger)
    occ = [r["pool_occupancy"] for r in ledger.step_records]
    assert max(occ) > 0.0
    assert occ[-1] == 0.0
    frag = [r["pool_fragmentation"] for r in ledger.step_records]
    assert all(0.0 <= f < 1.0 for f in frag)


# --------------------------------------------------------- trace round trip
def test_metrics_snapshot_and_jsonl_roundtrip(tiny_model_params, tmp_path):
    model, params = tiny_model_params
    prompts = _prompts((5, 9, 64), seed=9)
    max_news = [3, 4, 2]
    ledger = MetricsLedger()
    eng = _engine(model, params, page_pool=PagePoolCfg(page_size=16),
                  prefill_chunk=16)
    outs, _, _ = _async(eng, prompts, max_news, metrics=ledger)

    snap = ledger.snapshot()
    assert snap["steps"] == len(ledger.step_records) == eng.steps_run
    assert snap["requests"] == len(prompts)
    assert snap["tokens"] == sum(len(v) for v in outs.values())
    assert snap["ttft_s"]["n"] == len(prompts)
    # tpot is None for single-token requests, present otherwise
    assert snap["tpot_s"]["n"] == sum(1 for v in outs.values()
                                      if len(v) > 1)
    assert snap["prefill_chunk_steps"] > 0
    assert snap["prefill_interleave_ratio"] is not None
    # per-device pool gauge: unsharded engine = one device, whose entry
    # is exactly the pool occupancy of that step
    assert all(r["pool_device_occupancy"] == [r["pool_occupancy"]]
               for r in ledger.step_records)
    assert snap["pool_device_occupancy"]["n_devices"] == 1
    assert snap["pool_device_occupancy"]["final"] == [0.0]

    path = tmp_path / "trace.jsonl"
    ledger.write_jsonl(str(path))
    trace = load_trace(str(path))
    assert trace["meta"]["paged"] is True
    assert trace["meta"]["page_size"] == 16
    assert trace["steps"] == ledger.step_records
    assert trace["requests"] == ledger.request_records
    assert trace["summary"] == snap


# -------------------------------------------------------------- lifecycle
def test_frontend_lifecycle_errors(tiny_model_params):
    model, params = tiny_model_params
    eng = _engine(model, params)
    fe = AsyncFrontend(eng)
    with pytest.raises(RuntimeError, match="not running"):
        fe.submit(np.zeros(4, np.int32))

    async def double_start():
        async with AsyncFrontend(eng) as fe2:
            with pytest.raises(RuntimeError, match="already started"):
                fe2.start()

    asyncio.run(double_start())


def test_async_recurrent_slab_arch():
    """Config-zoo smoke: a recurrent (non-attention) arch serves through
    the async front end on the slab path (no paging, exact prefill)."""
    cfg = ArchConfig(name="fe-rg", family="hybrid", n_layers=2,
                     d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
                     vocab=256, head_dim=16, block_pattern=("rglru",))
    model = build_model(cfg, QuantPolicy(compute_dtype="float32"),
                        remat=False)
    params = model.init(jax.random.PRNGKey(0))
    eng = ServingEngine(model, params,
                        EngineCfg(batch_slots=1, max_len=64))
    prompts = _prompts((5, 8), seed=10)
    outs, _, reasons = _async(eng, prompts, [2, 2])
    assert all(len(v) == 2 for v in outs.values())
    assert all(r == "max_new_tokens" for r in reasons.values())
