"""Static calibrated activation scales, end to end.

Covers the calibration artifact (save/load round trip, glob resolution),
`apply_calibration` baking scales into a policy program, the engine's
up-front validation of static-mode sites (machine-readable
`MissingStaticScaleError`), static-vs-dynamic numerical agreement when the
static scale equals the dynamic one (all backends, 2-D + grouped), and the
acceptance claim: an engine serving with a calibration artifact performs
ZERO dynamic activation-scale computations (`backends.act_scale_stats()`).
"""
from __future__ import annotations

import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import backends
from repro.configs.base import ArchConfig
from repro.core.calibration import (ActTape, CalibrationArtifact,
                                    MissingStaticScaleError,
                                    apply_calibration,
                                    calibrate_activation_scales,
                                    calibrate_model, collecting_activations,
                                    static_scale_misses, uses_static_scales)
from repro.core.policy import OLIVE_W4A4, QuantPolicy
from repro.core.qlinear import qmatmul, quantize_params, quantize_weight
from repro.core.quantizer import sigma_init_scale
from repro.kernels import ops
from repro.models.model import build_model
from repro.serve.engine import EngineCfg, ServingEngine

TINY = ArchConfig(name="cal-tiny", family="dense", n_layers=2, d_model=64,
                  n_heads=4, n_kv_heads=2, d_ff=128, vocab=256,
                  head_dim=16, block_pattern=("attn",))


def rel_err(got, want):
    got, want = np.asarray(got, np.float64), np.asarray(want, np.float64)
    return float(np.max(np.abs(got - want)) / (np.max(np.abs(want)) + 1e-9))


def serve_program(backend: str = "xla"):
    """W4A4 static-mode program the engine tests serve under."""
    return QuantPolicy(method="olive", wbits=4, abits=4,
                       act_scale_mode="static", compute_dtype="float32",
                       backend=backend).as_program()


@pytest.fixture(scope="module")
def tiny_fp():
    model = build_model(TINY, QuantPolicy(compute_dtype="float32"),
                        remat=False)
    return model, model.init(jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def artifact(tiny_fp):
    model, params = tiny_fp
    batch = {"tokens": jnp.asarray(
        np.random.default_rng(0).integers(0, TINY.vocab, size=(2, 16))
        .astype(np.int32))}
    return calibrate_model(model, params, [batch], max_per_site=4096,
                           n_grid=8)


class TestArtifact:
    def test_round_trip(self, artifact, tmp_path):
        p = artifact.save(str(tmp_path / "calib.json"))
        loaded = CalibrationArtifact.load(p)
        assert loaded == artifact
        assert loaded.as_dict() == artifact.as_dict()
        # the payload is plain JSON with the declared kind
        with open(p) as f:
            payload = json.load(f)
        assert payload["kind"] == "olive-calibration"
        assert payload["scales"]

    def test_load_rejects_non_artifact(self, tmp_path):
        p = tmp_path / "not_calib.json"
        p.write_text('{"scales": {"a": 1.0}}')
        with pytest.raises(ValueError, match="not a calibration artifact"):
            CalibrationArtifact.load(str(p))

    def test_calibrated_sites_cover_quantized_tree(self, artifact,
                                                   tiny_fp):
        """Tape keys are the same addresses `quantize_params` resolves:
        every quantized leaf of the serving tree has a calibrated scale."""
        model, params = tiny_fp
        prog = apply_calibration(serve_program(), artifact)
        qmodel = build_model(TINY, prog, remat=False)
        qp = quantize_params(qmodel.adapt_params(params), prog)
        assert static_scale_misses(qp, prog) == []
        # per-layer unrolled addresses were taped (layers/<i>/...)
        assert any(s.startswith("layers/0/") for s in artifact.sites())
        assert any(s.startswith("layers/1/") for s in artifact.sites())

    def test_glob_keys_resolve(self):
        art = CalibrationArtifact.from_scales(
            {"layers/0/attn/wq": 0.25, "layers/*/mlp/w*": 0.5})
        assert art.resolve("layers/0/attn/wq") == 0.25
        assert art.resolve("layers/7/mlp/wg") == 0.5
        assert art.resolve("embed/table") is None
        prog = apply_calibration(serve_program(), art)
        assert prog.resolve("layers/3/mlp/wd").static_act_scale == 0.5
        assert prog.resolve("layers/0/attn/wq").static_act_scale == 0.25
        assert prog.resolve("layers/0/attn/wk").static_act_scale is None

    def test_glob_key_preserves_mixed_precision(self):
        """A glob artifact key attaches scales per concrete site without
        disturbing each site's own precision rule: layer 1's W8 rule
        survives a `layers/*/mlp/w*` scale key."""
        w8 = QuantPolicy(method="olive", wbits=8, abits=8,
                         w_normal_dtype="int8", a_normal_dtype="int8",
                         act_scale_mode="static",
                         compute_dtype="float32")
        prog = serve_program().with_rules([("layers/1/mlp/*", w8)])
        art = CalibrationArtifact.from_scales({"layers/*/mlp/w*": 0.5})
        cal = apply_calibration(prog, art)
        hot = cal.resolve("layers/1/mlp/wg")
        cold = cal.resolve("layers/2/mlp/wg")
        assert (hot.wbits, hot.static_act_scale) == (8, 0.5)
        assert (cold.wbits, cold.static_act_scale) == (4, 0.5)
        # the engine's backend override must not drop the overlay
        assert cal.with_backend("reference") \
            .resolve("layers/1/mlp/wg").static_act_scale == 0.5

    def test_overlapping_glob_keys_keep_author_order(self, tmp_path):
        """First key wins for overlapping globs, across a save/load
        round trip (no alphabetical re-sorting)."""
        art = CalibrationArtifact.from_scales(
            {"layers/0/*": 0.5, "layers/*": 0.1})
        loaded = CalibrationArtifact.load(
            art.save(str(tmp_path / "o.json")))
        for a in (art, loaded):
            assert a.resolve("layers/0/attn/wq") == 0.5
            assert a.resolve("layers/3/attn/wq") == 0.1

    def test_reapplied_artifact_fresh_scales_win(self, tmp_path):
        """Re-applying an updated artifact serves the NEW scale for a
        site both cover — in resolution and in the saved payload."""
        prog = apply_calibration(
            serve_program(),
            CalibrationArtifact.from_scales({"layers/0/attn/wq": 0.5}))
        prog2 = apply_calibration(
            prog,
            CalibrationArtifact.from_scales({"layers/0/attn/wq": 0.9}))
        assert prog2.resolve("layers/0/attn/wq").static_act_scale == 0.9
        merged = prog2.artifact
        assert merged.resolve("layers/0/attn/wq") == 0.9
        saved = CalibrationArtifact.load(
            merged.save(str(tmp_path / "m.json")))
        assert saved.resolve("layers/0/attn/wq") == 0.9


class TestStaticDynamicEquivalence:
    @pytest.mark.parametrize("backend",
                             ["xla", "pallas_interpret", "reference"])
    def test_matches_dynamic_when_scale_equal(self, backend):
        """With the static scale set to exactly the dynamic 3σ value, the
        static path reproduces the dynamic output on every backend (the
        Pallas constant-folded prologue to fp32 rounding)."""
        key = jax.random.PRNGKey(5)
        x = jax.random.normal(key, (32, 128)) * 2.0
        w = jax.random.normal(jax.random.split(key)[0], (128, 96))
        dyn = QuantPolicy(method="olive", wbits=4, abits=4,
                          compute_dtype="float32", backend=backend)
        wq = quantize_weight(w, dyn)
        s = float(sigma_init_scale(x, "int4"))
        st = dataclasses.replace(dyn, act_scale_mode="static",
                                 static_act_scale=s)
        got = qmatmul(x, wq, st, site="t")
        want = qmatmul(x, wq, dyn, site="t")
        assert rel_err(got, want) < 1e-5, backend

    def test_grouped_static_matches_dynamic(self):
        """The grouped (per-expert) kernel's static prologue agrees with
        the scale-operand path at the same scale."""
        key = jax.random.PRNGKey(6)
        xg = jax.random.normal(key, (4, 8, 64))
        ws = jax.random.normal(jax.random.split(key)[0], (4, 64, 48))
        pol = QuantPolicy(method="olive", wbits=4, abits=4,
                          compute_dtype="float32")
        wq = quantize_weight(ws, pol)
        s = float(sigma_init_scale(xg, "int4"))
        stat = ops.grouped_ovp_matmul(xg, wq, a_dtype="int4",
                                      static_act_scale=s, interpret=True)
        dyn = ops.grouped_ovp_matmul(xg, wq, a_dtype="int4",
                                     act_scale=jnp.float32(s),
                                     interpret=True)
        assert rel_err(stat, dyn) < 1e-5

    def test_static_path_is_one_pallas_call_scalar_scale_operand(self):
        """The static kernel stays a single dispatch and its activation
        scale is ONE (1, 1) scalar operand, not the (B, M, 1) per-row
        plane the dynamic prologue streams — and because the scale is an
        operand (not a baked constant), one compiled kernel serves every
        calibrated site."""
        key = jax.random.PRNGKey(7)
        x = jax.random.normal(key, (16, 128))
        w = jax.random.normal(jax.random.split(key)[0], (128, 64))
        pol = QuantPolicy(method="olive", wbits=4, abits=4,
                          compute_dtype="float32")
        wq = quantize_weight(w, pol)

        def static_mm(x):
            return ops.fused_ovp_matmul(x, wq, a_dtype="int4",
                                        static_act_scale=0.1,
                                        interpret=True)

        assert backends.count_pallas_calls(static_mm, x) == 1
        jaxpr = jax.make_jaxpr(static_mm)(x)
        [eqn] = [e for e in jax.tree_util.tree_leaves(
            [list(j.eqns) for j in _all_jaxprs(jaxpr.jaxpr)],
            is_leaf=lambda v: hasattr(v, "primitive"))
            if e.primitive.name == "pallas_call"]
        shapes = [tuple(v.aval.shape) for v in eqn.invars]
        assert (1, 1) in shapes          # the scalar scale word
        assert (x.shape[0], x.shape[1], 1) not in shapes \
            and (1, x.shape[0], 1) not in shapes  # no per-row plane
        # operand, not constant: a second scale at the same shape reuses
        # the compiled kernel instead of tracing a new one
        ops.fused_ovp_matmul(x, wq, a_dtype="int4",
                             static_act_scale=0.1, interpret=True)
        n_traces = ops._fused_padded._cache_size()
        ops.fused_ovp_matmul(x, wq, a_dtype="int4",
                             static_act_scale=0.25, interpret=True)
        assert ops._fused_padded._cache_size() == n_traces


def _all_jaxprs(jaxpr):
    yield jaxpr
    for eqn in jaxpr.eqns:
        for v in eqn.params.values():
            for item in (v if isinstance(v, (tuple, list)) else [v]):
                inner = getattr(item, "jaxpr", None)
                if inner is not None and hasattr(inner, "eqns"):
                    yield from _all_jaxprs(inner)


class TestValidation:
    def test_missing_scale_raises_machine_readable(self, tiny_fp):
        """Static mode without an artifact fails engine construction with
        the full miss list, not mid-trace on the first prefill."""
        model, params = tiny_fp
        prog = serve_program()
        qmodel = build_model(TINY, prog, remat=False)
        qp = quantize_params(params, prog)
        with pytest.raises(MissingStaticScaleError) as ei:
            ServingEngine(qmodel, qp, EngineCfg(batch_slots=1, max_len=32))
        assert ei.value.sites  # machine-readable: the offending addresses
        assert all("/" in s for s in ei.value.sites)
        assert "missing_static_scale" in str(ei.value)

    def test_unknown_site_in_artifact_leaves_misses(self, tiny_fp):
        """An artifact that only covers a bogus site leaves every real
        site unscaled — validation reports them all."""
        model, params = tiny_fp
        art = CalibrationArtifact.from_scales({"no/such/site": 0.1})
        prog = apply_calibration(serve_program(), art)
        qp = quantize_params(params, prog)
        misses = static_scale_misses(qp, prog)
        assert misses  # every quantized site is still uncalibrated
        assert "blocks/0/attn/wq" in misses

    def test_scanned_model_with_layer_keys_diagnoses_layout(self, tiny_fp,
                                                            artifact):
        """EngineCfg.calibration on a *scanned* model with layers/<i>
        artifact keys fails with a layout diagnosis, not a bare miss
        list (the keys can never match blocks/<j> sites)."""
        model, params = tiny_fp
        prog = serve_program()
        qmodel = build_model(TINY, prog, remat=False)
        assert not qmodel.unrolled
        qp = quantize_params(params, prog)
        with pytest.raises(ValueError, match="unrolled layers/<i> layout"):
            ServingEngine(qmodel, qp,
                          EngineCfg(batch_slots=1, max_len=32,
                                    calibration=artifact))

    def test_uses_static_scales_gate(self):
        assert uses_static_scales(serve_program())
        assert not uses_static_scales(OLIVE_W4A4)
        assert not uses_static_scales(
            QuantPolicy(compute_dtype="float32"))


class TestEngineStaticServing:
    def test_serves_with_zero_dynamic_scale_resolutions(self, tiny_fp,
                                                        artifact,
                                                        tmp_path):
        """Acceptance: an engine configured with act_scale_mode="static"
        and a calibration artifact serves end to end with zero dynamic
        activation-scale computations, verified via the backend ledger."""
        _, params = tiny_fp
        # round-trip the artifact through disk, as the serve CLI does
        art = CalibrationArtifact.load(
            artifact.save(str(tmp_path / "a.json")))
        prog = apply_calibration(serve_program(), art)
        model = build_model(TINY, prog, remat=False)
        assert model.unrolled  # per-layer scale rules address layers/<i>
        qp = quantize_params(model.adapt_params(params), prog)

        eng = ServingEngine(model, qp, EngineCfg(batch_slots=2, max_len=48))
        backends.reset_act_scale_stats()
        rng = np.random.default_rng(3)
        for _ in range(3):
            eng.submit(rng.integers(0, TINY.vocab, size=6)
                       .astype(np.int32), max_new_tokens=4)
        done = eng.run_until_drained()
        assert len(done) == 3
        assert all(len(r.out_tokens) == 4 for r in done)
        stats = backends.act_scale_stats()
        assert stats.get("dynamic", 0) == 0, stats
        assert stats.get("static", 0) > 0, stats

    def test_engine_cfg_applies_artifact(self, tiny_fp, artifact):
        """EngineCfg.calibration bakes the artifact in at construction;
        without it the same static-mode engine refuses to start."""
        _, params = tiny_fp
        prog = apply_calibration(serve_program(), artifact)
        model = build_model(TINY, prog, remat=False)
        base = serve_program()
        qp = quantize_params(model.adapt_params(params),
                             prog)  # scales don't affect weight packing
        # validation passes only because the cfg supplies the artifact
        eng = ServingEngine(
            build_model(TINY, apply_calibration(base, artifact),
                        remat=False), qp,
            EngineCfg(batch_slots=1, max_len=32, calibration=artifact))
        assert uses_static_scales(eng.model.policy)
        assert static_scale_misses(qp, eng.model.policy) == []


class TestTape:
    def test_collecting_activations_records_sites(self, tiny_fp):
        model, params = tiny_fp
        tape = ActTape(max_per_site=1024)
        batch = {"tokens": jnp.zeros((1, 8), jnp.int32)}
        with collecting_activations(tape):
            model.forward(params, batch, mode="train")
        # scanned stacks trace their body, so block sites need the
        # unrolled twin (calibrate_model handles that); the head site
        # tapes on any layout
        assert "lm_head/w_out" in tape.samples
        scales = calibrate_activation_scales(tape, "int4", n_grid=4)
        assert set(scales) == set(tape.samples)
        assert all(float(s) > 0 for s in scales.values())
