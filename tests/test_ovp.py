import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import datatypes as dt
from repro.core import ovp


def heavy_tailed(key, shape, outlier_frac=0.01, outlier_scale=20.0):
    """Gaussian bulk + sparse large outliers (Transformer-like, Fig. 2)."""
    k1, k2, k3 = jax.random.split(key, 3)
    x = jax.random.normal(k1, shape)
    mask = jax.random.uniform(k2, shape) < outlier_frac
    out = jax.random.normal(k3, shape) * outlier_scale
    return jnp.where(mask, out, x)


class TestPacking:
    @pytest.mark.parametrize("shape,axis", [((8,), -1), ((4, 6), -1),
                                            ((4, 6), 0), ((2, 3, 8), 1)])
    def test_pack_unpack_inverse(self, shape, axis):
        key = jax.random.PRNGKey(0)
        codes = jax.random.randint(key, shape, 0, 16).astype(jnp.uint8)
        # pairing axis must be even-length
        if shape[axis] % 2:
            pytest.skip("odd")
        packed = ovp.pack4(codes, axis)
        assert packed.dtype == jnp.uint8
        ax = axis % len(shape)
        assert packed.shape[ax] == shape[ax] // 2
        out = ovp.unpack4(packed, axis)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(codes))

    def test_one_byte_is_one_pair(self):
        codes = jnp.array([0x1, 0x2, 0x8, 0x5], dtype=jnp.uint8)
        packed = np.asarray(ovp.pack4(codes))
        assert packed.tolist() == [0x12, 0x85]


class TestEncodeDecode:
    def test_normal_pair_roundtrip(self):
        u = jnp.array([1.0, -3.0, 7.0, -7.0])
        out = ovp.ovp_decode_codes(ovp.ovp_encode_codes(u, "int4"), "int4")
        np.testing.assert_array_equal(np.asarray(out), np.asarray(u))

    def test_left_outlier_gets_right_victim(self):
        # pair (20, 1): 20 > 7 is an outlier; 1 becomes the victim (0)
        u = jnp.array([20.0, 1.0])
        codes = np.asarray(ovp.ovp_encode_codes(u, "int4"))
        assert codes[1] == dt.ID4
        out = np.asarray(ovp.ovp_decode_codes(jnp.asarray(codes), "int4"))
        assert out[1] == 0.0
        # 20 is not representable in E2M1+bias2 ({12,16,24,...}); it rounds
        # to 16 (Algorithm 2, base-integer rounding) — tie with 24 in value
        # space, so either neighbour is acceptable.
        assert out[0] in (16.0, 24.0)

    def test_right_outlier_gets_left_victim(self):
        u = jnp.array([1.0, -98.0])
        codes = np.asarray(ovp.ovp_encode_codes(u, "int4"))
        assert codes[0] == dt.ID4
        out = np.asarray(ovp.ovp_decode_codes(jnp.asarray(codes), "int4"))
        assert out[0] == 0.0
        assert out[1] == -96.0  # clipped to abfloat max (bias=2)

    def test_outlier_outlier_keeps_larger(self):
        u = jnp.array([30.0, -50.0])
        out = np.asarray(ovp.ovp_decode_codes(
            ovp.ovp_encode_codes(u, "int4"), "int4"))
        assert out[0] == 0.0          # smaller outlier becomes the victim
        assert out[1] == -48.0        # -50 -> nearest E2M1*4 {…,-48,-64,…}

    def test_exactly_one_nonzero_slot_when_outlier_present(self):
        key = jax.random.PRNGKey(1)
        x = heavy_tailed(key, (4096,))
        s = 3 * jnp.std(x) / 7
        u = x / s
        codes = np.asarray(ovp.ovp_encode_codes(u, "int4"))
        pairs = codes.reshape(-1, 2)
        has_id = (pairs == dt.ID4).any(axis=1)
        both_id = (pairs == dt.ID4).all(axis=1)
        assert not both_id.any(), "a pair can never be two victims"
        # identifier pairs decode with exactly one zero slot
        out = np.asarray(ovp.ovp_decode_codes(jnp.asarray(codes),
                                              "int4")).reshape(-1, 2)
        for i in np.where(has_id)[0][:50]:
            assert (out[i] == 0).sum() >= 1
            assert np.abs(out[i]).max() > 7  # the outlier survived

    @pytest.mark.parametrize("nd", ["int4", "flint4", "int8"])
    def test_decode_error_bounded(self, nd):
        key = jax.random.PRNGKey(2)
        x = heavy_tailed(key, (8192,))
        nmax = dt.NORMAL_MAX[nd]
        s = 3 * jnp.std(x) / nmax
        u = x / s
        out = ovp.ovp_decode_codes(ovp.ovp_encode_codes(u, nd), nd)
        err = np.asarray(jnp.abs(out - u))
        spec = dt.ABFLOAT_FOR_NORMAL[nd]
        # victims can be pruned (err <= nmax there); normals err <= 1;
        # outliers: relative error <= 1/2^mb + clip at max
        a = np.abs(np.asarray(u))
        normal_mask = a <= nmax
        # non-victim normal values: error <= quantization step (1.0 for int)
        step = 4.0 if nd == "flint4" else 0.51  # flint4 widest gap 8 -> /2
        pair_has_outlier = np.repeat(
            (np.abs(np.asarray(u)).reshape(-1, 2) > nmax).any(1), 2)
        ok = normal_mask & ~pair_has_outlier
        assert err[ok].max() <= step

    def test_int8_pairing(self):
        u = jnp.array([300.0, 5.0, -1.0, 2.0])
        codes = np.asarray(ovp.ovp_encode_codes(u, "int8"))
        assert codes[1] == dt.ID8
        out = np.asarray(ovp.ovp_decode_codes(jnp.asarray(codes), "int8"))
        assert out[1] == 0.0 and out[0] > 127
        np.testing.assert_array_equal(out[2:], [-1.0, 2.0])


class TestQuantizedTensor:
    def test_quantize_dequantize_shapes(self):
        key = jax.random.PRNGKey(3)
        x = heavy_tailed(key, (64, 32))
        qt = ovp.ovp_quantize(x, 0.05, "int4", pair_axis=-1)
        assert qt.data.shape == (64, 16)
        assert qt.data.dtype == jnp.uint8
        assert qt.shape == (64, 32)
        xh = ovp.ovp_dequantize(qt)
        assert xh.shape == (64, 32)

    def test_pair_axis_0(self):
        key = jax.random.PRNGKey(4)
        x = heavy_tailed(key, (64, 32))
        qt = ovp.ovp_quantize(x, 0.05, "int4", pair_axis=0)
        assert qt.data.shape == (32, 32)
        xh = ovp.ovp_dequantize(qt)
        assert xh.shape == (64, 32)
        # must agree with pair_axis=-1 on the transposed tensor
        qt2 = ovp.ovp_quantize(x.T, 0.05, "int4", pair_axis=-1)
        xh2 = ovp.ovp_dequantize(qt2)
        np.testing.assert_allclose(np.asarray(xh), np.asarray(xh2).T)

    def test_memory_is_4x_smaller(self):
        x = jax.random.normal(jax.random.PRNGKey(5), (256, 256))
        qt = ovp.ovp_quantize(x, 0.05, "int4")
        assert qt.nbytes() < x.size * 4 / 3.9

    def test_is_pytree(self):
        x = jax.random.normal(jax.random.PRNGKey(6), (16, 16))
        qt = ovp.ovp_quantize(x, 0.05, "int4")
        leaves = jax.tree_util.tree_leaves(qt)
        assert len(leaves) == 2  # data + scale
        # jit through it
        f = jax.jit(lambda q: ovp.ovp_dequantize(q))
        out = f(qt)
        np.testing.assert_allclose(np.asarray(out),
                                   np.asarray(ovp.ovp_dequantize(qt)))

    def test_fake_quant_matches_quant_dequant(self):
        key = jax.random.PRNGKey(7)
        x = heavy_tailed(key, (128, 64))
        fq = ovp.ovp_fake_quant(x, 0.07, "int4")
        qd = ovp.ovp_dequantize(ovp.ovp_quantize(x, 0.07, "int4"))
        np.testing.assert_allclose(np.asarray(fq), np.asarray(qd),
                                   rtol=1e-6, atol=1e-6)


def test_pair_statistics_table2_shape():
    key = jax.random.PRNGKey(8)
    x = heavy_tailed(key, (1 << 16,), outlier_frac=0.005)
    st = ovp.pair_statistics(x)
    assert 0.97 < st["normal_normal"] <= 1.0
    assert st["outlier_outlier"] < 0.005
    total = (st["normal_normal"] + st["outlier_normal"]
             + st["outlier_outlier"])
    assert abs(total - 1.0) < 1e-5
