"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, output shapes + no NaNs; decode-vs-parallel consistency; quantized
(PTQ) serving forward."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config
from repro.core.policy import QuantPolicy
from repro.core.qlinear import quantize_params
from repro.models import build_model

POL = QuantPolicy(compute_dtype="float32")
ALL_ARCHS = sorted(ARCHS)


def make_batch(cfg, key, b=2, t=16):
    ks = jax.random.split(key, 3)
    batch = {"tokens": jax.random.randint(ks[0], (b, t), 0, cfg.vocab)}
    if cfg.frontend == "vit":
        batch["patch_embeds"] = jax.random.normal(
            ks[1], (b, cfg.n_frontend_tokens, cfg.frontend_dim))
    if cfg.frontend == "audio":
        batch["frames"] = jax.random.normal(ks[1], (b, 10, cfg.frontend_dim))
    return batch


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_forward_smoke(arch):
    cfg = get_config(arch).reduced()
    model = build_model(cfg, POL, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg, jax.random.PRNGKey(1))
    logits, _, aux = model.forward(params, batch, mode="train")
    t_exp = batch["tokens"].shape[1] + (cfg.n_frontend_tokens
                                        if cfg.frontend == "vit" else 0)
    assert logits.shape == (2, t_exp, cfg.vocab)
    assert not bool(jnp.any(jnp.isnan(logits)))
    if cfg.n_experts:
        assert float(aux) > 0  # load-balance loss active


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_train_step_smoke(arch):
    """One loss/grad step: finite loss, finite non-zero grads."""
    cfg = get_config(arch).reduced()
    model = build_model(cfg, POL, remat=True)
    params = model.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg, jax.random.PRNGKey(1), b=2, t=8)

    def loss_fn(p):
        logits, _, aux = model.forward(p, batch, mode="train")
        tok = batch["tokens"]
        lg = logits[:, -tok.shape[1]:]  # vlm: skip patch positions
        tgt = jnp.roll(tok, -1, axis=1)
        ll = jax.nn.log_softmax(lg, axis=-1)
        nll = -jnp.take_along_axis(ll, tgt[..., None], axis=-1)[..., 0]
        return jnp.mean(nll[:, :-1]) + 0.01 * aux

    loss, grads = jax.value_and_grad(loss_fn)(params)
    assert np.isfinite(float(loss))
    gmax = max(float(jnp.max(jnp.abs(g)))
               for g in jax.tree_util.tree_leaves(grads))
    assert np.isfinite(gmax) and gmax > 0
    # every param family receives gradient somewhere
    zero_frac = np.mean([float(jnp.all(g == 0))
                         for g in jax.tree_util.tree_leaves(grads)])
    assert zero_frac < 0.5


DECODE_ARCHS = ["minitron-8b", "qwen2-7b", "qwen1.5-0.5b", "yi-6b",
                "recurrentgemma-9b", "xlstm-350m", "internvl2-1b",
                "seamless-m4t-large-v2"]


@pytest.mark.parametrize("arch", DECODE_ARCHS)
def test_decode_matches_parallel_forward(arch):
    cfg = get_config(arch).reduced()
    model = build_model(cfg, POL, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    b, t, p = 2, 12, 8
    batch = make_batch(cfg, jax.random.PRNGKey(1), b=b, t=t)
    full, _, _ = model.forward(params, batch, mode="train")
    off = cfg.n_frontend_tokens if cfg.frontend == "vit" else 0
    enc_len = 10 if cfg.enc_dec else 0
    caches = model.init_caches(b, max_len=t + off, enc_len=enc_len,
                               dtype=jnp.float32)
    pre_batch = dict(batch, tokens=batch["tokens"][:, :p])
    pre, caches, _ = model.forward(params, pre_batch, mode="prefill",
                                   caches=caches)
    errs = [float(jnp.max(jnp.abs(pre[:, -1] - full[:, off + p - 1])))]
    for i in range(p, t):
        pos = jnp.full((b,), off + i, jnp.int32)
        lg, caches, _ = model.forward(
            params, {"tokens": batch["tokens"][:, i:i + 1], "pos": pos},
            mode="decode", caches=caches)
        errs.append(float(jnp.max(jnp.abs(lg[:, 0] - full[:, off + i]))))
    assert max(errs) < 1e-3


def test_moe_decode_matches_parallel_without_drops():
    cfg = dataclasses.replace(get_config("qwen3-moe-30b-a3b").reduced(),
                              capacity_factor=8.0)
    model = build_model(cfg, POL, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    b, t, p = 2, 12, 8
    tokens = jax.random.randint(jax.random.PRNGKey(1), (b, t), 0, cfg.vocab)
    full, _, _ = model.forward(params, {"tokens": tokens}, mode="train")
    caches = model.init_caches(b, max_len=t, dtype=jnp.float32)
    pre, caches, _ = model.forward(params, {"tokens": tokens[:, :p]},
                                   mode="prefill", caches=caches)
    errs = [float(jnp.max(jnp.abs(pre[:, -1] - full[:, p - 1])))]
    for i in range(p, t):
        pos = jnp.full((b,), i, jnp.int32)
        lg, caches, _ = model.forward(
            params, {"tokens": tokens[:, i:i + 1], "pos": pos},
            mode="decode", caches=caches)
        errs.append(float(jnp.max(jnp.abs(lg[:, 0] - full[:, i]))))
    assert max(errs) < 1e-3


@pytest.mark.parametrize("arch", ["qwen1.5-0.5b", "recurrentgemma-9b",
                                  "qwen3-moe-30b-a3b"])
def test_quantized_serving_forward(arch):
    """PTQ the params (OliVe W4) and run prefill+decode: finite outputs,
    logits close-ish to fp (reduced models are noisy; just sanity)."""
    cfg = get_config(arch).reduced()
    fp = build_model(cfg, POL, remat=False)
    params = fp.init(jax.random.PRNGKey(0))
    pol = QuantPolicy(method="olive", wbits=4, abits=0,
                      compute_dtype="float32")
    qparams = quantize_params(params, pol, min_size=1024)
    qm = build_model(cfg, pol, remat=False)
    b, t = 2, 8
    tokens = jax.random.randint(jax.random.PRNGKey(1), (b, t), 0, cfg.vocab)
    caches = qm.init_caches(b, max_len=t + 4, dtype=jnp.float32)
    logits, caches, _ = qm.forward(params=qparams, batch={"tokens": tokens},
                                   mode="prefill", caches=caches)
    assert not bool(jnp.any(jnp.isnan(logits)))
    pos = jnp.full((b,), t, jnp.int32)
    nxt = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    lg2, _, _ = qm.forward(params=qparams, batch={"tokens": nxt, "pos": pos},
                           mode="decode", caches=caches)
    assert lg2.shape == (b, 1, cfg.vocab)
    assert not bool(jnp.any(jnp.isnan(lg2)))


def test_quantized_kv_cache_decode():
    """Beyond-paper OVP KV cache: decode stays close to fp cache decode."""
    cfg = get_config("qwen1.5-0.5b").reduced()
    fp_m = build_model(cfg, POL, remat=False)
    params = fp_m.init(jax.random.PRNGKey(0))
    b, t = 2, 12
    tokens = jax.random.randint(jax.random.PRNGKey(1), (b, t), 0, cfg.vocab)
    kv_pol = dataclasses.replace(POL, method="olive", kv_bits=4, abits=0)
    q_m = build_model(cfg, kv_pol, remat=False)

    def run(model):
        caches = model.init_caches(b, max_len=t + 4, dtype=jnp.float32)
        lg, caches, _ = model.forward(params, {"tokens": tokens[:, :t - 1]},
                                      mode="prefill", caches=caches)
        pos = jnp.full((b,), t - 1, jnp.int32)
        out, _, _ = model.forward(
            params, {"tokens": tokens[:, t - 1:], "pos": pos},
            mode="decode", caches=caches)
        return out

    fp_out = run(fp_m)
    q_out = run(q_m)
    assert not bool(jnp.any(jnp.isnan(q_out)))
    rel = float(jnp.linalg.norm(q_out - fp_out) / jnp.linalg.norm(fp_out))
    # random-init model => near-uniform logits amplify relative error;
    # trained-model KV-quant quality is measured in benchmarks/table9_llm
    assert rel < 0.35

    # memory win: the quantized cache is ~4x smaller
    cfp = fp_m.init_caches(b, max_len=64, dtype=jnp.bfloat16)
    cq = q_m.init_caches(b, max_len=64, dtype=jnp.bfloat16)

    def nbytes(c):
        return sum(x.size * x.dtype.itemsize
                   for x in jax.tree_util.tree_leaves(c))

    assert nbytes(cq) < 0.65 * nbytes(cfp)


def test_config_param_counts_in_range():
    """Sanity: estimated parameter counts are in the advertised ballpark."""
    expect = {"minitron-8b": (7e9, 10e9), "qwen2-7b": (6e9, 9e9),
              "qwen1.5-0.5b": (0.3e9, 0.8e9), "yi-6b": (5e9, 7e9),
              "recurrentgemma-9b": (7e9, 11e9), "xlstm-350m": (2e8, 5e8),
              "qwen3-moe-30b-a3b": (25e9, 35e9),
              "grok-1-314b": (250e9, 350e9),
              "internvl2-1b": (0.4e9, 1.2e9),
              "seamless-m4t-large-v2": (1e9, 2.8e9)}
    for name, (lo, hi) in expect.items():
        n = get_config(name).param_count()
        assert lo <= n <= hi, f"{name}: {n:.3g} not in [{lo:.3g},{hi:.3g}]"
