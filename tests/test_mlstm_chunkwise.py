"""Equivalence of the chunkwise-parallel mLSTM vs the per-token scan
(§Perf iteration X): same outputs, same end state, all chunk sizes,
including ragged T and non-zero initial state (prefill continuation).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.layers import _mlstm_chunkwise, _mlstm_core


def _inputs(key, b=2, t=48, h=2, dh=8, m0=0.0):
    ks = jax.random.split(key, 6)
    q = jax.random.normal(ks[0], (b, t, h, dh))
    k = jax.random.normal(ks[1], (b, t, h, dh))
    v = jax.random.normal(ks[2], (b, t, h, dh))
    i_pre = jax.random.normal(ks[3], (b, t, h)) * 2.0
    f_pre = jax.nn.log_sigmoid(jax.random.normal(ks[4], (b, t, h)) + 2.0)
    state = {"c": jnp.zeros((b, h, dh, dh), jnp.float32),
             "n": jnp.zeros((b, h, dh), jnp.float32),
             "m": jnp.full((b, h), m0, jnp.float32)}
    return q, k, v, i_pre, f_pre, state


@pytest.mark.parametrize("chunk", [1, 4, 16, 48, 64])
def test_matches_step_scan(chunk):
    q, k, v, i_pre, f_pre, st = _inputs(jax.random.PRNGKey(0))
    h_ref, st_ref = _mlstm_core(q, k, v, i_pre, f_pre, st)
    h_ck, st_ck = _mlstm_chunkwise(q, k, v, i_pre, f_pre, st, chunk=chunk)
    np.testing.assert_allclose(np.asarray(h_ck), np.asarray(h_ref),
                               rtol=2e-4, atol=2e-5)
    for key in ("c", "n", "m"):
        np.testing.assert_allclose(np.asarray(st_ck[key]),
                                   np.asarray(st_ref[key]),
                                   rtol=2e-4, atol=2e-5)


@pytest.mark.parametrize("t", [3, 17, 33, 65])
def test_ragged_lengths(t):
    q, k, v, i_pre, f_pre, st = _inputs(jax.random.PRNGKey(1), t=t)
    h_ref, st_ref = _mlstm_core(q, k, v, i_pre, f_pre, st)
    h_ck, st_ck = _mlstm_chunkwise(q, k, v, i_pre, f_pre, st, chunk=16)
    np.testing.assert_allclose(np.asarray(h_ck), np.asarray(h_ref),
                               rtol=2e-4, atol=2e-5)
    np.testing.assert_allclose(np.asarray(st_ck["m"]),
                               np.asarray(st_ref["m"]), rtol=2e-4)


def test_nonzero_initial_state():
    """Prefill continuation: run first half step-wise, second chunkwise."""
    q, k, v, i_pre, f_pre, st = _inputs(jax.random.PRNGKey(2), t=32)
    half = 16
    _, st_mid = _mlstm_core(q[:, :half], k[:, :half], v[:, :half],
                            i_pre[:, :half], f_pre[:, :half], st)
    h_ref, st_ref = _mlstm_core(q[:, half:], k[:, half:], v[:, half:],
                                i_pre[:, half:], f_pre[:, half:], st_mid)
    h_ck, st_ck = _mlstm_chunkwise(q[:, half:], k[:, half:], v[:, half:],
                                   i_pre[:, half:], f_pre[:, half:],
                                   st_mid, chunk=8)
    np.testing.assert_allclose(np.asarray(h_ck), np.asarray(h_ref),
                               rtol=2e-4, atol=2e-5)
    np.testing.assert_allclose(np.asarray(st_ck["c"]),
                               np.asarray(st_ref["c"]), rtol=2e-4,
                               atol=2e-5)


def test_gradients_flow():
    q, k, v, i_pre, f_pre, st = _inputs(jax.random.PRNGKey(3), t=32)

    def loss(q):
        h, _ = _mlstm_chunkwise(q, k, v, i_pre, f_pre, st, chunk=8)
        return jnp.sum(h ** 2)

    g = jax.grad(loss)(q)
    assert np.all(np.isfinite(np.asarray(g)))
    assert float(jnp.max(jnp.abs(g))) > 0