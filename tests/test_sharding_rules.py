"""Unit tests for the sharding rules: divisibility fallbacks, long-context
SP, vocab padding, and spec derivation for representative param shapes.
"""
from __future__ import annotations

import jax
import numpy as np
import pytest
from jax.sharding import AbstractMesh, PartitionSpec as P

from repro.configs import ARCHS, get_config
from repro.sharding.rules import (cache_pspecs, make_rules,
                                  mesh_axis_sizes, param_spec,
                                  params_pspecs)


def make_mesh(shape, axes):
    """Spec derivation only needs axis sizes — AbstractMesh works on one
    CPU device. jax 0.4.x takes ((name, size), ...); newer versions take
    (sizes, names)."""
    try:
        return AbstractMesh(tuple(zip(axes, shape)))
    except TypeError:
        return AbstractMesh(shape, axes)


@pytest.fixture(scope="module")
def mesh22():
    return make_mesh((2, 2), ("data", "model"))


class TestMakeRules:
    def test_divisible_heads_go_model(self, mesh22):
        cfg = get_config("yi-6b")          # 32 heads % 2 == 0
        r = make_rules(cfg, mesh22)
        assert r["heads"] == "model"
        assert r["batch"] == "data"

    def test_nondivisible_falls_back(self, mesh22):
        # qwen2-7b kv=4 divisible by 2; fabricate a 3-head config
        cfg = get_config("qwen2-7b")
        import dataclasses
        odd = dataclasses.replace(cfg, n_heads=7, n_kv_heads=7)
        r = make_rules(odd, mesh22)
        assert r["heads"] is None

    def test_long_context_replicates_batch_shards_seq(self, mesh22):
        cfg = get_config("recurrentgemma-9b")
        r = make_rules(cfg, mesh22, long_context=True)
        assert r["batch"] is None
        assert r["seq"] == "data"

    def test_long_context_multipod_uses_both_axes(self):
        mesh = make_mesh((1, 2, 2), ("pod", "data", "model"))
        cfg = get_config("xlstm-350m")
        r = make_rules(cfg, mesh, long_context=True)
        assert r["batch"] is None
        assert r["seq"] in (("pod", "data"), "pod", "data")

    def test_vocab_uses_padded(self, mesh22):
        cfg = get_config("seamless-m4t-large-v2")   # vocab 256206 -> padded
        assert cfg.padded_vocab % 256 == 0
        r = make_rules(cfg, mesh22)
        assert r["vocab"] == "model"


class TestVocabPadding:
    @pytest.mark.parametrize("arch", sorted(ARCHS))
    def test_all_archs_pad_to_256(self, arch):
        cfg = get_config(arch)
        assert cfg.padded_vocab % 256 == 0
        assert 0 <= cfg.padded_vocab - cfg.vocab < 256

    def test_padded_logits_masked(self):
        """Model with padded vocab must never emit a pad-token argmax."""
        import jax.numpy as jnp
        from repro.configs.base import ArchConfig
        from repro.core.policy import QuantPolicy
        from repro.models.model import build_model
        cfg = ArchConfig(name="padtest", family="dense", n_layers=1,
                         d_model=32, n_heads=2, n_kv_heads=2, d_ff=64,
                         vocab=300, head_dim=16, block_pattern=("attn",))
        assert cfg.padded_vocab == 512
        model = build_model(cfg, QuantPolicy(compute_dtype="float32"),
                            remat=False)
        params = model.init(jax.random.PRNGKey(0))
        toks = jnp.zeros((2, 8), jnp.int32)
        logits, _, _ = model.forward(params, {"tokens": toks},
                                     mode="train")
        assert logits.shape[-1] == 512
        assert np.all(np.asarray(logits[..., 300:]) <= -1e8)


class TestParamSpecs:
    def test_stacked_qkv_spec(self, mesh22):
        cfg = get_config("yi-6b")
        # stacked (G, d_model, H*hd): TP out-dim, FSDP in-dim
        class K:  # fake key path
            def __init__(self, key):
                self.key = key
        spec = param_spec((K("blocks"), K("0"), K("attn"), K("wq")),
                          (16, 4096, 4096), cfg, {"data": 2, "model": 2})
        assert spec == P(None, "data", "model")

    def test_row_parallel_wo(self, mesh22):
        cfg = get_config("yi-6b")
        class K:
            def __init__(self, key):
                self.key = key
        spec = param_spec((K("blocks"), K("0"), K("attn"), K("wo")),
                          (16, 4096, 4096), cfg, {"data": 2, "model": 2})
        assert spec == P(None, "model", "data")

    def test_moe_expert_dim_ep(self, mesh22):
        cfg = get_config("qwen3-moe-30b-a3b")   # 128 experts % 2 == 0
        class K:
            def __init__(self, key):
                self.key = key
        spec = param_spec(
            (K("blocks"), K("0"), K("moe"), K("experts"), K("wg")),
            (12, 128, 2048, 768), cfg, {"data": 2, "model": 2})
        assert spec[1] == "model"   # EP on the expert dim

    def test_norms_replicated(self, mesh22):
        cfg = get_config("yi-6b")
        class K:
            def __init__(self, key):
                self.key = key
        spec = param_spec((K("blocks"), K("0"), K("ln1"),
                           K("gamma_scale")), (16, 4096), cfg,
                          {"data": 2, "model": 2})
        assert spec == P(None, None)


class TestMeshAxisSizes:
    """Regression: `mesh_axis_sizes` used to hide EVERY failure behind a
    bare `except Exception` — a genuinely malformed mesh came back as
    `{}` (silently unsharded). Now only the legacy tuple-shaped
    AbstractMesh case is translated; bad meshes raise."""

    def test_real_mesh(self, forced_devices):
        from jax.sharding import Mesh
        mesh = Mesh(np.asarray(forced_devices[:8]).reshape(4, 2),
                    ("data", "model"))
        assert mesh_axis_sizes(mesh) == {"data": 4, "model": 2}

    def test_abstract_mesh(self, mesh22):
        assert mesh_axis_sizes(mesh22) == {"data": 2, "model": 2}

    def test_legacy_tuple_shape(self):
        class Legacy:
            shape = (4, 2)
            axis_names = ("data", "model")
        assert mesh_axis_sizes(Legacy()) == {"data": 4, "model": 2}

    def test_mismatched_lengths_raise(self):
        class Bad:
            shape = (4, 2, 1)
            axis_names = ("data", "model")
        with pytest.raises(ValueError, match="do not match"):
            mesh_axis_sizes(Bad())

    def test_shapeless_object_raises(self):
        with pytest.raises(AttributeError):
            mesh_axis_sizes(object())


class TestCachePSpecs:
    def test_kv_cache_spec_decode(self, mesh22):
        from repro.core.policy import QuantPolicy
        from repro.models.model import build_model
        cfg = get_config("yi-6b").reduced()
        model = build_model(cfg, QuantPolicy())
        caches = jax.eval_shape(lambda: model.init_caches(8, 64))
        specs = cache_pspecs(caches, cfg, mesh22)
        flat = jax.tree_util.tree_leaves(
            specs, is_leaf=lambda x: isinstance(x, P))
        assert all(isinstance(s, P) for s in flat)

    def test_kv_cache_long_context_seq_sharded(self):
        mesh = make_mesh((2, 2), ("data", "model"))
        from repro.core.policy import QuantPolicy
        from repro.models.model import build_model
        cfg = get_config("recurrentgemma-9b").reduced()
        model = build_model(cfg, QuantPolicy())
        caches = jax.eval_shape(lambda: model.init_caches(1, 64))
        specs = cache_pspecs(caches, cfg, mesh, long_context=True)

        def kv_specs(specs, caches):
            flat_s = jax.tree_util.tree_flatten_with_path(
                specs, is_leaf=lambda x: isinstance(x, P))[0]
            return {("/".join(str(getattr(k, "key", k)) for k in kp)): s
                    for kp, s in flat_s}

        m = kv_specs(specs, caches)
        kv = {k: v for k, v in m.items() if k.endswith("/k")}
        assert kv, "expected kv leaves"
        for k, s in kv.items():
            # batch dim replicated, seq dim sharded over data
            assert "data" in jax.tree_util.tree_leaves(s) or \
                s[-3] == "data"
