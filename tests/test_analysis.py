"""repro.analysis: the live repo passes every static pass clean, and
each seeded-violation fixture (tests/fixtures/analysis/) fails exactly
its pass. Plus the REPRO_SANITIZE=1 runtime hooks."""
from __future__ import annotations

from pathlib import Path

import jax.numpy as jnp
import pytest

from repro import analysis

FIX = Path(__file__).parent / "fixtures" / "analysis"


def _codes(findings):
    return {f.code for f in findings}


# ---------------------------------------------------------------- clean repo
def test_vocab_pass_clean():
    assert analysis.run_pass("vocab") == []


def test_hygiene_pass_clean():
    assert analysis.run_pass("hygiene") == []


def test_kernel_pass_clean():
    assert analysis.run_pass("kernels") == []


def test_policy_pass_clean():
    assert analysis.run_pass("policies") == []


def test_unknown_pass_rejected():
    with pytest.raises(KeyError):
        analysis.run_pass("nope")


# ------------------------------------------------------- seeded violations
def test_unregistered_decline_code_flagged():
    found = analysis.run_pass("vocab",
                              fixtures=(str(FIX / "bad_vocab.py"),))
    assert "VOCAB_UNREGISTERED_CODE" in _codes(found)
    assert any("decode_q_rank_bad" in f.message for f in found)


def test_pair_misaligned_k_split_flagged():
    found = analysis.run_pass("kernels",
                              fixtures=(str(FIX / "bad_pair_split.py"),))
    assert "KC_PAIR_SPLIT" in _codes(found)


def test_undeclared_aliasing_flagged():
    found = analysis.run_pass("kernels",
                              fixtures=(str(FIX / "bad_aliasing.py"),))
    assert "KC_ALIAS_MISSING" in _codes(found)


def test_dead_and_shadowed_policy_rules_flagged():
    found = analysis.run_pass("policies",
                              fixtures=(str(FIX / "bad_policy.py"),))
    codes = _codes(found)
    assert "POL_DEAD_RULE" in codes      # *conv_stem* matches no site
    assert "POL_SHADOWED" in codes       # *attn/wq* behind *attn*
    assert "POL_DEAD_GLOB" in codes      # dead calibration scale key


def test_broad_except_flagged():
    found = analysis.run_pass("hygiene",
                              fixtures=(str(FIX / "bad_hygiene.py"),))
    assert "HYG_BROAD_EXCEPT" in _codes(found)


def test_vmem_budget_enforced():
    # an absurdly small budget must trip every traced kernel
    found = analysis.run_pass("kernels", vmem_budget=64)
    assert "KC_VMEM_BUDGET" in _codes(found)


# ------------------------------------------------------------- sanitizer
def test_sanitize_disabled_is_noop(monkeypatch):
    monkeypatch.delenv("REPRO_SANITIZE", raising=False)
    from repro.analysis import sanitize
    assert not sanitize.enabled()
    sanitize.check(False, "never raises when disabled")


def test_sanitize_eager_check_raises(monkeypatch):
    monkeypatch.setenv("REPRO_SANITIZE", "1")
    from repro.analysis import sanitize
    sanitize.check(True, "fine")
    with pytest.raises(AssertionError, match="boom"):
        sanitize.check(False, "boom")


def test_sanitize_jit_checked_throws_on_failed_check(monkeypatch):
    monkeypatch.setenv("REPRO_SANITIZE", "1")
    from repro.analysis import sanitize

    def f(x):
        sanitize.check(jnp.all(x > 0), "non-positive input")
        return x * 2

    g = sanitize.jit_checked(f)
    assert (g(jnp.ones(3)) == 2).all()
    with pytest.raises(Exception, match="non-positive input"):
        g(-jnp.ones(3))


def test_sanitize_ovp_encode_rejects_nonfinite(monkeypatch):
    monkeypatch.setenv("REPRO_SANITIZE", "1")
    from repro.core import ovp
    ovp.ovp_encode_codes(jnp.zeros((2, 4)))      # clean input passes
    with pytest.raises(AssertionError, match="non-finite"):
        ovp.ovp_encode_codes(jnp.full((2, 4), jnp.nan))


def test_sanitize_ovp_decode_rejects_double_identifier(monkeypatch):
    monkeypatch.setenv("REPRO_SANITIZE", "1")
    from repro.core import ovp
    from repro.core.datatypes import ID4
    bad = jnp.full((2, 4), ID4, jnp.uint8)       # every pair double-ident
    with pytest.raises(AssertionError, match="identifier"):
        ovp.ovp_decode_codes(bad)


def test_trace_audit_flags_unexpected_retrace():
    from repro.analysis import sanitize

    class FakeEngine:
        def trace_audit(self):
            return {"prefill_traces": 3, "prefill_jits": 1,
                    "decode_traces": 1, "unexpected_retraces": 2}

    with pytest.raises(AssertionError, match="retraces"):
        sanitize.audit_traces(FakeEngine())
