import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import datatypes as dt


class TestNormalInt:
    def test_int4_never_emits_identifier(self):
        u = jnp.linspace(-40, 40, 1001)
        codes = dt.int_normal_encode(u, 4)
        assert not np.any(np.asarray(codes) == dt.ID4)

    def test_int8_never_emits_identifier(self):
        u = jnp.linspace(-300, 300, 2001)
        codes = dt.int_normal_encode(u, 8)
        assert not np.any(np.asarray(codes) == dt.ID8)

    def test_int4_roundtrip_exact_on_grid(self):
        vals = jnp.arange(-7, 8).astype(jnp.float32)
        out = dt.int_normal_decode(dt.int_normal_encode(vals, 4), 4)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(vals))

    def test_int8_roundtrip_exact_on_grid(self):
        vals = jnp.arange(-127, 128).astype(jnp.float32)
        out = dt.int_normal_decode(dt.int_normal_encode(vals, 8), 8)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(vals))

    def test_int4_clips_to_pm7(self):
        out = dt.int_normal_decode(dt.int_normal_encode(
            jnp.array([-100.0, 100.0]), 4), 4)
        np.testing.assert_array_equal(np.asarray(out), [-7.0, 7.0])


class TestFlint4:
    def test_value_set_matches_table3(self):
        # Table 3: 0, ±1, ±2, ±3, ±4, ±6, ±8, ±16
        grid = jnp.linspace(-20, 20, 4001)
        out = np.unique(np.asarray(dt.flint4_decode(dt.flint4_encode(grid))))
        expect = sorted({s * v for v in [0, 1, 2, 3, 4, 6, 8, 16]
                         for s in (-1, 1)})
        assert set(out.tolist()) <= set(expect)
        assert {0., 1., -1., 16., -16., 6., -6.} <= set(out.tolist())

    def test_never_emits_identifier(self):
        grid = jnp.linspace(-100, 100, 4001)
        codes = np.asarray(dt.flint4_encode(grid))
        assert not np.any(codes == dt.ID4)

    def test_identifier_decodes_to_zero(self):
        out = dt.flint4_decode(jnp.array([dt.ID4], dtype=jnp.uint8))
        assert float(out[0]) == 0.0

    def test_nearest(self):
        out = dt.flint4_decode(dt.flint4_encode(jnp.array([5.1, 6.9, 11.0])))
        np.testing.assert_array_equal(np.asarray(out), [6.0, 6.0, 8.0])


class TestAbfloat:
    def test_paper_biases(self):
        # §3.3: bias=2 for int4 ({12..96}), bias=3 for flint4 ({24..192})
        assert dt.E2M1_INT4.bias == 2
        assert dt.E2M1_INT4.min_mag == 12 and dt.E2M1_INT4.max_mag == 96
        assert dt.E2M1_FLINT4.bias == 3
        assert dt.E2M1_FLINT4.min_mag == 24 and dt.E2M1_FLINT4.max_mag == 192
        # 8-bit: E4M3, min just past 127, clipped at 2^15 (§4.5)
        assert dt.E4M3_INT8.min_mag == 144
        assert dt.E4M3_INT8.max_mag == 1 << 15

    def test_table4_values(self):
        # Table 4 with bias=0: magnitudes {3,4,6,8,12,16,24}
        spec = dt.AbfloatSpec(ebits=2, mb=1, bias=0)
        np.testing.assert_array_equal(spec.magnitudes(),
                                      [3, 4, 6, 8, 12, 16, 24])

    def test_fig7_example(self):
        # Fig. 7: bias=2, code 0101b -> 48
        spec = dt.AbfloatSpec(ebits=2, mb=1, bias=2)
        out = dt.abfloat_decode(jnp.array([0b0101], dtype=jnp.uint8), spec)
        assert float(out[0]) == 48.0

    @pytest.mark.parametrize("spec", [dt.E2M1_INT4, dt.E2M1_FLINT4,
                                      dt.E4M3_INT8])
    def test_roundtrip_exact_on_representables(self, spec):
        mags = spec.magnitudes()
        vals = jnp.concatenate([jnp.asarray(mags), -jnp.asarray(mags)])
        out = dt.abfloat_decode(dt.abfloat_encode(vals, spec), spec)
        np.testing.assert_allclose(np.asarray(out), np.asarray(vals))

    @pytest.mark.parametrize("spec", [dt.E2M1_INT4, dt.E4M3_INT8])
    def test_never_emits_disabled_codes(self, spec):
        vals = jnp.linspace(-4e4, 4e4, 20001)
        codes = np.asarray(dt.abfloat_encode(vals, spec))
        bits_mask = (1 << (spec.ebits + spec.mb)) - 1
        assert not np.any((codes & bits_mask) == 0), \
            "abfloat must never produce ±0 (identifier conflict, §3.3)"

    @pytest.mark.parametrize("spec", [dt.E2M1_INT4, dt.E2M1_FLINT4,
                                      dt.E4M3_INT8])
    def test_algorithm2_close_to_nearest(self, spec):
        vals = jnp.linspace(spec.min_mag, spec.max_mag, 3001)
        alg = dt.abfloat_decode(dt.abfloat_encode(vals, spec), spec)
        near = dt.abfloat_nearest(vals, spec)
        # Algorithm 2 rounds in base-integer space; it must be within one
        # representable step of true nearest everywhere.
        mags = spec.magnitudes()
        steps = np.diff(mags).max()
        assert np.max(np.abs(np.asarray(alg) - np.asarray(near))) <= steps

    def test_monotone(self):
        vals = jnp.linspace(12, 96, 500)
        out = np.asarray(dt.abfloat_decode(
            dt.abfloat_encode(vals, dt.E2M1_INT4), dt.E2M1_INT4))
        assert np.all(np.diff(out) >= 0)

    def test_sign_symmetry(self):
        vals = jnp.linspace(12, 96, 100)
        spec = dt.E2M1_INT4
        pos = dt.abfloat_decode(dt.abfloat_encode(vals, spec), spec)
        neg = dt.abfloat_decode(dt.abfloat_encode(-vals, spec), spec)
        np.testing.assert_allclose(np.asarray(pos), -np.asarray(neg))


def test_default_bias_rule():
    # bias = smallest b with (2^mb + 1) << b > normal max
    assert dt.default_bias("int4", 1) == 2
    assert dt.default_bias("flint4", 1) == 3
    assert dt.default_bias("int8", 3) == 4
