"""Seeded violation: a decline function returning an unregistered code.

`repro.analysis`'s vocabulary pass must flag VOCAB_UNREGISTERED_CODE on
this file; see tests/test_analysis.py.
"""


def decode_attn_decline(q, cache):
    if q is None:
        return "decode_q_rank_bad"   # not in backends.base.DECLINE_CODES
    return None
