"""Seeded violation: a broad exception handler that swallows failures.

The hygiene pass must flag HYG_BROAD_EXCEPT on this file.
"""


def swallow(fn):
    try:
        return fn()
    except Exception:
        return None
