"""Seeded violations for the policy pass: a rule glob no arch's param
tree can match (POL_DEAD_RULE), a rule an earlier rule always wins over
(POL_SHADOWED), and a calibration scale key matching no site
(POL_DEAD_GLOB).
"""


def analysis_programs():
    from repro.core.policy import (OLIVE_W4A4, OLIVE_W8A8, PolicyProgram,
                                   Rule)
    prog = PolicyProgram(
        rules=(Rule("*conv_stem*", OLIVE_W8A8),        # dead: no such site
               Rule("*attn*", OLIVE_W8A8),
               Rule("*attn/wq*", OLIVE_W4A4)),         # shadowed by *attn*
        default=OLIVE_W4A4, name="bad_policy")
    return [("bad_policy", prog)]


def analysis_artifacts():
    return [("bad_artifact", {"layers/*/conv_stem/w": 0.5})]
