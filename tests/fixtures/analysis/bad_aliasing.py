"""Seeded violation: a kernel that rewrites a pool operand without
declaring input_output_aliases.

Pages no grid step touches would come back uninitialized instead of
intact — the kernel pass must flag KC_ALIAS_MISSING.
"""


def analysis_cases():
    import jax
    import jax.numpy as jnp
    from jax.experimental import pallas as pl

    def build():
        pool = jnp.zeros((4, 8, 16), jnp.uint8)

        def kernel(p_ref, o_ref):
            o_ref[...] = p_ref[...] + 1

        def fn(pool):
            # writes the pool back out, but with no aliasing declared
            return pl.pallas_call(
                kernel,
                grid=(4,),
                in_specs=[pl.BlockSpec((1, 8, 16), lambda i: (i, 0, 0))],
                out_specs=pl.BlockSpec((1, 8, 16), lambda i: (i, 0, 0)),
                out_shape=jax.ShapeDtypeStruct((4, 8, 16), jnp.uint8),
                interpret=True)(pool)
        return fn, (pool,)

    return [{"name": "bad_aliasing", "build": build, "min_aliases": 1}]
