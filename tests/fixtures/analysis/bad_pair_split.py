"""Seeded violation: an int8-codes weight tiled with an odd K block.

Each int8 row is one value, so a 3-row K tile holds one and a half
outlier-victim pairs — the kernel pass must flag KC_PAIR_SPLIT.
"""


def analysis_cases():
    import jax
    import jax.numpy as jnp
    from jax.experimental import pallas as pl

    def build():
        w = jnp.zeros((96, 64), jnp.uint8)

        def kernel(w_ref, o_ref):
            o_ref[...] = w_ref[...].astype(jnp.float32)

        def fn(w):
            return pl.pallas_call(
                kernel,
                grid=(96 // 3,),
                in_specs=[pl.BlockSpec((3, 64), lambda k: (k, 0))],
                out_specs=pl.BlockSpec((3, 64), lambda k: (k, 0)),
                out_shape=jax.ShapeDtypeStruct((96, 64), jnp.float32),
                interpret=True)(w)
        return fn, (w,)

    return [{"name": "bad_pair_split", "build": build,
             "pair_blocks": (((96, 64), 0, 1),)}]
