"""8-device sharded-backend parity suite (ISSUE 9's headline proof).

Runs on 8 forced host CPU devices (conftest.py sets
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` before jax
imports) and pins the `pallas_sharded_interpret` contracts from
docs/sharding.md:

- TP column-parallel 2-D matmul is BIT-identical to the single-device
  fused kernel across int4 weight-only, flint4 W4A4, and W4A8 — the
  packed codes shard along N without re-encoding;
- TP row-parallel (`wo`/`wd` sites) splits K in whole outlier-victim
  pairs and psums — equal up to fp32 reassociation only;
- EP splits the grouped kernel's expert grid dim — bit-identical;
- Hkv-sharded decode AND paged cache-write prefill attention are
  bit-identical, including every written pool byte;
- a quantized paged ENGINE run on the sharded backend is
  token-for-token identical to the single-device engine, with ZERO
  sharded-path fallbacks and a per-device pool footprint of 1/tp;
- unshardable layouts decline with the machine-readable `shard_*`
  codes tabled in backends/base.py and fall back to the dense path.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import backends
from repro.backends import configure_mesh
from repro.configs.base import ArchConfig
from repro.core.policy import QuantPolicy
from repro.core.qlinear import _quantize_mixed_experts, quantize_params, \
    quantize_weight
from repro.models import layers as L
from repro.models.model import build_model
from repro.runtime.elastic import MeshPlan
from repro.serve.engine import EngineCfg, ServingEngine
from repro.serve.paging import PagePoolCfg

KB = "pallas_interpret"          # single-device reference twin
SB = "pallas_sharded_interpret"  # backend under test

PLAN42 = MeshPlan(shape=(4, 2), axis_names=("data", "model"),
                  dropped_devices=0)


def _pol(**kw):
    base = dict(method="olive", wbits=4, abits=0,
                compute_dtype="float32", backend=SB)
    base.update(kw)
    return QuantPolicy(**base)


# weight/activation precision grid the parity tests sweep
CASES = {
    "int4_weight_only": dict(),
    "flint4_w4a4": dict(abits=4, w_normal_dtype="flint4",
                        a_normal_dtype="flint4"),
    "w4a8": dict(abits=8),
}


@pytest.fixture()
def mesh42(forced_devices):
    """(data=4, model=2) mesh over the 8 forced devices; stats reset so
    every test asserts its own dispatch ledger; mesh cleared on exit so
    no other module ever sees sharded state."""
    mesh = configure_mesh(PLAN42)
    backends.reset_dispatch_stats()
    yield mesh
    configure_mesh(None)


def _assert_no_shard_fallbacks():
    bad = {k: v for k, v in backends.dispatch_stats().items()
           if "->fallback:shard" in k}
    assert not bad, f"sharded path fell back: {bad}"


def _served(suffix=""):
    return backends.dispatch_stats().get(f"{SB}{suffix}", 0)


# ------------------------------------------------------------------ registry
def test_sharded_backends_registered():
    avail = backends.available()
    assert "pallas_sharded" in avail
    assert "pallas_sharded_interpret" in avail


# ------------------------------------------------------------ 2-D TP matmul
@pytest.mark.parametrize("case", sorted(CASES))
def test_col_parallel_bit_identical(mesh42, case):
    pol = _pol(**CASES[case])
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.standard_normal((4, 64)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((64, 128)), jnp.float32)
    wq = quantize_weight(w, pol)
    y = backends.dispatch(x, wq, pol, site="blocks/0/attn/wq")
    ref = backends.dispatch(x, wq, pol.with_backend(KB),
                            site="blocks/0/attn/wq")
    assert _served() == 1
    _assert_no_shard_fallbacks()
    # no-collective column split: outputs must be BIT-identical
    assert np.array_equal(np.asarray(y), np.asarray(ref))


@pytest.mark.parametrize("case", sorted(CASES))
def test_row_parallel_psum_close(mesh42, case):
    pol = _pol(**CASES[case])
    rng = np.random.default_rng(4)
    x = jnp.asarray(rng.standard_normal((4, 128)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((128, 64)), jnp.float32)
    wq = quantize_weight(w, pol)
    y = backends.dispatch(x, wq, pol, site="blocks/0/attn/wo")
    ref = backends.dispatch(x, wq, pol.with_backend(KB),
                            site="blocks/0/attn/wo")
    assert _served() == 1
    _assert_no_shard_fallbacks()
    # the psum reassociates the fp32 K-sum: allclose, not array_equal
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_expert_parallel_bit_identical(mesh42):
    pol = _pol()
    rng = np.random.default_rng(5)
    xg = jnp.asarray(rng.standard_normal((4, 3, 64)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((4, 64, 128)), jnp.float32)
    wq = quantize_weight(w, pol)
    y = backends.dispatch(xg, wq, pol, site="blocks/0/moe/experts/wg")
    ref = backends.dispatch(xg, wq, pol.with_backend(KB),
                            site="blocks/0/moe/experts/wg")
    assert _served("[stacked]") == 1
    _assert_no_shard_fallbacks()
    assert np.array_equal(np.asarray(y), np.asarray(ref))


# --------------------------------------------------- Hkv-sharded attention
def _packed_slab(rng, b, s, hkv, d):
    cache = L.make_kv_cache(b, s, hkv, d, kv_bits=4)
    return {
        "k_data": jnp.asarray(
            rng.integers(0, 256, size=cache["k_data"].shape), jnp.uint8),
        "v_data": jnp.asarray(
            rng.integers(0, 256, size=cache["v_data"].shape), jnp.uint8),
        "k_scl": jnp.asarray(
            rng.uniform(0.05, 0.4, size=cache["k_scl"].shape),
            jnp.float32),
        "v_scl": jnp.asarray(
            rng.uniform(0.05, 0.4, size=cache["v_scl"].shape),
            jnp.float32),
    }


def _fill_pool(rng, cache):
    out = dict(cache)
    for name in ("k_data", "v_data"):
        out[name] = jnp.asarray(
            rng.integers(0, 256, size=cache[name].shape), jnp.uint8)
    for name in ("k_scl", "v_scl"):
        out[name] = jnp.asarray(
            rng.uniform(0.05, 0.4, size=cache[name].shape), jnp.float32)
    return out


def test_decode_attention_slab_bit_identical(mesh42):
    rng = np.random.default_rng(6)
    pol = _pol(kv_bits=4)
    cache = _packed_slab(rng, b=2, s=32, hkv=4, d=16)
    q = jnp.asarray(rng.standard_normal((2, 1, 8, 16)), jnp.float32)
    pos = jnp.asarray([5, 17], jnp.int32)
    y = backends.decode_attention(q, cache, pos, policy=pol)
    ref = backends.decode_attention(q, cache, pos,
                                    policy=pol.with_backend(KB))
    assert _served("[decode_attn]") == 1
    _assert_no_shard_fallbacks()
    # per-head attention: the Hkv shard changes nothing, bit for bit
    assert np.array_equal(np.asarray(y), np.asarray(ref))


def test_decode_attention_paged_bit_identical(mesh42):
    rng = np.random.default_rng(7)
    pol = _pol(kv_bits=4)
    cache = _fill_pool(rng, L.make_paged_kv_cache(
        8, 8, batch_slots=2, pages_per_row=2, n_kv=4, head_dim=16,
        kv_bits=4))
    cache["block_table"] = jnp.asarray([[1, 4], [2, 6]], jnp.int32)
    q = jnp.asarray(rng.standard_normal((2, 1, 8, 16)), jnp.float32)
    pos = jnp.asarray([5, 11], jnp.int32)
    y = backends.decode_attention(q, cache, pos, policy=pol)
    ref = backends.decode_attention(q, cache, pos,
                                    policy=pol.with_backend(KB))
    assert _served("[decode_attn]") == 1
    _assert_no_shard_fallbacks()
    assert np.array_equal(np.asarray(y), np.asarray(ref))


def test_prefill_attention_paged_bit_identical(mesh42):
    rng = np.random.default_rng(8)
    pol = _pol(kv_bits=4)
    cache = L.make_paged_kv_cache(8, 8, batch_slots=1, pages_per_row=2,
                                  n_kv=4, head_dim=16, kv_bits=4)
    cache["block_table"] = jnp.asarray([[3, 5]], jnp.int32)
    cache["stage_k"] = jnp.asarray(
        rng.standard_normal((1, 16, 4, 16)), jnp.float32)
    cache["stage_v"] = jnp.asarray(
        rng.standard_normal((1, 16, 4, 16)), jnp.float32)
    q = jnp.asarray(rng.standard_normal((1, 8, 8, 16)), jnp.float32)
    positions = jnp.arange(8, 16, dtype=jnp.int32)[None]
    y, new = backends.prefill_attention(q, cache, positions, policy=pol)
    ref_y, ref_new = backends.prefill_attention(
        q, cache, positions, policy=pol.with_backend(KB))
    assert _served("[prefill_attn]") == 1
    _assert_no_shard_fallbacks()
    assert np.array_equal(np.asarray(y), np.asarray(ref_y))
    # the fused quantize-and-write must land identical PAGE BYTES too
    for name in ("k_data", "v_data", "k_scl", "v_scl"):
        assert np.array_equal(np.asarray(new[name]),
                              np.asarray(ref_new[name])), name


# ------------------------------------------------------------- engine runs
TINY = ArchConfig(name="shard-tiny", family="dense", n_layers=2,
                  d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
                  vocab=256, head_dim=16, block_pattern=("attn",))


def _drain(model, params, backend, mesh=None):
    eng = ServingEngine(model, params, EngineCfg(
        batch_slots=2, max_len=64, backend=backend,
        page_pool=PagePoolCfg(page_size=16), prefill_chunk=16,
        mesh=mesh))
    rng = np.random.default_rng(2)
    for n, mn in zip((5, 9, 40), (4, 3, 5)):
        eng.submit(rng.integers(0, TINY.vocab, size=n).astype(np.int32),
                   max_new_tokens=mn)
    done = eng.run_until_drained()
    return eng, {r.uid: list(r.out_tokens) for r in done}


def test_engine_token_parity_sharded_vs_single(forced_devices):
    """Quantized (W4 + packed-KV4) paged+chunked serving on the sharded
    backend over a (4, 2) mesh, token-for-token vs single device."""
    pol = _pol(kv_bits=4, backend=KB)
    model = build_model(TINY, pol, remat=False)
    params = quantize_params(model.init(jax.random.PRNGKey(1),
                                        dtype=jnp.float32), pol)
    try:
        _, ref = _drain(model, params, KB)
        backends.reset_dispatch_stats()
        eng, outs = _drain(model, params, SB, mesh=PLAN42)
        stats = backends.dispatch_stats()
        # every matmul + both attention paths served sharded, zero falls
        assert any(k.startswith(SB) for k in stats), stats
        _assert_no_shard_fallbacks()
        assert outs == ref
        dps = eng.device_pool_stats()
        assert dps["n_devices"] == 2
        assert dps["pool_bytes_per_device"] * 2 == dps["pool_bytes_total"]
        assert len(dps["occupancy_per_device"]) == 2
    finally:
        configure_mesh(None)


def test_engine_cfg_without_mesh_falls_back_densely(forced_devices):
    """No mesh configured: the sharded backend declines every call with
    `shard_no_mesh` and the dense fallback still serves correct tokens."""
    configure_mesh(None)
    pol = _pol(kv_bits=4, backend=KB)
    model = build_model(TINY, pol, remat=False)
    params = quantize_params(model.init(jax.random.PRNGKey(1),
                                        dtype=jnp.float32), pol)
    _, ref = _drain(model, params, KB)
    backends.reset_dispatch_stats()
    _, outs = _drain(model, params, SB)   # mesh=None on purpose
    stats = backends.dispatch_stats()
    assert any("->fallback:shard_no_mesh" in k for k in stats), stats
    assert outs == ref


# ------------------------------------------------------ decline vocabulary
def test_decline_no_mesh(forced_devices):
    configure_mesh(None)
    pol = _pol()
    wq = quantize_weight(jnp.ones((64, 128), jnp.float32), pol)
    b = backends.get_backend(SB)
    assert b.decline_reason(jnp.ones((4, 64)), wq, pol,
                            site="blocks/0/attn/wq") == "shard_no_mesh"


@pytest.mark.parametrize("shape,site,code", [
    ((64, 65), "blocks/0/attn/wq", "shard_n_indivisible"),
    ((66, 64), "blocks/0/attn/wo", "shard_k_indivisible"),
])
def test_decline_tp_indivisible(mesh42, shape, site, code):
    pol = _pol()
    wq = quantize_weight(jnp.ones(shape, jnp.float32), pol)
    b = backends.get_backend(SB)
    x = jnp.ones((4, shape[0]), jnp.float32)
    assert b.decline_reason(x, wq, pol, site=site) == code


def test_decline_k_pair_straddle_int8(mesh42):
    """int8 codes are UNPACKED (two rows per outlier-victim pair): a K
    split must keep whole pairs, so rows % (tp * 2) gates the row path."""
    pol = _pol(wbits=8)
    b = backends.get_backend(SB)
    # 70 rows: divisible by tp=2 but 70 % (2*2) != 0 — a shard boundary
    # would cut a pair in half
    wq = quantize_weight(jnp.ones((70, 64), jnp.float32), pol)
    assert wq.data.shape[0] == 70           # unpacked: one row per value
    x = jnp.ones((4, 70), jnp.float32)
    assert b.decline_reason(x, wq, pol, site="blocks/0/attn/wo") \
        == "shard_k_indivisible"
    # 72 rows = 36 whole pairs per shard boundary: serves
    wq = quantize_weight(jnp.ones((72, 64), jnp.float32), pol)
    x = jnp.ones((4, 72), jnp.float32)
    assert b.decline_reason(x, wq, pol, site="blocks/0/attn/wo") is None


def test_decline_expert_indivisible(mesh42):
    pol = _pol()
    wq = quantize_weight(jnp.ones((3, 64, 128), jnp.float32), pol)
    b = backends.get_backend(SB)
    xg = jnp.ones((3, 2, 64), jnp.float32)
    assert b.decline_reason(xg, wq, pol, site="blocks/0/moe/experts/wg") \
        == "shard_expert_indivisible"


def test_decline_hkv(mesh42):
    rng = np.random.default_rng(9)
    b = backends.get_backend(SB)
    q1 = jnp.ones((2, 1, 4, 16), jnp.float32)
    assert b.decode_attn_decline_reason(
        q1, _packed_slab(rng, 2, 32, hkv=1, d=16)) == "shard_hkv_lt_axis"
    q3 = jnp.ones((2, 1, 6, 16), jnp.float32)
    assert b.decode_attn_decline_reason(
        q3, _packed_slab(rng, 2, 32, hkv=3, d=16)) \
        == "shard_hkv_indivisible"


def test_mixed_expert_group_declines_whole(mesh42):
    """Ragged per-expert precision groups decline in one piece and the
    dense fallback output matches the xla backend exactly."""
    pol = _pol()
    w = jnp.asarray(np.random.default_rng(10)
                    .standard_normal((4, 64, 128)), jnp.float32)
    mixed = _quantize_mixed_experts(
        w, [pol, pol, _pol(wbits=8), _pol(wbits=8)])
    xg = jnp.asarray(np.random.default_rng(11)
                     .standard_normal((4, 3, 64)), jnp.float32)
    y = backends.dispatch(xg, mixed, pol, site="blocks/0/moe/experts/wg")
    stats = backends.dispatch_stats()
    assert any("->fallback:shard_mixed_expert_group" in k
               for k in stats), stats
    ref = backends.dispatch(xg, mixed, pol.with_backend("xla"),
                            site="blocks/0/moe/experts/wg")
    assert np.array_equal(np.asarray(y), np.asarray(ref))
