"""Roofline HLO statistics: trip-count-aware walker vs ground truth."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.roofline import hlo_stats
from repro.roofline.analysis import collective_bytes, count_collectives


def _compile_text(f, *sds, **jit_kw):
    return jax.jit(f, **jit_kw).lower(*sds).compile().as_text()


class TestFlops:
    def test_plain_matmul(self):
        m = k = n = 128
        txt = _compile_text(lambda a, b: a @ b,
                            jax.ShapeDtypeStruct((m, k), jnp.float32),
                            jax.ShapeDtypeStruct((k, n), jnp.float32))
        st = hlo_stats.analyze_hlo(txt)
        assert st.flops == pytest.approx(2 * m * k * n, rel=0.01)

    def test_scan_multiplies_by_trip_count(self):
        L = 12

        def f_scan(x):
            return jax.lax.scan(lambda c, _: (c @ c, None), x, None,
                                length=L)[0]

        def f_unroll(x):
            for _ in range(L):
                x = x @ x
            return x

        sd = jax.ShapeDtypeStruct((128, 128), jnp.float32)
        st_s = hlo_stats.analyze_hlo(_compile_text(f_scan, sd))
        st_u = hlo_stats.analyze_hlo(_compile_text(f_unroll, sd))
        assert st_s.flops == pytest.approx(st_u.flops, rel=0.02)
        assert st_s.flops == pytest.approx(L * 2 * 128 ** 3, rel=0.02)
        # and matches XLA's own count for the unrolled version
        # (cost_analysis returns a per-device list on newer jax)
        ca = jax.jit(f_unroll).lower(sd).compile().cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0]
        assert st_u.flops == pytest.approx(ca["flops"], rel=0.05)

    def test_nested_scans(self):
        def f(x):
            def outer(c, _):
                def inner(ci, _):
                    return ci @ ci, None
                return jax.lax.scan(inner, c, None, length=3)[0], None
            return jax.lax.scan(outer, x, None, length=5)[0]

        sd = jax.ShapeDtypeStruct((64, 64), jnp.float32)
        st = hlo_stats.analyze_hlo(_compile_text(f, sd))
        assert st.flops == pytest.approx(15 * 2 * 64 ** 3, rel=0.02)

    def test_bytes_scale_with_scan(self):
        def f_scan(x):
            return jax.lax.scan(lambda c, _: (jnp.tanh(c), None), x, None,
                                length=10)[0]

        sd = jax.ShapeDtypeStruct((256, 256), jnp.float32)
        st = hlo_stats.analyze_hlo(_compile_text(f_scan, sd))
        one_pass = 2 * 256 * 256 * 4
        assert st.bytes >= 10 * one_pass * 0.8


class TestCollectives:
    """Collective analysis on REAL multi-device HLO: conftest forces 8
    host CPU devices (XLA_FLAGS), so these compile actual shard_map
    programs instead of skipping (the seed's `device_count() < 2` guard
    never ran anywhere)."""

    @staticmethod
    def _mesh42(devs):
        from jax.sharding import Mesh
        return Mesh(np.asarray(devs[:8]).reshape(4, 2),
                    ("data", "model"))

    def test_psum_compiles_to_all_reduce(self, forced_devices):
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P
        mesh = self._mesh42(forced_devices)

        def f(x):
            return shard_map(lambda xl: jax.lax.psum(xl, "model"),
                             mesh=mesh, in_specs=P(None, "model"),
                             out_specs=P(None, None),
                             check_rep=False)(x)

        txt = _compile_text(f, jax.ShapeDtypeStruct((8, 16), jnp.float32))
        colls = count_collectives(txt)
        assert sum(n for k, n in colls.items()
                   if k.startswith("all-reduce")) >= 1, colls
        cb = collective_bytes(txt)
        assert cb["total"] > 0

    def test_row_parallel_sharded_matmul_psums(self, forced_devices):
        """The sharded backend's row-parallel path must lower to an
        all-reduce (the K-partial psum); column-parallel must lower to
        NO collective at all — that is why it stays bit-identical."""
        from repro import backends
        from repro.backends import configure_mesh
        from repro.core.policy import QuantPolicy
        from repro.core.qlinear import quantize_weight
        from repro.runtime.elastic import MeshPlan

        pol = QuantPolicy(method="olive", wbits=4,
                          compute_dtype="float32",
                          backend="pallas_sharded_interpret")
        w = jnp.asarray(np.random.default_rng(0)
                        .standard_normal((64, 64)), jnp.float32)
        wq = quantize_weight(w, pol)
        configure_mesh(MeshPlan(shape=(4, 2),
                                axis_names=("data", "model"),
                                dropped_devices=0))
        try:
            sd = jax.ShapeDtypeStruct((4, 64), jnp.float32)
            row = _compile_text(
                lambda x: backends.dispatch(x, wq, pol,
                                            site="blocks/0/attn/wo"), sd)
            col = _compile_text(
                lambda x: backends.dispatch(x, wq, pol,
                                            site="blocks/0/attn/wq"), sd)
        finally:
            configure_mesh(None)
        assert sum(n for k, n in count_collectives(row).items()
                   if k.startswith("all-reduce")) >= 1
        assert count_collectives(col) == {}


def test_collective_bytes_parser_units():
    fake = """
ENTRY %main (p: f32[8,16]) -> f32[8,16] {
  %p = f32[8,16]{1,0} parameter(0)
  %ar = f32[8,16]{1,0} all-reduce(%p), replica_groups={}, to_apply=%add
  %ag = f32[16,16]{1,0} all-gather(%ar), dimensions={0}
  ROOT %out = f32[8,16]{1,0} slice(%ag), slice={[0:8],[0:16]}
}
"""
    cb = collective_bytes(fake)
    assert cb["all-reduce"] == 8 * 16 * 4 * 2   # ring factor 2
    assert cb["all-gather"] == 16 * 16 * 4
    st = hlo_stats.analyze_hlo(fake)
    assert st.collective_bytes == cb["all-reduce"] + cb["all-gather"]


def test_collectives_in_scan_scale_by_trip():
    fake = """
%body (t: (s32[], f32[128])) -> (s32[], f32[128]) {
  %t = (s32[], f32[128]{0}) parameter(0)
  %i = s32[] get-tuple-element(%t), index=0
  %x = f32[128]{0} get-tuple-element(%t), index=1
  %ar = f32[128]{0} all-reduce(%x), to_apply=%add
  %one = s32[] constant(1)
  %ip = s32[] add(%i, %one)
  ROOT %r = (s32[], f32[128]{0}) tuple(%ip, %ar)
}

%cond (t: (s32[], f32[128])) -> pred[] {
  %t = (s32[], f32[128]{0}) parameter(0)
  %i = s32[] get-tuple-element(%t), index=0
  %n = s32[] constant(7)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

ENTRY %main (p: f32[128]) -> f32[128] {
  %p = f32[128]{0} parameter(0)
  %z = s32[] constant(0)
  %t0 = (s32[], f32[128]{0}) tuple(%z, %p)
  %w = (s32[], f32[128]{0}) while(%t0), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"7"}}
  ROOT %o = f32[128]{0} get-tuple-element(%w), index=1
}
"""
    st = hlo_stats.analyze_hlo(fake)
    assert st.collective_bytes == 7 * 128 * 4 * 2
    assert st.collective_counts["all-reduce"] == 7


def test_count_collectives():
    fake = "%a = f32[4]{0} all-reduce(%x)\n%b = f32[4]{0} all-gather(%y)"
    # count_collectives works on result-shape patterns: needs '= shape op('
    fake = ("%a = f32[4]{0} all-reduce(%x), to_apply=%s\n"
            "%b = f32[8]{0} all-gather(%a), dimensions={0}\n")
    c = count_collectives(fake)
    assert c == {"all-reduce": 1, "all-gather": 1}
