"""Fused decode-attention kernel equivalence + dispatch suite.

The fused kernel (kernels/decode_attn.py) must match the dense XLA path
across fp and OVP-packed caches, GQA group sizes, ring + sliding-window
masks, and mixed active lengths in one batch; unsupported layouts must
decline with machine-readable reasons and fall back through the registry;
and a quantized-cache ServingEngine decode must never trace a full-cache
dequant (the bug this kernel fixes).

Note on tolerances: for packed caches the LEGACY dense path dequantizes
to bf16 before the einsum; the fused kernel keeps the decoded values in
f32. The kernel is compared tightly (1e-5) against an f32 dequant
reference and loosely (2e-2) against the legacy bf16 materialization.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import backends
from repro.configs.base import ArchConfig
from repro.core.policy import QuantPolicy
from repro.kernels import decode_attn as DA
from repro.models import layers as L
from repro.models.model import build_model
from repro.serve.engine import EngineCfg, ServingEngine

KB = "pallas_interpret"   # kernel backend under test (CPU interpreter)


def _mk_cache(rng, b, s, hkv, d, kv_bits, dtype=jnp.float32, ring=0,
              n_tok=None):
    cache = L.make_kv_cache(b, s, hkv, d, dtype, kv_bits)
    n_tok = s if n_tok is None else n_tok
    k = jnp.asarray(rng.standard_normal((b, n_tok, hkv, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, n_tok, hkv, d)), jnp.float32)
    return L.cache_write(cache, k, v, jnp.zeros((b,), jnp.int32),
                         ring=ring)


def _f32_reference(q, cache, pos, **kw):
    """Dense path on an f32 dequant of the cache (packed caches: tight
    oracle without the legacy bf16 rounding)."""
    k, v = DA.read_cache_dense(cache, dtype=jnp.float32)
    return DA.xla_decode_attention(q, {"k": k, "v": v}, pos, **kw)


def _fused(q, cache, pos, **kw):
    return DA.fused_decode_attention(q, cache, pos, interpret=True,
                                     block_s=8, **kw)


@pytest.mark.parametrize("g", [1, 2, 4])
@pytest.mark.parametrize("kv_bits", [0, 4])
def test_fused_matches_dense_gqa(g, kv_bits):
    rng = np.random.default_rng(0)
    b, s, hkv, d = 2, 20, 2, 16
    cache = _mk_cache(rng, b, s, hkv, d, kv_bits)
    q = jnp.asarray(rng.standard_normal((b, 1, hkv * g, d)), jnp.float32)
    pos = jnp.asarray([5, 19], jnp.int32)
    got = _fused(q, cache, pos)
    assert float(jnp.max(jnp.abs(got - _f32_reference(q, cache, pos)))) \
        < 1e-5
    # legacy dense path (bf16 dequant for packed caches): loose agreement
    legacy = DA.xla_decode_attention(q, cache, pos)
    assert float(jnp.max(jnp.abs(got - legacy))) < (2e-2 if kv_bits
                                                    else 1e-5)


def test_fused_matches_dense_bf16_cache():
    rng = np.random.default_rng(1)
    b, s, hkv, d = 2, 16, 2, 8
    cache = _mk_cache(rng, b, s, hkv, d, 0, dtype=jnp.bfloat16)
    q = jnp.asarray(rng.standard_normal((b, 1, 4, d)), jnp.float32)
    pos = jnp.asarray([3, 15], jnp.int32)
    got = _fused(q, cache, pos)
    # tight vs the f32 view of the same bf16 values; loose vs the legacy
    # path, which also rounds the probabilities to bf16
    assert float(jnp.max(jnp.abs(got - _f32_reference(q, cache, pos)))) \
        < 1e-5
    assert float(jnp.max(jnp.abs(
        got - DA.xla_decode_attention(q, cache, pos)))) < 2e-2


@pytest.mark.parametrize("kv_bits", [0, 4])
def test_ring_buffer_and_window(kv_bits):
    """Sliding-window ring cache: slot absolute positions reconstructed
    arithmetically in-kernel, wrap-around masked identically to dense."""
    rng = np.random.default_rng(2)
    b, ring, hkv, d, window = 2, 8, 2, 8, 8
    cache = _mk_cache(rng, b, ring, hkv, d, kv_bits, ring=ring)
    q = jnp.asarray(rng.standard_normal((b, 1, 4, d)), jnp.float32)
    for pos in ([13, 21], [7, 8]):
        pos = jnp.asarray(pos, jnp.int32)
        got = _fused(q, cache, pos, window=window, ring=ring)
        want = _f32_reference(q, cache, pos, window=window, ring=ring)
        assert float(jnp.max(jnp.abs(got - want))) < 1e-5


@pytest.mark.parametrize("kv_bits", [0, 4])
def test_sliding_window_no_ring(kv_bits):
    rng = np.random.default_rng(3)
    b, s, hkv, d = 2, 24, 2, 8
    cache = _mk_cache(rng, b, s, hkv, d, kv_bits)
    q = jnp.asarray(rng.standard_normal((b, 1, 2, d)), jnp.float32)
    pos = jnp.asarray([9, 23], jnp.int32)
    got = _fused(q, cache, pos, window=4)
    want = _f32_reference(q, cache, pos, window=4)
    assert float(jnp.max(jnp.abs(got - want))) < 1e-5


def test_mixed_active_lengths_one_batch():
    """One compiled kernel serves every active-length mix: positions are a
    traced operand, masking runs in-kernel."""
    rng = np.random.default_rng(4)
    b, s, hkv, d = 4, 32, 2, 16
    cache = _mk_cache(rng, b, s, hkv, d, 4)
    q = jnp.asarray(rng.standard_normal((b, 1, 4, d)), jnp.float32)
    fused = jax.jit(lambda q, c, p: _fused(q, c, p))
    for pos in ([0, 7, 18, 31], [31, 1, 1, 30]):
        pos = jnp.asarray(pos, jnp.int32)
        got = fused(q, cache, pos)
        want = _f32_reference(q, cache, pos)
        assert float(jnp.max(jnp.abs(got - want))) < 1e-5


def test_non_divisible_cache_length_avoids_per_step_pad():
    """A cache length that is no multiple of block_s must tile on an
    exact divisor when a sane one exists (a non-divisor tile would copy
    the whole cache through jnp.pad every traced decode step) — and stay
    correct either way."""
    assert DA._pick_bs(300, 256) == 150      # exact divisor, no padding
    assert DA._pick_bs(1024, 256) == 256
    assert DA._pick_bs(1021, 256) == 256     # prime: pad + in-kernel mask
    rng = np.random.default_rng(9)
    for s in (300, 97):                      # divisor-tiled and padded
        cache = _mk_cache(rng, 2, s, 2, 8, 4)
        q = jnp.asarray(rng.standard_normal((2, 1, 4, 8)), jnp.float32)
        pos = jnp.asarray([s // 3, s - 1], jnp.int32)
        got = DA.fused_decode_attention(q, cache, pos, interpret=True,
                                        block_s=256)
        want = _f32_reference(q, cache, pos)
        assert float(jnp.max(jnp.abs(got - want))) < 1e-5


def test_single_pallas_call_per_site():
    rng = np.random.default_rng(5)
    cache = _mk_cache(rng, 2, 16, 2, 8, 4)
    q = jnp.asarray(rng.standard_normal((2, 1, 4, 8)), jnp.float32)
    pos = jnp.asarray([3, 15], jnp.int32)
    n = backends.count_pallas_calls(
        lambda q, p: _fused(q, cache, p), q, pos)
    assert n == 1


# ---------------------------------------------------------------- declines
def test_decline_reasons():
    rng = np.random.default_rng(6)
    cache = _mk_cache(rng, 2, 8, 2, 8, 4)
    q1 = jnp.zeros((2, 1, 4, 8))
    assert DA.decline_reason(q1, cache) is None
    assert DA.decline_reason(jnp.zeros((2, 2, 4, 8)), cache) \
        == "decode_q_tokens_gt_1"
    odd = _mk_cache(rng, 2, 8, 2, 7, 0)
    assert DA.decline_reason(jnp.zeros((2, 1, 4, 7)), odd) \
        == "decode_head_dim_odd"
    empty = L.make_kv_cache(2, 0, 2, 8, jnp.float32, 0)
    assert DA.decline_reason(jnp.zeros((2, 1, 4, 8)), empty) \
        == "decode_empty_cache"
    assert DA.decline_reason(q1, {"rec": jnp.zeros((2, 8))}) \
        == "decode_no_kv_cache"
    # backend objects expose the same vocabulary; dense backends serve all
    kb = backends.get_backend(KB)
    assert kb.fuses_decode_attention
    assert kb.decode_attn_decline_reason(jnp.zeros((2, 2, 4, 8)), cache) \
        == "decode_q_tokens_gt_1"
    assert backends.get_backend("xla").decode_attn_decline_reason(
        jnp.zeros((2, 2, 4, 8)), cache) is None


def test_dispatch_served_and_fallback_stats():
    rng = np.random.default_rng(7)
    pol = QuantPolicy(method="olive", kv_bits=4, compute_dtype="float32",
                      backend=KB)
    cache = _mk_cache(rng, 2, 16, 2, 8, 4)
    q = jnp.asarray(rng.standard_normal((2, 1, 4, 8)), jnp.float32)
    pos = jnp.asarray([3, 15], jnp.int32)
    backends.reset_dispatch_stats()
    got = L.decode_attention(q, cache, pos, policy=pol)
    assert backends.dispatch_stats() == {f"{KB}[decode_attn]": 1}
    assert float(jnp.max(jnp.abs(
        got - _f32_reference(q, cache, pos)))) < 1e-5

    # declined layout: odd head_dim fp cache -> dense fallback, reason
    # recorded, output identical to the dense path
    odd = _mk_cache(rng, 2, 8, 2, 7, 0)
    q7 = jnp.asarray(rng.standard_normal((2, 1, 4, 7)), jnp.float32)
    p7 = jnp.asarray([3, 7], jnp.int32)
    backends.reset_dispatch_stats()
    got = L.decode_attention(q7, odd, p7, policy=pol)
    assert backends.dispatch_stats() == {
        f"{KB}->fallback:decode_head_dim_odd[decode_attn]": 1}
    np.testing.assert_array_equal(
        np.asarray(got), np.asarray(DA.xla_decode_attention(q7, odd, p7)))


def test_make_kv_cache_odd_head_dim_raises():
    with pytest.raises(ValueError, match="even head_dim"):
        L.make_kv_cache(2, 16, 2, 7, kv_bits=4)
    # fp caches stay constructible at any head_dim
    assert "k" in L.make_kv_cache(2, 16, 2, 7, kv_bits=0)


# ------------------------------------------------- cross-attention padding
def test_padded_encoder_cross_attention_matches_tight_cache():
    """enc_len < cache length: the zero-initialized tail rows must score
    -inf, not logit 0 — padded and tight caches agree bit-for-bit."""
    rng = np.random.default_rng(8)
    cfg = ArchConfig(name="xattn-tiny", family="dense", n_layers=1,
                     d_model=32, n_heads=4, n_kv_heads=2, d_ff=64,
                     vocab=64, head_dim=8, block_pattern=("attn",))
    pol = QuantPolicy(compute_dtype="float32")
    p = L.attention_params(jax.random.PRNGKey(0), cfg.d_model, cfg.n_heads,
                           cfg.n_kv_heads, cfg.head_dim)
    b, enc_len = 2, 10
    enc_out = jnp.asarray(rng.standard_normal((b, enc_len, cfg.d_model)),
                          jnp.float32)
    x_pre = jnp.asarray(rng.standard_normal((b, 3, cfg.d_model)),
                        jnp.float32)
    x_tok = jnp.asarray(rng.standard_normal((b, 1, cfg.d_model)),
                        jnp.float32)

    def run(cache_len):
        cache = L.make_kv_cache(b, cache_len, cfg.n_kv_heads, cfg.head_dim,
                                jnp.float32, 0, track_len=True)
        positions = jnp.broadcast_to(jnp.arange(3)[None], (b, 3))
        _, cache = L.attention_forward(p, x_pre, positions, cfg, pol,
                                       causal=False, cache=cache,
                                       mode="prefill", kv_x=enc_out,
                                       use_rope=False)
        assert int(cache["src_len"][0]) == min(enc_len, cache_len)
        out, _ = L.attention_forward(p, x_tok, jnp.full((b, 1), 3), cfg,
                                     pol, cache=cache, mode="decode",
                                     kv_x=jnp.zeros_like(x_tok),
                                     use_rope=False)
        return np.asarray(out)

    np.testing.assert_array_equal(run(enc_len), run(enc_len + 6))


# --------------------------------------------------- engine: zero dequants
TINY = ArchConfig(name="kv-decode-tiny", family="dense", n_layers=2,
                  d_model=64, n_heads=4, n_kv_heads=2, d_ff=128, vocab=256,
                  head_dim=16, block_pattern=("attn",))


def test_engine_quantized_decode_zero_full_dequant(monkeypatch):
    """With kv_bits=4 on a kernel backend, a full engine run must never
    trace a full-cache dequant: the fused kernel serves every attention
    site (dispatch stats), and `dequant_kv` is poisoned for the decode
    phase to prove no dense rematerialization hides in the traced step."""
    pol = QuantPolicy(method="olive", wbits=4, abits=0, kv_bits=4,
                      compute_dtype="float32", backend=KB)
    model = build_model(TINY, pol, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    eng = ServingEngine(model, params, EngineCfg(batch_slots=2, max_len=64))
    rng = np.random.default_rng(0)
    for n in (5, 9, 3):
        eng.submit(rng.integers(0, TINY.vocab, size=n).astype(np.int32),
                   max_new_tokens=4)
    backends.reset_dispatch_stats()

    def _poisoned(data, scl):
        raise AssertionError("full-cache dequant traced in decode")

    # every dense dequant (cache_read included) funnels through this one
    monkeypatch.setattr(DA, "dequant_kv", _poisoned)
    done = eng.run_until_drained()
    assert sorted(len(r.out_tokens) for r in done) == [4, 4, 4]
    stats = backends.dispatch_stats()
    decode_keys = {k: v for k, v in stats.items() if "[decode_attn]" in k}
    assert decode_keys.get(f"{KB}[decode_attn]", 0) >= 1
    assert not any("->fallback:" in k for k in decode_keys)


# ----------------------------------------------------- paged == slab
def _paged_from_slab(slab, ps, bt_rows, n_pages):
    """Scatter a slab cache's rows into a page pool through a block
    table: paged view of the exact same bytes. Unowned pages are filled
    with garbage to prove the table (not page order) selects the data."""
    rng = np.random.default_rng(99)
    bt = np.asarray(bt_rows, np.int32)
    paged = {"block_table": jnp.asarray(bt)}
    for key, leaf in slab.items():
        if key not in ("k", "v", "k_data", "v_data", "k_scl", "v_scl"):
            continue
        arr = np.asarray(leaf)
        b, s = arr.shape[:2]
        tiles = arr.reshape((b, s // ps, ps) + arr.shape[2:])
        if arr.dtype == np.uint8:
            pool = rng.integers(0, 255, (n_pages, ps) + arr.shape[2:],
                                dtype=np.uint8)
        else:
            pool = rng.standard_normal(
                (n_pages, ps) + arr.shape[2:]).astype(arr.dtype)
        for i in range(b):
            for j in range(s // ps):
                pool[bt[i, j]] = tiles[i, j]
        paged[key] = jnp.asarray(pool)
    return paged


@pytest.mark.parametrize("kv_bits,dtype", [(4, jnp.float32),
                                           (0, jnp.bfloat16)])
@pytest.mark.parametrize("g", [1, 2, 4])
def test_paged_matches_slab_bit_for_bit(kv_bits, dtype, g):
    """The paged kernel is the slab kernel plus one block-table
    indirection on the kv-tile grid dim — with slab block_s = page_size
    the tile arithmetic is identical, so outputs match bit-for-bit even
    on permuted, fragmented page layouts and non-divisible lengths."""
    rng = np.random.default_rng(10)
    b, s, ps, hkv, d = 2, 24, 8, 2, 16
    slab = _mk_cache(rng, b, s, hkv, d, kv_bits, dtype=dtype, n_tok=19)
    paged = _paged_from_slab(slab, ps, [[5, 2, 9], [0, 7, 3]], 12)
    q = jnp.asarray(rng.standard_normal((b, 1, hkv * g, d)), jnp.float32)
    for pos in ([5, 18], [18, 0]):          # non-divisible active lengths
        pos = jnp.asarray(pos, jnp.int32)
        got = DA.fused_decode_attention(q, paged, pos, interpret=True)
        want = DA.fused_decode_attention(q, slab, pos, interpret=True,
                                         block_s=ps)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
        # dense fallback materializes through the same table: also exact
        np.testing.assert_array_equal(
            np.asarray(DA.xla_decode_attention(q, paged, pos)),
            np.asarray(DA.xla_decode_attention(q, slab, pos)))


@pytest.mark.parametrize("kv_bits", [0, 4])
def test_paged_ring_window_matches_slab(kv_bits):
    rng = np.random.default_rng(11)
    b, ring, ps, hkv, d, window = 2, 16, 8, 2, 8, 8
    slab = _mk_cache(rng, b, ring, hkv, d, kv_bits, ring=ring)
    paged = _paged_from_slab(slab, ps, [[3, 1], [6, 0]], 8)
    q = jnp.asarray(rng.standard_normal((b, 1, 4, d)), jnp.float32)
    for pos in ([13, 21], [7, 40]):
        pos = jnp.asarray(pos, jnp.int32)
        got = DA.fused_decode_attention(q, paged, pos, interpret=True,
                                        window=window, ring=ring)
        want = DA.fused_decode_attention(q, slab, pos, interpret=True,
                                         block_s=ps, window=window,
                                         ring=ring)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_paged_fragmented_pool_matches_slab():
    """Alloc/free interleaving leaves a request's pages scattered across
    the pool; attention through the resulting block table must still be
    bit-identical to the contiguous slab."""
    from repro.serve.paging import PagePool
    rng = np.random.default_rng(12)
    b, s, ps, hkv, d = 2, 32, 8, 2, 16
    pool = PagePool(16, ps)
    pool.alloc(3, owner=100)                   # churn: stagger the frees
    row0 = pool.alloc(4, owner=1)
    pool.free(100)
    row1 = pool.alloc(4, owner=2)              # lands in the freed holes
    assert row1 != sorted(row1) or row1[0] < row0[-1]  # truly fragmented
    slab = _mk_cache(rng, b, s, hkv, d, 4)
    paged = _paged_from_slab(slab, ps, [row0, row1], 16)
    q = jnp.asarray(rng.standard_normal((b, 1, 4, d)), jnp.float32)
    pos = jnp.asarray([31, 11], jnp.int32)
    got = DA.fused_decode_attention(q, paged, pos, interpret=True)
    want = DA.fused_decode_attention(q, slab, pos, interpret=True,
                                     block_s=ps)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_paged_single_pallas_call():
    rng = np.random.default_rng(13)
    slab = _mk_cache(rng, 2, 16, 2, 8, 4)
    paged = _paged_from_slab(slab, 8, [[1, 4], [2, 5]], 8)
    q = jnp.asarray(rng.standard_normal((2, 1, 4, 8)), jnp.float32)
    pos = jnp.asarray([3, 15], jnp.int32)
    n = backends.count_pallas_calls(
        lambda q, p: DA.fused_decode_attention(q, paged, p,
                                               interpret=True), q, pos)
    assert n == 1


def test_paged_decline_reasons():
    rng = np.random.default_rng(14)
    slab = _mk_cache(rng, 2, 16, 2, 8, 4)
    paged = _paged_from_slab(slab, 8, [[1, 4], [2, 5]], 8)
    q = jnp.zeros((2, 1, 4, 8))
    assert DA.decline_reason(q, paged) is None
    assert DA.decline_reason(q, {"block_table": paged["block_table"]}) \
        == "paged_no_pool"
    bad_rank = dict(paged, block_table=paged["block_table"][..., None])
    assert DA.decline_reason(q, bad_rank) == "paged_table_rank"
    bad_dtype = dict(paged,
                     block_table=paged["block_table"].astype(jnp.float32))
    assert DA.decline_reason(q, bad_dtype) == "paged_table_rank"
    odd = {key: (leaf[:, :7] if key != "block_table" else leaf)
           for key, leaf in paged.items()}
    assert DA.decline_reason(q, odd) == "paged_page_misaligned"
    empty = dict(paged, block_table=paged["block_table"][:, :0])
    assert DA.decline_reason(q, empty) == "decode_empty_cache"


def test_engine_backend_override_reaches_decode_attention():
    """EngineCfg.backend rewrites the policy backend for decode-attention
    sites too: an xla-policy model overridden to the kernel backend must
    serve decode attention fused."""
    pol = QuantPolicy(method="olive", wbits=4, abits=0, kv_bits=4,
                      compute_dtype="float32", backend="xla")
    model = build_model(TINY, pol, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    eng = ServingEngine(model, params,
                        EngineCfg(batch_slots=1, max_len=64, backend=KB))
    backends.reset_dispatch_stats()
    eng.submit(np.arange(5, dtype=np.int32), max_new_tokens=3)
    eng.run_until_drained()
    stats = backends.dispatch_stats()
    assert stats.get(f"{KB}[decode_attn]", 0) >= 1
    assert not any("->fallback:" in k and "[decode_attn]" in k
                   for k in stats)
