"""Property-based tests (hypothesis) for the OVP encoding invariants.

System invariants under test, for every normal dtype and any real input:
  I1  pack/unpack is an exact inverse
  I2  encoded normal slots never hold the outlier identifier
  I3  every victim (identifier) slot is adjacent to an abfloat outlier
  I4  decode error of normal values ≤ the dtype's max rounding step
  I5  outliers survive with bounded relative error (vs catastrophic clip)
  I6  the MSE-searched scale never loses to the 3σ init
  I7  QuantizedTensor round-trips shape/dtype for any pair axis
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

# optional dependency: property tests are skipped (not a collection error)
# when hypothesis is absent — see tests/requirements-optional.txt
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.datatypes import (ABFLOAT_FOR_NORMAL, ID4, ID8, NORMAL_MAX,
                                  abfloat_decode, abfloat_encode)
from repro.core.ovp import (ovp_decode_codes, ovp_encode_codes,
                            ovp_dequantize, ovp_quantize, pack4, unpack4)
from repro.core.quantizer import ovp_search_scale, sigma_init_scale

DTYPES = ["int4", "flint4", "int8"]


def arrays(min_pairs=2, max_pairs=64, lo=-400.0, hi=400.0):
    return st.lists(
        st.floats(min_value=lo, max_value=hi, allow_nan=False,
                  width=32),
        min_size=2 * min_pairs, max_size=2 * max_pairs)\
        .filter(lambda v: len(v) % 2 == 0)\
        .map(lambda v: np.asarray(v, np.float32))


@settings(max_examples=40, deadline=None)
@given(vals=arrays(), dt=st.sampled_from(["int4", "flint4"]))
def test_pack_unpack_inverse(vals, dt):
    codes = ovp_encode_codes(jnp.asarray(vals), dt)
    rt = unpack4(pack4(codes))
    np.testing.assert_array_equal(np.asarray(rt), np.asarray(codes))


@settings(max_examples=40, deadline=None)
@given(vals=arrays(), dt=st.sampled_from(DTYPES))
def test_identifier_only_in_victim_slots(vals, dt):
    ident = ID8 if dt == "int8" else ID4
    codes = np.asarray(ovp_encode_codes(jnp.asarray(vals), dt))
    c0, c1 = codes[0::2], codes[1::2]
    # I2/I3: an identifier in one slot implies the partner is a non-zero
    # abfloat code (outliers never encode to 0 — disabled code invariant)
    both = (c0 == ident) & (c1 == ident)
    assert not both.any(), "both slots cannot be victims"
    spec = ABFLOAT_FOR_NORMAL[dt]
    for vic, out in [(c0, c1), (c1, c0)]:
        sel = vic == ident
        if sel.any():
            partner = out[sel]
            decoded = np.asarray(abfloat_decode(jnp.asarray(partner), spec))
            assert (decoded != 0).all(), "victim must pair with an outlier"


@settings(max_examples=40, deadline=None)
@given(vals=arrays(), dt=st.sampled_from(DTYPES))
def test_normal_value_error_bounded(vals, dt):
    t = NORMAL_MAX[dt]
    codes = ovp_encode_codes(jnp.asarray(vals), dt)
    dec = np.asarray(ovp_decode_codes(codes, dt))
    v = vals.reshape(-1, 2)
    d = dec.reshape(-1, 2)
    a = np.abs(v)
    # pairs where both |x| ≤ t are normal–normal: element error ≤ step
    nn = (a[:, 0] <= t) & (a[:, 1] <= t)
    step = {"int4": 0.5, "int8": 0.5, "flint4": 4.0}[dt]  # max half-gap
    assert np.all(np.abs(d[nn] - v[nn]) <= step + 1e-5)


@settings(max_examples=40, deadline=None)
@given(vals=arrays(), dt=st.sampled_from(DTYPES))
def test_outlier_survives(vals, dt):
    t = NORMAL_MAX[dt]
    spec = ABFLOAT_FOR_NORMAL[dt]
    codes = ovp_encode_codes(jnp.asarray(vals), dt)
    dec = np.asarray(ovp_decode_codes(codes, dt))
    v = vals.reshape(-1, 2)
    d = dec.reshape(-1, 2)
    a = np.abs(v)
    # one-outlier pairs: the outlier decodes within abfloat's quantization
    # error (≤ half the max gap between magnitudes) — never clipped to t
    lone0 = (a[:, 0] > t) & (a[:, 1] <= t)
    if lone0.any():
        x, y = v[lone0, 0], d[lone0, 0]
        in_range = np.minimum(np.abs(x), spec.max_mag)
        # relative error of the kept outlier ≤ 50% (vs int4 clip: ~1-t/|x|)
        assert np.all(np.abs(y - np.sign(x) * in_range)
                      <= 0.5 * in_range + 1e-5)
        assert np.all(d[lone0, 1] == 0), "its neighbour must be the victim"


@settings(max_examples=15, deadline=None)
@given(sigma=st.floats(0.02, 30.0), seed=st.integers(0, 2 ** 16))
def test_mse_search_never_loses_to_3sigma(sigma, seed):
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(key, (512,)) * sigma
    s0 = sigma_init_scale(x, "int4")
    s = ovp_search_scale(x, "int4", n_grid=16)

    def mse(sc):
        from repro.core.ovp import ovp_fake_quant
        return float(jnp.mean((ovp_fake_quant(x, sc, "int4") - x) ** 2))

    assert mse(s) <= mse(s0) * (1 + 1e-6)


@settings(max_examples=20, deadline=None)
@given(rows=st.integers(1, 5), pairs=st.integers(1, 8),
       axis=st.sampled_from([0, 1]), dt=st.sampled_from(DTYPES),
       seed=st.integers(0, 99))
def test_quantized_tensor_roundtrip_shapes(rows, pairs, axis, dt, seed):
    shape = [rows, 2 * pairs] if axis == 1 else [2 * pairs, rows]
    x = jax.random.normal(jax.random.PRNGKey(seed), shape) * 2.0
    qt = ovp_quantize(x, 1.0, dt, pair_axis=axis)
    xh = ovp_dequantize(qt)
    assert xh.shape == tuple(shape)
    assert qt.shape == tuple(shape)
    if dt != "int8":
        assert qt.data.shape[qt.pair_axis] == pairs
    assert qt.data.dtype == jnp.uint8


@settings(max_examples=30, deadline=None)
@given(vals=arrays(min_pairs=4), dt=st.sampled_from(["int4", "flint4"]))
def test_abfloat_codes_reencode_stable(vals, dt):
    """decode(encode(x)) is a fixed point of the abfloat codec."""
    spec = ABFLOAT_FOR_NORMAL[dt]
    big = jnp.asarray(np.abs(vals) + spec.min_mag)  # force outlier range
    c1 = abfloat_encode(big, spec)
    d1 = abfloat_decode(c1, spec)
    c2 = abfloat_encode(d1, spec)
    np.testing.assert_array_equal(np.asarray(c1), np.asarray(c2))
