"""End-to-end serving driver (the paper's deployment story).

Train a small LM on the synthetic corpus (cached), OliVe-PTQ it to W4
(+ optional OVP 4-bit KV cache), and serve a batch of requests through the
continuous-batching engine. Reports: greedy-output agreement vs the fp32
engine, weight footprint, and tokens/s.

`--mixed` serves a site-addressed policy *program* instead of a flat
policy: first/last layer W8 (+ OVP KV cache there), middle layers W4 —
the per-layer mixed precision the flat API could not express.

Run:  PYTHONPATH=src python examples/serve_quantized.py \
          [--kv4] [--w8 | --mixed]
"""
import argparse
import os
import sys
import time

import numpy as np

# reuse the cached trained-LM fixture from the benchmark harness
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
from benchmarks import common  # noqa: E402

from repro.core.policy import PolicyProgram, QuantPolicy  # noqa: E402
from repro.core.qlinear import quantize_params  # noqa: E402
from repro.models.model import build_model  # noqa: E402
from repro.serve.engine import EngineCfg, ServingEngine  # noqa: E402


footprint = common.footprint


def run_engine(model, params, prompts, max_new=24):
    eng = ServingEngine(model, params,
                        EngineCfg(batch_slots=4, max_len=192))
    t0 = time.time()
    for p in prompts:
        eng.submit(p, max_new_tokens=max_new)
    done = eng.run_until_drained()
    dt = time.time() - t0
    toks = sum(len(r.out_tokens) for r in done)
    outs = {r.uid: r.out_tokens for r in done}
    return outs, toks / dt


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--kv4", action="store_true",
                    help="also OVP-quantize the KV cache (beyond-paper)")
    ap.add_argument("--w8", action="store_true", help="W8A8 instead of W4")
    ap.add_argument("--mixed", action="store_true",
                    help="per-layer mixed program: first/last W8+KV4, "
                         "middle W4")
    ap.add_argument("--n-requests", type=int, default=12)
    args = ap.parse_args()

    model_fp, params, loader = common.trained_lm()
    cfg = model_fp.cfg

    w4 = QuantPolicy(method="olive", wbits=4, abits=0,
                     compute_dtype="float32",
                     kv_bits=4 if args.kv4 else 0)
    w8 = QuantPolicy(method="olive", wbits=8, abits=0,
                     w_normal_dtype="int8", compute_dtype="float32",
                     kv_bits=4 if args.kv4 else 0)
    if args.mixed:
        w8kv = QuantPolicy(method="olive", wbits=8, abits=0,
                           w_normal_dtype="int8", compute_dtype="float32",
                           kv_bits=4)
        pol = PolicyProgram.from_policy(w4, name="mixed_w48").with_rules([
            ("layers/0/*", w8kv),
            (f"layers/{cfg.n_layers - 1}/*", w8kv),
        ])
    else:
        pol = w8 if args.w8 else w4
    model_q = build_model(cfg, pol, remat=False)
    qparams = quantize_params(model_q.adapt_params(params), pol)

    print(f"weights: fp32 {footprint(params)/1e6:.2f} MB -> olive "
          f"{footprint(qparams)/1e6:.2f} MB "
          f"({footprint(params)/footprint(qparams):.2f}x)")

    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, size=rng.integers(4, 24))
               .astype(np.int32) for _ in range(args.n_requests)]

    outs_fp, tps_fp = run_engine(model_fp, params, prompts)
    outs_q, tps_q = run_engine(model_q, qparams, prompts)

    agree = []
    for uid in outs_fp:
        a, b = outs_fp[uid], outs_q.get(uid, [])
        n = min(len(a), len(b))
        agree.append(np.mean([a[i] == b[i] for i in range(n)]) if n else 0)
    print(f"served {len(outs_fp)} requests, continuous batching over 4 "
          f"slots")
    print(f"fp32 engine: {tps_fp:.1f} tok/s | olive engine: {tps_q:.1f} "
          f"tok/s (CPU decode-path; the TPU win is bandwidth, see "
          f"benchmarks/speedup.py)")
    print(f"greedy-token agreement fp32 vs olive: "
          f"{100*float(np.mean(agree)):.1f}%")
    ok = float(np.mean(agree)) > 0.85
    print("OK" if ok else "DEGRADED (check quantization)")
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
