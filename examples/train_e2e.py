"""End-to-end fault-tolerant training driver.

Trains a dense GQA transformer on the synthetic corpus with the production
trainer: microbatched gradient accumulation, bf16 moments/grads, async
checkpointing, preemption-safe resume, straggler monitoring. A mid-run
"crash" is simulated and training resumes bit-exactly from the checkpoint
(the stateless loader replays the identical data stream).

Default model is ~20M params so the demo finishes in minutes on CPU;
--model-100m selects the ~100M-param config the deliverable names.

Run:  PYTHONPATH=src python examples/train_e2e.py [--model-100m] [--steps N]
"""
import argparse
import os
import shutil

import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core.policy import QuantPolicy
from repro.data.loader import LoaderCfg, SyntheticLoader
from repro.data.synthetic import CorpusCfg
from repro.models.model import build_model
from repro.optim.adamw import AdamW
from repro.train.trainer import Trainer, TrainerCfg

SMALL = ArchConfig(name="e2e-20m", family="dense", n_layers=6, d_model=256,
                   n_heads=8, n_kv_heads=4, d_ff=768, vocab=8192,
                   head_dim=32, block_pattern=("attn",))
BIG = ArchConfig(name="e2e-100m", family="dense", n_layers=12, d_model=512,
                 n_heads=8, n_kv_heads=4, d_ff=2048, vocab=50304,
                 head_dim=64, block_pattern=("attn",))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--model-100m", action="store_true")
    ap.add_argument("--steps", type=int, default=0)
    ap.add_argument("--ckpt-dir", default="/tmp/olive_e2e_ckpt")
    args = ap.parse_args()

    cfg = BIG if args.model_100m else SMALL
    steps = args.steps or (200 if args.model_100m else 120)
    n_params = cfg.param_count()
    print(f"arch {cfg.name}: ~{n_params/1e6:.1f}M params, {steps} steps")

    if os.path.isdir(args.ckpt_dir):
        shutil.rmtree(args.ckpt_dir)
    model = build_model(cfg, QuantPolicy(compute_dtype="float32"),
                        remat=False)
    from repro.optim.adamw import cosine_schedule
    opt = AdamW(lr=cosine_schedule(1e-3, 20, steps),
                moment_dtype=jnp.bfloat16)
    loader = SyntheticLoader(LoaderCfg(
        global_batch=16, seq_len=256, corpus=CorpusCfg(vocab=cfg.vocab)))

    half = steps // 2
    tcfg = TrainerCfg(total_steps=half, ckpt_dir=args.ckpt_dir,
                      ckpt_every=max(half // 2, 10), ckpt_async=True,
                      log_every=10, n_microbatches=2)
    print(f"== phase 1: train to step {half}, then simulate a crash ==")
    t1 = Trainer(model, opt, loader, tcfg).init_or_restore()
    h1 = t1.run()

    print("== phase 2: fresh process restores the checkpoint and "
          "finishes ==")
    tcfg2 = TrainerCfg(total_steps=steps, ckpt_dir=args.ckpt_dir,
                       ckpt_every=max(half // 2, 10), ckpt_async=True,
                       log_every=10, n_microbatches=2, eval_every=0)
    t2 = Trainer(model, opt, loader, tcfg2).init_or_restore()
    assert t2.step == half, f"resume step {t2.step} != {half}"
    h2 = t2.run()

    ppl = t2.evaluate(n_batches=4)
    first, last = h1["loss"][0], h2["loss"][-1]
    print(f"loss {first:.3f} -> {last:.3f}; held-out ppl {ppl:.2f} "
          f"(vocab {cfg.vocab}: random = {cfg.vocab})")
    ok = last < 0.7 * first
    print("OK: loss decreased through the simulated crash/restore"
          if ok else "WARN: loss did not improve enough")
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
