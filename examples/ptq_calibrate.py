"""The paper's §3.4 quantization framework, end to end:

  1. take a trained model (cached fixture),
  2. run one batch of *training-set* data through it collecting activation
     tapes (the paper's calibration setting),
  3. MSE-search static activation scales seeded at 3σ,
  4. PTQ weights with OVP, serve W4A4 with static scales,
  5. report perplexity vs fp32 / dynamic-scale W4A4 / int4.

Run:  PYTHONPATH=src python examples/ptq_calibrate.py
"""
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
from benchmarks import common  # noqa: E402

from repro.core.calibration import (ActTape, auto_mixed,  # noqa: E402
                                    calibrate_activation_scales,
                                    record_weights, site_sensitivity)
from repro.core.policy import QuantPolicy  # noqa: E402
from repro.core.qlinear import quantize_params  # noqa: E402
from repro.models.model import build_model  # noqa: E402


def main():
    model_fp, params, loader = common.trained_lm()
    cfg = model_fp.cfg

    # --- calibration: tape the block inputs on one training batch -------
    tape = ActTape(max_per_site=32768)
    batch = loader.batch_at(0)  # training split, as the paper prescribes
    logits, _, _ = model_fp.forward(params, batch, mode="train")
    # tape the embedding output and logits input as representative sites
    x = params["embed"]["table"][batch["tokens"]]
    tape.record("embed_out", x)
    tape.record("head_in", logits[..., :64])  # subsample
    scales = calibrate_activation_scales(tape, "int4")
    print("calibrated static activation scales (3σ-seeded MSE search):")
    for k, v in scales.items():
        print(f"  {k}: {float(v):.5f}")

    # --- PTQ + serve-path evaluation ------------------------------------
    rows = {}
    rows["fp32"] = common.eval_ppl(model_fp, params, loader)

    for tag, pol in [
        ("olive_w4a4_dyn", QuantPolicy(method="olive", wbits=4, abits=4,
                                       compute_dtype="float32")),
        ("olive_w4", QuantPolicy(method="olive", wbits=4, abits=0,
                                 compute_dtype="float32")),
        ("int4_w4", QuantPolicy(method="int", wbits=4, abits=0,
                                compute_dtype="float32")),
    ]:
        qp = quantize_params(params, pol)
        rows[tag] = common.eval_ppl(build_model(cfg, pol, remat=False),
                                    qp, loader)

    # --- sensitivity pass -> automatic mixed-precision program ----------
    # per-site SQNR at 4 bits on the weight tape ranks the sites; the
    # emitted program keeps the most sensitive ones at W8 within a
    # 5-bit average budget (see docs/policies.md)
    w4 = QuantPolicy(method="olive", wbits=4, abits=0,
                     compute_dtype="float32")
    w8 = QuantPolicy(method="olive", wbits=8, abits=0,
                     w_normal_dtype="int8", compute_dtype="float32")
    sens = site_sensitivity(record_weights(params), "int4", n_grid=8)
    worst = sorted(sens, key=lambda k: sens[k])[:3]
    print("\nmost sensitive sites (lowest W4 SQNR):")
    for k in worst:
        print(f"  {k}: {sens[k]:.1f} dB")
    prog = auto_mixed(sens, budget_bits=5.0, low=w4, high=w8)
    model_am = build_model(cfg, prog, remat=False)
    qp = quantize_params(model_am.adapt_params(params), prog)
    rows["olive_auto_w48"] = common.eval_ppl(model_am, qp, loader)

    print("\nheld-out perplexity:")
    for k, v in rows.items():
        print(f"  {k:16s} {v:8.3f}  (+{100*(v/rows['fp32']-1):6.2f}%)")
    ok = rows["olive_w4a4_dyn"] < rows["int4_w4"] * 1.02 \
        and rows["olive_w4"] / rows["fp32"] < 1.05
    print("OK" if ok else "DEGRADED")
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
