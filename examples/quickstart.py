"""OliVe quickstart: OVP-quantize a tensor, inspect the encoding, run the
fused kernel, and see why outlier-blind int4 fails.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core import baselines
from repro.core.datatypes import ABFLOAT_FOR_NORMAL
from repro.core.ovp import (ovp_dequantize, ovp_quantize, pair_statistics,
                            unpack4)
from repro.core.quantizer import QuantSpec, quantization_error, quantize
from repro.kernels import ops, ref


def main():
    key = jax.random.PRNGKey(0)
    # A transformer-like tensor: Gaussian bulk + a few huge outliers.
    x = jax.random.normal(key, (256, 512))
    x = x.at[3, 17].set(41.0).at[100, 200].set(-57.0).at[200, 333].set(88.0)

    print("== pair statistics (paper Table 2) ==")
    st = pair_statistics(x.reshape(-1))
    for k, v in st.items():
        print(f"  {k}: {v:.6f}")

    print("\n== OliVe PTQ (scale search + OVP encode + pack) ==")
    qt = quantize(x, QuantSpec(normal_dtype="int4", granularity="tensor"))
    print(f"  packed bytes: {qt.nbytes()}  (fp32 was {x.size * 4})")
    err = quantization_error(x, QuantSpec(normal_dtype="int4"))
    print(f"  sqnr: {err['sqnr_db']:.2f} dB")

    # the outliers survive quantization:
    xh = ovp_dequantize(qt)
    for (i, j) in [(3, 17), (100, 200), (200, 333)]:
        print(f"  outlier x[{i},{j}] = {float(x[i,j]):+.1f}  ->  "
              f"{float(xh[i,j]):+.1f}")

    # compare: int4 clips them into oblivion
    xi4 = baselines.uniform_int_fake_quant(x, 4)
    print("  int4 (outlier-blind):     "
          + "  ".join(f"{float(xi4[i,j]):+.2f}"
                      for (i, j) in [(3, 17), (100, 200), (200, 333)]))

    print("\n== the byte IS the pair: inspect one OV pair ==")
    codes = unpack4(qt.data, qt.pair_axis)
    # find a victim (identifier 0x8) and show its pair
    vi, vj = map(int, jnp.argwhere(codes == 0x8)[0])
    pj = vj + 1 if vj % 2 == 0 else vj - 1
    print(f"  codes[{vi},{vj}] = 0x{int(codes[vi, vj]):x} (victim id), "
          f"codes[{vi},{pj}] = 0x{int(codes[vi, pj]):x} (abfloat outlier)")
    spec = ABFLOAT_FOR_NORMAL["int4"]
    print(f"  abfloat E2M1 bias={spec.bias}: magnitudes "
          f"{spec.magnitudes().tolist()}")

    print("\n== fused OVP-decode matmul (Pallas, interpret=True) ==")
    a = jax.random.normal(jax.random.PRNGKey(1), (64, 256))
    wq = ovp_quantize(x, jnp.std(x) * 3 / 7, "int4", pair_axis=0)
    got = ops.matmul_w4a16(a, wq.data, jnp.asarray(wq.scale),
                           interpret=True)
    want = ref.ovp_matmul_w4a16_ref(a, wq.data) * wq.scale
    print(f"  kernel vs oracle max err: "
          f"{float(jnp.max(jnp.abs(got - want))):.2e}")
    print("done.")


if __name__ == "__main__":
    main()
