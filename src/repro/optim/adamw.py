"""AdamW, hand-rolled (no optax offline), with distributed-memory options:
moments in bf16 (halves optimizer HBM — the ZeRO-style sharding of the
moment tensors comes free from the param PartitionSpecs) and global-norm
clipping computed in fp32.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    mu: Any          # first moment (pytree like params)
    nu: Any          # second moment


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: Callable[[jax.Array], jax.Array] | float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    moment_dtype: Any = jnp.float32   # bf16 at scale: halves optimizer HBM

    def init(self, params) -> AdamWState:
        z = lambda p: jnp.zeros(p.shape, self.moment_dtype)
        return AdamWState(step=jnp.zeros((), jnp.int32),
                          mu=jax.tree_util.tree_map(z, params),
                          nu=jax.tree_util.tree_map(z, params))

    def _lr_at(self, step):
        return self.lr(step) if callable(self.lr) else self.lr

    def update(self, grads, state: AdamWState, params):
        step = state.step + 1
        # global-norm clip in fp32
        if self.clip_norm:
            gsq = sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                      for g in jax.tree_util.tree_leaves(grads))
            gnorm = jnp.sqrt(gsq)
            scale = jnp.minimum(1.0, self.clip_norm /
                                jnp.maximum(gnorm, 1e-12))
        else:
            gnorm = jnp.zeros(())
            scale = jnp.ones(())
        b1, b2 = self.b1, self.b2
        c1 = 1.0 - b1 ** step.astype(jnp.float32)
        c2 = 1.0 - b2 ** step.astype(jnp.float32)
        lr = self._lr_at(step)

        def upd(g, m, v, p):
            g = g.astype(jnp.float32) * scale
            m32 = m.astype(jnp.float32) * b1 + (1 - b1) * g
            v32 = v.astype(jnp.float32) * b2 + (1 - b2) * g * g
            mh = m32 / c1
            vh = v32 / c2
            delta = mh / (jnp.sqrt(vh) + self.eps)
            if self.weight_decay:
                delta = delta + self.weight_decay * p.astype(jnp.float32)
            newp = p.astype(jnp.float32) - lr * delta
            return (newp.astype(p.dtype), m32.astype(self.moment_dtype),
                    v32.astype(self.moment_dtype))

        flat_g, treedef = jax.tree_util.tree_flatten(grads)
        flat_m = treedef.flatten_up_to(state.mu)
        flat_v = treedef.flatten_up_to(state.nu)
        flat_p = treedef.flatten_up_to(params)
        out = [upd(g, m, v, p)
               for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
        newp = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
        newm = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
        newv = jax.tree_util.tree_unflatten(treedef, [o[2] for o in out])
        return newp, AdamWState(step=step, mu=newm, nu=newv), \
            {"grad_norm": gnorm, "lr": lr * jnp.ones(())}


def cosine_schedule(peak_lr: float, warmup: int, total: int,
                    floor: float = 0.1):
    def lr(step):
        s = step.astype(jnp.float32)
        warm = peak_lr * s / max(warmup, 1)
        prog = jnp.clip((s - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = peak_lr * (floor + (1 - floor) * 0.5 *
                         (1 + jnp.cos(jnp.pi * prog)))
        return jnp.where(s < warmup, warm, cos)
    return lr
