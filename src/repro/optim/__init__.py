from .adamw import AdamW, AdamWState, cosine_schedule
