"""CLI: ``python -m repro.analysis`` — exit nonzero on any finding.

Runs all four passes by default; see docs/static_analysis.md.
"""
from __future__ import annotations

import argparse
import json
import sys

from . import PASS_NAMES, run_all


def sanitize_smoke() -> int:
    """Tiny end-to-end serving smoke under REPRO_SANITIZE=1: the
    pallas_interpret backend, checkified decode/prefill, and the
    jit-trace-count audit. Returns the number of failures (0 = pass)."""
    import os
    os.environ["REPRO_SANITIZE"] = "1"
    from repro.analysis import sanitize
    sanitize.configure()

    import jax
    import numpy as np
    from repro.configs.base import ArchConfig
    from repro.core.policy import QuantPolicy
    from repro.core.qlinear import quantize_params
    from repro.models.model import build_model
    from repro.serve.engine import EngineCfg, ServingEngine

    cfg = ArchConfig(name="analysis-smoke", family="dense", n_layers=2,
                     d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
                     vocab=256, head_dim=16, block_pattern=("attn",))
    policy = QuantPolicy(method="olive", wbits=4, abits=4, kv_bits=4,
                         backend="pallas_interpret",
                         compute_dtype="float32")
    model = build_model(cfg, policy, remat=False)
    # quantize_params self-functionalizes its staged checks when the
    # sanitizer is on, so the smoke calls it like any other caller.
    params = quantize_params(model.init(jax.random.PRNGKey(0)), policy)
    engine = ServingEngine(model, params,
                           EngineCfg(batch_slots=2, max_len=64))
    rng = np.random.default_rng(0)
    for n in (5, 9):   # two prompts, one 16-bucket, one shared trace
        engine.submit(rng.integers(1, cfg.vocab, size=n).astype(np.int32),
                      max_new_tokens=4)
    engine.run_until_drained()
    audit = sanitize.audit_traces(engine)
    print(f"sanitize smoke OK: {audit}")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="Static contract checker: vocabulary, kernel "
                    "contracts, policy resolution, exception hygiene.")
    ap.add_argument("--pass", dest="passes", action="append",
                    choices=PASS_NAMES, default=None,
                    help="run only this pass (repeatable; default: all)")
    ap.add_argument("--fixture", action="append", default=[],
                    help="extra .py module folded into the scan/case set "
                         "(seeded-violation fixtures)")
    ap.add_argument("--vmem-budget", type=int, default=None,
                    help="per-kernel live-block budget in bytes "
                         "(default: REPRO_VMEM_BUDGET or 16 MiB)")
    ap.add_argument("--json", action="store_true",
                    help="emit findings as a JSON array")
    ap.add_argument("--sanitize-smoke", action="store_true",
                    help="instead of the static passes, run the "
                         "REPRO_SANITIZE=1 serving engine smoke")
    args = ap.parse_args(argv)

    if args.sanitize_smoke:
        return sanitize_smoke()

    findings = run_all(passes=tuple(args.passes or PASS_NAMES),
                       fixtures=tuple(args.fixture),
                       vmem_budget=args.vmem_budget)
    if args.json:
        print(json.dumps([f.__dict__ for f in findings], indent=2))
    else:
        for f in findings:
            print(f)
        print(f"repro.analysis: {len(findings)} finding(s)")
    return 1 if findings else 0


if __name__ == "__main__":
    sys.exit(main())
