"""Policy pass: resolve preset programs against the real config zoo.

The site universe is built the way serving builds it: every arch in
`repro.configs.ARCHS` (reduced size), model constructed with a
layer-addressed program so the param tree unrolls to `layers/<i>/...`
addresses, `jax.eval_shape` over `model.init` (no memory, no compile),
`qlinear.tree_paths` + `is_linear_weight` to keep exactly the sites the
quantizer resolves — plus the per-layer `layers/<i>/attn/kv` cache
addresses for attention archs.

Checks, over every preset `PolicyProgram` (flat presets compile to
all-"compat" rule fans and are exempt — see `core.policy.Rule`):

- **POL_DEAD_RULE** — an authored rule pattern matches no site of any
  arch in the zoo: the rule can never fire, usually a renamed module or
  a typo'd glob.
- **POL_SHADOWED** — an authored rule matches sites, but on every one of
  them an earlier rule matches first: first-match-wins precedence makes
  the rule unreachable.
- **POL_DEAD_GLOB** — a calibration-artifact scale key (exact or
  fnmatch glob) matches no site: the calibrated scale would silently
  never apply.

Fixture modules may define `analysis_programs() -> [(name, program)]`
and/or `analysis_artifacts() -> [(name, {key: scale})]` to fold seeded
violations into the same checks.
"""
from __future__ import annotations

import fnmatch
import importlib.util
import re
from pathlib import Path
from typing import Dict, List, Sequence, Set, Tuple

from . import Finding


_UNIVERSES: Dict[str, List[str]] = {}


def site_universes() -> Dict[str, List[str]]:
    """{arch_name: [site, ...]} for the whole zoo, unrolled layout.
    Memoized — the zoo's shapes are process-constant."""
    if _UNIVERSES:
        return _UNIVERSES
    import jax
    from repro.configs import ARCHS
    from repro.core.policy import get_program
    from repro.core.qlinear import is_linear_weight, tree_paths
    from repro.models.model import build_model

    universes: Dict[str, List[str]] = {}
    for name, cfg in ARCHS.items():
        cfg = cfg.reduced()
        # a layer-addressed program forces the unrolled `layers/<i>/...`
        # layout, the one per-layer rules resolve against
        program = get_program("olive_mixed_w48", n_layers=cfg.n_layers)
        model = build_model(cfg, program, remat=False)
        params_sds = jax.eval_shape(
            lambda m=model: m.init(jax.random.PRNGKey(0)))
        sites = [path for path, w in tree_paths(params_sds)
                 if is_linear_weight(path, w)]
        layer_ids = {m.group(1) for s in sites
                     for m in [re.match(r"layers/(\d+)/", s)] if m}
        if any("attn/" in s for s in sites):
            sites += [f"layers/{i}/attn/kv" for i in sorted(layer_ids)]
        universes[name] = sites
    _UNIVERSES.update(universes)
    return _UNIVERSES


def _first_match(program, site: str) -> int:
    for i, rule in enumerate(program.rules):
        if rule.matches(site):
            return i
    return -1


def _check_program(name: str, builders,
                   universes: Dict[str, List[str]]) -> List[Finding]:
    """`builders` maps arch name -> the program instantiated for that
    arch (layer-addressed presets depend on n_layers)."""
    findings: List[Finding] = []
    # authored rule identity is (index-in-program, pattern); programs for
    # different archs share structure, so indexes line up
    matched: Dict[int, Set[str]] = {}
    reached: Set[int] = set()
    patterns: Dict[int, str] = {}
    for arch, sites in universes.items():
        program = builders[arch]
        authored = {i for i, r in enumerate(program.rules)
                    if r.origin != "compat"}
        for i in authored:
            patterns[i] = program.rules[i].pattern
        for site in sites:
            hit = _first_match(program, site)
            for i in authored:
                if program.rules[i].matches(site):
                    matched.setdefault(i, set()).add(f"{arch}:{site}")
            if hit in authored:
                reached.add(hit)
    for i, pattern in sorted(patterns.items()):
        if i not in matched:
            findings.append(Finding(
                "POL_DEAD_RULE", f"{name}[{i}]",
                f"rule pattern {pattern!r} matches no site of any arch "
                f"in the config zoo"))
        elif i not in reached:
            findings.append(Finding(
                "POL_SHADOWED", f"{name}[{i}]",
                f"rule pattern {pattern!r} matches sites but an earlier "
                f"rule always wins (first-match precedence)"))
    return findings


def _check_artifact(name: str, scales,
                    all_sites: List[str]) -> List[Finding]:
    findings: List[Finding] = []
    keys = scales.keys() if hasattr(scales, "keys") else \
        [k for k, _ in scales]
    for key in keys:
        low = key.lower()
        if not any(key == s or fnmatch.fnmatchcase(s.lower(), low)
                   for s in all_sites):
            findings.append(Finding(
                "POL_DEAD_GLOB", f"{name}[{key}]",
                f"calibration scale key {key!r} matches no site of any "
                f"arch in the config zoo"))
    return findings


def _load_fixture(path: Path):
    spec = importlib.util.spec_from_file_location(
        f"_analysis_fixture_{path.stem}", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def check(fixtures: Sequence[str] = ()) -> List[Finding]:
    from repro.core.policy import PROGRAM_PRESETS

    universes = site_universes()
    all_sites = [s for sites in universes.values() for s in sites]
    findings: List[Finding] = []

    for name, make in PROGRAM_PRESETS.items():
        from repro.configs import ARCHS
        builders = {arch: make(cfg.reduced().n_layers)
                    for arch, cfg in ARCHS.items()}
        findings.extend(_check_program(name, builders, universes))

    for f in fixtures:
        if not str(f).endswith(".py"):
            continue
        mod = _load_fixture(Path(f))
        for name, program in getattr(mod, "analysis_programs",
                                     lambda: [])():
            builders = {arch: program for arch in universes}
            findings.extend(_check_program(name, builders, universes))
        for name, scales in getattr(mod, "analysis_artifacts",
                                    lambda: [])():
            findings.extend(_check_artifact(name, scales, all_sites))
    return findings
