"""Vocabulary pass: decline codes and stats keys vs the base.py registry.

Three directions of drift, all fatal:

- **code -> registry**: AST-scan every module under `backends/` and
  `kernels/` for decline-code string literals — returns inside
  `*decline*` functions and arguments to `decline(...)` — plus
  `record_act_scale(...)` keys and `"[...]"` dispatch markers; every one
  must be registered in `backends/base.py` (VOCAB_UNREGISTERED_CODE,
  VOCAB_BAD_STATS_KEY).
- **registry -> code**: every registered decline code must be produced
  somewhere in the scanned source — a code nothing can return is dead
  vocabulary (VOCAB_UNUSED_CODE).
- **registry <-> docs**: the quoted tables in docs/backends.md and
  docs/sharding.md must list exactly the registered codes — nothing
  missing (VOCAB_UNDOCUMENTED_CODE), nothing stale
  (VOCAB_DOC_DRIFT).

Fixture files (seeded violations) are scanned with the same AST walk but
are exempt from the registry->code and doc directions (a fixture only
*adds* literals, it cannot un-document a code).
"""
from __future__ import annotations

import ast
import re
from pathlib import Path
from typing import Iterable, List, Sequence, Set, Tuple

from . import Finding

REPO = Path(__file__).resolve().parents[3]
SRC = REPO / "src" / "repro"
SCAN_DIRS = (SRC / "backends", SRC / "kernels")
DOC_VOCAB = (
    # (path, heading of the section holding the quoted tables)
    (REPO / "docs" / "backends.md", "Decline and dispatch vocabulary"),
    (REPO / "docs" / "sharding.md", "Sharded decline vocabulary"),
)

# decline codes are lower_snake identifiers from these families; the
# filter keeps ordinary string literals ("int8", "model", error text)
# and the `*_decline_reason` accessor names out of the scan
_CODE_RE = re.compile(
    r"^(?:shard|decode|paged|prefill|grouped|stacked|lhs|pair)_[a-z0-9_]+$")


def looks_like_code(s: str) -> bool:
    return bool(_CODE_RE.match(s)) and not s.endswith("_reason")


def _const_strings(node: ast.AST) -> Iterable[str]:
    """String constants reachable from an expression node (covers plain
    constants, `a if c else b`, boolean ops)."""
    for sub in ast.walk(node):
        if isinstance(sub, ast.Constant) and isinstance(sub.value, str):
            yield sub.value


def scan_file(path: Path) -> Tuple[List[Tuple[str, str]],
                                   List[Tuple[str, str]],
                                   List[Tuple[str, str]]]:
    """Returns (decline_literals, act_scale_keys, markers) as
    (literal, where) pairs for one python file."""
    tree = ast.parse(path.read_text(), filename=str(path))
    declines: List[Tuple[str, str]] = []
    act_keys: List[Tuple[str, str]] = []
    markers: List[Tuple[str, str]] = []
    rel = path.name

    class V(ast.NodeVisitor):
        def __init__(self):
            self.fn_stack: List[str] = []

        def visit_FunctionDef(self, node):
            self.fn_stack.append(node.name)
            self.generic_visit(node)
            self.fn_stack.pop()

        visit_AsyncFunctionDef = visit_FunctionDef

        def visit_Return(self, node):
            fn = self.fn_stack[-1] if self.fn_stack else ""
            if node.value is not None and "decline" in fn:
                for s in _const_strings(node.value):
                    if looks_like_code(s):
                        declines.append((s, f"{rel}::{fn}:{node.lineno}"))
            self.generic_visit(node)

        def visit_Call(self, node):
            name = ""
            if isinstance(node.func, ast.Name):
                name = node.func.id
            elif isinstance(node.func, ast.Attribute):
                name = node.func.attr
            where = f"{rel}:{node.lineno}"
            if name in ("decline", "_registered"):
                for arg in node.args:
                    for s in _const_strings(arg):
                        declines.append((s, where))
            if name == "record_act_scale":
                for arg in node.args:
                    for s in _const_strings(arg):
                        act_keys.append((s, where))
            self.generic_visit(node)

        def visit_Constant(self, node):
            if isinstance(node.value, str) and node.value.startswith("[") \
                    and node.value.endswith("]") and len(node.value) > 2 \
                    and node.value[1:-1].isidentifier():
                markers.append((node.value, f"{rel}:{node.lineno}"))

    V().visit(tree)
    return declines, act_keys, markers


def _doc_codes(path: Path, heading: str) -> Set[str]:
    """Backtick tokens that look like decline codes, taken from one
    heading's section only (up to the next `## `)."""
    text = path.read_text()
    m = re.search(rf"^##+\s+{re.escape(heading)}\s*$", text, re.MULTILINE)
    if m is None:
        return set()
    section = text[m.end():]
    nxt = re.search(r"^## ", section, re.MULTILINE)
    if nxt:
        section = section[:nxt.start()]
    return {tok for tok in re.findall(r"`([a-z0-9_]+)`", section)
            if looks_like_code(tok)}


def check(fixtures: Sequence[str] = ()) -> List[Finding]:
    from repro.backends.base import (ACT_SCALE_KEYS, ALL_DECLINE_CODES,
                                     DISPATCH_MARKERS)
    findings: List[Finding] = []

    repo_files = sorted(p for d in SCAN_DIRS for p in d.glob("*.py"))
    fixture_files = [Path(f) for f in fixtures if str(f).endswith(".py")]

    produced: Set[str] = set()
    for path, is_fixture in [(p, False) for p in repo_files] \
            + [(p, True) for p in fixture_files]:
        declines, act_keys, markers = scan_file(path)
        for code, where in declines:
            if code in ALL_DECLINE_CODES:
                if not is_fixture:
                    produced.add(code)
            else:
                findings.append(Finding(
                    "VOCAB_UNREGISTERED_CODE", where,
                    f"decline literal {code!r} is not registered in "
                    f"backends.base.DECLINE_CODES"))
        for key, where in act_keys:
            if key not in ACT_SCALE_KEYS:
                findings.append(Finding(
                    "VOCAB_BAD_STATS_KEY", where,
                    f"act-scale stats key {key!r} not in ACT_SCALE_KEYS "
                    f"{ACT_SCALE_KEYS}"))
        for marker, where in markers:
            if marker not in DISPATCH_MARKERS:
                findings.append(Finding(
                    "VOCAB_BAD_STATS_KEY", where,
                    f"dispatch marker {marker!r} not in DISPATCH_MARKERS "
                    f"{DISPATCH_MARKERS}"))

    for code in sorted(ALL_DECLINE_CODES - produced):
        findings.append(Finding(
            "VOCAB_UNUSED_CODE", "backends/base.py::DECLINE_CODES",
            f"registered decline code {code!r} is produced nowhere in "
            f"backends/ or kernels/"))

    documented: Set[str] = set()
    for path, heading in DOC_VOCAB:
        codes = _doc_codes(path, heading)
        documented |= codes
        for code in sorted(codes - ALL_DECLINE_CODES):
            findings.append(Finding(
                "VOCAB_DOC_DRIFT", f"{path.name}#{heading}",
                f"doc table lists {code!r}, which is not a registered "
                f"decline code"))
    for code in sorted(ALL_DECLINE_CODES - documented):
        findings.append(Finding(
            "VOCAB_UNDOCUMENTED_CODE", "docs/backends.md+docs/sharding.md",
            f"registered decline code {code!r} appears in neither quoted "
            f"doc table"))
    return findings
