"""`repro.analysis` — the repo-native static contract checker.

OliVe's encoding is *locally* checkable: one byte is one outlier-victim
pair, every scale travels with its tile, and every dispatch decline is a
registered code. This package turns those conventions into enforced
contracts, runnable as ``python -m repro.analysis`` (nonzero exit on
findings) and as pytest (`tests/test_analysis.py`). Four passes:

- **vocabulary** (`vocab.py`) — AST-scans `backends/` and `kernels/` for
  decline-code and dispatch-stats string literals and checks them against
  `backends/base.py::DECLINE_CODES` (+ the quoted copies in
  docs/backends.md and docs/sharding.md).
- **kernels** (`kernels.py`) — traces every registered `pallas_call`
  abstractly and checks grid/block divisibility, pair-aligned K tiling,
  page-size == decode-KV-tile, declared output aliasing, and a per-kernel
  VMEM footprint budget; sweeps the sharded row-parallel K-split
  predicate against the OVP pairing ground truth.
- **policies** (`policies.py`) — resolves every preset `PolicyProgram`
  (and any calibration artifact) against the real param trees of the
  config zoo, flagging dead rules, shadowed precedence, and globs that
  match nothing.
- **hygiene** (`hygiene.py`) — keeps bare/overbroad `except` handlers
  out of `src/repro/` (the typed-error pattern from `sharding/rules.py`).

`sanitize.py` is the runtime side: ``REPRO_SANITIZE=1`` turns on
`jax_debug_nans`, checkify assertions inside the OVP encode/decode
paths, and the serving engine's jit-trace-count audit. See
docs/static_analysis.md.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence


@dataclasses.dataclass(frozen=True)
class Finding:
    """One analyzer finding. `code` is a stable finding id (see
    docs/static_analysis.md), `where` a file/symbol anchor, `message`
    the human-readable defect statement."""
    code: str
    where: str
    message: str

    def __str__(self) -> str:
        return f"{self.code} {self.where}: {self.message}"


PASS_NAMES = ("vocab", "kernels", "policies", "hygiene")


def run_pass(name: str, fixtures: Sequence[str] = (),
             vmem_budget: Optional[int] = None) -> List[Finding]:
    """Run one pass by name. `fixtures` are extra .py files (seeded-
    violation modules) folded into the pass's scan/case set."""
    # pass modules import jax/the repo lazily so `import repro.analysis`
    # (e.g. from core/ovp.py's sanitizer hook) stays dependency-free
    if name == "vocab":
        from . import vocab
        return vocab.check(fixtures=fixtures)
    if name == "kernels":
        from . import kernels
        return kernels.check(fixtures=fixtures, vmem_budget=vmem_budget)
    if name == "policies":
        from . import policies
        return policies.check(fixtures=fixtures)
    if name == "hygiene":
        from . import hygiene
        return hygiene.check(fixtures=fixtures)
    raise KeyError(f"unknown analysis pass {name!r}; "
                   f"options: {PASS_NAMES}")


def run_all(passes: Sequence[str] = PASS_NAMES,
            fixtures: Sequence[str] = (),
            vmem_budget: Optional[int] = None) -> List[Finding]:
    findings: List[Finding] = []
    for name in passes:
        findings.extend(run_pass(name, fixtures=fixtures,
                                 vmem_budget=vmem_budget))
    return findings


__all__ = ["Finding", "PASS_NAMES", "run_pass", "run_all"]
