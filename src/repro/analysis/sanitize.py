"""REPRO_SANITIZE=1 — the runtime sanitizer mode.

Three wires, all off (zero overhead, not even a traced op) unless the
environment variable is set when the computation is built:

- `configure()` flips `jax_debug_nans` on, so any NaN materializing in a
  jitted step raises at the op that produced it instead of surfacing as
  garbage logits ten layers later.
- `check(pred, msg)` is a gated `checkify.check`: the OVP encode/decode
  paths (`core/ovp.py`) assert scale positivity and finiteness through
  it. The checks functionalize under jit when the enclosing computation
  is built by `jit_checked` (the serving engine does this for its decode
  and prefill steps); eager callers get the check evaluated immediately.
- the serving engine counts every jit trace it takes
  (`ServingEngine.trace_audit()`); `audit_traces(engine)` turns an
  unexpected retrace — a trace the bucket/stage-length cache should have
  absorbed — into a hard failure of the engine smoke.

This module imports nothing from the rest of the repo, so any layer
(core, kernels, serve) can hook it without cycles.
"""
from __future__ import annotations

import os
from typing import Callable, Dict

import jax
from jax.experimental import checkify


def enabled() -> bool:
    return os.environ.get("REPRO_SANITIZE", "") not in ("", "0")


def configure() -> None:
    """Install the global sanitizer config (idempotent). No-op unless
    REPRO_SANITIZE=1."""
    if enabled():
        jax.config.update("jax_debug_nans", True)


def check(pred, msg: str, **fmt) -> None:
    """Sanitizer assertion; nothing unless REPRO_SANITIZE=1 (the gate is
    a Python branch, so disabled runs trace zero extra ops).

    Concrete predicates assert immediately. Traced predicates become
    `checkify.check`s, which require the enclosing computation to be
    functionalized — build it with `jit_checked` (the serving engine's
    steps) or run it through `run_checked` (one-shot staged calls like
    `quantize_params`)."""
    if not enabled():
        return
    if isinstance(pred, jax.core.Tracer):
        checkify.check(pred, msg, **fmt)
    elif not bool(pred):
        raise AssertionError("REPRO_SANITIZE: " + msg.format(**fmt))


def jit_checked(fn: Callable) -> Callable:
    """`jax.jit(fn)`, plus checkify functionalization when sanitizing.

    The returned callable has the jit signature of `fn`: under
    REPRO_SANITIZE=1 it runs `jit(checkify(fn))`, throws on any failed
    `check` (as a `JaxRuntimeError` naming the check), and returns the
    payload — so call sites don't branch on the mode.
    """
    if not enabled():
        return jax.jit(fn)
    checked = jax.jit(checkify.checkify(fn, errors=checkify.user_checks))

    def wrapper(*args, **kwargs):
        err, out = checked(*args, **kwargs)
        err.throw()
        return out

    return wrapper


def run_checked(fn: Callable, *args, **kwargs):
    """Run one staged call (something that vmaps/scans internally, e.g.
    `quantize_params`) with its sanitizer checks functionalized; plain
    call when not sanitizing."""
    if not enabled():
        return fn(*args, **kwargs)
    err, out = checkify.checkify(fn, errors=checkify.user_checks)(
        *args, **kwargs)
    err.throw()
    return out


def audit_traces(engine) -> Dict[str, int]:
    """The jit-trace-count audit: returns the engine's `trace_audit()`
    ledger and raises if any trace happened that the prefill bucket /
    stage-length cache (or the single decode jit) should have absorbed.
    The sanitize engine smoke (`python -m repro.analysis
    --sanitize-smoke`) fails on exactly this."""
    audit = engine.trace_audit()
    if audit["unexpected_retraces"]:
        raise AssertionError(
            f"unexpected jit retraces under REPRO_SANITIZE=1: {audit} — "
            f"a shape/dtype/weak-type drifted between calls that should "
            f"share one trace")
    return audit
