"""Kernel-contract pass: abstract-eval every registered pallas_call.

Each `Case` traces one kernel entry point with `jax.make_jaxpr` (no
execution, no accelerator) and walks the jaxpr for `pallas_call`
equations; their `grid_mapping` / `input_output_aliases` params carry
the whole tiling contract. Checks, per pallas_call:

- **KC_NO_PALLAS_CALL** — the entry point traced to zero pallas_calls
  (the fused path silently fell back; the case is vacuous).
- **KC_BLOCK_INDIVISIBLE** — a block shape does not divide its operand
  shape. The wrappers in `kernels/ops.py` own padding and clamp blocks,
  so a non-divisor tile means a guard and a BlockSpec disagree.
- **KC_PAIR_SPLIT** — a K tile splits an outlier-victim pair: for int8
  codes (1 value per row) the K block must be even; packed nibbles are
  whole pairs by construction. Also sweeps
  `backends.sharded.row_shard_pair_aligned` against an independent
  shard-boundary ground truth.
- **KC_PAGE_TILE** — a paged kv/scale pool is tiled with a block that is
  not one whole page: the block-table indirection gathers per *page*,
  so any other tile reads across page boundaries.
- **KC_ALIAS_MISSING** — a kernel that rewrites pool leaves does not
  declare `input_output_aliases` for them (pages no tile touches would
  come back uninitialized instead of intact).
- **KC_VMEM_BUDGET** — the summed live-block footprint (block shape x
  itemsize over every operand and output) exceeds the budget
  (default 16 MiB ~ one TPU core's VMEM; override with
  `--vmem-budget` or `REPRO_VMEM_BUDGET`).

Fixture modules may define `analysis_cases() -> [dict]` (Case kwargs);
their cases are appended to the repo set.
"""
from __future__ import annotations

import dataclasses
import functools
import importlib.util
import math
import os
from pathlib import Path
from typing import Callable, List, Optional, Sequence, Tuple

from . import Finding

DEFAULT_VMEM_BUDGET = 16 * 1024 * 1024


@dataclasses.dataclass
class Case:
    """One traced kernel entry point plus its contract expectations.

    `build()` returns `(fn, args)`; the pass traces `fn(*args)`.
    `pair_blocks` lists `(array_shape, axis, values_per_row)` operands
    whose K tile must hold whole pairs; `page_tiles` lists
    `(array_shape, axis)` pool operands whose tile must be one whole
    page; `min_aliases` is the number of input->output alias pairs the
    call must declare. Operands are matched by exact array shape.
    """
    name: str
    build: Callable[[], Tuple[Callable, tuple]]
    pair_blocks: Tuple[Tuple[Tuple[int, ...], int, int], ...] = ()
    page_tiles: Tuple[Tuple[Tuple[int, ...], int], ...] = ()
    min_aliases: int = 0


# --------------------------------------------------------------------------
# jaxpr walking (same recursion as backends.count_pallas_calls)
# --------------------------------------------------------------------------
def _sub_jaxprs(v):
    if isinstance(v, (tuple, list)):
        for item in v:
            yield from _sub_jaxprs(item)
    else:
        inner = getattr(v, "jaxpr", None)
        if inner is not None:
            yield inner
        elif hasattr(v, "eqns"):
            yield v


def _iter_pallas_eqns(jaxpr):
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == "pallas_call":
            yield eqn
        for v in eqn.params.values():
            for inner in _sub_jaxprs(v):
                yield from _iter_pallas_eqns(inner)


def _blocks(eqn):
    """[(block_shape_ints, array_shape, itemsize)] for every operand and
    output of one pallas_call equation."""
    gm = eqn.params["grid_mapping"]
    out = []
    for bm in gm.block_mappings:
        sds = bm.array_shape_dtype
        block = tuple(d for d in bm.block_shape if isinstance(d, int))
        out.append((block, tuple(sds.shape), sds.dtype.itemsize))
    return out


def _kernel_name(eqn) -> str:
    info = eqn.params.get("name_and_src_info")
    return getattr(info, "name", None) or str(info)


def _alias_count(eqn) -> int:
    aliases = eqn.params.get("input_output_aliases") or ()
    if isinstance(aliases, dict):
        return len(aliases)
    return len(tuple(aliases))


# --------------------------------------------------------------------------
# The repo's kernel entry points as cases
# --------------------------------------------------------------------------
def _repo_cases() -> List[Case]:
    import jax.numpy as jnp
    import repro.backends  # noqa: F401 — entering the package through
    # kernels/ first would trip the core<->backends import cycle
    from repro.kernels import (decode_attn, ovp_encode, ovp_matmul,
                               prefill_attn)

    def mk_fused_w4():
        a = jnp.zeros((1, 128, 256), jnp.float32)
        sa = jnp.ones((1, 128, 1), jnp.float32)
        wd = jnp.zeros((128, 128), jnp.uint8)     # K/2 packed rows
        sw = jnp.ones((1, 128), jnp.float32)
        fn = functools.partial(ovp_matmul.fused_ovp_matmul_kernel,
                               w_dtype="int4", a_mode="fp", interpret=True)
        return fn, (a, sa, wd, sw)

    def mk_fused_w8():
        a = jnp.zeros((1, 128, 256), jnp.float32)
        sa = jnp.ones((1, 128, 1), jnp.float32)
        wd = jnp.zeros((256, 128), jnp.uint8)     # K int8 rows
        sw = jnp.ones((1, 128), jnp.float32)
        fn = functools.partial(ovp_matmul.fused_ovp_matmul_kernel,
                               w_dtype="int8", a_mode="fp", interpret=True)
        return fn, (a, sa, wd, sw)

    def mk_grouped_w4():
        a = jnp.zeros((1, 2, 128, 256), jnp.float32)
        sa = jnp.ones((1, 2, 128, 1), jnp.float32)
        wd = jnp.zeros((2, 128, 128), jnp.uint8)
        sw = jnp.ones((2, 1, 128), jnp.float32)
        fn = functools.partial(ovp_matmul.grouped_ovp_matmul_kernel,
                               w_dtype="int4", a_mode="fp", interpret=True)
        return fn, (a, sa, wd, sw)

    def mk_grouped_w8():
        a = jnp.zeros((1, 2, 128, 256), jnp.float32)
        sa = jnp.ones((1, 2, 128, 1), jnp.float32)
        wd = jnp.zeros((2, 256, 128), jnp.uint8)
        sw = jnp.ones((2, 1, 128), jnp.float32)
        fn = functools.partial(ovp_matmul.grouped_ovp_matmul_kernel,
                               w_dtype="int8", a_mode="fp", interpret=True)
        return fn, (a, sa, wd, sw)

    def mk_encode():
        u = jnp.zeros((256, 512), jnp.float32)
        return functools.partial(ovp_encode.ovp_encode_pallas,
                                 interpret=True), (u,)

    hkv, g, d, ps, n_pages, n_log = 2, 2, 16, 8, 4, 2
    h = hkv * g

    def mk_decode_slab():
        s = 32
        cache = {"k_data": jnp.zeros((1, s, hkv, d // 2), jnp.uint8),
                 "v_data": jnp.zeros((1, s, hkv, d // 2), jnp.uint8),
                 "k_scl": jnp.ones((1, s, hkv), jnp.float32),
                 "v_scl": jnp.ones((1, s, hkv), jnp.float32)}
        q = jnp.zeros((1, 1, h, d), jnp.float32)
        pos = jnp.array([7], jnp.int32)
        fn = functools.partial(decode_attn.fused_decode_attention,
                               interpret=True)
        return (lambda q, pos: fn(q, cache, pos)), (q, pos)

    def _paged_pools():
        return {"k_data": jnp.zeros((n_pages, ps, hkv, d // 2), jnp.uint8),
                "v_data": jnp.zeros((n_pages, ps, hkv, d // 2), jnp.uint8),
                "k_scl": jnp.ones((n_pages, ps, hkv), jnp.float32),
                "v_scl": jnp.ones((n_pages, ps, hkv), jnp.float32),
                "block_table": jnp.arange(n_log, dtype=jnp.int32)[None]}

    def mk_decode_paged():
        cache = _paged_pools()
        q = jnp.zeros((1, 1, h, d), jnp.float32)
        pos = jnp.array([ps * n_log - 1], jnp.int32)
        fn = functools.partial(decode_attn.fused_decode_attention,
                               interpret=True)
        return (lambda q, pos: fn(q, cache, pos)), (q, pos)

    def mk_prefill_paged():
        c = 4
        cache = _paged_pools()
        cache["stage_k"] = jnp.zeros((1, ps * n_log, hkv, d), jnp.float32)
        cache["stage_v"] = jnp.zeros((1, ps * n_log, hkv, d), jnp.float32)
        q = jnp.zeros((1, c, h, d), jnp.float32)
        positions = jnp.arange(c, dtype=jnp.int32)[None]
        fn = functools.partial(prefill_attn.fused_prefill_attention,
                               interpret=True)
        return (lambda q, positions: fn(q, cache, positions)), (q, positions)

    pool_d = (n_pages, ps, hkv, d // 2)
    pool_s = (n_pages, ps, hkv)
    page_tiles = (((pool_d), 1), ((pool_s), 1))
    return [
        Case("fused_matmul_w4a16", mk_fused_w4),
        Case("fused_matmul_w8a16", mk_fused_w8,
             pair_blocks=(((256, 128), 0, 1),)),
        Case("grouped_matmul_w4a16", mk_grouped_w4),
        Case("grouped_matmul_w8a16", mk_grouped_w8,
             pair_blocks=(((2, 256, 128), 1, 1),)),
        Case("ovp_encode", mk_encode),
        Case("decode_attn_slab_packed", mk_decode_slab),
        Case("decode_attn_paged_packed", mk_decode_paged,
             page_tiles=page_tiles),
        Case("prefill_attn_paged_packed", mk_prefill_paged,
             page_tiles=page_tiles, min_aliases=4),
    ]


def _load_fixture_cases(path: Path) -> List[Case]:
    spec = importlib.util.spec_from_file_location(
        f"_analysis_fixture_{path.stem}", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    maker = getattr(mod, "analysis_cases", None)
    if maker is None:
        return []
    return [c if isinstance(c, Case) else Case(**c) for c in maker()]


# --------------------------------------------------------------------------
# Checks
# --------------------------------------------------------------------------
def _check_case(case: Case, vmem_budget: int) -> List[Finding]:
    import jax
    findings: List[Finding] = []
    fn, args = case.build()
    closed = jax.make_jaxpr(fn)(*args)
    eqns = list(_iter_pallas_eqns(closed.jaxpr))
    if not eqns:
        return [Finding("KC_NO_PALLAS_CALL", case.name,
                        "entry point traced to zero pallas_calls — the "
                        "fused path silently fell back")]

    all_blocks = []
    for eqn in eqns:
        kname = _kernel_name(eqn)
        where = f"{case.name}/{kname}"
        blocks = _blocks(eqn)
        all_blocks.extend(blocks)
        for block, arr, _ in blocks:
            for bdim, adim in zip(block, arr[-len(block):] if block
                                  else arr):
                if bdim and adim % bdim:
                    findings.append(Finding(
                        "KC_BLOCK_INDIVISIBLE", where,
                        f"block {block} does not divide operand {arr}"))
                    break
        footprint = sum(math.prod(block) * itemsize
                        for block, _, itemsize in blocks)
        if footprint > vmem_budget:
            findings.append(Finding(
                "KC_VMEM_BUDGET", where,
                f"live-block footprint {footprint} B exceeds the VMEM "
                f"budget {vmem_budget} B"))

    def _find(shape):
        return [b for b in all_blocks if b[1] == tuple(shape)]

    for arr_shape, axis, vpr in case.pair_blocks:
        hits = _find(arr_shape)
        if not hits:
            findings.append(Finding(
                "KC_PAIR_SPLIT", case.name,
                f"no pallas operand with shape {tuple(arr_shape)} — pair "
                f"tiling contract is unverifiable"))
            continue
        for block, arr, _ in hits:
            if (block[axis] * vpr) % 2:
                findings.append(Finding(
                    "KC_PAIR_SPLIT", case.name,
                    f"K tile {block} of operand {arr} holds "
                    f"{block[axis] * vpr} values along axis {axis} — an "
                    f"odd count splits an outlier-victim pair"))

    for arr_shape, axis in case.page_tiles:
        for block, arr, _ in _find(arr_shape):
            if block[axis] != arr[axis]:
                findings.append(Finding(
                    "KC_PAGE_TILE", case.name,
                    f"pool {arr} tiled with block {block}: the kv tile "
                    f"along axis {axis} is {block[axis]}, not the page "
                    f"size {arr[axis]}"))

    if case.min_aliases:
        declared = max(_alias_count(eqn) for eqn in eqns)
        if declared < case.min_aliases:
            findings.append(Finding(
                "KC_ALIAS_MISSING", case.name,
                f"kernel rewrites {case.min_aliases} pool leaves but "
                f"declares only {declared} input_output_aliases"))
    return findings


def _shard_boundary_aligned(k_rows: int, tp: int, packed: bool) -> bool:
    """Independent ground truth for the row-parallel K split: pairs are
    consecutive value indices (2p, 2p+1), shards hold contiguous row
    ranges, and every shard must locally decode whole pairs — so K must
    divide and every shard's END (including the last one's, the total
    value count) must land on an even value index."""
    if k_rows % tp != 0:
        return False
    per_shard = (k_rows // tp) * (2 if packed else 1)
    return all((s * per_shard) % 2 == 0 for s in range(1, tp + 1))


def _check_shard_split() -> List[Finding]:
    from repro.backends.sharded import row_shard_pair_aligned
    findings: List[Finding] = []
    for packed in (False, True):
        for tp in (1, 2, 3, 4, 8):
            for k_rows in range(1, 65):
                got = row_shard_pair_aligned(k_rows, tp, packed)
                want = _shard_boundary_aligned(k_rows, tp, packed)
                if got != want:
                    findings.append(Finding(
                        "KC_SHARD_SPLIT",
                        "backends/sharded.py::row_shard_pair_aligned",
                        f"k_rows={k_rows} tp={tp} packed={packed}: "
                        f"predicate says {got}, shard-boundary ground "
                        f"truth says {want}"))
    return findings


def check(fixtures: Sequence[str] = (),
          vmem_budget: Optional[int] = None) -> List[Finding]:
    if vmem_budget is None:
        vmem_budget = int(os.environ.get("REPRO_VMEM_BUDGET",
                                         DEFAULT_VMEM_BUDGET))
    cases = _repo_cases()
    for f in fixtures:
        if str(f).endswith(".py"):
            cases.extend(_load_fixture_cases(Path(f)))
    findings: List[Finding] = []
    for case in cases:
        findings.extend(_check_case(case, vmem_budget))
    findings.extend(_check_shard_split())
    return findings
