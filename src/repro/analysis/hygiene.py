"""Hygiene pass: no broad exception handlers in `src/repro/`.

A bare ``except:``, ``except Exception``, or ``except BaseException``
swallows typed failures the dispatch layer is supposed to surface as
decline codes or hard errors (the bug class PR 8 fixed in
`sharding/rules.py`). Handlers must name the exception types they mean,
as a tuple if there are several. A handler that *re-raises* the broad
class unconditionally is fine — that is narrowing, not swallowing.
"""
from __future__ import annotations

import ast
from pathlib import Path
from typing import List, Sequence

from . import Finding

REPO = Path(__file__).resolve().parents[3]
SRC = REPO / "src" / "repro"

_BROAD = ("Exception", "BaseException")


def _names(expr: ast.AST) -> List[str]:
    """Exception-class names mentioned by an `except <expr>` clause."""
    out: List[str] = []
    for sub in ast.walk(expr):
        if isinstance(sub, ast.Name):
            out.append(sub.id)
        elif isinstance(sub, ast.Attribute):
            out.append(sub.attr)
    return out


def _always_reraises(handler: ast.ExceptHandler) -> bool:
    """True when the handler body ends in a bare `raise` at top level —
    it inspects/annotates and re-raises, rather than swallowing."""
    return any(isinstance(stmt, ast.Raise) and stmt.exc is None
               for stmt in handler.body)


def scan_file(path: Path) -> List[Finding]:
    findings: List[Finding] = []
    tree = ast.parse(path.read_text(), filename=str(path))
    rel = path.relative_to(REPO).as_posix() if path.is_relative_to(REPO) \
        else path.name
    for node in ast.walk(tree):
        if not isinstance(node, ast.ExceptHandler):
            continue
        if node.type is None:
            findings.append(Finding(
                "HYG_BROAD_EXCEPT", f"{rel}:{node.lineno}",
                "bare `except:` — name the exception types this handler "
                "means (tuple of types, per sharding/rules.py)"))
            continue
        broad = [n for n in _names(node.type) if n in _BROAD]
        if broad and not _always_reraises(node):
            findings.append(Finding(
                "HYG_BROAD_EXCEPT", f"{rel}:{node.lineno}",
                f"`except {broad[0]}` swallows typed failures — name the "
                f"exception types this handler means (tuple of types, "
                f"per sharding/rules.py)"))
    return findings


def check(fixtures: Sequence[str] = ()) -> List[Finding]:
    files = sorted(SRC.rglob("*.py"))
    files += [Path(f) for f in fixtures if str(f).endswith(".py")]
    findings: List[Finding] = []
    for path in files:
        findings.extend(scan_file(path))
    return findings
