"""Qwen2-7B: GQA with QKV bias [arXiv:2407.10671; hf]."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-7b",
    family="dense",
    n_layers=28,
    d_model=3584,
    n_heads=28,
    n_kv_heads=4,
    d_ff=18944,
    vocab=152064,
    head_dim=128,
    qkv_bias=True,
    mlp_kind="swiglu",
    block_pattern=("attn",),
    rope_theta=1e6,
    source="arXiv:2407.10671; hf",
)
