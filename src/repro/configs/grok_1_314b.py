"""Grok-1 (314B): 8-expert top-2 MoE [hf:xai-org/grok-1; unverified]."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="grok-1-314b",
    family="moe",
    n_layers=64,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=32768,
    vocab=131072,
    head_dim=128,
    n_experts=8,
    top_k=2,
    norm_topk=False,
    mlp_kind="swiglu",
    block_pattern=("moe",),
    source="hf:xai-org/grok-1; unverified",
)
