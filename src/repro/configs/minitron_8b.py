"""Minitron-8B: width/depth-pruned Nemotron-4 [arXiv:2407.14679; hf]."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="minitron-8b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=16384,
    vocab=256000,
    head_dim=128,
    mlp_kind="swiglu",
    block_pattern=("attn",),
    source="arXiv:2407.14679; hf",
)
