"""Yi-6B: llama-architecture GQA [arXiv:2403.04652; hf]."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="yi-6b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=4,
    d_ff=11008,
    vocab=64000,
    head_dim=128,
    mlp_kind="swiglu",
    block_pattern=("attn",),
    rope_theta=5e6,
    source="arXiv:2403.04652; hf",
)
