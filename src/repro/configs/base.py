"""Architecture + shape configuration schema.

Every assigned architecture is an `ArchConfig`; the four canonical input
shapes are `ShapeCfg`s. `reduced()` produces the smoke-test variant of the
same family (small widths, few layers/experts, tiny vocab).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                      # dense | hybrid | ssm | moe | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0                # 0 -> d_model // n_heads
    qkv_bias: bool = False
    mlp_kind: str = "swiglu"         # swiglu | gelu
    # MoE
    n_experts: int = 0
    top_k: int = 0
    norm_topk: bool = False
    capacity_factor: float = 1.25
    # block pattern (repeating period); tail = n_layers % len(pattern)
    block_pattern: Tuple[str, ...] = ("attn",)
    window: int = 0                  # sliding window for local_attn blocks
    d_rnn: int = 0                   # RG-LRU width (0 -> d_model)
    # encoder-decoder
    enc_dec: bool = False
    n_enc_layers: int = 0
    # modality frontend stubs
    frontend: str = ""               # "" | vit | audio
    frontend_dim: int = 0
    n_frontend_tokens: int = 0
    # misc
    rope_theta: float = 1e4
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    # mLSTM training formulation: chunkwise-parallel chunk length
    # (0 = per-token recurrent scan; §Perf iteration X)
    mlstm_chunk: int = 64
    source: str = ""                 # provenance tag

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim",
                               self.d_model // self.n_heads)

    @property
    def padded_vocab(self) -> int:
        """Vocab rounded up to a multiple of 256 (= 16 data x 16 model).

        Production TP practice (MaxText et al.): embedding/head tables are
        padded so the vocab dim shards on any production mesh axis; the
        pad columns are masked to -inf in the logits. Without this, a
        non-divisible vocab (e.g. seamless 256206, internvl 151655) forces
        the partitioner to shard the table's d_model dim instead, which
        collides with the microbatch scan's dynamic-slice after SPMD
        partitioning (a real compile failure found by the dry-run).
        """
        return -(-self.vocab // 256) * 256

    @property
    def sub_quadratic(self) -> bool:
        """True if decode memory/compute doesn't grow O(T²)/O(T) cache in
        full attention — i.e. every block is recurrent or windowed."""
        return all(bt in ("rglru", "mlstm", "slstm", "local_attn")
                   for bt in self.block_pattern)

    @property
    def has_decoder(self) -> bool:
        return True  # all assigned archs decode (enc-dec has a decoder)

    def moe_block_count(self) -> int:
        """Number of MoE blocks in the layer stack."""
        return sum(1 for i in range(self.n_layers)
                   if self.block_pattern[i % len(self.block_pattern)]
                   == "moe")

    def param_count(self) -> int:
        """Approximate parameter count (embeddings + blocks)."""
        d, hd = self.d_model, self.head_dim
        n_attn_p = d * hd * (self.n_heads + 2 * self.n_kv_heads) \
            + self.n_heads * hd * d
        if self.mlp_kind == "swiglu":
            mlp = 3 * d * self.d_ff
        else:
            mlp = 2 * d * self.d_ff
        total = 0
        pattern = self.block_pattern
        per = {
            "attn": n_attn_p + mlp,
            "local_attn": n_attn_p + mlp,
            "moe": n_attn_p + self.n_experts * 3 * d * self.d_ff
            + d * self.n_experts,
            "rglru": (self.d_rnn or d) * (2 * d + d)
            + 2 * (self.d_rnn or d) ** 2 + mlp,
            "mlstm": 2 * d * (4 * d) + 3 * (2 * d) ** 2 + 2 * d * d,
            "slstm": 4 * d * d + 3 * d * (d // max(self.n_heads, 1))
            + 2 * d * int(4 * d / 3),
            "encdec_attn": 2 * n_attn_p + mlp,
        }
        for i in range(self.n_layers):
            total += per[pattern[i % len(pattern)]]
        if self.enc_dec:
            total += self.n_enc_layers * (n_attn_p + mlp)
        total += 2 * self.vocab * d  # embed + head
        return total

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top-k experts only)."""
        if not self.n_experts:
            return self.param_count()
        d = self.d_model
        full = self.param_count()
        inactive = self.moe_block_count() * (self.n_experts - self.top_k) \
            * 3 * d * self.d_ff
        return full - inactive

    def reduced(self) -> "ArchConfig":
        """Smoke-test variant: same family/pattern, tiny dimensions."""
        period = len(self.block_pattern)
        return dataclasses.replace(
            self,
            name=self.name + "-smoke",
            n_layers=max(2 * period, period + self.n_layers % period),
            d_model=64,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads
            < self.n_heads else 4,
            head_dim=16,
            d_ff=128 if self.d_ff else 0,
            vocab=512,
            n_experts=min(self.n_experts, 8) if self.n_experts else 0,
            top_k=min(self.top_k, 2) if self.top_k else 0,
            window=min(self.window, 8) if self.window else 0,
            d_rnn=64 if self.d_rnn else 0,
            n_enc_layers=2 if self.enc_dec else 0,
            frontend_dim=32 if self.frontend else 0,
            n_frontend_tokens=4 if self.frontend else 0,
        )


@dataclasses.dataclass(frozen=True)
class ShapeCfg:
    name: str
    seq_len: int
    global_batch: int
    kind: str          # train | prefill | decode


SHAPES = {
    "train_4k": ShapeCfg("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCfg("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCfg("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCfg("long_500k", 524288, 1, "decode"),
}


def shape_applicable(cfg: ArchConfig, shape: ShapeCfg) -> Tuple[bool, str]:
    """(runs?, reason-if-skipped). long_500k needs sub-quadratic attention."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, ("full-attention architecture: 524k-token decode is "
                       "O(T) cache / O(T^2) prefill — skipped per "
                       "assignment rule (see DESIGN.md §5)")
    return True, ""
