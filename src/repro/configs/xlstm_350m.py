"""xLSTM-350M: alternating mLSTM / sLSTM blocks [arXiv:2405.04517;
unverified]. d_ff=0: xLSTM blocks carry their own up/down projections
(mLSTM pf=2 pre-up-projection, sLSTM pf=4/3 post-up-projection)."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="xlstm-350m",
    family="ssm",
    n_layers=24,
    d_model=1024,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab=50304,
    head_dim=256,
    block_pattern=("mlstm", "slstm"),
    source="arXiv:2405.04517; unverified",
)
