"""Qwen3-30B-A3B: 128-expert top-8 MoE, GQA kv=4, head_dim 128
[hf:Qwen/Qwen3-30B-A3B]. d_ff=768 is the per-expert intermediate size."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-moe-30b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=4,
    d_ff=768,
    vocab=151936,
    head_dim=128,
    n_experts=128,
    top_k=8,
    norm_topk=True,
    mlp_kind="swiglu",
    block_pattern=("moe",),
    rope_theta=1e6,
    source="hf:Qwen/Qwen3-30B-A3B",
)
