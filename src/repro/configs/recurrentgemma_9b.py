"""RecurrentGemma-9B (Griffin): RG-LRU + local attention, 2:1 pattern
[arXiv:2402.19427; unverified].

38 layers = 12 × (rglru, rglru, local_attn) + 2 rglru tail. MQA (kv=1),
head_dim 256, window 2048.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    n_layers=38,
    d_model=4096,
    n_heads=16,
    n_kv_heads=1,
    d_ff=12288,
    vocab=256000,
    head_dim=256,
    mlp_kind="swiglu",
    block_pattern=("rglru", "rglru", "local_attn"),
    window=2048,
    d_rnn=4096,
    source="arXiv:2402.19427; unverified",
)
