"""InternVL2-1B: InternViT frontend (STUB) + Qwen2-0.5B-style backbone
[arXiv:2404.16821; hf]. input_specs() supplies precomputed 1024-d patch
embeddings (256 tokens); the in-model projector maps them to d_model."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="internvl2-1b",
    family="vlm",
    n_layers=24,
    d_model=896,
    n_heads=14,
    n_kv_heads=2,
    d_ff=4864,
    vocab=151655,
    head_dim=64,
    qkv_bias=True,
    mlp_kind="swiglu",
    block_pattern=("attn",),
    frontend="vit",
    frontend_dim=1024,
    n_frontend_tokens=256,
    rope_theta=1e6,
    source="arXiv:2404.16821; hf",
)
