"""SeamlessM4T-large-v2: encoder-decoder, multimodal [arXiv:2308.11596; hf].

Backbone only per assignment: 24 encoder + 24 decoder layers over STUB
audio frame embeddings (160-d fbank features -> in-model input projection).
RoPE replaces the original learned positions (TPU-idiomatic, noted in
DESIGN.md); GELU MLPs as in the original.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="seamless-m4t-large-v2",
    family="audio",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=8192,
    vocab=256206,
    head_dim=64,
    qkv_bias=True,
    mlp_kind="gelu",
    block_pattern=("encdec_attn",),
    enc_dec=True,
    n_enc_layers=24,
    frontend="audio",
    frontend_dim=160,
    n_frontend_tokens=0,
    source="arXiv:2308.11596; hf",
)
