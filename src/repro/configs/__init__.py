"""Architecture registry: the 10 assigned configs + input shapes."""
from .base import SHAPES, ArchConfig, ShapeCfg, shape_applicable

from . import (grok_1_314b, internvl2_1b, minitron_8b, qwen1_5_0_5b,
               qwen2_7b, qwen3_moe_30b_a3b, recurrentgemma_9b,
               seamless_m4t_large_v2, xlstm_350m, yi_6b)

ARCHS = {
    m.CONFIG.name: m.CONFIG
    for m in (minitron_8b, qwen2_7b, qwen1_5_0_5b, yi_6b,
              recurrentgemma_9b, xlstm_350m, qwen3_moe_30b_a3b,
              grok_1_314b, internvl2_1b, seamless_m4t_large_v2)
}


def get_config(name: str) -> ArchConfig:
    if name.endswith("-smoke"):
        return ARCHS[name[:-len("-smoke")]].reduced()
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; options: {sorted(ARCHS)}")
    return ARCHS[name]


def get_shape(name: str) -> ShapeCfg:
    if name not in SHAPES:
        raise KeyError(f"unknown shape {name!r}; options: {sorted(SHAPES)}")
    return SHAPES[name]
