"""repro: OliVe (ISCA'23) outlier-victim pair quantization — a multi-pod
JAX training/serving framework."""
__version__ = "1.0.0"
