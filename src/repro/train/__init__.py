from .train_step import TrainState, init_state, lm_loss, make_train_step
from .trainer import Trainer, TrainerCfg
