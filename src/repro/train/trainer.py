"""Trainer: the fault-tolerant training loop.

Wires together the stateless loader, jit'd train step, async checkpointing,
preemption handling, and the straggler monitor. Restart-safe: resuming from
step N replays the exact data stream from N (stateless loader) on top of
the restored state.
"""
from __future__ import annotations

import dataclasses
import os
import time
from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import ckpt
from repro.data.loader import LoaderCfg, SyntheticLoader
from repro.models.model import Model
from repro.optim.adamw import AdamW
from repro.runtime.fault import PreemptionHandler, StepTimer, \
    StragglerMonitor
from .train_step import TrainState, init_state, make_train_step


@dataclasses.dataclass
class TrainerCfg:
    total_steps: int = 100
    ckpt_dir: str = ""
    ckpt_every: int = 50
    ckpt_async: bool = True
    eval_every: int = 0
    eval_batches: int = 2
    log_every: int = 10
    n_microbatches: int = 1
    seed: int = 0


class Trainer:
    def __init__(self, model: Model, optimizer: AdamW,
                 loader: SyntheticLoader, tcfg: TrainerCfg,
                 log_fn: Callable[[str], None] = print):
        self.model = model
        self.optimizer = optimizer
        self.loader = loader
        self.tcfg = tcfg
        self.log = log_fn
        self.preempt = PreemptionHandler()
        self.monitor = StragglerMonitor(n_hosts=1)
        self.step_fn = jax.jit(make_train_step(
            model, optimizer, n_microbatches=tcfg.n_microbatches))
        self.state: Optional[TrainState] = None
        self.step = 0
        self._pending_save = None

    # ------------------------------------------------------------ state
    def init_or_restore(self):
        template = init_state(self.model, self.optimizer,
                              jax.random.PRNGKey(self.tcfg.seed))
        start = None
        if self.tcfg.ckpt_dir:
            start = ckpt.latest_step(self.tcfg.ckpt_dir)
        if start is not None:
            self.state = ckpt.restore(self.tcfg.ckpt_dir, start,
                                      {"state": template})["state"]
            self.step = start
            self.log(f"[trainer] restored step {start} from "
                     f"{self.tcfg.ckpt_dir}")
        else:
            self.state = template
            self.step = 0
        return self

    def save(self, blocking=False, tag=""):
        if not self.tcfg.ckpt_dir:
            return
        if self._pending_save is not None:
            self._pending_save.join()
        self._pending_save = ckpt.save(
            self.tcfg.ckpt_dir, self.step, {"state": self.state},
            blocking=blocking or not self.tcfg.ckpt_async)
        if tag:
            self.log(f"[trainer] checkpoint @ step {self.step} ({tag})")

    # ------------------------------------------------------------- loop
    def run(self) -> Dict[str, list]:
        assert self.state is not None, "call init_or_restore() first"
        history = {"step": [], "loss": [], "step_time": []}
        while self.step < self.tcfg.total_steps:
            if self.preempt.should_stop:
                self.save(blocking=True, tag="preemption")
                self.log(f"[trainer] preempted at step {self.step}; "
                         "state saved")
                break
            batch = self.loader.global_batch_at(self.step)
            with StepTimer(self.monitor, host=0) as t:
                self.state, metrics = self.step_fn(self.state, batch)
                jax.block_until_ready(metrics["loss"])
            self.step += 1
            if self.step % self.tcfg.log_every == 0 or \
                    self.step == self.tcfg.total_steps:
                self.log(f"[trainer] step {self.step} "
                         f"loss {float(metrics['loss']):.4f} "
                         f"gnorm {float(metrics['grad_norm']):.3f} "
                         f"({t.last * 1e3:.0f} ms)")
            history["step"].append(self.step)
            history["loss"].append(float(metrics["loss"]))
            history["step_time"].append(t.last)
            if self.tcfg.ckpt_every and \
                    self.step % self.tcfg.ckpt_every == 0:
                self.save(tag="periodic")
            if self.tcfg.eval_every and \
                    self.step % self.tcfg.eval_every == 0:
                ppl = self.evaluate()
                self.log(f"[trainer] step {self.step} eval ppl {ppl:.3f}")
            if not self.monitor.healthy():
                self.log(f"[trainer] stragglers: "
                         f"{self.monitor.stragglers()}")
        self.save(blocking=True, tag="final")
        return history

    # ------------------------------------------------------------- eval
    def evaluate(self, n_batches: Optional[int] = None) -> float:
        from .train_step import lm_loss
        n = n_batches or self.tcfg.eval_batches
        tot, cnt = 0.0, 0
        loss_j = jax.jit(lambda p, b: lm_loss(self.model, p, b)[1]["ce"])
        for i in range(n):
            batch = self.loader.global_batch_at(i, eval_split=True)
            tot += float(loss_j(self.state.params, batch))
            cnt += 1
        return float(np.exp(tot / max(cnt, 1)))
