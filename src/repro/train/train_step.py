"""Training step: LM loss, gradient accumulation (microbatching), bf16
gradient compression, AdamW update — one jit-able function suitable for
pjit on the production mesh.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models.model import Model
from repro.optim.adamw import AdamW, AdamWState


class TrainState(NamedTuple):
    params: Any
    opt: AdamWState


def lm_loss(model: Model, params, batch: Dict[str, jax.Array],
            aux_weight: float = 0.01):
    """Next-token CE (fp32 logits); VLM patch positions are excluded."""
    logits, _, aux = model.forward(params, batch, mode="train")
    labels = batch["labels"]
    t = labels.shape[1]
    lg = logits[:, -t:]
    ll = jax.nn.log_softmax(lg.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(ll, labels[..., None], axis=-1)[..., 0]
    mask = batch.get("loss_mask")
    if mask is not None:
        nll = nll * mask
        denom = jnp.maximum(jnp.sum(mask), 1.0)
    else:
        denom = nll.size
    loss = jnp.sum(nll) / denom
    return loss + aux_weight * aux, {"ce": loss, "aux": aux}


def make_train_step(model: Model, optimizer: AdamW, *,
                    n_microbatches: int = 1,
                    grad_dtype=jnp.bfloat16,
                    donate: bool = True):
    """Returns train_step(state, batch) -> (state, metrics).

    n_microbatches > 1: the global batch is split on axis 0 and gradients
    accumulate in `grad_dtype` across a lax.scan — activation memory scales
    with the microbatch, and the cross-replica reduction XLA inserts runs
    on the compressed dtype (the gradient-compression trick, DESIGN.md §4).
    """

    def grads_of(params, batch):
        (loss, parts), grads = jax.value_and_grad(
            lambda p: lm_loss(model, p, batch), has_aux=True)(params)
        grads = jax.tree_util.tree_map(
            lambda g: g.astype(grad_dtype), grads)
        return loss, parts, grads

    def train_step(state: TrainState, batch: Dict[str, jax.Array]):
        if n_microbatches == 1:
            loss, parts, grads = grads_of(state.params, batch)
        else:
            def slice_mb(x):
                b = x.shape[0]
                mb = b // n_microbatches
                return x.reshape(n_microbatches, mb, *x.shape[1:])

            mbs = jax.tree_util.tree_map(slice_mb, batch)
            zero = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, grad_dtype), state.params)

            def body(acc, mb):
                loss_i, parts_i, g_i = grads_of(state.params, mb)
                acc_g = jax.tree_util.tree_map(jnp.add, acc[0], g_i)
                return (acc_g, acc[1] + loss_i,
                        jax.tree_util.tree_map(jnp.add, acc[2], parts_i)), \
                    None

            init = (zero, jnp.zeros(()), {"ce": jnp.zeros(()),
                                          "aux": jnp.zeros(())})
            (gsum, loss_sum, parts_sum), _ = jax.lax.scan(body, init, mbs)
            inv = 1.0 / n_microbatches
            grads = jax.tree_util.tree_map(
                lambda g: (g.astype(jnp.float32) * inv).astype(grad_dtype),
                gsum)
            loss = loss_sum * inv
            parts = jax.tree_util.tree_map(lambda x: x * inv, parts_sum)

        new_params, new_opt, opt_metrics = optimizer.update(
            grads, state.opt, state.params)
        metrics = {"loss": loss, **parts, **opt_metrics}
        return TrainState(new_params, new_opt), metrics

    return train_step


def init_state(model: Model, optimizer: AdamW, key,
               dtype=jnp.float32) -> TrainState:
    params = model.init(key, dtype=dtype)
    return TrainState(params=params, opt=optimizer.init(params))
