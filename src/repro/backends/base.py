"""Backend interface + the canonical activation-quantization rule.

A backend executes one quantized matmul: real-valued lhs `x` against an
OVP `QuantizedTensor` weight, under a `QuantPolicy`. Everything upstream
(models, serving engine, benchmarks) talks to `repro.backends.dispatch`;
nothing above this layer branches on backend names.

The activation scale rule lives here — NOT per backend — so every backend
quantizes activations identically and their outputs are comparable
bit-for-bit up to matmul reassociation. `core.qlinear.quantize_activation`
delegates to `quantize_activation` below. Under
`policy.act_scale_mode == "static"` the calibrated per-site scale (from a
`CalibrationArtifact`, carried as `policy.static_act_scale` or passed
explicitly) replaces the dynamic 3σ computation; a static-mode call with
no scale raises `MissingStaticScaleError` instead of silently recomputing.

Machine-readable dispatch vocabulary: the `DECLINE_CODES` registry below
is the single source of truth for every `decline_reason` code, grouped by
the dispatch family that produces it (matmul / sharded / decode_attn /
prefill_attn). Backends return codes through `decline()` — which rejects
anything unregistered at the return site — and the quoted copy in
docs/backends.md (sharded table: docs/sharding.md) is cross-checked
against this registry by the vocabulary pass of `repro.analysis`.

`DISPATCH_KEYS` documents the `dispatch_stats()` counter-key shapes
(`"<backend>"`, `"<backend>->fallback:<reason>"`), `DISPATCH_MARKERS`
the site-kind suffixes (`[stacked]`, `[decode_attn]`, `[prefill_attn]`),
and `ACT_SCALE_KEYS` the `act_scale_stats()` keys (`"static"` /
`"dynamic"` — how each traced quantized-activation matmul resolved its
A-side scale; a static-serving engine must show `dynamic == 0`).

This module must not import `repro.core.qlinear` (qlinear routes through
the registry; importing it back would be a cycle).
"""
from __future__ import annotations

import collections
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.ovp import QuantizedTensor, ovp_quantize
from repro.core.policy import QuantPolicy
from repro.core.quantizer import sigma_init_scale

# ==========================================================================
# The canonical decline / dispatch vocabulary (machine-readable registry)
# ==========================================================================
# family -> {code: meaning}. `None` always means "backend serves this
# operand layout" and is never registered. Removing or renaming a code
# here is an API change: docs/backends.md + docs/sharding.md quote these
# tables and `repro.analysis` fails on any drift between the three.
DECLINE_CODES: Dict[str, Dict[str, str]] = {
    # decline_reason(x, w, policy) — the quantized-matmul dispatch
    "matmul": {
        "pair_axis_not_reduction": "weight pairs not packed along K",
        "lhs_rank_lt_2": "2-D weight needs an (…, M, K) lhs",
        "grouped_lhs_rank_lt_3": "stacked weight needs an (…, E, C, K) lhs",
        "grouped_lhs_expert_mismatch": "lhs expert dim != weight stack dim",
        "stacked_rank_gt_3": ">3-D weight stacks are not kernelized",
    },
    # pallas_sharded (backends/sharded.py): the fused kernels under
    # shard_map; declines fall back one hop like any other decline
    "sharded": {
        "shard_no_mesh": "no mesh configured (configure_mesh)",
        "shard_n_indivisible":
            'column-parallel N not divisible by the "model" axis',
        "shard_k_indivisible":
            "row-parallel K does not split into whole outlier-victim "
            "pairs per shard",
        "shard_expert_indivisible":
            'grouped stack\'s E not divisible by the "model" axis',
        "shard_mixed_expert_group":
            "ragged MixedExpertQuant groups cannot split E evenly",
        "shard_hkv_lt_axis": 'fewer KV heads than "model" shards',
        "shard_hkv_indivisible": 'Hkv not divisible by the "model" axis',
    },
    # decode_attn_decline_reason — the fused KV-cache decode kernel
    "decode_attn": {
        "decode_q_tokens_gt_1": "decode kernel serves one query token only",
        "decode_no_kv_cache": "cache dict carries no k / k_data leaf",
        "decode_empty_cache": "zero-length cache (nothing to attend)",
        "decode_head_dim_odd":
            "even/odd plane split needs an even head dim",
        "paged_no_pool": "block_table present but no pool k/k_data",
        "paged_table_rank": "block table is not a 2-D integer array",
        "paged_page_misaligned": "page size not an even int >= 2",
    },
    # prefill_attn_decline_reason — the fused cache-write prefill kernel
    # over PAGED caches (the slab engine keeps prefill-then-splice and
    # never reaches this dispatch). Paged-layout defects reuse the
    # paged_*/decode_* codes above.
    "prefill_attn": {
        "prefill_not_paged": "cache carries no block_table (slab layout)",
        "prefill_no_stage": "no stage_k/stage_v raw-K/V staging leaves",
        "prefill_batch_gt_1": "kernel serves one request row at a time",
        "prefill_stage_misaligned":
            "stage length not a whole number of pages, or the table "
            "backs fewer pages than tiles",
    },
}

ALL_DECLINE_CODES = frozenset(
    code for family in DECLINE_CODES.values() for code in family)

# dispatch_stats() key shapes (trace-time, one count per traced site)
DISPATCH_KEYS: Dict[str, str] = {
    "<backend>": "served on the requested backend",
    "<backend>->fallback:<reason>": "declined; ran on backend.fallback",
}
# site-kind suffixes appended to either key shape
DISPATCH_MARKERS: Tuple[str, ...] = ("[stacked]", "[decode_attn]",
                                     "[prefill_attn]")
# act_scale_stats() keys — A-side scale resolution per traced matmul
ACT_SCALE_KEYS: Tuple[str, ...] = ("static", "dynamic")


def decline(code: Optional[str]) -> Optional[str]:
    """Validate-and-return for decline codes: `None` (served) passes
    through; a registered code returns itself; anything else is a bug at
    the return site, not a mystery key in dispatch stats downstream."""
    if code is not None and code not in ALL_DECLINE_CODES:
        raise KeyError(f"unregistered decline code {code!r}; add it to "
                       f"backends.base.DECLINE_CODES")
    return code


def dispatch_key(backend_name: str, reason: Optional[str] = None,
                 marker: str = "") -> str:
    """Build one `dispatch_stats()` counter key from the registered
    vocabulary (the only writer; see DISPATCH_KEYS / DISPATCH_MARKERS)."""
    if marker and marker not in DISPATCH_MARKERS:
        raise KeyError(f"unregistered dispatch marker {marker!r}")
    key = backend_name if reason is None \
        else f"{backend_name}->fallback:{decline(reason)}"
    return key + marker


def act_normal_dtype(policy: QuantPolicy) -> str:
    """The paper's A-side dtype rule: 4-bit uses the policy's activation
    normal dtype, 8-bit always int8 OVP."""
    return policy.a_normal_dtype if policy.abits == 4 else "int8"


# -- A-side scale-resolution ledger (see module docstring) ----------------
_ACT_SCALE_STATS: collections.Counter = collections.Counter()


def reset_act_scale_stats() -> None:
    _ACT_SCALE_STATS.clear()


def act_scale_stats() -> Dict[str, int]:
    """Counter keyed "static" / "dynamic": how each traced quantized
    matmul resolved its activation scale. The static-serving acceptance
    tests assert `dynamic == 0` over a whole engine run."""
    return dict(_ACT_SCALE_STATS)


def record_act_scale(kind: str) -> None:
    if kind not in ACT_SCALE_KEYS:
        raise KeyError(f"unregistered act-scale key {kind!r}; "
                       f"options: {ACT_SCALE_KEYS}")
    _ACT_SCALE_STATS[kind] += 1


def resolve_act_scale(x: jax.Array, policy: QuantPolicy,
                      static_scale: Optional[jax.Array] = None
                      ) -> Tuple[jax.Array, str]:
    """Returns (scale, normal_dtype) for the A side of one matmul.

    static mode: the caller's `static_scale` (per-tensor or per-row)
    wins, else the policy's calibrated `static_act_scale`; a miss raises
    rather than silently paying the dynamic std every step.
    """
    nd = act_normal_dtype(policy)
    if policy.act_scale_mode == "static":
        if static_scale is None:
            static_scale = policy.static_act_scale
        if static_scale is None:
            from repro.core.calibration import MissingStaticScaleError
            raise MissingStaticScaleError(["<unresolved site>"])
        record_act_scale("static")
        return jnp.asarray(static_scale, jnp.float32), nd
    record_act_scale("dynamic")
    return sigma_init_scale(x, nd), nd  # dynamic 3σ rule, cheap (one std)


def quantize_activation(x: jax.Array, policy: QuantPolicy,
                        static_scale: Optional[jax.Array] = None
                        ) -> QuantizedTensor:
    """Materialized OVP activation tensor (XLA/reference paths; the fused
    Pallas path quantizes in the kernel prologue instead)."""
    s, nd = resolve_act_scale(x, policy, static_scale)
    return ovp_quantize(x, s, nd, pair_axis=-1)


class QuantizedMatmulBackend:
    """One way to execute x @ dequant(w) under a policy.

    Subclasses set `name` (the registry key / `policy.backend` value) and
    implement `matmul`. `decline_reason` gates dispatch: when it returns a
    reason code (instead of None) the registry falls back to the `fallback`
    backend (default "xla"), so partial backends degrade gracefully instead
    of asserting mid-trace — and the reason is machine-readable, so
    benchmarks and dispatch stats can report *why* a layout fell back
    rather than burying it in prose.
    """

    name: str = "?"
    fallback: str = "xla"
    # True when activation OVP encode runs inside the matmul kernel (no
    # packed activation round trip through HBM) — benchmarks and the
    # roofline model read this.
    fuses_act_encode: bool = False
    # Device dispatches per quantized matmul with activation quantization
    # on: the unfused pipeline is encode + matmul + scale-multiply.
    dispatches_per_matmul: int = 3

    def decline_reason(self, x, w: QuantizedTensor, policy: QuantPolicy,
                       site: str = "") -> Optional[str]:
        """None when this backend can execute the operands; otherwise a
        short stable reason code (e.g. "stacked_rank", "lhs_rank") that
        dispatch records and `kernels_bench` surfaces. `site` is the
        "/"-joined weight address — layout-aware backends (the sharded
        one) read the leaf name off it to pick the parallelism class."""
        return None

    def supports(self, x, w: QuantizedTensor, policy: QuantPolicy,
                 site: str = "") -> bool:
        return self.decline_reason(x, w, policy, site=site) is None

    def mixed_expert_decline_reason(self, x, w, policy) -> Optional[str]:
        """None when this backend's grouped path can serve each
        homogeneous group of a per-expert `MixedExpertQuant`; a reason
        code routes the whole stack to `fallback` instead (the sharded
        backend declines ragged groups with `shard_mixed_expert_group`).
        """
        return None

    def matmul(self, x: jax.Array, w: QuantizedTensor, policy: QuantPolicy,
               act_scale: Optional[jax.Array] = None,
               precision=None, site: str = "") -> jax.Array:
        raise NotImplementedError

    # -- decode attention over KV caches ----------------------------------
    # True when `decode_attention` runs the fused Pallas kernel (packed
    # nibbles unpacked per tile in VMEM, no full-cache dequant); the base
    # implementation is the dense XLA path every backend can serve.
    fuses_decode_attention: bool = False

    def decode_attn_decline_reason(self, q, cache) -> Optional[str]:
        """None when this backend can execute decode attention over this
        (q, cache) layout; otherwise a stable reason code from the table
        in this module's docstring. The dense base path serves anything."""
        return None

    def decode_attention(self, q: jax.Array, cache, pos: jax.Array, *,
                         window: int = 0, ring: int = 0) -> jax.Array:
        """Single-token attention over a KV cache (q: (B, 1, H, D),
        pos: (B,)). Base = dense XLA path: dequantize/convert the whole
        cache, then einsum — correct everywhere, but it rematerializes
        the dense cache every step (the cost `kernels/decode_attn.py`
        removes on the pallas backends)."""
        from repro.kernels import decode_attn
        return decode_attn.xla_decode_attention(q, cache, pos,
                                                window=window, ring=ring)

    # -- paged cache-write prefill -----------------------------------------
    # True when `prefill_attention` runs the fused Pallas kernel (one
    # pallas_call does causal attention over the raw stage AND quantizes
    # every stage tile onto its physical page); the base implementation is
    # the dense twin in kernels/prefill_attn.py — bit-identical page bytes,
    # attention equal up to softmax reassociation.
    fuses_prefill_attention: bool = False

    def prefill_attn_decline_reason(self, q, cache) -> Optional[str]:
        """None when this backend can execute paged cache-write prefill
        over this (q, cache) layout; the dense base path needs only the
        paged layout itself (block_table + stage leaves)."""
        if cache is None or "block_table" not in cache:
            return decline("prefill_not_paged")
        if "stage_k" not in cache or "stage_v" not in cache:
            return decline("prefill_no_stage")
        return None

    def prefill_attention(self, q: jax.Array, cache, positions: jax.Array):
        """Prefill one chunk of one request over a PAGED cache: causal
        attention of q (1, C, H, D) against the raw stage, plus
        quantize-and-write of the whole stage onto its block-table pages.
        Returns (out, new_cache). Base = dense twin (masked einsum +
        whole-stage quantize + page scatter)."""
        from repro.kernels import prefill_attn
        return prefill_attn.xla_prefill_attention(q, cache, positions)

    def __repr__(self):
        return f"<{type(self).__name__} {self.name!r}>"
