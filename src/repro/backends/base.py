"""Backend interface + the canonical activation-quantization rule.

A backend executes one quantized matmul: real-valued lhs `x` against an
OVP `QuantizedTensor` weight, under a `QuantPolicy`. Everything upstream
(models, serving engine, benchmarks) talks to `repro.backends.dispatch`;
nothing above this layer branches on backend names.

The activation scale rule lives here — NOT per backend — so every backend
quantizes activations identically and their outputs are comparable
bit-for-bit up to matmul reassociation. `core.qlinear.quantize_activation`
delegates to `quantize_activation` below.

This module must not import `repro.core.qlinear` (qlinear routes through
the registry; importing it back would be a cycle).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.ovp import QuantizedTensor, ovp_quantize
from repro.core.policy import QuantPolicy
from repro.core.quantizer import sigma_init_scale


def act_normal_dtype(policy: QuantPolicy) -> str:
    """The paper's A-side dtype rule: 4-bit uses the policy's activation
    normal dtype, 8-bit always int8 OVP."""
    return policy.a_normal_dtype if policy.abits == 4 else "int8"


def resolve_act_scale(x: jax.Array, policy: QuantPolicy,
                      static_scale: Optional[jax.Array] = None
                      ) -> Tuple[jax.Array, str]:
    """Returns (scale, normal_dtype) for the A side of one matmul."""
    nd = act_normal_dtype(policy)
    if policy.act_scale_mode == "static" and static_scale is not None:
        return jnp.asarray(static_scale, jnp.float32), nd
    return sigma_init_scale(x, nd), nd  # dynamic 3σ rule, cheap (one std)


def quantize_activation(x: jax.Array, policy: QuantPolicy,
                        static_scale: Optional[jax.Array] = None
                        ) -> QuantizedTensor:
    """Materialized OVP activation tensor (XLA/reference paths; the fused
    Pallas path quantizes in the kernel prologue instead)."""
    s, nd = resolve_act_scale(x, policy, static_scale)
    return ovp_quantize(x, s, nd, pair_axis=-1)


class QuantizedMatmulBackend:
    """One way to execute x @ dequant(w) under a policy.

    Subclasses set `name` (the registry key / `policy.backend` value) and
    implement `matmul`. `decline_reason` gates dispatch: when it returns a
    reason code (instead of None) the registry falls back to the `fallback`
    backend (default "xla"), so partial backends degrade gracefully instead
    of asserting mid-trace — and the reason is machine-readable, so
    benchmarks and dispatch stats can report *why* a layout fell back
    rather than burying it in prose.
    """

    name: str = "?"
    fallback: str = "xla"
    # True when activation OVP encode runs inside the matmul kernel (no
    # packed activation round trip through HBM) — benchmarks and the
    # roofline model read this.
    fuses_act_encode: bool = False
    # Device dispatches per quantized matmul with activation quantization
    # on: the unfused pipeline is encode + matmul + scale-multiply.
    dispatches_per_matmul: int = 3

    def decline_reason(self, x, w: QuantizedTensor,
                       policy: QuantPolicy) -> Optional[str]:
        """None when this backend can execute the operands; otherwise a
        short stable reason code (e.g. "stacked_rank", "lhs_rank") that
        dispatch records and `kernels_bench` surfaces."""
        return None

    def supports(self, x, w: QuantizedTensor, policy: QuantPolicy) -> bool:
        return self.decline_reason(x, w, policy) is None

    def matmul(self, x: jax.Array, w: QuantizedTensor, policy: QuantPolicy,
               act_scale: Optional[jax.Array] = None,
               precision=None) -> jax.Array:
        raise NotImplementedError

    def __repr__(self):
        return f"<{type(self).__name__} {self.name!r}>"
