"""Fused Pallas backend: one `pallas_call` per quantized matmul.

Activation OVP quantization runs as the kernel prologue at a precomputed
per-tensor/per-row scale (no packed activation tensor in HBM, no XLA
encode -> kernel decode round trip), weight codes decode in VMEM, and both
scales apply in the accumulator epilogue. 2-D and 3-D lhs share the kernel
via its batch grid dim, so serving decode-step GEMMs hit the fused path
without reshape glue.

Stacked per-expert weights `(E, K, N)` run the grouped kernel: an expert
grid dim indexes the weight stack, the lhs carries a matching `(…, E, C,
K)` layout (the MoE dispatch tensor), and per-expert scales apply in the
epilogue. Layouts the kernels genuinely cannot execute are declined with a
machine-readable reason (`decline_reason`) and dispatch falls back to XLA.

Static calibrated activation scales (`policy.act_scale_mode == "static"`
with a per-site `static_act_scale` attached by
`calibration.apply_calibration`) skip the per-step scale computation
entirely: no 3σ std runs, and the kernel takes the calibrated scale as a
single (1, 1) scalar operand in place of the whole per-row scale plane —
one compiled kernel serves every calibrated site (see the `*_static`
kernel bodies in `kernels/ovp_matmul.py`).

Serving decode steps additionally route their ATTENTION through this
backend: `decode_attention` runs the fused decode-attention kernel
(`kernels/decode_attn.py`) that unpacks/dequantizes OVP-packed KV caches
per tile in VMEM — no full-cache dequant, no dense rematerialization —
with length/ring/window masking in-kernel from the traced position
(fp caches take the same kernel minus the unpack phase). Unsupported
(q, cache) layouts decline with a `decode_*` reason code and fall back
to the dense XLA path (see docs/kv_cache.md).

Decline-reason codes and the `dispatch_stats()` / `act_scale_stats()` key
vocabulary are registered once, in `backends/base.py::DECLINE_CODES` (and
`DISPATCH_KEYS` / `ACT_SCALE_KEYS`); every reason this backend returns
goes through `decline()` so unregistered codes fail at the return site.

`pallas_interpret` is the same backend with `interpret=True` — the CPU
emulation used by tests and this container; numerics are identical.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.ovp import QuantizedTensor
from repro.core.policy import QuantPolicy
from repro.kernels import decode_attn, ops, prefill_attn

from .base import (QuantizedMatmulBackend, act_normal_dtype, decline,
                   record_act_scale, resolve_act_scale)


def _static_const_scale(policy: QuantPolicy, act_scale) -> Optional[float]:
    """The activation scale as a Python float when it is a calibrated
    per-site scalar: static mode with the policy's scale (or an explicit
    Python scalar). Array scales — per-row statics, dynamic 3σ — return
    None and take the per-row scale-operand path instead."""
    if policy.act_scale_mode != "static":
        return None
    if act_scale is None:
        return None if policy.static_act_scale is None \
            else float(policy.static_act_scale)
    return float(act_scale) if isinstance(act_scale, (int, float)) else None


class PallasBackend(QuantizedMatmulBackend):
    name = "pallas"
    interpret = False
    fuses_act_encode = True
    dispatches_per_matmul = 1

    def decline_reason(self, x, w: QuantizedTensor, policy: QuantPolicy,
                       site: str = "") -> Optional[str]:
        if w.pair_axis % 2 != 0:
            # pairing must run along K (quantize_weight guarantees -2)
            return decline("pair_axis_not_reduction")
        if w.data.ndim == 2:
            return None if x.ndim >= 2 else decline("lhs_rank_lt_2")
        if w.data.ndim == 3:
            # grouped path: lhs must carry the matching expert dim at -3
            if x.ndim < 3:
                return decline("grouped_lhs_rank_lt_3")
            if x.shape[-3] != w.data.shape[0]:
                return decline("grouped_lhs_expert_mismatch")
            return None
        return decline("stacked_rank_gt_3")

    def matmul(self, x: jax.Array, w: QuantizedTensor, policy: QuantPolicy,
               act_scale: Optional[jax.Array] = None,
               precision=None, site: str = "") -> jax.Array:
        cdt = jnp.dtype(policy.compute_dtype)
        a_dtype = None
        scale = None
        static = None
        if policy.abits:
            static = _static_const_scale(policy, act_scale)
            if static is not None:
                # calibrated scalar: no std, and the kernel reads one
                # (1, 1) scale word instead of a per-row plane
                a_dtype = act_normal_dtype(policy)
                record_act_scale("static")
            else:
                scale, a_dtype = resolve_act_scale(x, policy, act_scale)
        if w.data.ndim == 3:
            return ops.grouped_ovp_matmul(x, w, a_dtype=a_dtype,
                                          act_scale=scale,
                                          static_act_scale=static,
                                          out_dtype=cdt,
                                          interpret=self.interpret)
        return ops.fused_ovp_matmul(x, w, a_dtype=a_dtype, act_scale=scale,
                                    static_act_scale=static, out_dtype=cdt,
                                    interpret=self.interpret)

    # -- fused decode attention (kernels/decode_attn.py) ------------------
    fuses_decode_attention = True

    def decode_attn_decline_reason(self, q, cache) -> Optional[str]:
        # the kernel module names the reason; decline() re-validates it
        # against the base.py registry at the backend boundary
        return decline(decode_attn.decline_reason(q, cache))

    def decode_attention(self, q: jax.Array, cache, pos: jax.Array, *,
                         window: int = 0, ring: int = 0) -> jax.Array:
        return decode_attn.fused_decode_attention(
            q, cache, pos, window=window, ring=ring,
            interpret=self.interpret)

    # -- fused cache-write prefill (kernels/prefill_attn.py) ---------------
    fuses_prefill_attention = True

    def prefill_attn_decline_reason(self, q, cache) -> Optional[str]:
        return decline(prefill_attn.prefill_decline_reason(q, cache))

    def prefill_attention(self, q: jax.Array, cache, positions: jax.Array):
        return prefill_attn.fused_prefill_attention(
            q, cache, positions, interpret=self.interpret)


class PallasInterpretBackend(PallasBackend):
    name = "pallas_interpret"
    interpret = True
