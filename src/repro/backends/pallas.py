"""Fused Pallas backend: one `pallas_call` per quantized matmul.

Activation OVP quantization runs as the kernel prologue at a precomputed
per-tensor/per-row scale (no packed activation tensor in HBM, no XLA
encode -> kernel decode round trip), weight codes decode in VMEM, and both
scales apply in the accumulator epilogue. 2-D and 3-D lhs share the kernel
via its batch grid dim, so serving decode-step GEMMs hit the fused path
without reshape glue.

`pallas_interpret` is the same backend with `interpret=True` — the CPU
emulation used by tests and this container; numerics are identical.

Stacked (scan/per-expert) weights carry a leading dim the kernel's weight
operand doesn't model — `supports` returns False there and dispatch falls
back to the XLA backend.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.ovp import QuantizedTensor
from repro.core.policy import QuantPolicy
from repro.kernels import ops

from .base import QuantizedMatmulBackend, act_normal_dtype, resolve_act_scale


class PallasBackend(QuantizedMatmulBackend):
    name = "pallas"
    interpret = False
    fuses_act_encode = True
    dispatches_per_matmul = 1

    def supports(self, x, w: QuantizedTensor, policy: QuantPolicy) -> bool:
        # 2-D weights only (stacked weights fall back to XLA); pairing must
        # run along K, which quantize_weight guarantees (pair_axis = -2).
        return w.data.ndim == 2 and w.pair_axis % 2 == 0 and x.ndim >= 2

    def matmul(self, x: jax.Array, w: QuantizedTensor, policy: QuantPolicy,
               act_scale: Optional[jax.Array] = None,
               precision=None) -> jax.Array:
        cdt = jnp.dtype(policy.compute_dtype)
        a_dtype = None
        scale = None
        if policy.abits:
            scale, a_dtype = resolve_act_scale(x, policy, act_scale)
        return ops.fused_ovp_matmul(x, w, a_dtype=a_dtype, act_scale=scale,
                                    out_dtype=cdt, interpret=self.interpret)


class PallasInterpretBackend(PallasBackend):
    name = "pallas_interpret"
    interpret = True
