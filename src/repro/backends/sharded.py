"""Sharded Pallas backend: the fused kernels under `shard_map` on a mesh.

`pallas_sharded` wraps the exact single-device fused kernels — the 2-D
OVP matmul, the grouped per-expert (MoE) kernel, and the decode/prefill
attention kernels — in `jax.experimental.shard_map` over a
`runtime/elastic.py::MeshPlan` mesh, so the packed codes shard without
re-encoding:

- **TP, column-parallel** (sites whose leaf is in
  `sharding/rules.py::COL_PARALLEL`, and the default — e.g. `wq`, `wu`,
  `w_out`): the packed weight `(K/2, N)` and its per-channel scale split
  N over the "model" axis; the lhs replicates; each shard runs the
  unmodified fused kernel on its N slice. No collective — outputs
  concatenate along N, **bit-identical** to the single-device kernel.
- **TP, row-parallel** (`ROW_PARALLEL` leaves — `wo`, `wd`, …): the lhs
  and the packed weight split K (whole outlier-victim pairs per shard:
  the packed row dim `K/2` must divide), per-channel scales replicate,
  and a `psum` over "model" reduces the partial products — equal to the
  single-device output up to fp32 reassociation of the K sum.
- **EP** (grouped stacks `(E, K/2, N)`): the expert grid dim splits over
  "model"; each shard owns whole expert stacks and their `(E, …)`
  scales, the lhs splits its matching expert axis. No all-to-all of
  dequantized weights ever materializes; bit-identical.
- **KV shard** (decode/prefill attention, slab and paged): every cache
  leaf carries `Hkv` at axis 2 — slab `(B, S, Hkv, D/2)` and paged pool
  `(P, ps, Hkv, D/2)` alike — so one spec rule splits the pool bytes,
  the per-(token, head) scales `(…, Hkv)`, and the staged prefill K/V
  across "model"; q splits its H axis (contiguous `h = kv*G + g` GQA
  grouping keeps each query head on the shard that owns its KV head);
  block tables and positions replicate. Attention is per-head, so both
  outputs and written page bytes are bit-identical.

The OVP property doing the work is the paper's alignment claim: one byte
is one outlier-victim pair and each scale travels with its tile, so any
even split of K — and any split of N / E / Hkv — is re-encoding-free and
needs no replicated coordination list.

Layouts (or meshes) the backend cannot shard decline with the
machine-readable `shard_*` codes registered in
`backends/base.py::DECLINE_CODES` and fall back one hop to the dense
gather path, exactly like every other decline.
Per-expert `MixedExpertQuant` stacks decline whole
(`shard_mixed_expert_group`): their group membership is static but the
groups are ragged, so splitting E across the mesh would leave shards
with unequal stacks.

Mesh state is module-level: `configure_mesh(plan)` builds and installs a
`jax.sharding.Mesh` from a `MeshPlan` (or accepts a ready `Mesh`);
`ServingEngine` calls it when `EngineCfg.mesh` is set, and
`launch/serve.py` exposes `--mesh`. With no mesh configured (or a
"model" axis of 1) the backend serves exactly like its single-device
parent.

`pallas_sharded_interpret` is the same backend over the interpret-mode
kernels — the CPU twin the 8-forced-host-device parity suite
(`tests/test_sharded_backend.py`) runs against.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.ovp import QuantizedTensor
from repro.core.policy import QuantPolicy
from repro.kernels import decode_attn, ops, prefill_attn
from repro.sharding.rules import ROW_PARALLEL, mesh_axis_sizes

from .base import (act_normal_dtype, decline, record_act_scale,
                   resolve_act_scale)
from .pallas import PallasBackend, _static_const_scale

# ---------------------------------------------------------------- mesh state
_MESH: Optional[Mesh] = None


def configure_mesh(plan=None, devices=None) -> Optional[Mesh]:
    """Install the mesh the sharded backend runs on (module-level state,
    mirroring the registry itself). `plan` is a
    `runtime/elastic.py::MeshPlan` (shape + axis names), a ready
    `jax.sharding.Mesh`, or None to clear. Returns the installed Mesh."""
    global _MESH
    if plan is None:
        _MESH = None
        return None
    if isinstance(plan, Mesh):
        _MESH = plan
        return plan
    devs = list(devices) if devices is not None else jax.devices()
    if len(devs) < plan.n_devices:
        raise ValueError(f"mesh plan {plan.shape} needs {plan.n_devices} "
                         f"devices, have {len(devs)}")
    mesh = Mesh(np.asarray(devs[:plan.n_devices]).reshape(plan.shape),
                plan.axis_names)
    _MESH = mesh
    return mesh


def current_mesh() -> Optional[Mesh]:
    return _MESH


def _model_axis() -> int:
    """Size of the "model" mesh axis; 0 = no mesh configured."""
    if _MESH is None:
        return 0
    return mesh_axis_sizes(_MESH).get("model", 1)


def _site_leaf(site: str) -> str:
    return site.rsplit("/", 1)[-1]


def row_shard_pair_aligned(k_rows: int, tp: int, packed: bool) -> bool:
    """Does a row-parallel K split over `tp` shards land every shard on
    whole outlier-victim pairs?

    `k_rows` is the K extent of the STORED code array (`w.data.shape[0]`):
    packed nibbles carry two 4-bit codes — one whole pair — per row, so
    any even split of rows preserves pairs; int8 codes are one value per
    row, so each shard additionally needs an even row count. This is the
    pure predicate behind `shard_k_indivisible`; `repro.analysis` sweeps
    it against the OVP pairing ground truth (pair = 2 adjacent K values)
    so the guard and the encoding can never drift apart silently.
    """
    if k_rows % tp != 0:
        return False                     # ragged shards: K must divide
    values_per_row = 2 if packed else 1
    return (k_rows // tp) * values_per_row % 2 == 0


class ShardedPallasBackend(PallasBackend):
    name = "pallas_sharded"
    interpret = False
    requires_mesh = True

    # -- quantized matmul --------------------------------------------------
    def decline_reason(self, x, w: QuantizedTensor, policy: QuantPolicy,
                       site: str = "") -> Optional[str]:
        reason = super().decline_reason(x, w, policy, site=site)
        if reason is not None:
            return reason
        tp = _model_axis()
        if tp == 0:
            return decline("shard_no_mesh")
        if tp == 1:
            return None              # degenerate mesh: single-device path
        if w.data.ndim == 3:
            if w.data.shape[0] % tp != 0:
                return decline("shard_expert_indivisible")
            return None
        if _site_leaf(site) in ROW_PARALLEL:
            # K splits in whole outlier-victim pairs: one packed row IS a
            # pair; int8 codes are one row per value, so two rows per pair
            if not row_shard_pair_aligned(w.data.shape[0], tp, w.is_packed):
                return decline("shard_k_indivisible")
            return None
        if w.data.shape[-1] % tp != 0:
            return decline("shard_n_indivisible")
        return None

    def mixed_expert_decline_reason(self, x, w, policy) -> Optional[str]:
        # ragged static expert groups: splitting E would unbalance shards
        return decline("shard_mixed_expert_group")

    def matmul(self, x: jax.Array, w: QuantizedTensor, policy: QuantPolicy,
               act_scale: Optional[jax.Array] = None,
               precision=None, site: str = "") -> jax.Array:
        tp = _model_axis()
        if tp <= 1:
            return super().matmul(x, w, policy, act_scale=act_scale,
                                  precision=precision, site=site)
        mesh = _MESH
        cdt = jnp.dtype(policy.compute_dtype)

        # A-side scale resolution happens OUTSIDE shard_map (on the full
        # lhs), exactly mirroring the parent — every shard then quantizes
        # at the same scale, and OVP pair selection is pairwise-local, so
        # even K splits reproduce the single-device codes.
        a_dtype = None
        scale = None
        static = None
        if policy.abits:
            static = _static_const_scale(policy, act_scale)
            if static is not None:
                a_dtype = act_normal_dtype(policy)
                record_act_scale("static")
            else:
                scale, a_dtype = resolve_act_scale(x, policy, act_scale)

        ws = jnp.asarray(w.scale)
        rep = lambda a: P(*([None] * jnp.ndim(a)))
        interpret = self.interpret
        grouped = w.data.ndim == 3

        if grouped:
            x_spec = P(*([None] * (x.ndim - 3)), "model", None, None)
            wd_spec = P("model", None, None)
            ws_spec = P("model", *([None] * (ws.ndim - 1))) if ws.ndim \
                else P()
            s_spec = None
            if scale is not None:
                s_spec = rep(scale)
                if scale.ndim >= 2 and scale.shape[-2:] == x.shape[-3:-1]:
                    # per-slot (…, E, C) plane: E rides at axis -2
                    parts = [None] * scale.ndim
                    parts[-2] = "model"
                    s_spec = P(*parts)
                elif scale.ndim >= 3 and scale.shape[-1] == 1 \
                        and scale.shape[-3:-1] == x.shape[-3:-1]:
                    parts = [None] * scale.ndim
                    parts[-3] = "model"
                    s_spec = P(*parts)
            out_spec = P(*([None] * (x.ndim - 3)), "model", None, None)

            def run(xl, wdl, wsl, sl):
                wl = QuantizedTensor(
                    data=wdl, scale=wsl, normal_dtype=w.normal_dtype,
                    pair_axis=w.pair_axis, orig_dim=w.orig_dim)
                return ops.grouped_ovp_matmul(
                    xl, wl, a_dtype=a_dtype, act_scale=sl,
                    static_act_scale=static, out_dtype=cdt,
                    interpret=interpret)
        elif _site_leaf(site) in ROW_PARALLEL:
            x_spec = P(*([None] * (x.ndim - 1)), "model")
            wd_spec = P("model", None)
            ws_spec = rep(ws)
            s_spec = rep(scale) if scale is not None else None
            out_spec = P(*([None] * x.ndim))
            local_k = w.orig_dim // tp   # each shard holds K/tp whole pairs

            def run(xl, wdl, wsl, sl):
                wl = QuantizedTensor(
                    data=wdl, scale=wsl, normal_dtype=w.normal_dtype,
                    pair_axis=w.pair_axis, orig_dim=local_k)
                part = ops.fused_ovp_matmul(
                    xl, wl, a_dtype=a_dtype, act_scale=sl,
                    static_act_scale=static, out_dtype=cdt,
                    interpret=interpret)
                return jax.lax.psum(part, "model")
        else:                                       # column-parallel
            x_spec = P(*([None] * x.ndim))
            wd_spec = P(None, "model")
            ws_spec = rep(ws)
            if ws.ndim and ws.shape[-1] == w.data.shape[-1]:
                ws_spec = P(*([None] * (ws.ndim - 1)), "model")
            s_spec = rep(scale) if scale is not None else None
            out_spec = P(*([None] * (x.ndim - 1)), "model")

            def run(xl, wdl, wsl, sl):
                wl = QuantizedTensor(
                    data=wdl, scale=wsl, normal_dtype=w.normal_dtype,
                    pair_axis=w.pair_axis, orig_dim=w.orig_dim)
                return ops.fused_ovp_matmul(
                    xl, wl, a_dtype=a_dtype, act_scale=sl,
                    static_act_scale=static, out_dtype=cdt,
                    interpret=interpret)

        if scale is None:
            sharded = shard_map(lambda xl, wdl, wsl: run(xl, wdl, wsl,
                                                         None),
                                mesh=mesh,
                                in_specs=(x_spec, wd_spec, ws_spec),
                                out_specs=out_spec, check_rep=False)
            return sharded(x, w.data, ws)
        sharded = shard_map(run, mesh=mesh,
                            in_specs=(x_spec, wd_spec, ws_spec, s_spec),
                            out_specs=out_spec, check_rep=False)
        return sharded(x, w.data, ws, scale)

    # -- decode / prefill attention over Hkv-sharded caches ----------------
    @staticmethod
    def _cache_hkv(cache) -> Optional[int]:
        for k in ("k", "k_data"):
            if cache is not None and k in cache:
                return int(cache[k].shape[2])
        return None

    def _hkv_decline(self, cache) -> Optional[str]:
        tp = _model_axis()
        if tp == 0:
            return decline("shard_no_mesh")
        if tp == 1:
            return None
        hkv = self._cache_hkv(cache)
        if hkv is None:
            return None              # parent decline codes already cover it
        if hkv < tp:
            return decline("shard_hkv_lt_axis")
        if hkv % tp != 0:
            return decline("shard_hkv_indivisible")
        return None

    @staticmethod
    def _cache_specs(cache):
        """One spec rule covers slab and paged layouts: every K/V leaf —
        pool bytes, scales, staged prefill K/V — carries Hkv at axis 2;
        block tables, src_len, and any other bookkeeping replicate."""
        specs = {}
        for name, leaf in cache.items():
            if name in ("k", "v", "k_data", "v_data", "stage_k",
                        "stage_v"):
                specs[name] = P(None, None, "model", None)
            elif name in ("k_scl", "v_scl"):
                specs[name] = P(None, None, "model")
            else:
                specs[name] = P(*([None] * jnp.ndim(leaf)))
        return specs

    def decode_attn_decline_reason(self, q, cache) -> Optional[str]:
        reason = super().decode_attn_decline_reason(q, cache)
        if reason is not None:
            return reason
        return self._hkv_decline(cache)

    def decode_attention(self, q: jax.Array, cache, pos: jax.Array, *,
                         window: int = 0, ring: int = 0) -> jax.Array:
        tp = _model_axis()
        if tp <= 1:
            return super().decode_attention(q, cache, pos, window=window,
                                            ring=ring)
        interpret = self.interpret
        q_spec = P(None, None, "model", None)

        def run(ql, cl, pl):
            return decode_attn.fused_decode_attention(
                ql, cl, pl, window=window, ring=ring, interpret=interpret)

        sharded = shard_map(
            run, mesh=_MESH,
            in_specs=(q_spec, self._cache_specs(cache), P(None)),
            out_specs=q_spec, check_rep=False)
        return sharded(q, cache, pos)

    def prefill_attn_decline_reason(self, q, cache) -> Optional[str]:
        reason = super().prefill_attn_decline_reason(q, cache)
        if reason is not None:
            return reason
        return self._hkv_decline(cache)

    def prefill_attention(self, q: jax.Array, cache, positions: jax.Array):
        tp = _model_axis()
        if tp <= 1:
            return super().prefill_attention(q, cache, positions)
        interpret = self.interpret
        q_spec = P(None, None, "model", None)
        cache_specs = self._cache_specs(cache)

        def run(ql, cl, pl):
            return prefill_attn.fused_prefill_attention(
                ql, cl, pl, interpret=interpret)

        sharded = shard_map(
            run, mesh=_MESH,
            in_specs=(q_spec, cache_specs, P(None, None)),
            out_specs=(q_spec, cache_specs), check_rep=False)
        return sharded(q, cache, positions)


class ShardedPallasInterpretBackend(ShardedPallasBackend):
    name = "pallas_sharded_interpret"
    interpret = True
