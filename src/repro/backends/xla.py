"""XLA decode-and-matmul backend.

Dequantizes the weight (and, when `policy.abits`, a materialized OVP
round-trip of the activation) to the compute dtype and lets XLA fuse the
decode into the GEMM prologue. This is the portable path: it handles any
lhs rank and stacked (scan/per-expert) weights via broadcasting, so it is
also the registry's fallback backend — `decline_reason` is never
overridden here (always `None`: nothing to decline).

The A side follows the shared rule in `backends/base.py` —
`quantize_activation` resolves dynamic 3σ or static calibrated scales
identically to every other backend (and records them in
`act_scale_stats()`). The decline-reason and dispatch/act-scale stats key
vocabulary is tabulated once in `base.py`'s module docstring.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.ovp import QuantizedTensor, ovp_dequantize
from repro.core.policy import QuantPolicy

from .base import QuantizedMatmulBackend, quantize_activation


class XlaBackend(QuantizedMatmulBackend):
    name = "xla"
    fuses_act_encode = False
    dispatches_per_matmul = 3  # encode, matmul, scale (pre-fusion XLA ops)

    def matmul(self, x: jax.Array, w: QuantizedTensor, policy: QuantPolicy,
               act_scale: Optional[jax.Array] = None,
               precision=None, site: str = "") -> jax.Array:
        cdt = jnp.dtype(policy.compute_dtype)
        wd = ovp_dequantize(w, dtype=cdt)
        if policy.abits:
            xq = quantize_activation(x, policy, act_scale)
            xd = ovp_dequantize(xq, dtype=cdt)
            return jnp.matmul(xd, wd, precision=precision).astype(cdt)
        return jnp.matmul(x.astype(cdt), wd, precision=precision)
