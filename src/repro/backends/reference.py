"""Pure-jnp fp32 oracle backend.

Same quantization semantics as every other backend (shared activation rule
from `backends.base`, including static calibrated scales), but everything
runs in float32 with no kernel, no padding, and no compute-dtype cast.
Equivalence tests compare the real backends against this one.

Never declines (`decline_reason` stays `None` for any layout); its
dispatch/act-scale stats keys follow the vocabulary tabulated in
`base.py`'s module docstring.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.ovp import QuantizedTensor, ovp_dequantize
from repro.core.policy import QuantPolicy

from .base import QuantizedMatmulBackend, quantize_activation


class ReferenceBackend(QuantizedMatmulBackend):
    name = "reference"
    fuses_act_encode = False
    dispatches_per_matmul = 3

    def matmul(self, x: jax.Array, w: QuantizedTensor, policy: QuantPolicy,
               act_scale: Optional[jax.Array] = None,
               precision=None, site: str = "") -> jax.Array:
        wd = ovp_dequantize(w, dtype=jnp.float32)
        xd = x.astype(jnp.float32)
        if policy.abits:
            xq = quantize_activation(x, policy, act_scale)
            xd = ovp_dequantize(xq, dtype=jnp.float32)
        return jnp.matmul(xd, wd, preferred_element_type=jnp.float32)
