"""Quantized-execution backend registry.

One entry point — `dispatch(x, w, policy, act_scale)` — executes every
quantized matmul in the repo. `policy.backend` names a registered
`QuantizedMatmulBackend`; consumers (qlinear, model layers, the serving
engine, benchmarks) never branch on backend strings themselves.

Registered backends:
  xla              — dequantize-to-compute-dtype, XLA fuses decode into the
                     GEMM prologue; handles any rank and stacked weights
                     (also the fallback for unsupported operand layouts)
  pallas           — single fused pallas_call: in-kernel activation OVP
                     quantization + VMEM weight decode + scale epilogue
  pallas_interpret — same kernel, CPU interpreter (tests / this container)
  reference        — pure-jnp fp32 oracle (equivalence tests)

Adding a backend: subclass `QuantizedMatmulBackend`, implement `matmul`
(and `supports` if partial), then `register(MyBackend())` — the name
becomes a valid `QuantPolicy.backend` value everywhere at once. See
docs/backends.md.
"""
from __future__ import annotations

from typing import Dict, Optional

import jax

from repro.core.ovp import QuantizedTensor
from repro.core.policy import QuantPolicy

from .base import (QuantizedMatmulBackend, act_normal_dtype,
                   quantize_activation, resolve_act_scale)
from .pallas import PallasBackend, PallasInterpretBackend
from .reference import ReferenceBackend
from .xla import XlaBackend

_REGISTRY: Dict[str, QuantizedMatmulBackend] = {}


def register(backend: QuantizedMatmulBackend) -> QuantizedMatmulBackend:
    """Register (or override) a backend under `backend.name`."""
    _REGISTRY[backend.name] = backend
    return backend


def get_backend(name: str) -> QuantizedMatmulBackend:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown quantized-matmul backend {name!r}; "
                       f"registered: {available()}") from None


def available() -> list:
    return sorted(_REGISTRY)


for _b in (XlaBackend(), PallasBackend(), PallasInterpretBackend(),
           ReferenceBackend()):
    register(_b)
del _b


def count_pallas_calls(fn, *args) -> int:
    """Number of pallas_call primitives in fn's jaxpr (recursing through
    pjit/closed-call sub-jaxprs) — the kernel-dispatch count of a pipeline.
    Benchmarks and tests use it to verify a backend's fusion claim
    (`dispatches_per_matmul`) against the traced program."""
    closed = jax.make_jaxpr(fn)(*args)

    def sub_jaxprs(v):
        # params hold sub-jaxprs as ClosedJaxpr (.jaxpr), bare Jaxpr
        # (.eqns), or tuples/lists of either (e.g. lax.cond branches)
        if isinstance(v, (tuple, list)):
            for item in v:
                yield from sub_jaxprs(item)
        else:
            inner = getattr(v, "jaxpr", None)
            if inner is not None and hasattr(inner, "eqns"):
                yield inner
            elif hasattr(v, "eqns"):
                yield v

    def walk(jaxpr) -> int:
        n = 0
        for eqn in jaxpr.eqns:
            if eqn.primitive.name == "pallas_call":
                n += 1
            for v in eqn.params.values():
                for inner in sub_jaxprs(v):
                    n += walk(inner)
        return n

    return walk(closed.jaxpr)


def dispatch(x: jax.Array, w: QuantizedTensor, policy: QuantPolicy,
             act_scale: Optional[jax.Array] = None,
             precision=None) -> jax.Array:
    """Execute x (..., K) @ dequant(w) (K, N) on the policy's backend.

    Falls back (one hop) when the requested backend does not support the
    operand layout — e.g. stacked per-expert weights on the Pallas kernel
    run on XLA instead of asserting mid-trace.
    """
    backend = get_backend(policy.backend)
    if not backend.supports(x, w, policy):
        backend = get_backend(backend.fallback)
    return backend.matmul(x, w, policy, act_scale=act_scale,
                          precision=precision)


__all__ = ["QuantizedMatmulBackend", "register", "get_backend", "available",
           "dispatch", "count_pallas_calls", "quantize_activation",
           "resolve_act_scale", "act_normal_dtype", "XlaBackend",
           "PallasBackend", "PallasInterpretBackend", "ReferenceBackend"]
