"""Quantized-execution backend registry.

One entry point — `dispatch(x, w, policy, act_scale)` — executes every
quantized matmul in the repo, and its KV-cache twin
`decode_attention(q, cache, pos, policy=...)` every serving decode-step
attention. `policy.backend` names a registered `QuantizedMatmulBackend`;
consumers (qlinear, model layers, the serving engine, benchmarks) never
branch on backend strings themselves.

Registered backends:
  xla              — dequantize-to-compute-dtype, XLA fuses decode into the
                     GEMM prologue; handles any rank and stacked weights
                     (also the fallback for unsupported operand layouts)
  pallas           — single fused pallas_call: in-kernel activation OVP
                     quantization + VMEM weight decode + scale epilogue
  pallas_interpret — same kernel, CPU interpreter (tests / this container)
  pallas_sharded   — the fused kernels under shard_map on the configured
                     mesh (`configure_mesh`): column/row tensor-parallel
                     2-D matmuls, expert-parallel grouped stacks,
                     Hkv-sharded decode/prefill attention (backends/sharded.py)
  pallas_sharded_interpret — the sharded backend over the interpret
                     kernels (the multi-host-CPU parity twin)
  reference        — pure-jnp fp32 oracle (equivalence tests)

Adding a backend: subclass `QuantizedMatmulBackend`, implement `matmul`
(and `supports` if partial), then `register(MyBackend())` — the name
becomes a valid `QuantPolicy.backend` value everywhere at once. See
docs/backends.md.

Observability: `dispatch_stats()` (served / declined-with-reason counts
per backend) and `act_scale_stats()` (static vs dynamic A-side scale
resolutions). The key vocabulary for both — and the full
`decline_reason` code registry — is machine-readable in
`backends/base.py` (`DECLINE_CODES` / `DISPATCH_KEYS` /
`DISPATCH_MARKERS` / `ACT_SCALE_KEYS`), re-exported here.
"""
from __future__ import annotations

import collections
import os
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.ovp import MixedExpertQuant, QuantizedTensor
from repro.core.policy import QuantPolicy

from .base import (ACT_SCALE_KEYS, ALL_DECLINE_CODES, DECLINE_CODES,
                   DISPATCH_KEYS, DISPATCH_MARKERS,
                   QuantizedMatmulBackend, act_normal_dtype,
                   act_scale_stats, decline, dispatch_key,
                   quantize_activation, reset_act_scale_stats,
                   resolve_act_scale)
from .pallas import PallasBackend, PallasInterpretBackend
from .reference import ReferenceBackend
from .sharded import (ShardedPallasBackend, ShardedPallasInterpretBackend,
                      configure_mesh, current_mesh)
from .xla import XlaBackend

_REGISTRY: Dict[str, QuantizedMatmulBackend] = {}


def register(backend: QuantizedMatmulBackend) -> QuantizedMatmulBackend:
    """Register (or override) a backend under `backend.name`."""
    _REGISTRY[backend.name] = backend
    return backend


def get_backend(name: str) -> QuantizedMatmulBackend:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown quantized-matmul backend {name!r}; "
                       f"registered: {available()}") from None


def available() -> list:
    return sorted(_REGISTRY)


for _b in (XlaBackend(), PallasBackend(), PallasInterpretBackend(),
           ReferenceBackend(), ShardedPallasBackend(),
           ShardedPallasInterpretBackend()):
    register(_b)
del _b

# REPRO_FORCE_INTERPRET=1 re-registers "pallas" (and its sharded sibling)
# as the interpret twin, so CI (no TPU) exercises the real kernel code
# paths — including grouped MoE dispatch and shard_map wrapping — under
# any config that names the compiled backend.
if os.environ.get("REPRO_FORCE_INTERPRET", "0") not in ("", "0"):
    class _ForcedInterpret(PallasInterpretBackend):
        name = "pallas"

    class _ForcedShardedInterpret(ShardedPallasInterpretBackend):
        name = "pallas_sharded"
    register(_ForcedInterpret())
    register(_ForcedShardedInterpret())


# --------------------------------------------------------------------------
# Dispatch statistics: fused-vs-fallback counts with machine-readable
# decline reasons. Counts accumulate at trace time (one per traced matmul
# call site), which is exactly the granularity kernels_bench reports.
# --------------------------------------------------------------------------
_DISPATCH_STATS: collections.Counter = collections.Counter()


def reset_dispatch_stats() -> None:
    _DISPATCH_STATS.clear()


def dispatch_stats() -> Dict[str, int]:
    """Counter keyed "backend" (served) / "backend->fallback:reason"
    (declined), with a `stacked` marker for 3-D weight stacks."""
    return dict(_DISPATCH_STATS)


def _record(backend_name: str, reason: Optional[str],
            marker: str = "") -> None:
    # dispatch_key validates both the reason code and the marker against
    # the base.py registry, so a typo'd decline string fails at the
    # dispatch site instead of surfacing as a mystery stats key
    _DISPATCH_STATS[dispatch_key(backend_name, reason, marker)] += 1


def count_pallas_calls(fn, *args) -> int:
    """Number of pallas_call primitives in fn's jaxpr (recursing through
    pjit/closed-call sub-jaxprs) — the kernel-dispatch count of a pipeline.
    Benchmarks and tests use it to verify a backend's fusion claim
    (`dispatches_per_matmul`) against the traced program."""
    closed = jax.make_jaxpr(fn)(*args)

    def sub_jaxprs(v):
        # params hold sub-jaxprs as ClosedJaxpr (.jaxpr), bare Jaxpr
        # (.eqns), or tuples/lists of either (e.g. lax.cond branches)
        if isinstance(v, (tuple, list)):
            for item in v:
                yield from sub_jaxprs(item)
        else:
            inner = getattr(v, "jaxpr", None)
            if inner is not None and hasattr(inner, "eqns"):
                yield inner
            elif hasattr(v, "eqns"):
                yield v

    def walk(jaxpr) -> int:
        n = 0
        for eqn in jaxpr.eqns:
            if eqn.primitive.name == "pallas_call":
                n += 1
            for v in eqn.params.values():
                for inner in sub_jaxprs(v):
                    n += walk(inner)
        return n

    return walk(closed.jaxpr)


def dispatch(x: jax.Array, w, policy: QuantPolicy,
             act_scale: Optional[jax.Array] = None,
             precision=None, site: str = "") -> jax.Array:
    """Execute x (..., K) @ dequant(w) (K, N) on the policy's backend.

    Stacked per-expert weights (3-D `w.data`) take the grouped kernel on
    backends that support them; a `MixedExpertQuant` (per-expert mixed
    precision) dispatches each homogeneous group and stitches the outputs
    back into expert order (backends that cannot split ragged groups —
    `mixed_expert_decline_reason` — route the whole stack to their
    fallback). Falls back (one hop) when the requested backend declines
    the operand layout, recording the machine-readable reason in
    `dispatch_stats()` instead of asserting mid-trace. `site` is the
    "/"-joined weight address; layout-aware backends (the sharded one)
    classify column- vs row-parallel off its leaf name.
    """
    if isinstance(w, MixedExpertQuant):
        backend = get_backend(policy.backend)
        reason = backend.mixed_expert_decline_reason(x, w, policy)
        if reason is not None:
            _record(backend.name, reason, "[stacked]")
            policy = policy.with_backend(backend.fallback)
        return _dispatch_mixed_experts(x, w, policy, act_scale, precision,
                                       site)
    backend = get_backend(policy.backend)
    reason = backend.decline_reason(x, w, policy, site=site)
    _record(backend.name, reason, "[stacked]" if w.data.ndim > 2 else "")
    if reason is not None:
        backend = get_backend(backend.fallback)
    return backend.matmul(x, w, policy, act_scale=act_scale,
                          precision=precision, site=site)


def decode_attention(q: jax.Array, cache, pos: jax.Array, *,
                     policy: Optional[QuantPolicy] = None,
                     window: int = 0, ring: int = 0) -> jax.Array:
    """Execute single-token decode attention over a KV cache on the
    policy's backend (q: (B, 1, H, D); pos: (B,)).

    The KV-cache twin of `dispatch`: `policy.backend` (resolved per cache
    site by `models/layers.py::decode_attention`) picks the registered
    backend; the pallas backends run the fused decode-attention kernel —
    packed OVP caches unpack/dequantize PER KV TILE inside the kernel, no
    full-cache dequant ever traces — while `xla`/`reference` serve the
    dense dequant-then-einsum path. Layouts a kernel backend declines
    fall back (one hop) with the machine-readable reason recorded under a
    `"...[decode_attn]"` key in `dispatch_stats()`. `policy=None` is the
    dense XLA path (training / direct layer calls).
    """
    backend = get_backend(policy.backend if policy is not None else "xla")
    reason = backend.decode_attn_decline_reason(q, cache)
    _record(backend.name, reason, "[decode_attn]")
    if reason is not None:
        backend = get_backend(backend.fallback)
    return backend.decode_attention(q, cache, pos, window=window,
                                    ring=ring)


def prefill_attention(q: jax.Array, cache, positions: jax.Array, *,
                      policy: Optional[QuantPolicy] = None):
    """Execute paged cache-write prefill on the policy's backend
    (q: (1, C, H, D) chunk queries; cache: paged dict with block_table +
    raw stage_k/stage_v; positions: (1, C) absolute chunk positions).

    The prefill twin of `decode_attention`: the pallas backends run ONE
    pallas_call that both attends the chunk causally over the raw stage
    and OVP-quantizes every stage tile onto its physical page (no
    prefill-then-splice round trip); `xla`/`reference` serve the dense
    twin — bit-identical page bytes, attention equal up to softmax
    reassociation. Declines record a `"...[prefill_attn]"` key in
    `dispatch_stats()` and fall back one hop. Returns (out, new_cache).
    """
    backend = get_backend(policy.backend if policy is not None else "xla")
    reason = backend.prefill_attn_decline_reason(q, cache)
    _record(backend.name, reason, "[prefill_attn]")
    if reason is not None:
        backend = get_backend(backend.fallback)
    return backend.prefill_attention(q, cache, positions)


def _dispatch_mixed_experts(x: jax.Array, w: MixedExpertQuant,
                            policy: QuantPolicy,
                            act_scale: Optional[jax.Array],
                            precision, site: str = "") -> jax.Array:
    """Per-expert mixed precision: run each homogeneous group through the
    registry (so W4 groups and W8 groups each hit the grouped kernel) and
    scatter the group outputs back into the stacked expert order.

    Contract: only the WEIGHT side is per-expert — each group's precision
    comes from its QuantizedTensor (packed at quantization time under the
    expert's resolved rule). The A side, backend, and compute dtype come
    from the call-site `policy`, exactly as for any other dispatch; rule
    fields beyond weight precision (abits, backend, ...) do not vary
    within one stacked matmul. fp groups (rules that disable an expert)
    run a plain matmul with unquantized activations.

    `x` is the grouped lhs (…, E, C, K); expert membership is static
    (decided at quantization time), so the gathers/permutation lower to
    static slices under jit.
    """
    cdt = jnp.dtype(policy.compute_dtype)
    outs = []
    for qt, ids in zip(w.groups, w.expert_ids):
        idx = np.asarray(ids, dtype=np.int32)
        xg = jnp.take(x, idx, axis=-3)
        # per-slot scales carry the expert dim — gather it to match this
        # group's expert subset ((…, E, C) and (…, E, C, 1) layouts both
        # accepted; scalars / per-tensor scales pass through)
        scale = act_scale
        if scale is not None and getattr(scale, "ndim", 0):
            scale = jnp.asarray(scale)
            if scale.ndim >= 3 and scale.shape[-3] == w.n_experts \
                    and scale.shape[-1] == 1:
                scale = jnp.take(scale, idx, axis=-3)
            elif scale.ndim >= 2 and scale.shape[-2:] == x.shape[-3:-1]:
                scale = jnp.take(scale, idx, axis=-2)
        if isinstance(qt, QuantizedTensor):
            outs.append(dispatch(xg, qt, policy, act_scale=scale,
                                 precision=precision, site=site))
        else:  # fp group — the site policy resolved to "no quantization"
            outs.append(jnp.matmul(xg.astype(cdt), qt.astype(cdt),
                                   precision=precision))
    cat = jnp.concatenate([o.astype(cdt) for o in outs], axis=-3)
    flat_ids = np.concatenate([np.asarray(ids, dtype=np.int32)
                               for ids in w.expert_ids])
    order = np.argsort(flat_ids)
    return jnp.take(cat, order, axis=-3)


__all__ = ["QuantizedMatmulBackend", "register", "get_backend", "available",
           "DECLINE_CODES", "ALL_DECLINE_CODES", "DISPATCH_KEYS",
           "DISPATCH_MARKERS", "ACT_SCALE_KEYS", "decline", "dispatch_key",
           "dispatch", "decode_attention", "prefill_attention",
           "dispatch_stats",
           "reset_dispatch_stats",
           "act_scale_stats", "reset_act_scale_stats",
           "count_pallas_calls", "quantize_activation",
           "resolve_act_scale", "act_normal_dtype", "XlaBackend",
           "PallasBackend", "PallasInterpretBackend", "ReferenceBackend",
           "ShardedPallasBackend", "ShardedPallasInterpretBackend",
           "configure_mesh", "current_mesh"]
