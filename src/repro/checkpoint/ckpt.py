"""Sharded checkpointing: npz-per-step + JSON manifest, async save thread,
restore-with-resharding (elastic restarts onto a different mesh).

Layout:
  <dir>/step_<N>/arrays.npz     flat {path: ndarray} (device_get'ed)
  <dir>/step_<N>/manifest.json  step, names, dtypes, shapes, done-marker

A save is only valid once `manifest.json` exists (atomic rename), so a
preemption mid-write can never leave a checkpoint that restores garbage.
Restore targets a template pytree (structure + dtypes), then device_puts
onto the *current* mesh's shardings — the elastic path.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
import threading
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for kp, leaf in flat:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in kp)
        out[key] = leaf
    return out


def _to_npz_safe(a: np.ndarray) -> np.ndarray:
    """npz cannot round-trip ml_dtypes (bfloat16 etc.); store the raw bits
    as uint16/uint8 — the manifest keeps the true dtype for restore."""
    if a.dtype.kind == "V" or str(a.dtype) in ("bfloat16", "float8_e4m3fn",
                                               "float8_e5m2"):
        return a.view(np.uint16 if a.dtype.itemsize == 2 else np.uint8)
    return a


def save(ckpt_dir: str, step: int, tree: Any, blocking: bool = True,
         keep: int = 3) -> threading.Thread | None:
    """Save `tree` (params/opt state/metadata pytree) at `step`."""
    flat = _flatten(tree)
    # snapshot to host memory synchronously (cheap vs I/O), write async
    host = {k: np.asarray(jax.device_get(v)) for k, v in flat.items()}

    def _write():
        final = os.path.join(ckpt_dir, f"step_{step:08d}")
        tmp = tempfile.mkdtemp(dir=ckpt_dir, prefix=".tmp_")
        np.savez(os.path.join(tmp, "arrays.npz"),
                 **{k: _to_npz_safe(v) for k, v in host.items()})
        manifest = {"step": step,
                    "names": sorted(host),
                    "shapes": {k: list(v.shape) for k, v in host.items()},
                    "dtypes": {k: str(v.dtype) for k, v in host.items()}}
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)  # atomic publish
        _gc(ckpt_dir, keep)

    os.makedirs(ckpt_dir, exist_ok=True)
    if blocking:
        _write()
        return None
    t = threading.Thread(target=_write, daemon=True)
    t.start()
    return t


def _gc(ckpt_dir: str, keep: int):
    steps = sorted(d for d in os.listdir(ckpt_dir)
                   if d.startswith("step_"))
    for d in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    best = None
    for d in os.listdir(ckpt_dir):
        if d.startswith("step_") and os.path.exists(
                os.path.join(ckpt_dir, d, "manifest.json")):
            best = max(best or -1, int(d.split("_")[1]))
    return best


def restore(ckpt_dir: str, step: int, template: Any,
            shardings: Any = None) -> Any:
    """Restore into the structure of `template`. If `shardings` (matching
    pytree of NamedSharding) is given, leaves are device_put with it —
    resharding onto whatever mesh the restarted job runs on."""
    path = os.path.join(ckpt_dir, f"step_{step:08d}", "arrays.npz")
    data = np.load(path)
    flat_t = jax.tree_util.tree_flatten_with_path(template)[0]
    treedef = jax.tree_util.tree_structure(template)
    flat_s = (jax.tree_util.tree_leaves(
        shardings, is_leaf=lambda x: hasattr(x, "spec"))
        if shardings is not None else [None] * len(flat_t))
    leaves = []
    for (kp, tleaf), shd in zip(flat_t, flat_s):
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in kp)
        arr = data[key]
        want = np.dtype(tleaf.dtype)
        if arr.dtype != want:
            if arr.dtype.itemsize == want.itemsize and \
                    arr.dtype.kind == "u":
                arr = arr.view(want)     # bit-stored ml_dtype (bfloat16…)
            else:
                arr = arr.astype(want)
        leaves.append(jax.device_put(arr, shd) if shd is not None
                      else jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, leaves)
