from . import ckpt
