"""Paged KV-cache page allocator: a global pool of fixed-size pages.

OliVe's OVP packing keeps every quantized token at a FIXED byte cost per
(token, head) — 1 byte per value pair plus one f32 scale — so a KV cache
pages in fixed-size blocks with no sparsity side-tables (the property
global-coordination schemes like GOBO lack). This module is the host-side
allocator for that pool:

  pool    — each cache site holds its K/V data as `(n_pages, page_size,
            Hkv, …)` arrays instead of a `(batch_slots, max_len, …)` slab;
            page `p` is a physically contiguous tile of `page_size` token
            rows. The PAGE is the unit of both allocation and kernel DMA
            (page size == the decode kernel's kv-tile size, so a paged
            gather is one whole-tile indirection per grid step).
  tables  — a per-slot block table `(batch_slots, pages_per_slot)` int32
            maps logical page `j` of a request (token rows
            [j*page_size, (j+1)*page_size)) to its physical page id; the
            fused kernels read it as a scalar-prefetch operand, the dense
            fallback materializes pages into a slab (`gather_paged_cache`).
  accounting — `PagePool` below: free-list alloc/free keyed by request
            uid, admission-time `can_alloc` so the scheduler reserves a
            request's worst-case pages BEFORE admitting it (no
            mid-request OOM), occupancy/fragmentation stats, and
            `compact()` (defrag) which renumbers live pages onto the low
            end of the pool so an elastic deployment can shrink it.

HBM math (why paging wins): a slab reserves `batch_slots * max_len` token
rows; the pool reserves only pages actually backing live tokens, so with
mean active context `L` the same HBM serves ~`max_len / L` times the
concurrent requests (see `max_concurrent_requests` and the paged section
of benchmarks/kernels_bench.py). Pages are position-independent: physical
fragmentation never costs bytes or correctness (the fragmentation
property test interleaves free/re-alloc and asserts bit-identical
attention), so `compact()` exists for pool elasticity, not hygiene.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Tuple

import numpy as np


@dataclasses.dataclass
class PagePoolCfg:
    """Engine-facing paged-KV configuration (EngineCfg.page_pool).

    page_size: token rows per page; also the fused decode kernel's kv-tile
        size. Must be even (OVP nibbles pack 2 values/byte along head_dim;
        scales are per token so any even size aligns).
    n_pages: pool size. 0 = slab-equivalent capacity
        (batch_slots * ceil(max_len / page_size)) — same worst case HBM,
        but under-capacity pools are the point: admission blocks on
        `can_alloc`, so a pool sized for the REAL mean context serves
        strictly more concurrent requests from the same bytes.
    """
    page_size: int = 16
    n_pages: int = 0

    def __post_init__(self):
        if self.page_size < 2 or self.page_size % 2:
            raise ValueError(
                f"page_size must be an even int >= 2 (OVP packs value "
                f"pairs 2-per-byte along head_dim); got {self.page_size}")
        if self.n_pages < 0:
            raise ValueError(f"n_pages must be >= 0, got {self.n_pages}")


def pages_for(tokens: int, page_size: int) -> int:
    """Pages needed to back `tokens` rows (admission-time reservation)."""
    return max(1, math.ceil(tokens / page_size))


def kv_bytes_per_token_per_site(n_kv: int, head_dim: int,
                                kv_bits: int, fp_bytes: int = 4) -> int:
    """Bytes one token row costs in one cache site's pool.

    Packed (kv_bits=4): D/2 nibble bytes + one f32 scale, K and V each.
    fp: head_dim * itemsize, K and V each.
    """
    if kv_bits == 4:
        return 2 * (head_dim // 2 + 4) * n_kv
    return 2 * head_dim * fp_bytes * n_kv


def pool_pages_for_budget(hbm_bytes: int, page_size: int,
                          bytes_per_token: int) -> int:
    """Largest pool that fits `hbm_bytes` (bytes_per_token summed over
    every cache site — see kernels_bench's paged section)."""
    per_page = page_size * bytes_per_token
    return max(0, hbm_bytes // per_page)


def max_concurrent_requests(n_pages: int, page_size: int,
                            tokens_per_request: int) -> int:
    """How many requests of `tokens_per_request` reserved rows the pool
    admits at once — the capacity number the slab fixes at batch_slots."""
    return n_pages // pages_for(tokens_per_request, page_size)


class PagePool:
    """Free-list allocator over `n_pages` physical pages.

    Page ids are indices into every cache site's pool arrays — sites share
    one allocator because a request needs the same token rows in every
    layer, so one id list backs all of them. All accounting is host-side
    numpy/python (admission happens between jitted steps); nothing here
    traces.
    """

    def __init__(self, n_pages: int, page_size: int):
        if n_pages < 1:
            raise ValueError(f"n_pages must be >= 1, got {n_pages}")
        self.n_pages = int(n_pages)
        self.page_size = int(page_size)
        # LIFO free stack, low page ids on top: fresh allocations pack the
        # low end of the pool first, which keeps compact() cheap
        self._free: List[int] = list(range(self.n_pages - 1, -1, -1))
        self._owned: Dict[int, List[int]] = {}
        self.allocs = 0
        self.frees = 0
        self.alloc_failures = 0
        self.peak_used = 0

    # ------------------------------------------------------------ queries
    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def used_pages(self) -> int:
        return self.n_pages - len(self._free)

    def occupancy(self) -> float:
        return self.used_pages / self.n_pages

    def can_alloc(self, n: int) -> bool:
        """Admission gate: reserve-before-admit means a request either
        gets its whole worst-case page budget or stays queued."""
        return n <= len(self._free)

    def pages_of(self, owner: int) -> List[int]:
        return list(self._owned.get(owner, ()))

    def owners(self) -> List[int]:
        return sorted(self._owned)

    def high_watermark(self) -> int:
        """Highest live physical page id + 1 — the pool prefix an elastic
        deployment must keep resident. 0 when no page is held. After
        `compact()` this equals `used_pages` (no holes)."""
        live = [p for pages in self._owned.values() for p in pages]
        return max(live) + 1 if live else 0

    def fragmentation(self) -> float:
        """Free fraction of the live span [0, high_watermark): the holes
        `compact()` would squeeze out. 0.0 for an empty or perfectly
        packed pool; never affects correctness (pages are
        position-independent), only pool elasticity."""
        hw = self.high_watermark()
        return 0.0 if hw == 0 else 1.0 - self.used_pages / hw

    def stats(self) -> Dict[str, float]:
        """Pool ledger. `used_pages`/`free_pages`/`occupancy`/
        `high_watermark`/`fragmentation`/`owners` are instantaneous
        gauges; `allocs`/`frees`/`alloc_failures`/`peak_used` are
        lifetime counters (see `ServingEngine.stats()` for the shared
        semantics). The serve metrics ledger (`serve/metrics.py`)
        samples the gauges every step."""
        return {"n_pages": self.n_pages, "page_size": self.page_size,
                "used_pages": self.used_pages,
                "free_pages": self.free_pages,
                "occupancy": self.occupancy(),
                "high_watermark": self.high_watermark(),
                "fragmentation": self.fragmentation(),
                "allocs": self.allocs, "frees": self.frees,
                "alloc_failures": self.alloc_failures,
                "peak_used": self.peak_used,
                "owners": len(self._owned)}

    # ------------------------------------------------------- alloc / free
    def alloc(self, n: int, owner: int) -> Optional[List[int]]:
        """n pages for request `owner`, or None (and a counted failure)
        when the pool cannot cover them — never a partial grant."""
        if n < 1:
            raise ValueError(f"alloc of {n} pages")
        if n > len(self._free):
            self.alloc_failures += 1
            return None
        got = [self._free.pop() for _ in range(n)]
        self._owned.setdefault(owner, []).extend(got)
        self.allocs += n
        self.peak_used = max(self.peak_used, self.used_pages)
        return got

    def free(self, owner: int, pages: Optional[List[int]] = None) -> int:
        """Release `pages` of `owner` (None = all of them). Returns the
        count released. Unknown pages raise — a double free would hand one
        physical page to two requests."""
        held = self._owned.get(owner)
        if held is None:
            if pages:
                raise KeyError(f"owner {owner} holds no pages")
            return 0
        if pages is None:
            pages = list(held)
        for p in pages:
            try:
                held.remove(p)
            except ValueError:
                raise KeyError(
                    f"page {p} is not held by owner {owner} "
                    f"(double free?)") from None
            self._free.append(p)
        if not held:
            del self._owned[owner]
        self.frees += len(pages)
        return len(pages)

    # ------------------------------------------------------------- defrag
    def compact(self) -> Tuple[np.ndarray, Dict[int, int]]:
        """Renumber live pages onto [0, used_pages) — defragmentation.

        Returns (src, remap): `src` (n_pages,) int32 gathers the POOL
        arrays (`new_pool = old_pool[src]` — new page i's data comes from
        old page src[i]), `remap` rewrites page ids everywhere they are
        held (block tables, `_owned` is rewritten in place). Pages are
        position-independent so this never changes served results (the
        defrag property test asserts bit-identical attention); its point
        is pool elasticity — after compaction the tail [used_pages,
        n_pages) is entirely free and can be released.
        """
        live = sorted(p for pages in self._owned.values() for p in pages)
        remap = {old: new for new, old in enumerate(live)}
        src = np.arange(self.n_pages, dtype=np.int32)
        src[:len(live)] = live
        spare = [p for p in range(self.n_pages) if p not in remap]
        src[len(live):] = spare
        for owner, pages in self._owned.items():
            self._owned[owner] = [remap[p] for p in pages]
        self._free = list(range(self.n_pages - 1, len(live) - 1, -1))
        return src, remap
