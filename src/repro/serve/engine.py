"""Batched serving engine: continuous batching over fixed decode slots.

Requests queue up; free slots take the next request (prefill), all active
slots step together (one batched decode). Slots free on EOS / max-tokens.
Weights can be OliVe-PTQ-quantized (`quantize_params`), the KV cache
OVP-packed (policy.kv_bits=4), and activation quantization can run on
calibrated *static* scales (`EngineCfg.calibration`, validated up front —
zero per-step scale computations; see docs/calibration.md) — the paper's
serving story end to end.

Decode-step attention routes through the backend registry
(`backends.decode_attention`, resolved per cache site): on the pallas
backends the fused decode-attention kernel (`kernels/decode_attn.py`)
consumes OVP-packed caches IN PLACE — nibbles unpack per KV tile inside
the kernel, no full-cache dequant ever traces, and in-kernel masking from
the traced positions means one compiled decode step serves every
active-length mix in the slots. `EngineCfg.backend` overrides the
policy's backend for these sites too. See docs/kv_cache.md.
"""
from __future__ import annotations

import collections
import copy
import dataclasses
import time
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import backends
from repro.core.calibration import (CalibrationArtifact,
                                    MissingStaticScaleError,
                                    apply_calibration, static_scale_misses,
                                    uses_static_scales)
from repro.models.model import Model


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray                  # (T,) int32
    max_new_tokens: int = 16
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    t_submit: float = 0.0
    t_first: float = 0.0
    t_done: float = 0.0


@dataclasses.dataclass
class EngineCfg:
    batch_slots: int = 4
    max_len: int = 256
    eos_id: int = -1            # -1: no EOS, run to max_new_tokens
    greedy: bool = True
    # quantized-matmul execution backend override; None keeps the model
    # policy's backend. Must name a `repro.backends` registry entry.
    backend: Optional[str] = None
    # calibrated static activation scales (see docs/calibration.md): baked
    # into the model policy at engine construction via `apply_calibration`.
    # With `act_scale_mode="static"` anywhere in the policy, construction
    # validates that EVERY static-mode quantized site has a scale —
    # misses raise the machine-readable `MissingStaticScaleError` up
    # front instead of mid-trace on the first prefill.
    calibration: Optional[CalibrationArtifact] = None


class ServingEngine:
    """Single-host reference engine (the multi-host path shards the same
    jitted steps over the mesh via pjit; see launch/serve.py)."""

    def __init__(self, model: Model, params, cfg: EngineCfg):
        if cfg.backend is not None and \
                model.policy.backends() != frozenset((cfg.backend,)):
            # shallow-copy so the override never leaks into other users of
            # the caller's Model instance (`with_backend` rewrites every
            # rule of a policy program)
            model = copy.copy(model)
            model.policy = model.policy.with_backend(cfg.backend)
        if cfg.calibration is not None:
            model = copy.copy(model)
            model.policy = apply_calibration(model.policy, cfg.calibration)
        # resolve every rule's backend through the registry up front: a
        # typo'd backend name fails here, not mid-trace on first prefill
        for name in model.policy.backends():
            backends.get_backend(name)
        # static-scale completeness: every quantized site that will
        # quantize activations at a calibrated scale must actually have
        # one. Fails at construction with the full site list (the
        # mid-trace backstop can only name one site at a time).
        if uses_static_scales(model.policy):
            misses = static_scale_misses(params, model.policy)
            if misses and cfg.calibration is not None \
                    and not getattr(model, "unrolled", False) \
                    and any(k.lower().startswith("layers/")
                            for k in cfg.calibration.as_dict()):
                # the artifact was calibrated on the unrolled layout but
                # this model (and its quantized tree) is still scanned —
                # its sites are blocks/<j>, so no layers/<i> key can ever
                # match. Diagnose the layout, not just the misses.
                raise ValueError(
                    "calibration artifact keys address the unrolled "
                    "layers/<i> layout but this model is scanned "
                    "(blocks/<j> sites). Apply the artifact with "
                    "apply_calibration() BEFORE build_model / "
                    "quantize_params so the program unrolls the model "
                    "(launch/serve.py does this; see docs/calibration.md)"
                    ", or key the artifact by blocks/<j>")
            if misses:
                raise MissingStaticScaleError(misses)
        self.model = model
        self.params = params
        self.cfg = cfg
        self.queue: collections.deque[Request] = collections.deque()
        self.slots: List[Optional[Request]] = [None] * cfg.batch_slots
        self.pos = np.zeros((cfg.batch_slots,), np.int32)
        self.caches = model.init_caches(cfg.batch_slots, cfg.max_len,
                                        dtype=jnp.float32)
        self.completed: List[Request] = []
        self._uid = 0
        # Bucketed prefill right-pads the prompt so the trace is keyed by
        # the bucket length, not the exact prompt length. Under a causal
        # index mask real tokens never attend the trailing pads and the pad
        # cache rows sit beyond `pos`, where decode overwrites them before
        # they can become valid — but recurrent states and ring (sliding-
        # window) caches DO absorb trailing garbage, so those block types
        # keep the exact-length path.
        self._bucket_ok = all(bt in ("attn", "moe")
                              for bt in model.cfg.block_pattern)
        self.prefill_traces = 0  # trace counter (tests assert bucket reuse)

        def prefill_one(params, caches, tokens, length):
            """Prefill one slot row; `tokens` (1, bucket) right-padded,
            `length` the true prompt length (traced, so one jit trace
            serves every prompt in the bucket)."""
            self.prefill_traces += 1
            logits, new_caches, _ = self.model.forward(
                params, {"tokens": tokens}, mode="prefill", caches=caches)
            return jnp.take(logits, length - 1, axis=1), new_caches

        def decode_step(params, caches, tokens, pos):
            logits, new_caches, _ = self.model.forward(
                params, {"tokens": tokens, "pos": pos}, mode="decode",
                caches=caches)
            return logits[:, 0], new_caches

        self._decode = jax.jit(decode_step)
        self._prefill = prefill_one  # jit per prompt-length bucket below
        self._prefill_cache: Dict[int, Callable] = {}

    # -------------------------------------------------------------- API
    def submit(self, prompt: np.ndarray, max_new_tokens: int = 16) -> int:
        self._uid += 1
        self.queue.append(Request(uid=self._uid,
                                  prompt=np.asarray(prompt, np.int32),
                                  max_new_tokens=max_new_tokens,
                                  t_submit=time.monotonic()))
        return self._uid

    def _bucket(self, n: int) -> int:
        b = 16
        while b < n:
            b *= 2
        return b

    def _admit(self):
        """Fill free slots from the queue (prefill batched per request).

        Prompts right-pad to the bucket length so the jit cache key (the
        bucket) matches the traced shape: every prompt length in a bucket
        reuses one trace. Next-token logits read at `length - 1`."""
        for s in range(self.cfg.batch_slots):
            # loop: a request finished by its own prefill token frees the
            # slot for the next queued request in the same admit pass
            while self.slots[s] is None and self.queue:
                req = self.queue.popleft()
                t = len(req.prompt)
                bucket = self._bucket(t) if self._bucket_ok else t
                toks = np.zeros((bucket,), np.int32)
                toks[:t] = req.prompt  # right-pad; causal mask shields pads
                key = bucket
                if key not in self._prefill_cache:
                    self._prefill_cache[key] = jax.jit(self._prefill)
                # prefill into a fresh single-row cache, splice into slot s
                row_cache = self.model.init_caches(1, self.cfg.max_len,
                                                   dtype=jnp.float32)
                logits, row_cache = self._prefill_cache[key](
                    self.params, row_cache, jnp.asarray(toks[None, :]),
                    jnp.int32(t))
                self.caches = _splice_slot(self.caches, row_cache, s)
                self.pos[s] = t
                nxt = int(jnp.argmax(logits[0]))
                req.out_tokens.append(nxt)
                req.t_first = time.monotonic()
                if (self.cfg.eos_id >= 0 and nxt == self.cfg.eos_id) or \
                        len(req.out_tokens) >= req.max_new_tokens:
                    # the prefill token already satisfies the budget (or
                    # hit EOS): never enter decode — a max_new_tokens=1
                    # request must return exactly one token, not two
                    req.done = True
                    req.t_done = time.monotonic()
                    self.completed.append(req)
                    continue
                self.slots[s] = req

    def _active(self) -> List[int]:
        return [i for i, r in enumerate(self.slots) if r is not None]

    def step(self):
        """One engine iteration: admit + one batched decode step."""
        self._admit()
        act = self._active()
        if not act:
            return
        tokens = np.zeros((self.cfg.batch_slots, 1), np.int32)
        for i in act:
            tokens[i, 0] = self.slots[i].out_tokens[-1]
        logits, self.caches = self._decode(
            self.params, self.caches, jnp.asarray(tokens),
            jnp.asarray(self.pos))
        nxt = np.asarray(jnp.argmax(logits, axis=-1))
        for i in act:
            req = self.slots[i]
            self.pos[i] += 1
            tok = int(nxt[i])
            req.out_tokens.append(tok)
            if (self.cfg.eos_id >= 0 and tok == self.cfg.eos_id) or \
                    len(req.out_tokens) >= req.max_new_tokens or \
                    int(self.pos[i]) >= self.cfg.max_len - 1:
                req.done = True
                req.t_done = time.monotonic()
                self.completed.append(req)
                self.slots[i] = None

    def run_until_drained(self, max_steps: int = 10000):
        steps = 0
        while (self.queue or self._active()) and steps < max_steps:
            self.step()
            steps += 1
        return self.completed


def _splice_slot(full_caches, row_caches, slot: int):
    """Copy a 1-row cache pytree into row `slot` of the batched caches.

    Batch is the first dim of unstacked leaves and the second of scan-
    stacked leaves (leading group dim) — detected by matching shapes.
    """
    def splice(full, row):
        if full.shape == row.shape:
            return row
        # find the axis where row has size 1 and full has batch_slots
        for ax in range(row.ndim):
            if row.shape[ax] == 1 and full.shape[ax] != 1 and \
                    row.shape[:ax] == full.shape[:ax] and \
                    row.shape[ax + 1:] == full.shape[ax + 1:]:
                idx = [slice(None)] * full.ndim
                idx[ax] = slice(slot, slot + 1)
                return full.at[tuple(idx)].set(row.astype(full.dtype))
        # silently keeping `full` here would drop the prefilled row and
        # serve the request on a stale cache — fail loudly instead
        raise ValueError(
            f"_splice_slot: cannot splice row cache of shape {row.shape} "
            f"into batched cache of shape {full.shape}: no axis has "
            f"size 1 in the row and the slot count in the batch")

    return jax.tree_util.tree_map(splice, full_caches, row_caches)
