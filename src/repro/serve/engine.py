"""Batched serving engine: continuous batching over fixed decode slots.

Requests queue up; free slots take the next request (prefill), all active
slots step together (one batched decode). Slots free on EOS / max-tokens.
`step()` is the ONE step API — it returns `StepEvents` (every token
sampled this step, attributed to its request) and both serve loops build
on it: the synchronous `run_until_drained` batch loop here, and the
asyncio streaming front end in `serve/frontend.py` (per-request token
streams + TTFT/TPOT SLO metrics via `serve/metrics.py`; see
docs/serving.md).
Weights can be OliVe-PTQ-quantized (`quantize_params`), the KV cache
OVP-packed (policy.kv_bits=4), and activation quantization can run on
calibrated *static* scales (`EngineCfg.calibration`, validated up front —
zero per-step scale computations; see docs/calibration.md) — the paper's
serving story end to end.

Decode-step attention routes through the backend registry
(`backends.decode_attention`, resolved per cache site): on the pallas
backends the fused decode-attention kernel (`kernels/decode_attn.py`)
consumes OVP-packed caches IN PLACE — nibbles unpack per KV tile inside
the kernel, no full-cache dequant ever traces, and in-kernel masking from
the traced positions means one compiled decode step serves every
active-length mix in the slots. `EngineCfg.backend` overrides the
policy's backend for these sites too. See docs/kv_cache.md.

PAGED mode (`EngineCfg.page_pool`): instead of one dense
`(batch_slots, max_len)` slab per cache site, every site shares a global
pool of fixed-size OVP-packed pages (`serve/paging.py`); a per-slot block
table maps logical token rows to physical pages and admission reserves a
request's WORST-CASE pages up front (`PagePool.can_alloc`), so a request
never OOMs mid-decode — it queues instead. Prefill runs CHUNKED: `_admit`
stages the prompt and `step()` interleaves at most ONE fixed-size prefill
chunk per engine step into the running decode batch (a long prompt never
stalls decode for more than one chunk), each chunk one fused
cache-write-prefill dispatch (`backends.prefill_attention`) that attends
the raw staged prompt AND quantizes every stage tile onto its pages —
no `_splice_slot` round trip. Decode gathers K/V tiles through the block
table inside the same fused decode kernel (page size == kv tile size).
Slots free their pages on completion; `defrag()` compacts the pool.
"""
from __future__ import annotations

import collections
import copy
import dataclasses
import time
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import backends
from repro.analysis import sanitize
from repro.core.calibration import (CalibrationArtifact,
                                    MissingStaticScaleError,
                                    apply_calibration, static_scale_misses,
                                    uses_static_scales)
from repro.models.model import Model
from repro.serve.paging import PagePool, PagePoolCfg, pages_for


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray                  # (T,) int32
    max_new_tokens: int = 16
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    # why the request stopped: "eos" | "max_new_tokens" | "length_cap"
    # (hit cfg.max_len - 1 — previously a silent truncation)
    finish_reason: Optional[str] = None
    t_submit: float = 0.0
    t_first: float = 0.0
    t_done: float = 0.0


@dataclasses.dataclass
class TokenEvent:
    """One sampled token, attributed to its request — the unit both the
    async streaming front end (`serve/frontend.py`) and the metrics
    ledger (`serve/metrics.py`) consume. Emitted the same engine step the
    token is sampled: prefill tokens carry `first=True` (the TTFT token),
    and the request's terminal token carries `done`/`finish_reason`."""
    uid: int
    token: int
    index: int                  # 0-based position in Request.out_tokens
    first: bool                 # True for the prefill (TTFT) token
    done: bool
    finish_reason: Optional[str] = None


@dataclasses.dataclass
class StepEvents:
    """What one `ServingEngine.step()` did, in consumable form.

    This is the step API both serve loops share: `run_until_drained`
    (batch/benchmark mode) and the asyncio front end both just call
    `step()` and read the returned events — neither reaches into slots
    or diffs `out_tokens`. All counts are PER STEP; lifetime counters
    live in `ServingEngine.stats()`.
    """
    step: int                   # 0-based engine step index
    t_start: float              # time.monotonic() at step entry / exit
    t_end: float
    admitted: List[int]         # uids leaving the queue this step
    prefill_chunks: int         # chunked-prefill dispatches run (0 or 1)
    decode_batch: int           # active slots in this step's batched decode
    tokens: List[TokenEvent]    # every token sampled this step
    queue_depth: int            # queued requests AFTER the step
    active: int                 # occupied decode slots after the step
    prefilling: int             # requests mid-chunked-prefill after the step


@dataclasses.dataclass
class _Prefilling:
    """One request mid-chunked-prefill (paged mode): pages are already
    reserved, the raw prompt K/V accumulates in per-site stage buffers,
    and `step()` feeds one chunk per engine step until `written` covers
    the prompt."""
    req: Request
    slot: int
    toks: np.ndarray        # (stage_len,) right-padded prompt
    t: int                  # true prompt length
    chunk: int              # tokens per chunk (page-size multiple)
    stage_len: int          # staged rows (trace key; page-size multiple)
    stage_tiles: int        # stage_len // page_size
    pages: List[int]        # physical pages, logical order
    gen_pages: int          # pages kept after prefill (decode horizon)
    target: int             # chunked tokens to process: ceil(t/chunk)*chunk
    written: int            # tokens already prefilled
    stage: object           # per-site {"stage_k","stage_v"} pytree


@dataclasses.dataclass
class EngineCfg:
    batch_slots: int = 4
    max_len: int = 256
    eos_id: int = -1            # -1: no EOS, run to max_new_tokens
    greedy: bool = True
    # quantized-matmul execution backend override; None keeps the model
    # policy's backend. Must name a `repro.backends` registry entry.
    backend: Optional[str] = None
    # calibrated static activation scales (see docs/calibration.md): baked
    # into the model policy at engine construction via `apply_calibration`.
    # With `act_scale_mode="static"` anywhere in the policy, construction
    # validates that EVERY static-mode quantized site has a scale —
    # misses raise the machine-readable `MissingStaticScaleError` up
    # front instead of mid-trace on the first prefill.
    calibration: Optional[CalibrationArtifact] = None
    # paged KV cache (serve/paging.py): replaces the per-site
    # (batch_slots, max_len) slab with a global page pool + block tables,
    # chunked prefill, and page-level admission control. Needs a pure
    # attn/moe block pattern. None = slab mode (unchanged).
    page_pool: Optional[PagePoolCfg] = None
    # chunked-prefill chunk size in tokens (paged mode; rounded up to a
    # page multiple). 0 = whole prompt in one chunk. Either way at most
    # ONE chunk runs per engine step, interleaved with decode.
    prefill_chunk: int = 0
    # LRU cap on the per-bucket jitted-prefill cache: with exact-length
    # prefill (non-bucketable block patterns) the cache previously grew
    # one entry per distinct prompt length, without bound.
    prefill_cache_cap: int = 8
    # device mesh for the sharded backends: a `runtime.elastic.MeshPlan`
    # (or a built `jax.sharding.Mesh`), installed via
    # `backends.configure_mesh` at engine construction so the
    # `pallas_sharded*` backends see it. None leaves any process-level
    # mesh untouched — without one those backends decline every call with
    # `shard_no_mesh` and serve through their dense fallback.
    mesh: Optional[object] = None


class ServingEngine:
    """Single-host reference engine (the multi-host path shards the same
    jitted steps over the mesh via pjit; see launch/serve.py)."""

    def __init__(self, model: Model, params, cfg: EngineCfg):
        # REPRO_SANITIZE=1: jax_debug_nans + checkified steps + the
        # trace audit (no-op otherwise; see repro.analysis.sanitize)
        sanitize.configure()
        if cfg.backend is not None and \
                model.policy.backends() != frozenset((cfg.backend,)):
            # shallow-copy so the override never leaks into other users of
            # the caller's Model instance (`with_backend` rewrites every
            # rule of a policy program)
            model = copy.copy(model)
            model.policy = model.policy.with_backend(cfg.backend)
        if cfg.calibration is not None:
            model = copy.copy(model)
            model.policy = apply_calibration(model.policy, cfg.calibration)
        # resolve every rule's backend through the registry up front: a
        # typo'd backend name fails here, not mid-trace on first prefill
        for name in model.policy.backends():
            backends.get_backend(name)
        if cfg.mesh is not None:
            # install the mesh BEFORE any trace so the sharded backends'
            # decline checks see the real model-axis size from step one
            backends.configure_mesh(cfg.mesh)
        # static-scale completeness: every quantized site that will
        # quantize activations at a calibrated scale must actually have
        # one. Fails at construction with the full site list (the
        # mid-trace backstop can only name one site at a time).
        if uses_static_scales(model.policy):
            misses = static_scale_misses(params, model.policy)
            if misses and cfg.calibration is not None \
                    and not getattr(model, "unrolled", False) \
                    and any(k.lower().startswith("layers/")
                            for k in cfg.calibration.as_dict()):
                # the artifact was calibrated on the unrolled layout but
                # this model (and its quantized tree) is still scanned —
                # its sites are blocks/<j>, so no layers/<i> key can ever
                # match. Diagnose the layout, not just the misses.
                raise ValueError(
                    "calibration artifact keys address the unrolled "
                    "layers/<i> layout but this model is scanned "
                    "(blocks/<j> sites). Apply the artifact with "
                    "apply_calibration() BEFORE build_model / "
                    "quantize_params so the program unrolls the model "
                    "(launch/serve.py does this; see docs/calibration.md)"
                    ", or key the artifact by blocks/<j>")
            if misses:
                raise MissingStaticScaleError(misses)
        self.model = model
        self.params = params
        self.cfg = cfg
        self.queue: collections.deque[Request] = collections.deque()
        self.slots: List[Optional[Request]] = [None] * cfg.batch_slots
        self.pos = np.zeros((cfg.batch_slots,), np.int32)
        self.completed: List[Request] = []
        self._uid = 0
        # Bucketed prefill right-pads the prompt so the trace is keyed by
        # the bucket length, not the exact prompt length. Under a causal
        # index mask real tokens never attend the trailing pads and the pad
        # cache rows sit beyond `pos`, where decode overwrites them before
        # they can become valid — but recurrent states and ring (sliding-
        # window) caches DO absorb trailing garbage, so those block types
        # keep the exact-length path.
        self._bucket_ok = all(bt in ("attn", "moe")
                              for bt in model.cfg.block_pattern)
        self.prefill_traces = 0  # trace counter (tests assert bucket reuse)
        self.decode_traces = 0   # the single decode jit should trace once
        self._prefill_jits = 0   # jit entries built (traces > jits means
        #                          a jitted entry silently retraced)
        self.prefill_cache_evictions = 0
        self.prefill_chunks_run = 0
        self.steps_run = 0
        # per-step event buffers, drained into the StepEvents that
        # `step()` returns (see the StepEvents docstring)
        self._token_events: List[TokenEvent] = []
        self._admitted_uids: List[int] = []

        self.paged = cfg.page_pool is not None
        if self.paged:
            if not self._bucket_ok:
                raise ValueError(
                    f"page_pool needs a pure attn/moe block pattern "
                    f"(ring/recurrent state does not page); got "
                    f"{model.cfg.block_pattern}")
            pp = cfg.page_pool
            # table width covers the BUCKETED stage of the longest prompt,
            # not just max_len (buckets round up to powers of two)
            self.pages_per_row = pages_for(self._bucket(cfg.max_len),
                                           pp.page_size)
            n_pages = pp.n_pages or cfg.batch_slots * self.pages_per_row
            self.pool = PagePool(n_pages, pp.page_size)
            self._bt = np.zeros((cfg.batch_slots, self.pages_per_row),
                                np.int32)
            self.caches = model.init_paged_caches(
                n_pages, pp.page_size, cfg.batch_slots, self.pages_per_row,
                dtype=jnp.float32)
            self._prefilling: collections.deque = collections.deque()
            self._prefill_slots: set = set()
            # inactive slots decode in the batch like everyone else (the
            # batched step has no per-row gating); park their write index
            # past the table capacity so the scatter DROPS instead of
            # landing on page 0, which a live request may own
            self._pos_parked = self.pages_per_row * pp.page_size
            self.pos[:] = self._pos_parked
            self._sync_tables()
        else:
            self.caches = model.init_caches(cfg.batch_slots, cfg.max_len,
                                            dtype=jnp.float32)

        def prefill_one(params, caches, tokens, length):
            """Prefill one slot row; `tokens` (1, bucket) right-padded,
            `length` the true prompt length (traced, so one jit trace
            serves every prompt in the bucket)."""
            self.prefill_traces += 1
            logits, new_caches, _ = self.model.forward(
                params, {"tokens": tokens}, mode="prefill", caches=caches)
            return jnp.take(logits, length - 1, axis=1), new_caches

        def decode_step(params, caches, tokens, pos):
            self.decode_traces += 1
            logits, new_caches, _ = self.model.forward(
                params, {"tokens": tokens, "pos": pos}, mode="decode",
                caches=caches)
            return logits[:, 0], new_caches

        def prefill_chunk(params, caches, tokens, positions, len_m1):
            """One chunked-prefill dispatch (paged mode): tokens (1, C) of
            one request, positions (1, C) absolute, `len_m1` the prompt's
            last index (traced — the chunk offset and the logit read both
            trace, so ONE jit trace per stage length serves every chunk of
            every prompt in the bucket)."""
            self.prefill_traces += 1
            logits, new_caches, _ = self.model.forward(
                params, {"tokens": tokens}, mode="prefill", caches=caches,
                positions=positions)
            idx = jnp.clip(len_m1 - positions[0, 0], 0,
                           tokens.shape[1] - 1)
            return jnp.take(logits, idx, axis=1), new_caches

        self._decode = sanitize.jit_checked(decode_step)
        self._prefill = prefill_one  # jit per prompt-length bucket below
        self._prefill_chunk = prefill_chunk
        # LRU over jitted prefill entries (keyed by bucket / stage length)
        self._prefill_cache: "collections.OrderedDict[object, Callable]" \
            = collections.OrderedDict()

    # -------------------------------------------------------------- API
    def submit(self, prompt: np.ndarray, max_new_tokens: int = 16) -> int:
        self._uid += 1
        self.queue.append(Request(uid=self._uid,
                                  prompt=np.asarray(prompt, np.int32),
                                  max_new_tokens=max_new_tokens,
                                  t_submit=time.monotonic()))
        return self._uid

    def _bucket(self, n: int) -> int:
        b = 16
        while b < n:
            b *= 2
        return b

    def _jit_prefill(self, key, fn) -> Callable:
        """Jitted-prefill cache with an LRU cap: exact-length prefill
        (non-bucketable patterns) keys on the raw prompt length, which is
        unbounded over a long-running serve."""
        cache = self._prefill_cache
        if key in cache:
            cache.move_to_end(key)
            return cache[key]
        jitted = sanitize.jit_checked(fn)
        self._prefill_jits += 1
        cache[key] = jitted
        while len(cache) > max(1, self.cfg.prefill_cache_cap):
            cache.popitem(last=False)
            self.prefill_cache_evictions += 1
        return jitted

    def trace_audit(self) -> Dict[str, int]:
        """Jit-trace ledger for the sanitizer's retrace audit: a prefill
        trace the bucket/stage-length cache should have absorbed, or a
        decode jit tracing more than once, counts as unexpected (a
        shape/dtype/weak-type drifted between calls meant to share one
        trace). `repro.analysis.sanitize.audit_traces` fails on it."""
        return {
            "prefill_traces": self.prefill_traces,
            "prefill_jits": self._prefill_jits,
            "decode_traces": self.decode_traces,
            "unexpected_retraces":
                max(0, self.prefill_traces - self._prefill_jits)
                + max(0, self.decode_traces - 1),
        }

    # ------------------------------------------------- paged-cache helpers
    @staticmethod
    def _map_sites(tree, fn):
        """Apply fn to every paged cache-site dict (detected by its
        "block_table" key) in a cache pytree."""
        if isinstance(tree, dict):
            if "block_table" in tree:
                return fn(tree)
            return {k: ServingEngine._map_sites(v, fn)
                    for k, v in tree.items()}
        if isinstance(tree, (list, tuple)):
            return type(tree)(ServingEngine._map_sites(v, fn)
                              for v in tree)
        return tree

    @staticmethod
    def _pair_sites(a, b, fn):
        """Zip two cache pytrees (a drives the structure) and apply fn at
        each paged site pair."""
        if isinstance(a, dict):
            if "block_table" in a:
                return fn(a, b)
            return {k: ServingEngine._pair_sites(a[k], b[k], fn)
                    for k in a}
        if isinstance(a, (list, tuple)):
            return type(a)(ServingEngine._pair_sites(x, y, fn)
                           for x, y in zip(a, b))
        return a

    def _sync_tables(self):
        """Push the host block table into every cache site (scan-stacked
        sites broadcast the same table across groups — page ids back the
        same token rows in every layer)."""
        bt = jnp.asarray(self._bt)

        def set_bt(site):
            cur = site["block_table"]
            new = bt if cur.ndim == 2 else \
                jnp.broadcast_to(bt[None], cur.shape)
            return dict(site, block_table=new)

        self.caches = self._map_sites(self.caches, set_bt)

    def _fresh_stage(self, site, stage_len: int):
        cfg = self.model.cfg
        shape = (1, stage_len, cfg.n_kv_heads, cfg.head_dim)
        if site["block_table"].ndim == 3:
            shape = (site["block_table"].shape[0],) + shape
        z = jnp.zeros(shape, jnp.float32)
        return {"stage_k": z, "stage_v": z}

    def _emit_token(self, req: Request, tok: int, first: bool):
        """Record one sampled token into the current step's event buffer
        (call AFTER the request's done/finish_reason are settled)."""
        self._token_events.append(TokenEvent(
            uid=req.uid, token=tok, index=len(req.out_tokens) - 1,
            first=first, done=req.done, finish_reason=req.finish_reason))

    def _admit(self):
        if self.paged:
            self._admit_paged()
            return
        self._admit_slab()

    def _admit_slab(self):
        """Fill free slots from the queue (prefill batched per request).

        Prompts right-pad to the bucket length so the jit cache key (the
        bucket) matches the traced shape: every prompt length in a bucket
        reuses one trace. Next-token logits read at `length - 1`."""
        for s in range(self.cfg.batch_slots):
            # loop: a request finished by its own prefill token frees the
            # slot for the next queued request in the same admit pass
            while self.slots[s] is None and self.queue:
                req = self.queue.popleft()
                self._admitted_uids.append(req.uid)
                t = len(req.prompt)
                bucket = self._bucket(t) if self._bucket_ok else t
                toks = np.zeros((bucket,), np.int32)
                toks[:t] = req.prompt  # right-pad; causal mask shields pads
                fn = self._jit_prefill(bucket, self._prefill)
                # prefill into a fresh single-row cache, splice into slot s
                row_cache = self.model.init_caches(1, self.cfg.max_len,
                                                   dtype=jnp.float32)
                logits, row_cache = fn(
                    self.params, row_cache, jnp.asarray(toks[None, :]),
                    jnp.int32(t))
                self.caches = _splice_slot(self.caches, row_cache, s)
                self.pos[s] = t
                nxt = int(jnp.argmax(logits[0]))
                req.out_tokens.append(nxt)
                req.t_first = time.monotonic()
                finished = self._finish_at_admit(req, nxt)
                self._emit_token(req, nxt, first=True)
                if not finished:
                    self.slots[s] = req

    def _finish_at_admit(self, req: Request, nxt: int) -> bool:
        """The prefill token already satisfies the budget (or hit EOS):
        never enter decode — a max_new_tokens=1 request must return
        exactly one token, not two."""
        if self.cfg.eos_id >= 0 and nxt == self.cfg.eos_id:
            req.finish_reason = "eos"
        elif len(req.out_tokens) >= req.max_new_tokens:
            req.finish_reason = "max_new_tokens"
        else:
            return False
        req.done = True
        req.t_done = time.monotonic()
        self.completed.append(req)
        return True

    def _admit_paged(self):
        """Reserve pages + a slot for queued requests and move them into
        the chunked-prefill pipeline. Admission is all-or-nothing on the
        request's WORST-CASE page budget (prompt stage + full decode
        horizon), so a running request can never OOM the pool mid-decode;
        FIFO order holds — a head-of-line request that doesn't fit blocks
        the queue until frees make room."""
        ps = self.pool.page_size
        for s in range(self.cfg.batch_slots):
            if not self.queue:
                return
            if self.slots[s] is not None or s in self._prefill_slots:
                continue
            req = self.queue[0]
            t = len(req.prompt)
            chunk = self.cfg.prefill_chunk
            chunk = -(-chunk // ps) * ps if chunk else 0
            stage_len = -(-self._bucket(t) // (chunk or ps)) * (chunk or ps)
            chunk = chunk or stage_len
            stage_tiles = stage_len // ps
            horizon = min(t + req.max_new_tokens, self.cfg.max_len)
            gen_pages = pages_for(horizon, ps)
            need = max(gen_pages, stage_tiles)
            got = self.pool.alloc(need, req.uid)
            if got is None:
                return
            self.queue.popleft()
            self._admitted_uids.append(req.uid)
            toks = np.zeros((stage_len,), np.int32)
            toks[:t] = req.prompt
            self._bt[s, :] = 0
            self._bt[s, :need] = got
            self._sync_tables()
            stage = self._map_sites(
                self.caches, lambda site: self._fresh_stage(site,
                                                            stage_len))
            self._prefilling.append(_Prefilling(
                req=req, slot=s, toks=toks, t=t, chunk=chunk,
                stage_len=stage_len, stage_tiles=stage_tiles, pages=got,
                gen_pages=gen_pages, target=-(-t // chunk) * chunk,
                written=0, stage=stage))
            self._prefill_slots.add(s)

    def _run_prefill_chunk(self):
        """Feed ONE chunk of the oldest mid-prefill request through the
        fused cache-write prefill — the per-step prefill budget that keeps
        long prompts from stalling the decode batch."""
        if not self._prefilling:
            return
        pf = self._prefilling[0]
        off = pf.written
        toks = pf.toks[off:off + pf.chunk]
        positions = np.arange(off, off + pf.chunk, dtype=np.int32)
        bt_row = jnp.asarray(np.asarray(pf.pages[:pf.stage_tiles],
                                        np.int32)[None])

        def view(site, stage):
            btv = bt_row if site["block_table"].ndim == 2 else \
                jnp.broadcast_to(bt_row[None],
                                 (site["block_table"].shape[0],)
                                 + bt_row.shape)
            return dict(site, block_table=btv, **stage)

        caches_view = self._pair_sites(self.caches, pf.stage, view)
        fn = self._jit_prefill(("paged", pf.stage_len),
                               self._prefill_chunk)
        logits, new_view = fn(self.params, caches_view,
                              jnp.asarray(toks[None]),
                              jnp.asarray(positions[None]),
                              jnp.int32(pf.t - 1))
        self.prefill_chunks_run += 1
        # pool leaves mutated by the chunk write back into the live
        # caches NOW (decode steps of other slots interleave between
        # chunks); the raw stage persists on the request
        self.caches = self._pair_sites(
            self.caches, new_view,
            lambda site, new: dict(site, **{k: new[k] for k in site
                                            if k != "block_table"}))
        pf.stage = self._pair_sites(
            self.caches, new_view,
            lambda site, new: {"stage_k": new["stage_k"],
                               "stage_v": new["stage_v"]})
        pf.written += pf.chunk
        if pf.written < pf.target:
            return
        # prompt fully prefilled: release the stage-only page surplus
        # (stage tiles past the decode horizon) and activate the slot
        req, s = pf.req, pf.slot
        self._prefilling.popleft()
        self._prefill_slots.discard(s)
        if len(pf.pages) > pf.gen_pages:
            self.pool.free(req.uid, pf.pages[pf.gen_pages:])
        self._bt[s, :] = 0
        self._bt[s, :pf.gen_pages] = pf.pages[:pf.gen_pages]
        self._sync_tables()
        nxt = int(jnp.argmax(logits[0]))
        req.out_tokens.append(nxt)
        req.t_first = time.monotonic()
        finished = self._finish_at_admit(req, nxt)
        self._emit_token(req, nxt, first=True)
        if finished:
            self._free_slot_pages(s, req)
            return
        self.pos[s] = pf.t
        self.slots[s] = req

    def _free_slot_pages(self, s: int, req: Request):
        self.pool.free(req.uid)
        self._bt[s, :] = 0
        self.pos[s] = self._pos_parked
        self._sync_tables()

    def _active(self) -> List[int]:
        return [i for i, r in enumerate(self.slots) if r is not None]

    def step(self) -> StepEvents:
        """One engine iteration: admit, at most one prefill chunk (paged
        mode), then one batched decode step for every active slot.

        Returns the step's `StepEvents` — every token sampled this step
        (with its request attribution), admissions, and post-step
        queue/slot occupancy. Both serve loops (`run_until_drained` and
        the asyncio front end in `serve/frontend.py`) drive this one
        method and consume the events; nothing else mutates the engine.
        """
        t_start = time.monotonic()
        self._token_events = []
        self._admitted_uids = []
        chunks_before = self.prefill_chunks_run
        self._admit()
        if self.paged:
            self._run_prefill_chunk()
        act = self._active()
        decode_batch = len(act)
        if act:
            tokens = np.zeros((self.cfg.batch_slots, 1), np.int32)
            for i in act:
                tokens[i, 0] = self.slots[i].out_tokens[-1]
            logits, self.caches = self._decode(
                self.params, self.caches, jnp.asarray(tokens),
                jnp.asarray(self.pos))
            nxt = np.asarray(jnp.argmax(logits, axis=-1))
            for i in act:
                req = self.slots[i]
                self.pos[i] += 1
                tok = int(nxt[i])
                req.out_tokens.append(tok)
                if self.cfg.eos_id >= 0 and tok == self.cfg.eos_id:
                    reason = "eos"
                elif len(req.out_tokens) >= req.max_new_tokens:
                    reason = "max_new_tokens"
                elif int(self.pos[i]) >= self.cfg.max_len - 1:
                    # out of cache rows before the token budget: surface
                    # the truncation instead of silently stopping early
                    reason = "length_cap"
                else:
                    self._emit_token(req, tok, first=False)
                    continue
                req.done = True
                req.finish_reason = reason
                req.t_done = time.monotonic()
                self.completed.append(req)
                self.slots[i] = None
                if self.paged:
                    self._free_slot_pages(i, req)
                self._emit_token(req, tok, first=False)
        ev = StepEvents(
            step=self.steps_run, t_start=t_start, t_end=time.monotonic(),
            admitted=self._admitted_uids, prefill_chunks=(
                self.prefill_chunks_run - chunks_before),
            decode_batch=decode_batch, tokens=self._token_events,
            queue_depth=len(self.queue), active=len(self._active()),
            prefilling=len(self._prefilling) if self.paged else 0)
        self.steps_run += 1
        return ev

    def has_work(self) -> bool:
        """True while a `step()` could make progress: requests queued,
        decoding, or mid-chunked-prefill. Both serve loops poll this."""
        return bool(self.queue or self._active()
                    or (self.paged and self._prefilling))

    def run_until_drained(self, max_steps: int = 10000, metrics=None):
        """Synchronous batch loop: step until no request is queued,
        prefilling, or decoding. `metrics` (a
        `serve.metrics.MetricsLedger`) records every step's events —
        the same ledger the async front end feeds, so drained-loop
        benchmarks and async serves produce comparable traces."""
        steps = 0
        while self.has_work() and steps < max_steps:
            ev = self.step()
            if metrics is not None:
                metrics.on_step(ev, self)
            steps += 1
        return self.completed

    # ------------------------------------------------------ observability
    def stats(self) -> Dict[str, object]:
        """Engine counters: prefill trace/cache behaviour, chunk counts,
        steps run, and (paged mode) the page pool's occupancy/failure
        stats.

        COUNTER SEMANTICS — every scalar here is a LIFETIME counter:
        monotone non-decreasing since engine construction, never reset by
        `step()` or `run_until_drained()` (two drained runs on one engine
        accumulate). Per-step numbers come from the `StepEvents` that
        `step()` returns, or from a `serve.metrics.MetricsLedger` fed
        with them; `prefill_cache_size` and the pool's
        `used_pages`/`free_pages`/`occupancy` are instantaneous gauges,
        while the pool's `allocs`/`frees`/`alloc_failures`/`peak_used`
        are lifetime too. `tests/test_serve_frontend.py` pins this
        contract.
        """
        st: Dict[str, object] = {
            "steps_run": self.steps_run,
            "prefill_traces": self.prefill_traces,
            "prefill_cache_size": len(self._prefill_cache),
            "prefill_cache_evictions": self.prefill_cache_evictions,
            "prefill_chunks_run": self.prefill_chunks_run,
        }
        if self.paged:
            st["page_pool"] = self.pool.stats()
        return st

    def device_pool_stats(self) -> Dict[str, object]:
        """Per-device KV-pool footprint (paged mode).

        Under an installed mesh the sharded backends split every pool
        data/scale leaf along `Hkv` over the "model" axis, so each device
        holds `1/model` of the pool bytes; block tables replicate (they
        are bytes-negligible index arrays). Without a mesh this degrades
        to the single-device view (`n_devices=1`). Occupancy is the SAME
        gauge on every shard — pages allocate globally, shards differ
        only in which heads of a page they hold — so the per-device list
        repeats the pool's occupancy once per model-axis shard.
        """
        if not self.paged:
            return {"n_devices": 1, "pool_bytes_total": 0,
                    "pool_bytes_per_device": 0,
                    "occupancy_per_device": []}
        mesh = backends.current_mesh()
        tp = 1
        if mesh is not None:
            from repro.sharding.rules import mesh_axis_sizes
            tp = mesh_axis_sizes(mesh).get("model", 1) or 1
        total = 0
        flat = jax.tree_util.tree_flatten_with_path(self.caches)[0]
        for kp, leaf in flat:
            name = str(getattr(kp[-1], "key", getattr(kp[-1], "idx",
                                                      kp[-1])))
            if name in ("k", "v", "k_data", "v_data", "k_scl", "v_scl",
                        "stage_k", "stage_v"):
                total += int(leaf.size * leaf.dtype.itemsize)
        occ = float(self.pool.stats()["occupancy"])
        return {"n_devices": int(tp),
                "pool_bytes_total": int(total),
                "pool_bytes_per_device": int(total // tp),
                "occupancy_per_device": [occ] * int(tp)}

    def defrag(self):
        """Compact live pages onto the low end of the pool (paged mode):
        gathers every site's pool arrays by the compaction source map and
        rebuilds the block tables. Serving results are unchanged — pages
        are position-independent — so this exists for pool elasticity
        (the free tail can be released), not correctness."""
        if not self.paged:
            return None
        src, remap = self.pool.compact()
        srcj = jnp.asarray(src)

        def gather(site):
            out = {}
            for k, v in site.items():
                if k == "block_table":
                    out[k] = v
                else:
                    out[k] = v[srcj] if site["block_table"].ndim == 2 \
                        else v[:, srcj]
            return out

        self.caches = self._map_sites(self.caches, gather)
        self._bt[:] = 0
        owners = {r.uid: (s, r) for s, r in enumerate(self.slots)
                  if r is not None}
        for pf in self._prefilling:
            pf.pages = self.pool.pages_of(pf.req.uid)
            self._bt[pf.slot, :len(pf.pages)] = pf.pages
        for uid, (s, _r) in owners.items():
            pages = self.pool.pages_of(uid)
            self._bt[s, :len(pages)] = pages
        self._sync_tables()
        return remap


def _splice_slot(full_caches, row_caches, slot: int):
    """Copy a 1-row cache pytree into row `slot` of the batched caches.

    Batch is the first dim of unstacked leaves and the second of scan-
    stacked leaves (leading group dim) — detected by matching shapes.
    """
    def splice(full, row):
        if full.shape == row.shape:
            return row
        # find the axis where row has size 1 and full has batch_slots
        for ax in range(row.ndim):
            if row.shape[ax] == 1 and full.shape[ax] != 1 and \
                    row.shape[:ax] == full.shape[:ax] and \
                    row.shape[ax + 1:] == full.shape[ax + 1:]:
                idx = [slice(None)] * full.ndim
                idx[ax] = slice(slot, slot + 1)
                return full.at[tuple(idx)].set(row.astype(full.dtype))
        # silently keeping `full` here would drop the prefilled row and
        # serve the request on a stale cache — fail loudly instead
        raise ValueError(
            f"_splice_slot: cannot splice row cache of shape {row.shape} "
            f"into batched cache of shape {full.shape}: no axis has "
            f"size 1 in the row and the slot count in the batch")

    return jax.tree_util.tree_map(splice, full_caches, row_caches)
