"""Asyncio streaming serve front end over `ServingEngine`.

The engine's `step()` is synchronous and batched; this module is the
control plane that turns it into a service: continuous request intake,
per-request TOKEN STREAMS (an async iterator that yields each token the
engine step it was sampled — the prefill token included), and step-level
SLO observability through a `serve.metrics.MetricsLedger`. Admission
control is the engine's own: paged mode reserves a request's worst-case
page budget all-or-nothing before it leaves the queue (`PagePool`
grants; see docs/kv_cache.md), so the front end never admits what the
pool cannot finish.

    engine = ServingEngine(model, params, EngineCfg(...))
    ledger = MetricsLedger()
    async with AsyncFrontend(engine, metrics=ledger) as fe:
        stream = fe.submit(prompt, max_new_tokens=32)
        async for tok in stream:          # yields the step it's sampled
            print(tok)
    print(ledger.snapshot()["ttft_s"])    # TTFT distribution

Design notes (docs/serving.md has the full architecture):

- ONE serve-loop task drives the engine. Each iteration flushes intake
  into the engine queue, runs `engine.step()` in the default thread-pool
  executor (the event loop stays responsive while the device works, so
  consumers drain their streams *during* a step), then publishes the
  returned `StepEvents` to the streams and the ledger. The engine is
  only ever touched from the loop task — submissions buffer in
  `_intake` and join the queue at the next step boundary, so no lock
  guards the engine and a mid-step `submit()` never races admission.
- Token order within one stream is sampling order (the engine appends
  to `Request.out_tokens` in step order and events mirror that list);
  a stream finishes — `finish_reason` set, iteration stops — strictly
  after its last token is yielded.
- When the engine drains, the loop parks on an event instead of
  busy-polling; `submit()` wakes it. `drain()` awaits the parked state.
"""
from __future__ import annotations

import asyncio
import collections
from typing import Deque, Dict, List, Optional, Tuple

import numpy as np

from repro.serve.engine import ServingEngine, StepEvents

_DONE = object()    # stream sentinel: terminal marker after the last token


class TokenStream:
    """One request's async token stream.

    `async for tok in stream` yields each sampled token (ints) in
    sampling order and stops after the terminal token; `finish_reason`
    ("eos" / "max_new_tokens" / "length_cap") is set before the
    iteration ends. `tokens` accumulates everything yielded so far,
    `uid` is assigned when the request enters the engine queue (the
    next step boundary after `submit`), and `queue_position` is the
    submission index on this front end (0-based).
    """

    def __init__(self, queue_position: int):
        self.uid: Optional[int] = None
        self.queue_position = queue_position
        self.tokens: List[int] = []
        self.finish_reason: Optional[str] = None
        self.done = False
        self._q: asyncio.Queue = asyncio.Queue()

    def __aiter__(self) -> "TokenStream":
        return self

    async def __anext__(self) -> int:
        if self.done and self._q.empty():
            raise StopAsyncIteration
        item = await self._q.get()
        if item is _DONE:
            self.done = True
            raise StopAsyncIteration
        return item


class AsyncFrontend:
    """Async serving shell: continuous intake, streaming, SLO metrics.

    Use as an async context manager (`async with AsyncFrontend(...)`),
    or call `start()` from a running event loop and `aclose()` when
    done. `aclose()` finishes all in-flight and queued work first —
    closing is a drain, never an abort.
    """

    def __init__(self, engine: ServingEngine,
                 metrics: Optional[object] = None):
        self.engine = engine
        self.metrics = metrics
        self._intake: Deque[Tuple[TokenStream, np.ndarray, int]] = \
            collections.deque()
        self._streams: Dict[int, TokenStream] = {}
        self._submitted = 0
        self._task: Optional[asyncio.Task] = None
        self._closing = False
        self._wake: Optional[asyncio.Event] = None
        self._idle: Optional[asyncio.Event] = None

    # ----------------------------------------------------------- lifecycle
    def start(self) -> None:
        """Start the serve-loop task on the running event loop."""
        if self._task is not None:
            raise RuntimeError("AsyncFrontend already started")
        loop = asyncio.get_running_loop()
        self._wake = asyncio.Event()
        self._idle = asyncio.Event()
        self._idle.set()
        self._task = loop.create_task(self._serve_loop(),
                                      name="repro-serve-loop")

    async def __aenter__(self) -> "AsyncFrontend":
        self.start()
        return self

    async def __aexit__(self, *exc) -> None:
        await self.aclose()

    async def aclose(self) -> None:
        """Drain remaining work, then stop the serve loop. Re-raises any
        engine error the loop died on."""
        if self._task is None:
            return
        self._closing = True
        self._wake.set()
        try:
            await self._task
        finally:
            self._task = None

    async def drain(self) -> None:
        """Wait until no request is queued, prefilling, or decoding.
        Streams submitted before this call are complete when it
        returns; the front end stays open for more submissions."""
        self._require_running()
        await self._idle.wait()

    # ------------------------------------------------------------- intake
    def submit(self, prompt, max_new_tokens: int = 16) -> TokenStream:
        """Queue one request; returns its `TokenStream` immediately.

        The request joins the engine queue at the next step boundary
        (admission — including the paged all-or-nothing page
        reservation — is the engine's, exactly as in the drained loop).
        Synchronous and loop-thread-only, like all front-end methods.
        """
        self._require_running()
        if self._closing:
            raise RuntimeError("AsyncFrontend is closing")
        stream = TokenStream(queue_position=self._submitted)
        self._submitted += 1
        self._intake.append((stream, np.asarray(prompt, np.int32),
                             max_new_tokens))
        self._idle.clear()
        self._wake.set()
        return stream

    @property
    def completed(self):
        """Completed `Request`s, in completion order (engine-owned)."""
        return self.engine.completed

    # --------------------------------------------------------- serve loop
    def _require_running(self) -> None:
        if self._task is None:
            raise RuntimeError(
                "AsyncFrontend is not running: use `async with "
                "AsyncFrontend(engine) as fe:` or call start() first")
        if self._task.done():
            # surface a crashed serve loop at the call site instead of
            # hanging the caller on a stream that will never finish
            self._task.result()
            raise RuntimeError("AsyncFrontend serve loop has exited")

    def _flush_intake(self) -> None:
        """Move buffered submissions into the engine queue (loop task
        only — the single engine-touching thread)."""
        while self._intake:
            stream, prompt, max_new = self._intake.popleft()
            stream.uid = self.engine.submit(prompt, max_new)
            self._streams[stream.uid] = stream

    def _has_work(self) -> bool:
        return bool(self._intake) or self.engine.has_work()

    async def _serve_loop(self) -> None:
        loop = asyncio.get_running_loop()
        while True:
            self._flush_intake()
            if not self._has_work():
                self._idle.set()
                if self._closing:
                    return
                self._wake.clear()
                await self._wake.wait()
                continue
            self._idle.clear()
            # the blocking jitted step runs off-loop so stream consumers
            # and new submissions stay live while the device works
            ev = await loop.run_in_executor(None, self.engine.step)
            self._publish(ev)

    def _publish(self, ev: StepEvents) -> None:
        """Fan one step's token events out to their streams and the
        metrics ledger — the only consumer of `StepEvents` here."""
        for te in ev.tokens:
            stream = self._streams.get(te.uid)
            if stream is None:
                continue    # submitted directly on the engine: no stream
            stream.tokens.append(te.token)
            stream._q.put_nowait(te.token)
            if te.done:
                stream.finish_reason = te.finish_reason
                stream._q.put_nowait(_DONE)
        if self.metrics is not None:
            self.metrics.on_step(ev, self.engine)
