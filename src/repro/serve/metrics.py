"""Step-level serving observability: the TTFT/TPOT SLO ledger.

`MetricsLedger.on_step(events, engine)` consumes the `StepEvents` that
`ServingEngine.step()` returns — the same events the async streaming
front end publishes tokens from — and accumulates two record streams:

  step records     — one per engine step: wall time, queue depth, batch
                     occupancy, decode batch size, prefill-chunk
                     interleaving, page-pool occupancy/fragmentation
                     gauges (paged mode), and the per-step *delta* of
                     `backends.dispatch_stats()` (so fused-vs-fallback
                     attribution lands on the step that traced it).
  request records  — one per completed request: TTFT, TPOT, end-to-end
                     latency, token count, finish reason, queue position.

Metric vocabulary (canonical definitions — docs/serving.md quotes this
table; all times are `time.monotonic()` seconds):

| metric             | definition                                        |
|--------------------|---------------------------------------------------|
| `ttft_s`           | time to first token: `t_first - t_submit` (the   |
|                    | prefill token's sample time minus submission)     |
| `tpot_s`           | time per output token after the first:            |
|                    | `(t_done - t_first) / (n_tokens - 1)`; absent     |
|                    | (`None`) for single-token requests                |
| `latency_s`        | end-to-end: `t_done - t_submit`                   |
| `queue_depth`      | requests waiting in the engine queue AFTER a step |
| `batch_occupancy`  | decode batch size / `batch_slots` for the step    |
| `pool_occupancy`   | `PagePool` used/total pages after the step        |
| `pool_fragmentation` | free fraction of the pool's live span (the      |
|                    | holes `defrag()` would compact)                   |
| `pool_device_occupancy` | per-device pool-occupancy gauge (list, one  |
|                    | entry per "model"-axis shard of the installed     |
|                    | mesh; `[occupancy]` when unsharded) — see         |
|                    | docs/sharding.md                                  |
| `prefill_interleave_ratio` | of steps that ran a prefill chunk, the    |
|                    | fraction that also decoded a non-empty batch      |
|                    | (1.0 = chunked prefill never stalled decode)      |
| `dispatch` / `fallbacks` | folded `backends.dispatch_stats()` deltas:  |
|                    | keys per backends/base.py; `fallbacks` sums every |
|                    | `"->fallback:"` key (quantized serving wants 0)   |

Distributions (`_dist`) report `n/mean/p50/p95/min/max`.

The JSONL trace (`write_jsonl`) is the exchange format the benchmarks
consume (`benchmarks/kernels_bench.py` serve-latency section,
`benchmarks/speedup.py`): one JSON object per line, discriminated by
`"kind"` — `"meta"`, then `"step"` and `"request"` records in emission
order, then one `"summary"` (the `snapshot()` dict). `load_trace` reads
it back grouped by kind.
"""
from __future__ import annotations

import collections
import json
from typing import Dict, List, Optional

import numpy as np

from repro import backends
from repro.serve.engine import ServingEngine, StepEvents


def _dist(xs: List[Optional[float]]) -> Dict[str, float]:
    """n/mean/p50/p95/min/max of the non-None entries ({"n": 0} when
    nothing survives) — the distribution shape every summary metric
    uses."""
    vals = [x for x in xs if x is not None]
    if not vals:
        return {"n": 0}
    a = np.asarray(vals, dtype=np.float64)
    return {"n": int(a.size), "mean": float(a.mean()),
            "p50": float(np.percentile(a, 50)),
            "p95": float(np.percentile(a, 95)),
            "min": float(a.min()), "max": float(a.max())}


class MetricsLedger:
    """Accumulates step + request records from `StepEvents` (see the
    module docstring for the metric vocabulary).

    One ledger serves one engine run — feed it either through
    `run_until_drained(metrics=...)` or an `AsyncFrontend(metrics=...)`;
    both call `on_step` with identical events, so traces from the two
    loops are directly comparable (the golden test in
    tests/test_serve_frontend.py relies on it).
    """

    def __init__(self):
        self.step_records: List[dict] = []
        self.request_records: List[dict] = []
        self.meta: Optional[dict] = None
        self._t0: Optional[float] = None
        self._completed_seen = 0
        # dispatch stats are process-global trace-time counters; deltas
        # attribute them to the step whose jit trace recorded them
        self._last_dispatch = collections.Counter(backends.dispatch_stats())
        self._dispatch_total: collections.Counter = collections.Counter()

    # ---------------------------------------------------------- recording
    def _capture_meta(self, engine: ServingEngine) -> dict:
        cfg = engine.cfg
        meta = {"kind": "meta", "batch_slots": cfg.batch_slots,
                "max_len": cfg.max_len, "paged": engine.paged,
                "prefill_chunk": cfg.prefill_chunk}
        if engine.paged:
            meta["page_size"] = engine.pool.page_size
            meta["n_pages"] = engine.pool.n_pages
        return meta

    def on_step(self, ev: StepEvents, engine: ServingEngine) -> dict:
        """Record one step's events; returns the step record dict."""
        if self.meta is None:
            self.meta = self._capture_meta(engine)
        if self._t0 is None:
            self._t0 = ev.t_start
        cur = collections.Counter(backends.dispatch_stats())
        delta = cur - self._last_dispatch
        self._last_dispatch = cur
        self._dispatch_total += delta
        rec = {
            "kind": "step",
            "step": ev.step,
            "t_s": ev.t_end - self._t0,
            "dt_s": ev.t_end - ev.t_start,
            "admitted": list(ev.admitted),
            "prefill_chunks": ev.prefill_chunks,
            "decode_batch": ev.decode_batch,
            "batch_occupancy": ev.decode_batch / engine.cfg.batch_slots,
            "tokens": len(ev.tokens),
            "first_tokens": sum(1 for t in ev.tokens if t.first),
            "completed": [t.uid for t in ev.tokens if t.done],
            "queue_depth": ev.queue_depth,
            "active": ev.active,
            "prefilling": ev.prefilling,
        }
        if engine.paged:
            pool = engine.pool
            rec["pool_occupancy"] = pool.occupancy()
            rec["pool_used_pages"] = pool.used_pages
            rec["pool_fragmentation"] = pool.fragmentation()
            rec["pool_alloc_failures"] = pool.alloc_failures
            if hasattr(engine, "device_pool_stats"):
                # per-device pool-occupancy gauge: under a sharded mesh
                # each "model"-axis shard holds 1/tp of the pool bytes
                # at the SAME page occupancy (pages allocate globally)
                rec["pool_device_occupancy"] = \
                    engine.device_pool_stats()["occupancy_per_device"]
        if delta:
            rec["dispatch"] = dict(delta)
        self.step_records.append(rec)
        # harvest newly completed requests (engine.completed only grows)
        for req in engine.completed[self._completed_seen:]:
            n = len(req.out_tokens)
            self.request_records.append({
                "kind": "request",
                "uid": req.uid,
                "n_tokens": n,
                "finish_reason": req.finish_reason,
                "ttft_s": req.t_first - req.t_submit,
                "tpot_s": ((req.t_done - req.t_first) / (n - 1)
                           if n > 1 else None),
                "latency_s": req.t_done - req.t_submit,
            })
        self._completed_seen = len(engine.completed)
        return rec

    # ------------------------------------------------------------ exports
    def snapshot(self) -> dict:
        """Structured summary of everything recorded so far (the
        `"summary"` JSONL record): request-level TTFT/TPOT/latency
        distributions, step-level queue/occupancy distributions, the
        chunked-prefill interleave ratio, and the folded dispatch ledger
        with its fallback total."""
        steps = self.step_records
        reqs = self.request_records
        chunk_steps = [r for r in steps if r["prefill_chunks"] > 0]
        interleaved = [r for r in chunk_steps if r["decode_batch"] > 0]
        fallbacks = sum(v for k, v in self._dispatch_total.items()
                        if "->fallback:" in k)
        snap = {
            "kind": "summary",
            "steps": len(steps),
            "requests": len(reqs),
            "tokens": sum(r["tokens"] for r in steps),
            "wall_s": steps[-1]["t_s"] if steps else 0.0,
            "ttft_s": _dist([r["ttft_s"] for r in reqs]),
            "tpot_s": _dist([r["tpot_s"] for r in reqs]),
            "latency_s": _dist([r["latency_s"] for r in reqs]),
            "queue_depth": _dist([r["queue_depth"] for r in steps]),
            "batch_occupancy": _dist([r["batch_occupancy"]
                                      for r in steps]),
            "prefill_chunk_steps": len(chunk_steps),
            "interleaved_steps": len(interleaved),
            "prefill_interleave_ratio": (
                len(interleaved) / len(chunk_steps) if chunk_steps
                else None),
            "finish_reasons": dict(collections.Counter(
                r["finish_reason"] for r in reqs)),
            "dispatch": dict(self._dispatch_total),
            "fallbacks": fallbacks,
        }
        if steps and "pool_occupancy" in steps[0]:
            snap["pool_occupancy"] = _dist(
                [r.get("pool_occupancy") for r in steps])
            snap["pool_fragmentation"] = _dist(
                [r.get("pool_fragmentation") for r in steps])
        if steps and "pool_device_occupancy" in steps[0]:
            per_dev = [r.get("pool_device_occupancy") or [] for r in steps]
            snap["pool_device_occupancy"] = {
                "n_devices": max((len(p) for p in per_dev), default=0),
                "peak": max((max(p) for p in per_dev if p), default=0.0),
                "final": (per_dev[-1] if per_dev and per_dev[-1]
                          else []),
            }
        return snap

    def write_jsonl(self, path: str) -> None:
        """Write the trace: meta line, then step/request records in
        emission order, then one summary line (`snapshot()`)."""
        with open(path, "w") as f:
            if self.meta is not None:
                f.write(json.dumps(self.meta) + "\n")
            for rec in self.step_records:
                f.write(json.dumps(rec) + "\n")
            for rec in self.request_records:
                f.write(json.dumps(rec) + "\n")
            f.write(json.dumps(self.snapshot()) + "\n")


def load_trace(path: str) -> Dict[str, object]:
    """Read a `write_jsonl` trace back, grouped by record kind:
    `{"meta": dict|None, "steps": [...], "requests": [...],
    "summary": dict|None}` — what the benchmarks consume."""
    out = {"meta": None, "steps": [], "requests": [], "summary": None}
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            rec = json.loads(line)
            kind = rec.get("kind")
            if kind == "meta":
                out["meta"] = rec
            elif kind == "step":
                out["steps"].append(rec)
            elif kind == "request":
                out["requests"].append(rec)
            elif kind == "summary":
                out["summary"] = rec
    return out
