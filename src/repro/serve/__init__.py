from .engine import (EngineCfg, Request, ServingEngine, StepEvents,
                     TokenEvent)
from .frontend import AsyncFrontend, TokenStream
from .metrics import MetricsLedger, load_trace
