from .engine import EngineCfg, Request, ServingEngine
