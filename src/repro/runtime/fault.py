"""Fault-tolerance runtime: preemption handling + straggler detection.

On a real cluster the coordinator runs one `StragglerMonitor` fed by
per-host heartbeats (here: per-step timings from the local trainer, the
multi-host transport being jax.distributed / GCS in production). The
preemption handler turns SIGTERM/SIGINT into a clean "save-and-exit" at
the next step boundary — paired with the atomic checkpoint publish this
gives at-most-one-step loss on eviction.
"""
from __future__ import annotations

import collections
import signal
import statistics
import threading
import time
from typing import Callable, Dict, List, Optional


class PreemptionHandler:
    """SIGTERM/SIGINT -> flag; trainer checks `should_stop` each step."""

    def __init__(self, signals=(signal.SIGTERM,)):
        self._stop = threading.Event()
        self._prev = {}
        for s in signals:
            try:
                self._prev[s] = signal.signal(s, self._handler)
            except ValueError:
                pass  # non-main thread (tests)

    def _handler(self, signum, frame):
        self._stop.set()

    @property
    def should_stop(self) -> bool:
        return self._stop.is_set()

    def trigger(self):  # testable without a real signal
        self._stop.set()

    def restore(self):
        for s, h in self._prev.items():
            signal.signal(s, h)


class StragglerMonitor:
    """Flags hosts whose recent step times exceed `threshold` x median.

    Production action: report to the coordinator which re-slices the data
    shards away from the slow host (or triggers replacement); here the
    decision logic is what we test.
    """

    def __init__(self, n_hosts: int, window: int = 16,
                 threshold: float = 1.8):
        self.window = window
        self.threshold = threshold
        self.times: Dict[int, collections.deque] = {
            h: collections.deque(maxlen=window) for h in range(n_hosts)}

    def record(self, host: int, step_time: float):
        self.times[host].append(step_time)

    def medians(self) -> Dict[int, float]:
        return {h: statistics.median(ts) if ts else 0.0
                for h, ts in self.times.items()}

    def stragglers(self) -> List[int]:
        meds = {h: m for h, m in self.medians().items() if m > 0}
        if len(meds) < 2:
            return []
        overall = statistics.median(meds.values())
        return [h for h, m in meds.items() if m > self.threshold * overall]

    def healthy(self) -> bool:
        return not self.stragglers()


class StepTimer:
    """Context manager collecting step wall-times for the monitor."""

    def __init__(self, monitor: Optional[StragglerMonitor] = None,
                 host: int = 0):
        self.monitor = monitor
        self.host = host
        self.last: float = 0.0

    def __enter__(self):
        self._t0 = time.monotonic()
        return self

    def __exit__(self, *exc):
        self.last = time.monotonic() - self._t0
        if self.monitor is not None:
            self.monitor.record(self.host, self.last)
        return False
