from .fault import PreemptionHandler, StepTimer, StragglerMonitor
from .elastic import MeshPlan, plan_mesh, resize_plan
