"""Elastic scaling: re-plan the mesh when the healthy device count changes.

Checkpoints are mesh-agnostic (host arrays + logical specs re-derived from
the ArchConfig), so elasticity = pick a new mesh shape + `ckpt.restore`
with the new shardings. This module owns the shape-picking policy.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MeshPlan:
    shape: Tuple[int, ...]
    axis_names: Tuple[str, ...]
    dropped_devices: int

    @property
    def n_devices(self):
        n = 1
        for s in self.shape:
            n *= s
        return n


def plan_mesh(n_devices: int, prefer_model: int = 16,
              multi_pod_at: int = 512,
              global_batch: int = 256) -> MeshPlan:
    """Choose (pod, data, model) for the devices we actually have.

    Policy: keep TP ("model") at the largest power-of-two ≤ prefer_model
    that divides the device count; DP absorbs the rest; a "pod" axis
    appears when the fleet spans multiple 256-chip pods. Devices that
    don't fit the factorisation are dropped (reported) — the elastic
    restart can proceed with a ragged fleet.
    """
    if n_devices < 1:
        raise ValueError("no devices")
    model = 1
    while model * 2 <= prefer_model and n_devices % (model * 2) == 0:
        model *= 2
    rest = n_devices // model
    if n_devices >= multi_pod_at and rest % 2 == 0:
        pod = n_devices // 256 if n_devices % 256 == 0 else 2
        data = rest // pod
        if pod * data * model == n_devices and data >= 1:
            return MeshPlan((pod, data, model), ("pod", "data", "model"), 0)
    # single-pod (or ragged): use the largest usable count
    usable = rest * model
    dropped = n_devices - usable
    # cap DP so global batch still divides
    data = rest
    while data > 1 and global_batch % data != 0:
        data -= 1
        dropped = n_devices - data * model
    return MeshPlan((data, model), ("data", "model"), dropped)


def resize_plan(old: MeshPlan, new_n_devices: int,
                global_batch: int = 256) -> Dict:
    """What changes when going old -> new device count."""
    new = plan_mesh(new_n_devices, prefer_model=old.shape[-1],
                    global_batch=global_batch)
    return {
        "new_plan": new,
        "tp_changed": new.shape[-1] != old.shape[-1],
        "needs_reshard": new.shape != old.shape,
        "dp_ratio": (new.n_devices / new.shape[-1]) /
                    max(old.n_devices / old.shape[-1], 1),
    }
