from .model import Model, build_model, block_params, block_forward
from . import layers
