"""Model assembly: block builders, scanned layer stacks, caches, and the
unified forward for train / prefill / decode across all assigned families.

Layer stacks scan over "groups" — one group = one period of
`cfg.block_pattern` — with per-period-position params stacked on a leading
group axis (MaxText-style). Remainder layers live in `tail`.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import qlinear
from repro.core.policy import PolicyLike, PolicyProgram, QuantPolicy
from repro.configs.base import ArchConfig
from repro.sharding.axes import logical
from . import layers as L

Params = Dict[str, Any]


# ==========================================================================
# Per-block param builders / forwards
# ==========================================================================
def block_params(key, cfg: ArchConfig, btype: str, dtype=jnp.float32):
    d = cfg.d_model
    ks = jax.random.split(key, 6)
    if btype in ("attn", "local_attn"):
        return {"ln1": L.rms_norm_params(d),
                "attn": L.attention_params(ks[0], d, cfg.n_heads,
                                           cfg.n_kv_heads, cfg.head_dim,
                                           cfg.qkv_bias, dtype),
                "ln2": L.rms_norm_params(d),
                "mlp": (L.swiglu_params(ks[1], d, cfg.d_ff, dtype)
                        if cfg.mlp_kind == "swiglu" else
                        L.gelu_mlp_params(ks[1], d, cfg.d_ff, dtype))}
    if btype == "moe":
        return {"ln1": L.rms_norm_params(d),
                "attn": L.attention_params(ks[0], d, cfg.n_heads,
                                           cfg.n_kv_heads, cfg.head_dim,
                                           cfg.qkv_bias, dtype),
                "ln2": L.rms_norm_params(d),
                "moe": L.moe_params(ks[1], d, cfg.d_ff, cfg.n_experts,
                                    dtype)}
    if btype == "rglru":
        return {"ln1": L.rms_norm_params(d),
                "rec": L.rglru_params(ks[0], d, cfg.d_rnn or d, dtype),
                "ln2": L.rms_norm_params(d),
                "mlp": L.swiglu_params(ks[1], d, cfg.d_ff, dtype)}
    if btype == "mlstm":
        return {"ln1": L.rms_norm_params(d),
                "mlstm": L.mlstm_params(ks[0], d, cfg.n_heads, dtype)}
    if btype == "slstm":
        return {"ln1": L.rms_norm_params(d),
                "slstm": L.slstm_params(ks[0], d, cfg.n_heads, dtype)}
    if btype == "encdec_attn":  # decoder block with cross-attention
        return {"ln1": L.rms_norm_params(d),
                "attn": L.attention_params(ks[0], d, cfg.n_heads,
                                           cfg.n_kv_heads, cfg.head_dim,
                                           cfg.qkv_bias, dtype),
                "lnx": L.rms_norm_params(d),
                "xattn": L.attention_params(ks[1], d, cfg.n_heads,
                                            cfg.n_kv_heads, cfg.head_dim,
                                            cfg.qkv_bias, dtype),
                "ln2": L.rms_norm_params(d),
                "mlp": (L.swiglu_params(ks[2], d, cfg.d_ff, dtype)
                        if cfg.mlp_kind == "swiglu" else
                        L.gelu_mlp_params(ks[2], d, cfg.d_ff, dtype))}
    raise ValueError(btype)


def block_cache(cfg: ArchConfig, btype: str, batch: int, max_len: int,
                enc_len: int = 0, dtype=jnp.bfloat16, kv_bits: int = 0):
    d = cfg.d_model
    if btype in ("attn", "moe"):
        return {"kv": L.make_kv_cache(batch, max_len, cfg.n_kv_heads,
                                      cfg.head_dim, dtype, kv_bits)}
    if btype == "local_attn":
        ring = min(cfg.window, max_len)
        return {"kv": L.make_kv_cache(batch, ring, cfg.n_kv_heads,
                                      cfg.head_dim, dtype, kv_bits)}
    if btype == "rglru":
        return {"rec": L.rglru_init_state(batch, cfg.d_rnn or d)}
    if btype == "mlstm":
        return {"mlstm": L.mlstm_init_state(batch, d, cfg.n_heads)}
    if btype == "slstm":
        return {"slstm": L.slstm_init_state(batch, d)}
    if btype == "encdec_attn":
        # xkv tracks the true encoder length: the encoder output may be
        # shorter than the cache, and decode must mask the unwritten tail
        return {"kv": L.make_kv_cache(batch, max_len, cfg.n_kv_heads,
                                      cfg.head_dim, dtype, kv_bits),
                "xkv": L.make_kv_cache(batch, enc_len, cfg.n_kv_heads,
                                       cfg.head_dim, dtype, 0,
                                       track_len=True)}
    raise ValueError(btype)


def block_forward(p, x, positions, cfg: ArchConfig, policy: PolicyLike,
                  btype: str, cache=None, mode="train", enc_out=None,
                  site=""):
    """Returns (x, new_cache, aux_loss).

    `site` is this block's policy-program address prefix — the pytree path
    of its params (``layers/3``, ``blocks/0``, ``tail/1``, ...); the layer
    forwards resolve each projection under it.
    """
    def sub(leaf):
        return f"{site}/{leaf}" if site else leaf

    aux = jnp.zeros((), jnp.float32)
    if btype in ("attn", "local_attn", "moe"):
        window = cfg.window if btype == "local_attn" else 0
        h, kv = L.attention_forward(
            p["attn"], L.rms_norm(x, p["ln1"], cfg.norm_eps), positions,
            cfg, policy, window=window, cache=None if cache is None
            else cache["kv"], mode=mode, site=sub("attn"))
        x = x + h
        xm = L.rms_norm(x, p["ln2"], cfg.norm_eps)
        if btype == "moe":
            h2, aux = L.moe_layer(p["moe"], xm, cfg, policy,
                                  site=sub("moe"))
        elif cfg.mlp_kind == "swiglu":
            h2 = L.swiglu(p["mlp"], xm, policy, site=sub("mlp"))
        else:
            h2 = L.gelu_mlp(p["mlp"], xm, policy, site=sub("mlp"))
        x = x + h2
        return x, (None if cache is None else {"kv": kv}), aux
    if btype == "rglru":
        h, st = L.rglru_forward(p["rec"],
                                L.rms_norm(x, p["ln1"], cfg.norm_eps),
                                cfg, policy,
                                state=None if cache is None
                                else cache["rec"], mode=mode,
                                site=sub("rec"))
        x = x + h
        h2 = L.swiglu(p["mlp"], L.rms_norm(x, p["ln2"], cfg.norm_eps),
                      policy, site=sub("mlp"))
        x = x + h2
        return x, (None if cache is None else {"rec": st}), aux
    if btype == "mlstm":
        h, st = L.mlstm_forward(p["mlstm"],
                                L.rms_norm(x, p["ln1"], cfg.norm_eps),
                                cfg, policy,
                                state=None if cache is None
                                else cache["mlstm"], mode=mode,
                                site=sub("mlstm"))
        return x + h, (None if cache is None else {"mlstm": st}), aux
    if btype == "slstm":
        h, st = L.slstm_forward(p["slstm"],
                                L.rms_norm(x, p["ln1"], cfg.norm_eps),
                                cfg, policy,
                                state=None if cache is None
                                else cache["slstm"], mode=mode,
                                site=sub("slstm"))
        return x + h, (None if cache is None else {"slstm": st}), aux
    if btype == "encdec_attn":
        h, kv = L.attention_forward(
            p["attn"], L.rms_norm(x, p["ln1"], cfg.norm_eps), positions,
            cfg, policy, cache=None if cache is None else cache["kv"],
            mode=mode, site=sub("attn"))
        x = x + h
        xkv = None if cache is None else cache["xkv"]
        if mode == "decode":
            hx, _ = L.attention_forward(
                p["xattn"], L.rms_norm(x, p["lnx"], cfg.norm_eps),
                positions, cfg, policy, cache=xkv, mode="decode",
                kv_x=jnp.zeros_like(x), use_rope=False,
                site=sub("xattn"))
            new_xkv = xkv
        else:
            hx, new_xkv = L.attention_forward(
                p["xattn"], L.rms_norm(x, p["lnx"], cfg.norm_eps),
                positions, cfg, policy, causal=False, cache=xkv,
                mode=mode, kv_x=enc_out, use_rope=False,
                site=sub("xattn"))
        x = x + hx
        xm = L.rms_norm(x, p["ln2"], cfg.norm_eps)
        h2 = (L.swiglu(p["mlp"], xm, policy, site=sub("mlp"))
              if cfg.mlp_kind == "swiglu"
              else L.gelu_mlp(p["mlp"], xm, policy, site=sub("mlp")))
        x = x + h2
        new_cache = None if cache is None else {"kv": kv, "xkv": new_xkv}
        return x, new_cache, aux
    raise ValueError(btype)


# ==========================================================================
# The Model
# ==========================================================================
class Model:
    """Functional LM bundle for one ArchConfig.

    `policy` is a flat `QuantPolicy` (uniform — the layer stack scans over
    groups with stacked params, MaxText-style) or a `PolicyProgram`. A
    program that resolves differently across layers *unrolls* the stack:
    params live under ``layers/<i>/...`` so every per-layer site address
    exists in the pytree and each layer runs under its own resolved policy
    (mixed W4/W8 trees, per-layer kv_bits, per-site backends).
    """

    def __init__(self, cfg: ArchConfig, policy: PolicyLike = QuantPolicy(),
                 remat: bool = True):
        self.cfg = cfg
        self.policy = policy
        self.remat = remat
        period = len(cfg.block_pattern)
        self.unrolled = (isinstance(policy, PolicyProgram)
                         and policy.addresses_layers(cfg.n_layers))
        if self.unrolled:
            self.n_groups, self.n_tail = 0, 0
        else:
            self.n_groups = cfg.n_layers // period
            self.n_tail = cfg.n_layers % period

    def _block_type(self, layer: int) -> str:
        return self.cfg.block_pattern[layer % len(self.cfg.block_pattern)]

    # ------------------------------------------------------------- init
    def init(self, key, dtype=jnp.float32) -> Params:
        cfg = self.cfg
        keys = jax.random.split(key, 8)
        vp = cfg.padded_vocab  # TP-divisible table (pad cols masked)
        params: Params = {
            "embed": {"table": (jax.random.normal(
                keys[0], (vp, cfg.d_model)) * 0.02).astype(dtype)},
            "final_norm": L.rms_norm_params(cfg.d_model),
            "lm_head": {"w_out": (jax.random.normal(
                keys[1], (cfg.d_model, vp))
                / math.sqrt(cfg.d_model)).astype(dtype)},
        }
        # stacked per-period-position block params (or, for layer-varying
        # policy programs, an unrolled per-layer list so every layer has
        # its own `layers/<i>/...` address)
        period = len(cfg.block_pattern)

        if self.unrolled:
            lks = jax.random.split(keys[2], cfg.n_layers)
            params["layers"] = [block_params(lks[i], cfg,
                                             self._block_type(i), dtype)
                                for i in range(cfg.n_layers)]
            params["blocks"], params["tail"] = {}, []
        else:
            def one_group(k):
                gks = jax.random.split(k, period)
                return {str(j): block_params(gks[j], cfg,
                                             cfg.block_pattern[j], dtype)
                        for j in range(period)}

            gkeys = jax.random.split(keys[2], max(self.n_groups, 1))
            params["blocks"] = jax.vmap(one_group)(gkeys) if self.n_groups \
                else {}
            tks = jax.random.split(keys[3], max(self.n_tail, 1))
            params["tail"] = [block_params(tks[j], cfg,
                                           cfg.block_pattern[j], dtype)
                              for j in range(self.n_tail)]
        if cfg.enc_dec:
            eks = jax.random.split(keys[4], max(cfg.n_enc_layers, 1))

            def one_enc(k):
                return block_params(k, cfg, "attn", dtype)

            params["enc_blocks"] = jax.vmap(one_enc)(eks)
            params["enc_norm"] = L.rms_norm_params(cfg.d_model)
        if cfg.frontend:
            params["frontend_proj"] = {
                "w_in": (jax.random.normal(
                    keys[5], (cfg.frontend_dim, cfg.d_model))
                    / math.sqrt(cfg.frontend_dim)).astype(dtype),
                "b_in": jnp.zeros((cfg.d_model,), dtype)}
        return params

    # ------------------------------------------------------------ caches
    def init_caches(self, batch: int, max_len: int, enc_len: int = 0,
                    dtype=jnp.bfloat16):
        """KV/recurrent caches; kv_bits resolves per cache site
        (``<block>/attn/kv``), so a program can OVP-pack some layers'
        caches and keep others full precision."""
        cfg = self.cfg
        pol = self.policy
        period = len(cfg.block_pattern)

        if self.unrolled:
            return {"layers": [
                block_cache(cfg, self._block_type(i), batch, max_len,
                            enc_len, dtype,
                            pol.resolve(f"layers/{i}/attn/kv").kv_bits)
                for i in range(cfg.n_layers)]}

        def one_group(_):
            return {str(j): block_cache(
                cfg, cfg.block_pattern[j], batch, max_len, enc_len, dtype,
                pol.resolve(f"blocks/{j}/attn/kv").kv_bits)
                for j in range(period)}

        caches = {
            "blocks": (jax.vmap(one_group)(jnp.arange(self.n_groups))
                       if self.n_groups else {}),
            "tail": [block_cache(cfg, cfg.block_pattern[j], batch, max_len,
                                 enc_len, dtype,
                                 pol.resolve(f"tail/{j}/attn/kv").kv_bits)
                     for j in range(self.n_tail)],
        }
        return caches

    def init_paged_caches(self, n_pages: int, page_size: int,
                          batch_slots: int, pages_per_row: int,
                          dtype=jnp.bfloat16):
        """PAGED KV caches: every cache site holds a `(n_pages, page_size,
        …)` pool plus a `(batch_slots, pages_per_row)` block table (see
        `layers.make_paged_kv_cache` / `serve/paging.py`). Page ids are
        shared across sites — one allocator row backs the same token rows
        in every layer. Only pure attention patterns page (local_attn ring
        buffers, recurrent state, and enc-dec caches keep the slab
        layout); mixed patterns raise rather than silently paging half
        the stack."""
        cfg = self.cfg
        pol = self.policy
        bad = sorted({bt for bt in cfg.block_pattern
                      if bt not in ("attn", "moe")})
        if bad:
            raise ValueError(
                f"paged KV caches support pure attn/moe block patterns; "
                f"pattern {cfg.block_pattern} has {bad}")
        period = len(cfg.block_pattern)

        def one(addr):
            kv_bits = pol.resolve(addr).kv_bits
            return {"kv": L.make_paged_kv_cache(
                n_pages, page_size, batch_slots, pages_per_row,
                cfg.n_kv_heads, cfg.head_dim, dtype, kv_bits)}

        if self.unrolled:
            return {"layers": [one(f"layers/{i}/attn/kv")
                               for i in range(cfg.n_layers)]}

        def one_group(_):
            return {str(j): one(f"blocks/{j}/attn/kv")
                    for j in range(period)}

        return {"blocks": (jax.vmap(one_group)(jnp.arange(self.n_groups))
                           if self.n_groups else {}),
                "tail": [one(f"tail/{j}/attn/kv")
                         for j in range(self.n_tail)]}

    # ----------------------------------------------------------- forward
    def _embed_inputs(self, params, batch: Dict[str, jax.Array]):
        cfg = self.cfg
        pol = self.policy
        cdt = jnp.dtype(pol.compute_dtype)
        tok = batch["tokens"]
        x = params["embed"]["table"][tok].astype(cdt) \
            * math.sqrt(cfg.d_model)
        if cfg.frontend == "vit" and "patch_embeds" in batch:
            pe = qlinear.linear(batch["patch_embeds"].astype(cdt),
                                params["frontend_proj"]["w_in"],
                                params["frontend_proj"]["b_in"],
                                pol.resolve("frontend_proj/w_in"),
                                site="frontend_proj/w_in")
            x = jnp.concatenate([pe, x], axis=1)
        return logical(x, "batch", "seq", "embed")

    def _encode(self, params, frames: jax.Array):
        """Audio/enc-dec encoder over stub frame embeddings."""
        cfg = self.cfg
        pol = self.policy
        cdt = jnp.dtype(pol.compute_dtype)
        x = qlinear.linear(frames.astype(cdt),
                           params["frontend_proj"]["w_in"],
                           params["frontend_proj"]["b_in"],
                           pol.resolve("frontend_proj/w_in"),
                           site="frontend_proj/w_in")
        b, s, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))

        def body(carry, p):
            h, _, _ = block_forward(p, carry, positions, cfg, pol, "attn",
                                    mode="encode", site="enc_blocks")
            return h, None

        fn = jax.checkpoint(body) if self.remat else body
        x, _ = jax.lax.scan(fn, x, params["enc_blocks"])
        return L.rms_norm(x, params["enc_norm"], cfg.norm_eps)

    def forward(self, params, batch: Dict[str, jax.Array], *,
                mode: str = "train", caches=None, positions=None,
                enc_out=None, last_only: bool = False):
        """Returns (logits, new_caches, aux).

        train/prefill: batch["tokens"] (B, T) [+ patch_embeds / frames]
        decode:        batch["tokens"] (B, 1), batch["pos"] (B,)
        last_only: project only the final position through the LM head
        (prefill serving path: avoids the (B, T, V) logits tensor).
        """
        cfg = self.cfg
        pol = self.policy
        if cfg.enc_dec and mode != "decode" and enc_out is None:
            enc_out = self._encode(params, batch["frames"])

        x = self._embed_inputs(params, batch)
        b, t = x.shape[:2]
        if positions is None:
            if mode == "decode":
                positions = batch["pos"][:, None]
            else:
                positions = jnp.broadcast_to(jnp.arange(t)[None], (b, t))

        aux0 = jnp.zeros((), jnp.float32)
        period = len(cfg.block_pattern)

        if self.unrolled:
            # per-layer policies: python loop, one block per `layers/<i>`
            aux = aux0
            new_layer_caches = []
            for i in range(cfg.n_layers):
                bt = self._block_type(i)
                c_i = None if caches is None else caches["layers"][i]

                def run(p_i, h, c_i, i=i, bt=bt):
                    return block_forward(p_i, h, positions, cfg, pol, bt,
                                         cache=c_i, mode=mode,
                                         enc_out=enc_out,
                                         site=f"layers/{i}")

                fn = jax.checkpoint(run) if (self.remat
                                             and mode == "train") else run
                x, nc, a = fn(params["layers"][i], x, c_i)
                new_layer_caches.append(nc)
                aux = aux + a
            new_caches = ({"layers": new_layer_caches}
                          if caches is not None else None)
            return self._head(params, x, aux, new_caches, last_only)

        def body(carry, xs):
            h, aux = carry
            if caches is None:
                pg, cg = xs, None
            else:
                pg, cg = xs
            new_cg = {}
            for j in range(period):
                bt = cfg.block_pattern[j]
                c_j = None if cg is None else cg[str(j)]
                h, nc, a = block_forward(pg[str(j)], h, positions, cfg,
                                         pol, bt, cache=c_j, mode=mode,
                                         enc_out=enc_out,
                                         site=f"blocks/{j}")
                if nc is not None:
                    new_cg[str(j)] = nc
                aux = aux + a
            return (h, aux), (new_cg if caches is not None else None)

        fn = jax.checkpoint(body) if (self.remat and mode == "train") \
            else body
        if self.n_groups:
            xs = (params["blocks"] if caches is None
                  else (params["blocks"], caches["blocks"]))
            (x, aux), new_block_caches = jax.lax.scan(fn, (x, aux0), xs)
        else:
            aux, new_block_caches = aux0, None

        new_tail = []
        for j in range(self.n_tail):
            bt = cfg.block_pattern[j]
            c_j = None if caches is None else caches["tail"][j]
            x, nc, a = block_forward(params["tail"][j], x, positions, cfg,
                                     pol, bt, cache=c_j, mode=mode,
                                     enc_out=enc_out, site=f"tail/{j}")
            new_tail.append(nc)
            aux = aux + a

        new_caches = None
        if caches is not None:
            new_caches = {"blocks": new_block_caches, "tail": new_tail}
        return self._head(params, x, aux, new_caches, last_only)

    def _head(self, params, x, aux, new_caches, last_only: bool):
        cfg = self.cfg
        pol = self.policy
        if last_only:
            x = x[:, -1:]
        x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
        head = params["lm_head"]["w_out"]
        if cfg.tie_embeddings:
            head = params["embed"]["table"].T
        logits = qlinear.qmatmul(x, head, pol.resolve("lm_head/w_out"),
                                 site="lm_head/w_out").astype(jnp.float32)
        if cfg.padded_vocab != cfg.vocab:
            # mask pad columns (elementwise along the sharded vocab dim)
            col = jax.lax.broadcasted_iota(jnp.int32, logits.shape,
                                           logits.ndim - 1)
            logits = jnp.where(col >= cfg.vocab, jnp.float32(-1e9), logits)
        logits = logical(logits, "batch", "seq", "vocab")
        return logits, new_caches, aux


    # ------------------------------------------------------------- layout
    def adapt_params(self, params) -> Params:
        """Convert a param tree to this model's layout.

        Scan-stacked (``blocks``/``tail``) trees unroll into per-layer
        ``layers/<i>`` entries when this model is layer-addressed; trees
        already in the right layout pass through. Re-stacking an unrolled
        tree is not supported (quantized leaves may differ per layer)."""
        has_layers = isinstance(params, dict) and params.get("layers")
        if self.unrolled and not has_layers:
            return unroll_params(self.cfg, params)
        if not self.unrolled and has_layers:
            raise ValueError(
                "cannot re-stack an unrolled param tree for a uniform "
                "policy; rebuild the model with the layer-varying program")
        return params


def unroll_params(cfg: ArchConfig, params: Params) -> Params:
    """``blocks``/``tail`` (scan-stacked) param layout -> per-layer
    ``layers/<i>`` list, so layer-addressed policy programs can resolve
    each layer independently. Slices the leading group dim off every
    stacked leaf; ``tail`` entries append in order."""
    period = len(cfg.block_pattern)
    out = {k: v for k, v in params.items() if k not in ("blocks", "tail")}
    layers = []
    blocks = params.get("blocks") or {}
    if blocks:
        any_leaf = jax.tree_util.tree_leaves(blocks)[0]
        n_groups = any_leaf.shape[0]
        for g in range(n_groups):
            for j in range(period):
                layers.append(jax.tree_util.tree_map(
                    lambda leaf, g=g: leaf[g], blocks[str(j)]))
    layers.extend(params.get("tail") or [])
    out["layers"] = layers
    out["blocks"], out["tail"] = {}, []
    return out


def build_model(cfg: ArchConfig, policy: PolicyLike = QuantPolicy(),
                remat: bool = True) -> Model:
    return Model(cfg, policy, remat)
