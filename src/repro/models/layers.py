"""Model-zoo building blocks: norms, RoPE, blockwise (flash-style)
attention with GQA / sliding-window / cross variants, KV caches (fp and
OVP-quantized), MoE with capacity-based dispatch, RG-LRU, mLSTM, sLSTM.

Everything is functional: params are plain dicts, layers are pure
functions, quantization routes through `repro.core.qlinear` and sharding
hints through `repro.sharding.axes.logical`.
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import qlinear
from repro.core.policy import PolicyLike, QuantPolicy
from repro.sharding.axes import logical

Params = dict
NEG_INF = -1e30


def rp(policy: PolicyLike, site: str, leaf: str = "") -> QuantPolicy:
    """Resolve the policy (flat or program) for one weight site.

    `site` is the block's address prefix (e.g. ``layers/3/attn``), `leaf`
    the weight name under it; empty-prefix callers resolve on the leaf
    alone, which the flag-compat program buckets by substring exactly like
    the seed heuristics did.
    """
    return rps(policy, site, leaf)[0]


def rps(policy: PolicyLike, site: str, leaf: str = ""):
    """(resolved policy, full site address) for one weight site.

    Unpacks straight into `qlinear.linear(x, w, b, *rps(...))`: the site
    rides along so the calibration tape records matmul inputs under the
    exact address the program resolves, and so a calibrated static scale
    (carried by the resolved policy) is attributable on a miss.
    """
    full = f"{site}/{leaf}" if (site and leaf) else (site or leaf)
    return policy.resolve(full), full


def _init(key, shape, scale=None, dtype=jnp.float32):
    scale = 1.0 / math.sqrt(shape[0]) if scale is None else scale
    return (jax.random.normal(key, shape) * scale).astype(dtype)


# ==========================================================================
# Norms
# ==========================================================================
def rms_norm_params(d):
    return {"gamma_scale": jnp.ones((d,))}


def rms_norm(x, p, eps=1e-6):
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * p["gamma_scale"].astype(jnp.float32)).astype(dt)


def layer_norm_params(d):
    return {"gamma_scale": jnp.ones((d,)), "beta_shift": jnp.zeros((d,))}


def layer_norm(x, p, eps=1e-6):
    dt = x.dtype
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * p["gamma_scale"] + p["beta_shift"]).astype(dt)


# ==========================================================================
# RoPE
# ==========================================================================
def rope(x, positions, theta=1e4):
    """x: (B, T, H, D), positions: (B, T) absolute positions."""
    d = x.shape[-1]
    half = d // 2
    freqs = jnp.exp(-jnp.arange(0, half, dtype=jnp.float32)
                    * (math.log(theta) / half))
    ang = positions[..., None].astype(jnp.float32) * freqs  # (B,T,half)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    return jnp.concatenate([y1, y2], axis=-1).astype(x.dtype)


# ==========================================================================
# Blockwise (flash-style) attention — bounded-memory softmax for long seq
# ==========================================================================
def _attend_block(qb, kb, vb, mask, m, l, acc, scale):
    """One (q-chunk, kv-chunk) online-softmax update.

    qb: (B, qc, Hkv, G, D); kb/vb: (B, kc, Hkv, D);
    mask: (B, 1, 1, qc, kc) or broadcastable; m,l: (B, Hkv, G, qc);
    acc: (B, qc, Hkv, G, D).
    """
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qb.astype(jnp.float32),
                   kb.astype(jnp.float32)) * scale
    s = jnp.where(mask, s, NEG_INF)
    m_new = jnp.maximum(m, jnp.max(s, axis=-1))
    p = jnp.exp(s - m_new[..., None])
    corr = jnp.exp(m - m_new)
    l_new = l * corr + jnp.sum(p, axis=-1)
    pv = jnp.einsum("bhgqk,bkhd->bqhgd", p, vb.astype(jnp.float32))
    # corr: (B,Hkv,G,qc) -> (B,qc,Hkv,G,1) to rescale the accumulator
    acc_new = acc * corr.transpose(0, 3, 1, 2)[..., None]
    return m_new, l_new, acc_new + pv


def _flash_fwd_impl(q, k, v, causal, q_offset, q_chunk, kv_chunk):
    """Online-softmax chunked attention forward.

    Returns (out (B,T,H,D) in q.dtype, lse (B, nq, qc, Hkv, G) fp32) where
    lse = m + log l is the per-position log-sum-exp (+inf for rows with no
    valid key — their output is 0 and their backward p is exp(-inf) = 0).
    """
    b, t, h, d = q.shape
    s_len = k.shape[1]
    hkv = k.shape[2]
    g = h // hkv
    scale = 1.0 / math.sqrt(d)
    qc = min(q_chunk, t)
    kc = min(kv_chunk, s_len)
    tp, sp = -(-t // qc) * qc, -(-s_len // kc) * kc
    qg = jnp.pad(q, ((0, 0), (0, tp - t), (0, 0), (0, 0)))
    kg = jnp.pad(k, ((0, 0), (0, sp - s_len), (0, 0), (0, 0)))
    vg = jnp.pad(v, ((0, 0), (0, sp - s_len), (0, 0), (0, 0)))
    qg = qg.reshape(b, tp // qc, qc, hkv, g, d)
    kg = kg.reshape(b, sp // kc, kc, hkv, d)
    vg = vg.reshape(b, sp // kc, kc, hkv, d)

    def q_block(_, iq_qb):
        iq, qb = iq_qb
        qpos = q_offset + iq * qc + jnp.arange(qc)

        def kv_block(carry, ik_kb):
            m, l, acc = carry
            ik, kb, vb = ik_kb
            kp = ik * kc + jnp.arange(kc)
            mask = jnp.broadcast_to(kp[None, :] < s_len, (qc, kc))
            if causal:
                mask = mask & (qpos[:, None] >= kp[None, :])
            mask = mask[None, None, None]                    # (1,1,1,qc,kc)
            return _attend_block(qb, kb, vb, mask, m, l, acc, scale), None

        m0 = jnp.full((b, hkv, g, qc), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, hkv, g, qc), jnp.float32)
        a0 = jnp.zeros((b, qc, hkv, g, d), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_block, (m0, l0, a0),
            (jnp.arange(sp // kc), jnp.moveaxis(kg, 1, 0),
             jnp.moveaxis(vg, 1, 0)))
        out = acc / jnp.maximum(l, 1e-30).transpose(0, 3, 1, 2)[..., None]
        lse = jnp.where(l > 0, m + jnp.log(jnp.maximum(l, 1e-30)),
                        jnp.inf)                             # (B,Hkv,G,qc)
        return None, (out, lse.transpose(0, 3, 1, 2))        # lse (B,qc,...)

    _, (outs, lses) = jax.lax.scan(
        q_block, None, (jnp.arange(tp // qc), jnp.moveaxis(qg, 1, 0)))
    out = jnp.moveaxis(outs, 0, 1).reshape(b, tp, hkv, g, d)[:, :t]
    lse = jnp.moveaxis(lses, 0, 1)                           # (B,nq,qc,..)
    return out.reshape(b, t, h, d).astype(q.dtype), \
        lse.reshape(b, 1, tp // qc, qc, hkv, g)[:, 0]


@partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def _flash_attention(q, k, v, causal, q_offset, q_chunk, kv_chunk):
    return _flash_fwd_impl(q, k, v, causal, q_offset, q_chunk, kv_chunk)[0]


def _flash_fwd(q, k, v, causal, q_offset, q_chunk, kv_chunk):
    out, lse = _flash_fwd_impl(q, k, v, causal, q_offset, q_chunk,
                               kv_chunk)
    return out, (q, k, v, out, lse)


def _flash_bwd(causal, q_offset, q_chunk, kv_chunk, res, do):
    """FlashAttention-2 style backward (§Perf iteration F): recompute the
    chunk scores from (q, k, lse) instead of letting autodiff stack the
    inner kv-scan's score residuals (the dominant HBM term of every dense
    train cell at baseline). Saves only O(T·D) tensors: q, k, v, out, lse.
    """
    q, k, v, out, lse = res
    b, t, h, d = q.shape
    s_len = k.shape[1]
    hkv = k.shape[2]
    g = h // hkv
    scale = 1.0 / math.sqrt(d)
    qc = min(q_chunk, t)
    kc = min(kv_chunk, s_len)
    tp, sp = -(-t // qc) * qc, -(-s_len // kc) * kc
    nq, nk = tp // qc, sp // kc

    def pad_q(x):
        return jnp.pad(x, ((0, 0), (0, tp - t)) + ((0, 0),) * (x.ndim - 2))

    def pad_k(x):
        return jnp.pad(x, ((0, 0), (0, sp - s_len))
                       + ((0, 0),) * (x.ndim - 2))

    f32 = jnp.float32
    qg = pad_q(q).reshape(b, nq, qc, hkv, g, d).astype(f32)
    dog = pad_q(do).reshape(b, nq, qc, hkv, g, d).astype(f32)
    og = pad_q(out).reshape(b, nq, qc, hkv, g, d).astype(f32)
    kg = pad_k(k).reshape(b, nk, kc, hkv, d).astype(f32)
    vg = pad_k(v).reshape(b, nk, kc, hkv, d).astype(f32)
    # delta_i = rowsum(dO ∘ O)  (B, nq, qc, Hkv, G)
    delta = jnp.sum(dog * og, axis=-1)

    def block_ds(iq, qb, lse_i, delta_i, ik, kb, vb, dob):
        """Recomputed p and ds for one (q-chunk, kv-chunk) pair."""
        qpos = q_offset + iq * qc + jnp.arange(qc)
        kp = ik * kc + jnp.arange(kc)
        mask = jnp.broadcast_to(kp[None, :] < s_len, (qc, kc))
        if causal:
            mask = mask & (qpos[:, None] >= kp[None, :])
        s = jnp.einsum("bqhgd,bkhd->bhgqk", qb, kb) * scale
        # lse_i: (B,qc,Hkv,G) -> (B,Hkv,G,qc,1)
        p = jnp.exp(s - lse_i.transpose(0, 2, 3, 1)[..., None])
        p = jnp.where(mask[None, None, None], p, 0.0)
        dp = jnp.einsum("bqhgd,bkhd->bhgqk", dob, vb)
        ds = p * (dp - delta_i.transpose(0, 2, 3, 1)[..., None]) * scale
        return p, ds

    # ---- dq: per q chunk, sum over kv chunks ---------------------------
    def dq_block(_, xs):
        iq, qb, lse_i, delta_i, dob = xs

        def kv_acc(dq_i, ys):
            ik, kb, vb = ys
            _, ds = block_ds(iq, qb, lse_i, delta_i, ik, kb, vb, dob)
            return dq_i + jnp.einsum("bhgqk,bkhd->bqhgd", ds, kb), None

        dq0 = jnp.zeros((b, qc, hkv, g, d), f32)
        dq_i, _ = jax.lax.scan(kv_acc, dq0,
                               (jnp.arange(nk), jnp.moveaxis(kg, 1, 0),
                                jnp.moveaxis(vg, 1, 0)))
        return None, dq_i

    _, dqs = jax.lax.scan(
        dq_block, None,
        (jnp.arange(nq), jnp.moveaxis(qg, 1, 0),
         jnp.moveaxis(lse, 1, 0), jnp.moveaxis(delta, 1, 0),
         jnp.moveaxis(dog, 1, 0)))
    dq = jnp.moveaxis(dqs, 0, 1).reshape(b, tp, h, d)[:, :t]

    # ---- dk, dv: per kv chunk, sum over q chunks -----------------------
    def dkv_block(_, xs):
        ik, kb, vb = xs

        def q_acc(carry, ys):
            dk_j, dv_j = carry
            iq, qb, lse_i, delta_i, dob = ys
            p, ds = block_ds(iq, qb, lse_i, delta_i, ik, kb, vb, dob)
            dk_j = dk_j + jnp.einsum("bhgqk,bqhgd->bkhd", ds, qb)
            dv_j = dv_j + jnp.einsum("bhgqk,bqhgd->bkhd", p, dob)
            return (dk_j, dv_j), None

        z = jnp.zeros((b, kc, hkv, d), f32)
        (dk_j, dv_j), _ = jax.lax.scan(
            q_acc, (z, z),
            (jnp.arange(nq), jnp.moveaxis(qg, 1, 0),
             jnp.moveaxis(lse, 1, 0), jnp.moveaxis(delta, 1, 0),
             jnp.moveaxis(dog, 1, 0)))
        return None, (dk_j, dv_j)

    _, (dks, dvs) = jax.lax.scan(
        dkv_block, None,
        (jnp.arange(nk), jnp.moveaxis(kg, 1, 0), jnp.moveaxis(vg, 1, 0)))
    dk = jnp.moveaxis(dks, 0, 1).reshape(b, sp, hkv, d)[:, :s_len]
    dv = jnp.moveaxis(dvs, 0, 1).reshape(b, sp, hkv, d)[:, :s_len]
    return (dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype))


_flash_attention.defvjp(_flash_fwd, _flash_bwd)


def blockwise_attention(q, k, v, *, causal=True, q_offset=0,
                        q_chunk=512, kv_chunk=512):
    """q: (B,T,H,D); k,v: (B,S,Hkv,D). Returns (B,T,H,D).

    Online-softmax double scan with a FlashAttention-2 custom VJP: HBM
    footprint is O(T·D + qc·kc) in BOTH directions. `q_offset` is the
    absolute position of q[0] relative to k[0] (cross-attention passes
    causal=False).
    """
    return _flash_attention(q, k, v, causal, int(q_offset), q_chunk,
                            kv_chunk)


def local_blockwise_attention(q, k, v, *, window, q_offset=0, chunk=512):
    """Sliding-window causal attention, O(T·window).

    For q chunk i, only kv positions in (q_pos - window, q_pos] matter;
    we left-pad K/V by `w_pad` and dynamic-slice a (w_pad + chunk) span.
    """
    b, t, h, d = q.shape
    s_len = k.shape[1]
    hkv = k.shape[2]
    g = h // hkv
    scale = 1.0 / math.sqrt(d)
    c = min(chunk, t)
    w_pad = -(-window // c) * c
    tp = -(-t // c) * c
    qg = jnp.pad(q, ((0, 0), (0, tp - t), (0, 0), (0, 0)))
    kg = jnp.pad(k, ((0, 0), (w_pad, tp - s_len), (0, 0), (0, 0)))
    vg = jnp.pad(v, ((0, 0), (w_pad, tp - s_len), (0, 0), (0, 0)))
    qg = qg.reshape(b, tp // c, c, hkv, g, d)
    span = w_pad + c

    def q_block(_, iq_qb):
        iq, qb = iq_qb
        qpos = q_offset + iq * c + jnp.arange(c)
        start = iq * c  # padded coords; covers original [iq*c - w_pad, ...)
        kb = jax.lax.dynamic_slice_in_dim(kg, start, span, axis=1)
        vb = jax.lax.dynamic_slice_in_dim(vg, start, span, axis=1)
        kpos = q_offset + start - w_pad + jnp.arange(span)
        mask = ((kpos[None, :] >= 0) & (kpos[None, :] <= qpos[:, None])
                & (kpos[None, :] > qpos[:, None] - window)
                & (kpos[None, :] < q_offset + s_len))
        s = jnp.einsum("bqhgd,bkhd->bhgqk", qb.astype(jnp.float32),
                       kb.astype(jnp.float32)) * scale
        s = jnp.where(mask[None, None, None], s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        out = jnp.einsum("bhgqk,bkhd->bqhgd", p, vb.astype(jnp.float32))
        return None, out

    _, outs = jax.lax.scan(q_block, None,
                           (jnp.arange(tp // c), jnp.moveaxis(qg, 1, 0)))
    out = jnp.moveaxis(outs, 0, 1).reshape(b, tp, hkv, g, d)[:, :t]
    return out.reshape(b, t, h, d).astype(q.dtype)


# ==========================================================================
# KV caches (fp16/bf16 and OVP-quantized beyond-paper variant)
# ==========================================================================
def make_kv_cache(batch, length, n_kv, head_dim, dtype=jnp.bfloat16,
                  kv_bits: int = 0, track_len: bool = False):
    """KV cache dict. `track_len` adds a per-row `src_len` leaf recording
    how many rows actually hold data (cross-attention encoder caches: the
    encoder output can be shorter than the cache, and the zero-initialized
    tail must never receive softmax mass)."""
    if head_dim % 2 != 0 and kv_bits == 4:
        raise ValueError(
            f"OVP-packed KV cache needs an even head_dim (values pair "
            f"2-per-byte along it); got head_dim={head_dim}. Use an even "
            f"head_dim or kv_bits=0 for this site.")
    if kv_bits == 4:
        cache = {"k_data": jnp.zeros((batch, length, n_kv, head_dim // 2),
                                     jnp.uint8),
                 "v_data": jnp.zeros((batch, length, n_kv, head_dim // 2),
                                     jnp.uint8),
                 "k_scl": jnp.ones((batch, length, n_kv), jnp.float32),
                 "v_scl": jnp.ones((batch, length, n_kv), jnp.float32)}
    else:
        cache = {"k": jnp.zeros((batch, length, n_kv, head_dim), dtype),
                 "v": jnp.zeros((batch, length, n_kv, head_dim), dtype)}
    if track_len:
        cache["src_len"] = jnp.zeros((batch,), jnp.int32)
    return cache


def make_paged_kv_cache(n_pages, page_size, batch_slots, pages_per_row,
                        n_kv, head_dim, dtype=jnp.bfloat16,
                        kv_bits: int = 0):
    """PAGED KV cache dict for one cache site: a global pool of
    `(n_pages, page_size, Hkv, …)` fixed-size pages plus a per-slot
    `block_table` `(batch_slots, pages_per_row)` int32 mapping logical
    page j of a slot to its physical page id (`serve/paging.py` owns the
    id accounting; unset entries default to page 0 — harmless because
    every read masks by position, exactly like a slab's unwritten rows).

    OVP packing is what makes this layout possible: every quantized token
    row costs the same bytes (D/2 nibbles + one f32 scale per head), so a
    page is a dense tile with no sparsity side-tables. Detection is
    `"block_table" in cache` everywhere (cache_write / cache_len /
    kernels); page_size is also the fused decode kernel's kv-tile size.
    """
    if page_size < 2 or page_size % 2:
        raise ValueError(
            f"page_size must be an even int >= 2 (OVP packs value pairs "
            f"2-per-byte along head_dim); got {page_size}")
    if head_dim % 2 != 0 and kv_bits == 4:
        raise ValueError(
            f"OVP-packed KV cache needs an even head_dim (values pair "
            f"2-per-byte along it); got head_dim={head_dim}.")
    if kv_bits == 4:
        cache = {"k_data": jnp.zeros((n_pages, page_size, n_kv,
                                      head_dim // 2), jnp.uint8),
                 "v_data": jnp.zeros((n_pages, page_size, n_kv,
                                      head_dim // 2), jnp.uint8),
                 "k_scl": jnp.ones((n_pages, page_size, n_kv),
                                   jnp.float32),
                 "v_scl": jnp.ones((n_pages, page_size, n_kv),
                                   jnp.float32)}
    else:
        cache = {"k": jnp.zeros((n_pages, page_size, n_kv, head_dim),
                                dtype),
                 "v": jnp.zeros((n_pages, page_size, n_kv, head_dim),
                                dtype)}
    cache["block_table"] = jnp.zeros((batch_slots, pages_per_row),
                                     jnp.int32)
    return cache


def _quant_kv_token(x):
    """x: (B, T, Hkv, D) -> packed nibbles + per-(token, head) 3σ scales."""
    from repro.core.ovp import ovp_encode_codes, pack4
    s = jnp.maximum(3.0 * jnp.std(x.astype(jnp.float32), axis=-1) / 7.0,
                    1e-6)                                  # (B,T,Hkv)
    u = x.astype(jnp.float32) / s[..., None]
    codes = ovp_encode_codes(u, "int4", pair_axis=-1)
    return pack4(codes, pair_axis=-1), s


def cache_write(cache, k_new, v_new, pos, ring: int = 0):
    """Write one step (T may be >1 for prefill). pos: (B,) write position of
    k_new[:, 0]. ring>0 wraps indices modulo the ring size (local attn).
    Non-KV leaves (e.g. `src_len`) pass through untouched."""
    b, t = k_new.shape[:2]
    idx = pos[:, None] + jnp.arange(t)[None, :]            # (B, T)
    if ring:
        idx = idx % ring
    if "block_table" in cache:
        return _paged_cache_write(cache, k_new, v_new, idx)
    bidx = jnp.arange(b)[:, None] + jnp.zeros_like(idx)
    out = dict(cache)
    if "k" in cache:
        out["k"] = cache["k"].at[bidx, idx].set(
            k_new.astype(cache["k"].dtype), mode="drop")
        out["v"] = cache["v"].at[bidx, idx].set(
            v_new.astype(cache["v"].dtype), mode="drop")
        return out
    kd, ks = _quant_kv_token(k_new)
    vd, vs = _quant_kv_token(v_new)
    out["k_data"] = cache["k_data"].at[bidx, idx].set(kd, mode="drop")
    out["v_data"] = cache["v_data"].at[bidx, idx].set(vd, mode="drop")
    out["k_scl"] = cache["k_scl"].at[bidx, idx].set(ks, mode="drop")
    out["v_scl"] = cache["v_scl"].at[bidx, idx].set(vs, mode="drop")
    return out


def _paged_cache_write(cache, k_new, v_new, idx):
    """Scatter token rows through the block table: logical row `idx`
    (B, T) of slot b lands in pool page `block_table[b, idx // ps]` at
    page row `idx % ps`. Rows past a slot's table capacity drop — same
    semantics as a slab's `mode="drop"` past max_len."""
    bt = cache["block_table"]                              # (B, n)
    pool = cache.get("k", cache.get("k_data"))
    ps, n = pool.shape[1], bt.shape[1]
    page = jnp.take_along_axis(bt, jnp.clip(idx // ps, 0, n - 1), axis=1)
    # pool.shape[0] is one past the last page -> dropped by mode="drop"
    page = jnp.where((idx >= 0) & (idx < n * ps), page, pool.shape[0])
    row = idx % ps
    out = dict(cache)
    if "k" in cache:
        out["k"] = cache["k"].at[page, row].set(
            k_new.astype(cache["k"].dtype), mode="drop")
        out["v"] = cache["v"].at[page, row].set(
            v_new.astype(cache["v"].dtype), mode="drop")
        return out
    kd, ks = _quant_kv_token(k_new)
    vd, vs = _quant_kv_token(v_new)
    out["k_data"] = cache["k_data"].at[page, row].set(kd, mode="drop")
    out["v_data"] = cache["v_data"].at[page, row].set(vd, mode="drop")
    out["k_scl"] = cache["k_scl"].at[page, row].set(ks, mode="drop")
    out["v_scl"] = cache["v_scl"].at[page, row].set(vs, mode="drop")
    return out


def cache_read(cache, dtype=jnp.float32):
    """dtype=None: return the cache's native dtype (no full-cache convert
    — materializing an f32 copy of a multi-GB cache per layer was the
    dominant decode HBM term, §Perf iteration D2). For OVP-packed caches
    this is a FULL dequant — the serving decode path avoids it entirely
    via the fused kernel (`decode_attention` below)."""
    from repro.kernels import decode_attn
    return decode_attn.read_cache_dense(cache, dtype=dtype)


def decode_attention(q, cache, pos, *, window: int = 0, ring: int = 0,
                     policy: Optional[QuantPolicy] = None):
    """Single-token attention over a cache, routed through the backend
    registry.

    q: (B, 1, H, D); pos: (B,) current absolute position (token at `pos` is
    already written). `ring` = physical cache length for ring buffers; slot
    absolute positions are reconstructed arithmetically. `policy` is the
    RESOLVED policy of this cache's site (`<block>/attn/kv`):
    `policy.backend` picks the execution path — the pallas backends run
    the fused decode-attention kernel (OVP-packed caches never dequantize
    densely; fp caches skip the unpack phase), everything else serves the
    dense XLA path. None (direct callers, training utilities) = dense XLA.
    """
    from repro import backends
    return backends.decode_attention(q, cache, pos, policy=policy,
                                     window=window, ring=ring)


# ==========================================================================
# Attention layer (projections + cache plumbing)
# ==========================================================================
def attention_params(key, d_model, n_heads, n_kv, head_dim, qkv_bias=False,
                     dtype=jnp.float32):
    ks = jax.random.split(key, 4)
    p = {"wq": _init(ks[0], (d_model, n_heads * head_dim), dtype=dtype),
         "wk": _init(ks[1], (d_model, n_kv * head_dim), dtype=dtype),
         "wv": _init(ks[2], (d_model, n_kv * head_dim), dtype=dtype),
         "wo": _init(ks[3], (n_heads * head_dim, d_model), dtype=dtype)}
    if qkv_bias:
        p["bq"] = jnp.zeros((n_heads * head_dim,), dtype)
        p["bk"] = jnp.zeros((n_kv * head_dim,), dtype)
        p["bv"] = jnp.zeros((n_kv * head_dim,), dtype)
    return p


def attention_forward(p, x, positions, cfg, policy: PolicyLike, *,
                      window=0, causal=True, cache=None, mode="train",
                      kv_x=None, use_rope=True, site="attn"):
    """mode: train|prefill|decode. Returns (out, new_cache).

    kv_x: source for K/V (cross-attention); defaults to x.
    site: policy-program address prefix for this block's projections.
    """
    b, t, d_model = x.shape
    nh, nkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    src = x if kv_x is None else kv_x

    q = qlinear.linear(x, p["wq"], p.get("bq"), *rps(policy, site, "wq"))
    q = q.reshape(b, t, nh, hd)
    if mode == "decode" and kv_x is None:
        k_new = qlinear.linear(x, p["wk"], p.get("bk"),
                               *rps(policy, site, "wk"))
        v_new = qlinear.linear(x, p["wv"], p.get("bv"),
                               *rps(policy, site, "wv"))
        k_new = k_new.reshape(b, t, nkv, hd)
        v_new = v_new.reshape(b, t, nkv, hd)
        if use_rope:
            q = rope(q, positions, cfg.rope_theta)
            k_new = rope(k_new, positions, cfg.rope_theta)
        ring = window if (window and cache_len(cache) == window) else 0
        cache = cache_write(cache, k_new, v_new, positions[:, 0], ring=ring)
        out = decode_attention(q, cache, positions[:, 0], window=window,
                               ring=ring, policy=rp(policy, site, "kv"))
    elif mode == "decode":  # cross-attention decode: cache holds enc K/V
        if use_rope:
            q = rope(q, positions, cfg.rope_theta)
        # attend only the rows the encoder actually wrote: `src_len`
        # (tracked at prefill) caps the softmax, so zero-initialized tail
        # rows of an oversized cache never steal mass (their logit would
        # be 0, not -inf)
        src_len = cache.get("src_len")
        pos_x = (src_len - 1) if src_len is not None else \
            positions[:, 0] * 0 + cache_len(cache) - 1
        out = decode_attention(q, cache, pos_x,
                               policy=rp(policy, site, "kv"))
    else:
        k = qlinear.linear(src, p["wk"], p.get("bk"), *rps(policy, site, "wk"))
        v = qlinear.linear(src, p["wv"], p.get("bv"), *rps(policy, site, "wv"))
        s_len = src.shape[1]
        k = k.reshape(b, s_len, nkv, hd)
        v = v.reshape(b, s_len, nkv, hd)
        if use_rope:
            q = rope(q, positions, cfg.rope_theta)
            kpos = positions if kv_x is None else \
                jnp.broadcast_to(jnp.arange(s_len)[None], (b, s_len))
            k = rope(k, kpos, cfg.rope_theta)
        if (mode == "prefill" and kv_x is None and window == 0
                and cache is not None and "block_table" in cache
                and "stage_k" in cache):
            # paged fused prefill: append the chunk's raw K/V to the
            # per-request stage, then one registry dispatch both attends
            # the chunk causally over the stage AND quantize-writes every
            # stage tile onto its block-table pages (no splice round
            # trip). Chunk offset = positions[0, 0] (traced: one jit
            # trace per stage length serves every chunk index).
            off = positions[0, 0]
            st_k = jax.lax.dynamic_update_slice(
                cache["stage_k"], k.astype(cache["stage_k"].dtype),
                (0, off, 0, 0))
            st_v = jax.lax.dynamic_update_slice(
                cache["stage_v"], v.astype(cache["stage_v"].dtype),
                (0, off, 0, 0))
            cache = dict(cache, stage_k=st_k, stage_v=st_v)
            from repro import backends
            out, cache = backends.prefill_attention(
                q, cache, positions, policy=rp(policy, site, "kv"))
            out = out.reshape(b, t, nh * hd)
            out = qlinear.linear(out, p["wo"], None,
                                 *rps(policy, site, "wo"))
            return logical(out, "batch", "seq", "embed"), cache
        q = logical(q, "batch", "seq", "heads", None)
        k = logical(k, "batch", "seq", "kv_heads", None)
        if window and causal:
            out = local_blockwise_attention(q, k, v, window=window)
        else:
            out = blockwise_attention(q, k, v, causal=causal)
        if mode == "prefill" and cache is not None:
            if kv_x is None:
                ring = window if (window and cache_len(cache) == window) \
                    else 0
                if ring:
                    keep = min(window, s_len)
                    cache = cache_write(cache, k[:, -keep:], v[:, -keep:],
                                        positions[:, -keep], ring=ring)
                else:
                    cache = cache_write(cache, k, v, positions[:, 0])
            else:  # store encoder K/V once, recording the true length
                cache = cache_write(cache, k, v,
                                    jnp.zeros((b,), jnp.int32))
                if "src_len" in cache:
                    cache["src_len"] = jnp.full((b,), min(
                        s_len, cache_len(cache)), jnp.int32)
    out = out.reshape(b, t, nh * hd)
    out = qlinear.linear(out, p["wo"], None, *rps(policy, site, "wo"))
    return logical(out, "batch", "seq", "embed"), cache


def cache_len(cache) -> int:
    if cache is None:
        return 0
    leaf = cache.get("k", cache.get("k_data"))
    if "block_table" in cache:
        # paged: logical capacity of one slot = table width * page size
        return cache["block_table"].shape[1] * leaf.shape[1]
    return leaf.shape[1]


# ==========================================================================
# MLPs
# ==========================================================================
def swiglu_params(key, d_model, d_ff, dtype=jnp.float32):
    ks = jax.random.split(key, 3)
    return {"wg": _init(ks[0], (d_model, d_ff), dtype=dtype),
            "wu": _init(ks[1], (d_model, d_ff), dtype=dtype),
            "wd": _init(ks[2], (d_ff, d_model), dtype=dtype)}


def swiglu(p, x, policy: PolicyLike, site="mlp"):
    g = qlinear.linear(x, p["wg"], None, *rps(policy, site, "wg"))
    u = qlinear.linear(x, p["wu"], None, *rps(policy, site, "wu"))
    h = jax.nn.silu(g) * u
    h = logical(h, "batch", "seq", "ffn")
    return logical(qlinear.linear(h, p["wd"], None, *rps(policy, site, "wd")),
                   "batch", "seq", "embed")


def gelu_mlp_params(key, d_model, d_ff, dtype=jnp.float32):
    ks = jax.random.split(key, 2)
    return {"wi": _init(ks[0], (d_model, d_ff), dtype=dtype),
            "wd": _init(ks[1], (d_ff, d_model), dtype=dtype),
            "bi": jnp.zeros((d_ff,), dtype),
            "bd": jnp.zeros((d_model,), dtype)}


def gelu_mlp(p, x, policy: PolicyLike, site="mlp"):
    h = jax.nn.gelu(qlinear.linear(x, p["wi"], p["bi"],
                                   *rps(policy, site, "wi")))
    h = logical(h, "batch", "seq", "ffn")
    return qlinear.linear(h, p["wd"], p["bd"], *rps(policy, site, "wd"))


# ==========================================================================
# Mixture of Experts (capacity-based sort dispatch, EP-shardable)
# ==========================================================================
def moe_params(key, d_model, d_ff, n_experts, dtype=jnp.float32):
    ks = jax.random.split(key, 4)
    s = 1.0 / math.sqrt(d_model)
    return {
        "router": {"w_gate": _init(ks[0], (d_model, n_experts),
                                   dtype=jnp.float32)},
        "experts": {
            "wg": (jax.random.normal(ks[1], (n_experts, d_model, d_ff))
                   * s).astype(dtype),
            "wu": (jax.random.normal(ks[2], (n_experts, d_model, d_ff))
                   * s).astype(dtype),
            "wd": (jax.random.normal(ks[3], (n_experts, d_ff, d_model))
                   / math.sqrt(d_ff)).astype(dtype),
        },
    }


def moe_layer(p, x, cfg, policy: PolicyLike, capacity_factor=None,
              site="moe"):
    """Top-k token-choice MoE. Returns (y, aux_loss).

    Dispatch is PER BATCH ROW (§Perf iteration M): routing, capacity,
    gather and combine are vmapped over the batch dim, which is sharded
    over the data axes — so the argsort/gather/scatter machinery never
    crosses a data shard. (The earlier global-token dispatch forced the
    SPMD partitioner to all-reduce full-token tensors — f32[B·T, d] per
    MoE layer per microbatch, the dominant collective in the MoE train
    cells.) Cross-shard traffic is now only the expert einsum resharding
    along the EP ("model") axis, sized by the dispatched slots.

    Per-row capacity keeps the same global capacity budget:
    cap_row = ceil(cf · t · k / e). Dropped tokens fall back to the
    residual stream (standard capacity semantics).
    """
    b, t, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    if capacity_factor is None:
        capacity_factor = getattr(cfg, "capacity_factor", 1.25)

    router_w = p["router"]["w_gate"]
    if policy.enabled and not rp(policy, site, "router/w_gate").enabled \
            and hasattr(router_w, "astype"):
        router_w = router_w.astype(jnp.float32)
    logits = x.astype(jnp.float32) @ router_w            # (B, T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    topw, topi = jax.lax.top_k(probs, k)                 # (B, T, k)
    if cfg.norm_topk:
        topw = topw / jnp.sum(topw, axis=-1, keepdims=True)

    # load-balance aux (Switch-style), over all tokens
    me = jnp.mean(probs, axis=(0, 1))
    ce = jnp.mean(jnp.sum(jax.nn.one_hot(topi, e, dtype=jnp.float32),
                          axis=2), axis=(0, 1))
    aux = e * jnp.sum(me * ce) / k

    cap = max(int(capacity_factor * t * k / e), 4)

    def dispatch_row(xr, topi_r, topw_r):
        """xr (T, d); topi/topw (T, k) -> slots (E, cap, d) + combine meta."""
        flat_e = topi_r.reshape(-1)                      # (T*k,)
        flat_t = jnp.repeat(jnp.arange(t), k)
        flat_w = topw_r.reshape(-1)
        order = jnp.argsort(flat_e)
        se, st, sw = flat_e[order], flat_t[order], flat_w[order]
        counts = jnp.bincount(se, length=e)
        starts = jnp.cumsum(counts) - counts
        rank = jnp.arange(t * k) - starts[se]
        keep = rank < cap
        dest = jnp.where(keep, se * cap + rank, e * cap)  # drop -> scratch
        slot_token = jnp.zeros((e * cap + 1,), jnp.int32).at[dest].set(st)
        slot_valid = jnp.zeros((e * cap + 1,), jnp.bool_).at[dest].set(keep)
        xg = xr[slot_token[:-1]] * slot_valid[:-1, None]
        return xg.reshape(e, cap, d), (dest, st, sw, keep)

    xg, (dest, st, sw, keep) = jax.vmap(dispatch_row)(x, topi, topw)
    # (B, E, cap, d): batch stays on the data axes, experts go to EP
    xg = logical(xg, "batch", "expert", "expert_cap", "embed")

    ew = p["experts"]
    h = _expert_ein(xg, ew["wg"], *rps(policy, site, "experts/wg"))
    u = _expert_ein(xg, ew["wu"], *rps(policy, site, "experts/wu"))
    hh = jax.nn.silu(h) * u
    hh = logical(hh, "batch", "expert", "expert_cap", "ffn")
    yg = _expert_ein(hh, ew["wd"],
                     *rps(policy, site, "experts/wd"))  # (B, E, cap, d)
    yg = logical(yg, "batch", "expert", "expert_cap", "embed")

    def combine_row(yg_r, dest_r, st_r, sw_r, keep_r):
        """Slot-side combine (§Perf iteration M2): weight each expert slot
        and scatter-add it into the (t, d) output directly. With yg
        EP-sharded, every chip scatter-adds its LOCAL expert slots and the
        partitioner inserts ONE (t, d) partial-sum all-reduce — vs the
        assignment-side gather, whose forward select/AR and backward
        scatter/AR move (T·k, d) tensors across the EP axis (16x more).
        Runs in the compute dtype so f32 router weights don't promote it.
        """
        w = (sw_r * keep_r).astype(yg_r.dtype)
        slot_w = jnp.zeros((e * cap + 1,), yg_r.dtype).at[dest_r].set(w)
        slot_tok = jnp.zeros((e * cap + 1,), jnp.int32).at[dest_r].set(st_r)
        yflat = yg_r.reshape(e * cap, d) * slot_w[:-1, None]
        return jnp.zeros((t, d), yg_r.dtype).at[slot_tok[:-1]].add(yflat)

    y = jax.vmap(combine_row)(yg, dest, st, sw, keep)
    return y.astype(x.dtype), aux


def _expert_ein(xg, w, policy: QuantPolicy, site: str = ""):
    """([B,] E, C, K) x (E, K, F) -> ([B,] E, C, F) quantized matmul.

    Quantized per-expert weights go through the backend registry like every
    other matmul. On the pallas backends a stacked (E, K, F) weight runs
    the *grouped* kernel — one pallas_call whose expert grid dim streams
    each expert's packed tile (no XLA broadcast of the stack); per-expert
    mixed-precision `MixedExpertQuant` stacks dispatch group-wise through
    the same kernel. Layouts a backend declines fall back to XLA with the
    reason recorded in `backends.dispatch_stats()`. Expert GEMMs stay
    weight-only quantized — activation quantization here would change MoE
    accuracy baselines and needs its own calibrated scales (dispatched
    slots are capacity-gathered, so the 3σ rule sees padding).
    """
    from repro.core.ovp import MixedExpertQuant, QuantizedTensor
    cdt = jnp.dtype(policy.compute_dtype)
    if isinstance(w, (QuantizedTensor, MixedExpertQuant)):
        from repro import backends
        w_only = dataclasses.replace(policy, abits=0)
        return backends.dispatch(xg, w, w_only, site=site)
    eq = "eck,ekf->ecf" if xg.ndim == 3 else "beck,ekf->becf"
    return jnp.einsum(eq, xg.astype(cdt), w.astype(cdt))


# ==========================================================================
# Causal depthwise conv (RG-LRU & mLSTM front-ends), width-4
# ==========================================================================
def conv1d_params(key, d, width=4, dtype=jnp.float32):
    return {"conv_kernel": (jax.random.normal(key, (width, d)) /
                            math.sqrt(width)).astype(dtype),
            "conv_bias": jnp.zeros((d,), dtype)}


def conv1d_causal(p, x, state=None):
    """x: (B,T,D). state: (B,W-1,D) trailing inputs for decode. Returns
    (y, new_state)."""
    w = p["conv_kernel"].shape[0]
    if state is None:
        xp = jnp.pad(x, ((0, 0), (w - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([state.astype(x.dtype), x], axis=1)
    # y_t = sum_i k_i * x_{t-w+1+i}
    y = sum(xp[:, i:i + x.shape[1]] * p["conv_kernel"][i]
            for i in range(w))
    new_state = xp[:, -(w - 1):] if w > 1 else None
    return y + p["conv_bias"], new_state


# ==========================================================================
# RG-LRU (Griffin / RecurrentGemma recurrent block)
# ==========================================================================
def rglru_params(key, d_model, d_rnn, dtype=jnp.float32):
    ks = jax.random.split(key, 6)
    return {
        "wx": _init(ks[0], (d_model, d_rnn), dtype=dtype),
        "wgate": _init(ks[1], (d_model, d_rnn), dtype=dtype),
        "wo": _init(ks[2], (d_rnn, d_model), dtype=dtype),
        "conv": conv1d_params(ks[3], d_rnn, dtype=dtype),
        # recurrence gates
        "w_inp_gate": _init(ks[4], (d_rnn, d_rnn), dtype=dtype),
        "w_rec_gate": _init(ks[5], (d_rnn, d_rnn), dtype=dtype),
        "a_param": jnp.full((d_rnn,), 2.0),   # sigmoid(2)^8 ≈ 0.31 decay
    }


def _rglru_core(p, u, h0, policy: PolicyLike, site="rec"):
    """u: (B,T,Dr) inputs; h0: (B,Dr). Linear diag recurrence via
    associative scan: h_t = a_t ⊙ h_{t-1} + b_t."""
    rt = jax.nn.sigmoid(
        qlinear.linear(u, p["w_rec_gate"], None,
                       *rps(policy, site, "w_rec_gate"))
        .astype(jnp.float32))
    it = jax.nn.sigmoid(
        qlinear.linear(u, p["w_inp_gate"], None,
                       *rps(policy, site, "w_inp_gate"))
        .astype(jnp.float32))
    log_a = -8.0 * jax.nn.softplus(p["a_param"]) * rt  # log a_t ≤ 0
    a = jnp.exp(log_a)
    gated = it * u.astype(jnp.float32)
    b_t = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * gated

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    a_scan, b_scan = jax.lax.associative_scan(combine, (a, b_t), axis=1)
    # fold in h0: h_t = a_scan_t * h0 + b_scan_t
    return a_scan * h0[:, None, :] + b_scan


def rglru_forward(p, x, cfg, policy, *, state=None, mode="train",
                  site="rec"):
    """Griffin recurrent block. state = {"h": (B,Dr), "conv": (B,3,Dr)}."""
    b, t, _ = x.shape
    gate = jax.nn.gelu(qlinear.linear(x, p["wgate"], None,
                                      *rps(policy, site, "wgate")))
    u = qlinear.linear(x, p["wx"], None, *rps(policy, site, "wx"))
    conv_state = state["conv"] if state is not None else None
    u, new_conv = conv1d_causal(p["conv"], u, conv_state)
    h0 = state["h"] if state is not None else jnp.zeros(
        (b, u.shape[-1]), jnp.float32)
    h = _rglru_core(p, u, h0, policy, site=site)
    y = qlinear.linear((h.astype(x.dtype) * gate), p["wo"], None,
                       *rps(policy, site, "wo"))
    new_state = None
    if state is not None:
        new_state = {"h": h[:, -1].astype(jnp.float32), "conv": new_conv}
    return logical(y, "batch", "seq", "embed"), new_state


def rglru_init_state(batch, d_rnn, conv_width=4):
    return {"h": jnp.zeros((batch, d_rnn), jnp.float32),
            "conv": jnp.zeros((batch, conv_width - 1, d_rnn), jnp.float32)}


# ==========================================================================
# xLSTM: mLSTM (matrix memory) and sLSTM (scalar memory), per the paper
# ==========================================================================
def mlstm_params(key, d_model, n_heads, dtype=jnp.float32):
    d_inner = 2 * d_model
    ks = jax.random.split(key, 8)
    return {
        "w_up": _init(ks[0], (d_model, 2 * d_inner), dtype=dtype),
        "conv": conv1d_params(ks[1], d_inner, dtype=dtype),
        "wq": _init(ks[2], (d_inner, d_inner), dtype=dtype),
        "wk": _init(ks[3], (d_inner, d_inner), dtype=dtype),
        "wv": _init(ks[4], (d_inner, d_inner), dtype=dtype),
        "w_igate": _init(ks[5], (d_inner, n_heads), 0.01, dtype=dtype),
        "w_fgate": _init(ks[6], (d_inner, n_heads), 0.01, dtype=dtype),
        "fgate_bias": jnp.full((n_heads,), 3.0),
        "igate_bias": jnp.zeros((n_heads,)),
        "w_down": _init(ks[7], (d_inner, d_model), dtype=dtype),
        "outnorm": {"gamma_scale": jnp.ones((d_inner,))},
    }


def _mlstm_core(q, k, v, i_pre, f_pre, state):
    """Recurrent mLSTM scan. q,k,v: (B,T,H,Dh); gates (B,T,H).
    state: dict(c: (B,H,Dh,Dh), n: (B,H,Dh), m: (B,H)). Returns (h, state).
    """
    b, t, h, dh = q.shape
    kscale = 1.0 / math.sqrt(dh)

    def step(carry, xs):
        c, n, m = carry
        qt, kt, vt, it, ft = xs           # (B,H,Dh), gates (B,H)
        m_new = jnp.maximum(ft + m, it)
        i_ = jnp.exp(it - m_new)
        f_ = jnp.exp(ft + m - m_new)
        kt = kt * kscale
        c = f_[..., None, None] * c \
            + i_[..., None, None] * jnp.einsum("bhd,bhe->bhde", vt, kt)
        n = f_[..., None] * n + i_[..., None] * kt
        num = jnp.einsum("bhde,bhe->bhd", c, qt)
        den = jnp.abs(jnp.einsum("bhd,bhd->bh", n, qt))
        hout = num / jnp.maximum(den, jnp.exp(-m_new))[..., None]
        return (c, n, m_new), hout

    xs = (jnp.moveaxis(q, 1, 0).astype(jnp.float32),
          jnp.moveaxis(k, 1, 0).astype(jnp.float32),
          jnp.moveaxis(v, 1, 0).astype(jnp.float32),
          jnp.moveaxis(i_pre, 1, 0), jnp.moveaxis(f_pre, 1, 0))
    (c, n, m), hs = jax.lax.scan(step, (state["c"], state["n"],
                                        state["m"]), xs)
    return jnp.moveaxis(hs, 0, 1), {"c": c, "n": n, "m": m}


def _mlstm_chunkwise(q, k, v, i_pre, f_pre, state, chunk: int = 64):
    """Chunkwise-parallel mLSTM (the xLSTM paper's training formulation).

    Mathematically identical to `_mlstm_core` but scans over T/chunk
    chunks instead of T steps: intra-chunk terms are (L x L) matmuls, the
    (Dh x Dh) matrix state updates once per chunk. This is §Perf
    iteration X — the per-token scan materializes C (B,H,Dh,Dh) residuals
    T times per layer in the backward; chunkwise cuts that by `chunk`x.

    Stabilization: with a_t = cumsum(log f), w_s = i_s - a_s,
    u_t = cummax(w), M_t = max(m_prev, u_t), every exponent used is
    ≤ 0: intra coeff = exp(w_s - M_t), inter coeff = exp(m_prev - M_t),
    and the per-position stabilizer is m_t = a_t + M_t.
    """
    b, t, h, dh = q.shape
    kscale = 1.0 / math.sqrt(dh)
    L = min(chunk, t)
    nc = -(-t // L)
    pad = nc * L - t

    def pad_t(x):
        return jnp.pad(x, ((0, 0), (0, pad)) + ((0, 0),) * (x.ndim - 2))

    # (B, nc, L, H, ...) -> scan over nc
    qg = pad_t(q).reshape(b, nc, L, h, dh)
    kg = pad_t(k).reshape(b, nc, L, h, dh)
    vg = pad_t(v).reshape(b, nc, L, h, dh)
    # padded gate steps: f=0 (decay 1), i=-inf (no contribution)
    ig = pad_t(i_pre + 0.0)
    if pad:
        ig = ig.at[:, t:].set(NEG_INF)
    ig = ig.reshape(b, nc, L, h)
    fg = pad_t(f_pre).reshape(b, nc, L, h)

    causal = jnp.tril(jnp.ones((L, L), jnp.bool_))

    def chunk_step(carry, xs):
        c_prev, n_prev, m_prev = carry          # (B,H,Dh,Dh) (B,H,Dh) (B,H)
        qc, kc, vc, ic, fc = xs                 # (B,L,H,*) / (B,L,H)
        qc = qc.astype(jnp.float32)
        kc = kc.astype(jnp.float32) * kscale
        vc = vc.astype(jnp.float32)
        a = jnp.cumsum(fc, axis=1)              # (B,L,H)
        w = ic - a
        u = jax.lax.cummax(w, axis=1)
        M = jnp.maximum(m_prev[:, None], u)     # (B,L,H)
        inter = jnp.exp(m_prev[:, None] - M)    # (B,L,H)
        # D[t,s] = exp(w_s - M_t), s<=t
        D = jnp.exp(w[:, None, :, :] - M[:, :, None, :])  # (B,Lt,Ls,H)
        D = jnp.where(causal[None, :, :, None], D, 0.0)
        qk = jnp.einsum("bthd,bshd->btsh", qc, kc)
        S = qk * D
        num = jnp.einsum("btsh,bshd->bthd", S, vc) \
            + inter[..., None] * jnp.einsum("bhde,bthe->bthd", c_prev, qc)
        den = jnp.sum(S, axis=2) \
            + inter * jnp.einsum("bthd,bhd->bth", qc, n_prev)
        m_t = a + M                             # (B,L,H)
        hout = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_t))[..., None]
        # end-of-chunk state
        a_L, M_L = a[:, -1], M[:, -1]           # (B,H)
        coef = jnp.exp(w - M_L[:, None])        # (B,L,H)
        decay = jnp.exp(m_prev - M_L)           # (B,H)
        c_new = decay[..., None, None] * c_prev \
            + jnp.einsum("blh,blhd,blhe->bhde", coef, vc, kc)
        n_new = decay[..., None] * n_prev \
            + jnp.einsum("blh,blhd->bhd", coef, kc)
        m_new = a_L + M_L
        return (c_new, n_new, m_new), hout

    xs = tuple(jnp.moveaxis(z, 1, 0)
               for z in (qg, kg, vg, ig, fg))
    (c, n, m), hs = jax.lax.scan(
        chunk_step, (state["c"], state["n"], state["m"]), xs)
    hout = jnp.moveaxis(hs, 0, 1).reshape(b, nc * L, h, dh)[:, :t]
    return hout, {"c": c, "n": n, "m": m}


def mlstm_forward(p, x, cfg, policy, *, state=None, mode="train",
                  site="mlstm"):
    b, t, d = x.shape
    nh = cfg.n_heads
    up = qlinear.linear(x, p["w_up"], None, *rps(policy, site, "w_up"))
    xm, z = jnp.split(up, 2, axis=-1)
    conv_state = state["conv"] if state is not None else None
    xc, new_conv = conv1d_causal(p["conv"], jax.nn.silu(xm), conv_state)
    d_inner = xm.shape[-1]
    dh = d_inner // nh
    q = qlinear.linear(xc, p["wq"], None,
                       *rps(policy, site, "wq")).reshape(b, t, nh, dh)
    k = qlinear.linear(xc, p["wk"], None,
                       *rps(policy, site, "wk")).reshape(b, t, nh, dh)
    v = qlinear.linear(xm, p["wv"], None,
                       *rps(policy, site, "wv")).reshape(b, t, nh, dh)
    i_pre = (xc.astype(jnp.float32) @ p["w_igate"].astype(jnp.float32)
             + p["igate_bias"])
    f_pre = jax.nn.log_sigmoid(
        xc.astype(jnp.float32) @ p["w_fgate"].astype(jnp.float32)
        + p["fgate_bias"])
    st = state["mem"] if state is not None else {
        "c": jnp.zeros((b, nh, dh, dh), jnp.float32),
        "n": jnp.zeros((b, nh, dh), jnp.float32),
        "m": jnp.zeros((b, nh), jnp.float32)}
    chunk = getattr(cfg, "mlstm_chunk", 64)
    if t > 1 and chunk > 1:
        # chunkwise-parallel form for train/prefill (§Perf iteration X)
        hout, new_mem = _mlstm_chunkwise(q, k, v, i_pre, f_pre, st,
                                         chunk=chunk)
    else:
        hout, new_mem = _mlstm_core(q, k, v, i_pre, f_pre, st)
    hout = hout.reshape(b, t, d_inner).astype(x.dtype)
    hout = rms_norm(hout, p["outnorm"])
    y = qlinear.linear(hout * jax.nn.silu(z), p["w_down"], None,
                       *rps(policy, site, "w_down"))
    new_state = None
    if state is not None:
        new_state = {"mem": new_mem, "conv": new_conv}
    return logical(y, "batch", "seq", "embed"), new_state


def mlstm_init_state(batch, d_model, n_heads, conv_width=4):
    d_inner = 2 * d_model
    dh = d_inner // n_heads
    return {"mem": {"c": jnp.zeros((batch, n_heads, dh, dh), jnp.float32),
                    "n": jnp.zeros((batch, n_heads, dh), jnp.float32),
                    "m": jnp.zeros((batch, n_heads), jnp.float32)},
            "conv": jnp.zeros((batch, conv_width - 1, d_inner),
                              jnp.float32)}


def slstm_params(key, d_model, n_heads, dtype=jnp.float32):
    ks = jax.random.split(key, 9)
    dh = d_model // n_heads
    ff = int(4 * d_model / 3) // 2 * 2  # post up-proj (pf=4/3), even
    return {
        "wz": _init(ks[0], (d_model, d_model), dtype=dtype),
        "wi_gate": _init(ks[1], (d_model, d_model), 0.01, dtype=dtype),
        "wf_gate": _init(ks[2], (d_model, d_model), 0.01, dtype=dtype),
        "wo_gate": _init(ks[3], (d_model, d_model), 0.01, dtype=dtype),
        # block-diagonal recurrent weights, per head: (H, Dh, Dh)
        "r_z": (jax.random.normal(ks[4], (n_heads, dh, dh)) /
                math.sqrt(dh)).astype(dtype),
        "r_i": (jax.random.normal(ks[5], (n_heads, dh, dh)) * 0.01
                ).astype(dtype),
        "r_f": (jax.random.normal(ks[6], (n_heads, dh, dh)) * 0.01
                ).astype(dtype),
        "fgate_bias": jnp.full((d_model,), 3.0),
        "mlp": {"wu2": _init(ks[7], (d_model, ff), dtype=dtype),
                "wd2": _init(ks[8], (ff, d_model), dtype=dtype)},
    }


def _slstm_core(p, zi, ii, fi, oi, n_heads, state):
    """True recurrence (h feeds back through R) — scan over time.
    zi/ii/fi/oi: (B,T,D) pre-activations from the input side."""
    b, t, d = zi.shape
    dh = d // n_heads

    def blockdiag(h, r):  # h: (B,D) x r: (H,Dh,Dh)
        hh = h.reshape(b, n_heads, dh)
        return jnp.einsum("bhd,hde->bhe", hh,
                          r.astype(jnp.float32)).reshape(b, d)

    def step(carry, xs):
        c, n, m, h = carry
        zt, it, ft, ot = xs
        z = jnp.tanh(zt + blockdiag(h, p["r_z"]))
        ipre = it + blockdiag(h, p["r_i"])
        fpre = ft + blockdiag(h, p["r_f"])
        opre = ot
        m_new = jnp.maximum(jax.nn.log_sigmoid(fpre) + m, ipre)
        i_ = jnp.exp(ipre - m_new)
        f_ = jnp.exp(jax.nn.log_sigmoid(fpre) + m - m_new)
        c = f_ * c + i_ * z
        n = f_ * n + i_
        h_new = jax.nn.sigmoid(opre) * c / jnp.maximum(n, 1e-6)
        return (c, n, m_new, h_new), h_new

    xs = tuple(jnp.moveaxis(a.astype(jnp.float32), 1, 0)
               for a in (zi, ii, fi, oi))
    (c, n, m, h), hs = jax.lax.scan(
        step, (state["c"], state["n"], state["m"], state["h"]), xs)
    return jnp.moveaxis(hs, 0, 1), {"c": c, "n": n, "m": m, "h": h}


def slstm_forward(p, x, cfg, policy, *, state=None, mode="train",
                  site="slstm"):
    b, t, d = x.shape
    zi = qlinear.linear(x, p["wz"], None, *rps(policy, site, "wz"))
    ii = qlinear.linear(x, p["wi_gate"], None, *rps(policy, site, "wi_gate"))
    fi = qlinear.linear(x, p["wf_gate"], None,
                        *rps(policy, site, "wf_gate")) + p["fgate_bias"]
    oi = qlinear.linear(x, p["wo_gate"], None, *rps(policy, site, "wo_gate"))
    st = state["mem"] if state is not None else {
        "c": jnp.zeros((b, d), jnp.float32),
        "n": jnp.ones((b, d), jnp.float32),
        "m": jnp.zeros((b, d), jnp.float32),
        "h": jnp.zeros((b, d), jnp.float32)}
    hs, new_mem = _slstm_core(p, zi, ii, fi, oi, cfg.n_heads, st)
    hs = hs.astype(x.dtype)
    # post up-projection MLP (xLSTM sLSTM block, pf = 4/3)
    u = jax.nn.gelu(qlinear.linear(hs, p["mlp"]["wu2"], None,
                                   *rps(policy, site, "mlp/wu2")))
    y = qlinear.linear(u, p["mlp"]["wd2"], None, *rps(policy, site, "mlp/wd2"))
    new_state = {"mem": new_mem} if state is not None else None
    return logical(y, "batch", "seq", "embed"), new_state


def slstm_init_state(batch, d_model):
    return {"mem": {"c": jnp.zeros((batch, d_model), jnp.float32),
                    "n": jnp.ones((batch, d_model), jnp.float32),
                    "m": jnp.zeros((batch, d_model), jnp.float32),
                    "h": jnp.zeros((batch, d_model), jnp.float32)}}
