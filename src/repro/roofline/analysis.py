"""Roofline-term extraction from a compiled (dry-run) executable.

compute term    = per-chip HLO FLOPs / 197 TFLOP/s        (cost_analysis is
memory term     = per-chip HLO bytes / 819 GB/s            post-SPMD, i.e.
collective term = per-chip collective bytes / 50 GB/s      already per-chip)

Collective bytes come from parsing the optimized HLO text: operand sizes of
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute,
with ring-traffic multipliers (all-reduce moves ~2x its operand bytes).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

from . import hw

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16,
}

# result-side shapes of collective ops in optimized HLO, e.g.:
#   %all-reduce.5 = f32[1024,512]{1,0} all-reduce(...)
#   ... = (f32[8,128]{1,0}, f32[8,128]{1,0}) all-reduce(...)
_COLL_RE = re.compile(
    r"=\s*(\([^)]*\)|[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?)\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")

# bytes moved per chip relative to the (per-chip) result bytes
_TRAFFIC_FACTOR = {
    "all-reduce": 2.0,          # reduce-scatter + all-gather ring
    "all-gather": 1.0,          # result ≈ gathered bytes received
    "reduce-scatter": 1.0,      # sends ≈ input ≈ result × n ≈ … (lower bd)
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Per-op-kind traffic bytes (per chip) from optimized HLO text."""
    out: Dict[str, float] = {}
    for shape_str, kind in _COLL_RE.findall(hlo_text):
        b = _shape_bytes(shape_str) * _TRAFFIC_FACTOR[kind]
        out[kind] = out.get(kind, 0.0) + b
    out["total"] = sum(v for k, v in out.items() if k != "total")
    return out


def count_collectives(hlo_text: str) -> Dict[str, int]:
    counts: Dict[str, int] = {}
    for _, kind in _COLL_RE.findall(hlo_text):
        counts[kind] = counts.get(kind, 0) + 1
    return counts


@dataclasses.dataclass
class Roofline:
    flops_per_chip: float
    bytes_per_chip: float
    coll_bytes_per_chip: float
    n_chips: int
    model_flops_global: float = 0.0   # 6·N·D (train) / 2·N·tokens (decode)
    arg_bytes_per_chip: float = 0.0   # resident state (params+caches+opt)
    raw_cost_analysis: Optional[dict] = None   # XLA's own (while-once)
    collective_counts: Optional[dict] = None
    flags: Optional[dict] = None

    @property
    def t_compute(self) -> float:
        return hw.compute_time_s(self.flops_per_chip)

    @property
    def t_memory(self) -> float:
        return hw.memory_time_s(self.bytes_per_chip)

    @property
    def t_collective(self) -> float:
        return hw.collective_time_s(self.coll_bytes_per_chip)

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def t_bound(self) -> float:
        """Roofline step time: the dominant term (perfect overlap model)."""
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs (global): remat/waste diagnostic."""
        hlo_global = self.flops_per_chip * self.n_chips
        return self.model_flops_global / hlo_global if hlo_global else 0.0

    @property
    def mfu_bound(self) -> float:
        """Model-FLOPs utilisation at the roofline bound."""
        if not self.t_bound:
            return 0.0
        return (self.model_flops_global /
                (self.n_chips * hw.PEAK_FLOPS_BF16 * self.t_bound))

    def as_dict(self) -> dict:
        return {
            "flops_per_chip": self.flops_per_chip,
            "bytes_per_chip": self.bytes_per_chip,
            "coll_bytes_per_chip": self.coll_bytes_per_chip,
            "arg_bytes_per_chip": self.arg_bytes_per_chip,
            "n_chips": self.n_chips,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "t_bound_s": self.t_bound,
            "model_flops_global": self.model_flops_global,
            "useful_flops_ratio": self.useful_flops_ratio,
            "mfu_bound": self.mfu_bound,
            "raw_cost_analysis": self.raw_cost_analysis,
            "collective_counts": self.collective_counts,
            "flags": self.flags,
        }


def analyze(compiled, n_chips: int,
            model_flops_global: float = 0.0) -> Roofline:
    """Roofline terms from a compiled executable.

    Uses the trip-count-aware HLO walker (hlo_stats) as the source of
    truth: XLA's cost_analysis() counts while bodies once, understating
    scanned-layer models by ~n_layers×. cost_analysis values are kept as
    cross-check fields in `raw_cost_analysis`.
    """
    from . import hlo_stats
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):     # older jax: one dict per device
        ca = ca[0] if ca else {}
    hlo = compiled.as_text()
    st = hlo_stats.analyze_hlo(hlo)
    mem = compiled.memory_analysis()
    arg_bytes = float(getattr(mem, "argument_size_in_bytes", 0) or 0)
    r = Roofline(
        flops_per_chip=float(st.flops),
        bytes_per_chip=float(st.bytes),
        # HLO text is the per-device SPMD module -> already per-chip
        coll_bytes_per_chip=float(st.collective_bytes),
        n_chips=n_chips,
        model_flops_global=model_flops_global,
        arg_bytes_per_chip=arg_bytes,
    )
    r.raw_cost_analysis = {"flops": float(ca.get("flops", 0.0)),
                           "bytes_accessed":
                           float(ca.get("bytes accessed", 0.0))}
    r.collective_counts = dict(st.collective_counts)
    r.flags = {"unknown_trip_counts": st.unknown_trip_counts,
               "custom_call_matmuls": st.custom_call_matmuls}
    return r
