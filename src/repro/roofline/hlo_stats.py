"""Trip-count-aware statistics over optimized HLO text.

XLA's `Executable.cost_analysis()` counts each computation ONCE — a
lax.scan body's FLOPs are not multiplied by the trip count, which would
understate scanned-layer models by ~n_layers×. This walker parses the
optimized HLO text instead:

  · builds the computation table (instruction -> result shape),
  · counts dot FLOPs per computation (folding fusion-called computations
    into their caller),
  · estimates HBM bytes per *loop-level* computation (operands + results
    of top-level instructions; fusion internals excluded — matching
    fusion semantics),
  · sums collective traffic (result bytes × ring factor),
  · propagates multiplicity through the call graph using the
    `known_trip_count` backend_config on `while` ops.

Validated against unrolled-loop cost_analysis in tests/test_roofline.py.
"""
from __future__ import annotations

import dataclasses
import json
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "token": 0, "s2": 1, "u2": 1,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%([\w.\-]+)\s+\(.*\)\s*->")
_SIMPLE_SHAPE = re.compile(r"[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?")
_OPCODE = re.compile(r"([\w\-]+)\(")
_WHILE_META = re.compile(
    r"condition=%([\w.\-]+),\s*body=%([\w.\-]+)")
_TRIP = re.compile(r"known_trip_count\W+n\W+(\d+)")
_CALLS = re.compile(r"calls=%([\w.\-]+)")
_BRANCHES = re.compile(r"branch_computations=\{([^}]*)\}")
_DOT_LHS_CONTRACT = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")
_TRAFFIC_FACTOR = {"all-reduce": 2.0, "all-gather": 1.0,
                   "reduce-scatter": 1.0, "all-to-all": 1.0,
                   "collective-permute": 1.0}
# ops whose "result" is a view / no HBM traffic of its own
_VIEW_OPS = {"parameter", "constant", "tuple", "get-tuple-element",
             "bitcast", "after-all", "iota", "partition-id", "replica-id"}
# ops that read only a slice of their (possibly huge) operand
_SLICE_OPS = {"dynamic-slice", "slice", "gather"}

# ---- virtual fusion (TPU model) -------------------------------------------
# The CPU backend fuses far less aggressively than TPU, so top-level HLO is
# full of bare elementwise chains that a TPU compiler would fuse into their
# consumers. We model XLA's core instruction-fusion heuristic: a producer
# with EXACTLY ONE consumer, where producer is fusable and the consumer can
# absorb it, keeps its result in registers/VMEM — its HBM write (and the
# consumer's corresponding read) is elided.
_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum",
    "exponential", "exponential-minus-one", "log", "log-plus-one",
    "tanh", "logistic", "select", "compare", "and", "or", "xor", "not",
    "negate", "abs", "sign", "sqrt", "rsqrt", "cbrt", "power", "floor",
    "ceil", "round-nearest-afz", "round-nearest-even", "clamp", "convert",
    "shift-left", "shift-right-logical", "shift-right-arithmetic",
    "is-finite", "cosine", "sine", "atan2", "remainder", "rem",
    "bitcast-convert", "reduce-precision", "stochastic-convert",
}
# producers whose single-consumer write can stay on-chip (incl. dot/reduce
# epilogue fusion, broadcast-into-consumer)
_FUSABLE_PRODUCER = _ELEMENTWISE | {"broadcast", "dot", "convolution",
                                    "reduce", "transpose", "reshape",
                                    "copy", "pad", "reverse"}
# consumers that absorb a fused producer (loop/input fusion targets)
_FUSABLE_CONSUMER = _ELEMENTWISE | {"reduce", "dynamic-update-slice",
                                    "broadcast", "transpose", "reshape",
                                    "copy", "pad", "reverse", "fusion",
                                    "concatenate", "scatter", "select"}


def _virtual_fusion(comp: "Computation"):
    """(fused_writes, fused_reads): results that never hit HBM and the
    corresponding (consumer, operand) read edges to skip."""
    consumers: Dict[str, List["Instr"]] = {}
    for ins in comp.instrs:
        for o in set(_operand_names(ins.line, ins.opcode)):
            if o in comp.symbols:
                consumers.setdefault(o, []).append(ins)
    fused_writes = set()
    fused_reads = set()
    for ins in comp.instrs:
        if ins.opcode not in _FUSABLE_PRODUCER:
            continue
        cons = consumers.get(ins.name, [])
        if len(cons) == 1 and cons[0].opcode in _FUSABLE_CONSUMER:
            fused_writes.add(ins.name)
            fused_reads.add((cons[0].name, ins.name))
    return fused_writes, fused_reads


def _shape_dims(shape_str: str) -> List[Tuple[str, List[int]]]:
    out = []
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt in _DTYPE_BYTES:
            out.append((dt, [int(d) for d in dims.split(",") if d]))
    return out


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _shape_dims(shape_str):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class Instr:
    name: str
    shape: str
    opcode: str
    line: str


@dataclasses.dataclass
class Computation:
    name: str
    instrs: List[Instr]
    symbols: Dict[str, str]          # instr name -> result shape string


def _parse_instr(line: str) -> Optional[Instr]:
    s = line.strip()
    if s.startswith("ROOT "):
        s = s[5:]
    if not s.startswith("%"):
        return None
    eq = s.find(" = ")
    if eq < 0:
        return None
    name = s[1:eq]
    rest = s[eq + 3:]
    if rest.startswith("("):          # tuple shape: balanced-paren scan
        depth = 0
        end = 0
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        shape = rest[:end + 1]
        rem = rest[end + 1:].lstrip()
    else:
        m = _SIMPLE_SHAPE.match(rest)
        if not m:
            return None
        shape = m.group(0)
        rem = rest[m.end():].lstrip()
    m2 = _OPCODE.match(rem)
    if not m2:
        return None
    return Instr(name, shape, m2.group(1), line)


def parse_computations(hlo: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for line in hlo.splitlines():
        stripped = line.strip()
        hdr = _COMP_HDR.match(stripped)
        if hdr and line.rstrip().endswith("{"):
            cur = Computation(hdr.group(1), [], {})
            comps[cur.name] = cur
            # computation parameters appear as instruction lines too
            continue
        if cur is None:
            continue
        if stripped == "}":
            cur = None
            continue
        ins = _parse_instr(line)
        if ins:
            cur.instrs.append(ins)
            cur.symbols[ins.name] = ins.shape
    return comps


def _operand_names(line: str, opcode: str) -> List[str]:
    """%refs inside the operand parens of the instruction."""
    i = line.find(opcode + "(")
    if i < 0:
        return []
    j = i + len(opcode) + 1
    depth = 1
    k = j
    while k < len(line) and depth:
        if line[k] == "(":
            depth += 1
        elif line[k] == ")":
            depth -= 1
        k += 1
    return re.findall(r"%([\w.\-]+)", line[j:k - 1])


def _dot_flops(ins: Instr, comp: Computation) -> float:
    res_elems = 0
    for _, dims in _shape_dims(ins.shape):
        n = 1
        for d in dims:
            n *= d
        res_elems += n
    ops = _operand_names(ins.line, ins.opcode)
    if not ops:
        return 0.0
    lhs_shape = comp.symbols.get(ops[0], "")
    lhs_dims_all = _shape_dims(lhs_shape)
    if not lhs_dims_all:
        return 0.0
    lhs_dims = lhs_dims_all[0][1]
    m = _DOT_LHS_CONTRACT.search(ins.line)
    contract = 1
    if m:
        for idx in m.group(1).split(","):
            if idx and int(idx) < len(lhs_dims):
                contract *= lhs_dims[int(idx)]
    return 2.0 * res_elems * contract


@dataclasses.dataclass
class HloStats:
    flops: float = 0.0
    bytes: float = 0.0
    collective_bytes: float = 0.0
    collective_counts: Dict[str, float] = dataclasses.field(
        default_factory=dict)
    unknown_trip_counts: int = 0
    custom_call_matmuls: int = 0

    def as_dict(self):
        return dataclasses.asdict(self)


def analyze_hlo(hlo: str) -> HloStats:
    comps = parse_computations(hlo)
    entry = None
    for line in hlo.splitlines():
        if line.startswith("ENTRY"):
            m = _COMP_HDR.match(line.strip())
            if m:
                entry = m.group(1)
    if entry is None and comps:
        entry = list(comps)[-1]

    # fusion-closure flops per computation (dots inside called fusions
    # attribute to the caller)
    flops_cache: Dict[str, float] = {}

    def comp_flops(cname: str, seen=()) -> float:
        if cname in flops_cache:
            return flops_cache[cname]
        comp = comps.get(cname)
        if comp is None or cname in seen:
            return 0.0
        total = 0.0
        for ins in comp.instrs:
            if ins.opcode in ("dot", "convolution"):
                total += _dot_flops(ins, comp)
            elif ins.opcode == "fusion":
                m = _CALLS.search(ins.line)
                if m:
                    total += comp_flops(m.group(1), seen + (cname,))
        flops_cache[cname] = total
        return total

    stats = HloStats()
    fusion_cache: Dict[str, Tuple[set, set]] = {}
    # BFS over loop-level computations with multiplicity
    pending: List[Tuple[str, float]] = [(entry, 1.0)]
    visited_mult: Dict[str, float] = {}
    while pending:
        cname, mult = pending.pop()
        visited_mult[cname] = visited_mult.get(cname, 0.0) + mult
        comp = comps.get(cname)
        if comp is None:
            continue
        if cname not in fusion_cache:
            fusion_cache[cname] = _virtual_fusion(comp)
        fused_writes, fused_reads = fusion_cache[cname]
        stats.flops += comp_flops(cname) * mult
        for ins in comp.instrs:
            opb = ins.opcode
            if opb == "while":
                m = _WHILE_META.search(ins.line)
                t = _TRIP.search(ins.line)
                trip = float(t.group(1)) if t else 1.0
                if not t:
                    stats.unknown_trip_counts += 1
                if m:
                    pending.append((m.group(2), mult * trip))  # body
                continue
            if opb == "conditional":
                mb = _BRANCHES.search(ins.line)
                if mb:
                    for b in re.findall(r"%([\w.\-]+)", mb.group(1)):
                        pending.append((b, mult))
                continue
            if opb == "call":
                m = _CALLS.search(ins.line) or re.search(
                    r"to_apply=%([\w.\-]+)", ins.line)
                if m:
                    pending.append((m.group(1), mult))
            if opb == "custom-call" and re.search(
                    r"matmul|gemm|dot", ins.line, re.I):
                stats.custom_call_matmuls += 1
            # ---- bytes: top-level instruction operands + result
            base = opb.replace("-start", "").replace("-done", "")
            if base in COLLECTIVES:
                rb = _shape_bytes(ins.shape) * _TRAFFIC_FACTOR[base]
                # -done ops re-reference the -start result: count once
                if not opb.endswith("-done"):
                    stats.collective_bytes += rb * mult
                    stats.collective_counts[base] = \
                        stats.collective_counts.get(base, 0.0) + mult
            stats.bytes += _instr_bytes(ins, comp, comps, fused_writes,
                                        fused_reads) * mult
    return stats


def _instr_bytes(ins: Instr, comp: Computation,
                 comps: Dict[str, Computation],
                 fused_writes=frozenset(),
                 fused_reads=frozenset()) -> float:
    """HBM traffic estimate for one top-level instruction.

    Mirrors XLA's utilization model where it matters: slicing ops read
    only their result-sized window; dynamic-update-slice writes only the
    update region; fusion operands consumed solely through slices count
    at slice size (the lax.scan xs pattern). Virtual fusion (TPU model):
    writes in `fused_writes` and read edges in `fused_reads` stay on-chip.
    """
    op = ins.opcode
    if op in _VIEW_OPS or op in ("while", "conditional", "call"):
        return 0.0
    if op.endswith("-done"):
        return 0.0                       # aliases the -start buffer
    rb = _shape_bytes(ins.shape)
    write = 0.0 if ins.name in fused_writes else rb
    if op in _SLICE_OPS:
        return rb + write                # read window + write result
    if op in ("dynamic-update-slice", "scatter"):
        ops = _operand_names(ins.line, ins.opcode)
        ui = 1 if op == "dynamic-update-slice" else 2
        upd = ops[ui] if len(ops) > ui else None
        ub = _shape_bytes(comp.symbols.get(upd, "")) if upd else rb
        rd = 0.0 if (upd and (ins.name, upd) in fused_reads) else ub
        return rd + 2.0 * ub             # read update + r/w region
    if op == "broadcast":
        return write                     # operand is small; write dominates
    if op == "fusion":
        return _fusion_bytes(ins, comp, comps)
    b = write
    for o in _operand_names(ins.line, ins.opcode):
        if (ins.name, o) in fused_reads:
            continue
        b += _shape_bytes(comp.symbols.get(o, ""))
    return b


def _fusion_root(called: Computation) -> Optional[Instr]:
    """The fusion's semantic root: look through layout-only wrapper ops
    (bitcast/reshape/transpose/copy) AND dtype converts to the producing
    instruction, so `convert(dynamic-update-slice(convert(...)))` is
    accounted as a DUS. The convert sandwich is a CPU-backend
    legalization (no native bf16 scatter/DUS kernels) that a TPU build
    would not emit — the cache round-trip it implies is not real HBM
    traffic on the target."""
    root = called.instrs[-1] if called.instrs else None
    hops = 0
    while root is not None and hops < 4 and \
            root.opcode in ("bitcast", "reshape", "transpose", "copy",
                            "convert"):
        ops = _operand_names(root.line, root.opcode)
        if not ops:
            break
        nxt = next((i for i in called.instrs if i.name == ops[0]), None)
        if nxt is None:
            break
        root = nxt
        hops += 1
    return root


def _fusion_bytes(ins: Instr, comp: Computation,
                  comps: Dict[str, Computation]) -> float:
    m = _CALLS.search(ins.line)
    called = comps.get(m.group(1)) if m else None
    if called is None:
        return 2.0 * _shape_bytes(ins.shape)
    # write side: DUS/scatter roots update in place; the buffer operand
    # is aliased (never read in full) so its parameter is skipped below
    root = _fusion_root(called)
    skip_params: set = set()
    if root is not None and root.opcode in ("dynamic-update-slice",
                                            "scatter"):
        ops = _operand_names(root.line, root.opcode)
        upd_idx = 1 if root.opcode == "dynamic-update-slice" else 2
        upd = ops[upd_idx] if len(ops) > upd_idx else None
        wb = 2.0 * _shape_bytes(called.symbols.get(upd, "")) if upd \
            else _shape_bytes(ins.shape)
        if ops:
            tgt = ops[0]                       # the aliased buffer chain
            for _ in range(4):
                producer = next((i for i in called.instrs
                                 if i.name == tgt), None)
                if producer is None:
                    break
                if producer.opcode == "parameter":
                    mnum = re.search(r"parameter\((\d+)\)", producer.line)
                    if mnum:
                        skip_params.add(int(mnum.group(1)))
                    break
                pops = _operand_names(producer.line, producer.opcode)
                if producer.opcode in ("bitcast", "reshape", "transpose",
                                       "copy", "convert") and pops:
                    tgt = pops[0]
                else:
                    break
    else:
        wb = _shape_bytes(ins.shape)
    # read side: per fused operand, slice-only consumers count at slice size
    params = {}
    for inner in called.instrs:
        if inner.opcode == "parameter":
            mnum = re.search(r"parameter\((\d+)\)", inner.line)
            if mnum:
                params[int(mnum.group(1))] = inner
    outer_ops = _operand_names(ins.line, ins.opcode)
    rb = 0.0
    for i, _ in enumerate(outer_ops):
        if i in skip_params:
            continue                            # aliased DUS buffer
        p = params.get(i)
        if p is None:
            continue
        consumed = _consumer_bytes(p.name, called)
        rb += consumed if consumed is not None \
            else _shape_bytes(p.shape)
    return wb + rb


def _consumer_bytes(pname: str, comp: Computation,
                    depth: int = 0) -> Optional[float]:
    """If `pname` is consumed only through slicing ops (via views), the
    bytes actually read; None -> consumed broadly (count full size)."""
    if depth > 3:
        return None
    total = 0.0
    found = False
    for ins in comp.instrs:
        if ins.opcode == "parameter":
            continue
        ops = _operand_names(ins.line, ins.opcode)
        if pname not in ops:
            continue
        found = True
        if ins.opcode in _SLICE_OPS:
            total += _shape_bytes(ins.shape)
        elif ins.opcode == "bitcast":
            sub = _consumer_bytes(ins.name, comp, depth + 1)
            if sub is None:
                return None
            total += sub
        else:
            return None
    return total if found else 0.0
