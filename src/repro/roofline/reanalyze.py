"""Re-derive roofline terms from the dry-run's saved optimized HLO —
no recompilation. Used when the byte/FLOP cost model changes (§Perf
accounting iterations) and for quick what-if analysis.

  PYTHONPATH=src python -m repro.roofline.reanalyze [--out EXPERIMENTS/dryrun]
"""
from __future__ import annotations

import argparse
import glob
import gzip
import json
import os

from . import hlo_stats
from .analysis import Roofline


def reanalyze_cell(json_path: str, hlo_dir: str) -> bool:
    with open(json_path) as f:
        rec = json.load(f)
    if rec.get("status") != "ok":
        return False
    tag = rec["cell"]
    hlo_path = os.path.join(hlo_dir, tag + ".hlo.gz")
    if not os.path.exists(hlo_path):
        return False
    with gzip.open(hlo_path, "rt") as f:
        hlo = f.read()
    st = hlo_stats.analyze_hlo(hlo)
    old = rec["roofline"]
    r = Roofline(
        flops_per_chip=float(st.flops),
        bytes_per_chip=float(st.bytes),
        coll_bytes_per_chip=float(st.collective_bytes),
        n_chips=old["n_chips"],
        model_flops_global=old["model_flops_global"],
        arg_bytes_per_chip=old.get("arg_bytes_per_chip", 0.0),
    )
    r.raw_cost_analysis = old.get("raw_cost_analysis")
    r.collective_counts = dict(st.collective_counts)
    r.flags = {"unknown_trip_counts": st.unknown_trip_counts,
               "custom_call_matmuls": st.custom_call_matmuls}
    rec["roofline"] = r.as_dict()
    with open(json_path, "w") as f:
        json.dump(rec, f, indent=1)
    return True


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="EXPERIMENTS/dryrun")
    args = ap.parse_args()
    hlo_dir = os.path.join(args.out, "hlo")
    n = 0
    for p in sorted(glob.glob(os.path.join(args.out, "*.json"))):
        if reanalyze_cell(p, hlo_dir):
            n += 1
            with open(p) as f:
                r = json.load(f)["roofline"]
            print(f"{os.path.basename(p)[:-5]}: "
                  f"mem={r['t_memory_s']:.3g}s coll={r['t_collective_s']:.3g}s "
                  f"comp={r['t_compute_s']:.3g}s -> {r['bottleneck']}")
    print(f"reanalyzed {n} cells")


if __name__ == "__main__":
    main()
