from . import hw
from .analysis import Roofline, analyze, collective_bytes, count_collectives
