"""Roofline report: EXPERIMENTS/dryrun/*.json -> markdown tables.

  PYTHONPATH=src python -m repro.roofline.report [--mesh single] [--quant none]
"""
from __future__ import annotations

import argparse
import glob
import json
import os
from typing import Dict, List

ARCH_ORDER = ["minitron-8b", "qwen2-7b", "qwen1.5-0.5b", "yi-6b",
              "recurrentgemma-9b", "xlstm-350m", "qwen3-moe-30b-a3b",
              "grok-1-314b", "internvl2-1b", "seamless-m4t-large-v2"]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(out_dir: str, mesh: str, quant: str) -> List[dict]:
    recs = []
    for p in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        with open(p) as f:
            r = json.load(f)
        parts = os.path.basename(p)[:-5].split("__")
        if len(parts) != 4:
            continue
        arch, shape, m, q = parts
        if m == mesh and q == quant:
            r.update(arch=arch, shape=shape)
            recs.append(r)
    recs.sort(key=lambda r: (ARCH_ORDER.index(r["arch"])
                             if r["arch"] in ARCH_ORDER else 99,
                             SHAPE_ORDER.index(r["shape"])
                             if r["shape"] in SHAPE_ORDER else 99))
    return recs


def fmt(x, nd=3):
    if x == 0:
        return "0"
    if x >= 100 or x < 0.01:
        return f"{x:.2e}"
    return f"{x:.{nd}g}"


def table(recs: List[dict]) -> str:
    hdr = ("| arch | shape | t_comp (s) | t_mem (s) | t_coll (s) | "
           "bottleneck | MODEL_FL/HLO_FL | MFU@bound | note |")
    sep = "|" + "---|" * 9
    rows = [hdr, sep]
    for r in recs:
        if r["status"] == "skipped":
            rows.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                        f"skipped | — | — | {r['reason'][:40]}… |")
            continue
        if r["status"] != "ok":
            rows.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                        f"ERROR | — | — | {r['error'][:40]} |")
            continue
        f = r["roofline"]
        rows.append(
            f"| {r['arch']} | {r['shape']} | {fmt(f['t_compute_s'])} | "
            f"{fmt(f['t_memory_s'])} | {fmt(f['t_collective_s'])} | "
            f"**{f['bottleneck']}** | {fmt(f['useful_flops_ratio'])} | "
            f"{100*f['mfu_bound']:.1f}% | {r.get('note','')[:46]} |")
    return "\n".join(rows)


def pick_hillclimb(recs: List[dict]) -> Dict[str, dict]:
    ok = [r for r in recs if r["status"] == "ok"]
    worst_mfu = min((r for r in ok if r["shape"] == "train_4k"),
                    key=lambda r: r["roofline"]["mfu_bound"], default=None)
    coll = max(ok, key=lambda r: (r["roofline"]["t_collective_s"]
                                  / max(r["roofline"]["t_bound_s"], 1e-12)))
    return {"worst_mfu_train": worst_mfu, "most_collective": coll}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="EXPERIMENTS/dryrun")
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--quant", default="none")
    args = ap.parse_args()
    recs = load(args.out, args.mesh, args.quant)
    print(f"## Roofline — mesh={args.mesh}, quant={args.quant}, "
          f"{len(recs)} cells\n")
    print(table(recs))
    picks = pick_hillclimb(recs)
    print("\nhillclimb candidates:")
    for k, r in picks.items():
        if r:
            print(f"  {k}: {r['arch']} x {r['shape']} "
                  f"(mfu={100*r['roofline']['mfu_bound']:.1f}%, "
                  f"bottleneck={r['roofline']['bottleneck']})")


if __name__ == "__main__":
    main()
