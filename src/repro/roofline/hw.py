"""TPU v5e-class hardware constants for the roofline model."""

PEAK_FLOPS_BF16 = 197e12      # FLOP/s per chip
HBM_BW = 819e9                # B/s per chip
ICI_BW_PER_LINK = 50e9        # B/s per link (per assignment)

CHIP_HBM_BYTES = 16 * 1024 ** 3   # 16 GiB


def compute_time_s(flops_per_chip: float) -> float:
    return flops_per_chip / PEAK_FLOPS_BF16


def memory_time_s(bytes_per_chip: float) -> float:
    return bytes_per_chip / HBM_BW


def collective_time_s(coll_bytes_per_chip: float) -> float:
    return coll_bytes_per_chip / ICI_BW_PER_LINK
