from .axes import axis_rules, logical, logical_sharding, resolve
