"""Logical-axis sharding annotations (MaxText-style, hand-rolled).

Models annotate activations with *logical* axis names; a rules table maps
logical names to physical mesh axes. Outside a mesh context the annotations
are no-ops, so the same model code runs on 1 CPU device and on the 512-chip
production mesh.

    with axis_rules(mesh, RULES):
        x = logical(x, "batch", "seq", "embed")
"""
from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

AxisVal = Union[None, str, Tuple[str, ...]]

_ctx = threading.local()


def _current():
    return getattr(_ctx, "stack", [None])[-1]


@contextlib.contextmanager
def axis_rules(mesh: Mesh, rules: Dict[str, AxisVal]):
    if not hasattr(_ctx, "stack"):
        _ctx.stack = [None]
    _ctx.stack.append((mesh, dict(rules)))
    try:
        yield
    finally:
        _ctx.stack.pop()


def resolve(names: Tuple[Optional[str], ...],
            rules: Dict[str, AxisVal]) -> P:
    """Logical names -> PartitionSpec under `rules` (unknown -> replicated).

    Guards against reusing one mesh axis twice in a single spec (illegal in
    GSPMD): later duplicates degrade to replicated.
    """
    used = set()
    parts = []
    for n in names:
        v = rules.get(n) if n is not None else None
        if v is None:
            parts.append(None)
            continue
        vt = (v,) if isinstance(v, str) else tuple(v)
        vt = tuple(a for a in vt if a not in used)
        if not vt:
            parts.append(None)
            continue
        used.update(vt)
        parts.append(vt if len(vt) > 1 else vt[0])
    return P(*parts)


def logical(x: jax.Array, *names: Optional[str]) -> jax.Array:
    """Annotate an activation with logical axes (no-op without a mesh)."""
    cur = _current()
    if cur is None:
        return x
    mesh, rules = cur
    if len(names) != x.ndim:
        raise ValueError(f"{len(names)} names for rank-{x.ndim} array")
    spec = resolve(tuple(names), rules)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, spec))


def logical_sharding(mesh: Mesh, rules: Dict[str, AxisVal],
                     *names: Optional[str]) -> NamedSharding:
    return NamedSharding(mesh, resolve(tuple(names), rules))
