"""Per-architecture sharding rules and parameter PartitionSpec derivation.

Physical mesh axes: ("pod", "data", "model") multi-pod / ("data", "model")
single-pod. Mapping (DESIGN.md §4):

  DP    batch            -> ("pod", "data")
  TP    heads / ffn / vocab dims -> "model" (divisibility-aware fallback)
  EP    MoE expert dim   -> "model" (fallback: TP inside the expert)
  SP    long-context KV seq dim -> "data" (batch=1 cells)
  FSDP  weight reduction dims + optimizer state -> "data"

All decisions are static functions of (ArchConfig, mesh shape, shape kind),
so the dry-run and the launcher derive identical layouts.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig

# weight-name classes: first-of-pair (column-parallel: out dim -> TP) vs
# second-of-pair (row-parallel: in dim -> TP)
COL_PARALLEL = {"wq", "wk", "wv", "wg", "wu", "wi", "wz", "wi_gate",
                "wf_gate", "wo_gate", "wu2", "wx", "wgate", "w_up"}
ROW_PARALLEL = {"wo", "wd", "w_down", "wd2"}
REPLICATED_NAMES = {"gamma_scale", "beta_shift", "a_param", "fgate_bias",
                    "igate_bias", "conv_bias", "conv_kernel", "b_in", "bq",
                    "bk", "bv", "bi", "bd", "r_z", "r_i", "r_f", "w_gate",
                    "w_inp_gate", "w_rec_gate"}


def mesh_axis_sizes(mesh) -> Dict[str, int]:
    """Axis-name -> size for Mesh and AbstractMesh alike.

    Current JAX exposes an axis-name -> size mapping as `.shape` on both;
    older AbstractMesh returned a plain size tuple, which zips against
    `.axis_names`. Only that tuple-shaped case is caught: a mesh with no
    `.shape`/`.axis_names` at all, or with mismatched lengths, raises
    instead of being silently treated as unsharded (touching
    `AbstractMesh.devices` is never safe — it raises ValueError, which a
    bare hasattr/except used to swallow).
    """
    shape = mesh.shape
    try:
        return dict(shape)
    except (TypeError, ValueError):
        pass                      # legacy plain size tuple
    names = tuple(mesh.axis_names)
    sizes = tuple(shape)
    if len(names) != len(sizes):
        raise ValueError(f"mesh axis_names {names!r} do not match mesh "
                         f"shape {sizes!r}")
    return dict(zip(names, sizes))


SMALL_MODEL_PARAMS = int(2e9)   # below this, TP hurts: go pure DP/FSDP


def use_dp_only(cfg: ArchConfig, mesh, global_batch: Optional[int]) -> bool:
    """Small models on big meshes: per-layer TP all-reduces dominate the
    step (§Perf iteration S). When the global batch divides the WHOLE
    mesh, run pure data-parallel with FSDP-sharded weights instead."""
    if global_batch is None:
        return False
    if "slstm" in cfg.block_pattern:
        # sLSTM's per-token recurrence closes replicated weights over a
        # 4096-step scan; GSPMD psums their gradient EVERY step under any
        # layout, and dp_only makes it worse (measured: 6.3 -> 12.5 s
        # collective, §Perf S2 refuted). Until the hand-written sLSTM VJP
        # lands (accumulate dW locally, reduce once), keep TP.
        return False
    sizes = mesh_axis_sizes(mesh)
    total = 1
    for v in sizes.values():
        total *= v
    return (cfg.active_param_count() <= SMALL_MODEL_PARAMS
            and global_batch % total == 0)


def make_rules(cfg: ArchConfig, mesh: Mesh,
               long_context: bool = False,
               global_batch: Optional[int] = None) -> Dict[str, Any]:
    """Logical-axis -> mesh-axis rules for activations and caches."""
    sizes = mesh_axis_sizes(mesh)
    tp = sizes.get("model", 1)
    batch_axes = tuple(a for a in ("pod", "data") if a in sizes)
    if use_dp_only(cfg, mesh, global_batch):
        all_axes = tuple(sizes)
        return {"batch": all_axes, "seq": None, "embed": None,
                "heads": None, "kv_heads": None, "ffn": None,
                "expert": None, "expert_cap": None, "vocab": None}
    div = lambda n: (n and n % tp == 0)
    rules = {
        # long-context cells run batch=1: batch is replicated and the
        # sequence/KV dim takes ALL data-parallel axes (sequence parallel)
        "batch": None if long_context else (batch_axes or None),
        "seq": (batch_axes or None) if long_context else None,
        "embed": None,
        "heads": "model" if div(cfg.n_heads) else None,
        "kv_heads": "model" if div(cfg.n_kv_heads) else None,
        "ffn": "model" if div(cfg.d_ff) else None,
        "expert": "model" if (cfg.n_experts and div(cfg.n_experts))
        else None,
        # MoE slot/capacity dim: shard over "data" so few-expert MoEs
        # (grok: E=8 < tp) still keep dispatched tokens distributed
        "expert_cap": "data" if "data" in sizes else None,
        "vocab": "model" if div(cfg.padded_vocab) else None,
    }
    for k in ("batch", "seq"):
        if isinstance(rules[k], tuple) and len(rules[k]) == 1:
            rules[k] = rules[k][0]
    return rules


def _leaf_name(path) -> str:
    parts = [str(getattr(k, "key", getattr(k, "idx", k))) for k in path]
    # QuantizedTensor leaves end in .data / .scale — classify by parent
    if parts and parts[-1] in ("data", "scale"):
        return parts[-2] if len(parts) > 1 else parts[-1]
    return parts[-1] if parts else ""


def _is_scale(path) -> bool:
    parts = [str(getattr(k, "key", getattr(k, "idx", k))) for k in path]
    return bool(parts) and parts[-1] == "scale"


def _path_str(path) -> str:
    return "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                    for k in path)


def param_spec(path, shape: Tuple[int, ...], cfg: ArchConfig,
               sizes: Dict[str, int], dp_only: bool = False) -> P:
    """PartitionSpec for one parameter leaf.

    2-D core weights: TP on the hidden dim, FSDP ("data") on the other.
    Stacked leading dims (scan groups / experts) handled positionally.
    dp_only (§Perf iteration S): FSDP-shard the largest weight dim over
    every mesh axis; no tensor parallelism.
    """
    tp = sizes.get("model", 1)
    dp = sizes.get("data", 1)
    name = _leaf_name(path)
    pstr = _path_str(path)
    rank = len(shape)

    if dp_only:
        # NOTE: recurrent weights (r_z/r_i/r_f, gate mats) are NOT
        # replicated here — a replicated weight closed over a lax.scan
        # gets its gradient psum'd on EVERY step (measured: 3x
        # f32[4,256,256] all-reduce x 49k steps on xlstm). Sharding them
        # makes the forward all-gather loop-invariant (hoisted) and the
        # backward reduce once.
        if rank <= 1 or _is_scale(path):
            return P(*([None] * rank))
        axes = tuple(sizes)
        full = 1
        for v in sizes.values():
            full *= v
        parts = [None] * rank
        if shape[-1] % full == 0:
            parts[-1] = axes if len(axes) > 1 else axes[0]
        elif shape[-2] % full == 0:
            parts[-2] = axes if len(axes) > 1 else axes[0]
        elif shape[-1] % dp == 0:
            parts[-1] = "data"
        elif shape[-2] % dp == 0:
            parts[-2] = "data"
        return P(*parts)

    def tp_ok(n):
        return n % tp == 0

    def dp_ok(n):
        return n % dp == 0

    if rank <= 1 or name in REPLICATED_NAMES:
        return P(*([None] * rank))

    if _is_scale(path):
        # (..., 1, N) per-channel scales: shard N like the weight out-dim
        parts = [None] * rank
        owner = _leaf_name(path[:-1])
        if owner in COL_PARALLEL and tp_ok(shape[-1]):
            parts[-1] = "model"
        return P(*parts)

    # embeddings
    if name == "table":
        v, d = shape[-2], shape[-1]
        if tp_ok(v):
            return P(*([None] * (rank - 2)), "model",
                     "data" if dp_ok(d) else None)
        return P(*([None] * (rank - 2)), "data" if dp_ok(v) else None,
                 "model" if tp_ok(d) else None)
    if name == "w_out":
        d, v = shape[-2], shape[-1]
        return P(*([None] * (rank - 2)), "data" if dp_ok(d) else None,
                 "model" if tp_ok(v) else None)
    if name == "w_in":  # frontend projector (small)
        return P(*([None] * rank))

    # MoE experts: (..., E, K, N) — EP on E when divisible, else TP inside
    if "experts" in pstr:
        e_idx = rank - 3
        parts = [None] * rank
        e = shape[e_idx]
        if tp_ok(e):
            parts[e_idx] = "model"
            # FSDP the larger matrix dim
            if dp_ok(shape[-2]):
                parts[-2] = "data"
            elif dp_ok(shape[-1]):
                parts[-1] = "data"
        else:
            # TP inside the expert: out-dim for wg/wu, in-dim for wd
            if name in ROW_PARALLEL:
                if tp_ok(shape[-2]):
                    parts[-2] = "model"
                if dp_ok(shape[-1]):
                    parts[-1] = "data"
            else:
                if tp_ok(shape[-1]):
                    parts[-1] = "model"
                if dp_ok(shape[-2]):
                    parts[-2] = "data"
        return P(*parts)

    if name in ROW_PARALLEL:
        parts = [None] * rank
        if tp_ok(shape[-2]):
            parts[-2] = "model"
        if dp_ok(shape[-1]):
            parts[-1] = "data"
        return P(*parts)
    if name in COL_PARALLEL or name.startswith("w"):
        parts = [None] * rank
        if tp_ok(shape[-1]):
            parts[-1] = "model"
        if dp_ok(shape[-2]):
            parts[-2] = "data"
        return P(*parts)
    return P(*([None] * rank))


def params_pspecs(params, cfg: ArchConfig, mesh: Mesh,
                  dp_only: bool = False):
    """PartitionSpec pytree matching `params` (works on ShapeDtypeStructs)."""
    sizes = mesh_axis_sizes(mesh)
    flat = jax.tree_util.tree_flatten_with_path(params)[0]
    treedef = jax.tree_util.tree_structure(params)
    specs = [param_spec(kp, tuple(leaf.shape), cfg, sizes, dp_only)
             for kp, leaf in flat]
    return jax.tree_util.tree_unflatten(treedef, specs)


def params_shardings(params, cfg: ArchConfig, mesh: Mesh):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s),
        params_pspecs(params, cfg, mesh),
        is_leaf=lambda x: isinstance(x, P))


def cache_pspecs(caches, cfg: ArchConfig, mesh: Mesh,
                 long_context: bool = False):
    """KV-cache / recurrent-state specs.

    k/v: (G?, B, S, H, D) — batch over DP axes (or seq over "data" for
    long-context SP), heads over "model" when divisible.
    """
    sizes = mesh_axis_sizes(mesh)
    tp = sizes.get("model", 1)
    batch_axes = tuple(a for a in ("pod", "data") if a in sizes)
    if long_context:
        b_rule = None                     # batch=1: replicated
        s_rule = batch_axes or None       # SP over every DP axis
        if isinstance(s_rule, tuple) and len(s_rule) == 1:
            s_rule = s_rule[0]
    else:
        b_rule = batch_axes or None
        s_rule = None
    if isinstance(b_rule, tuple) and len(b_rule) == 1:
        b_rule = b_rule[0]

    def spec_for(path, leaf):
        name = _leaf_name(path)
        rank = len(leaf.shape)
        lead = [None] * (rank - 4) if rank >= 4 else []
        if name in ("k", "v", "k_data", "v_data"):
            h = leaf.shape[-2]
            s = leaf.shape[-3]
            h_rule = "model" if (h % tp == 0) else None
            kv_s_rule = s_rule
            if h_rule is None and s_rule is None and s % tp == 0:
                # flash-decoding style: kv_heads not TP-divisible -> shard
                # the KV sequence dim over "model"; the softmax combine
                # across seq shards is a tiny (B,H[,D]) all-reduce instead
                # of an all-gather of the whole cache (§Perf iteration D)
                kv_s_rule = "model"
            return P(*lead, b_rule, kv_s_rule, h_rule, None)
        if name in ("k_scl", "v_scl"):
            lead = [None] * (rank - 3)
            h = leaf.shape[-1]
            s = leaf.shape[-2]
            kv_s_rule = s_rule
            if (h % tp) and s_rule is None and s % tp == 0:
                kv_s_rule = "model"
            return P(*lead, b_rule, kv_s_rule, None)
        # recurrent states: (G?, B, ...) — batch-shard dim after lead
        parts = [None] * rank
        # find batch dim: first non-group dim
        bdim = rank - len(leaf.shape[-(rank):])  # 0
        # heuristics: states are (G, B, ...) inside scan stacks or (B, ...)
        parts_idx = 1 if rank >= 2 and "blocks" in _path_str(path) else 0
        parts[parts_idx] = b_rule if not long_context else None
        return P(*parts)

    flat = jax.tree_util.tree_flatten_with_path(caches)[0]
    treedef = jax.tree_util.tree_structure(caches)
    specs = [spec_for(kp, leaf) for kp, leaf in flat]
    return jax.tree_util.tree_unflatten(treedef, specs)
