"""OliVe PTQ quantization framework (paper §3.4).

Scale-factor selection: MSE minimisation seeded at the 3σ point. The initial
scale maps 3σ to the normal-value max; candidates sweep a geometric range
around it and the OVP round-trip MSE picks the winner. Per-tensor (paper's
setting) and per-channel granularities are supported.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from .datatypes import ABFLOAT_FOR_NORMAL, NORMAL_MAX, AbfloatSpec
from .ovp import (QuantizedTensor, ovp_dequantize, ovp_fake_quant,
                  ovp_quantize)


def sigma_init_scale(x: jax.Array, normal_dtype: str, k_sigma: float = 3.0,
                     axes=None) -> jax.Array:
    """3σ rule initial scale (§3.4): k·σ maps to the normal max."""
    nmax = float(NORMAL_MAX[normal_dtype])
    sigma = jnp.std(x, axis=axes, keepdims=axes is not None)
    return jnp.maximum(k_sigma * sigma / nmax, 1e-8)


@partial(jax.jit, static_argnames=("normal_dtype", "spec", "n_grid",
                                   "lo", "hi", "pair_axis"))
def ovp_search_scale(x: jax.Array, normal_dtype: str = "int4",
                     spec: Optional[AbfloatSpec] = None, n_grid: int = 24,
                     lo: float = 0.35, hi: float = 2.2,
                     pair_axis: int = -1) -> jax.Array:
    """Per-tensor MSE grid search around the 3σ init. Returns scalar scale."""
    s0 = sigma_init_scale(x, normal_dtype)
    # the grid always contains s0 itself, so the search can never lose to
    # the 3σ init (hypothesis found the counterexample when it didn't)
    grid = jnp.concatenate([s0 * jnp.geomspace(lo, hi, n_grid - 1),
                            s0[None]])

    def mse_at(s):
        xh = ovp_fake_quant(x, s, normal_dtype, spec, pair_axis)
        return jnp.mean((xh - x.astype(jnp.float32)) ** 2)

    mses = jax.lax.map(mse_at, grid)  # sequential: keeps peak memory flat
    return grid[jnp.argmin(mses)]


def ovp_search_scale_per_channel(x: jax.Array, channel_axis: int,
                                 normal_dtype: str = "int4",
                                 spec: Optional[AbfloatSpec] = None,
                                 n_grid: int = 16, lo: float = 0.35,
                                 hi: float = 2.2) -> jax.Array:
    """Per-channel MSE search. Pairing runs along the *other* (last) axis.

    Returns scale shaped for broadcasting: (..., C, 1) against x moved so
    channel_axis is -2 — callers should use `quantize(...)` below which
    handles the bookkeeping.
    """
    xm = jnp.moveaxis(x, channel_axis, 0)          # (C, rest...)
    flat = xm.reshape(xm.shape[0], -1)             # (C, K)

    def one(row):
        return ovp_search_scale(row, normal_dtype, spec, n_grid, lo, hi)

    return jax.lax.map(one, flat)                  # (C,)


@dataclasses.dataclass(frozen=True)
class QuantSpec:
    """How to quantize one tensor."""
    normal_dtype: str = "int4"          # int4 | flint4 | int8
    granularity: str = "tensor"         # tensor | channel
    channel_axis: int = -1
    pair_axis: int = -1                 # reduction axis for matmul operands
    n_grid: int = 24
    abfloat: Optional[AbfloatSpec] = None

    @property
    def bits(self) -> int:
        return 8 if self.normal_dtype == "int8" else 4


def quantize(x: jax.Array, spec: QuantSpec = QuantSpec()) -> QuantizedTensor:
    """Full OliVe PTQ for one tensor: scale search + OVP encode + pack."""
    if spec.granularity == "tensor":
        s = ovp_search_scale(x, spec.normal_dtype, spec.abfloat, spec.n_grid)
        return ovp_quantize(x, s, spec.normal_dtype, spec.abfloat,
                            spec.pair_axis)
    # per-channel: scales along channel_axis, pairing along pair_axis
    ca = spec.channel_axis % x.ndim
    pa = spec.pair_axis % x.ndim
    if ca == pa:
        raise ValueError("channel_axis must differ from pair_axis")
    s = ovp_search_scale_per_channel(x, ca, spec.normal_dtype, spec.abfloat,
                                     max(8, spec.n_grid // 2))
    shape = [1] * x.ndim
    shape[ca] = x.shape[ca]
    s = s.reshape(shape)
    return ovp_quantize(x, s, spec.normal_dtype, spec.abfloat, spec.pair_axis)


def dequantize(qt: QuantizedTensor, dtype=jnp.float32) -> jax.Array:
    return ovp_dequantize(qt, dtype=dtype)


def fake_quant_ste(x: jax.Array, scale: jax.Array,
                   normal_dtype: str = "int4",
                   spec: Optional[AbfloatSpec] = None,
                   pair_axis: int = -1) -> jax.Array:
    """QAT fake-quant with straight-through estimator (§3.4, STE [5])."""
    xh = ovp_fake_quant(x, scale, normal_dtype, spec, pair_axis)
    return x + jax.lax.stop_gradient(xh - x)


def quantization_error(x: jax.Array, spec: QuantSpec = QuantSpec()) -> dict:
    """MSE / SQNR diagnostics for one tensor under full OliVe PTQ."""
    qt = quantize(x, spec)
    xh = dequantize(qt)
    err = xh - x.astype(jnp.float32)
    mse = jnp.mean(err ** 2)
    power = jnp.mean(x.astype(jnp.float32) ** 2)
    sqnr = 10.0 * jnp.log10(jnp.maximum(power, 1e-30) /
                            jnp.maximum(mse, 1e-30))
    return {"mse": float(mse), "sqnr_db": float(sqnr),
            "scale": jnp.asarray(qt.scale),
            "bytes": qt.nbytes(),
            "fp32_bytes": int(x.size * 4)}
