"""Quantization policy: which tensors get quantized, how, and on what backend.

Two levels of API:

`QuantPolicy` — the per-site decision record: method, bit widths, dtypes,
granularity, backend, compute dtype. One frozen dataclass.

`PolicyProgram` — a *site-addressed program*: an ordered list of
(glob pattern -> QuantPolicy) rules matched against pytree-path site names
(the same "/"-joined addresses `quantize_params` walks and `ActTape`
records), plus a default. `resolve(site)` returns the policy for one site;
first matching rule wins. Mixed precision (first/last blocks W8, middle
W4, per-layer kv_bits, per-site backends) is a program; the old global
booleans (`quantize_attn`, `quantize_ffn`, ...) compile into an equivalent
program via `PolicyProgram.from_policy`, so every legacy
`QuantPolicy(quantize_attn=..., ...)` call site keeps working unchanged —
`QuantPolicy.resolve(site)` delegates to its compiled program.

Site grammar: `fnmatch` globs, matched case-insensitively against the full
path; `*` crosses `/` separators. Canonical addresses (see
docs/policies.md): `layers/<i>/attn/wq`, `layers/<i>/mlp/wg`,
`layers/<i>/attn/kv` (KV cache), `blocks/<j>/...` (scan-stacked layouts),
`embed/table`, `lm_head/w_out`, `moe/experts/wg`, `moe/router/w_gate`.
"""
from __future__ import annotations

import dataclasses
import fnmatch
import functools
from typing import List, Optional, Sequence, Tuple, Union


@dataclasses.dataclass(frozen=True)
class QuantPolicy:
    # "none" -> full precision; "olive" -> OVP (the paper);
    # "int" -> uniform int baseline; "ant" -> ANT adaptive-type baseline.
    method: str = "none"

    # weight quantization
    wbits: int = 4                      # 4 or 8
    w_normal_dtype: str = "int4"        # int4 | flint4 | int8
    w_granularity: str = "channel"      # tensor | channel

    # activation quantization (0 = keep activations in compute dtype)
    abits: int = 0
    a_normal_dtype: str = "int4"
    act_scale_mode: str = "dynamic"     # dynamic (3σ rule) | static (calibrated)
    # calibrated per-site activation scale (a plain float, so the resolved
    # policy stays hashable). Populated per site by
    # `calibration.apply_calibration`'s resolve-time overlay
    # (`CalibratedProgram`); consumed by `backends.base.resolve_act_scale`
    # and the static Pallas prologue (as a (1, 1) scalar kernel operand).
    # None under act_scale_mode="static" means "not calibrated yet" — the
    # serving engine rejects such sites up front (MissingStaticScaleError).
    static_act_scale: Optional[float] = None

    # legacy coarse layer selection (compiled into a PolicyProgram by
    # `from_policy`; new code writes site rules instead)
    quantize_attn: bool = True
    quantize_ffn: bool = True
    quantize_embed: bool = False
    quantize_router: bool = False       # MoE router stays fp32

    # beyond-paper: OVP-quantized KV cache (0 = off)
    kv_bits: int = 0

    # QAT: raw weights get STE fake-quant in the forward pass; off means
    # raw weights under an enabled policy run full precision (PTQ serving
    # where quantize_params already converted the eligible ones)
    qat: bool = False

    # execution backend for quantized matmuls: any name registered in
    # `repro.backends` (xla | pallas | pallas_interpret | reference | ...)
    backend: str = "xla"

    # compute dtype for the dequantized matmul on the MXU
    compute_dtype: str = "bfloat16"

    @property
    def enabled(self) -> bool:
        return self.method != "none"

    def normal_dtype_for_bits(self, bits: int) -> str:
        return "int8" if bits == 8 else self.w_normal_dtype

    # ----------------------------------------------------- program protocol
    # QuantPolicy and PolicyProgram share this surface so every consumer
    # (models, quantize_params, the serving engine) takes either.
    def resolve(self, site: str) -> "QuantPolicy":
        """Per-site policy under the legacy boolean flags."""
        return _compiled(self).resolve(site)

    def off(self) -> "QuantPolicy":
        """Disabled variant: same compute dtype / backend, no quantization."""
        return dataclasses.replace(self, method="none")

    def with_backend(self, name: str) -> "QuantPolicy":
        return self if name == self.backend \
            else dataclasses.replace(self, backend=name)

    def replace_all(self, **kw) -> "QuantPolicy":
        return dataclasses.replace(self, **kw)

    def backends(self) -> frozenset:
        return frozenset((self.backend,))

    def as_program(self) -> "PolicyProgram":
        return _compiled(self)


@dataclasses.dataclass(frozen=True)
class Rule:
    """One pattern -> policy entry of a PolicyProgram.

    `origin` tags where the rule came from: "" for authored rules
    (presets, `with_rules`, CLI), "compat" for the legacy-flag synonym
    fan compiled by `from_policy` (those patterns deliberately cover
    naming conventions — `*ffn*`, `*attention*` — that no current model
    uses, so `repro.analysis`'s dead-rule check exempts them).
    """
    pattern: str
    policy: QuantPolicy
    origin: str = ""

    def matches(self, site: str) -> bool:
        return fnmatch.fnmatchcase(site.lower(), self.pattern.lower())


def _as_rule(r) -> Rule:
    if isinstance(r, Rule):
        return r
    pattern, policy = r                 # (pattern, policy) tuples accepted
    return Rule(pattern, policy)


# Probe addresses used to decide whether a program distinguishes layers —
# one representative site per block family plus the KV-cache address.
_LAYER_PROBES = ("attn/wq", "attn/wk", "attn/wv", "attn/wo", "attn/kv",
                 "mlp/wg", "mlp/wu", "mlp/wd", "mlp/wi",
                 "moe/experts/wg", "moe/experts/wd", "moe/router/w_gate",
                 "mlstm/wq", "mlstm/w_up", "rec/wx", "slstm/wz")


@dataclasses.dataclass(frozen=True)
class PolicyProgram:
    """Ordered (pattern -> QuantPolicy) rules + a default; first match wins."""
    rules: Tuple[Rule, ...] = ()
    default: QuantPolicy = QuantPolicy()
    name: str = ""

    def __post_init__(self):
        object.__setattr__(self, "rules",
                           tuple(_as_rule(r) for r in self.rules))

    # ---------------------------------------------------------- resolution
    def resolve(self, site: str) -> QuantPolicy:
        return _program_resolve(self, site)

    # ---------------------------------------------------------- protocol
    @property
    def enabled(self) -> bool:
        return self.default.enabled or any(r.policy.enabled
                                           for r in self.rules)

    @property
    def compute_dtype(self) -> str:
        return self.default.compute_dtype

    @property
    def backend(self) -> str:
        return self.default.backend

    @property
    def kv_bits(self) -> int:
        """Largest kv_bits any rule can resolve to (capacity planning /
        logging; cache construction resolves per layer instead)."""
        return max([self.default.kv_bits]
                   + [r.policy.kv_bits for r in self.rules])

    @property
    def qat(self) -> bool:
        return self.default.qat or any(r.policy.qat for r in self.rules)

    def backends(self) -> frozenset:
        return frozenset([self.default.backend]
                         + [r.policy.backend for r in self.rules])

    def off(self) -> "PolicyProgram":
        return self.replace_all(method="none")

    def with_backend(self, name: str) -> "PolicyProgram":
        return self.replace_all(backend=name)

    def as_program(self) -> "PolicyProgram":
        return self

    def replace_all(self, **kw) -> "PolicyProgram":
        """`dataclasses.replace` applied to every rule policy + default."""
        return PolicyProgram(
            rules=tuple(Rule(r.pattern, dataclasses.replace(r.policy, **kw),
                             origin=r.origin)
                        for r in self.rules),
            default=dataclasses.replace(self.default, **kw),
            name=self.name)

    def with_rules(self, rules: Sequence, front: bool = True
                   ) -> "PolicyProgram":
        """New program with extra rules prepended (they take precedence)
        or appended."""
        extra = tuple(_as_rule(r) for r in rules)
        new = extra + self.rules if front else self.rules + extra
        return PolicyProgram(rules=new, default=self.default, name=self.name)

    # ------------------------------------------------------------- layout
    def varies_across_layers(self, n_layers: int) -> bool:
        """True when any two layers resolve differently at a probe site."""
        if n_layers <= 1:
            return False
        sig0 = tuple(self.resolve(f"layers/0/{s}") for s in _LAYER_PROBES)
        return any(tuple(self.resolve(f"layers/{i}/{s}")
                         for s in _LAYER_PROBES) != sig0
                   for i in range(1, n_layers))

    def addresses_layers(self, n_layers: int) -> bool:
        """Should the model unroll its layer stack so `layers/<i>/...`
        addresses exist in the param tree?

        True when the program resolves differently across layers at a
        probe site, OR when any rule pattern references the `layers/`
        grammar at all — a rule written against `layers/...` can only
        ever match on the unrolled layout, so keeping the scan would
        silently drop it (even layer-uniform ones like
        ``layers/*/attn/wq``, which no probe can distinguish)."""
        if any("layers/" in r.pattern.lower() for r in self.rules):
            return True
        return self.varies_across_layers(n_layers)

    # -------------------------------------------------------------- compat
    @classmethod
    def from_policy(cls, policy: QuantPolicy,
                    name: str = "") -> "PolicyProgram":
        """Compile the legacy boolean flags into an equivalent program.

        Mirrors the seed `eligible()` heuristic exactly: embed/lm_head
        first, then router, then attention substrings, then FFN substrings,
        with the FFN flag as the default bucket.
        """
        on = policy
        off = policy.off()
        a = on if policy.quantize_attn else off
        f = on if policy.quantize_ffn else off
        e = on if policy.quantize_embed else off
        r = on if policy.quantize_router else off
        rules = tuple(
            Rule(p, pol, origin="compat") for p, pol in (
                ("*embed*", e), ("*lm_head*", e),
                ("*router*", r),
                ("*attn*", a), ("*attention*", a),
                ("*wq*", a), ("*wk*", a), ("*wv*", a),
                ("*wo*", a),
                ("*mlp*", f), ("*ffn*", f), ("*expert*", f),
                ("*wi*", f), ("*wu*", f), ("*wg*", f),
                ("*wd*", f),
            ))
        return cls(rules=rules, default=f, name=name or "compat")


@functools.lru_cache(maxsize=256)
def _compiled(policy: QuantPolicy) -> PolicyProgram:
    return PolicyProgram.from_policy(policy)


@functools.lru_cache(maxsize=65536)
def _program_resolve(program: PolicyProgram, site: str) -> QuantPolicy:
    for rule in program.rules:
        if rule.matches(site):
            return rule.policy
    return program.default


PolicyLike = Union[QuantPolicy, PolicyProgram]


def as_program(policy: PolicyLike) -> PolicyProgram:
    """Normalize either policy form to a PolicyProgram."""
    return policy.as_program()


def resolve(policy: PolicyLike, site: str) -> QuantPolicy:
    """The single resolution entry point consumers call per site."""
    return policy.resolve(site)


# ==========================================================================
# Convenience presets — flat policies (legacy) and policy programs
# ==========================================================================
FP = QuantPolicy(method="none")
OLIVE_W4A4 = QuantPolicy(method="olive", wbits=4, abits=4)
OLIVE_W4 = QuantPolicy(method="olive", wbits=4, abits=0)
OLIVE_W8A8 = QuantPolicy(method="olive", wbits=8, abits=8,
                         w_normal_dtype="int8", a_normal_dtype="int8")
INT8 = QuantPolicy(method="int", wbits=8, abits=8, w_normal_dtype="int8")
INT4 = QuantPolicy(method="int", wbits=4, abits=4)
ANT4 = QuantPolicy(method="ant", wbits=4, abits=4)
OLIVE_SERVE = dataclasses.replace(OLIVE_W4A4, kv_bits=4)

PRESETS = {
    "fp": FP, "olive_w4a4": OLIVE_W4A4, "olive_w4": OLIVE_W4,
    "olive_w8a8": OLIVE_W8A8, "int8": INT8, "int4": INT4, "ant4": ANT4,
    "olive_serve": OLIVE_SERVE,
}


def olive_mixed_w48(n_layers: int) -> PolicyProgram:
    """First/last layer W8A8, everything between W4A4 — the paper's
    "keep sensitive layers high precision" at layer granularity."""
    base = PolicyProgram.from_policy(OLIVE_W4A4, name="olive_mixed_w48")
    return base.with_rules([
        ("layers/0/*", OLIVE_W8A8),
        (f"layers/{max(n_layers - 1, 0)}/*", OLIVE_W8A8),
    ])


def olive_owq_style(n_layers: int = 0) -> PolicyProgram:
    """OWQ-style: the sensitive attention q/k projections (RoPE feeds
    them straight into the score path) stay W8, the rest runs W4."""
    base = PolicyProgram.from_policy(OLIVE_W4A4, name="olive_owq_style")
    return base.with_rules([
        ("*attn/wq*", OLIVE_W8A8),
        ("*attn/wk*", OLIVE_W8A8),
    ])


PROGRAM_PRESETS = {
    "olive_mixed_w48": olive_mixed_w48,
    "olive_owq_style": olive_owq_style,
}


def get_policy(name: Optional[str]) -> QuantPolicy:
    if name is None:
        return FP
    if name not in PRESETS:
        raise KeyError(f"unknown quant policy {name!r}; "
                       f"options: {sorted(PRESETS)}")
    return PRESETS[name]


def get_program(name: Optional[str], n_layers: int = 0) -> PolicyProgram:
    """Program for any preset name — flat presets compile via from_policy,
    program presets (layer-addressed) take the target's layer count."""
    if name in PROGRAM_PRESETS:
        return PROGRAM_PRESETS[name](n_layers)
    return PolicyProgram.from_policy(get_policy(name), name=name or "fp")


def parse_rules(spec: str) -> List[Rule]:
    """Parse a CLI rule list: ``pattern=preset[,pattern=preset...]``.

    Presets name `PRESETS` entries (``fp`` disables a site). Example:
    ``--policy-rules "layers/0/*=olive_w8a8,*mlp*=olive_w4a4"``.
    """
    rules = []
    for tok in spec.split(","):
        tok = tok.strip()
        if not tok:
            continue
        if "=" not in tok:
            raise ValueError(f"bad rule {tok!r}: expected pattern=preset")
        pattern, preset = tok.split("=", 1)
        rules.append(Rule(pattern.strip(), get_policy(preset.strip())))
    return rules
