"""Quantization policy: which tensors get quantized, how, and on what backend.

This is the framework-level switch that makes OliVe a first-class feature:
every linear in the model zoo routes through `repro.core.qlinear` and
consults a `QuantPolicy`.
"""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class QuantPolicy:
    # "none" -> full precision; "olive" -> OVP (the paper);
    # "int" -> uniform int baseline; "ant" -> ANT adaptive-type baseline.
    method: str = "none"

    # weight quantization
    wbits: int = 4                      # 4 or 8
    w_normal_dtype: str = "int4"        # int4 | flint4 | int8
    w_granularity: str = "channel"      # tensor | channel

    # activation quantization (0 = keep activations in compute dtype)
    abits: int = 0
    a_normal_dtype: str = "int4"
    act_scale_mode: str = "dynamic"     # dynamic (3σ rule) | static (calibrated)

    # layer selection (paper keeps sensitive layers high precision)
    quantize_attn: bool = True
    quantize_ffn: bool = True
    quantize_embed: bool = False
    quantize_router: bool = False       # MoE router stays fp32

    # beyond-paper: OVP-quantized KV cache (0 = off)
    kv_bits: int = 0

    # QAT: raw weights get STE fake-quant in the forward pass; off means
    # raw weights under an enabled policy run full precision (PTQ serving
    # where quantize_params already converted the eligible ones)
    qat: bool = False

    # execution backend for quantized matmuls: any name registered in
    # `repro.backends` (xla | pallas | pallas_interpret | reference | ...)
    backend: str = "xla"

    # compute dtype for the dequantized matmul on the MXU
    compute_dtype: str = "bfloat16"

    @property
    def enabled(self) -> bool:
        return self.method != "none"

    def normal_dtype_for_bits(self, bits: int) -> str:
        return "int8" if bits == 8 else self.w_normal_dtype


# Convenience presets
FP = QuantPolicy(method="none")
OLIVE_W4A4 = QuantPolicy(method="olive", wbits=4, abits=4)
OLIVE_W4 = QuantPolicy(method="olive", wbits=4, abits=0)
OLIVE_W8A8 = QuantPolicy(method="olive", wbits=8, abits=8,
                         w_normal_dtype="int8", a_normal_dtype="int8")
INT8 = QuantPolicy(method="int", wbits=8, abits=8, w_normal_dtype="int8")
INT4 = QuantPolicy(method="int", wbits=4, abits=4)
ANT4 = QuantPolicy(method="ant", wbits=4, abits=4)
OLIVE_SERVE = dataclasses.replace(OLIVE_W4A4, kv_bits=4)

PRESETS = {
    "fp": FP, "olive_w4a4": OLIVE_W4A4, "olive_w4": OLIVE_W4,
    "olive_w8a8": OLIVE_W8A8, "int8": INT8, "int4": INT4, "ant4": ANT4,
    "olive_serve": OLIVE_SERVE,
}


def get_policy(name: Optional[str]) -> QuantPolicy:
    if name is None:
        return FP
    if name not in PRESETS:
        raise KeyError(f"unknown quant policy {name!r}; "
                       f"options: {sorted(PRESETS)}")
    return PRESETS[name]
