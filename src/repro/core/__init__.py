"""OliVe core: outlier-victim pair quantization (ISCA'23) in JAX."""
from .datatypes import (ABFLOAT_FOR_NORMAL, E2M1_INT4, E2M1_FLINT4,
                        E4M3_INT8, FLINT4_LUT, ID4, ID8, NORMAL_MAX,
                        AbfloatSpec, abfloat_decode, abfloat_encode,
                        abfloat_nearest, abfloat_spec_for, default_bias,
                        flint4_decode, flint4_encode, normal_decode,
                        normal_encode)
from .ovp import (QuantizedTensor, ovp_decode_codes, ovp_dequantize,
                  ovp_encode_codes, ovp_fake_quant, ovp_quantize, pack4,
                  pair_statistics, unpack4)
from .policy import (PRESETS, PROGRAM_PRESETS, PolicyProgram, QuantPolicy,
                     Rule, as_program, get_policy, get_program, parse_rules,
                     resolve)
from .quantizer import (QuantSpec, dequantize, fake_quant_ste,
                        ovp_search_scale, ovp_search_scale_per_channel,
                        quantization_error, quantize, sigma_init_scale)
from .qlinear import (linear, qmatmul, quantize_activation, quantize_params,
                      quantize_weight)
from .calibration import (ActTape, CalibratedProgram, CalibrationArtifact,
                          MissingStaticScaleError, apply_calibration,
                          auto_mixed, calibrate_activation_scales,
                          calibrate_model, collecting_activations,
                          record_weights, run_calibration, site_sensitivity,
                          static_scale_misses, uses_static_scales)
