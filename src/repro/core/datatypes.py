"""Numeric data types for OliVe OVP quantization (paper §3.2–3.3).

All encoders/decoders operate on *scaled* magnitudes (value / scale) and on
integer nibble/byte codes, fully vectorised in jnp (branch-free: the paper's
hardware decoders become `where`-trees that lower to VPU selects on TPU).

Code conventions
----------------
4-bit codes live in uint8 arrays with values 0..15 (one nibble per element,
packing into bytes happens in `repro.core.ovp`). 8-bit codes use the full byte.

Normal data types (Table 3)
  int4    values 0,±1..±7         identifier 1000b  (-8 removed)
  flint4  values 0,±1..±4,±6,±8,±16 identifier 1000b (-0, unused by design)
  int8    values 0,±1..±127       identifier 10000000b (-128 removed)

Outlier data type: abfloat (§3.3), fixed-point float
  value = sign × (2^mb + mantissa) << (exponent + bias)
  4-bit: E2M1 (paper-selected, Fig. 5);  8-bit: E4M3.
  Codes x000...0 (±0) are disabled for outliers so the victim identifier
  cannot be forged; consequently min magnitude is (2^mb + 1) << bias.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

# --------------------------------------------------------------------------
# Identifiers (victim markers)
# --------------------------------------------------------------------------
ID4 = 0x8          # 1000b
ID8 = 0x80         # 10000000b

# Normal-value max magnitude (defines the outlier threshold T = nmax, §3.4)
NORMAL_MAX = {"int4": 7, "flint4": 16, "int8": 127}

# flint4 magnitude LUT (ANT data type, Table 3): index = low 3 bits of code.
FLINT4_LUT = np.array([0, 1, 2, 3, 4, 6, 8, 16], dtype=np.float32)


# --------------------------------------------------------------------------
# abfloat spec
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class AbfloatSpec:
    """sign × (2^mb + m) << (e + bias); total bits = 1 + ebits + mb."""
    ebits: int
    mb: int
    bias: int

    @property
    def bits(self) -> int:
        return 1 + self.ebits + self.mb

    @property
    def min_mag(self) -> int:
        # code bits e=0, m=1 (e=0,m=0 disabled — identifier/zero conflict)
        return ((1 << self.mb) + 1) << self.bias

    @property
    def max_mag(self) -> int:
        base = (1 << (self.mb + 1)) - 1
        mag = base << ((1 << self.ebits) - 1 + self.bias)
        # §4.5: clip outliers at 2^15 so int32 accumulators cannot overflow.
        return min(mag, 1 << 15)

    def magnitudes(self) -> np.ndarray:
        """All representable magnitudes (sorted, for tests / nearest-mode)."""
        out = []
        for e in range(1 << self.ebits):
            for m in range(1 << self.mb):
                if e == 0 and m == 0:
                    continue  # disabled code
                out.append(min((((1 << self.mb) + m) << (e + self.bias)),
                               1 << 15))
        return np.unique(np.array(out, dtype=np.float32))


def default_bias(normal_dtype: str, mb: int) -> int:
    """Adaptive bias (§3.3): smallest b with min outlier mag > normal max."""
    t = NORMAL_MAX[normal_dtype]
    b = 0
    while (((1 << mb) + 1) << b) <= t:
        b += 1
    return b


# Paper's chosen configurations (§3.3): E2M1 for 4-bit, E4M3 for 8-bit.
E2M1_INT4 = AbfloatSpec(ebits=2, mb=1, bias=default_bias("int4", 1))      # bias=2, {12..96}
E2M1_FLINT4 = AbfloatSpec(ebits=2, mb=1, bias=default_bias("flint4", 1))  # bias=3, {24..192}
E4M3_INT8 = AbfloatSpec(ebits=4, mb=3, bias=default_bias("int8", 3))      # bias=4, {144..32768}

ABFLOAT_FOR_NORMAL = {
    "int4": E2M1_INT4,
    "flint4": E2M1_FLINT4,
    "int8": E4M3_INT8,
}


def abfloat_spec_for(normal_dtype: str, ebits: int | None = None,
                     mb: int | None = None) -> AbfloatSpec:
    """Spec for a normal dtype; ebits/mb override for the Fig. 5 sweep."""
    if ebits is None and mb is None:
        return ABFLOAT_FOR_NORMAL[normal_dtype]
    ebits = 2 if ebits is None else ebits
    mb = 1 if mb is None else mb
    return AbfloatSpec(ebits=ebits, mb=mb, bias=default_bias(normal_dtype, mb))


# --------------------------------------------------------------------------
# Normal-value encode / decode (nibble or byte codes)
# --------------------------------------------------------------------------
def int_normal_encode(u: jax.Array, bits: int) -> jax.Array:
    """Scaled value -> two's-complement code, identifier excluded.

    u is value/scale. Output uint8 code in [0, 2^bits) with the pattern
    100..0b never produced (range clipped to ±(2^(bits-1)-1)).
    """
    nmax = (1 << (bits - 1)) - 1
    q = jnp.clip(jnp.round(u), -nmax, nmax).astype(jnp.int32)
    mask = (1 << bits) - 1
    return (q & mask).astype(jnp.uint8)


def int_normal_decode(code: jax.Array, bits: int) -> jax.Array:
    """Code -> scaled value. The identifier decodes to 0 (victim)."""
    c = code.astype(jnp.int32)
    half = 1 << (bits - 1)
    v = jnp.where(c >= half, c - (1 << bits), c)
    return jnp.where(c == half, 0, v).astype(jnp.float32)


def flint4_encode(u: jax.Array) -> jax.Array:
    """Nearest flint4 value (ANT LUT); code = sign<<3 | idx, never 1000b."""
    lut = jnp.asarray(FLINT4_LUT)
    mags = jnp.abs(u)
    # nearest index among the 8 magnitudes (ties -> smaller index)
    d = jnp.abs(mags[..., None] - lut)
    idx = jnp.argmin(d, axis=-1).astype(jnp.int32)
    neg = (u < 0) & (idx > 0)  # -0 is the identifier; encode 0 as +0
    return ((neg.astype(jnp.int32) << 3) | idx).astype(jnp.uint8)


def flint4_decode(code: jax.Array) -> jax.Array:
    lut = jnp.asarray(FLINT4_LUT)
    c = code.astype(jnp.int32)
    mag = lut[c & 0x7]
    sign = jnp.where((c >> 3) & 1 == 1, -1.0, 1.0)
    v = sign * mag
    # 1000b (-0) is the identifier -> victim -> 0 (already ±0; keep exact +0)
    return jnp.where(c == ID4, 0.0, v).astype(jnp.float32)


def normal_encode(u: jax.Array, normal_dtype: str) -> jax.Array:
    if normal_dtype == "int4":
        return int_normal_encode(u, 4)
    if normal_dtype == "flint4":
        return flint4_encode(u)
    if normal_dtype == "int8":
        return int_normal_encode(u, 8)
    raise ValueError(f"unknown normal dtype {normal_dtype!r}")


def normal_decode(code: jax.Array, normal_dtype: str) -> jax.Array:
    if normal_dtype == "int4":
        return int_normal_decode(code, 4)
    if normal_dtype == "flint4":
        return flint4_decode(code)
    if normal_dtype == "int8":
        return int_normal_decode(code, 8)
    raise ValueError(f"unknown normal dtype {normal_dtype!r}")


# --------------------------------------------------------------------------
# abfloat encode / decode (Algorithm 2 / Fig. 7)
# --------------------------------------------------------------------------
def abfloat_encode(u: jax.Array, spec: AbfloatSpec) -> jax.Array:
    """Scaled value -> abfloat code (Algorithm 2, vectorised).

    Magnitude is clamped to [min_mag, max_mag]; the disabled ±0 codes are
    never produced, so the output cannot collide with the victim identifier.
    """
    sign = (u < 0).astype(jnp.int32)
    mag = jnp.clip(jnp.abs(u), spec.min_mag, spec.max_mag).astype(jnp.float32)
    # exp = floor(log2(|e|)) - mb   (Algorithm 2 line 2, mb generalised)
    exp = jnp.floor(jnp.log2(mag)).astype(jnp.int32) - spec.mb
    base = jnp.round(mag / jnp.exp2(exp.astype(jnp.float32))).astype(jnp.int32)
    # overflow of the mantissa window: base == 2^(mb+1) -> bump exponent
    ovf = base == (1 << (spec.mb + 1))
    exp = jnp.where(ovf, exp + 1, exp)
    base = jnp.where(ovf, 1 << spec.mb, base)
    # encoded field = exp - bias (Algorithm 2 line 7), clamped to field width
    efield = jnp.clip(exp - spec.bias, 0, (1 << spec.ebits) - 1)
    mfield = base & ((1 << spec.mb) - 1)
    code = (sign << (spec.ebits + spec.mb)) | (efield << spec.mb) | mfield
    # guard the disabled code (e=0, m=0): round up to the minimum magnitude
    zero_bits = (efield == 0) & (mfield == 0)
    code = jnp.where(zero_bits, code | 1, code)
    return code.astype(jnp.uint8)


def abfloat_decode(code: jax.Array, spec: AbfloatSpec) -> jax.Array:
    """abfloat code -> scaled value (Fig. 7). ±0 codes decode to 0."""
    c = code.astype(jnp.int32)
    sign_bit = (c >> (spec.ebits + spec.mb)) & 1
    bits = c & ((1 << (spec.ebits + spec.mb)) - 1)
    e = bits >> spec.mb
    m = bits & ((1 << spec.mb) - 1)
    integer = (1 << spec.mb) + m
    mag = integer.astype(jnp.float32) * jnp.exp2(
        (e + spec.bias).astype(jnp.float32))
    mag = jnp.minimum(mag, float(1 << 15))
    v = jnp.where(sign_bit == 1, -mag, mag)
    return jnp.where(bits == 0, 0.0, v).astype(jnp.float32)


def abfloat_nearest(u: jax.Array, spec: AbfloatSpec) -> jax.Array:
    """Round-to-nearest-representable (reference mode, used in tests)."""
    mags = jnp.asarray(spec.magnitudes())
    a = jnp.clip(jnp.abs(u), spec.min_mag, spec.max_mag)
    idx = jnp.argmin(jnp.abs(a[..., None] - mags), axis=-1)
    val = mags[idx]
    return jnp.where(u < 0, -val, val).astype(jnp.float32)
