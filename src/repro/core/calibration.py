"""PTQ calibration (paper §3.4): static activation scales from one batch,
plus the per-site sensitivity pass that can *emit* a mixed-precision
`PolicyProgram` automatically.

The paper uses one batch of *training-set* data to select scale factors.
The flow is artifact-based (see docs/calibration.md):

  1. run the un-jitted model forward under `collecting_activations(tape)`
     — `qlinear.qmatmul` tapes every matmul input under its site address —
     or feed `run_calibration` an `apply_collect` callback,
  2. `calibrate_activation_scales` MSE-searches a static scale per site
     (3σ-seeded) and `CalibrationArtifact` captures the scale dict plus
     program provenance (`save`/`load` round-trip through JSON),
  3. `apply_calibration(policy, artifact)` overlays the artifact on the
     policy program (`CalibratedProgram`): every covered site resolves to
     a `QuantPolicy` carrying `act_scale_mode="static"` +
     `static_act_scale`, which every execution backend honors (the fused
     Pallas kernels take the scale as one (1, 1) scalar operand in place
     of the per-row scale plane — and skip the per-step 3σ std),
  4. the serving engine validates up front that every static-mode site has
     a calibrated scale (`static_scale_misses` — misses raise the
     machine-readable `MissingStaticScaleError`).

Site addressing is shared with the policy program: tape keys, artifact
scale keys, and the rules an `auto_mixed` program emits all use the same
"/"-joined pytree-path grammar that `quantize_params` walks — including
the unrolled ``layers/<i>/...`` and per-expert ``.../experts/<name>/<e>``
addresses (see docs/policies.md). Artifact keys may also be `fnmatch`
globs, so one entry can cover every layer of a scanned stack.
"""
from __future__ import annotations

import contextlib
import dataclasses
import fnmatch
import functools
import json
import os
from typing import Callable, Dict, Iterable, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .ovp import MixedExpertQuant, QuantizedTensor, ovp_fake_quant
from .policy import (PolicyLike, PolicyProgram, QuantPolicy, Rule,
                     as_program)
from .quantizer import ovp_search_scale


class ActTape:
    """Mutable activation tape threaded through un-jitted calibration runs."""

    def __init__(self, max_per_site: int = 65536, seed: int = 0):
        self.max_per_site = max_per_site
        self.rng = np.random.default_rng(seed)
        self.samples: Dict[str, np.ndarray] = {}

    def record(self, name: str, x) -> None:
        flat = np.asarray(jax.device_get(x), dtype=np.float32).reshape(-1)
        if flat.size > self.max_per_site:
            idx = self.rng.choice(flat.size, self.max_per_site, replace=False)
            flat = flat[idx]
        prev = self.samples.get(name)
        if prev is not None:
            both = np.concatenate([prev, flat])
            if both.size > self.max_per_site:
                idx = self.rng.choice(both.size, self.max_per_site,
                                      replace=False)
                both = both[idx]
            self.samples[name] = both
        else:
            self.samples[name] = flat


# --------------------------------------------------------------------------
# Activation collection: a process-wide tape that `qlinear.qmatmul` feeds
# --------------------------------------------------------------------------
_ACTIVE_TAPE: Optional[ActTape] = None


@contextlib.contextmanager
def collecting_activations(tape: ActTape):
    """Install `tape` as the process-wide activation tape.

    While active, every `qlinear.qmatmul` records its (un-jitted) matmul
    input under the call's site address — the same "/"-joined grammar the
    policy program resolves — so a plain `model.forward(...)` over the
    calibration batch yields a tape keyed exactly like the quantized tree.
    Traced calls (under jit) are skipped silently: calibration runs eagerly.
    """
    global _ACTIVE_TAPE
    prev, _ACTIVE_TAPE = _ACTIVE_TAPE, tape
    try:
        yield tape
    finally:
        _ACTIVE_TAPE = prev


def tap(site: str, x) -> None:
    """Record one matmul input on the active tape (no-op when inactive,
    when the site is anonymous, or when `x` is a tracer)."""
    tape = _ACTIVE_TAPE
    if tape is None or not site or isinstance(x, jax.core.Tracer):
        return
    tape.record(site, x)


def record_weights(params, tape: Optional[ActTape] = None,
                   min_size: int = 4096) -> ActTape:
    """Tape every linear-weight leaf under its param-tree site address —
    the weight-side twin of the activation tape, so the sensitivity pass
    and `auto_mixed` run on the exact addresses `quantize_params` resolves.
    """
    from .qlinear import is_linear_weight, tree_paths
    tape = tape if tape is not None else ActTape()
    for path, w in tree_paths(params):
        if hasattr(w, "ndim") and w.ndim >= 2 and w.size >= min_size \
                and is_linear_weight(path, w):
            tape.record(path, w)
    return tape


def calibrate_activation_scales(tape: ActTape, normal_dtype="int4",
                                n_grid: int = 24) -> Dict[str, jax.Array]:
    """Per-site static scales via the OVP MSE search (3σ-seeded), keyed by
    the tape's site addresses.

    `normal_dtype` is one dtype string, or a ``site -> dtype`` callable so
    mixed-precision programs search each site on the grid its activations
    will actually quantize to (W8A8 sites on int8, W4A4 on int4/flint4).
    """
    dtype_for = normal_dtype if callable(normal_dtype) \
        else (lambda _site: normal_dtype)
    scales = {}
    for name, sample in sorted(tape.samples.items()):
        s = sample
        if s.size % 2 != 0:  # pairing needs even length
            s = s[:-1]
        scales[name] = ovp_search_scale(jnp.asarray(s), dtype_for(name),
                                        n_grid=n_grid)
    return scales


def run_calibration(apply_collect: Callable, params, batches: Iterable,
                    normal_dtype: str = "int4",
                    max_per_site: int = 65536) -> Dict[str, jax.Array]:
    """apply_collect(params, batch) -> (out, acts: dict[str, array]).

    Runs the model over calibration batches, tapes matmul inputs, returns
    static activation scales per site.
    """
    tape = ActTape(max_per_site=max_per_site)
    for batch in batches:
        _, acts = apply_collect(params, batch)
        for name, x in acts.items():
            tape.record(name, x)
    return calibrate_activation_scales(tape, normal_dtype)


# ==========================================================================
# CalibrationArtifact: the save/load unit between calibration and serving
# ==========================================================================
_ARTIFACT_KIND = "olive-calibration"
_ARTIFACT_VERSION = 1


class MissingStaticScaleError(ValueError):
    """A static-mode site has no calibrated activation scale.

    Machine-readable: `.sites` lists the offending "/"-joined addresses,
    and the message is a single `missing_static_scale sites=[...]` line so
    launchers and CI can grep it.
    """

    def __init__(self, sites):
        self.sites = sorted(sites)
        super().__init__(f"missing_static_scale sites={self.sites}")


@dataclasses.dataclass(frozen=True)
class CalibrationArtifact:
    """Per-site static activation scales + the provenance to re-derive them.

    `scales` maps site addresses (or `fnmatch` globs over them — the same
    grammar as `PolicyProgram` rules) to the calibrated scale. `program`
    records which policy/program the tape ran under and `normal_dtype` the
    A-side dtype the MSE search targeted; `meta` is free-form (batch
    counts, sample caps, ...). The artifact round-trips through JSON via
    `save`/`load`.
    """

    scales: Tuple[Tuple[str, float], ...]
    normal_dtype: str = "int4"
    program: str = ""
    meta: Tuple[Tuple[str, str], ...] = ()

    @classmethod
    def from_scales(cls, scales: Dict[str, jax.Array],
                    normal_dtype: str = "int4", program: str = "",
                    **meta) -> "CalibrationArtifact":
        # keys keep their given order — for overlapping glob keys,
        # first-match-wins precedence is the author's, like program rules
        return cls(scales=tuple((k, float(v)) for k, v in scales.items()),
                   normal_dtype=normal_dtype, program=program,
                   meta=tuple(sorted((k, str(v)) for k, v in meta.items())))

    def as_dict(self) -> Dict[str, float]:
        """Keys -> scales, first occurrence winning on duplicates (a
        re-applied artifact stacks its fresh keys in front)."""
        d: Dict[str, float] = {}
        for k, v in self.scales:
            d.setdefault(k, v)
        return d

    def sites(self) -> List[str]:
        return [k for k, _ in self.scales]

    def resolve(self, site: str) -> Optional[float]:
        """Scale for one site: the FIRST matching key wins — exact match
        or glob, in author order — the same first-match-wins semantics as
        program rules (so re-applied artifacts and overlapping globs
        behave identically to prepended rules)."""
        low = site.lower()
        for pattern, s in self.scales:
            if pattern == site or fnmatch.fnmatchcase(low,
                                                      pattern.lower()):
                return s
        return None

    # ------------------------------------------------------------ save/load
    def save(self, path: str) -> str:
        payload = {
            "kind": _ARTIFACT_KIND, "version": _ARTIFACT_VERSION,
            "normal_dtype": self.normal_dtype, "program": self.program,
            "meta": dict(self.meta),
            "scales": self.as_dict(),  # first duplicate wins, like resolve
        }
        d = os.path.dirname(os.path.abspath(path))
        os.makedirs(d, exist_ok=True)
        with open(path, "w") as f:
            # no sort_keys: the scales object must round-trip in author
            # order (glob-key precedence is positional)
            json.dump(payload, f, indent=2)
            f.write("\n")
        return path

    @classmethod
    def load(cls, path: str) -> "CalibrationArtifact":
        with open(path) as f:
            payload = json.load(f)
        if payload.get("kind") != _ARTIFACT_KIND:
            raise ValueError(f"{path}: not a calibration artifact "
                             f"(kind={payload.get('kind')!r})")
        if not isinstance(payload.get("scales"), dict):
            raise ValueError(f"{path}: artifact has no 'scales' dict")
        return cls(scales=tuple((str(k), float(v)) for k, v
                                in payload["scales"].items()),
                   normal_dtype=str(payload.get("normal_dtype", "int4")),
                   program=str(payload.get("program", "")),
                   meta=tuple(sorted((str(k), str(v)) for k, v in
                                     payload.get("meta", {}).items())))


@dataclasses.dataclass(frozen=True)
class CalibratedProgram(PolicyProgram):
    """A `PolicyProgram` with a `CalibrationArtifact` overlaid per site.

    `resolve(site)` resolves the *base* program first (rules + default,
    first match wins as ever), then — when the artifact covers the
    concrete site (exact key, else its first matching glob key) —
    replaces `act_scale_mode`/`static_act_scale` on the resolved policy.
    Overlaying per concrete site, instead of baking pre-resolved rules at
    apply time, keeps glob artifact keys correct on mixed-precision
    programs: the base policy under ``layers/*/mlp/w*`` comes from each
    covered site's own rule (layer 1 may be W8, layer 2 W4), never from
    resolving the glob string as a pseudo-site.

    Program-surgery methods (`with_rules`, `replace_all`, `off`,
    `with_backend` — the engine's backend override) preserve the overlay.
    """
    artifact: CalibrationArtifact = CalibrationArtifact(scales=())

    def resolve(self, site: str) -> QuantPolicy:
        return _calibrated_resolve(self, site)

    def with_rules(self, rules, front: bool = True) -> "CalibratedProgram":
        base = PolicyProgram.with_rules(self, rules, front)
        return CalibratedProgram(rules=base.rules, default=base.default,
                                 name=base.name, artifact=self.artifact)

    def replace_all(self, **kw) -> "CalibratedProgram":
        base = PolicyProgram.replace_all(self, **kw)
        return CalibratedProgram(rules=base.rules, default=base.default,
                                 name=base.name, artifact=self.artifact)

    def addresses_layers(self, n_layers: int) -> bool:
        """Artifact keys participate in layout detection: per-layer scale
        keys (``layers/<i>/...``) can only match on the unrolled layout,
        exactly like per-layer rules."""
        if any("layers/" in k.lower() for k, _ in self.artifact.scales):
            return True
        return PolicyProgram.addresses_layers(self, n_layers)


@functools.lru_cache(maxsize=65536)
def _calibrated_resolve(program: CalibratedProgram,
                        site: str) -> QuantPolicy:
    pol = PolicyProgram.resolve(program, site)
    s = program.artifact.resolve(site)
    if s is None:
        return pol
    return dataclasses.replace(pol, act_scale_mode="static",
                               static_act_scale=float(s))


def apply_calibration(policy: PolicyLike,
                      artifact: CalibrationArtifact) -> CalibratedProgram:
    """Overlay an artifact on a policy: every site the artifact covers
    resolves with `act_scale_mode="static"` plus its calibrated
    `static_act_scale`; everything else keeps the base program's
    behavior — and the engine's validation pass rejects static-mode sites
    the artifact missed.

    Keys address sites with the program grammar (literal addresses or
    globs), so calibrated per-layer scales keep working on the unrolled
    ``layers/<i>`` layout and per-expert ``experts/<name>/<e>`` sub-sites.
    Applying a second artifact stacks in front: its keys win where both
    cover a site.
    """
    prog = as_program(policy)
    if isinstance(prog, CalibratedProgram):
        artifact = dataclasses.replace(
            artifact, scales=artifact.scales + prog.artifact.scales)
    return CalibratedProgram(rules=prog.rules, default=prog.default,
                             name=prog.name, artifact=artifact)


def calibrate_model(model, params, batches: Iterable,
                    normal_dtype: Optional[str] = None, n_grid: int = 24,
                    max_per_site: int = 65536) -> CalibrationArtifact:
    """One-stop PTQ calibration over a model: run the (un-jitted) forward
    on each batch with the activation tape installed, MSE-search a static
    scale per taped site, and wrap the result as an artifact.

    `normal_dtype` defaults to resolving the A-side dtype PER SITE from
    the model's policy (the paper's rule: 8-bit activations always int8,
    4-bit the policy's `a_normal_dtype`), so on a mixed-precision program
    every site's MSE search targets the grid its scales will actually be
    used on; pass a string to force one dtype for every site.

    Run this on the *raw* (pre-`quantize_params`) tree so the taped values
    are the fp activations the paper calibrates on; the taped site
    addresses match the quantized tree's, since both walk the same pytree.

    Scanned layer stacks tape through an *unrolled* twin of the model:
    `lax.scan` traces its body even eagerly (so scanned sites would never
    reach the tape), and per-layer ``layers/<i>`` scale keys are what the
    serving path wants anyway — applying the resulting artifact makes the
    program layer-addressed, which unrolls the serving model to the same
    layout the scales were measured on.
    """
    import copy
    if normal_dtype is None:
        from repro.backends.base import act_normal_dtype
        policy_prog = as_program(model.policy)

        def normal_dtype(site):
            pol = policy_prog.resolve(site)
            return act_normal_dtype(pol) if pol.abits \
                else pol.a_normal_dtype
    if getattr(model, "n_groups", 0) or getattr(model, "n_tail", 0):
        from repro.models.model import unroll_params
        unrolled = copy.copy(model)
        unrolled.unrolled, unrolled.n_groups, unrolled.n_tail = True, 0, 0
        model, params = unrolled, unroll_params(model.cfg, params)
    tape = ActTape(max_per_site=max_per_site)
    n_batches = 0
    with collecting_activations(tape):
        for batch in batches:
            model.forward(params, batch, mode="train")
            n_batches += 1
    scales = calibrate_activation_scales(tape, normal_dtype, n_grid=n_grid)
    prog = getattr(model.policy, "name", "") or type(model.policy).__name__
    dtypes = {normal_dtype(s) for s in scales} if callable(normal_dtype) \
        else {normal_dtype}
    return CalibrationArtifact.from_scales(
        scales, normal_dtype=dtypes.pop() if len(dtypes) == 1 else "mixed",
        program=prog, n_batches=n_batches, max_per_site=max_per_site)


def static_scale_misses(params, policy: PolicyLike) -> List[str]:
    """Quantized-weight sites whose resolved policy wants a static
    activation scale but has none calibrated.

    Walks the (quantized) param tree exactly like dispatch will: every
    `QuantizedTensor` leaf resolves its own site, `MixedExpertQuant`
    leaves resolve each per-expert sub-site. Expert-stack einsums run
    weight-only (`models.layers._expert_ein` forces `abits=0`), so
    ``.../experts/...`` stacked sites never need an activation scale and
    are skipped. The serving engine raises `MissingStaticScaleError` on a
    non-empty result.
    """
    from .qlinear import tree_paths
    misses = []

    def needs_scale(pol: QuantPolicy) -> bool:
        return (pol.enabled and pol.abits > 0
                and pol.act_scale_mode == "static"
                and pol.static_act_scale is None)

    for path, w in tree_paths(params):
        if isinstance(w, (QuantizedTensor, MixedExpertQuant)):
            stacked = getattr(getattr(w, "data", None), "ndim", 2) > 2 \
                or isinstance(w, MixedExpertQuant)
            if stacked and "/experts/" in f"/{path}/":
                continue  # expert einsums execute weight-only
            sub = [path] if isinstance(w, QuantizedTensor) else \
                [f"{path}/{e}" for e in range(w.n_experts)]
            misses += [s for s in sub if needs_scale(policy.resolve(s))]
    return misses


def uses_static_scales(policy: PolicyLike) -> bool:
    """True when any rule (or the default) quantizes activations under
    `act_scale_mode="static"` — or a calibration overlay can force sites
    static. The gate for the engine's validation."""
    prog = as_program(policy)
    pols = [prog.default] + [r.policy for r in prog.rules]
    quantizing = [p for p in pols if p.enabled and p.abits > 0]
    if any(p.act_scale_mode == "static" for p in quantizing):
        return True
    return bool(quantizing) and isinstance(prog, CalibratedProgram) \
        and bool(prog.artifact.scales)


# ==========================================================================
# Sensitivity pass: per-site SQNR -> automatic mixed-precision program
# ==========================================================================
def site_sensitivity(tape: ActTape, normal_dtype: str = "int4",
                     n_grid: int = 16) -> Dict[str, float]:
    """Per-site SQNR (dB) of the best low-precision OVP round-trip.

    Low SQNR = the site loses the most signal at `normal_dtype` = the most
    sensitive site = the first candidate for higher precision.
    """
    out = {}
    for name, sample in sorted(tape.samples.items()):
        s = sample[:-1] if sample.size % 2 else sample
        x = jnp.asarray(s)
        scale = ovp_search_scale(x, normal_dtype, n_grid=n_grid)
        xh = ovp_fake_quant(x, scale, normal_dtype)
        mse = float(jnp.mean((xh - x) ** 2))
        power = float(jnp.mean(x * x))
        out[name] = 10.0 * float(np.log10(max(power, 1e-30)
                                          / max(mse, 1e-30)))
    return out


def auto_mixed(sensitivity: Dict[str, float],
               budget_bits: float = 4.5,
               low: QuantPolicy = None,
               high: QuantPolicy = None) -> PolicyProgram:
    """Emit a mixed-precision program from a sensitivity map.

    Sites rank by ascending SQNR; the most sensitive get `high` (default
    W8A8 OVP) until the average weight bit-width over the quantized sites
    would exceed `budget_bits`; everything else resolves through the
    compiled `low` program (default W4A4 OVP with the standard embed/
    router exclusions). Sites the low program keeps at full precision
    (embed/head/router under default flags) are never promoted — the
    exclusions outrank sensitivity. Rule patterns are the literal site
    addresses, so the program applies exactly to the tree it was
    measured on.
    """
    from .policy import OLIVE_W4A4, OLIVE_W8A8
    low = low if low is not None else OLIVE_W4A4
    high = high if high is not None else OLIVE_W8A8
    base = PolicyProgram.from_policy(low, name="auto_mixed")
    candidates = {k: v for k, v in sensitivity.items()
                  if base.resolve(k).enabled}
    if not candidates:
        return base
    span = high.wbits - low.wbits
    frac_high = 0.0 if span <= 0 else \
        min(max((budget_bits - low.wbits) / span, 0.0), 1.0)
    n_high = int(frac_high * len(candidates))
    ranked = sorted(candidates, key=lambda k: candidates[k])
    return base.with_rules([Rule(site, high) for site in ranked[:n_high]])
