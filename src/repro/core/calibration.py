"""PTQ calibration (paper §3.4): static activation scales from one batch,
plus the per-site sensitivity pass that can *emit* a mixed-precision
`PolicyProgram` automatically.

The paper uses one batch of *training-set* data to select scale factors.
Models in `repro.models` support `collect_acts=True`, returning a tape of
matmul-input activations keyed by site name. We subsample each site, run the
OVP MSE scale search, and hand the scales back to the serving path
(`QuantPolicy.act_scale_mode == "static"`).

Site addressing is shared with the policy program: tape keys, the static
scale dict returned by `calibrate_activation_scales`, and the rules an
`auto_mixed` program emits all use the same "/"-joined pytree-path grammar
that `quantize_params` walks (see docs/policies.md).
"""
from __future__ import annotations

from typing import Callable, Dict, Iterable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .policy import PolicyProgram, QuantPolicy, Rule
from .quantizer import ovp_search_scale
from .ovp import ovp_fake_quant


class ActTape:
    """Mutable activation tape threaded through un-jitted calibration runs."""

    def __init__(self, max_per_site: int = 65536, seed: int = 0):
        self.max_per_site = max_per_site
        self.rng = np.random.default_rng(seed)
        self.samples: Dict[str, np.ndarray] = {}

    def record(self, name: str, x) -> None:
        flat = np.asarray(jax.device_get(x), dtype=np.float32).reshape(-1)
        if flat.size > self.max_per_site:
            idx = self.rng.choice(flat.size, self.max_per_site, replace=False)
            flat = flat[idx]
        prev = self.samples.get(name)
        if prev is not None:
            both = np.concatenate([prev, flat])
            if both.size > self.max_per_site:
                idx = self.rng.choice(both.size, self.max_per_site,
                                      replace=False)
                both = both[idx]
            self.samples[name] = both
        else:
            self.samples[name] = flat


def record_weights(params, tape: Optional[ActTape] = None,
                   min_size: int = 4096) -> ActTape:
    """Tape every linear-weight leaf under its param-tree site address —
    the weight-side twin of the activation tape, so the sensitivity pass
    and `auto_mixed` run on the exact addresses `quantize_params` resolves.
    """
    from .qlinear import is_linear_weight, tree_paths
    tape = tape if tape is not None else ActTape()
    for path, w in tree_paths(params):
        if hasattr(w, "ndim") and w.ndim >= 2 and w.size >= min_size \
                and is_linear_weight(path, w):
            tape.record(path, w)
    return tape


def calibrate_activation_scales(tape: ActTape, normal_dtype: str = "int4",
                                n_grid: int = 24) -> Dict[str, jax.Array]:
    """Per-site static scales via the OVP MSE search (3σ-seeded), keyed by
    the tape's site addresses."""
    scales = {}
    for name, sample in sorted(tape.samples.items()):
        s = sample
        if s.size % 2 != 0:  # pairing needs even length
            s = s[:-1]
        scales[name] = ovp_search_scale(jnp.asarray(s), normal_dtype,
                                        n_grid=n_grid)
    return scales


def run_calibration(apply_collect: Callable, params, batches: Iterable,
                    normal_dtype: str = "int4",
                    max_per_site: int = 65536) -> Dict[str, jax.Array]:
    """apply_collect(params, batch) -> (out, acts: dict[str, array]).

    Runs the model over calibration batches, tapes matmul inputs, returns
    static activation scales per site.
    """
    tape = ActTape(max_per_site=max_per_site)
    for batch in batches:
        _, acts = apply_collect(params, batch)
        for name, x in acts.items():
            tape.record(name, x)
    return calibrate_activation_scales(tape, normal_dtype)


# ==========================================================================
# Sensitivity pass: per-site SQNR -> automatic mixed-precision program
# ==========================================================================
def site_sensitivity(tape: ActTape, normal_dtype: str = "int4",
                     n_grid: int = 16) -> Dict[str, float]:
    """Per-site SQNR (dB) of the best low-precision OVP round-trip.

    Low SQNR = the site loses the most signal at `normal_dtype` = the most
    sensitive site = the first candidate for higher precision.
    """
    out = {}
    for name, sample in sorted(tape.samples.items()):
        s = sample[:-1] if sample.size % 2 else sample
        x = jnp.asarray(s)
        scale = ovp_search_scale(x, normal_dtype, n_grid=n_grid)
        xh = ovp_fake_quant(x, scale, normal_dtype)
        mse = float(jnp.mean((xh - x) ** 2))
        power = float(jnp.mean(x * x))
        out[name] = 10.0 * float(np.log10(max(power, 1e-30)
                                          / max(mse, 1e-30)))
    return out


def auto_mixed(sensitivity: Dict[str, float],
               budget_bits: float = 4.5,
               low: QuantPolicy = None,
               high: QuantPolicy = None) -> PolicyProgram:
    """Emit a mixed-precision program from a sensitivity map.

    Sites rank by ascending SQNR; the most sensitive get `high` (default
    W8A8 OVP) until the average weight bit-width over the quantized sites
    would exceed `budget_bits`; everything else resolves through the
    compiled `low` program (default W4A4 OVP with the standard embed/
    router exclusions). Sites the low program keeps at full precision
    (embed/head/router under default flags) are never promoted — the
    exclusions outrank sensitivity. Rule patterns are the literal site
    addresses, so the program applies exactly to the tree it was
    measured on.
    """
    from .policy import OLIVE_W4A4, OLIVE_W8A8
    low = low if low is not None else OLIVE_W4A4
    high = high if high is not None else OLIVE_W8A8
    base = PolicyProgram.from_policy(low, name="auto_mixed")
    candidates = {k: v for k, v in sensitivity.items()
                  if base.resolve(k).enabled}
    if not candidates:
        return base
    span = high.wbits - low.wbits
    frac_high = 0.0 if span <= 0 else \
        min(max((budget_bits - low.wbits) / span, 0.0), 1.0)
    n_high = int(frac_high * len(candidates))
    ranked = sorted(candidates, key=lambda k: candidates[k])
    return base.with_rules([Rule(site, high) for site in ranked[:n_high]])
