"""PTQ calibration (paper §3.4): static activation scales from one batch.

The paper uses one batch of *training-set* data to select scale factors.
Models in `repro.models` support `collect_acts=True`, returning a tape of
matmul-input activations keyed by site name. We subsample each site, run the
OVP MSE scale search, and hand the scales back to the serving path
(`QuantPolicy.act_scale_mode == "static"`).
"""
from __future__ import annotations

from typing import Callable, Dict, Iterable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .quantizer import ovp_search_scale


class ActTape:
    """Mutable activation tape threaded through un-jitted calibration runs."""

    def __init__(self, max_per_site: int = 65536, seed: int = 0):
        self.max_per_site = max_per_site
        self.rng = np.random.default_rng(seed)
        self.samples: Dict[str, np.ndarray] = {}

    def record(self, name: str, x) -> None:
        flat = np.asarray(jax.device_get(x), dtype=np.float32).reshape(-1)
        if flat.size > self.max_per_site:
            idx = self.rng.choice(flat.size, self.max_per_site, replace=False)
            flat = flat[idx]
        prev = self.samples.get(name)
        if prev is not None:
            both = np.concatenate([prev, flat])
            if both.size > self.max_per_site:
                idx = self.rng.choice(both.size, self.max_per_site,
                                      replace=False)
                both = both[idx]
            self.samples[name] = both
        else:
            self.samples[name] = flat


def calibrate_activation_scales(tape: ActTape, normal_dtype: str = "int4",
                                n_grid: int = 24) -> Dict[str, jax.Array]:
    """Per-site static scales via the OVP MSE search (3σ-seeded)."""
    scales = {}
    for name, sample in sorted(tape.samples.items()):
        s = sample
        if s.size % 2 != 0:  # pairing needs even length
            s = s[:-1]
        scales[name] = ovp_search_scale(jnp.asarray(s), normal_dtype,
                                        n_grid=n_grid)
    return scales


def run_calibration(apply_collect: Callable, params, batches: Iterable,
                    normal_dtype: str = "int4",
                    max_per_site: int = 65536) -> Dict[str, jax.Array]:
    """apply_collect(params, batch) -> (out, acts: dict[str, array]).

    Runs the model over calibration batches, tapes matmul inputs, returns
    static activation scales per site.
    """
    tape = ActTape(max_per_site=max_per_site)
    for batch in batches:
        _, acts = apply_collect(params, batch)
        for name, x in acts.items():
            tape.record(name, x)
    return calibrate_activation_scales(tape, normal_dtype)
