"""Outlier-Victim Pair (OVP) encoding (paper §3, Algorithm 1).

Semantics (per adjacent non-overlapping pair along `pair_axis`):
  normal–normal   -> both quantized with the normal dtype (int4/flint4/int8)
  outlier–normal  -> normal neighbour pruned to 0 (the *victim*), its slot
                     holds the identifier (1000b / 10000000b); the outlier is
                     stored as abfloat in its own slot
  outlier–outlier -> the smaller-magnitude outlier is pruned (becomes the
                     victim); <0.06% of pairs in practice (Table 2)

Storage is dense + byte-aligned: 4-bit codes pack two-per-byte so one byte
IS one pair — exactly the paper's memory-aligned claim. 8-bit codes stay one
code per byte (a pair = two adjacent bytes).

All functions are jit-safe; `normal_dtype` and specs are static.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis import sanitize

from .datatypes import (ABFLOAT_FOR_NORMAL, ID4, ID8, NORMAL_MAX, AbfloatSpec,
                        abfloat_decode, abfloat_encode, normal_decode,
                        normal_encode)


def _identifier(normal_dtype: str) -> int:
    return ID8 if normal_dtype == "int8" else ID4


def _move_pair_axis(x: jax.Array, axis: int) -> jax.Array:
    return jnp.moveaxis(x, axis, -1)


# --------------------------------------------------------------------------
# Code-level encode / decode (values are already scaled: u = x / scale)
# --------------------------------------------------------------------------
def ovp_encode_codes(u: jax.Array, normal_dtype: str = "int4",
                     spec: Optional[AbfloatSpec] = None,
                     pair_axis: int = -1) -> jax.Array:
    """Scaled tensor -> uint8 code tensor (same shape), Algorithm 1.

    The size along `pair_axis` must be even.
    """
    spec = ABFLOAT_FOR_NORMAL[normal_dtype] if spec is None else spec
    ident = _identifier(normal_dtype)
    t = float(NORMAL_MAX[normal_dtype])

    v = _move_pair_axis(u, pair_axis)
    if v.shape[-1] % 2 != 0:
        raise ValueError(f"pair axis length {v.shape[-1]} must be even")
    sanitize.check(jnp.all(jnp.isfinite(v)),
                   "ovp_encode_codes: non-finite scaled input (NaN/Inf "
                   "upstream of the encoder, or a zero/garbage scale)")
    x0, x1 = v[..., 0::2], v[..., 1::2]
    a0, a1 = jnp.abs(x0), jnp.abs(x1)

    o0, o1 = a0 > t, a1 > t
    # outlier–outlier: keep the larger magnitude (§3.1); ties keep the left
    first_out = o0 & (~o1 | (a0 >= a1))
    second_out = o1 & ~first_out

    n0 = normal_encode(x0, normal_dtype).astype(jnp.uint8)
    n1 = normal_encode(x1, normal_dtype).astype(jnp.uint8)
    f0 = abfloat_encode(x0, spec)
    f1 = abfloat_encode(x1, spec)

    c0 = jnp.where(first_out, f0, jnp.where(second_out, ident, n0))
    c1 = jnp.where(second_out, f1, jnp.where(first_out, ident, n1))

    codes = jnp.stack([c0, c1], axis=-1).reshape(v.shape).astype(jnp.uint8)
    return jnp.moveaxis(codes, -1, pair_axis)


def ovp_decode_codes(codes: jax.Array, normal_dtype: str = "int4",
                     spec: Optional[AbfloatSpec] = None,
                     pair_axis: int = -1) -> jax.Array:
    """uint8 code tensor -> scaled values (float32). Victims decode to 0."""
    spec = ABFLOAT_FOR_NORMAL[normal_dtype] if spec is None else spec
    ident = _identifier(normal_dtype)

    c = _move_pair_axis(codes, pair_axis)
    n0, n1 = c[..., 0::2], c[..., 1::2]
    sanitize.check(~jnp.any((n0 == ident) & (n1 == ident)),
                   "ovp_decode_codes: both codes of a pair hold the "
                   "identifier — not a valid OVP encoding (corrupt or "
                   "misaligned code stream)")

    # if my neighbour holds the identifier, I am the outlier (abfloat);
    # if I hold it, I am the victim (0); otherwise I am a normal value.
    v0 = jnp.where(n1 == ident, abfloat_decode(n0, spec),
                   jnp.where(n0 == ident, 0.0,
                             normal_decode(n0, normal_dtype)))
    v1 = jnp.where(n0 == ident, abfloat_decode(n1, spec),
                   jnp.where(n1 == ident, 0.0,
                             normal_decode(n1, normal_dtype)))
    out = jnp.stack([v0, v1], axis=-1).reshape(c.shape).astype(jnp.float32)
    return jnp.moveaxis(out, -1, pair_axis)


# --------------------------------------------------------------------------
# Nibble packing: two 4-bit codes per byte (one byte == one OV pair)
# --------------------------------------------------------------------------
def pack4(codes: jax.Array, pair_axis: int = -1) -> jax.Array:
    """(…, 2K, …) nibble codes -> (…, K, …) bytes; even index = high nibble."""
    c = _move_pair_axis(codes, pair_axis).astype(jnp.uint8)
    hi, lo = c[..., 0::2], c[..., 1::2]
    packed = (hi << 4) | (lo & jnp.uint8(0xF))
    return jnp.moveaxis(packed, -1, pair_axis)


def unpack4(packed: jax.Array, pair_axis: int = -1) -> jax.Array:
    """(…, K, …) bytes -> (…, 2K, …) nibble codes."""
    p = _move_pair_axis(packed, pair_axis).astype(jnp.uint8)
    hi = (p >> 4) & jnp.uint8(0xF)
    lo = p & jnp.uint8(0xF)
    c = jnp.stack([hi, lo], axis=-1)
    c = c.reshape(p.shape[:-1] + (p.shape[-1] * 2,))
    return jnp.moveaxis(c, -1, pair_axis)


# --------------------------------------------------------------------------
# QuantizedTensor: pytree carrying packed codes + scale + static metadata
# --------------------------------------------------------------------------
@partial(jax.tree_util.register_dataclass,
         data_fields=["data", "scale"],
         meta_fields=["normal_dtype", "pair_axis", "orig_dim"])
@dataclasses.dataclass
class QuantizedTensor:
    """OVP-quantized tensor.

    data:   uint8. 4-bit dtypes: packed nibbles, `pair_axis` length = dim/2.
            int8: one code per byte, full length.
    scale:  float32, broadcastable against the dequantized tensor
            (per-tensor scalar or per-channel with pair_axis collapsed to 1).
    normal_dtype: "int4" | "flint4" | "int8" (static)
    pair_axis: axis along which values pair/pack (static)
    orig_dim: unpacked length of pair_axis (static)
    """
    data: jax.Array
    scale: jax.Array
    normal_dtype: str
    pair_axis: int   # stored NEGATIVE so vmap/scan batching keeps it valid
    orig_dim: int

    @property
    def is_packed(self) -> bool:
        return self.normal_dtype != "int8"

    @property
    def shape(self):
        s = list(self.data.shape)
        ax = self.pair_axis % len(s)
        s[ax] = self.orig_dim
        return tuple(s)

    def nbytes(self) -> int:
        return int(np.prod(self.data.shape)) + int(np.prod(self.scale.shape)) * 4


# --------------------------------------------------------------------------
# MixedExpertQuant: one stacked (E, K, N) weight whose experts resolved to
# DIFFERENT per-site policies (OWQ-style per-expert mixed precision)
# --------------------------------------------------------------------------
@partial(jax.tree_util.register_dataclass,
         data_fields=["groups"],
         meta_fields=["expert_ids", "n_experts"])
@dataclasses.dataclass
class MixedExpertQuant:
    """Per-expert heterogeneously quantized stack of expert weights.

    `quantize_params` emits this when a policy program addresses individual
    experts of one stacked `(E, K, N)` weight (sites ``…/experts/wg/<e>``)
    and the experts resolve to different precisions. Experts are grouped by
    resolved policy: each group is a homogeneous stacked `QuantizedTensor`
    (or a raw array for fp groups), so every group still runs the grouped
    kernel — dispatch stitches the groups back into expert order.

    groups:     one entry per distinct resolved policy — a stacked
                QuantizedTensor (Ei, K/2|K, N) or a raw (Ei, K, N) array
    expert_ids: tuple of tuples — expert_ids[g][i] is the original expert
                index of groups[g]'s i-th slice
    n_experts:  E, the stacked leading dim the groups partition
    """
    groups: tuple
    expert_ids: tuple
    n_experts: int

    @property
    def shape(self):
        g0 = self.groups[0]
        inner = g0.shape[1:]
        return (self.n_experts,) + tuple(inner)

    def nbytes(self) -> int:
        tot = 0
        for g in self.groups:
            if isinstance(g, QuantizedTensor):
                tot += g.nbytes()
            else:
                tot += int(np.prod(g.shape)) * g.dtype.itemsize
        return tot


def ovp_quantize(x: jax.Array, scale: jax.Array, normal_dtype: str = "int4",
                 spec: Optional[AbfloatSpec] = None,
                 pair_axis: int = -1) -> QuantizedTensor:
    """Quantize a real tensor with OVP at a given scale."""
    scale = jnp.asarray(scale, dtype=jnp.float32)
    sanitize.check(jnp.all((scale > 0) & jnp.isfinite(scale)),
                   "ovp_quantize: scale must be positive and finite")
    u = x.astype(jnp.float32) / scale
    codes = ovp_encode_codes(u, normal_dtype, spec, pair_axis)
    # store pair_axis negative: stays correct if leading batch/stack dims
    # are later added by vmap/scan (stacked per-layer weights)
    neg_ax = pair_axis if pair_axis < 0 else pair_axis - x.ndim
    data = pack4(codes, pair_axis=neg_ax) if normal_dtype != "int8" else codes
    return QuantizedTensor(data=data, scale=scale, normal_dtype=normal_dtype,
                           pair_axis=neg_ax, orig_dim=x.shape[neg_ax])


def ovp_dequantize(qt: QuantizedTensor,
                   spec: Optional[AbfloatSpec] = None,
                   dtype=jnp.float32) -> jax.Array:
    """Dequantize back to real values: decode(codes) * scale."""
    codes = (unpack4(qt.data, qt.pair_axis) if qt.is_packed else qt.data)
    vals = ovp_decode_codes(codes, qt.normal_dtype, spec, qt.pair_axis)
    return (vals * qt.scale).astype(dtype)


def ovp_fake_quant(x: jax.Array, scale: jax.Array, normal_dtype: str = "int4",
                   spec: Optional[AbfloatSpec] = None,
                   pair_axis: int = -1) -> jax.Array:
    """quantize→dequantize without packing (used by MSE search / QAT STE)."""
    scale = jnp.asarray(scale, dtype=jnp.float32)
    u = x.astype(jnp.float32) / scale
    codes = ovp_encode_codes(u, normal_dtype, spec, pair_axis)
    vals = ovp_decode_codes(codes, normal_dtype, spec, pair_axis)
    return vals * scale


# --------------------------------------------------------------------------
# Pair statistics (paper §2.3, Table 2)
# --------------------------------------------------------------------------
def pair_statistics(x: jax.Array, k_sigma: float = 3.0,
                    pair_axis: int = -1) -> dict:
    """Fractions of normal-normal / outlier-normal / outlier-outlier pairs."""
    v = _move_pair_axis(x, pair_axis)
    sigma = jnp.std(v)
    mu = jnp.mean(v)
    out = jnp.abs(v - mu) > k_sigma * sigma
    o0, o1 = out[..., 0::2], out[..., 1::2]
    nn = jnp.mean((~o0) & (~o1))
    oo = jnp.mean(o0 & o1)
    on = 1.0 - nn - oo
    return {"normal_normal": float(nn), "outlier_normal": float(on),
            "outlier_outlier": float(oo),
            "outlier_ratio": float(jnp.mean(out)), "sigma": float(sigma)}
