"""Quantization baselines the paper compares against (§5.1–5.2).

Every baseline exposes `fake_quant(x) -> x_hat` semantics (quantize +
dequantize) plus byte accounting, so the benchmark harness can rank methods
by round-trip error and memory footprint on identical tensors.

  uniform int4/int8   — symmetric uniform quantization (max- or MSE-scaled)
  ANT                 — per-tensor adaptive type: best of {int4, flint4}
                        by MSE (Guo et al., MICRO'22) [32]
  GOBO                — weight-only: outliers (>kσ) kept fp32 in a coordinate
                        list, normal values -> centroid codebook [85]
  AdaptivFloat        — float with tensor-wise exponent bias [76]
  outlier-clip        — clip at kσ then uniform int4 (Fig. 3 "clipping")
  prune-random/victim — Fig. 3 pruning controls
"""
from __future__ import annotations

from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp

from .datatypes import FLINT4_LUT, flint4_decode, flint4_encode
from .ovp import _move_pair_axis


# --------------------------------------------------------------------------
# Uniform symmetric int quantization
# --------------------------------------------------------------------------
def uniform_int_fake_quant(x: jax.Array, bits: int,
                           scale_mode: str = "mse") -> jax.Array:
    nmax = (1 << (bits - 1)) - 1
    amax = jnp.max(jnp.abs(x))
    if scale_mode == "max":
        s = jnp.maximum(amax / nmax, 1e-8)
        return jnp.clip(jnp.round(x / s), -nmax - 1, nmax) * s

    # MSE grid search on the clip point (standard PTQ practice [4])
    grid = jnp.maximum(amax, 1e-8) * jnp.geomspace(0.05, 1.0, 40)

    def mse_at(c):
        s = c / nmax
        xh = jnp.clip(jnp.round(x / s), -nmax - 1, nmax) * s
        return jnp.mean((xh - x) ** 2)

    mses = jax.lax.map(mse_at, grid)
    s = grid[jnp.argmin(mses)] / nmax
    return jnp.clip(jnp.round(x / s), -nmax - 1, nmax) * s


def uniform_int_dynamic_act(x: jax.Array, bits: int) -> jax.Array:
    """Per-tensor dynamic (max-scaled) activation fake-quant — the standard
    runtime path of int8/int4 baselines (no grid search in the hot loop)."""
    nmax = (1 << (bits - 1)) - 1
    s = jnp.maximum(jnp.max(jnp.abs(x)) / nmax, 1e-8)
    return jnp.clip(jnp.round(x / s), -nmax - 1, nmax) * s


# --------------------------------------------------------------------------
# ANT: adaptive data type (int4 vs flint4), per-tensor by MSE
# --------------------------------------------------------------------------
def flint4_fake_quant(x: jax.Array) -> jax.Array:
    fmax = float(FLINT4_LUT[-1])
    amax = jnp.max(jnp.abs(x))
    grid = jnp.maximum(amax, 1e-8) / fmax * jnp.geomspace(0.08, 1.1, 40)

    def mse_at(s):
        xh = flint4_decode(flint4_encode(x / s)) * s
        return jnp.mean((xh - x) ** 2)

    mses = jax.lax.map(mse_at, grid)
    s = grid[jnp.argmin(mses)]
    return flint4_decode(flint4_encode(x / s)) * s


def ant_fake_quant(x: jax.Array) -> jax.Array:
    """ANT 4-bit: pick the better of int4 / flint4 for this tensor."""
    a = uniform_int_fake_quant(x, 4, "mse")
    b = flint4_fake_quant(x)
    mse_a = jnp.mean((a - x) ** 2)
    mse_b = jnp.mean((b - x) ** 2)
    return jnp.where(mse_a <= mse_b, a, b)


# --------------------------------------------------------------------------
# GOBO-style: outliers exact (sparse fp32), normals -> centroid codebook
# --------------------------------------------------------------------------
def gobo_fake_quant(x: jax.Array, bits: int = 4, k_sigma: float = 3.0,
                    iters: int = 6) -> Tuple[jax.Array, dict]:
    """Returns (x_hat, stats). stats carries the GOBO byte accounting:
    normals at `bits` + outliers at 32 bits value + 32 bits coordinate —
    the unaligned overhead OliVe's Table 1 criticises.
    """
    mu, sigma = jnp.mean(x), jnp.std(x)
    is_out = jnp.abs(x - mu) > k_sigma * sigma
    normals = jnp.where(is_out, mu, x)

    k = 1 << bits
    qs = jnp.linspace(0.5 / k, 1 - 0.5 / k, k)
    cent = jnp.quantile(normals.reshape(-1), qs)
    flat = normals.reshape(-1)

    def lloyd(cent, _):
        assign = jnp.argmin(jnp.abs(flat[:, None] - cent[None, :]), axis=1)
        sums = jax.ops.segment_sum(flat, assign, num_segments=k)
        cnts = jax.ops.segment_sum(jnp.ones_like(flat), assign,
                                   num_segments=k)
        new = jnp.where(cnts > 0, sums / jnp.maximum(cnts, 1), cent)
        return new, None

    cent, _ = jax.lax.scan(lloyd, cent, None, length=iters)
    assign = jnp.argmin(jnp.abs(flat[:, None] - cent[None, :]), axis=1)
    qn = cent[assign].reshape(x.shape)
    xh = jnp.where(is_out, x, qn)  # outliers kept exact (fp32 side list)

    n_out = jnp.sum(is_out)
    bytes_ = (x.size - n_out) * bits / 8 + n_out * (4 + 4) + k * 4
    return xh, {"outlier_frac": float(jnp.mean(is_out)),
                "bytes": float(bytes_)}


# --------------------------------------------------------------------------
# AdaptivFloat: float with a tensor-wise exponent bias [76]
# --------------------------------------------------------------------------
def adaptivfloat_fake_quant(x: jax.Array, bits: int = 4,
                            ebits: int = 2) -> jax.Array:
    mb = bits - 1 - ebits
    amax = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12)
    # bias aligns the max representable with the tensor max exponent
    max_mant = 2.0 - 2.0 ** (-mb)
    ebias = jnp.floor(jnp.log2(amax / max_mant))
    emin = ebias - ((1 << ebits) - 1)

    sign = jnp.sign(x)
    a = jnp.abs(x)
    e = jnp.clip(jnp.floor(jnp.log2(jnp.maximum(a, 1e-30))), emin, ebias)
    step = jnp.exp2(e - mb)
    mant = jnp.clip(jnp.round(a / step), 0, (2 ** (mb + 1)) - 1)
    xh = sign * mant * step
    # flush below min subnormal-ish magnitude
    min_mag = jnp.exp2(emin)
    return jnp.where(a < min_mag / 2, 0.0, xh)


# --------------------------------------------------------------------------
# Fig. 3 controls: clip outliers / prune victims / prune random normals
# --------------------------------------------------------------------------
def clip_outliers(x: jax.Array, k_sigma: float = 3.0) -> jax.Array:
    mu, sigma = jnp.mean(x), jnp.std(x)
    return jnp.clip(x, mu - k_sigma * sigma, mu + k_sigma * sigma)


def prune_victims(x: jax.Array, k_sigma: float = 3.0,
                  pair_axis: int = -1) -> jax.Array:
    """Zero the normal neighbour of each outlier (and the smaller of an
    outlier-outlier pair) — everything else kept full precision (Fig. 3)."""
    v = _move_pair_axis(x, pair_axis)
    mu, sigma = jnp.mean(v), jnp.std(v)
    t = k_sigma * sigma
    x0, x1 = v[..., 0::2], v[..., 1::2]
    a0, a1 = jnp.abs(x0 - mu), jnp.abs(x1 - mu)
    o0, o1 = a0 > t, a1 > t
    first_out = o0 & (~o1 | (a0 >= a1))
    second_out = o1 & ~first_out
    y0 = jnp.where(second_out, 0.0, x0)   # victim of a right outlier
    y1 = jnp.where(first_out, 0.0, x1)    # victim of a left outlier
    out = jnp.stack([y0, y1], axis=-1).reshape(v.shape)
    return jnp.moveaxis(out, -1, pair_axis)


def prune_random(x: jax.Array, frac: float, key: jax.Array) -> jax.Array:
    mask = jax.random.uniform(key, x.shape) < frac
    return jnp.where(mask, 0.0, x)
