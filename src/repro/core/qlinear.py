"""Quantized linear ops — the integration point between OliVe and the models.

Weights arrive either as raw arrays (training / fp serving) or as
`QuantizedTensor` (post-PTQ serving). `linear()` dispatches:

  raw + policy off        -> plain matmul
  raw + policy on (QAT)   -> STE fake-quant matmul
  QuantizedTensor         -> `repro.backends.dispatch`: the registered
                             execution backend named by `policy.backend`
                             (xla decode-and-matmul, fused Pallas kernel,
                             fp32 reference, ...)

Pairing/packing is always along the reduction dim so per-channel (output)
scales never split a pair.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Union

import jax
import jax.numpy as jnp

from repro import backends

from . import baselines
from . import calibration as _calibration
from .ovp import MixedExpertQuant, QuantizedTensor
from .policy import PolicyLike, QuantPolicy, resolve
from repro.analysis import sanitize
from .quantizer import (QuantSpec, fake_quant_ste, quantize,
                        sigma_init_scale)

Weight = Union[jax.Array, QuantizedTensor]


# --------------------------------------------------------------------------
# Offline weight quantization (PTQ)
# --------------------------------------------------------------------------
def quantize_weight(w: jax.Array, policy: QuantPolicy) -> Weight:
    """PTQ one weight matrix (..., K, N): pair along K, scale per N.

    Stacked (scan-over-layers / per-expert) weights with leading dims are
    vmapped so scales get a matching leading dim and stay scan-sliceable.
    """
    if not policy.enabled:
        return w
    nd = policy.normal_dtype_for_bits(policy.wbits)
    if policy.method == "olive":
        if w.ndim > 2:
            qt = jax.vmap(lambda ww: quantize_weight(ww, policy))(w)
            if qt.scale.ndim == 1:
                # per-stack-entry tensor-granularity scales come back (E,);
                # give them the trailing singletons dequant broadcasting
                # and the grouped kernel's (E, 1, N) layout both need
                qt = dataclasses.replace(
                    qt, scale=qt.scale[:, None, None])
            return qt
        spec = QuantSpec(normal_dtype=nd,
                         granularity=policy.w_granularity,
                         channel_axis=-1, pair_axis=-2)
        return quantize(w, spec)
    # baselines keep fake-quant semantics (they model accuracy, and their
    # byte accounting is handled by the benchmark harness)
    if policy.method == "int":
        return baselines.uniform_int_fake_quant(w, policy.wbits)
    if policy.method == "ant":
        return baselines.ant_fake_quant(w)
    raise ValueError(policy.method)


# --------------------------------------------------------------------------
# Activation quantization (dynamic 3σ or static calibrated scale)
# --------------------------------------------------------------------------
def quantize_activation(x: jax.Array, policy: QuantPolicy,
                        static_scale: Optional[jax.Array] = None):
    """Materialized OVP activation tensor for the A side.

    The scale rule is owned by `repro.backends.base` so every execution
    backend quantizes identically; this delegate keeps the public API.
    The fused Pallas backend never calls this — it quantizes in the kernel
    prologue from the same resolved scale.
    """
    return backends.quantize_activation(x, policy, static_scale)


# --------------------------------------------------------------------------
# The quantized matmul
# --------------------------------------------------------------------------
def qmatmul(x: jax.Array, w: Weight, policy: QuantPolicy, site: str = "",
            act_scale: Optional[jax.Array] = None,
            precision=None) -> jax.Array:
    """x: (..., K) @ w: (K, N) with the policy's quantization applied.

    `site` is the weight's "/"-joined param-tree address (threaded by the
    model layers): it feeds the calibration tape when one is active, and
    names the offending site when a static-scale policy arrives without a
    calibrated scale.
    """
    _calibration.tap(site, x)
    cdt = jnp.dtype(policy.compute_dtype)
    if isinstance(w, (QuantizedTensor, MixedExpertQuant)):
        if (policy.abits and policy.act_scale_mode == "static"
                and act_scale is None and policy.static_act_scale is None):
            raise _calibration.MissingStaticScaleError([site or "<unknown>"])
        return backends.dispatch(x, w, policy, act_scale=act_scale,
                                 precision=precision, site=site)
    # raw weights
    if policy.enabled and policy.qat and policy.method == "olive":
        # QAT path: STE fake-quant on W (and A if configured)
        nd = policy.normal_dtype_for_bits(policy.wbits)
        ws = sigma_init_scale(w, nd)
        wq = fake_quant_ste(w, ws, nd, pair_axis=-2)
        xx = x
        if policy.abits:
            nda = policy.a_normal_dtype
            xs = sigma_init_scale(x, nda)
            xx = fake_quant_ste(x, xs, nda, pair_axis=-1)
        return jnp.matmul(xx.astype(cdt), wq.astype(cdt),
                          precision=precision)
    if (policy.enabled and not policy.qat and policy.abits
            and policy.method in ("int", "ant")):
        # baseline PTQ serving: weights were fake-quantized offline; the
        # activation side runs dynamic max-scaled int fake-quant (the
        # standard int8/int4 runtime path the paper compares against)
        xx = baselines.uniform_int_dynamic_act(x.astype(jnp.float32),
                                               policy.abits)
        return jnp.matmul(xx.astype(cdt), w.astype(cdt),
                          precision=precision)
    return jnp.matmul(x.astype(cdt), w.astype(cdt), precision=precision)


def linear(x: jax.Array, w: Weight, b: Optional[jax.Array],
           policy: QuantPolicy, site: str = "",
           act_scale: Optional[jax.Array] = None,
           precision=None) -> jax.Array:
    y = qmatmul(x, w, policy, site, act_scale, precision)
    if b is not None:
        y = y + b.astype(y.dtype)
    return y


# --------------------------------------------------------------------------
# Whole-pytree PTQ: quantize every eligible weight in a param tree
# --------------------------------------------------------------------------
NEVER_QUANT = {"w_igate", "w_fgate", "w_gate", "conv_kernel"}


def is_linear_weight(path: str, w) -> bool:
    """Structural gate: is this leaf a matmul weight qlinear consumes at
    all? (Site *eligibility* — should it quantize — is the policy
    program's job; this only filters gates/convs/norms/small tensors.)"""
    if not hasattr(w, "ndim") or w.ndim < 2:
        return False
    leaf = path.split("/")[-1]
    if leaf in NEVER_QUANT:
        return False  # tiny gate/conv tensors consumed outside qlinear
    return leaf.startswith("w") or leaf in ("kernel", "wi", "wo", "wq", "wk",
                                            "wv", "wu", "wg", "wd")


def eligible(path: str, policy: PolicyLike) -> bool:
    """Per-site enablement — now a thin wrapper over policy resolution
    (the seed's string heuristics live on as `PolicyProgram.from_policy`).
    """
    return resolve(policy, path).enabled


def _qt_leaf(x) -> bool:
    # QuantizedTensor / MixedExpertQuant are registered pytrees; treat them
    # as one leaf so site addresses stay the weight path, not .../data etc.
    return isinstance(x, (QuantizedTensor, MixedExpertQuant))


def tree_paths(params):
    """(path, leaf) pairs with "/"-joined string paths — the site addresses
    the policy program resolves against. QuantizedTensor leaves stay whole.
    """
    flat = jax.tree_util.tree_flatten_with_path(params, is_leaf=_qt_leaf)[0]
    return [("/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                      for k in kp), w)
            for kp, w in flat]


def _expert_site_policies(path: str, n_experts: int, policy: PolicyLike):
    """Resolved policies for the per-expert sub-sites ``<path>/<e>`` of one
    stacked (E, K, N) weight, or None when the program does not distinguish
    experts (every sub-site resolves identically — the common case, which
    keeps the stack a single homogeneous QuantizedTensor)."""
    pols = [resolve(policy, f"{path}/{e}") for e in range(n_experts)]
    # activation-scale calibration is an A-side property: two experts
    # whose policies differ only in static_act_scale must pack as one
    # homogeneous stack (dispatch takes the A side from the call-site
    # policy, never per expert) — so the scale is stripped from both the
    # homogeneity gate AND the returned grouping keys
    wkey = [dataclasses.replace(p, static_act_scale=None) for p in pols]
    return wkey if len(set(wkey)) > 1 else None


def _quantize_mixed_experts(w, pols) -> MixedExpertQuant:
    """Group experts by resolved policy; quantize each group as one stacked
    homogeneous QuantizedTensor (fp groups stay raw arrays)."""
    by_pol = {}
    for e, pol in enumerate(pols):
        by_pol.setdefault(pol, []).append(e)
    groups, ids = [], []
    for pol, idx in by_pol.items():
        sub = jnp.take(jnp.asarray(w), jnp.asarray(idx), axis=0)
        if pol.enabled:
            groups.append(quantize_weight(sub.astype(jnp.float32), pol))
        else:
            groups.append(sub)
        ids.append(tuple(idx))
    return MixedExpertQuant(groups=tuple(groups), expert_ids=tuple(ids),
                            n_experts=len(pols))


def quantize_params(params, policy: PolicyLike, min_size: int = 4096):
    """Map PTQ over a parameter pytree. Norms/bias/small tensors stay fp.

    `policy` is a `QuantPolicy` (uniform, legacy flags) or a
    `PolicyProgram`: each leaf quantizes under the policy its own site
    address resolves to, so one tree can mix W4 and W8 leaves (and leave
    sites fp) according to the program. Stacked per-expert weights
    additionally resolve the per-expert sub-sites ``<site>/<e>``: when a
    program distinguishes experts (e.g. a rule ``*/experts/wg/3``), the
    stack quantizes group-wise into a `MixedExpertQuant` so one MoE layer
    can mix W4 and W8 experts.

    Pair axis = -2 (reduction dim), per-output-channel scales. Dims must be
    even along the pair axis — true for every assigned architecture.
    """
    if not policy.enabled:
        return params
    if sanitize.enabled():
        # PTQ stages the OVP scale search under lax.map, so the sanitizer
        # checks inside must be functionalized here at the entry point.
        return sanitize.run_checked(_quantize_params, params, policy,
                                    min_size)
    return _quantize_params(params, policy, min_size)


def _quantize_params(params, policy: PolicyLike, min_size: int):
    treedef = jax.tree_util.tree_structure(params, is_leaf=_qt_leaf)
    out = []
    for path, w in tree_paths(params):
        structural_ok = (hasattr(w, "ndim") and w.ndim >= 2
                         and w.size >= min_size and w.shape[-2] % 2 == 0
                         and is_linear_weight(path, w))
        if structural_ok and w.ndim == 3:
            pols = _expert_site_policies(path, w.shape[0], policy)
            if pols is not None:
                out.append(_quantize_mixed_experts(w, pols))
                continue
        site_policy = resolve(policy, path)
        if structural_ok and site_policy.enabled:
            out.append(quantize_weight(jnp.asarray(w, jnp.float32),
                                       site_policy))
        else:
            out.append(w)
    return jax.tree_util.tree_unflatten(treedef, out)
