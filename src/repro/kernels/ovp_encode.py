"""Pallas OVP encoder kernel (Algorithm 1 + Algorithm 2 in one pass).

Encodes scaled values u = x/scale into packed OVP bytes. Used on the serving
path to quantize activations online (the paper's quantization-unit-embedded
encoder, §3.1: "a thread handles two values simultaneously" — here one VPU
lane handles one byte = one pair).

Pairs run along the last axis: out byte (r, c) holds u[r, 2c] (high nibble)
and u[r, 2c+1] (low nibble).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.datatypes import ABFLOAT_FOR_NORMAL, AbfloatSpec, NORMAL_MAX


def _encode_normal_int4(u: jax.Array) -> jax.Array:
    q = jnp.clip(jnp.round(u), -7, 7).astype(jnp.int32)
    return (q & 0xF).astype(jnp.uint8)


def _encode_abfloat4(u: jax.Array, spec: AbfloatSpec) -> jax.Array:
    sign = (u < 0).astype(jnp.int32)
    mag = jnp.clip(jnp.abs(u), spec.min_mag, spec.max_mag)
    exp = jnp.floor(jnp.log2(mag)).astype(jnp.int32) - spec.mb
    base = jnp.round(mag / jnp.exp2(exp.astype(jnp.float32))).astype(jnp.int32)
    ovf = base == (1 << (spec.mb + 1))
    exp = jnp.where(ovf, exp + 1, exp)
    base = jnp.where(ovf, 1 << spec.mb, base)
    efield = jnp.clip(exp - spec.bias, 0, (1 << spec.ebits) - 1)
    mfield = base & ((1 << spec.mb) - 1)
    code = (sign << 3) | (efield << spec.mb) | mfield
    zero_bits = (efield == 0) & (mfield == 0)
    return jnp.where(zero_bits, code | 1, code).astype(jnp.uint8)


def _encode_kernel(u_ref, o_ref, *, spec, nmax):
    u = u_ref[...].astype(jnp.float32)
    u0 = u[:, 0::2]
    u1 = u[:, 1::2]
    a0, a1 = jnp.abs(u0), jnp.abs(u1)
    o0, o1 = a0 > nmax, a1 > nmax
    first_out = o0 & (~o1 | (a0 >= a1))
    second_out = o1 & ~first_out

    n0, n1 = _encode_normal_int4(u0), _encode_normal_int4(u1)
    f0, f1 = _encode_abfloat4(u0, spec), _encode_abfloat4(u1, spec)
    ident = jnp.uint8(0x8)
    c0 = jnp.where(first_out, f0, jnp.where(second_out, ident, n0))
    c1 = jnp.where(second_out, f1, jnp.where(first_out, ident, n1))
    o_ref[...] = (c0 << 4) | (c1 & jnp.uint8(0xF))


def ovp_encode_pallas(u: jax.Array, normal_dtype: str = "int4",
                      spec: AbfloatSpec | None = None,
                      bm: int = 256, bk: int = 512,
                      interpret: bool = False) -> jax.Array:
    """u: (M, K) scaled values -> (M, K/2) packed uint8. int4 normals only
    (the serving activation path; flint4 activations are not used by the
    paper either)."""
    assert normal_dtype == "int4", "encoder kernel targets int4 activations"
    spec = ABFLOAT_FOR_NORMAL[normal_dtype] if spec is None else spec
    m, k = u.shape
    bm, bk = min(bm, m), min(bk, k)
    bk2 = bk // 2
    grid = (m // bm, (k // 2) // bk2)
    kernel = functools.partial(_encode_kernel, spec=spec,
                               nmax=float(NORMAL_MAX[normal_dtype]))
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((bm, bk), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((bm, bk2), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, k // 2), jnp.uint8),
        interpret=interpret,
    )(u)
