"""Pure-jnp oracles for the Pallas kernels.

The kernels compute in *scaled units* (codes decoded, scales applied by the
wrapper); these oracles mirror that contract exactly so allclose tests are
meaningful bit-for-bit (fp32 accumulate on both sides).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.datatypes import ABFLOAT_FOR_NORMAL
from repro.core.ovp import (QuantizedTensor, ovp_decode_codes,
                            ovp_encode_codes, pack4, unpack4)


def decode_packed(packed: jax.Array, normal_dtype: str,
                  pair_axis: int) -> jax.Array:
    """uint8 packed codes -> decoded values in scaled units (float32)."""
    codes = unpack4(packed, pair_axis) if normal_dtype != "int8" else packed
    return ovp_decode_codes(codes, normal_dtype, pair_axis=pair_axis)


def ovp_matmul_w4a16_ref(a: jax.Array, w_packed: jax.Array,
                         normal_dtype: str = "int4") -> jax.Array:
    """a: (M, K) float; w_packed: (K/2, N) uint8 (paired along K).

    Returns (M, N) float32 in w-scaled units (caller applies w scales).
    """
    wd = decode_packed(w_packed, normal_dtype, pair_axis=0)
    return jnp.matmul(a.astype(jnp.float32), wd,
                      preferred_element_type=jnp.float32)


def ovp_matmul_w4a4_ref(a_packed: jax.Array, w_packed: jax.Array,
                        normal_dtype: str = "int4") -> jax.Array:
    """a_packed: (M, K/2) uint8 (paired along K); w_packed: (K/2, N) uint8.

    Returns (M, N) float32 in (a·w)-scaled units.
    """
    ad = decode_packed(a_packed, normal_dtype, pair_axis=1)
    wd = decode_packed(w_packed, normal_dtype, pair_axis=0)
    return jnp.matmul(ad, wd, preferred_element_type=jnp.float32)


def ovp_encode_ref(u: jax.Array, normal_dtype: str = "int4") -> jax.Array:
    """u: (M, K) scaled values -> (M, K/2) packed uint8 codes."""
    codes = ovp_encode_codes(u, normal_dtype, pair_axis=-1)
    return pack4(codes, pair_axis=-1)


def matmul_ref(a: jax.Array, w: jax.Array) -> jax.Array:
    return jnp.matmul(a.astype(jnp.float32), w.astype(jnp.float32),
                      preferred_element_type=jnp.float32)
