"""Fused decode-attention Pallas kernel over (optionally OVP-packed) KV
caches — the serving decode path that makes the 4-bit cache pay for itself.

The problem it fixes: the seed decode path dequantized the ENTIRE packed
(B, max_len, Hkv, D) cache to bf16 every step, for every layer, before
attention ran as a plain XLA einsum — rematerializing exactly the dense
tensor the 4-bit cache was supposed to eliminate (the decode HBM term came
back, plus a full-cache decode dispatch per layer per token).

This kernel reads the packed `k_data`/`v_data` nibbles and the
per-(token, head) scales straight from HBM and unpacks/dequantizes PER KV
TILE in VMEM, inside the same kernel that consumes them:

  grid      — (batch, kv_head, S/bs) with the kv-tile dim innermost, so
              the (b, h) output block stays resident in VMEM while tiles
              stream through; one `pallas_call` per layer per step.
  prologue  — a packed tile decodes branch-free on the VPU (same
              nibble-plane trick as `ovp_matmul`: even K-lanes in the high
              nibbles, odd in the low, so no interleaving relayout is ever
              needed); fp16/bf16 caches take the same kernel minus the
              unpack phase (the planes are strided slices of the fp tile).
  body      — online-softmax accumulation in f32: scores fold the
              per-token K scale in (s = (q @ k_codes^T) * k_scl), the
              probabilities fold the V scale (p * v_scl) so decoded code
              planes feed the MXU directly.
  masking   — length / ring / sliding-window validity is computed
              IN-KERNEL from the traced `pos`, so ONE compiled kernel
              serves every active-length mix in the batch (continuous
              batching never retraces on request churn).
  epilogue  — the accumulator normalizes by the softmax denominator on
              the last tile.

HBM read per decode step for the packed path drops ~4x vs the dequant
path (1 byte per 2 values + one f32 scale per (token, head) vs 2-4 bytes
per value), and the full-cache dequant materialization disappears.

Outputs keep the even/odd plane layout (first D/2 lanes = even K-lanes);
the public wrapper re-interleaves once on the (B, 1, H, D) result.

PAGED CACHES (serve/paging.py): a paged cache stores its K/V data as a
global `(n_pages, page_size, Hkv, …)` pool plus a per-row block table
`(B, pages_per_row)` int32 mapping logical page j (token rows
[j*page_size, (j+1)*page_size)) to a physical page. Because this kernel
already streams one kv tile per grid step, paging is ONE INDIRECTION on
the kv-tile grid dim: the block table rides in as a scalar-prefetch
operand and the kv BlockSpec index map reads `table[b, ss]` instead of
`ss` — page size == kv tile size, so each gather is a whole tile and the
kernel bodies (unpack, scores, online softmax, masking) are shared
verbatim with the slab path. Logical slot arithmetic is unchanged
(`program_id(2) * page_size + iota`), so length/ring/window masking and
bit-for-bit equivalence with the slab kernel at `block_s == page_size`
come for free.

`xla_decode_attention` below is the dense fallback (full-cache dequant +
einsum) that non-kernel backends serve and declined layouts fall back to
— for paged caches it first materializes the pages into a slab
(`gather_paged_cache`), so every backend serves bit-identical results;
`models/layers.py::decode_attention` routes between them through the
backend registry (see docs/kv_cache.md for the decline vocabulary).
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.datatypes import ABFLOAT_FOR_NORMAL
from repro.core.ovp import ovp_decode_codes, unpack4
from .ovp_matmul import decode_nibble_planes

NEG_INF = -1e30

# KV dtype the packed cache encodes with (see layers._quant_kv_token)
KV_NORMAL_DTYPE = "int4"


# --------------------------------------------------------------------------
# Dense (XLA) path: full-cache dequant + einsum. This is the fallback the
# paper's critics describe — kept as the reference and the decline target.
# --------------------------------------------------------------------------
def dequant_kv(data: jax.Array, scl: jax.Array) -> jax.Array:
    """Packed (…, T, Hkv, D/2) nibbles + (…, T, Hkv) scales -> f32 values.

    This materializes the WHOLE dense tensor — fine for tests and the XLA
    fallback, but never traced in a fused-kernel decode step (the
    zero-dequant acceptance test asserts exactly that)."""
    vals = ovp_decode_codes(unpack4(data, -1), KV_NORMAL_DTYPE, pair_axis=-1)
    return vals * scl[..., None]


def gather_paged_cache(cache):
    """Materialize a paged cache into a `(B, pages_per_row * page_size,
    …)` slab dict — the dense fallback's view of the pool.

    One `jnp.take` per leaf through the block table; this is exactly the
    per-step HBM rematerialization the paged kernel avoids, kept so
    non-kernel backends serve bit-identical results on paged caches."""
    bt = cache["block_table"]                       # (B, pages_per_row)
    b, n = bt.shape
    out = {}
    for key in ("k", "v", "k_data", "v_data", "k_scl", "v_scl"):
        if key in cache:
            pool = cache[key]                       # (P, ps, …)
            flat = jnp.take(pool, bt.reshape(-1), axis=0)
            out[key] = flat.reshape((b, n * pool.shape[1]) + pool.shape[2:])
    return out


def read_cache_dense(cache, dtype=None):
    """(k, v) dense views of a KV cache dict (fp or OVP-packed; paged
    caches materialize through the block table first).

    dtype=None keeps fp caches in their native dtype; packed caches decode
    to bf16 (matching the seed `cache_read` contract)."""
    if "block_table" in cache:
        cache = gather_paged_cache(cache)
    if "k" in cache:
        k, v = cache["k"], cache["v"]
        if dtype is None:
            return k, v
        return k.astype(dtype), v.astype(dtype)
    kd = dequant_kv(cache["k_data"], cache["k_scl"])
    vd = dequant_kv(cache["v_data"], cache["v_scl"])
    if dtype is None:
        dtype = jnp.bfloat16
    return kd.astype(dtype), vd.astype(dtype)


def slot_validity(pos: jax.Array, slots: jax.Array, *, window: int,
                  ring: int):
    """(abs_pos, valid) for cache slots given per-row `pos` (B,).

    Shared by the dense path and the tests; the kernel computes the same
    arithmetic on its per-tile iota. `ring` > 0 means slot i holds the
    largest p' <= pos with p' % ring == i; otherwise slot i is position i.
    """
    p = pos[:, None]
    if ring:
        abs_pos = p - ((p - slots[None, :]) % ring)
        valid = abs_pos >= 0
    else:
        abs_pos = jnp.broadcast_to(slots[None, :],
                                   (pos.shape[0], slots.shape[0]))
        valid = abs_pos <= p
    if window:
        valid = valid & (abs_pos > p - window) & (abs_pos <= p)
    return abs_pos, valid


def xla_decode_attention(q: jax.Array, cache, pos: jax.Array, *,
                         window: int = 0, ring: int = 0) -> jax.Array:
    """Single-token attention over a cache, dense XLA path.

    q: (B, 1, H, D); pos: (B,) current absolute position (token at `pos`
    already written). Dequantizes the whole cache first — the decode HBM
    term the fused kernel exists to remove. Paged caches materialize into
    a slab through the block table (and trim to the ring length: the pool
    rounds a ring up to whole pages, and the modular slot arithmetic must
    never see the rounding tail).
    """
    if "block_table" in cache:
        cache = gather_paged_cache(cache)
        if ring:
            cache = {key: leaf[:, :ring] for key, leaf in cache.items()}
    k, v = read_cache_dense(cache, dtype=None)
    b, s_len, hkv, d = k.shape
    h = q.shape[2]
    g = h // hkv
    scale = 1.0 / math.sqrt(d)
    qg = q.reshape(b, 1, hkv, g, d)
    s = jnp.einsum("bqhgd,bshd->bhgqs", qg.astype(k.dtype), k,
                   preferred_element_type=jnp.float32) * scale
    _, valid = slot_validity(pos, jnp.arange(s_len), window=window,
                             ring=ring)
    s = jnp.where(valid[:, None, None, None, :], s, NEG_INF)
    p_att = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgqs,bshd->bqhgd", p_att.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, 1, h, d).astype(q.dtype)


# --------------------------------------------------------------------------
# Decline vocabulary (machine-readable; recorded in dispatch_stats())
# --------------------------------------------------------------------------
def decline_reason(q: jax.Array, cache) -> Optional[str]:
    """None when the fused kernel can serve this (q, cache) layout; codes
    are registered in `backends/base.py::DECLINE_CODES["decode_attn"]`
    (validated by `_registered` below and re-checked at the backend
    boundary by `decline()`)."""
    return _registered(_decline_reason(q, cache))


def _registered(code: Optional[str]) -> Optional[str]:
    # lazy import: backends imports this module at registry construction,
    # so a module-level `from repro.backends.base import decline` would
    # cycle; by the first dispatch the registry is fully imported
    from repro.backends.base import decline
    return decline(code)


def _decline_reason(q: jax.Array, cache) -> Optional[str]:
    if q.shape[1] != 1:
        return "decode_q_tokens_gt_1"
    paged = "block_table" in cache
    leaf = cache.get("k", cache.get("k_data"))
    if leaf is None:
        # a table with no pool behind it is malformed paging, not a
        # missing cache — the distinct code routes the caller to the
        # pool construction, not the cache construction
        return "paged_no_pool" if paged else "decode_no_kv_cache"
    if paged:
        bt = cache["block_table"]
        if bt.ndim != 2 or not jnp.issubdtype(bt.dtype, jnp.integer):
            return "paged_table_rank"
        if leaf.shape[0] == 0 or bt.shape[1] == 0:
            return "decode_empty_cache"
        if leaf.shape[1] < 2 or leaf.shape[1] % 2 != 0:
            # page size IS the kv tile size; odd tiles break the even/odd
            # lane tiling the TPU layouts want (PagePoolCfg enforces the
            # same invariant at pool construction)
            return "paged_page_misaligned"
    elif leaf.shape[1] == 0:
        return "decode_empty_cache"
    if "k" in cache and cache["k"].shape[-1] % 2 != 0:
        # the shared even/odd-plane body needs an even head_dim (packed
        # caches are guaranteed even at construction)
        return "decode_head_dim_odd"
    return None


# --------------------------------------------------------------------------
# Kernel bodies. Blocks carry `bh` kv heads (default 1 — one head per grid
# step, the TPU-parallel layout; interpret mode folds all heads into one
# tile to amortize the per-grid-step interpreter overhead — numerics are
# identical, it is a block-size tunable exactly like bm/bn/bk in the
# matmul kernel).
# --------------------------------------------------------------------------
_BATCHED = (((2,), (2,)), ((0,), (1,)))   # (bh,G,x) @ (bs,bh,x) -> (bh,G,bs)
_BATCHED_PV = (((2,), (0,)), ((0,), (1,)))  # (bh,G,bs) @ (bs,bh,x)


def _online_softmax_step(s, v_even, v_odd, v_scl, o_ref, m_ref, l_ref,
                         d2: int):
    """One kv-tile online-softmax update against the (b, h-block) output.

    s: (bh, G, bs) masked scores; v_even/v_odd: (bs, bh, D/2) decoded
    value planes; v_scl: (bs, bh) per-token V scale or None (fp caches).
    """
    m_prev = m_ref[0]                                      # (bh, G, 1)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)                                 # (bh, G, bs)
    corr = jnp.exp(m_prev - m_new)                         # (bh, G, 1)
    l_ref[0] = l_ref[0] * corr + jnp.sum(p, axis=-1, keepdims=True)
    m_ref[0] = m_new
    if v_scl is not None:
        p = p * jnp.transpose(v_scl)[:, None, :]
    o_ref[0, :, :, :d2] = o_ref[0, :, :, :d2] * corr + jax.lax.dot_general(
        p, v_even, _BATCHED_PV, preferred_element_type=jnp.float32)
    o_ref[0, :, :, d2:] = o_ref[0, :, :, d2:] * corr + jax.lax.dot_general(
        p, v_odd, _BATCHED_PV, preferred_element_type=jnp.float32)


def _tile_mask(pos, bs: int, s_len: int, window: int, ring: int):
    """(1, 1, bs) validity of this tile's slots at traced position `pos`."""
    slot = pl.program_id(2) * bs + jax.lax.broadcasted_iota(
        jnp.int32, (1, 1, bs), 2)
    if ring:
        abs_pos = pos - ((pos - slot) % ring)
        valid = abs_pos >= 0
    else:
        abs_pos = slot
        valid = slot <= pos
    valid = valid & (slot < s_len)                 # padded tail slots
    if window:
        valid = valid & (abs_pos > pos - window) & (abs_pos <= pos)
    return valid


def _scores(q_tile, k_even, k_odd):
    """(bh, G, D) query block x (bs, bh, D/2) key planes -> (bh, G, bs)
    f32 scores (query even lanes live in [..., :D/2], plane layout)."""
    d2 = k_even.shape[-1]
    return (jax.lax.dot_general(q_tile[..., :d2], k_even, _BATCHED,
                                preferred_element_type=jnp.float32)
            + jax.lax.dot_general(q_tile[..., d2:], k_odd, _BATCHED,
                                  preferred_element_type=jnp.float32))


def _finish(o_ref, l_ref):
    @pl.when(pl.program_id(2) == pl.num_programs(2) - 1)
    def _norm():
        o_ref[0] = o_ref[0] / jnp.maximum(l_ref[0], 1e-30)


def _init_carry(o_ref, m_ref, l_ref):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)


def _decode_attn_kernel_packed(q_ref, kd_ref, vd_ref, ks_ref, vs_ref,
                               pos_ref, o_ref, m_ref, l_ref, *,
                               bs: int, s_len: int, window: int, ring: int):
    """One (batch, head_block, kv_tile) grid step over an OVP-packed cache.

    q_ref  (1, bh, G, D)    f32 query block, pre-scaled by 1/sqrt(D), with
                            even K-lanes in [..., :D/2] (plane layout)
    kd/vd  (1, bs, bh, D/2) packed nibble tiles (streamed HBM->VMEM)
    ks/vs  (1, bs, bh)      per-(token, head) 3-sigma scales
    pos    (1, 1)           this row's current absolute position
    o_ref  (1, bh, G, D)    f32 accumulator in even/odd plane layout
    m/l    (1, bh, G, 1)    online-softmax running max / denominator
    """
    _init_carry(o_ref, m_ref, l_ref)
    spec = ABFLOAT_FOR_NORMAL[KV_NORMAL_DTYPE]
    k_even, k_odd = decode_nibble_planes(kd_ref[0], KV_NORMAL_DTYPE, spec)
    v_even, v_odd = decode_nibble_planes(vd_ref[0], KV_NORMAL_DTYPE, spec)
    # fold the per-token K scale into the scores, the V scale into the
    # probabilities — the decoded code planes feed the MXU directly
    s = _scores(q_ref[0], k_even, k_odd) \
        * jnp.transpose(ks_ref[0])[:, None, :]
    valid = _tile_mask(pos_ref[0, 0], bs, s_len, window, ring)
    s = jnp.where(valid, s, NEG_INF)
    _online_softmax_step(s, v_even, v_odd, vs_ref[0], o_ref, m_ref,
                         l_ref, k_even.shape[-1])
    _finish(o_ref, l_ref)


def _decode_attn_kernel_fp(q_ref, k_ref, v_ref, pos_ref, o_ref, m_ref,
                           l_ref, *, bs: int, s_len: int, window: int,
                           ring: int):
    """fp16/bf16/f32 cache variant: same body minus the unpack phase —
    the even/odd planes are strided slices of the fp tile."""
    _init_carry(o_ref, m_ref, l_ref)
    kt = k_ref[0].astype(jnp.float32)                      # (bs, bh, D)
    vt = v_ref[0].astype(jnp.float32)
    s = _scores(q_ref[0], kt[..., 0::2], kt[..., 1::2])
    valid = _tile_mask(pos_ref[0, 0], bs, s_len, window, ring)
    s = jnp.where(valid, s, NEG_INF)
    _online_softmax_step(s, vt[..., 0::2], vt[..., 1::2], None, o_ref,
                         m_ref, l_ref, kt.shape[-1] // 2)
    _finish(o_ref, l_ref)


# --------------------------------------------------------------------------
# pallas_call builder + public wrapper
# --------------------------------------------------------------------------
@functools.partial(jax.jit, static_argnames=("packed", "s_len", "window",
                                             "ring", "bs", "bh",
                                             "interpret"))
def _decode_attn_call(q4, kd, vd, ks, vs, pos2, *, packed: bool,
                      s_len: int, window: int, ring: int, bs: int,
                      bh: int, interpret: bool):
    """q4 (B, Hkv, G, D) f32 plane-layout queries; kd/vd the (padded)
    cache data; ks/vs (B, Sp, Hkv) scales (fp caches pass (1, 1, 1)
    sentinels — the fp branch never reads them); pos2 (B, 1) int32.
    Returns (B, Hkv, G, D) f32 in plane layout."""
    b, hkv, g, d = q4.shape
    sp = kd.shape[1]
    grid = (b, hkv // bh, sp // bs)
    kv_spec = pl.BlockSpec((1, bs, bh, kd.shape[-1]),
                           lambda bb, hh, ss: (bb, ss, hh, 0))
    scl_spec = pl.BlockSpec((1, bs, bh), lambda bb, hh, ss: (bb, ss, hh))
    q_spec = pl.BlockSpec((1, bh, g, d), lambda bb, hh, ss: (bb, hh, 0, 0))
    pos_spec = pl.BlockSpec((1, 1), lambda bb, hh, ss: (bb, 0))
    carry_spec = pl.BlockSpec((1, bh, g, 1),
                              lambda bb, hh, ss: (bb, hh, 0, 0))
    out_shapes = (jax.ShapeDtypeStruct((b, hkv, g, d), jnp.float32),
                  jax.ShapeDtypeStruct((b, hkv, g, 1), jnp.float32),
                  jax.ShapeDtypeStruct((b, hkv, g, 1), jnp.float32))
    out_specs = (pl.BlockSpec((1, bh, g, d),
                              lambda bb, hh, ss: (bb, hh, 0, 0)),
                 carry_spec, carry_spec)
    if packed:
        kernel = functools.partial(_decode_attn_kernel_packed, bs=bs,
                                   s_len=s_len, window=window, ring=ring)
        out, _, _ = pl.pallas_call(
            kernel, grid=grid,
            in_specs=[q_spec, kv_spec, kv_spec, scl_spec, scl_spec,
                      pos_spec],
            out_specs=out_specs, out_shape=out_shapes,
            interpret=interpret)(q4, kd, vd, ks, vs, pos2)
    else:
        kernel = functools.partial(_decode_attn_kernel_fp, bs=bs,
                                   s_len=s_len, window=window, ring=ring)
        out, _, _ = pl.pallas_call(
            kernel, grid=grid,
            in_specs=[q_spec, kv_spec, kv_spec, pos_spec],
            out_specs=out_specs, out_shape=out_shapes,
            interpret=interpret)(q4, kd, vd, pos2)
    return out


@functools.partial(jax.jit, static_argnames=("packed", "s_len", "window",
                                             "ring", "ps", "bh",
                                             "interpret"))
def _paged_decode_attn_call(bt, q4, kd, vd, ks, vs, pos2, *, packed: bool,
                            s_len: int, window: int, ring: int, ps: int,
                            bh: int, interpret: bool):
    """Paged twin of `_decode_attn_call`: identical kernel bodies, but the
    kv/scale BlockSpec index maps read the physical page id from the
    block table (`bt`, a scalar-prefetch operand) instead of using the
    grid's kv-tile index directly. kd/vd/ks/vs are the `(n_pages,
    page_size, Hkv, …)` pools; one whole page == one kv tile, so the
    gather costs nothing beyond the index indirection."""
    b, hkv, g, d = q4.shape
    n = bt.shape[1]
    grid = (b, hkv // bh, n)
    kv_spec = pl.BlockSpec((1, ps, bh, kd.shape[-1]),
                           lambda bb, hh, ss, tbl: (tbl[bb, ss], 0, hh, 0))
    scl_spec = pl.BlockSpec((1, ps, bh),
                            lambda bb, hh, ss, tbl: (tbl[bb, ss], 0, hh))
    q_spec = pl.BlockSpec((1, bh, g, d),
                          lambda bb, hh, ss, tbl: (bb, hh, 0, 0))
    pos_spec = pl.BlockSpec((1, 1), lambda bb, hh, ss, tbl: (bb, 0))
    carry_spec = pl.BlockSpec((1, bh, g, 1),
                              lambda bb, hh, ss, tbl: (bb, hh, 0, 0))
    out_shapes = (jax.ShapeDtypeStruct((b, hkv, g, d), jnp.float32),
                  jax.ShapeDtypeStruct((b, hkv, g, 1), jnp.float32),
                  jax.ShapeDtypeStruct((b, hkv, g, 1), jnp.float32))
    out_specs = (pl.BlockSpec((1, bh, g, d),
                              lambda bb, hh, ss, tbl: (bb, hh, 0, 0)),
                 carry_spec, carry_spec)
    if packed:
        body = functools.partial(_decode_attn_kernel_packed, bs=ps,
                                 s_len=s_len, window=window, ring=ring)

        def kernel(tbl_ref, *refs):
            body(*refs)

        in_specs = [q_spec, kv_spec, kv_spec, scl_spec, scl_spec, pos_spec]
        operands = (bt, q4, kd, vd, ks, vs, pos2)
    else:
        body = functools.partial(_decode_attn_kernel_fp, bs=ps,
                                 s_len=s_len, window=window, ring=ring)

        def kernel(tbl_ref, *refs):
            body(*refs)

        in_specs = [q_spec, kv_spec, kv_spec, pos_spec]
        operands = (bt, q4, kd, vd, pos2)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1, grid=grid, in_specs=in_specs,
        out_specs=out_specs)
    out, _, _ = pl.pallas_call(kernel, grid_spec=grid_spec,
                               out_shape=out_shapes,
                               interpret=interpret)(*operands)
    return out


def _pad_s(x, mult, value=0):
    rem = (-x.shape[1]) % mult
    if rem == 0:
        return x
    pads = [(0, 0)] * x.ndim
    pads[1] = (0, rem)
    return jnp.pad(x, pads, constant_values=value)


def _pick_bs(s_len: int, block_s: int) -> int:
    """kv-tile size: the largest divisor of `s_len` <= block_s when a
    reasonable one exists, else block_s (padding kicks in).

    A non-divisor tile forces `_pad_s` to copy the WHOLE cache every
    traced decode step — a per-step full-cache HBM round trip that
    defeats the point of the kernel — so exact tiling wins whenever the
    divisor keeps the grid sane; in-kernel masking covers the padded
    remainder for pathological (e.g. prime) cache lengths."""
    bs = min(block_s, s_len)
    if s_len % bs == 0:
        return bs
    for cand in range(bs, 0, -1):
        if s_len % cand == 0:
            return cand if cand >= min(64, s_len) else bs
    return bs


def fused_decode_attention(q: jax.Array, cache, pos: jax.Array, *,
                           window: int = 0, ring: int = 0,
                           interpret: bool = False,
                           block_s: int = 256,
                           block_h: int = 0) -> jax.Array:
    """Single-token attention over a KV cache, one pallas_call.

    q: (B, 1, H, D); `cache` an fp ({"k", "v"}) or OVP-packed
    ({"k_data", "v_data", "k_scl", "v_scl"}) cache dict; pos: (B,)
    current absolute position (token at `pos` already written). Length,
    ring and sliding-window masking run in-kernel from the traced `pos`.
    Layout preconditions are `decline_reason`'s job — callers go through
    `backends.decode_attention`, which falls back on a reason code.

    `block_s`/`block_h` tile the kv and head dims. block_h=0 picks the
    default: 1 head per grid step when compiled (TPU-parallel), all heads
    per step under the interpreter (amortizes per-grid-step emulation
    overhead; numerics identical).
    """
    b, t, h, d = q.shape
    packed = "k_data" in cache
    paged = "block_table" in cache
    kd = cache["k_data"] if packed else cache["k"]
    vd = cache["v_data"] if packed else cache["v"]
    hkv = kd.shape[2]
    g = h // hkv
    if block_h == 0:
        block_h = hkv if interpret else 1
    bh = min(block_h, hkv)
    if hkv % bh:
        bh = 1
    qf = q.reshape(b, hkv, g, d).astype(jnp.float32) / math.sqrt(d)
    # even/odd plane layout: q[..., :d/2] multiplies the even K-lanes
    qf = jnp.concatenate([qf[..., 0::2], qf[..., 1::2]], axis=-1)
    pos2 = pos.reshape(b, 1).astype(jnp.int32)
    if paged:
        # page size IS the kv tile size: no padding, no _pick_bs — each
        # grid step gathers one whole physical page through the table.
        # Logical capacity is pages_per_row * page_size; a ring cache's
        # true length is the ring (the pool rounds it up to whole pages
        # and the mask must exclude the rounding tail).
        bt = cache["block_table"].astype(jnp.int32)
        ps = kd.shape[1]
        s_len = ring if ring else bt.shape[1] * ps
        if packed:
            ks, vs = cache["k_scl"], cache["v_scl"]
        else:
            ks = vs = jnp.zeros((1, 1, 1), jnp.float32)
        out = _paged_decode_attn_call(bt, qf, kd, vd, ks, vs, pos2,
                                      packed=packed, s_len=s_len,
                                      window=window, ring=ring, ps=ps,
                                      bh=bh, interpret=interpret)
    else:
        s_len = kd.shape[1]
        bs = _pick_bs(s_len, block_s)
        kd, vd = _pad_s(kd, bs), _pad_s(vd, bs)
        if packed:
            ks = _pad_s(cache["k_scl"], bs, value=1.0)
            vs = _pad_s(cache["v_scl"], bs, value=1.0)
        else:
            # the fp kernel takes no scale refs; tiny sentinels keep the
            # jitted call signature uniform without materializing scale
            # planes
            ks = vs = jnp.zeros((1, 1, 1), jnp.float32)
        out = _decode_attn_call(qf, kd, vd, ks, vs, pos2, packed=packed,
                                s_len=s_len, window=window, ring=ring,
                                bs=bs, bh=bh, interpret=interpret)
    d2 = d // 2
    out = jnp.stack([out[..., :d2], out[..., d2:]], axis=-1)
    return out.reshape(b, hkv, g, d).reshape(b, t, h, d).astype(q.dtype)
