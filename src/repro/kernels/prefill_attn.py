"""Fused cache-write prefill kernel: OVP quantize-and-page + blockwise
causal attention in ONE `pallas_call` per cache site.

The slab engine prefills through a round trip this kernel deletes: run
blockwise attention over the prompt, quantize K/V with an XLA
encode/pack dispatch, `cache_write` into a fresh single-row cache, then
`_splice_slot` copies that row into the batched slab — the prompt's K/V
crosses HBM four times before the first decode step. Here the paged
engine hands the kernel the request's raw K/V *stage* and its block-table
row, and one kernel both:

  writes  — every stage tile quantizes IN-KERNEL (the same per-(token,
            head) 3σ scale + Algorithm-1 encode as `_quant_kv_token`,
            so paged bytes are bit-identical to slab bytes) and lands on
            its physical page through the block table (scalar-prefetch
            output index map; the pool is input/output-aliased so
            untouched pages keep their contents).
  attends — blockwise causal attention of the chunk's queries over the
            RAW stage values (exactly what the slab path attends), with
            online-softmax accumulation per stage tile.

CHUNKED PREFILL semantics: the stage `(1, S, Hkv, D)` holds the raw K/V
of every token of this request prefilled SO FAR (the engine appends each
chunk before the call). The kernel re-quantizes and rewrites history
pages on every chunk — quantization is deterministic per token row, so
the rewrite is byte-idempotent, and uniform tiles keep one trace per
stage length serving every chunk index (the chunk offset arrives as a
traced operand, only in the causal mask). Attention reads the raw stage,
not the quantized pages, so chunked prefill is mathematically the
standard causal forward computed in pieces — chunk boundaries never
inject quantization noise the slab path doesn't have.

`xla_prefill_attention` is the dense twin every backend can serve
(masked einsum + whole-stage quantize + page scatter): bit-identical
page bytes, attention equal up to softmax reassociation. Dispatch picks
between them via `backends.prefill_attention` (decline codes in
`prefill_decline_reason`; see docs/kv_cache.md).
"""
from __future__ import annotations

import functools
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.ovp import ovp_encode_codes, pack4
from .decode_attn import KV_NORMAL_DTYPE, NEG_INF

STAGE_KEYS = ("stage_k", "stage_v")


def is_paged_prefill(cache) -> bool:
    return cache is not None and "block_table" in cache \
        and "stage_k" in cache


def prefill_decline_reason(q: jax.Array, cache) -> Optional[str]:
    """None when the fused prefill kernel serves this (q, cache) layout.

    The fused path exists for PAGED caches (slab prefill keeps the
    blockwise-attention + splice pipeline); codes are registered in
    `backends/base.py::DECLINE_CODES` and validated on return."""
    from repro.kernels.decode_attn import _registered
    return _registered(_prefill_decline_reason(q, cache))


def _prefill_decline_reason(q: jax.Array, cache) -> Optional[str]:
    if cache is None or "block_table" not in cache:
        return "prefill_not_paged"
    if "stage_k" not in cache or "stage_v" not in cache:
        return "prefill_no_stage"
    if q.shape[0] != 1:
        return "prefill_batch_gt_1"
    pool = cache.get("k", cache.get("k_data"))
    if pool is None:
        return "paged_no_pool"
    ps = pool.shape[1]
    if ps < 2 or ps % 2:
        return "paged_page_misaligned"
    s = cache["stage_k"].shape[1]
    if s % ps or cache["block_table"].shape[1] < s // ps:
        # stage must tile exactly onto pages and the table must back
        # every stage tile with a physical page
        return "prefill_stage_misaligned"
    if "k" in cache and cache["k"].shape[-1] % 2:
        return "decode_head_dim_odd"
    return None


# --------------------------------------------------------------------------
# Kernel bodies: grid (Hkv/bh, n_stage_tiles), kv-tile dim innermost.
# --------------------------------------------------------------------------
_QK = (((3,), (2,)), ((0,), (1,)))   # (bh,G,C,D) @ (ps,bh,D) -> (bh,G,C,ps)
_PV = (((3,), (0,)), ((0,), (1,)))   # (bh,G,C,ps) @ (ps,bh,D) -> (bh,G,C,D)


def _quant_tile(xt):
    """(ps, bh, D) raw f32 tile -> (packed (ps, bh, D/2) u8, scale
    (ps, bh) f32). Identical arithmetic to layers._quant_kv_token, so the
    page bytes match the slab cache bytes bit-for-bit."""
    s = jnp.maximum(3.0 * jnp.std(xt, axis=-1) / 7.0, 1e-6)
    codes = ovp_encode_codes(xt / s[..., None], KV_NORMAL_DTYPE,
                             pair_axis=-1)
    return pack4(codes, pair_axis=-1), s


def _attend_tile(q_ref, kt, vt, off_ref, o_ref, m_ref, l_ref, *, ps: int):
    """One online-softmax step of the chunk queries against one raw
    stage tile, causal on absolute positions (qpos = off + row)."""
    @pl.when(pl.program_id(1) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    c = q_ref.shape[3]
    kpos = pl.program_id(1) * ps + jax.lax.broadcasted_iota(
        jnp.int32, (1, 1, 1, ps), 3)
    qpos = off_ref[0, 0] + jax.lax.broadcasted_iota(
        jnp.int32, (1, 1, c, 1), 2)
    s = jax.lax.dot_general(q_ref[0], kt, _QK,
                            preferred_element_type=jnp.float32)
    s = jnp.where(kpos <= qpos, s, NEG_INF)        # (bh, G, C, ps)
    m_prev = m_ref[0]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_ref[0] = l_ref[0] * corr + jnp.sum(p, axis=-1, keepdims=True)
    m_ref[0] = m_new
    o_ref[0] = o_ref[0] * corr + jax.lax.dot_general(
        p, vt, _PV, preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(1) == pl.num_programs(1) - 1)
    def _norm():
        o_ref[0] = o_ref[0] / jnp.maximum(l_ref[0], 1e-30)


def _prefill_kernel_packed(tbl_ref, q_ref, ksg_ref, vsg_ref, off_ref,
                           kdp_ref, vdp_ref, ksp_ref, vsp_ref,
                           o_ref, m_ref, l_ref,
                           kd_ref, vd_ref, ks_ref, vs_ref, *, ps: int):
    """q (1,bh,G,C,D) pre-scaled; ksg/vsg (1,ps,bh,D) raw stage tiles;
    kd/vd/ks/vs out blocks land on page tbl[0, tile] (aliased pool)."""
    kt = ksg_ref[0].astype(jnp.float32)
    vt = vsg_ref[0].astype(jnp.float32)
    kd_ref[0], ks_ref[0] = _quant_tile(kt)
    vd_ref[0], vs_ref[0] = _quant_tile(vt)
    _attend_tile(q_ref, kt, vt, off_ref, o_ref, m_ref, l_ref, ps=ps)


def _prefill_kernel_fp(tbl_ref, q_ref, ksg_ref, vsg_ref, off_ref,
                       kp_ref, vp_ref, o_ref, m_ref, l_ref,
                       k_ref, v_ref, *, ps: int):
    kt = ksg_ref[0].astype(jnp.float32)
    vt = vsg_ref[0].astype(jnp.float32)
    k_ref[0] = kt.astype(k_ref.dtype)
    v_ref[0] = vt.astype(v_ref.dtype)
    _attend_tile(q_ref, kt, vt, off_ref, o_ref, m_ref, l_ref, ps=ps)


# --------------------------------------------------------------------------
# pallas_call builder + public wrappers
# --------------------------------------------------------------------------
@functools.partial(jax.jit, static_argnames=("packed", "ps", "n_tiles",
                                             "bh", "interpret"))
def _prefill_call(bt, q5, ksg, vsg, off2, pools, *, packed: bool, ps: int,
                  n_tiles: int, bh: int, interpret: bool):
    """q5 (1, Hkv, G, C, D) f32 pre-scaled; ksg/vsg (1, S, Hkv, D) raw
    stage; off2 (1, 1) chunk offset; pools the pool leaves (aliased
    through to the outputs). Returns (out5, new_pools)."""
    _, hkv, g, c, d = q5.shape
    grid = (hkv // bh, n_tiles)
    q_spec = pl.BlockSpec((1, bh, g, c, d),
                          lambda hh, ss, tbl: (0, hh, 0, 0, 0))
    stage_spec = pl.BlockSpec((1, ps, bh, d),
                              lambda hh, ss, tbl: (0, ss, hh, 0))
    off_spec = pl.BlockSpec((1, 1), lambda hh, ss, tbl: (0, 0))
    carry_spec = pl.BlockSpec((1, bh, g, c, 1),
                              lambda hh, ss, tbl: (0, hh, 0, 0, 0))
    o_spec = pl.BlockSpec((1, bh, g, c, d),
                          lambda hh, ss, tbl: (0, hh, 0, 0, 0))
    page_spec = pl.BlockSpec((1, ps, bh, pools[0].shape[-1]),
                             lambda hh, ss, tbl: (tbl[0, ss], 0, hh, 0))
    scl_spec = pl.BlockSpec((1, ps, bh),
                            lambda hh, ss, tbl: (tbl[0, ss], 0, hh))
    carry_shape = jax.ShapeDtypeStruct((1, hkv, g, c, 1), jnp.float32)
    o_shape = jax.ShapeDtypeStruct((1, hkv, g, c, d), jnp.float32)
    pool_shapes = tuple(jax.ShapeDtypeStruct(p.shape, p.dtype)
                        for p in pools)
    pool_specs = tuple(scl_spec if p.ndim == 3 else page_spec
                       for p in pools)
    kernel = functools.partial(
        _prefill_kernel_packed if packed else _prefill_kernel_fp, ps=ps)
    # pool operands sit after (bt, q5, ksg, vsg, off2); their outputs
    # after (o, m, l) — aliasing keeps pages no stage tile touches intact
    aliases = {5 + i: 3 + i for i in range(len(pools))}
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1, grid=grid,
        in_specs=[q_spec, stage_spec, stage_spec, off_spec, *pool_specs],
        out_specs=(o_spec, carry_spec, carry_spec, *pool_specs))
    res = pl.pallas_call(
        kernel, grid_spec=grid_spec,
        out_shape=(o_shape, carry_shape, carry_shape, *pool_shapes),
        input_output_aliases=aliases,
        interpret=interpret)(bt, q5, ksg, vsg, off2, *pools)
    return res[0], res[3:]


def fused_prefill_attention(q: jax.Array, cache, positions: jax.Array, *,
                            interpret: bool = False,
                            block_h: int = 0) -> Tuple[jax.Array, dict]:
    """One pallas_call: causal attention of the chunk over the raw stage
    + OVP quantize-and-write of every stage tile onto its physical page.

    q: (1, C, H, D) chunk queries (rope applied); `cache` a paged cache
    dict carrying pool leaves, a single-row "block_table" (1, n), and the
    raw "stage_k"/"stage_v" (1, S, Hkv, D) with the current chunk already
    appended; positions: (1, C) absolute positions of the chunk (the
    offset positions[0, 0] is traced — one trace per stage length serves
    every chunk index). Returns (out (1, C, H, D), new cache dict with
    updated pool leaves). Layout preconditions are
    `prefill_decline_reason`'s job — callers go through
    `backends.prefill_attention`.
    """
    b, c, h, d = q.shape
    packed = "k_data" in cache
    stage_k, stage_v = cache["stage_k"], cache["stage_v"]
    s, hkv = stage_k.shape[1], stage_k.shape[2]
    pool_keys = ("k_data", "v_data", "k_scl", "v_scl") if packed \
        else ("k", "v")
    pools = tuple(cache[key] for key in pool_keys)
    ps = pools[0].shape[1]
    n_tiles = s // ps
    g = h // hkv
    if block_h == 0:
        block_h = hkv if interpret else 1
    bh = min(block_h, hkv)
    if hkv % bh:
        bh = 1
    q5 = q.reshape(b, c, hkv, g, d).transpose(0, 2, 3, 1, 4) \
        .astype(jnp.float32) / math.sqrt(d)
    bt = cache["block_table"].astype(jnp.int32)
    off2 = positions[:, :1].astype(jnp.int32)
    out5, new_pools = _prefill_call(
        bt, q5, stage_k.astype(jnp.float32), stage_v.astype(jnp.float32),
        off2, pools, packed=packed, ps=ps, n_tiles=n_tiles, bh=bh,
        interpret=interpret)
    out = out5.transpose(0, 3, 1, 2, 4).reshape(b, c, h, d).astype(q.dtype)
    new_cache = dict(cache)
    for key, pool in zip(pool_keys, new_pools):
        new_cache[key] = pool
    return out, new_cache


# --------------------------------------------------------------------------
# Dense twin (any backend; also the decline fallback)
# --------------------------------------------------------------------------
def xla_prefill_attention(q: jax.Array, cache,
                          positions: jax.Array) -> Tuple[jax.Array, dict]:
    """Masked-einsum attention over the raw stage + whole-stage quantize
    + page scatter. Page bytes are bit-identical to the fused kernel's
    (same per-token quantization arithmetic); the attention output agrees
    up to softmax reassociation."""
    from repro.models.layers import _quant_kv_token
    b, c, h, d = q.shape
    stage_k, stage_v = cache["stage_k"], cache["stage_v"]
    s, hkv = stage_k.shape[1], stage_k.shape[2]
    g = h // hkv
    k = stage_k.astype(jnp.float32)
    v = stage_v.astype(jnp.float32)
    qg = q.reshape(b, c, hkv, g, d).astype(jnp.float32) / math.sqrt(d)
    scores = jnp.einsum("bqhgd,bshd->bhgqs", qg, k,
                        preferred_element_type=jnp.float32)
    valid = jnp.arange(s)[None, None, :] <= positions[:, :, None]
    scores = jnp.where(valid[:, None, None], scores, NEG_INF)
    p_att = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgqs,bshd->bqhgd", p_att, v,
                     preferred_element_type=jnp.float32)
    out = out.reshape(b, c, h, d).astype(q.dtype)

    new_cache = dict(cache)
    bt = cache["block_table"]
    packed = "k_data" in cache
    ps = (cache["k_data"] if packed else cache["k"]).shape[1]
    n_tiles = s // ps
    pages = bt[:, :n_tiles].reshape(-1)

    def scatter(pool, vals):
        tiles = vals.reshape((b * n_tiles, ps) + vals.shape[2:])
        return pool.at[pages].set(tiles.astype(pool.dtype))

    if packed:
        kd, ks = _quant_kv_token(stage_k)
        vd, vs = _quant_kv_token(stage_v)
        for key, vals in (("k_data", kd), ("v_data", vd),
                          ("k_scl", ks), ("v_scl", vs)):
            new_cache[key] = scatter(cache[key], vals)
    else:
        new_cache["k"] = scatter(cache["k"], stage_k)
        new_cache["v"] = scatter(cache["v"], stage_v)
    return out, new_cache
