"""Fused OVP-decode + matmul Pallas kernels (the paper's decoder, §4.2–4.4,
re-sited for TPU).

TPU adaptation of the OliVe decoder: on the GPU/systolic designs the OVP
decoder sits per dot-product lane / at the array edge. The MXU is fixed
function, so the decoder becomes the *VMEM prologue* of the matmul kernel:
packed uint8 tiles stream HBM->VMEM (4x less traffic than bf16), nibbles are
decoded branch-free on the VPU, and the MXU consumes the decoded tiles.

Key structural trick: pairs are packed along K, so a packed tile holds the
even-K values in the high nibbles and odd-K values in the low nibbles.
Instead of interleaving (a relayout), we split the reduction:

    out = a_even @ w_even + a_odd @ w_odd

two half-K MXU matmuls per tile, no transposes, no gathers — this is the
memory-alignment claim of the paper realised on TPU.

Blocks default to (bm, bk, bn) = (128, 256, 128): MXU-aligned, and the
working set (a: 128x256 f32 + w packed: 128x128 u8 + out: 128x128 f32)
is ~200 KiB, far inside VMEM; bk can grow to 2048 before VMEM pressure.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.datatypes import ABFLOAT_FOR_NORMAL, AbfloatSpec


# --------------------------------------------------------------------------
# Branch-free nibble decode (VPU-friendly: selects + integer shifts only)
# --------------------------------------------------------------------------
def _decode_normal_int4(c: jax.Array) -> jax.Array:
    ci = c.astype(jnp.int32)
    return jnp.where(ci >= 8, ci - 16, ci).astype(jnp.float32)


def _decode_normal_flint4(c: jax.Array) -> jax.Array:
    ci = c.astype(jnp.int32)
    idx = ci & 0x7
    mag = jnp.where(idx <= 4, idx,
                    jnp.where(idx == 5, 6, jnp.where(idx == 6, 8, 16)))
    sign = jnp.where((ci >> 3) == 1, -1, 1)
    return (sign * mag).astype(jnp.float32)


def _decode_abfloat4(c: jax.Array, spec: AbfloatSpec) -> jax.Array:
    """Fig. 7 decoder: exponent = bias + e-bits; integer = (1 m)b."""
    ci = c.astype(jnp.int32)
    bits = ci & 0x7
    e = bits >> spec.mb
    m = bits & ((1 << spec.mb) - 1)
    mag = ((1 << spec.mb) + m) << (e + spec.bias)   # pure shifts, §3.3
    v = jnp.where((ci >> 3) == 1, -mag, mag)
    return jnp.where(bits == 0, 0, v).astype(jnp.float32)


def decode_nibble_planes(packed: jax.Array, normal_dtype: str,
                         spec: AbfloatSpec):
    """packed (R, C) uint8 -> (even, odd) decoded fp32 planes, each (R, C).

    Row r of `even` is K-position 2r; `odd` is 2r+1 when pairs run along the
    first axis (weights). For activations packed along the last axis the
    same planes correspond to columns 2c / 2c+1.
    """
    hi = (packed >> 4) & jnp.uint8(0xF)
    lo = packed & jnp.uint8(0xF)
    if normal_dtype == "int4":
        dn = _decode_normal_int4
    elif normal_dtype == "flint4":
        dn = _decode_normal_flint4
    else:
        raise ValueError("packed kernels support 4-bit dtypes only")

    def slot(c, neighbour):
        is_victim = c == jnp.uint8(0x8)
        neighbour_victim = neighbour == jnp.uint8(0x8)
        return jnp.where(neighbour_victim, _decode_abfloat4(c, spec),
                         jnp.where(is_victim, 0.0, dn(c)))

    return slot(hi, lo), slot(lo, hi)


# --------------------------------------------------------------------------
# Kernel bodies
# --------------------------------------------------------------------------
def _mm_w4a16_kernel(a_ref, wp_ref, o_ref, *, normal_dtype, spec, n_k):
    """a (bm, bk) fp; wp (bk/2, bn) packed; o (bm, bn) fp32 accumulator."""

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    w_even, w_odd = decode_nibble_planes(wp_ref[...], normal_dtype, spec)
    a = a_ref[...].astype(jnp.float32)
    a_even = a[:, 0::2]
    a_odd = a[:, 1::2]
    o_ref[...] += (
        jnp.dot(a_even, w_even, preferred_element_type=jnp.float32)
        + jnp.dot(a_odd, w_odd, preferred_element_type=jnp.float32))


def _mm_w4a4_kernel(ap_ref, wp_ref, o_ref, *, normal_dtype, spec, n_k):
    """ap (bm, bk/2) packed; wp (bk/2, bn) packed; o (bm, bn) fp32."""

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    # activation planes: column c of each plane is K-position 2c / 2c+1,
    # matching weight rows exactly — the reduction splits cleanly.
    a_even, a_odd = decode_nibble_planes(ap_ref[...], normal_dtype, spec)
    w_even, w_odd = decode_nibble_planes(wp_ref[...], normal_dtype, spec)
    o_ref[...] += (
        jnp.dot(a_even, w_even, preferred_element_type=jnp.float32)
        + jnp.dot(a_odd, w_odd, preferred_element_type=jnp.float32))


# --------------------------------------------------------------------------
# pallas_call builders
# --------------------------------------------------------------------------
def _grid(m, n, k2, bm, bn, bk2):
    return (m // bm, n // bn, k2 // bk2)


def ovp_matmul_w4a16(a: jax.Array, w_packed: jax.Array,
                     normal_dtype: str = "int4",
                     spec: AbfloatSpec | None = None,
                     bm: int = 128, bn: int = 128, bk: int = 256,
                     interpret: bool = False) -> jax.Array:
    """a: (M, K) fp; w_packed: (K/2, N) uint8 -> (M, N) fp32 (w-units)."""
    spec = ABFLOAT_FOR_NORMAL[normal_dtype] if spec is None else spec
    m, k = a.shape
    k2, n = w_packed.shape
    assert k == 2 * k2, (a.shape, w_packed.shape)
    bm, bn = min(bm, m), min(bn, n)
    bk = min(bk, k)
    bk2 = bk // 2
    grid = _grid(m, n, k2, bm, bn, bk2)
    kernel = functools.partial(_mm_w4a16_kernel, normal_dtype=normal_dtype,
                               spec=spec, n_k=grid[2])
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk2, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(a, w_packed)


def ovp_matmul_w4a4(a_packed: jax.Array, w_packed: jax.Array,
                    normal_dtype: str = "int4",
                    spec: AbfloatSpec | None = None,
                    bm: int = 128, bn: int = 128, bk: int = 256,
                    interpret: bool = False) -> jax.Array:
    """a_packed: (M, K/2) uint8; w_packed: (K/2, N) uint8 -> (M, N) fp32."""
    spec = ABFLOAT_FOR_NORMAL[normal_dtype] if spec is None else spec
    m, ak2 = a_packed.shape
    k2, n = w_packed.shape
    assert ak2 == k2, (a_packed.shape, w_packed.shape)
    bm, bn = min(bm, m), min(bn, n)
    bk2 = min(bk // 2, k2)
    grid = _grid(m, n, k2, bm, bn, bk2)
    kernel = functools.partial(_mm_w4a4_kernel, normal_dtype=normal_dtype,
                               spec=spec, n_k=grid[2])
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk2), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk2, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(a_packed, w_packed)
