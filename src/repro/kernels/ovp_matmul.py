"""Fused OVP matmul Pallas kernel (the paper's decoder + encoder, §3–4,
re-sited for TPU).

TPU adaptation of the OliVe datapath: on the GPU/systolic designs the OVP
decoder sits per dot-product lane and the encoder inside the quantization
unit. The MXU is fixed function, so both become *phases of one matmul
kernel*:

  prologue  — activations are either decoded from packed OVP bytes
              (pre-quantized operands) or OVP-quantized in value domain
              straight from the fp tile (online serving: no packed
              activation tensor ever touches HBM),
  body      — packed uint8 weight tiles stream HBM->VMEM (4x less traffic
              than bf16), nibbles/bytes are decoded branch-free on the VPU,
              and the MXU consumes the decoded tiles,
  epilogue  — per-row activation scales and per-output-channel weight
              scales are applied to the fp32 accumulator on the last
              K step (no separate XLA multiply dispatch).

Key structural trick: pairs are packed along K, so a packed tile holds the
even-K values in the high nibbles and odd-K values in the low nibbles (for
int8 OVP: even/odd K rows/columns). Instead of interleaving (a relayout),
we split the reduction:

    out = a_even @ w_even + a_odd @ w_odd

two half-K MXU matmuls per tile, no transposes, no gathers — this is the
memory-alignment claim of the paper realised on TPU.

The grid is (batch, M/bm, N/bn, K2/bk2) with K innermost, so a 3-D lhs
(decode-step GEMMs from the serving engine) hits the kernel without any
reshape glue; 2-D callers pass batch=1.

Blocks default to (bm, bk, bn) = (128, 256, 128): MXU-aligned, and the
working set (a: 128x256 f32 + w packed: 128x128 u8 + out: 128x128 f32)
is ~200 KiB, far inside VMEM; bk can grow to 2048 before VMEM pressure.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.datatypes import (ABFLOAT_FOR_NORMAL, ID4, ID8, NORMAL_MAX,
                                  AbfloatSpec, abfloat_decode, abfloat_encode,
                                  int_normal_decode)

# Activation operand modes of the fused kernel (static):
#   fp        — fp tile used as-is (W4A16 / W8A16)
#   quantize  — fp tile OVP fake-quantized in the prologue at the per-row
#               scale (online W4A4 / W8A8 serving: no packed tensor in HBM)
#   codes4    — packed nibble codes, decoded in the prologue
#   codes8    — int8 OVP codes (one per byte), decoded in the prologue
# "quantize" has a *static-scale* twin (`a_static=True`, the
# `_*_kernel_static` bodies): the calibrated per-site scale arrives as a
# single (1, 1) scalar operand instead of the (B, M, 1) per-row stream,
# so one compiled kernel serves every calibrated site and no per-step 3σ
# std runs upstream.
ACT_MODES = ("fp", "quantize", "codes4", "codes8")


# --------------------------------------------------------------------------
# Branch-free decode (VPU-friendly: selects + integer shifts only)
# --------------------------------------------------------------------------
def _decode_normal_int4(c: jax.Array) -> jax.Array:
    ci = c.astype(jnp.int32)
    return jnp.where(ci >= 8, ci - 16, ci).astype(jnp.float32)


def _decode_normal_flint4(c: jax.Array) -> jax.Array:
    ci = c.astype(jnp.int32)
    idx = ci & 0x7
    mag = jnp.where(idx <= 4, idx,
                    jnp.where(idx == 5, 6, jnp.where(idx == 6, 8, 16)))
    sign = jnp.where((ci >> 3) == 1, -1, 1)
    return (sign * mag).astype(jnp.float32)


def _decode_normal_int8(c: jax.Array) -> jax.Array:
    # the datatypes decoder is already a branch-free where-chain, safe
    # inside the kernel body (unlike LUT gathers)
    return int_normal_decode(c, 8)


_NORMAL_DECODERS = {"int4": _decode_normal_int4,
                    "flint4": _decode_normal_flint4,
                    "int8": _decode_normal_int8}


def _decode_abfloat(c: jax.Array, spec: AbfloatSpec) -> jax.Array:
    """Fig. 7 decoder: exponent = bias + e-bits; integer = (1 m)b.

    Pure shifts + selects (§3.3); magnitudes clamp at 2^15 (§4.5) to match
    `datatypes.abfloat_decode` for the wide int8/E4M3 spec.
    """
    ci = c.astype(jnp.int32)
    nbits = spec.ebits + spec.mb
    bits = ci & ((1 << nbits) - 1)
    e = bits >> spec.mb
    m = bits & ((1 << spec.mb) - 1)
    mag = ((1 << spec.mb) + m) << (e + spec.bias)    # pure shifts, §3.3
    mag = jnp.minimum(mag, 1 << 15)
    v = jnp.where((ci >> nbits) & 1 == 1, -mag, mag)
    return jnp.where(bits == 0, 0, v).astype(jnp.float32)


def decode_pair_planes(c0: jax.Array, c1: jax.Array, normal_dtype: str,
                       spec: AbfloatSpec):
    """Two code planes (pair-mates) -> decoded fp32 planes.

    If my neighbour holds the identifier, I am the outlier (abfloat); if I
    hold it, I am the victim (0); otherwise I am a normal value.
    """
    ident = jnp.uint8(ID8 if normal_dtype == "int8" else ID4)
    dn = _NORMAL_DECODERS[normal_dtype]

    def slot(c, neighbour):
        return jnp.where(neighbour == ident, _decode_abfloat(c, spec),
                         jnp.where(c == ident, 0.0, dn(c)))

    return slot(c0, c1), slot(c1, c0)


def decode_nibble_planes(packed: jax.Array, normal_dtype: str,
                         spec: AbfloatSpec):
    """packed (R, C) uint8 -> (even, odd) decoded fp32 planes, each (R, C).

    Row r of `even` is K-position 2r; `odd` is 2r+1 when pairs run along the
    first axis (weights). For activations packed along the last axis the
    same planes correspond to columns 2c / 2c+1.
    """
    if normal_dtype == "int8":
        raise ValueError("int8 codes are not nibble-packed; split the code "
                         "planes and use decode_pair_planes directly")
    hi = (packed >> 4) & jnp.uint8(0xF)
    lo = packed & jnp.uint8(0xF)
    return decode_pair_planes(hi, lo, normal_dtype, spec)


# --------------------------------------------------------------------------
# In-kernel OVP fake quantization (the fused activation prologue).
# Value-domain mirror of encode->decode: identical outlier/victim selection
# (Algorithm 1) and identical rounding, so the fused path is bit-compatible
# with the XLA encode -> kernel decode round trip it replaces.
# --------------------------------------------------------------------------
def _roundtrip_normal(u: jax.Array, normal_dtype: str) -> jax.Array:
    if normal_dtype == "int4":
        return jnp.clip(jnp.round(u), -7, 7)
    if normal_dtype == "int8":
        return jnp.clip(jnp.round(u), -127, 127)
    # flint4: nearest magnitude in {0,1,2,3,4,6,8,16} via midpoint
    # thresholds (ties -> smaller magnitude, matching flint4_encode's
    # argmin tie rule). A select chain, not a LUT gather: pallas_call
    # rejects captured constant arrays in the kernel body.
    a = jnp.abs(u)
    mag = jnp.where(a <= 0.5, 0.0,
          jnp.where(a <= 1.5, 1.0,
          jnp.where(a <= 2.5, 2.0,
          jnp.where(a <= 3.5, 3.0,
          jnp.where(a <= 5.0, 4.0,
          jnp.where(a <= 7.0, 6.0,
          jnp.where(a <= 12.0, 8.0, 16.0)))))))
    return jnp.where((u < 0) & (mag > 0), -mag, mag)


def _roundtrip_abfloat(u: jax.Array, spec: AbfloatSpec) -> jax.Array:
    return abfloat_decode(abfloat_encode(u, spec), spec)


def quantize_pair_planes(u0: jax.Array, u1: jax.Array, normal_dtype: str,
                         spec: AbfloatSpec):
    """Scaled value planes -> OVP fake-quantized planes (Algorithm 1).

    Same outlier-victim selection as `core.ovp.ovp_encode_codes`: per pair,
    at most one outlier survives as abfloat, its neighbour is pruned to 0.
    """
    t = float(NORMAL_MAX[normal_dtype])
    a0, a1 = jnp.abs(u0), jnp.abs(u1)
    o0, o1 = a0 > t, a1 > t
    first_out = o0 & (~o1 | (a0 >= a1))
    second_out = o1 & ~first_out
    q0 = jnp.where(first_out, _roundtrip_abfloat(u0, spec),
                   jnp.where(second_out, 0.0,
                             _roundtrip_normal(u0, normal_dtype)))
    q1 = jnp.where(second_out, _roundtrip_abfloat(u1, spec),
                   jnp.where(first_out, 0.0,
                             _roundtrip_normal(u1, normal_dtype)))
    return q0.astype(jnp.float32), q1.astype(jnp.float32)


# --------------------------------------------------------------------------
# Shared tile phases (2-D and grouped kernel bodies both use these)
# --------------------------------------------------------------------------
def _weight_tile_planes(wp: jax.Array, w_dtype: str, w_spec: AbfloatSpec):
    """Packed weight tile -> (even, odd) decoded fp32 half-K planes."""
    if w_dtype == "int8":
        return decode_pair_planes(wp[0::2, :], wp[1::2, :], "int8", w_spec)
    return decode_nibble_planes(wp, w_dtype, w_spec)


def _act_tile_planes(a: jax.Array, sa: jax.Array, a_mode: str,
                     a_dtype: str, a_spec: AbfloatSpec):
    """Activation prologue: (bm, a_blk) tile -> (even, odd) fp32 planes.

    codes4/codes8 decode packed operands; quantize runs the in-kernel OVP
    fake-quant at the per-row scale `sa`; fp splits the raw tile.
    """
    if a_mode == "codes4":
        return decode_nibble_planes(a, a_dtype, a_spec)
    if a_mode == "codes8":
        return decode_pair_planes(a[:, 0::2], a[:, 1::2], "int8", a_spec)
    af = a.astype(jnp.float32)
    if a_mode == "quantize":
        u = af / sa
        return quantize_pair_planes(u[:, 0::2], u[:, 1::2], a_dtype, a_spec)
    return af[:, 0::2], af[:, 1::2]  # fp


# --------------------------------------------------------------------------
# The unified fused kernel body
# --------------------------------------------------------------------------
def _fused_mm_kernel(a_ref, sa_ref, wp_ref, sw_ref, o_ref, *,
                     w_dtype: str, w_spec: AbfloatSpec,
                     a_mode: str, a_dtype: str, a_spec: AbfloatSpec):
    """One (batch, M, N, K) grid step.

    a_ref  (1, bm, bk)   fp tile (fp/quantize), or codes: (1, bm, bk2)
                         packed nibbles (codes4) / (1, bm, bk) bytes (codes8)
    sa_ref (1, bm, 1)    per-row activation scale (1.0 when unscaled)
    wp_ref (bk2, bn)     packed nibbles, or (bk, bn) int8 OVP codes
    sw_ref (1, bn)       per-output-channel weight scale (1.0 when unscaled)
    o_ref  (1, bm, bn)   fp32 accumulator; scales applied on the last K step
    """
    @pl.when(pl.program_id(3) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    w_even, w_odd = _weight_tile_planes(wp_ref[...], w_dtype, w_spec)
    a_even, a_odd = _act_tile_planes(a_ref[0], sa_ref[0], a_mode, a_dtype,
                                     a_spec)

    o_ref[0] += (
        jnp.dot(a_even, w_even, preferred_element_type=jnp.float32)
        + jnp.dot(a_odd, w_odd, preferred_element_type=jnp.float32))

    # -- scale epilogue ---------------------------------------------------
    @pl.when(pl.program_id(3) == pl.num_programs(3) - 1)
    def _epilogue():
        o_ref[0] = o_ref[0] * sa_ref[0] * sw_ref[...]


def _act_tile_planes_static(a: jax.Array, a_dtype: str,
                            a_spec: AbfloatSpec, s: jax.Array):
    """Static-scale activation prologue: OVP fake-quant at the calibrated
    scalar `s`. One reciprocal per tile instead of a per-row divide, and
    no (bm, 1) scale tile is ever streamed."""
    u = a.astype(jnp.float32) * (1.0 / s)
    return quantize_pair_planes(u[:, 0::2], u[:, 1::2], a_dtype, a_spec)


def _fused_mm_kernel_static(a_ref, sa_ref, wp_ref, sw_ref, o_ref, *,
                            w_dtype: str, w_spec: AbfloatSpec,
                            a_dtype: str, a_spec: AbfloatSpec):
    """Static-scale twin of `_fused_mm_kernel` (a_mode="quantize" only).

    The calibrated activation scale arrives as ONE (1, 1) scalar operand
    instead of the (B, M, 1) per-row stream: a single word replaces a
    whole operand plane, one compiled kernel serves every calibrated
    site/scale, and — upstream — no per-step 3σ std is ever computed.
    This is the serving fast path for `act_scale_mode="static"`.

    a_ref  (1, bm, bk)   fp tile, quantized in-kernel at the scalar scale
    sa_ref (1, 1)        the calibrated scale (same word on every tile)
    wp_ref (bk2, bn)     packed nibbles, or (bk, bn) int8 OVP codes
    sw_ref (1, bn)       per-output-channel weight scale
    o_ref  (1, bm, bn)   fp32 accumulator; scales applied on the last K step
    """
    @pl.when(pl.program_id(3) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    s = sa_ref[0, 0]
    w_even, w_odd = _weight_tile_planes(wp_ref[...], w_dtype, w_spec)
    a_even, a_odd = _act_tile_planes_static(a_ref[0], a_dtype, a_spec, s)

    o_ref[0] += (
        jnp.dot(a_even, w_even, preferred_element_type=jnp.float32)
        + jnp.dot(a_odd, w_odd, preferred_element_type=jnp.float32))

    @pl.when(pl.program_id(3) == pl.num_programs(3) - 1)
    def _epilogue():
        o_ref[0] = o_ref[0] * (s * sw_ref[...])


# --------------------------------------------------------------------------
# Grouped (per-expert) kernel body: one expert grid dim over stacked weights
# --------------------------------------------------------------------------
def _grouped_mm_kernel(a_ref, sa_ref, wp_ref, sw_ref, o_ref, *,
                       w_dtype: str, w_spec: AbfloatSpec,
                       a_mode: str, a_dtype: str, a_spec: AbfloatSpec):
    """One (batch, expert, M, N, K) grid step.

    The expert grid dim indexes the stacked weight's leading axis, so each
    (e, m, n) tile streams only expert e's packed bytes — no broadcast of
    the full (E, K, N) stack, no global coordination between experts
    (the paper's memory-alignment claim extends to the MoE layout).

    a_ref  (1, 1, bm, a_blk)  one expert's dispatched-slot tile
    sa_ref (1, 1, bm, 1)      per-slot activation scale
    wp_ref (1, w_blk, bn)     this expert's packed weight tile
    sw_ref (1, 1, bn)         this expert's per-output-channel scale
    o_ref  (1, 1, bm, bn)     fp32 accumulator, scales on the last K step
    """
    @pl.when(pl.program_id(4) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    w_even, w_odd = _weight_tile_planes(wp_ref[0], w_dtype, w_spec)
    a_even, a_odd = _act_tile_planes(a_ref[0, 0], sa_ref[0, 0], a_mode,
                                     a_dtype, a_spec)

    o_ref[0, 0] += (
        jnp.dot(a_even, w_even, preferred_element_type=jnp.float32)
        + jnp.dot(a_odd, w_odd, preferred_element_type=jnp.float32))

    @pl.when(pl.program_id(4) == pl.num_programs(4) - 1)
    def _epilogue():
        o_ref[0, 0] = o_ref[0, 0] * sa_ref[0, 0] * sw_ref[0]


def _grouped_mm_kernel_static(a_ref, sa_ref, wp_ref, sw_ref, o_ref, *,
                              w_dtype: str, w_spec: AbfloatSpec,
                              a_dtype: str, a_spec: AbfloatSpec):
    """Static-scale twin of `_grouped_mm_kernel` (a_mode="quantize" only):
    same scalar-operand prologue/epilogue as `_fused_mm_kernel_static`,
    on the (batch, expert, M, N, K) grid.

    a_ref  (1, 1, bm, bk)  one expert's dispatched-slot fp tile
    sa_ref (1, 1, 1)       the calibrated scale (same word on every tile)
    wp_ref (1, w_blk, bn)  this expert's packed weight tile
    sw_ref (1, 1, bn)      this expert's per-output-channel scale
    o_ref  (1, 1, bm, bn)  fp32 accumulator, scales on the last K step
    """
    @pl.when(pl.program_id(4) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    s = sa_ref[0, 0, 0]
    w_even, w_odd = _weight_tile_planes(wp_ref[0], w_dtype, w_spec)
    a_even, a_odd = _act_tile_planes_static(a_ref[0, 0], a_dtype, a_spec,
                                            s)

    o_ref[0, 0] += (
        jnp.dot(a_even, w_even, preferred_element_type=jnp.float32)
        + jnp.dot(a_odd, w_odd, preferred_element_type=jnp.float32))

    @pl.when(pl.program_id(4) == pl.num_programs(4) - 1)
    def _epilogue():
        o_ref[0, 0] = o_ref[0, 0] * (s * sw_ref[0])


# --------------------------------------------------------------------------
# pallas_call builder
# --------------------------------------------------------------------------
def fused_ovp_matmul_kernel(a: jax.Array, a_scale: jax.Array,
                            w_data: jax.Array, w_scale: jax.Array, *,
                            w_dtype: str = "int4",
                            a_mode: str = "fp", a_dtype: str = "int4",
                            w_spec: AbfloatSpec | None = None,
                            a_spec: AbfloatSpec | None = None,
                            a_static: bool = False,
                            bm: int = 128, bn: int = 128, bk: int = 256,
                            interpret: bool = False) -> jax.Array:
    """a: (B, M, Ka); a_scale: (B, M, 1); w_data: (Kw, N); w_scale: (1, N).

    Ka is K for fp/quantize/codes8 activations and K/2 for codes4; Kw is
    K/2 for packed nibbles and K for int8 codes. Returns (B, M, N) fp32
    with both scales applied. Shapes must divide the (clamped) blocks —
    `repro.kernels.ops` owns padding.

    `a_static` (with a_mode="quantize") switches to the static prologue:
    `a_scale` is a single (1, 1) calibrated scalar instead of the
    (B, M, 1) per-row plane, and the kernel reads that one word — one
    compiled kernel serves every calibrated site/scale.
    """
    assert a_mode in ACT_MODES, a_mode
    w_spec = ABFLOAT_FOR_NORMAL[w_dtype] if w_spec is None else w_spec
    a_spec = ABFLOAT_FOR_NORMAL[a_dtype] if a_spec is None else a_spec

    b, m, ka = a.shape
    kw, n = w_data.shape
    k2 = kw if w_dtype != "int8" else kw // 2   # number of pairs along K
    bm, bn = min(bm, m), min(bn, n)
    bk2 = min(bk // 2, k2)
    grid = (b, m // bm, n // bn, k2 // bk2)

    a_blk = bk2 if a_mode == "codes4" else 2 * bk2
    w_blk = bk2 if w_dtype != "int8" else 2 * bk2
    assert ka % a_blk == 0 and m % bm == 0 and n % bn == 0 \
        and kw % w_blk == 0, (a.shape, w_data.shape, (bm, bn, bk2))

    if a_static:
        assert a_mode == "quantize", \
            "static activation scales imply the in-kernel quantize prologue"
        assert a_scale.shape == (1, 1), a_scale.shape
        kernel = functools.partial(_fused_mm_kernel_static,
                                   w_dtype=w_dtype, w_spec=w_spec,
                                   a_dtype=a_dtype, a_spec=a_spec)
        sa_spec = pl.BlockSpec((1, 1), lambda bb, i, j, kk: (0, 0))
    else:
        kernel = functools.partial(_fused_mm_kernel, w_dtype=w_dtype,
                                   w_spec=w_spec, a_mode=a_mode,
                                   a_dtype=a_dtype, a_spec=a_spec)
        sa_spec = pl.BlockSpec((1, bm, 1), lambda bb, i, j, kk: (bb, i, 0))
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bm, a_blk), lambda bb, i, j, kk: (bb, i, kk)),
            sa_spec,
            pl.BlockSpec((w_blk, bn), lambda bb, i, j, kk: (kk, j)),
            pl.BlockSpec((1, bn), lambda bb, i, j, kk: (0, j)),
        ],
        out_specs=pl.BlockSpec((1, bm, bn),
                               lambda bb, i, j, kk: (bb, i, j)),
        out_shape=jax.ShapeDtypeStruct((b, m, n), jnp.float32),
        interpret=interpret,
    )(a, a_scale, w_data, w_scale)


# --------------------------------------------------------------------------
# Grouped pallas_call builder (stacked per-expert weights)
# --------------------------------------------------------------------------
def grouped_ovp_matmul_kernel(a: jax.Array, a_scale: jax.Array,
                              w_data: jax.Array, w_scale: jax.Array, *,
                              w_dtype: str = "int4",
                              a_mode: str = "fp", a_dtype: str = "int4",
                              w_spec: AbfloatSpec | None = None,
                              a_spec: AbfloatSpec | None = None,
                              a_static: bool = False,
                              bm: int = 128, bn: int = 128, bk: int = 256,
                              interpret: bool = False) -> jax.Array:
    """a: (B, E, M, Ka); a_scale: (B, E, M, 1); w_data: (E, Kw, N);
    w_scale: (E, 1, N). Returns (B, E, M, N) fp32 with both scales applied.

    The grid is (B, E, M/bm, N/bn, K2/bk2) with K innermost; the expert dim
    rides the grid like the batch dim, so per-expert MoE einsums hit one
    pallas_call with no XLA broadcast of the stacked weights. Shapes must
    divide the (clamped) blocks — `repro.kernels.ops` owns padding.

    `a_static` (with a_mode="quantize") takes the static prologue:
    `a_scale` is a single (1, 1, 1) calibrated scalar instead of the
    per-slot plane, exactly as in `fused_ovp_matmul_kernel`.
    """
    assert a_mode in ACT_MODES, a_mode
    w_spec = ABFLOAT_FOR_NORMAL[w_dtype] if w_spec is None else w_spec
    a_spec = ABFLOAT_FOR_NORMAL[a_dtype] if a_spec is None else a_spec

    b, e, m, ka = a.shape
    ew, kw, n = w_data.shape
    assert ew == e, (a.shape, w_data.shape)
    k2 = kw if w_dtype != "int8" else kw // 2   # number of pairs along K
    bm, bn = min(bm, m), min(bn, n)
    bk2 = min(bk // 2, k2)
    grid = (b, e, m // bm, n // bn, k2 // bk2)

    a_blk = bk2 if a_mode == "codes4" else 2 * bk2
    w_blk = bk2 if w_dtype != "int8" else 2 * bk2
    assert ka % a_blk == 0 and m % bm == 0 and n % bn == 0 \
        and kw % w_blk == 0, (a.shape, w_data.shape, (bm, bn, bk2))

    if a_static:
        assert a_mode == "quantize", \
            "static activation scales imply the in-kernel quantize prologue"
        assert a_scale.shape == (1, 1, 1), a_scale.shape
        kernel = functools.partial(_grouped_mm_kernel_static,
                                   w_dtype=w_dtype, w_spec=w_spec,
                                   a_dtype=a_dtype, a_spec=a_spec)
        sa_spec = pl.BlockSpec((1, 1, 1),
                               lambda bb, ee, i, j, kk: (0, 0, 0))
    else:
        kernel = functools.partial(_grouped_mm_kernel, w_dtype=w_dtype,
                                   w_spec=w_spec, a_mode=a_mode,
                                   a_dtype=a_dtype, a_spec=a_spec)
        sa_spec = pl.BlockSpec((1, 1, bm, 1),
                               lambda bb, ee, i, j, kk: (bb, ee, i, 0))
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bm, a_blk),
                         lambda bb, ee, i, j, kk: (bb, ee, i, kk)),
            sa_spec,
            pl.BlockSpec((1, w_blk, bn),
                         lambda bb, ee, i, j, kk: (ee, kk, j)),
            pl.BlockSpec((1, 1, bn), lambda bb, ee, i, j, kk: (ee, 0, j)),
        ],
        out_specs=pl.BlockSpec((1, 1, bm, bn),
                               lambda bb, ee, i, j, kk: (bb, ee, i, j)),
        out_shape=jax.ShapeDtypeStruct((b, e, m, n), jnp.float32),
        interpret=interpret,
    )(a, a_scale, w_data, w_scale)


# --------------------------------------------------------------------------
# Back-compat 2-D builders (scaled-unit outputs, as the oracles in ref.py)
# --------------------------------------------------------------------------
def _ones_scales(b, m, n):
    return jnp.ones((b, m, 1), jnp.float32), jnp.ones((1, n), jnp.float32)


def ovp_matmul_w4a16(a: jax.Array, w_packed: jax.Array,
                     normal_dtype: str = "int4",
                     spec: AbfloatSpec | None = None,
                     bm: int = 128, bn: int = 128, bk: int = 256,
                     interpret: bool = False) -> jax.Array:
    """a: (M, K) fp; w_packed: (K/2, N) uint8 -> (M, N) fp32 (w-units)."""
    m, k = a.shape
    k2, n = w_packed.shape
    assert k == 2 * k2, (a.shape, w_packed.shape)
    sa, sw = _ones_scales(1, m, n)
    out = fused_ovp_matmul_kernel(a[None], sa, w_packed, sw,
                                  w_dtype=normal_dtype, a_mode="fp",
                                  w_spec=spec, bm=bm, bn=bn, bk=bk,
                                  interpret=interpret)
    return out[0]


def ovp_matmul_w4a4(a_packed: jax.Array, w_packed: jax.Array,
                    normal_dtype: str = "int4",
                    spec: AbfloatSpec | None = None,
                    bm: int = 128, bn: int = 128, bk: int = 256,
                    interpret: bool = False) -> jax.Array:
    """a_packed: (M, K/2) uint8; w_packed: (K/2, N) uint8 -> (M, N) fp32."""
    m, ak2 = a_packed.shape
    k2, n = w_packed.shape
    assert ak2 == k2, (a_packed.shape, w_packed.shape)
    sa, sw = _ones_scales(1, m, n)
    out = fused_ovp_matmul_kernel(a_packed[None], sa, w_packed, sw,
                                  w_dtype=normal_dtype, a_mode="codes4",
                                  a_dtype=normal_dtype, w_spec=spec,
                                  a_spec=spec, bm=bm, bn=bn, bk=bk,
                                  interpret=interpret)
    return out[0]
