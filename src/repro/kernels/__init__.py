"""Pallas TPU kernels for OliVe hot spots (inventory: README.md here).

ovp_matmul  — the unified fused OVP matmul: activation quantize/decode
              prologue, split-K decode body, scale epilogue, batched lhs
              (W4A16 / W4A4 / W8A8 / mixed, one pallas_call each)
decode_attn — fused decode attention over (OVP-packed or fp) KV caches:
              per-tile unpack in VMEM, online softmax, in-kernel
              length/ring/window masking from the traced position; plus
              the dense XLA fallback path (see docs/kv_cache.md)
ovp_encode  — standalone pairwise OVP encoder (KV packing, tests)

`ops` holds the jit'd wrappers; `ref` the pure-jnp oracles; kernels are
validated on CPU with interpret=True across shape/dtype sweeps. Execution
policy lives one level up in `repro.backends` — models never call these
directly.
"""
from . import decode_attn, ops, ref
from .ovp_matmul import (fused_ovp_matmul_kernel, ovp_matmul_w4a16,
                         ovp_matmul_w4a4)
from .ovp_encode import ovp_encode_pallas
