"""Pallas TPU kernels for OliVe hot spots.

ovp_matmul — fused OVP-decode + MXU matmul (W4A16 and W4A4 variants)
ovp_encode — pairwise OVP encoder (online activation quantization)

`ops` holds the jit'd wrappers; `ref` the pure-jnp oracles; kernels are
validated on CPU with interpret=True across shape/dtype sweeps.
"""
from . import ops, ref
from .ovp_matmul import ovp_matmul_w4a16, ovp_matmul_w4a4
from .ovp_encode import ovp_encode_pallas
