"""Jit'd public wrappers around the fused OVP Pallas kernel.

Handles: lhs normalization to the kernel's 3-D (batch, M, K) layout, shape
padding to block multiples, scale broadcasting into the kernel's epilogue
layout (per-row activation / per-output-channel weight), QuantizedTensor
plumbing, and the interpret switch (CPU validation vs TPU execution).

Scales are applied *inside* the kernel epilogue — there is no post-kernel
XLA multiply; a quantized matmul is exactly one device dispatch. With a
calibrated `static_act_scale` the activation scale shrinks to a single
(1, 1) scalar operand — no per-row plane, no per-step scale computation
(see docs/calibration.md).
"""
from __future__ import annotations

import functools
from typing import Optional, Union

import jax
import jax.numpy as jnp

from repro.core.ovp import QuantizedTensor
from . import ovp_matmul as _mm
from . import ovp_encode as _enc


def _pad_to(x: jax.Array, mults, value=0):
    pads = []
    for d, m in zip(x.shape, mults):
        rem = (-d) % m
        pads.append((0, rem))
    if all(p == (0, 0) for p in pads):
        return x
    return jnp.pad(x, pads, constant_values=value)


@functools.partial(jax.jit, static_argnames=("w_dtype", "a_mode", "a_dtype",
                                             "a_static", "out_dtype",
                                             "interpret", "bm", "bn", "bk"))
def _fused_padded(a3: jax.Array, sa3: jax.Array,
                  w_data: jax.Array, sw: jax.Array, *, w_dtype: str,
                  a_mode: str, a_dtype: str, a_static: bool = False,
                  out_dtype=jnp.float32, interpret: bool = False,
                  bm: int = 128, bn: int = 128, bk: int = 256) -> jax.Array:
    """Pad operands to block multiples, run the fused kernel, slice back.

    a3 (B, M, Ka); sa3 (B, M, 1); w_data (Kw, N); sw (1, N).
    Padded activation rows get scale 1 (prologue divides by the scale) and
    padded codes/values decode to 0, so padding never perturbs the result.

    `a_static` takes the static-prologue kernel: sa3 is the calibrated
    (1, 1) scalar (a traced operand, so one jit entry and one compiled
    kernel serve every scale value) and never needs padding.
    """
    b, m, ka = a3.shape
    kw, n = w_data.shape
    k2 = kw if w_dtype != "int8" else kw // 2
    # clamp blocks to the problem, then pad up to the clamped multiples
    bm = min(bm, m)
    bn = min(bn, n)
    bk2 = min(bk // 2, k2)
    a_mult = bk2 if a_mode == "codes4" else 2 * bk2
    w_mult = bk2 if w_dtype != "int8" else 2 * bk2
    ap = _pad_to(a3, (1, bm, a_mult))
    sap = sa3 if a_static else _pad_to(sa3, (1, bm, 1), value=1.0)
    wp = _pad_to(w_data, (w_mult, bn))
    swp = _pad_to(sw, (1, bn), value=1.0)
    out = _mm.fused_ovp_matmul_kernel(ap, sap, wp, swp, w_dtype=w_dtype,
                                      a_mode=a_mode, a_dtype=a_dtype,
                                      a_static=a_static,
                                      bm=bm, bn=bn, bk=2 * bk2,
                                      interpret=interpret)
    return out[:, :m, :n].astype(out_dtype)


def _as_3d(x: jax.Array):
    """(…, M, K) -> ((B, M, K), lead) with all leading dims folded into B."""
    if x.ndim < 2:
        raise ValueError(f"lhs must be at least 2-D, got {x.shape}")
    lead = x.shape[:-2]
    b = 1
    for d in lead:
        b *= d
    return x.reshape(b, x.shape[-2], x.shape[-1]), lead


def _as_4d(x: jax.Array):
    """(…, E, C, K) -> ((B, E, C, K), lead): leading dims fold into B."""
    if x.ndim < 3:
        raise ValueError(f"grouped lhs must be at least 3-D, got {x.shape}")
    lead = x.shape[:-3]
    b = 1
    for d in lead:
        b *= d
    return x.reshape(b, *x.shape[-3:]), lead


def _row_scale(s, x: jax.Array) -> jax.Array:
    """Broadcast a scalar / per-row scale to the kernel's (B, M, 1)."""
    s = jnp.asarray(s, jnp.float32)
    target = x.shape[:-1] + (1,)
    if s.ndim and s.shape == x.shape[:-1]:
        s = s[..., None]
    s3, _ = _as_3d(jnp.broadcast_to(s, target))
    return s3


def _col_scale(s, n: int) -> jax.Array:
    """Broadcast a scalar / per-channel weight scale to (1, N)."""
    s = jnp.asarray(s, jnp.float32)
    return jnp.broadcast_to(s.reshape(1, -1) if s.ndim else s, (1, n))


def fused_ovp_matmul(x: Union[jax.Array, QuantizedTensor],
                     w: QuantizedTensor, *,
                     a_dtype: Optional[str] = None,
                     act_scale: Optional[jax.Array] = None,
                     static_act_scale: Union[float, jax.Array, None] = None,
                     out_dtype=jnp.float32, interpret: bool = False,
                     bm: int = 128, bn: int = 128,
                     bk: int = 256) -> jax.Array:
    """Single-dispatch quantized matmul: (…, K) @ (K, N) -> (…, N).

    x is either a real-valued tensor — used as-is (W4A16/W8A16) or
    OVP-quantized in the kernel prologue when `a_dtype` is set (pass the
    per-tensor or per-row `act_scale`; no packed activation tensor is ever
    materialized) — or a pre-quantized `QuantizedTensor` whose codes are
    decoded in the prologue. Weight pairs must run along K; any leading lhs
    dims are batch (3-D decode-step GEMMs take the same path as 2-D).

    `static_act_scale` (the calibrated per-site scalar — a Python float
    or 0-d array) replaces `act_scale`: it reaches the kernel as a single
    (1, 1) scalar operand instead of the per-row plane, and no per-step
    scale computation of any kind runs. This is the
    `act_scale_mode="static"` serving fast path.
    """
    n = w.data.shape[-1]
    sw = _col_scale(w.scale, n)
    static = False
    if isinstance(x, QuantizedTensor):
        a_mode = "codes4" if x.is_packed else "codes8"
        a3, lead = _as_3d(x.data)
        sa3 = _row_scale(x.scale, x.data)
        a_dtype = x.normal_dtype
    elif a_dtype is not None:
        if static_act_scale is not None:
            a_mode = "quantize"
            a3, lead = _as_3d(x)
            sa3 = jnp.asarray(static_act_scale,
                              jnp.float32).reshape(1, 1)
            static = True
        elif act_scale is None:
            raise ValueError("in-kernel activation quantization needs an "
                             "act_scale (per-tensor or per-row) or a "
                             "static_act_scale constant")
        else:
            a_mode = "quantize"
            a3, lead = _as_3d(x)
            sa3 = _row_scale(act_scale, x)
    else:
        a_mode = "fp"
        a3, lead = _as_3d(x)
        sa3 = jnp.ones((a3.shape[0], a3.shape[1], 1), jnp.float32)
        a_dtype = w.normal_dtype
    out = _fused_padded(a3, sa3, w.data, sw, w_dtype=w.normal_dtype,
                        a_mode=a_mode, a_dtype=a_dtype, a_static=static,
                        out_dtype=out_dtype, interpret=interpret,
                        bm=bm, bn=bn, bk=bk)
    return out.reshape(*lead, out.shape[-2], out.shape[-1]) if lead \
        else out[0]


# --------------------------------------------------------------------------
# Grouped (per-expert) matmul over stacked weights
# --------------------------------------------------------------------------
@functools.partial(jax.jit, static_argnames=("w_dtype", "a_mode", "a_dtype",
                                             "a_static", "out_dtype",
                                             "interpret", "bm", "bn", "bk"))
def _grouped_padded(a4: jax.Array, sa4: jax.Array,
                    w_data: jax.Array, sw: jax.Array, *, w_dtype: str,
                    a_mode: str, a_dtype: str, a_static: bool = False,
                    out_dtype=jnp.float32,
                    interpret: bool = False, bm: int = 128, bn: int = 128,
                    bk: int = 256) -> jax.Array:
    """Pad grouped operands to block multiples, run the kernel, slice back.

    a4 (B, E, M, Ka); sa4 (B, E, M, 1); w_data (E, Kw, N); sw (E, 1, N).
    The expert dim never pads (block size 1 on the expert grid dim).
    `a_static` takes the static-prologue kernel (sa4 is the calibrated
    (1, 1, 1) scalar), exactly as in `_fused_padded`.
    """
    b, e, m, ka = a4.shape
    _, kw, n = w_data.shape
    k2 = kw if w_dtype != "int8" else kw // 2
    bm = min(bm, m)
    bn = min(bn, n)
    bk2 = min(bk // 2, k2)
    a_mult = bk2 if a_mode == "codes4" else 2 * bk2
    w_mult = bk2 if w_dtype != "int8" else 2 * bk2
    ap = _pad_to(a4, (1, 1, bm, a_mult))
    sap = sa4 if a_static else _pad_to(sa4, (1, 1, bm, 1), value=1.0)
    wp = _pad_to(w_data, (1, w_mult, bn))
    swp = _pad_to(sw, (1, 1, bn), value=1.0)
    out = _mm.grouped_ovp_matmul_kernel(ap, sap, wp, swp, w_dtype=w_dtype,
                                        a_mode=a_mode, a_dtype=a_dtype,
                                        a_static=a_static,
                                        bm=bm, bn=bn, bk=2 * bk2,
                                        interpret=interpret)
    return out[:, :, :m, :n].astype(out_dtype)


def _expert_row_scale(s, x: jax.Array) -> jax.Array:
    """Broadcast a scalar / per-slot act scale to the kernel's (B,E,M,1)."""
    s = jnp.asarray(s, jnp.float32)
    target = x.shape[:-1] + (1,)
    if s.ndim and s.shape == x.shape[:-1]:
        s = s[..., None]
    s4, _ = _as_4d(jnp.broadcast_to(s, target))
    return s4


def _expert_col_scale(s, e: int, n: int) -> jax.Array:
    """Broadcast per-expert weight scales to the kernel's (E, 1, N) layout.

    Accepts a scalar (shared), (E,) per-expert-tensor scales (vmapped
    tensor granularity), or (E, 1, N) per-expert-channel scales (vmapped
    channel granularity)."""
    s = jnp.asarray(s, jnp.float32)
    if s.ndim == 0:
        return jnp.broadcast_to(s, (e, 1, n))
    if s.ndim == 1:
        return jnp.broadcast_to(s[:, None, None], (e, 1, n))
    return jnp.broadcast_to(s.reshape(e, 1, -1), (e, 1, n))


def grouped_ovp_matmul(x: Union[jax.Array, QuantizedTensor],
                       w: QuantizedTensor, *,
                       a_dtype: Optional[str] = None,
                       act_scale: Optional[jax.Array] = None,
                       static_act_scale: Union[float, jax.Array,
                                               None] = None,
                       out_dtype=jnp.float32, interpret: bool = False,
                       bm: int = 128, bn: int = 128,
                       bk: int = 256) -> jax.Array:
    """Single-dispatch grouped matmul: (…, E, C, K) @ (E, K, N) -> (…, E, C, N).

    The per-expert mirror of `fused_ovp_matmul`: stacked packed weights ride
    an expert grid dim, per-expert scales apply in the accumulator epilogue,
    and the same activation modes are supported — fp lhs (weight-only, the
    MoE expert-einsum default), in-kernel OVP quantization when `a_dtype` +
    `act_scale` (or the constant `static_act_scale`) are set, or
    pre-quantized codes. Any dims left of (E, C, K) fold into the batch
    grid dim.
    """
    e, n = w.data.shape[0], w.data.shape[-1]
    sw = _expert_col_scale(w.scale, e, n)
    static = False
    if isinstance(x, QuantizedTensor):
        a_mode = "codes4" if x.is_packed else "codes8"
        a4, lead = _as_4d(x.data)
        sa4 = _expert_row_scale(x.scale, x.data)
        a_dtype = x.normal_dtype
    elif a_dtype is not None:
        if static_act_scale is not None:
            a_mode = "quantize"
            a4, lead = _as_4d(x)
            sa4 = jnp.asarray(static_act_scale,
                              jnp.float32).reshape(1, 1, 1)
            static = True
        elif act_scale is None:
            raise ValueError("in-kernel activation quantization needs an "
                             "act_scale (per-tensor or per-slot) or a "
                             "static_act_scale constant")
        else:
            a_mode = "quantize"
            a4, lead = _as_4d(x)
            sa4 = _expert_row_scale(act_scale, x)
    else:
        a_mode = "fp"
        a4, lead = _as_4d(x)
        sa4 = jnp.ones(a4.shape[:-1] + (1,), jnp.float32)
        a_dtype = w.normal_dtype
    out = _grouped_padded(a4, sa4, w.data, sw, w_dtype=w.normal_dtype,
                          a_mode=a_mode, a_dtype=a_dtype, a_static=static,
                          out_dtype=out_dtype, interpret=interpret,
                          bm=bm, bn=bn, bk=bk)
    return out.reshape(*lead, *out.shape[-3:]) if lead else out[0]


# --------------------------------------------------------------------------
# Shape-level wrappers (kernel benchmarks / tests drive these directly)
# --------------------------------------------------------------------------
def matmul_w4a16(a: jax.Array, w_data: jax.Array, w_scale: jax.Array,
                 normal_dtype: str = "int4", out_dtype=jnp.float32,
                 interpret: bool = False, bm: int = 128, bn: int = 128,
                 bk: int = 256) -> jax.Array:
    """a (M, K) fp @ packed w (K/2, N): decode + scales fused in-kernel."""
    m = a.shape[0]
    n = w_data.shape[1]
    return _fused_padded(a[None], jnp.ones((1, m, 1), jnp.float32),
                         w_data, _col_scale(w_scale, n),
                         w_dtype=normal_dtype, a_mode="fp",
                         a_dtype=normal_dtype, out_dtype=out_dtype,
                         interpret=interpret, bm=bm, bn=bn, bk=bk)[0]


def matmul_w4a4(a_data: jax.Array, a_scale: jax.Array, w_data: jax.Array,
                w_scale: jax.Array, normal_dtype: str = "int4",
                out_dtype=jnp.float32, interpret: bool = False,
                bm: int = 128, bn: int = 128, bk: int = 256) -> jax.Array:
    """packed a (M, K/2) @ packed w (K/2, N): decode + scales in-kernel."""
    n = w_data.shape[1]
    return _fused_padded(a_data[None], _row_scale(a_scale, a_data),
                         w_data, _col_scale(w_scale, n),
                         w_dtype=normal_dtype, a_mode="codes4",
                         a_dtype=normal_dtype, out_dtype=out_dtype,
                         interpret=interpret, bm=bm, bn=bn, bk=bk)[0]


def matmul_w8a8(a_data: jax.Array, a_scale: jax.Array, w_data: jax.Array,
                w_scale: jax.Array, out_dtype=jnp.float32,
                interpret: bool = False, bm: int = 128, bn: int = 128,
                bk: int = 256) -> jax.Array:
    """int8 OVP codes a (M, K) @ w codes (K, N), one code per byte."""
    n = w_data.shape[1]
    return _fused_padded(a_data[None], _row_scale(a_scale, a_data),
                         w_data, _col_scale(w_scale, n),
                         w_dtype="int8", a_mode="codes8", a_dtype="int8",
                         out_dtype=out_dtype, interpret=interpret,
                         bm=bm, bn=bn, bk=bk)[0]


def ovp_matmul(a: Union[jax.Array, QuantizedTensor], w: QuantizedTensor,
               out_dtype=jnp.float32, interpret: bool = False) -> jax.Array:
    """Public entry: dispatch from operand types (4-bit packed or int8 OVP).

    Leading batch dims of `a` ride the kernel's batch grid dim. Weight
    pairs must run along K (pair_axis == 0 of the 2-D weight).
    """
    return fused_ovp_matmul(a, w, out_dtype=out_dtype, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("normal_dtype", "interpret",
                                             "bm", "bk"))
def ovp_encode(x: jax.Array, scale: jax.Array, normal_dtype: str = "int4",
               interpret: bool = False, bm: int = 256,
               bk: int = 512) -> jax.Array:
    """x (M, K) real values -> packed OVP bytes (M, K/2) at `scale`.

    Standalone encoder (KV-cache packing, tests). The serving matmul path
    does NOT use it — activation quantization runs in the fused matmul
    prologue instead.
    """
    m, k = x.shape
    u = x.astype(jnp.float32) / scale
    bm_, bk_ = min(bm, m), min(bk, k)
    up = _pad_to(u, (bm_, bk_))
    out = _enc.ovp_encode_pallas(up, normal_dtype, bm=bm_, bk=bk_,
                                 interpret=interpret)
    return out[:m, :k // 2]
