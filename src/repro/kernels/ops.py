"""Jit'd public wrappers around the Pallas kernels.

Handles: shape padding to block multiples, scale application (kernels work
in scaled units), QuantizedTensor plumbing, and the interpret switch (CPU
validation vs TPU execution).
"""
from __future__ import annotations

import functools
from typing import Optional, Union

import jax
import jax.numpy as jnp

from repro.core.ovp import QuantizedTensor
from . import ovp_matmul as _mm
from . import ovp_encode as _enc


def _pad_to(x: jax.Array, mults, value=0):
    pads = []
    for d, m in zip(x.shape, mults):
        rem = (-d) % m
        pads.append((0, rem))
    if all(p == (0, 0) for p in pads):
        return x
    return jnp.pad(x, pads, constant_values=value)


def _block_sizes(m, n, k, bm, bn, bk):
    """Clamp block sizes to the (padded) problem size."""
    bm = min(bm, max(8, m))
    bn = min(bn, max(128, n)) if n >= 128 else n
    bk = min(bk, k)
    return bm, bn, bk


@functools.partial(jax.jit, static_argnames=("normal_dtype", "out_dtype",
                                             "interpret", "bm", "bn", "bk"))
def matmul_w4a16(a: jax.Array, w_data: jax.Array, w_scale: jax.Array,
                 normal_dtype: str = "int4", out_dtype=jnp.float32,
                 interpret: bool = False, bm: int = 128, bn: int = 128,
                 bk: int = 256) -> jax.Array:
    """a (M, K) fp @ packed w (K/2, N): decode fused into the kernel."""
    m, k = a.shape
    k2, n = w_data.shape
    # pad to block multiples; packed pad byte 0x00 decodes to (0, 0)
    ap = _pad_to(a, (bm, bk))
    wp = _pad_to(w_data, (bk // 2, bn))
    out = _mm.ovp_matmul_w4a16(ap, wp, normal_dtype,
                               bm=bm, bn=bn, bk=bk, interpret=interpret)
    out = out[:m, :n]
    return (out * w_scale.reshape(1, -1) if w_scale.ndim else
            out * w_scale).astype(out_dtype)


@functools.partial(jax.jit, static_argnames=("normal_dtype", "out_dtype",
                                             "interpret", "bm", "bn", "bk"))
def matmul_w4a4(a_data: jax.Array, a_scale: jax.Array, w_data: jax.Array,
                w_scale: jax.Array, normal_dtype: str = "int4",
                out_dtype=jnp.float32, interpret: bool = False,
                bm: int = 128, bn: int = 128, bk: int = 256) -> jax.Array:
    """packed a (M, K/2) @ packed w (K/2, N), both decoded in-kernel."""
    m, k2a = a_data.shape
    k2, n = w_data.shape
    ap = _pad_to(a_data, (bm, bk // 2))
    wp = _pad_to(w_data, (bk // 2, bn))
    out = _mm.ovp_matmul_w4a4(ap, wp, normal_dtype,
                              bm=bm, bn=bn, bk=bk, interpret=interpret)
    out = out[:m, :n]
    sa = a_scale if a_scale.ndim == 0 else a_scale.reshape(m, 1)
    sw = w_scale if w_scale.ndim == 0 else w_scale.reshape(1, -1)
    return (out * sa * sw).astype(out_dtype)


def ovp_matmul(a: Union[jax.Array, QuantizedTensor], w: QuantizedTensor,
               out_dtype=jnp.float32, interpret: bool = False) -> jax.Array:
    """Public entry: dispatch W4A16 vs W4A4 from the operand types.

    Leading batch dims of `a` are flattened into M. Weight pairs must run
    along K (pair_axis == 0 of the 2-D weight).
    """
    if w.normal_dtype == "int8":
        raise NotImplementedError("packed kernels are 4-bit; int8 OVP uses "
                                  "the XLA path")
    if isinstance(a, QuantizedTensor):
        ad, ascale = a.data, jnp.asarray(a.scale)
        lead = ad.shape[:-1]
        m = 1
        for d in lead:
            m *= d
        out = matmul_w4a4(ad.reshape(m, ad.shape[-1]),
                          jnp.broadcast_to(ascale, ()).astype(jnp.float32)
                          if ascale.ndim == 0 else ascale.reshape(-1),
                          w.data, jnp.asarray(w.scale).reshape(-1)
                          if jnp.asarray(w.scale).ndim else
                          jnp.asarray(w.scale),
                          w.normal_dtype, out_dtype, interpret)
        return out.reshape(*lead, out.shape[-1])
    lead = a.shape[:-1]
    m = 1
    for d in lead:
        m *= d
    a2 = a.reshape(m, a.shape[-1])
    ws = jnp.asarray(w.scale)
    out = matmul_w4a16(a2, w.data,
                       ws.reshape(-1) if ws.ndim else ws,
                       w.normal_dtype, out_dtype, interpret)
    return out.reshape(*lead, out.shape[-1])


@functools.partial(jax.jit, static_argnames=("normal_dtype", "interpret",
                                             "bm", "bk"))
def ovp_encode(x: jax.Array, scale: jax.Array, normal_dtype: str = "int4",
               interpret: bool = False, bm: int = 256,
               bk: int = 512) -> jax.Array:
    """x (M, K) real values -> packed OVP bytes (M, K/2) at `scale`."""
    m, k = x.shape
    u = x.astype(jnp.float32) / scale
    bm_, bk_ = min(bm, m), min(bk, k)
    up = _pad_to(u, (bm_, bk_))
    out = _enc.ovp_encode_pallas(up, normal_dtype, bm=bm_, bk=bk_,
                                 interpret=interpret)
    return out[:m, :k // 2]
