"""Cell construction for the multi-pod dry-run: ShapeDtypeStruct inputs,
shardings, and the step function for every (arch × shape × mesh [× quant])
combination. Shared by dryrun.py, the roofline harness, and launch CLIs.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import get_config, get_shape
from repro.configs.base import ArchConfig, ShapeCfg, shape_applicable
from repro.core.policy import QuantPolicy
from repro.core.qlinear import quantize_params
from repro.models.model import Model, build_model
from repro.optim.adamw import AdamW
from repro.sharding import axes as ax
from repro.sharding.rules import (cache_pspecs, make_rules, params_pspecs,
                                  use_dp_only)
from repro.train.train_step import init_state, make_train_step


@dataclasses.dataclass
class Cell:
    arch: str
    shape: str
    kind: str                      # train | prefill | decode
    fn: Callable                   # jit-able step function
    args_sds: Tuple                # ShapeDtypeStruct pytrees
    in_shardings: Tuple
    out_shardings: Any
    mesh: Mesh
    rules: Dict[str, Any]
    model_flops: float             # global useful FLOPs per step
    n_chips: int
    note: str = ""


def _named(mesh, spec_tree):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))


def microbatches_for(cfg: ArchConfig, shape: ShapeCfg) -> int:
    """Grad-accumulation depth: keep per-microbatch activation memory
    bounded (~0.5 GB/chip at d_model 4k). Static policy, CLI-overridable."""
    if shape.kind != "train":
        return 1
    big = cfg.d_model >= 4096 or cfg.n_layers >= 48 or cfg.n_experts >= 64
    return 8 if big else 4


def serve_policy(quant: str, n_layers: int = 0, calibration=None):
    """Policy (or policy program, for the mixed presets) for one serve
    cell. Program presets need the layer count to address first/last.

    `calibration` — a `CalibrationArtifact` or a path to one — switches
    every rule to `act_scale_mode="static"` and bakes the artifact's
    per-site scales in (`apply_calibration`), so the cell's quantized
    matmuls run the static prologue with zero per-step scale computation
    (see docs/calibration.md).
    """
    from repro.core.policy import PROGRAM_PRESETS, get_program
    if quant in PROGRAM_PRESETS:
        policy = get_program(quant, n_layers=n_layers) \
            .replace_all(compute_dtype="bfloat16")
    elif quant == "none":
        policy = QuantPolicy(compute_dtype="bfloat16")
    elif quant == "olive":        # paper-faithful W4A4 serving
        policy = QuantPolicy(method="olive", wbits=4, abits=4,
                             compute_dtype="bfloat16")
    elif quant == "olive_kv":     # beyond-paper: + OVP int4 KV cache
        policy = QuantPolicy(method="olive", wbits=4, abits=4, kv_bits=4,
                             compute_dtype="bfloat16")
    elif quant == "olive_w8":
        policy = QuantPolicy(method="olive", wbits=8, abits=8,
                             w_normal_dtype="int8",
                             compute_dtype="bfloat16")
    else:
        raise ValueError(quant)
    if calibration is not None:
        from repro.core.calibration import (CalibrationArtifact,
                                            apply_calibration)
        if isinstance(calibration, str):
            calibration = CalibrationArtifact.load(calibration)
        policy = apply_calibration(
            policy.replace_all(act_scale_mode="static"), calibration)
    return policy


def _batch_spec(mesh, rules, cfg: ArchConfig, shape: ShapeCfg,
                kind: str) -> Dict[str, Any]:
    b_rule = rules["batch"]
    gb, s = shape.global_batch, shape.seq_len
    specs: Dict[str, Any] = {}
    sds: Dict[str, Any] = {}
    if kind == "decode":
        sds["tokens"] = jax.ShapeDtypeStruct((gb, 1), jnp.int32)
        specs["tokens"] = P(b_rule, None)
        sds["pos"] = jax.ShapeDtypeStruct((gb,), jnp.int32)
        specs["pos"] = P(b_rule)
        return sds, specs
    sds["tokens"] = jax.ShapeDtypeStruct((gb, s), jnp.int32)
    specs["tokens"] = P(b_rule, None)
    if kind == "train":
        sds["labels"] = jax.ShapeDtypeStruct((gb, s), jnp.int32)
        specs["labels"] = P(b_rule, None)
    if cfg.frontend == "vit":
        sds["patch_embeds"] = jax.ShapeDtypeStruct(
            (gb, cfg.n_frontend_tokens, cfg.frontend_dim), jnp.bfloat16)
        specs["patch_embeds"] = P(b_rule, None, None)
    if cfg.frontend == "audio":
        sds["frames"] = jax.ShapeDtypeStruct((gb, s, cfg.frontend_dim),
                                             jnp.bfloat16)
        specs["frames"] = P(b_rule, None, None)
    return sds, specs


def build_train_cell(arch: str, shape_name: str, mesh: Mesh, *,
                     n_microbatches: Optional[int] = None,
                     remat: bool = True) -> Cell:
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    assert shape.kind == "train"
    dp_only = use_dp_only(cfg, mesh, shape.global_batch)
    rules = make_rules(cfg, mesh, global_batch=shape.global_batch)
    nm = n_microbatches or microbatches_for(cfg, shape)
    if dp_only:
        nm = 1  # one sequence per chip already
    policy = QuantPolicy(compute_dtype="bfloat16")
    model = build_model(cfg, policy, remat=remat)
    opt = AdamW(lr=1e-4, moment_dtype=jnp.bfloat16)

    state_sds = jax.eval_shape(
        lambda: init_state(model, opt, jax.random.PRNGKey(0),
                           dtype=jnp.float32))
    pspecs = params_pspecs(state_sds.params, cfg, mesh, dp_only=dp_only)
    state_specs = type(state_sds)(
        params=pspecs,
        opt=type(state_sds.opt)(step=P(),
                                mu=pspecs, nu=pspecs))
    batch_sds, batch_specs = _batch_spec(mesh, rules, cfg, shape, "train")

    step = make_train_step(model, opt, n_microbatches=nm)

    def train_step(state, batch):
        with ax.axis_rules(mesh, rules):
            return step(state, batch)

    metrics_specs = {"loss": P(), "ce": P(), "aux": P(),
                     "grad_norm": P(), "lr": P()}
    n_tokens = shape.global_batch * shape.seq_len
    return Cell(
        arch=arch, shape=shape_name, kind="train", fn=train_step,
        args_sds=(state_sds, batch_sds),
        in_shardings=(_named(mesh, state_specs), _named(mesh, batch_specs)),
        out_shardings=(_named(mesh, state_specs),
                       _named(mesh, metrics_specs)),
        mesh=mesh, rules=rules,
        model_flops=6.0 * cfg.active_param_count() * n_tokens,
        n_chips=mesh.devices.size,
        note=f"microbatches={nm}, remat={remat}, moments=bf16, grads=bf16"
             + (", dp_only(FSDP)" if dp_only else ""),
    )


def build_serve_cell(arch: str, shape_name: str, mesh: Mesh, *,
                     quant: str = "none", calibration=None) -> Cell:
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    assert shape.kind in ("prefill", "decode")
    long_ctx = shape.name == "long_500k"
    rules = make_rules(cfg, mesh, long_context=long_ctx)
    policy = serve_policy(quant, n_layers=cfg.n_layers,
                          calibration=calibration)
    model = build_model(cfg, policy, remat=False)

    params_sds = jax.eval_shape(
        lambda: model.init(jax.random.PRNGKey(0), dtype=jnp.bfloat16))
    if policy.enabled:
        params_sds = jax.eval_shape(
            lambda p: quantize_params(p, policy), params_sds)
    pspecs = params_pspecs(params_sds, cfg, mesh)

    gb, s = shape.global_batch, shape.seq_len
    enc_len = s if cfg.enc_dec else 0
    caches_sds = jax.eval_shape(
        lambda: model.init_caches(gb, s, enc_len=enc_len,
                                  dtype=jnp.bfloat16))
    cspecs = cache_pspecs(caches_sds, cfg, mesh, long_context=long_ctx)
    batch_sds, batch_specs = _batch_spec(mesh, rules, cfg, shape,
                                         shape.kind)
    b_rule = rules["batch"]
    logit_spec = P(b_rule, None, rules["vocab"])

    if shape.kind == "prefill":
        def fn(params, caches, batch):
            with ax.axis_rules(mesh, rules):
                logits, new_caches, _ = model.forward(
                    params, batch, mode="prefill", caches=caches,
                    last_only=True)
            return logits, new_caches
        # prefill of an audio enc-dec feeds frames, not tokens
        if cfg.enc_dec:
            batch_sds = dict(batch_sds)
            batch_sds["tokens"] = jax.ShapeDtypeStruct((gb, s), jnp.int32)
        model_flops = 2.0 * cfg.active_param_count() * gb * s
    else:
        def fn(params, caches, batch):
            with ax.axis_rules(mesh, rules):
                logits, new_caches, _ = model.forward(
                    params, batch, mode="decode", caches=caches)
            return logits, new_caches
        model_flops = 2.0 * cfg.active_param_count() * gb

    return Cell(
        arch=arch, shape=shape_name, kind=shape.kind, fn=fn,
        args_sds=(params_sds, caches_sds, batch_sds),
        in_shardings=(_named(mesh, pspecs), _named(mesh, cspecs),
                      _named(mesh, batch_specs)),
        out_shardings=(_named(mesh, logit_spec), _named(mesh, cspecs)),
        mesh=mesh, rules=rules,
        model_flops=model_flops,
        n_chips=mesh.devices.size,
        note=f"quant={quant}, kv_bits={policy.kv_bits}"
             + (", static_act_scales" if calibration is not None else ""),
    )


def build_cell(arch: str, shape_name: str, mesh: Mesh, *,
               quant: str = "none", calibration=None,
               n_microbatches: Optional[int] = None) -> Cell:
    shape = get_shape(shape_name)
    if shape.kind == "train":
        return build_train_cell(arch, shape_name, mesh,
                                n_microbatches=n_microbatches)
    return build_serve_cell(arch, shape_name, mesh, quant=quant,
                            calibration=calibration)


def lower_cell(cell: Cell):
    jitted = jax.jit(cell.fn, in_shardings=cell.in_shardings,
                     out_shardings=cell.out_shardings)
    return jitted.lower(*cell.args_sds)
