"""Production serving launcher.

Loads (or trains a throwaway) model for --arch, applies the OliVe PTQ
policy — a flat preset, a named mixed-precision *policy program* preset
(`olive_mixed_w48`, `olive_owq_style`), and/or ad-hoc site rules — and
runs the continuous-batching engine on a synthetic request stream.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-0.5b-smoke \
      --quant olive_serve --requests 16
  PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-0.5b-smoke \
      --quant olive_mixed_w48 \
      --policy-rules "layers/1/mlp/*=olive_w8a8" --requests 16

Static calibrated activation scales (docs/calibration.md) — one command
calibrates on a synthetic batch, saves the artifact, and serves on it:

  PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-0.5b-smoke \
      --quant olive_w4a4 --calibrate --calibration /tmp/calib.json \
      --requests 8

Re-serving from a saved artifact skips the calibration pass:

  PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-0.5b-smoke \
      --quant olive_w4a4 --calibration /tmp/calib.json --requests 8

Paged KV cache (docs/kv_cache.md) — block-table page pool instead of the
(slots, max_len) slab, fused cache-write prefill, optional chunked
prefill interleaved with decode:

  PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-0.5b-smoke \
      --quant olive_serve --paged 16 --prefill-chunk 32 --requests 16

Async streaming serve (docs/serving.md) — the asyncio front end drives
the same engine step: per-request token streams (`--stream` prints each
token the step it is sampled), TTFT/TPOT SLO metrics per step, and a
JSONL metrics trace the benchmarks consume:

  PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-0.5b-smoke \
      --quant olive_serve --paged 16 --prefill-chunk 32 --requests 16 \
      --async --stream --metrics-out /tmp/serve_trace.jsonl
"""
from __future__ import annotations

import argparse
import asyncio
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import backends
from repro.configs import get_config
from repro.core.calibration import (CalibrationArtifact, apply_calibration,
                                    calibrate_model)
from repro.core.policy import (PRESETS, PROGRAM_PRESETS, get_policy,
                               get_program, parse_rules)
from repro.core.qlinear import quantize_params
from repro.models.model import build_model
from repro.serve.engine import EngineCfg, Request, ServingEngine
from repro.serve.frontend import AsyncFrontend
from repro.serve.metrics import MetricsLedger
from repro.serve.paging import PagePoolCfg


async def _serve_async(eng, prompts, max_new, metrics, stream_tokens):
    """Drive the engine through the asyncio streaming front end: submit
    every prompt, consume each token stream as tokens arrive (printing
    per token when --stream), drain, and return the completed requests
    in the same token-for-token order the drained loop would produce."""

    async def consume(stream):
        seen = 0
        async for tok in stream:
            if stream_tokens:
                tag = "first" if seen == 0 else f"+{seen}"
                print(f"[stream] uid={stream.uid} {tag} token={tok}")
            seen += 1
        if stream_tokens:
            print(f"[stream] uid={stream.uid} done "
                  f"({len(stream.tokens)} tokens, {stream.finish_reason})")

    async with AsyncFrontend(eng, metrics=metrics) as fe:
        streams = [fe.submit(p, max_new_tokens=max_new) for p in prompts]
        await asyncio.gather(*(consume(s) for s in streams))
    return list(eng.completed)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--quant", default="olive_w4",
                    choices=sorted(PRESETS) + sorted(PROGRAM_PRESETS)
                    + ["fp"],
                    help="PTQ policy or policy-program preset for the "
                         "weights/KV")
    ap.add_argument("--policy-rules", default=None,
                    help="extra site rules prepended to the program, "
                         "e.g. 'layers/0/*=olive_w8a8,*mlp*=olive_w4a4' "
                         "(see docs/policies.md)")
    ap.add_argument("--backend", default=None,
                    choices=backends.available(),
                    help="quantized-matmul execution backend "
                         "(default: the policy's; CPU smoke runs can use "
                         "pallas_interpret to exercise the fused kernel)")
    ap.add_argument("--calibration", default=None, metavar="PATH",
                    help="CalibrationArtifact JSON: serve with static "
                         "calibrated activation scales "
                         "(act_scale_mode='static' on every quantized "
                         "site; see docs/calibration.md)")
    ap.add_argument("--calibrate", action="store_true",
                    help="calibrate-then-serve: run the §3.4 calibration "
                         "pass on a synthetic batch first, save the "
                         "artifact to --calibration PATH, then serve on "
                         "it (one command end to end)")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--paged", type=int, default=0, metavar="PAGE_SIZE",
                    help="serve on the paged KV cache: a block-table "
                         "page pool with this page size instead of the "
                         "(slots, max_len) slab; prefill writes pages "
                         "through the fused cache-write kernel (see "
                         "docs/kv_cache.md)")
    ap.add_argument("--prefill-chunk", type=int, default=0,
                    help="paged mode: split long prompts into chunks of "
                         "this many tokens, interleaved with decode "
                         "steps (at most one chunk per step)")
    ap.add_argument("--async", dest="use_async", action="store_true",
                    help="serve through the asyncio streaming front end "
                         "(serve/frontend.py): continuous intake, "
                         "per-request token streams, step-level TTFT/"
                         "TPOT SLO metrics (see docs/serving.md)")
    ap.add_argument("--stream", action="store_true",
                    help="async mode: print every token the step it is "
                         "sampled (one line per request completion too)")
    ap.add_argument("--mesh", default=None, metavar="DATA,MODEL",
                    help="comma-separated mesh axis sizes for the "
                         "sharded backends, e.g. '4,2' for a "
                         "(data=4, model=2) mesh over 8 devices "
                         "(XLA_FLAGS=--xla_force_host_platform_device_"
                         "count=8 forces logical CPU devices). Installs "
                         "the mesh via backends.configure_mesh so "
                         "--backend pallas_sharded[_interpret] "
                         "tensor/expert/KV-shards the quantized serve "
                         "path (see docs/sharding.md)")
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="write the step/request JSONL metrics trace "
                         "(serve/metrics.py vocabulary) to PATH; works "
                         "in both the drained loop and --async mode")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    if args.calibrate and not args.calibration:
        ap.error("--calibrate needs --calibration PATH to save into")
    if args.prefill_chunk and not args.paged:
        ap.error("--prefill-chunk requires --paged (chunked prefill is "
                 "a paged-cache feature)")
    if args.stream and not args.use_async:
        ap.error("--stream requires --async (the drained loop has no "
                 "token streams)")

    cfg = get_config(args.arch)
    if args.quant in PROGRAM_PRESETS or args.policy_rules:
        policy = get_program(None if args.quant == "fp" else args.quant,
                             n_layers=cfg.n_layers)
        if args.policy_rules:
            policy = policy.with_rules(parse_rules(args.policy_rules))
    else:
        policy = get_policy(None if args.quant == "fp" else args.quant)
    # CPU engine default: weight + KV quant only (replace_all rewrites
    # every rule of a program, or the one flat policy). A calibration
    # artifact keeps the preset's abits — static scales exist precisely to
    # serve quantized activations without per-step scale computation.
    if args.calibration:
        policy = policy.replace_all(compute_dtype="float32",
                                    act_scale_mode="static")
    else:
        policy = policy.replace_all(compute_dtype="float32", abits=0)
    if args.backend is not None:
        policy = policy.with_backend(args.backend)
    print(f"[serve] quantized-matmul backend(s): "
          f"{', '.join(sorted(policy.backends()))}")
    model = build_model(cfg, policy, remat=False)
    params = model.init(jax.random.PRNGKey(args.seed), dtype=jnp.float32)

    if args.calibration:
        if args.calibrate:
            rng = np.random.default_rng(args.seed)
            batch = {"tokens": jnp.asarray(rng.integers(
                0, cfg.vocab, size=(2, 64)).astype(np.int32))}
            t0 = time.time()
            artifact = calibrate_model(model, params, [batch])
            artifact.save(args.calibration)
            print(f"[serve] calibrated {len(artifact.sites())} sites in "
                  f"{time.time()-t0:.1f}s -> {args.calibration}")
        else:
            if not os.path.exists(args.calibration):
                ap.error(f"--calibration {args.calibration} does not "
                         f"exist; pass --calibrate to create it")
            artifact = CalibrationArtifact.load(args.calibration)
            print(f"[serve] loaded {len(artifact.sites())} static scales "
                  f"from {args.calibration}")
        policy = apply_calibration(policy, artifact)
        # per-layer scale rules address layers/<i>: rebuild so the model
        # unrolls to the layout the scales were calibrated on
        model = build_model(cfg, policy, remat=False)
        params = model.adapt_params(params)

    if policy.enabled:
        t0 = time.time()
        params = quantize_params(params, policy)
        print(f"[serve] PTQ ({args.quant}) in {time.time()-t0:.1f}s")

    mesh_plan = None
    if args.mesh:
        from repro.runtime.elastic import MeshPlan
        sizes = tuple(int(s) for s in args.mesh.split(","))
        if len(sizes) != 2 or any(s < 1 for s in sizes):
            ap.error(f"--mesh wants two positive sizes 'data,model', "
                     f"got {args.mesh!r}")
        if sizes[0] * sizes[1] > jax.device_count():
            ap.error(f"--mesh {args.mesh} needs {sizes[0] * sizes[1]} "
                     f"devices, have {jax.device_count()} (set "
                     f"XLA_FLAGS=--xla_force_host_platform_device_count"
                     f"=N before launch)")
        mesh_plan = MeshPlan(shape=sizes, axis_names=("data", "model"),
                             dropped_devices=0)
        print(f"[serve] mesh: data={sizes[0]} model={sizes[1]} over "
              f"{jax.device_count()} devices")

    page_pool = PagePoolCfg(page_size=args.paged) if args.paged else None
    eng = ServingEngine(model, params, EngineCfg(
        batch_slots=args.slots, max_len=args.max_len,
        page_pool=page_pool, prefill_chunk=args.prefill_chunk,
        mesh=mesh_plan))
    rng = np.random.default_rng(args.seed)
    prompts = [rng.integers(0, cfg.vocab,
                            size=int(rng.integers(4, 32)))
               .astype(np.int32) for _ in range(args.requests)]
    metrics = MetricsLedger() if (args.metrics_out or args.use_async) \
        else None
    t0 = time.time()
    if args.use_async:
        done = asyncio.run(_serve_async(eng, prompts, args.max_new,
                                        metrics, args.stream))
    else:
        for p in prompts:
            eng.submit(p, max_new_tokens=args.max_new)
        done = eng.run_until_drained(metrics=metrics)
    dt = time.time() - t0
    toks = sum(len(r.out_tokens) for r in done)
    lat = [r.t_done - r.t_submit for r in done]
    ttft = [r.t_first - r.t_submit for r in done if r.t_first]
    print(f"[serve] {len(done)} requests, {toks} tokens in {dt:.2f}s "
          f"({toks/dt:.1f} tok/s)")
    # latency and TTFT are independent metrics: an empty TTFT list (no
    # request ever recorded a first token) must not suppress the latency
    # line, so they print separately
    if lat:
        print(f"[serve] mean latency {np.mean(lat)*1e3:.0f} ms")
    if ttft:
        print(f"[serve] mean TTFT {np.mean(ttft)*1e3:.0f} ms")
    dec_stats = {k: v for k, v in backends.dispatch_stats().items()
                 if "[decode_attn]" in k or "[prefill_attn]" in k}
    if dec_stats:
        # which backend served each attention path per traced site — on
        # the pallas backends a packed KV cache must show zero fallbacks
        # (no full-cache dequant per step; see docs/kv_cache.md)
        print(f"[serve] attention dispatch: {dec_stats}")
    if args.paged:
        st = eng.stats()
        print(f"[serve] page pool: {st['page_pool']} "
              f"(prefill chunks: {st['prefill_chunks_run']})")
    if args.calibration:
        # the whole point of static serving: zero dynamic resolutions
        print(f"[serve] act-scale resolutions: {backends.act_scale_stats()}")
    if metrics is not None:
        snap = metrics.snapshot()

        def _fmt(d):
            if not d.get("n"):
                return "n=0"
            return (f"n={d['n']} mean={d['mean']*1e3:.1f}ms "
                    f"p50={d['p50']*1e3:.1f}ms p95={d['p95']*1e3:.1f}ms")

        print(f"[serve] SLO: TTFT {_fmt(snap['ttft_s'])} | "
              f"TPOT {_fmt(snap['tpot_s'])}")
        print(f"[serve] {snap['steps']} steps, fallbacks={snap['fallbacks']}"
              + (f", interleave={snap['prefill_interleave_ratio']:.2f}"
                 if snap["prefill_interleave_ratio"] is not None else ""))
        if args.metrics_out:
            metrics.write_jsonl(args.metrics_out)
            print(f"[serve] metrics trace -> {args.metrics_out}")


if __name__ == "__main__":
    main()
