"""Production training launcher.

Single-host (CPU/dev) and multi-host (TPU pod) entry point: builds the
mesh, shards the train state with the same rules the dry-run verified,
and runs the fault-tolerant trainer loop.

  PYTHONPATH=src python -m repro.launch.train --arch qwen1.5-0.5b-smoke \
      --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/run1

On a real pod, set --mesh to the production shape and launch one process
per host (jax.distributed.initialize is called when JAX_COORDINATOR is
set); on this CPU container the default mesh is 1x1.
"""
from __future__ import annotations

import argparse
import os

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_config
from repro.core.policy import PRESETS, QuantPolicy, get_policy
from repro.data.loader import LoaderCfg, SyntheticLoader
from repro.data.synthetic import CorpusCfg
from repro.models.model import build_model
from repro.optim.adamw import AdamW
from repro.train.trainer import Trainer, TrainerCfg
from repro.launch import mesh as meshmod


def parse_mesh(s: str):
    """'16x16' -> (data, model); '2x16x16' -> (pod, data, model)."""
    dims = tuple(int(d) for d in s.lower().split("x"))
    axes = ("pod", "data", "model")[-len(dims):]
    return meshmod.make_mesh(dims, axes)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--mesh", default="",
                    help="e.g. 16x16 or 2x16x16; default single-device")
    ap.add_argument("--quant", default=None, choices=sorted(PRESETS),
                    help="QAT policy (STE fake-quant in the fwd pass)")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--eval-every", type=int, default=0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    if os.environ.get("JAX_COORDINATOR"):
        jax.distributed.initialize()  # multi-host pod entry

    cfg = get_config(args.arch)
    policy = get_policy(args.quant)
    if policy.enabled:
        import dataclasses
        policy = dataclasses.replace(policy, qat=True)
    model = build_model(cfg, policy, remat=True)
    from repro.optim.adamw import cosine_schedule
    opt = AdamW(lr=cosine_schedule(args.lr, min(20, args.steps // 5),
                                   args.steps),
                moment_dtype=jnp.bfloat16)
    loader = SyntheticLoader(LoaderCfg(
        global_batch=args.batch, seq_len=args.seq,
        corpus=CorpusCfg(vocab=cfg.vocab)))
    tcfg = TrainerCfg(total_steps=args.steps, ckpt_dir=args.ckpt_dir,
                      ckpt_every=args.ckpt_every,
                      eval_every=args.eval_every,
                      n_microbatches=args.microbatches, seed=args.seed)

    if args.mesh:
        mesh = parse_mesh(args.mesh)
        from repro.launch.specs import build_train_cell
        from repro.train.train_step import init_state
        cell = build_train_cell(args.arch, "train_4k", mesh,
                                n_microbatches=args.microbatches)
        print(f"[train] mesh {mesh.devices.shape} {mesh.axis_names}; "
              f"sharded step verified by dry-run rules")
        trainer = Trainer(model, opt, loader, tcfg)
        trainer.step_fn = jax.jit(cell.fn, in_shardings=cell.in_shardings,
                                  out_shardings=cell.out_shardings)
    else:
        trainer = Trainer(model, opt, loader, tcfg)

    trainer.init_or_restore()
    hist = trainer.run()
    if hist["loss"]:
        print(f"[train] done: step {trainer.step}, "
              f"loss {hist['loss'][0]:.4f} -> {hist['loss'][-1]:.4f}")
    if args.eval_every or args.steps >= 20:
        print(f"[train] held-out ppl: {trainer.evaluate():.3f}")


if __name__ == "__main__":
    main()
