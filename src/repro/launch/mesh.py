"""Production mesh construction.

Defined as FUNCTIONS (never module-level constants) so importing this
module never touches jax device state — required because the dry-run must
set XLA_FLAGS before the first jax initialisation.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax

try:  # jax >= 0.4.31; older releases predate explicit axis types
    from jax.sharding import AxisType
except ImportError:  # pragma: no cover - depends on installed jax
    AxisType = None


def _axis_kwargs(n_axes: int) -> dict:
    if AxisType is None:
        return {}
    return {"axis_types": (AxisType.Auto,) * n_axes}


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single pod (256 chips) or 2x16x16 (512 chips, 2 pods)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_axis_kwargs(len(axes)))


def make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]):
    """Arbitrary mesh (tests use tiny ones, e.g. (2,2,2))."""
    return jax.make_mesh(shape, axes, **_axis_kwargs(len(axes)))


def batch_axes(mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)
