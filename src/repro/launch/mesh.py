"""Production mesh construction.

Defined as FUNCTIONS (never module-level constants) so importing this
module never touches jax device state — required because the dry-run must
set XLA_FLAGS before the first jax initialisation.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
from jax.sharding import AxisType


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single pod (256 chips) or 2x16x16 (512 chips, 2 pods)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(axes))


def make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]):
    """Arbitrary mesh (tests use tiny ones, e.g. (2,2,2))."""
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(axes))


def batch_axes(mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)
