import os
os.environ["XLA_FLAGS"] = (os.environ.get("DRYRUN_XLA_FLAGS")
                           or "--xla_force_host_platform_device_count=512")
# ^ MUST precede every other import: jax locks the device count on first
# initialisation. DRYRUN_XLA_FLAGS lets tests use fewer fake devices.

"""Multi-pod dry-run driver (deliverable e).

For every (architecture × input shape × mesh) cell:
  jax.jit(step, in_shardings, out_shardings).lower(**ShapeDtypeStructs)
      .compile()
then prints memory_analysis() / cost_analysis() and dumps the roofline
terms as JSON under EXPERIMENTS/dryrun/. Incremental: already-done cells
are skipped unless --force.

Usage:
  python -m repro.launch.dryrun                        # everything
  python -m repro.launch.dryrun --arch yi-6b --shape train_4k --mesh multi
  python -m repro.launch.dryrun --quant olive          # quantized serving
"""
import argparse
import json
import time
import traceback

import jax


def run_cell(arch: str, shape_name: str, mesh_kind: str, quant: str,
             out_dir: str, force: bool = False,
             mesh_override=None, calibration=None) -> dict:
    from repro.configs import get_config, get_shape
    from repro.configs.base import shape_applicable
    from repro.launch import mesh as meshmod
    from repro.launch.specs import build_cell, lower_cell
    from repro.roofline.analysis import analyze, count_collectives

    cfg = get_config(arch)
    shape = get_shape(shape_name)
    tag = f"{arch}__{shape_name}__{mesh_kind}__{quant}"
    path = os.path.join(out_dir, tag + ".json")
    if os.path.exists(path) and not force:
        with open(path) as f:
            return json.load(f)

    ok, reason = shape_applicable(cfg, shape)
    if not ok:
        rec = {"cell": tag, "status": "skipped", "reason": reason}
        _dump(path, rec)
        return rec
    if quant != "none" and shape.kind == "train":
        rec = {"cell": tag, "status": "skipped",
               "reason": "quantized variants are serving-only (PTQ)"}
        _dump(path, rec)
        return rec

    mesh = mesh_override if mesh_override is not None else \
        meshmod.make_production_mesh(multi_pod=(mesh_kind == "multi"))
    t0 = time.time()
    try:
        cell = build_cell(arch, shape_name, mesh, quant=quant,
                          calibration=calibration)
        lowered = lower_cell(cell)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        mem = compiled.memory_analysis()
        hlo_text = compiled.as_text()
        roof = analyze(compiled, cell.n_chips, cell.model_flops)
        colls = count_collectives(hlo_text)
        # save the optimized HLO so §Perf re-analysis (e.g. byte-model
        # changes) never needs a recompile
        import gzip
        os.makedirs(os.path.join(out_dir, "hlo"), exist_ok=True)
        with gzip.open(os.path.join(out_dir, "hlo", tag + ".hlo.gz"),
                       "wt") as hf:
            hf.write(hlo_text)
        rec = {
            "cell": tag, "status": "ok",
            "arch": arch, "shape": shape_name, "mesh": mesh_kind,
            "quant": quant, "kind": cell.kind, "note": cell.note,
            "n_chips": cell.n_chips,
            "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
            "memory_analysis": {
                "argument_size_per_chip": mem.argument_size_in_bytes,
                "output_size_per_chip": mem.output_size_in_bytes,
                "temp_size_per_chip": mem.temp_size_in_bytes,
                "alias_size_per_chip": mem.alias_size_in_bytes,
            },
            "collective_ops": colls,
            "roofline": roof.as_dict(),
        }
    except (ValueError, TypeError, KeyError, AttributeError,
            AssertionError, NotImplementedError, RuntimeError) as e:
        # a failing cell is a bug — record it loudly (RuntimeError covers
        # XlaRuntimeError: lowering/compile failures land here)
        rec = {"cell": tag, "status": "error",
               "error": f"{type(e).__name__}: {e}",
               "trace": traceback.format_exc()[-2000:]}
    _dump(path, rec)
    return rec


def _dump(path, rec):
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi",
                                                       "both"])
    ap.add_argument("--quant", default="none",
                    choices=["none", "olive", "olive_kv", "olive_w8"])
    ap.add_argument("--calibration", default=None, metavar="PATH",
                    help="CalibrationArtifact JSON: lower the quantized "
                         "serve cells with static calibrated activation "
                         "scales baked in (act_scale_mode='static'; see "
                         "docs/calibration.md). Ignored for --quant none "
                         "and train shapes.")
    ap.add_argument("--out", default="EXPERIMENTS/dryrun")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    from repro.configs import ARCHS, SHAPES
    archs = sorted(ARCHS) if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    n_ok = n_skip = n_err = 0
    for arch in archs:
        for shape in shapes:
            for mk in meshes:
                rec = run_cell(arch, shape, mk, args.quant, args.out,
                               force=args.force,
                               calibration=args.calibration)
                st = rec["status"]
                n_ok += st == "ok"
                n_skip += st == "skipped"
                n_err += st == "error"
                line = f"[dryrun] {rec['cell']}: {st}"
                if st == "ok":
                    r = rec["roofline"]
                    line += (f"  bottleneck={r['bottleneck']}"
                             f" t_bound={r['t_bound_s']:.4g}s"
                             f" compile={rec['compile_s']:.0f}s")
                    print(line)
                    print("   memory_analysis:",
                          json.dumps(rec["memory_analysis"]))
                    print("   cost: flops/chip=%.4g bytes/chip=%.4g "
                          "coll_bytes/chip=%.4g" % (
                              r["flops_per_chip"], r["bytes_per_chip"],
                              r["coll_bytes_per_chip"]))
                elif st == "skipped":
                    print(line + f"  ({rec['reason'][:70]}…)")
                else:
                    print(line + f"  {rec['error']}")
    print(f"[dryrun] done: {n_ok} ok, {n_skip} skipped, {n_err} errors")
    return 1 if n_err else 0


if __name__ == "__main__":
    raise SystemExit(main())
