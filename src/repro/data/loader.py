"""Stateless sharded data loader — restart-safe by construction.

The batch for (step, dp_rank) is a pure function of the run seed: after a
crash/preemption the trainer resumes at `step` and every rank regenerates
exactly the batch it would have seen, with no iterator state to checkpoint.
Also the hook point for real corpora: any array-backed source implementing
`batch_at(step, rank)` drops in.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .synthetic import CorpusCfg, sample_batch


@dataclasses.dataclass(frozen=True)
class LoaderCfg:
    global_batch: int
    seq_len: int
    n_ranks: int = 1           # data-parallel ranks
    corpus: CorpusCfg = CorpusCfg()
    eval_offset: int = 1 << 30  # held-out doc-id range


class SyntheticLoader:
    def __init__(self, cfg: LoaderCfg):
        assert cfg.global_batch % cfg.n_ranks == 0
        self.cfg = cfg
        self.per_rank = cfg.global_batch // cfg.n_ranks

    def doc_ids(self, step: int, rank: int, eval_split=False) -> jax.Array:
        base = step * self.cfg.global_batch + rank * self.per_rank
        if eval_split:
            base += self.cfg.eval_offset
        return jnp.arange(base, base + self.per_rank, dtype=jnp.int32)

    def batch_at(self, step: int, rank: int = 0,
                 eval_split: bool = False) -> Dict[str, jax.Array]:
        toks = sample_batch(self.cfg.corpus,
                            self.doc_ids(step, rank, eval_split),
                            self.cfg.seq_len + 1, self.per_rank)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def global_batch_at(self, step: int,
                        eval_split: bool = False) -> Dict[str, jax.Array]:
        """All ranks concatenated (single-process testing / pjit input)."""
        parts = [self.batch_at(step, r, eval_split)
                 for r in range(self.cfg.n_ranks)]
        return {k: jnp.concatenate([p[k] for p in parts])
                for k in parts[0]}

    def __iter__(self) -> Iterator[Dict[str, jax.Array]]:
        step = 0
        while True:
            yield self.global_batch_at(step)
            step += 1
