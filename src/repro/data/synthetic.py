"""Deterministic synthetic LM corpus with learnable structure.

A fixed-seed bigram transition table over the vocab (power-law unigram
marginals + strong bigram edges) generates token streams a small LM can
actually learn — held-out perplexity drops well below the unigram entropy,
which makes PTQ-quality deltas (the paper's Tbl. 9 analogue) measurable
without external datasets.

Sampling is **stateless**: token `j` of document `i` is a pure function of
(seed, i, j), so any worker can materialise any batch index — the property
the restart-safe loader relies on.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class CorpusCfg:
    vocab: int = 512
    seed: int = 1234
    branch: int = 4          # plausible next-tokens per token
    temperature: float = 0.35


def _tables(cfg: CorpusCfg):
    rng = np.random.default_rng(cfg.seed)
    # power-law unigram, random bigram successor sets
    succ = rng.integers(0, cfg.vocab, size=(cfg.vocab, cfg.branch))
    logit = rng.normal(size=(cfg.vocab, cfg.branch)) / cfg.temperature
    probs = np.exp(logit - logit.max(1, keepdims=True))
    probs /= probs.sum(1, keepdims=True)
    cum = np.cumsum(probs, axis=1)
    return jnp.asarray(succ, jnp.int32), jnp.asarray(cum, jnp.float32)


@partial(jax.jit, static_argnames=("cfg", "seq_len", "batch"))
def sample_batch(cfg: CorpusCfg, doc_ids: jax.Array, seq_len: int,
                 batch: int):
    """doc_ids: (batch,) int32 — deterministic documents. Returns tokens
    (batch, seq_len) int32 in [0, vocab)."""
    succ, cum = _tables(cfg)

    def doc(did):
        key = jax.random.fold_in(jax.random.PRNGKey(cfg.seed), did)
        k0, kseq = jax.random.split(key)
        t0 = jax.random.randint(k0, (), 0, cfg.vocab)
        us = jax.random.uniform(kseq, (seq_len,))

        def step(tok, u):
            row = cum[tok]
            idx = jnp.sum(u > row).astype(jnp.int32)
            nxt = succ[tok, jnp.minimum(idx, row.shape[0] - 1)]
            return nxt, nxt

        _, toks = jax.lax.scan(step, t0, us)
        return toks

    return jax.vmap(doc)(doc_ids)


def bigram_entropy(cfg: CorpusCfg) -> float:
    """Per-token entropy of the generator (nats) — the PPL floor."""
    _, cum = _tables(cfg)
    p = np.diff(np.concatenate([np.zeros((cum.shape[0], 1)),
                                np.asarray(cum)], axis=1), axis=1)
    h = -(p * np.log(np.maximum(p, 1e-12))).sum(1)
    return float(h.mean())
