from .loader import LoaderCfg, SyntheticLoader
from .synthetic import CorpusCfg, bigram_entropy, sample_batch
